"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time

import jax
import numpy as np


def compile_kws_full():
    """Compile the full Fig.-7 reconstruction once (shared by benches)."""
    from repro.core import compiler
    from repro.models import kws

    spec = kws.build_kws_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(
        spec, weights, thresholds,
        rotate_hints=kws.ROTATE_HINTS, rowsplit_hints=kws.ROWSPLIT_HINTS,
    )
    return spec, params, prog


def timed(fn, *args, repeats: int = 3, **kw):
    fn(*args, **kw)  # warmup / trace
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return out, (time.perf_counter() - t0) / repeats * 1e6  # us


def row(name: str, us_per_call: float | str, derived: str = "") -> str:
    return f"{name},{us_per_call},{derived}"
