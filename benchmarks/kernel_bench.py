"""Kernel micro-bench (beyond-paper, DESIGN.md §2.4): popcount vs MXU path.

On this CPU container the Pallas kernels run in interpret mode, so wall
times are NOT TPU-representative; what this bench contributes is (a) the
bytes-moved comparison (the bitpacked path's 16x weight compression), and
(b) the analytic v5e time model both paths are dispatched on, with the
measured-interpreted sanity timing alongside.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.kernels import ops


def run() -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    for m, k, n, tag in ((1, 1024, 512, "decode-ish"),
                         (256, 1024, 512, "batch-ish")):
        x = jnp.array(rng.integers(0, 2, (m, k)), jnp.uint32)
        w = jnp.array(rng.integers(-1, 2, (k, n)), jnp.int32)
        thr = jnp.zeros((n,), jnp.float32)
        flip = jnp.zeros((n,), bool)
        bytes_pop = m * k / 8 + 2 * k * n / 8
        bytes_mxu = m * k + k * n
        pick = ops.pick_path(m, k, n)
        _, us_pop = timed(ops.twm_linear, x, w, thr, flip, repeats=2)
        _, us_mxu = timed(ops.twm_linear_mxu, x, w, thr, flip, repeats=2)
        rows.append(row(
            f"kernel.{tag}.pick", pick,
            f"bytes_popcount={bytes_pop:.0f};bytes_mxu={bytes_mxu:.0f};"
            f"ratio={bytes_mxu / bytes_pop:.1f}x",
        ))
        rows.append(row(f"kernel.{tag}.interp_us_popcount", f"{us_pop:.0f}",
                        "CPU interpret mode (not TPU time)"))
        rows.append(row(f"kernel.{tag}.interp_us_mxu", f"{us_mxu:.0f}",
                        "CPU interpret mode (not TPU time)"))
    return rows
