"""Paper §III-A: network simulation result (92.53% on GSCD, 12 classes).

GSCD is unavailable offline (DESIGN.md §9.1): this benchmark trains the
binarized model briefly on the synthetic GSCD-like corpus and reports
(a) accuracy trend on held-out synthetic data, and (b) bit-exactness of the
CIM-executed inference vs the QAT forward — the claims our substrate can
actually validate.  The full training run lives in examples/kws_train.py;
here we keep it short enough for a benchmark pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import compiler
from repro.core.executor import Executor
from repro.data import gscd
from repro.models import kws
from repro.train import optimizer as opt_lib

STEPS = 30
BATCH = 24


def run() -> list[str]:
    # reduced-width model + shorter audio for benchmark-scale training
    spec = kws.build_kws_spec(in_len=4000, width=24)
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    ocfg = opt_lib.OptConfig(name="adamw", lr=2e-3, clip_norm=1.0)
    state = opt_lib.init_opt_state(ocfg, params)

    @jax.jit
    def step(state, params, x, y):
        loss, grads = jax.value_and_grad(kws.kws_loss)(params, x, y, spec)
        state, _ = opt_lib.update(ocfg, state, grads)
        params = opt_lib.cast_params_like(state["master"], params)
        return state, params, loss

    losses = []
    for i in range(STEPS):
        xb, yb = gscd.batch(seed=1, step=i, batch_size=BATCH, n=spec.in_len)
        state, params, loss = step(state, params, jnp.array(xb), jnp.array(yb))
        losses.append(float(loss))

    xe, ye = gscd.batch(seed=2, step=999, batch_size=64, n=spec.in_len)
    acc = float(kws.kws_accuracy(params, jnp.array(xe), jnp.array(ye), spec))

    # CIM-executed inference must match QAT bit-exactly
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(spec, weights, thresholds)
    ex = Executor(prog)
    n_match = 0
    for i in range(8):
        out = ex.run(xe[i][:, None]).output.ravel()
        qat = np.asarray(kws.kws_forward(params, jnp.array(xe[i]), spec))
        n_match += int(np.array_equal(out.astype(np.float64), qat))
    return [
        row("kws.loss_first", f"{losses[0]:.3f}", ""),
        row("kws.loss_last", f"{losses[-1]:.3f}",
            f"decreasing={losses[-1] < losses[0]}"),
        row("kws.synthetic_accuracy", f"{acc:.3f}",
            f"{STEPS} steps, reduced model; paper GSCD=0.9253 "
            "(full run: examples/kws_train.py)"),
        row("kws.cim_exec_bitexact", f"{n_match}/8", "executor vs QAT"),
    ]
