"""Paper Fig. 5: flexible vs conventional ping-pong feature SRAM.

(a) layer-by-layer fit check on the KWS model for both allocators,
(b) a large-feature-map case only the flexible scheme hosts (Fig. 5c),
(c) bank power-off accounting during the KWS run (Fig. 5d).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import compile_kws_full, row
from repro.core import isa
from repro.core.executor import Executor
from repro.core.pingpong import FixedPingPong, FmapRef, PingPongSRAM


def run() -> list[str]:
    spec, _, prog = compile_kws_full()
    rows = []

    # (a) fit check along the compiled program's PTR stream
    fixed = FixedPingPong()
    shapes = spec.trace_shapes()
    l, c = spec.in_len, spec.in_channels
    fmt = "u8" if spec.in_bits > 1 else "bits"
    fixed_ok = flex_ok = True
    for b, (ol, oc) in zip(prog.bindings, shapes):
        out_fmt = "u8" if getattr(b.spec, "out_raw", False) or b.spec.name == "gap" else "bits"
        ifm = FmapRef(b.ifm_addr, l, c, fmt)
        ofm = FmapRef(b.ofm_addr, ol, oc, out_fmt)
        fixed_ok &= fixed.fits(ifm, ofm)
        try:
            PingPongSRAM.check_layer(ifm, ofm)
        except MemoryError:
            flex_ok = False
        l, c, fmt = ol, oc, out_fmt
    rows.append(row("pingpong.kws_fits_flexible", flex_ok, ""))
    rows.append(row("pingpong.kws_fits_fixed", fixed_ok,
                    "KWS maps are exactly 128Kb; both schemes host them"))

    # (b) Fig. 5c: IFM > 128Kb fits flexibly, not in fixed halves
    big = FmapRef(0, 5000, 32, "bits")
    small = FmapRef(6144, 2000, 32, "bits")
    PingPongSRAM.check_layer(big, small)
    rows.append(row("pingpong.large_fmap_flexible", True,
                    "5000w IFM + 2000w OFM"))
    rows.append(row("pingpong.large_fmap_fixed", fixed.fits(big, small),
                    "fixed halves cap at 4096w"))

    # (c) Fig. 5d: power-off accounting
    x = np.random.default_rng(0).integers(0, 256, (spec.in_len, 1)).astype(np.uint8)
    rep = Executor(prog).run(x)
    active = rep.bank_active_cycles
    total = rep.ledger.cycles
    off_frac = 1.0 - active.sum() / (4.0 * total)
    rows.append(row("pingpong.bank_off_fraction", f"{off_frac:.2f}",
                    f"bank_active_cycles={active.tolist()};total={total}"))
    return rows
