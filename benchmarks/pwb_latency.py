"""Paper §II-H: pooling write-back fused vs independent (-35.9% latency).

Runs the reconstructed KWS model both ways through the cycle-accurate
executor, plus the pool-datapath-width sensitivity sweep (the paper does not
state the width; DESIGN.md §9).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import compile_kws_full, row
from repro.core import pwb
from repro.core.executor import Executor

PAPER_REDUCTION_PCT = 35.9


def run() -> list[str]:
    spec, _, prog = compile_kws_full()
    x = np.random.default_rng(0).integers(0, 256, (spec.in_len, 1)).astype(np.uint8)

    rows = []
    orig_width = pwb.POOL_UNIT_BITS
    try:
        for width in (32, 64, 128):
            pwb.POOL_UNIT_BITS = width
            fused = Executor(prog, fuse_pool=True).run(x).ledger.cycles
            indep = Executor(prog, fuse_pool=False).run(x).ledger.cycles
            red = 100.0 * (1 - fused / indep)
            tag = " (default)" if width == orig_width else ""
            rows.append(row(
                f"pwb.reduction_width{width}", f"{red:.1f}%",
                f"fused={fused}cyc;indep={indep}cyc;paper={PAPER_REDUCTION_PCT}%{tag}",
            ))
    finally:
        pwb.POOL_UNIT_BITS = orig_width
    return rows
