"""Aggregate the dry-run matrix (results/dryrun/*.json) into the roofline
table (EXPERIMENTS.md §Roofline).  Rows appear as cells complete; missing
cells are reported as pending."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import row

DRYRUN_DIR = pathlib.Path(__file__).resolve().parent.parent / "results" / "dryrun"


def run() -> list[str]:
    rows = []
    if not DRYRUN_DIR.exists():
        return [row("roofline.status", "no results yet",
                    "run: python -m repro.launch.dryrun")]
    cells = sorted(DRYRUN_DIR.glob("*.json"))
    n_ok = n_fail = n_skip = 0
    for path in cells:
        d = json.loads(path.read_text())
        name = f"{d['arch']}/{d['shape']}/{d['mesh']}"
        if d["status"] == "skip":
            n_skip += 1
            continue
        if d["status"] == "fail":
            n_fail += 1
            rows.append(row(f"roofline.{name}", "FAIL",
                            d.get("error", "")[:120]))
            continue
        n_ok += 1
        r = d["roofline"]
        frac = d.get("useful_flops_frac")
        rows.append(row(
            f"roofline.{name}",
            f"{r['step_s_lower_bound']:.4f}s",
            f"dom={r['dominant']};c={r['compute_s']:.3f};m={r['memory_s']:.3f};"
            f"coll={r['collective_s']:.3f};peak_gb={d['mem']['peak_gb']:.1f};"
            f"useful={frac:.2f}" if frac else "",
        ))
    rows.insert(0, row("roofline.cells", f"{n_ok}ok/{n_fail}fail/{n_skip}skip",
                       f"of {len(cells)} attempted"))
    return rows
