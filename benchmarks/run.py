"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call/value,derived`` CSV rows (repo convention).

  PYTHONPATH=src python -m benchmarks.run            # all tables
  PYTHONPATH=src python -m benchmarks.run table1 pwb # subset
"""
from __future__ import annotations

import sys
import traceback

SUITES = {
    "table1": "benchmarks.table1",          # Table I perf summary
    "pwb": "benchmarks.pwb_latency",        # §II-H fused pooling -35.9%
    "twm": "benchmarks.twm_vs_bwm",         # Fig. 3 sensing margin
    "pingpong": "benchmarks.pingpong_bench",  # Fig. 5 flexible SRAM
    "wstream": "benchmarks.weight_stream",  # §II-G weight replacement
    "kws": "benchmarks.kws_accuracy",       # §III-A network simulation
    "kernel": "benchmarks.kernel_bench",    # beyond-paper kernel duel
    "roofline": "benchmarks.roofline_table",  # dry-run aggregation
    "stream": "benchmarks.stream_bench",    # multi-stream always-on runtime
}


def main() -> None:
    which = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for key in which:
        mod_name = SUITES[key]
        try:
            mod = __import__(mod_name, fromlist=["run"])
            for line in mod.run():
                print(line, flush=True)
        except Exception as e:  # noqa: BLE001
            failed.append(key)
            print(f"{key}.ERROR,{type(e).__name__},{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
