"""Streaming runtime throughput: in-jit finalization vs the host-peek and
full re-run baselines, steady-state batch sweep, elastic-pool churn, and
the mesh-sharded 1k-stream sweep.

The offline path answers "what does this stream say now?" by re-running the
whole utterance through the executor — the cost a deployment would pay per
emitted frame without incremental state.  The streaming scheduler instead
advances all B streams one hop with a single batched step that *includes*
finalization: the fused tail (ghost flush + classifier kernel) emits every
active slot's executor-exact logits on-device, so steady-state hop latency
IS hop-to-logits latency.  Reported:

  * steady-state hop latency p50/p95, frames/sec and measured silicon-
    equivalent uJ/inference at B in {8, 64, 256} (every slot active,
    per-hop logits on), with the hop split into host-pack vs device time
    (``host_pack_ms_p50`` / ``device_ms_p50`` per config)
  * before/after vs the previous committed BENCH_stream.json at B=8
  * the host-pack microbench at B=1024: the pre-arena per-slot ring walk
    (one python pop per stream per hop) vs ``RingArena.pack_hops``'s one
    vectorized gather — the ``host_pack_ms`` field CI asserts on, with
    the before/after reduction recorded
  * a join/leave churn scenario against the elastic slot pool: staggered
    arrivals/departures, pool resizes counted, hop latency under churn
  * the async-overlap scenario at the largest sweep batch: the whole
    timed load preloaded into an oversized arena, then one open-loop
    ``drain()`` on the sync scheduler vs the double-buffered
    ``AsyncStreamScheduler`` — pack+detector time hidden under device
    spans measured from the fenced trace (``overlap`` in the artifact;
    acceptance floor: >=90% hidden at the non-smoke B=256)
  * the skewed-churn scenario: leaves concentrated onto one shard, steady
    capacity with vs without the cross-shard rebalance plane — the
    rebalanced pool must shrink to within 2x of the balanced floor
    ``S * next_pow2(ceil(active/S))`` where the no-rebalance pool stays
    pinned at the fullest shard's count (``skewed_churn`` in the
    artifact, asserted by the multi-device CI leg)
  * the offline re-run baseline frames/sec and the speedup
  * the mesh-sharded sweep: >=1024 concurrent streams on one logical slot
    pool spanning 1, 2 and 8 shards of a forced multi-device host
    (XLA_FLAGS=--xla_force_host_platform_device_count=8 — set below when
    this module owns jax initialization), acceptance floor: some
    multi-shard config beats the single-device pool at the same total
    stream count

Writes BENCH_stream.json next to the repo root so the perf trajectory of
streams/sec is tracked across PRs.  ``STREAM_BENCH_SMOKE=1`` shrinks every
round count for CI.
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import time

if "jax" not in sys.modules:  # pragma: no cover - import-order dependent
    # must land before jax initializes; inert when the operator set their own
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
    )

import jax
import numpy as np

from benchmarks.common import row
from repro.core import compiler
from repro.core.executor import Executor
from repro.data import gscd
from repro.launch.mesh import make_stream_mesh
from repro.models import kws
from repro.obs import (
    EventLog,
    MetricsRegistry,
    Observability,
    Tracer,
    coverage,
    overlap_stats,
)
from repro.stream import (
    AsyncStreamScheduler,
    FrameRing,
    RingArena,
    StreamScheduler,
    plan_stream,
)
from repro.stream.metrics import StreamMetrics
from repro.stream.scheduler import _next_pow2

SMOKE = os.environ.get("STREAM_BENCH_SMOKE", "") not in ("", "0")

BATCH_SWEEP = (8,) if SMOKE else (8, 64, 256)
HOP_FRAMES = 2            # matches the BENCH_stream.json trajectory
WARM_ROUNDS = 1 if SMOKE else 2
TIMED_ROUNDS = 2 if SMOKE else 20
CHURN_STREAMS = 8 if SMOKE else 24
CHURN_CAP = 32
TENANT_KS = (1, 2, 4, 8)  # pool sizes swept at fixed total streams
TENANT_TOTAL = 16            # fixed across K; same total in smoke + full
TENANT_ROUNDS = 2 if SMOKE else 10
LM_ELASTIC_SLOTS = (4, 8, 16)  # slot-pool ceilings for the LM decode split
LM_ELASTIC_WAVES = 2
SHARD_TOTAL = 1024        # the ROADMAP "1k+ concurrent streams" target
SHARD_CONFIGS = (1, 2, 8)
SHARD_TIMED_ROUNDS = 2 if SMOKE else 6
# at 1k streams the per-hop python packing loop is the serial floor; a
# bigger hop amortizes it so the device-side speedup is what gets measured
SHARD_HOP_FRAMES = 8

_OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_stream.json"


def _steady(spec, weights, thresholds, n_streams: int, mesh=None,
            warm_rounds: int = WARM_ROUNDS, timed_rounds: int = TIMED_ROUNDS,
            chunk_hops: int = 4, hop_frames: int = HOP_FRAMES,
            backend: str = "jnp",
            obs: Observability | None = None) -> dict[str, object]:
    """All slots active, per-hop logits on: the always-on steady state.

    Quantiles come from the scheduler's own bounded metrics plane:
    ``begin_window()`` after warm-up opens a fresh measurement window, so
    ``summary()`` / ``phase_summary()`` report exactly the steady-state
    rounds (exact order statistics while the reservoir holds every
    sample; ``latency_estimated`` flags the histogram fallback).
    """
    sched = StreamScheduler(
        spec, weights, thresholds, capacity=n_streams,
        initial_capacity=n_streams, min_capacity=n_streams,
        hop_frames=hop_frames, emit_logits=True, mesh=mesh, obs=obs,
        backend=backend,
    )
    plan = sched.plan
    chunk = plan.hop_samples * chunk_hops
    need = plan.prime_samples + plan.hop_samples + (
        warm_rounds + timed_rounds
    ) * chunk
    rng = np.random.default_rng(0)
    audio = rng.integers(0, 256, (n_streams, need)).astype(np.uint8)
    sids = [sched.add_stream() for _ in range(n_streams)]

    # prime + trace the jitted step outside the timed region; results are
    # consumed columnar (sched.drain) — the per-stream tuple collation of
    # run_until_starved is exactly the per-slot python the vectorized
    # ingest plane removed, so the bench measures the hot path itself
    pos = plan.prime_samples + plan.hop_samples
    sched.push_audio_batch(sids, list(audio[:, :pos]))
    sched.drain()
    for r in range(warm_rounds):
        sched.push_audio_batch(sids, list(audio[:, pos : pos + chunk]))
        sched.drain()
        pos += chunk

    sched.metrics.begin_window()
    t0 = time.perf_counter()
    for r in range(timed_rounds):
        sched.push_audio_batch(sids, list(audio[:, pos : pos + chunk]))
        sched.drain()
        pos += chunk
    wall = time.perf_counter() - t0

    m = sched.metrics.summary()
    phases = sched.metrics.phase_summary()
    frames = sched.metrics.frames_total()
    energy = sched.metrics.energy_summary()
    return {
        "hop_ms_p50": m["step_ms_p50"],
        "hop_ms_p95": m["step_ms_p95"],
        "hop_ms_p99": m["step_ms_p99"],
        "hop_ms_p999": m["step_ms_p999"],
        "host_pack_ms_p50": m["host_pack_ms_p50"],
        "device_ms_p50": m["device_ms_p50"],
        "device_ms_p95": m["device_ms_p95"],
        "device_ms_p99": m["device_ms_p99"],
        "latency_estimated": m["latency_estimated"],
        # the fenced per-phase split of the hop (pack / dispatch / device
        # / detector): quantiles + each phase's share of hop wall time
        "phases": {
            p: {k: d[k] for k in ("ms_p50", "ms_p95", "ms_p99", "ms_p999",
                                  "share_of_wall")}
            for p, d in phases.items()
        },
        "frames_per_sec": frames / wall,
        "stream_hops_per_sec": frames / plan.frames_per_hop / wall,
        "audio_sec_per_wall_sec": frames * plan.samples_per_frame
        / gscd.SR / wall,
        "uj_per_inference": energy["uj_per_inference"],
        # per-shard pallas_call count for one emit hop (0 = plain XLA)
        "device_dispatches_per_hop": m["device_dispatches_per_hop"],
        "backend": backend,
    }


def _obs_overhead(spec, hop_ms_p50: float, n_streams: int = 256,
                  rounds: int = 2000) -> dict[str, float]:
    """Cost of the instrumentation itself, against the <=2% acceptance
    bound.

    Replays exactly what one hop adds to the hot path — one ``on_step``
    (reservoir records, ledger charge) plus the six ``trace.add`` ring
    appends — with no device work, so the measured per-hop cost is pure
    observability overhead.  The timed region starts *after* the latency
    reservoirs have wrapped, so it measures the saturated regime (ring
    write + live histogram record per series — the most expensive the
    instrumentation ever gets over unbounded uptime).  Compared against
    the measured steady-state hop p50 at the same batch size.
    """
    plan = plan_stream(spec, hop_frames=HOP_FRAMES)
    metrics = StreamMetrics(plan, registry=MetricsRegistry())
    tr = Tracer()

    def hop() -> None:
        metrics.on_step(n_streams, plan.frames_per_hop, 4e-3,
                        host_pack_s=4e-4, dispatch_s=6e-4, device_s=2.6e-3,
                        detector_s=4e-4)
        tr.add_batch((
            ("pack", 0.0, 4e-4, {"n": n_streams}),
            ("dispatch", 0.0, 6e-4, {}),
            ("device", 0.0, 2.6e-3, {}),
            ("detector", 0.0, 4e-4, {}),
            ("push_fold", 0.0, 1e-4, {}),
            ("hop", 0.0, 4e-3, {"n": n_streams}),
        ))

    for _ in range(metrics._wall_res.capacity + 8):  # wrap the reservoirs
        hop()
    assert metrics.latency_estimated
    t0 = time.perf_counter()
    for _ in range(rounds):
        hop()
    per_hop_ms = (time.perf_counter() - t0) / rounds * 1e3
    frac = per_hop_ms / hop_ms_p50 if hop_ms_p50 else 0.0
    return {
        "instrument_ms_per_hop": per_hop_ms,
        "hop_ms_p50": hop_ms_p50,
        "overhead_frac": frac,
        "within_2pct": float(frac <= 0.02),
    }


def _host_pack_micro(hop_samples: int, n_streams: int = 1024,
                     rounds: int = 8) -> dict[str, float]:
    """Host-side hop packing in isolation, before vs after the arena.

    "Before" reconstructs the PR-3 packing loop: one per-stream ring
    object (u8 codes as (n, 1) int32 — the old AudioFrontend layout) and
    one python pop per stream per hop, scattered row by row into the
    batched step input.  "After" is the shared RingArena's one-shot
    ``pack_hops`` gather.  Same data, same output, no device work — this
    isolates exactly the serial floor the ingest refactor removes.
    """
    rng = np.random.default_rng(7)
    need = (rounds + 1) * hop_samples
    codes = rng.integers(0, 256, (n_streams, need)).astype(np.uint8)

    rings = [FrameRing(need, 1, np.int32) for _ in range(n_streams)]
    for i, r in enumerate(rings):
        r.push(codes[i].astype(np.int32)[:, None])
    t0 = time.perf_counter()
    for _ in range(rounds):
        audio = np.zeros((n_streams, hop_samples), np.int32)
        for i, r in enumerate(rings):
            audio[i] = r.pop(hop_samples)[:, 0]
    t_before = (time.perf_counter() - t0) / rounds
    check_before = audio.sum()

    arena = RingArena(n_streams, need)
    arena.push_batch(np.arange(n_streams), list(codes))
    slots = np.arange(n_streams)
    t0 = time.perf_counter()
    for _ in range(rounds):
        audio = arena.pack_hops(slots, hop_samples)
    t_after = (time.perf_counter() - t0) / rounds
    assert audio.sum() == check_before  # same final hop, both paths
    return {
        "streams": float(n_streams),
        "hop_samples": float(hop_samples),
        "host_pack_ms_before": t_before * 1e3,
        "host_pack_ms_after": t_after * 1e3,
        "reduction": t_before / t_after,
    }


def _churn(spec, weights, thresholds,
           obs: Observability | None = None) -> dict[str, float]:
    """Bursty arrivals/departures against the elastic slot pool."""
    sched = StreamScheduler(
        spec, weights, thresholds, capacity=CHURN_CAP,
        hop_frames=HOP_FRAMES, emit_logits=True, obs=obs,
    )
    rng = np.random.default_rng(1)
    clips = [
        gscd.sample(rng, int(c), n=spec.in_len)
        for c in rng.integers(0, gscd.N_CLASSES, CHURN_STREAMS)
    ]
    pending = list(range(CHURN_STREAMS))
    live: dict[int, int] = {}  # sid -> clip index
    pos: dict[int, int] = {}
    t0 = time.perf_counter()
    while pending or live:
        # a burst of arrivals every round (2 at a time)
        for _ in range(2):
            if pending and len(live) < CHURN_CAP:
                j = pending.pop(0)
                sid = sched.add_stream()
                live[sid] = j
                pos[sid] = 0
        for sid, j in list(live.items()):
            n = int(rng.integers(160, 512))
            sched.push_audio(sid, clips[j][pos[sid] : pos[sid] + n])
            pos[sid] += n
        sched.run_until_starved()
        for sid, j in list(live.items()):
            if pos[sid] >= spec.in_len:
                sched.close_stream(sid)
                del live[sid], pos[sid]
    wall = time.perf_counter() - t0
    m = sched.metrics.summary()
    caps = [c for _, c in sched.metrics.capacity_events]
    return {
        "streams": float(CHURN_STREAMS),
        "wall_s": wall,
        "hop_ms_p50": m["step_ms_p50"],
        "resizes": m["resizes"],
        "peak_capacity": float(max(caps)) if caps else float(sched.capacity),
        "final_capacity": float(sched.capacity),
    }


def _overlap_async(spec, weights, thresholds) -> dict[str, object]:
    """Async execution plane vs the sync scheduler, open-loop at the
    largest sweep batch.

    The whole timed load is preloaded (``inbox_samples`` sized to hold
    it), then one ``drain()`` consumes it: on the async plane every hop's
    pack for N+1 and the deferred detector fold for N ride inside hop N's
    (resp. N+1's) device window, so the pipeline is the steady state the
    whole time — no closed-loop push/step alternation in the timed
    region.  Overlap comes from the fenced trace spans
    (``overlap_stats``: pack+detector time inside the union of device
    spans); the acceptance bar is >=90% hidden at the non-smoke B=256.
    Both schedulers consume identical audio and the async plane is
    bit-exact by tests/test_async.py, so the throughput delta is pure
    scheduling.
    """
    B = BATCH_SWEEP[-1]
    hops = 12 if SMOKE else 48
    plan = plan_stream(spec, hop_frames=HOP_FRAMES)
    warm = plan.prime_samples + 2 * plan.hop_samples
    need = warm + hops * plan.hop_samples
    rng = np.random.default_rng(11)
    audio = rng.integers(0, 256, (B, need)).astype(np.uint8)

    out: dict[str, object] = {"batch": B, "hops": hops}
    for label, cls in (("sync", StreamScheduler),
                       ("async", AsyncStreamScheduler)):
        sched = cls(
            spec, weights, thresholds, capacity=B, initial_capacity=B,
            min_capacity=B, hop_frames=HOP_FRAMES, emit_logits=True,
            inbox_samples=need,
            obs=Observability.create(mirror_events=False),
        )
        sids = [sched.add_stream() for _ in range(B)]
        sched.push_audio_batch(sids, list(audio[:, :warm]))
        sched.drain()
        # fresh span window + metrics window: only the open-loop timed
        # drain below contributes to the overlap measurement
        sched.obs.trace.reset()
        sched.metrics.begin_window()
        sched.push_audio_batch(sids, list(audio[:, warm:]))
        t0 = time.perf_counter()
        sched.drain()
        wall = time.perf_counter() - t0
        frames = sched.metrics.frames_total()
        stats = overlap_stats(sched.obs.trace.spans())
        out[label] = {
            "wall_s": wall,
            "stream_hops_per_sec": frames / plan.frames_per_hop / wall,
            "hidden_ms": stats["hidden"] * 1e3,
            "hidden_frac": stats["hidden_frac"],
            "utilization": stats["utilization"],
            "host_ms": stats["host_total"] * 1e3,
            "device_busy_ms": stats["busy_total"] * 1e3,
            # the scheduler's own per-hop accounting of the same overlap
            "metrics": sched.metrics.overlap_summary(),
        }
        if hasattr(sched, "shutdown"):
            sched.shutdown()
    a, s = out["async"], out["sync"]
    out.update(
        # the fields the multi-device CI leg asserts on, promoted to the
        # top of the split
        hidden_ms=a["hidden_ms"],
        hidden_frac=a["hidden_frac"],
        utilization=a["utilization"],
        speedup_vs_sync=a["stream_hops_per_sec"] / s["stream_hops_per_sec"],
        hidden_target_met=bool(a["hidden_frac"] >= 0.9),
    )
    return out


def _skewed_churn(spec, weights, thresholds,
                  events: EventLog | None = None) -> dict[str, object] | None:
    """Leaves skewed onto one shard: shrink floor with vs without the
    cross-shard rebalance plane.

    Every stream joins, then every tenant off shard 0 leaves — the
    churn-unlucky shape that pinned the PR 3 pool at ``S *
    _next_pow2(fullest shard)`` because rows could not cross devices.
    The survivors keep streaming a few hops so the migrate-on-idle
    rebalance (and the shrink it unpins) actually executes; recorded is
    each pool's steady capacity next to the balanced floor ``S *
    _next_pow2(ceil(active / S))`` the acceptance criterion bounds
    against (rebalanced capacity <= 2x that floor).  Returns None on a
    1-device host, like ``_sharded_sweep``.
    """
    if jax.device_count() < 2:
        return None
    S = min(8, jax.device_count())
    mesh = make_stream_mesh(S)
    total = 8 * S
    rng = np.random.default_rng(3)
    out: dict[str, object] = {}
    for label, thr in (("no_rebalance", None), ("rebalance", 1)):
        obs = None
        if events is not None:
            # the shared bench-wide event log: this scenario is where the
            # rebalance lifecycle records come from
            obs = Observability(registry=MetricsRegistry(), trace=Tracer(),
                                events=events)
        sched = StreamScheduler(
            spec, weights, thresholds, capacity=total,
            initial_capacity=total, min_capacity=S,
            hop_frames=HOP_FRAMES, mesh=mesh, rebalance_threshold=thr,
            obs=obs,
        )
        plan = sched.plan
        warm = plan.prime_samples + 2 * plan.hop_samples
        tail = 4 * plan.hop_samples
        audio = rng.integers(0, 256, (total, warm + tail)).astype(np.uint8)
        sids = [sched.add_stream() for _ in range(total)]
        sched.push_audio_batch(sids, list(audio[:, :warm]))
        sched.drain()
        survivors = [
            sid for sid in sids
            if sched._streams[sid].slot < sched.shard_capacity
        ]
        for sid in sids:
            if sid not in survivors:
                sched.close_stream(sid)
        sched.push_audio_batch(survivors,
                               list(audio[survivors][:, warm:]))
        sched.drain()
        m = sched.metrics.summary()
        out[label] = {
            "steady_capacity": float(sched.capacity),
            "rebalances": m["rebalances"],
            "rows_migrated": m["rows_migrated"],
        }
        active = len(survivors)
    floor = S * _next_pow2(-(-active // S))
    out.update(
        shards=S, total_streams=total, active_after_churn=active,
        floor_capacity=float(floor),
        # the acceptance criterion: rebalanced steady capacity within 2x
        # of the balanced floor while the pinned pool cannot get there
        rebalance_within_2x_floor=bool(
            out["rebalance"]["steady_capacity"] <= 2 * floor
        ),
        pinned_capacity_ratio=(
            out["no_rebalance"]["steady_capacity"]
            / out["rebalance"]["steady_capacity"]
        ),
    )
    return out


def _multi_tenant(spec, weights, thresholds) -> dict[str, object]:
    """K tenant models, one megakernel launch: the fused weight pool vs
    K independent single-tenant schedulers at the SAME total stream
    count.

    The baseline is what a deployment without the pool would run: one
    scheduler per model, each advancing ``total/K`` streams with its own
    (smaller) batched hop — K host packs, K dispatches, K detector
    passes per round.  The fused pool advances all ``total`` streams in
    ONE batched hop whose kernels gather each slot-block's weight planes
    by the per-slot model index, so its launches/hop are K-independent
    (recorded per K from the megakernel's static accounting, which
    tests/test_multitenant.py pins to the traced count).  The acceptance
    bar asserted by the multi-device CI leg: fused hop throughput >= 2x
    the K-separate-schedulers baseline at K=4.
    """
    total = TENANT_TOTAL
    plan = plan_stream(spec, hop_frames=HOP_FRAMES)
    tb = max(1, total // max(TENANT_KS))
    # K complete variants of the same geometry (distinct init seeds);
    # variant 0 is the schedulers' default model
    names = [f"tenant{i}" for i in range(max(TENANT_KS))]
    variants = {names[0]: (weights, thresholds)}
    for i, name in enumerate(names[1:], start=1):
        p = kws.init_kws_params(jax.random.PRNGKey(100 + i), spec)
        variants[name] = kws.export_kws(p, spec)
    chunk = plan.hop_samples * 4
    need = plan.prime_samples + plan.hop_samples + (2 + TENANT_ROUNDS) * chunk
    rng = np.random.default_rng(13)
    audio = rng.integers(0, 256, (total, need)).astype(np.uint8)

    def drive(scheds, sid_lists, rounds):
        """Lockstep rounds over one-or-K schedulers; returns wall s."""
        pos = [plan.prime_samples + plan.hop_samples] * len(scheds)
        for j, (s, sids) in enumerate(zip(scheds, sid_lists)):
            rows = audio[j * len(sids) : (j + 1) * len(sids)]
            s.push_audio_batch(sids, list(rows[:, : pos[j]]))
            s.drain()
        for r in range(2 + rounds):  # 2 warm rounds, then timed
            if r == 2:
                for s in scheds:
                    s.metrics.begin_window()
                t0 = time.perf_counter()
            for j, (s, sids) in enumerate(zip(scheds, sid_lists)):
                rows = audio[j * len(sids) : (j + 1) * len(sids)]
                s.push_audio_batch(sids, list(rows[:, pos[j] : pos[j] + chunk]))
                s.drain()
                pos[j] += chunk
        return time.perf_counter() - t0

    per_k: dict[str, dict[str, object]] = {}
    for K in TENANT_KS:
        # fused pool: one scheduler, round-robin tenant binding
        fused = StreamScheduler(
            spec, weights, thresholds, capacity=total,
            initial_capacity=total, min_capacity=total,
            hop_frames=HOP_FRAMES, emit_logits=True,
            max_models=max(K, 2), tenant_block=tb,
        )
        for name in names[1:K]:
            fused.register_model(name, *variants[name])
        # block-contiguous binding (total/K streams per tenant): the
        # tenant-aware placement packs each tenant's streams into whole
        # blocks either way; contiguous joins keep the round deterministic
        # variant 0 rides the ctor default model (pool row 0)
        sids = [fused.add_stream(
                    model=names[t] if (t := (i * K) // total) else None)
                for i in range(total)]
        wall_f = drive([fused], [sids], TENANT_ROUNDS)
        hops_f = TENANT_ROUNDS * 4 * total
        mf = fused.metrics.summary()
        # the same load on K independent single-tenant schedulers
        scheds, sid_lists = [], []
        for k in range(K):
            s = StreamScheduler(
                spec, *variants[names[k]], capacity=total // K,
                initial_capacity=total // K, min_capacity=total // K,
                hop_frames=HOP_FRAMES, emit_logits=True,
            )
            scheds.append(s)
            sid_lists.append([s.add_stream() for _ in range(total // K)])
        wall_b = drive(scheds, sid_lists, TENANT_ROUNDS)
        # launches/hop from the pooled megakernel's static accounting at
        # this K (pure python, no compile) — must not move with K
        mk = StreamScheduler(
            spec, weights, thresholds, capacity=4, hop_frames=HOP_FRAMES,
            backend="megakernel", max_models=max(K, 2), tenant_block=2,
        )
        per_k[str(K)] = {
            "hop_ms_p50": mf["step_ms_p50"],
            "host_pack_ms_p50": mf["host_pack_ms_p50"],
            "device_ms_p50": mf["device_ms_p50"],
            "stream_hops_per_sec": hops_f / wall_f,
            "dispatches_per_emit_hop": mk._model.dispatches_per_hop(True),
            "dispatches_per_steady_hop": mk._model.dispatches_per_hop(False),
            "baseline": {
                "schedulers": K,
                "streams_each": total // K,
                "stream_hops_per_sec": hops_f / wall_b,
                "wall_s": wall_b,
            },
            "speedup_vs_separate": wall_b / wall_f,
        }
    emit_counts = {c["dispatches_per_emit_hop"] for c in per_k.values()}
    k4 = per_k.get("4", {})
    return {
        "total_streams": total,
        "hop_frames": HOP_FRAMES,
        "tenant_block": tb,
        "per_k": per_k,
        "launches_k_independent": len(emit_counts) == 1,
        "speedup_at_k4": k4.get("speedup_vs_separate"),
        # the multi-device CI leg's acceptance bar (full runs only)
        "k4_target_met": bool((k4.get("speedup_vs_separate") or 0.0) >= 2.0),
    }


def _lm_elastic(events) -> dict[str, object]:
    """LM decode on the shared slot pool: tokens/s under grow/shrink churn.

    The serving engine rides the same ``repro.runtime.SlotPool`` as the
    streaming scheduler; this split measures continuous-batching decode
    throughput at slot-pool ceilings {4, 8, 16}.  Each config starts the
    pool at 2 slots and feeds waves of mixed-length requests: admission
    doubles capacity up to the ceiling (``lm_resize`` grow, emitted by the
    pool), the short tail finishing and the end-of-wave drain shrink it
    back (``lm_resize`` shrink) — so every timed wave crosses at least one
    grow and one shrink mid-decode.  Throughput is generated tokens over
    wall; resize lifecycle counts come from the pool's own event stream
    (landing in the shared lifecycle JSONL artifact).
    """
    from repro.configs.base import get_arch
    from repro.models import api
    from repro.serve.engine import Engine, Request

    cfg = get_arch("qwen3-0.6b", smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    max_new = 4 if SMOKE else 8
    configs: dict[str, dict] = {}

    def wave(eng, rid0: int, n_req: int) -> tuple[int, int]:
        for i in range(n_req):
            eng.submit(Request(
                rid=rid0 + i,
                prompt=np.arange(6, dtype=np.int32) + rid0 + i,
                # alternate short/long so finishes skew occupancy and the
                # shrink path runs while the long half still decodes
                max_new_tokens=2 if i % 2 else max_new,
            ))
        done = eng.run_until_drained_async()
        return rid0 + n_req, sum(len(r.out_tokens) for r in done)

    for slots in LM_ELASTIC_SLOTS:
        obs = Observability(registry=MetricsRegistry(), trace=Tracer(),
                            events=events)
        eng = Engine(cfg, params, batch_slots=2, max_seq=64, obs=obs,
                     max_slots=slots, min_slots=2)
        # untimed warm wave: compiles decode at every pow-2 capacity the
        # elastic pool visits, so the timed waves measure the runtime,
        # not jit
        rid, _ = wave(eng, 0, 2 * slots)
        seq0 = events.seq
        tokens = 0
        t0 = time.perf_counter()
        for _ in range(LM_ELASTIC_WAVES):
            rid, t = wave(eng, rid, 2 * slots)
            tokens += t
        wall = time.perf_counter() - t0
        resizes = [e for e in events.tail()
                   if e["event"] == "lm_resize" and e["seq"] >= seq0]
        grew = [e for e in resizes if e["new"] > e["old"]]
        shrank = [e for e in resizes if e["new"] < e["old"]]
        configs[str(slots)] = {
            "tokens": tokens,
            "wall_s": wall,
            "tokens_per_sec": tokens / wall,
            "requests": rid,
            "resizes_grow": len(grew),
            "resizes_shrink": len(shrank),
            "peak_capacity": max((e["new"] for e in grew), default=2),
            "final_capacity": eng.slots,
        }
    return {
        "arch": "qwen3-0.6b (smoke)",
        "min_slots": 2,
        "waves": LM_ELASTIC_WAVES,
        "max_new_tokens": max_new,
        "configs": configs,
    }


def _sharded_sweep(spec, weights, thresholds) -> dict[str, object] | None:
    """>=1024 streams on one logical pool across 1/2/8 shards.

    The same total stream count runs against a single-device pool and
    against mesh-sharded pools, so the aggregate streams/s comparison
    isolates what sharding the slot-pool batch axis buys.  Returns None
    on a 1-device host (e.g. another suite initialized jax before this
    module could force 8 host devices) so a degraded run never clobbers
    a committed multi-device sweep.
    """
    if jax.device_count() < 2:
        return None
    shards = [s for s in SHARD_CONFIGS if s <= jax.device_count()]
    configs: dict[str, dict[str, float]] = {}
    configs_per_stage: dict[str, dict[str, float]] = {}
    configs_fused: dict[str, dict[str, float]] = {}
    for s in shards:
        mesh = make_stream_mesh(s) if s > 1 else None
        kw = dict(mesh=mesh, warm_rounds=1, timed_rounds=SHARD_TIMED_ROUNDS,
                  chunk_hops=2, hop_frames=SHARD_HOP_FRAMES)
        # the committed trajectory row (plain-XLA backend), the per-stage
        # kernel path (before: one launch per stage), and the fused
        # megakernel (after: ONE launch per shard per hop, emit included)
        configs[str(s)] = _steady(spec, weights, thresholds, SHARD_TOTAL,
                                  **kw)
        configs_per_stage[str(s)] = _steady(
            spec, weights, thresholds, SHARD_TOTAL, backend="pallas", **kw
        )
        configs_fused[str(s)] = _steady(
            spec, weights, thresholds, SHARD_TOTAL, backend="megakernel",
            **kw
        )
    single = configs.get("1", {}).get("stream_hops_per_sec")
    multi = [
        c["stream_hops_per_sec"] for k, c in configs.items() if int(k) > 1
    ]
    f_single = configs_fused.get("1", {}).get("stream_hops_per_sec")
    f_multi = [c["stream_hops_per_sec"] for k, c in configs_fused.items()
               if int(k) > 1]
    top = str(max(shards))
    return {
        "total_streams": SHARD_TOTAL,
        "devices": jax.device_count(),
        "hop_frames": SHARD_HOP_FRAMES,
        "configs": configs,
        # the before/after device-ms split of the fusion: per-stage
        # kernel launches vs the hop megakernel, same pool, same mesh
        "configs_per_stage": configs_per_stage,
        "configs_fused": configs_fused,
        "fused_vs_per_stage_device_p50": (
            configs_per_stage[top]["device_ms_p50"]
            / configs_fused[top]["device_ms_p50"]
            if configs_fused[top]["device_ms_p50"] else None
        ),
        "best_single_stream_hops_per_sec": single,
        "best_multi_stream_hops_per_sec": max(multi) if multi else None,
        "multi_vs_single": (max(multi) / single) if multi and single else None,
        "fused_multi_vs_single": (
            max(f_multi) / f_single if f_multi and f_single else None
        ),
    }


def run() -> list[str]:
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(spec, weights, thresholds)
    prev = json.loads(_OUT.read_text()) if _OUT.exists() else {}

    # ---- offline baseline: full re-run per emitted frame --------------------
    rng = np.random.default_rng(0)
    clip = gscd.sample(rng, 0, n=spec.in_len)
    ex = Executor(prog)
    ex.run(clip[:, None])  # warm caches
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        ex.run(clip[:, None])
    t_rerun = (time.perf_counter() - t0) / reps
    # every new frame on every stream would pay one full re-run
    baseline_fps = BATCH_SWEEP[0] / t_rerun

    # ---- shared observability artifacts ------------------------------------
    # one event log across every scenario (steady joins, churn
    # join/close/resize, skewed-churn rebalance) -> the lifecycle JSONL
    # artifact; one tracer on the B=8 steady config -> the Chrome trace
    suffix = "_smoke" if SMOKE else ""
    trace_path = _OUT.with_name(f"BENCH_stream_trace{suffix}.json")
    events_path = _OUT.with_name(f"BENCH_stream_events{suffix}.jsonl")
    events = EventLog(path=str(events_path), mirror=False, mode="w")

    def _obs() -> Observability:
        return Observability(registry=MetricsRegistry(), trace=Tracer(),
                             events=events)

    steady_obs = _obs()

    # ---- steady-state sweep + host-pack micro + churn + sharded sweep ------
    sweep = {
        b: _steady(spec, weights, thresholds, b,
                   obs=steady_obs if b == BATCH_SWEEP[0] else None)
        for b in BATCH_SWEEP
    }
    trace_events = steady_obs.trace.export_chrome()
    span_coverage = coverage(trace_events)
    n_trace = steady_obs.trace.export_chrome(path=str(trace_path))
    obs_over = _obs_overhead(spec, sweep[BATCH_SWEEP[-1]]["hop_ms_p50"],
                             n_streams=BATCH_SWEEP[-1],
                             rounds=200 if SMOKE else 2000)
    pack_plan = plan_stream(spec, hop_frames=SHARD_HOP_FRAMES)
    host_pack = _host_pack_micro(pack_plan.hop_samples,
                                 rounds=2 if SMOKE else 8)
    churn = _churn(spec, weights, thresholds, obs=_obs())
    overlap = _overlap_async(spec, weights, thresholds)
    multi_tenant = _multi_tenant(spec, weights, thresholds)
    sharded = _sharded_sweep(spec, weights, thresholds)
    sharded_skipped = sharded is None
    if sharded_skipped:
        # carry the previously committed multi-device sweep through, but
        # mark it stale in the artifact itself — this run never saw it
        sharded = prev.get("sharded")
        if sharded is not None:
            sharded = {**sharded, "carried_from_prior_run": True}
    skewed = _skewed_churn(spec, weights, thresholds, events=events)
    skewed_skipped = skewed is None
    if skewed_skipped:
        skewed = prev.get("skewed_churn")
        if skewed is not None:
            skewed = {**skewed, "carried_from_prior_run": True}
    lm_elastic = _lm_elastic(events)
    events.flush()
    event_counts = events.counts()
    events.close()

    # ---- per-hop device-dispatch accounting (static, plan + backend) -------
    def _disp(backend: str) -> dict[str, int]:
        s = StreamScheduler(spec, weights, thresholds, capacity=2,
                            hop_frames=SHARD_HOP_FRAMES, backend=backend)
        return {"emit": s._model.dispatches_per_hop(True),
                "steady": s._model.dispatches_per_hop(False)}

    disp = {b: _disp(b) for b in ("jnp", "pallas", "megakernel")}
    device_dispatches = {
        # per-shard pallas_call launches for one hop, by backend; "emit"
        # includes the ghost flush + classifier tail.  The fused target
        # from the megakernel issue is <= 2 launches per emit hop.
        "per_hop_emit": {b: d["emit"] for b, d in disp.items()},
        "per_hop_steady": {b: d["steady"] for b, d in disp.items()},
        "fused_target": 2,
        "fused_target_met": disp["megakernel"]["emit"] <= 2,
    }

    b0 = sweep[BATCH_SWEEP[0]]
    speedup = b0["frames_per_sec"] / baseline_fps
    prev_p50 = prev.get("step_ms_p50")
    # None -> null: keeps the committed artifact strict-JSON when there is
    # no prior BENCH_stream.json to compare against
    hop_speedup = (prev_p50 / b0["hop_ms_p50"]) if prev_p50 else None

    payload = {
        "n_streams": BATCH_SWEEP[0],
        "hop_frames": HOP_FRAMES,
        "smoke": SMOKE,
        "frames_per_sec": b0["frames_per_sec"],
        "frame_latency_ms": 1e3 / b0["frames_per_sec"],
        "step_ms_p50": b0["hop_ms_p50"],
        "step_ms_p95": b0["hop_ms_p95"],
        "step_ms_p99": b0["hop_ms_p99"],
        "step_ms_p999": b0["hop_ms_p999"],
        "latency_estimated": b0["latency_estimated"],
        # the fenced per-phase hop breakdown at B=8 (pack / dispatch /
        # device / detector quantiles + share of hop wall) — CI asserts
        # these fields exist and the phase names match the trace spans
        "phases": b0["phases"],
        "trace": {
            "artifact": trace_path.name,
            "events": n_trace,
            "span_coverage": span_coverage,
        },
        "event_log": {
            "artifact": events_path.name,
            "counts": event_counts,
        },
        # instrumentation hot-path cost vs the <=2% of hop-p50 bound
        "obs_overhead": obs_over,
        "audio_sec_per_wall_sec": b0["audio_sec_per_wall_sec"],
        "baseline_rerun_s": t_rerun,
        "baseline_frames_per_sec": baseline_fps,
        "speedup_vs_rerun": speedup,
        "prev_step_ms_p50": prev_p50,
        "hop_speedup_vs_prev": hop_speedup,
        # host-side per-hop packing at B=1024: the field CI asserts on
        # (vectorized arena gather), with the pre-arena per-slot loop and
        # the reduction recorded next to it
        "host_pack_ms": host_pack["host_pack_ms_after"],
        "host_pack": host_pack,
        "sweep": {str(b): sweep[b] for b in BATCH_SWEEP},
        "churn": churn,
        # async execution plane vs sync at the largest sweep batch,
        # open-loop: hidden_ms / utilization are what CI asserts on
        "overlap": overlap,
        # per-hop launch counts by backend + the fused <=2 target (CI
        # asserts fused_target_met on the multi-device leg)
        "device_dispatches": device_dispatches,
        # K tenant models on one batched dispatch: per-K hop p50 +
        # launches/hop + speedup vs K separate schedulers (CI asserts
        # the >=2x bar at K=4 on the committed full-run artifact)
        "multi_tenant": multi_tenant,
        # the LM engine on the same shared SlotPool: decode tokens/s at
        # slot ceilings {4,8,16} under grow/shrink churn (lm_resize
        # lifecycle asserted by CI from the shared event log)
        "lm_elastic": lm_elastic,
        "sharded": sharded,
        # shrink-floor capacity with vs without the cross-shard rebalance
        # plane under one-shard-skewed leave churn (CI asserts on this)
        "skewed_churn": skewed,
    }
    # smoke runs park their (low-round, noisy) numbers next to the real
    # artifact so they can never corrupt the committed perf trajectory
    out_path = _OUT.with_name("BENCH_stream_smoke.json") if SMOKE else _OUT
    out_path.write_text(json.dumps(payload, indent=2) + "\n")

    out = [
        row("stream.frames_per_sec", f"{b0['frames_per_sec']:.1f}",
            f"B={BATCH_SWEEP[0]} streams, per-hop logits on"),
        row("stream.hop_ms_p50", f"{b0['hop_ms_p50']:.3f}",
            "steady-state hop -> finalized logits"),
        row("stream.hop_ms_p99", f"{b0['hop_ms_p99']:.3f}",
            f"p999 {b0['hop_ms_p999']:.3f}; "
            f"{'exact' if not b0['latency_estimated'] else 'histogram est'}"),
        row("stream.host_pack_ms_b1024", f"{host_pack['host_pack_ms_after']:.3f}",
            f"arena gather; per-slot loop was "
            f"{host_pack['host_pack_ms_before']:.3f}"),
        row("stream.host_pack_reduction", f"{host_pack['reduction']:.1f}",
            f"{'PASS' if host_pack['reduction'] >= 5 else 'FAIL'} "
            "(floor 5x, B=1024)"),
        row("stream.uj_per_inference", f"{b0['uj_per_inference']:.4f}",
            "measured ledger: mac+sa+sram+ctrl"),
    ]
    for p in ("pack", "dispatch", "device", "detector"):
        ph = b0["phases"][p]
        out.append(row(f"stream.phase_{p}_ms_p50", f"{ph['ms_p50']:.3f}",
                       f"p99 {ph['ms_p99']:.3f}, "
                       f"{ph['share_of_wall']*100:.1f}% of hop wall"))
    out.extend([
        row("stream.trace_coverage", f"{span_coverage:.3f}",
            f"{'PASS' if span_coverage >= 0.95 else 'FAIL'} (floor 0.95); "
            f"{n_trace} spans -> {trace_path.name}"),
        row("stream.obs_overhead_pct", f"{obs_over['overhead_frac']*100:.3f}",
            f"{'PASS' if obs_over['within_2pct'] else 'FAIL'} (<=2% of hop "
            f"p50 at B={BATCH_SWEEP[-1]}; "
            f"{obs_over['instrument_ms_per_hop']*1e3:.1f} us/hop)"),
        row("stream.event_log", f"{sum(event_counts.values())}",
            ", ".join(f"{k}={v}" for k, v in sorted(event_counts.items()))
            + f" -> {events_path.name}"),
    ])
    for b in BATCH_SWEEP[1:]:
        out.append(row(f"stream.hop_ms_p50_b{b}",
                       f"{sweep[b]['hop_ms_p50']:.3f}",
                       f"B={b}, {sweep[b]['frames_per_sec']:.0f} frames/s"))
    if prev_p50:
        out.append(row("stream.hop_p50_vs_prev", f"{hop_speedup:.2f}",
                       "x prior committed BENCH_stream.json"))
    for s, c in sorted(lm_elastic["configs"].items(),
                       key=lambda kv: int(kv[0])):
        out.append(row(
            f"stream.lm_elastic_s{s}", f"{c['tokens_per_sec']:.1f}",
            f"LM decode tok/s, slot ceiling {s}; grow {c['resizes_grow']} "
            f"shrink {c['resizes_shrink']}, peak cap {c['peak_capacity']}",
        ))
    if sharded_skipped:
        out.append(row(
            "stream.sharded", "SKIP",
            "1 device visible; run this suite alone (or set XLA_FLAGS="
            "--xla_force_host_platform_device_count=8); prior sweep kept",
        ))
    if sharded is not None:
        for s, c in sorted(sharded["configs"].items(),
                           key=lambda kv: int(kv[0])):
            out.append(row(f"stream.sharded_x{s}",
                           f"{c['stream_hops_per_sec']:.1f}",
                           f"stream-hops/s, {sharded['total_streams']} streams, "
                           f"hop p50 {c['hop_ms_p50']:.1f} ms"))
        ratio = sharded["multi_vs_single"]
        if ratio is not None and not sharded_skipped:
            out.append(row(
                "stream.sharded_speedup", f"{ratio:.2f}",
                f"{'PASS' if ratio > 1.0 else 'FAIL'} "
                "(multi-shard > single device, same total streams)",
            ))
        fused = sharded.get("configs_fused") or {}
        for s, c in sorted(fused.items(), key=lambda kv: int(kv[0])):
            ps = sharded["configs_per_stage"][s]
            out.append(row(
                f"stream.fused_x{s}", f"{c['stream_hops_per_sec']:.1f}",
                f"megakernel stream-hops/s; device p50 "
                f"{c['device_ms_p50']:.1f} ms vs per-stage "
                f"{ps['device_ms_p50']:.1f} ms, "
                f"{c['device_dispatches_per_hop']:.0f} vs "
                f"{ps['device_dispatches_per_hop']:.0f} launches/hop",
            ))
        fvp = sharded.get("fused_vs_per_stage_device_p50")
        if fvp is not None and not sharded_skipped:
            out.append(row(
                "stream.fused_vs_per_stage", f"{fvp:.2f}",
                f"{'PASS' if fvp > 1.0 else 'FAIL'} (fused hop device p50 "
                "faster than per-stage launches, same pool)",
            ))
        fms = sharded.get("fused_multi_vs_single")
        if fms is not None and not sharded_skipped:
            out.append(row(
                "stream.fused_sharded_speedup", f"{fms:.2f}",
                "megakernel multi-shard vs single, same total streams",
            ))
    if skewed_skipped:
        out.append(row(
            "stream.skewed_churn", "SKIP",
            "1 device visible; prior scenario kept" if skewed is not None
            else "1 device visible",
        ))
    if skewed is not None:
        reb = skewed["rebalance"]
        pin = skewed["no_rebalance"]
        out.append(row(
            "stream.skewed_churn_capacity",
            f"{reb['steady_capacity']:.0f}",
            f"{'PASS' if skewed['rebalance_within_2x_floor'] else 'FAIL'} "
            f"(<= 2x floor {skewed['floor_capacity']:.0f}; pinned pool "
            f"stuck at {pin['steady_capacity']:.0f}, "
            f"{reb['rows_migrated']:.0f} rows migrated)",
        ))
    out.extend([
        row("stream.realtime_factor", f"{b0['audio_sec_per_wall_sec']:.1f}",
            "audio-sec per wall-sec"),
        row("stream.baseline_rerun_fps", f"{baseline_fps:.1f}",
            "full re-run per frame"),
        row("stream.speedup_vs_rerun", f"{speedup:.1f}",
            f"{'PASS' if speedup >= 2 else 'FAIL'} (floor 2x)"),
        row("stream.churn_resizes", f"{churn['resizes']:.0f}",
            f"elastic pool peak {churn['peak_capacity']:.0f} -> "
            f"final {churn['final_capacity']:.0f}"),
        row("stream.churn_hop_ms_p50", f"{churn['hop_ms_p50']:.3f}",
            f"{CHURN_STREAMS} streams join/leave, cap {CHURN_CAP}"),
        row("stream.overlap_hidden_pct",
            f"{overlap['hidden_frac']*100:.1f}",
            f"{'PASS' if overlap['hidden_target_met'] else 'FAIL'} "
            f"(>=90% pack+detector hidden under device, "
            f"B={overlap['batch']} open-loop, "
            f"{overlap['hidden_ms']:.1f} ms hidden)"),
        row("stream.overlap_speedup", f"{overlap['speedup_vs_sync']:.2f}",
            f"async vs sync stream-hops/s at B={overlap['batch']}; "
            f"device util {overlap['utilization']*100:.1f}%"),
        *[
            row(f"stream.tenant_k{K}",
                f"{c['stream_hops_per_sec']:.1f}",
                f"fused-pool stream-hops/s at {multi_tenant['total_streams']}"
                f" streams; hop p50 {c['hop_ms_p50']:.2f} ms, "
                f"{c['dispatches_per_emit_hop']:.0f} launches/emit-hop, "
                f"{c['speedup_vs_separate']:.2f}x vs {K} separate")
            for K, c in sorted(
                ((int(k), c) for k, c in multi_tenant["per_k"].items())
            )
        ],
        row("stream.tenant_speedup_k4",
            f"{multi_tenant['speedup_at_k4']:.2f}",
            f"{'PASS' if multi_tenant['k4_target_met'] else 'FAIL'} "
            "(fused pool >= 2x K=4 separate schedulers, same total "
            "streams; launches/hop K-independent: "
            f"{multi_tenant['launches_k_independent']})"),
        row("stream.dispatches_per_emit_hop",
            f"{device_dispatches['per_hop_emit']['megakernel']}",
            f"{'PASS' if device_dispatches['fused_target_met'] else 'FAIL'} "
            f"(fused target <= {device_dispatches['fused_target']}; "
            f"per-stage pallas "
            f"{device_dispatches['per_hop_emit']['pallas']}, jnp "
            f"{device_dispatches['per_hop_emit']['jnp']})"),
        row("stream.artifact", out_path.name,
            "perf trajectory" if not SMOKE else "smoke numbers, kept apart"),
    ])
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in run():
        print(line, flush=True)
