"""Streaming runtime throughput: incremental multi-stream steps vs the
per-frame full re-run baseline.

The offline path answers "what does this stream say now?" by re-running the
whole utterance through the executor — the cost a deployment would pay per
emitted frame without incremental state.  The streaming scheduler instead
advances all B streams one hop with a single batched step, computing only
each conv layer's receptive-field tail.  Reported:

  * frames/sec aggregated over B concurrent streams (with per-hop logits)
  * p50/p95 step latency and the real-time factor (audio-sec per wall-sec)
  * the offline re-run baseline frames/sec and the speedup

Writes BENCH_stream.json next to the repo root so the perf trajectory of
streams/sec is tracked across PRs.  Acceptance floor: speedup >= 2x at
batch >= 8 streams (it lands far above that).
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import row
from repro.core import compiler
from repro.core.executor import Executor
from repro.data import gscd
from repro.models import kws
from repro.stream import StreamScheduler

N_STREAMS = 8
HOP_FRAMES = 2
SECONDS_PER_STREAM = 0.8  # synthetic audio per stream (= one smoke clip)


def run() -> list[str]:
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(spec, weights, thresholds)

    rng = np.random.default_rng(0)
    clips = [
        gscd.sample(rng, int(c), n=spec.in_len)
        for c in rng.integers(0, gscd.N_CLASSES, N_STREAMS)
    ]

    # ---- offline baseline: full re-run per emitted frame --------------------
    ex = Executor(prog)
    ex.run(clips[0][:, None])  # warm caches
    t0 = time.perf_counter()
    reps = 3
    for i in range(reps):
        ex.run(clips[i % N_STREAMS][:, None])
    t_rerun = (time.perf_counter() - t0) / reps
    # every new frame on every stream would pay one full re-run
    baseline_fps = N_STREAMS / t_rerun

    # ---- streaming: batched incremental steps -------------------------------
    sched = StreamScheduler(
        spec, weights, thresholds, capacity=N_STREAMS, hop_frames=HOP_FRAMES,
        emit_logits=True,
    )
    sids = [sched.add_stream() for _ in range(N_STREAMS)]
    # trace/warm the jitted step outside the timed region
    for sid, clip in zip(sids, clips):
        sched.push_audio(sid, clip[: sched.plan.prime_samples
                                  + sched.plan.hop_samples])
    sched.run_until_starved()

    chunk = sched.plan.hop_samples * 4
    frames_warm = sched.metrics.frames_total()
    steps_warm = len(sched.metrics.step_wall_s)  # includes the jit trace
    t0 = time.perf_counter()
    pos = sched.plan.prime_samples + sched.plan.hop_samples
    while pos < spec.in_len:
        for sid, clip in zip(sids, clips):
            sched.push_audio(sid, clip[pos : pos + chunk])
        sched.run_until_starved()
        pos += chunk
    stream_wall = time.perf_counter() - t0

    e = sched.metrics.energy_summary()
    steady_wall = np.asarray(sched.metrics.step_wall_s[steps_warm:])
    step_p50, step_p95 = np.percentile(steady_wall, [50, 95]) * 1e3
    frames_timed = sched.metrics.frames_total() - frames_warm
    stream_fps = frames_timed / stream_wall
    speedup = stream_fps / baseline_fps
    frame_ms = stream_wall / frames_timed * 1e3
    audio_per_wall = (
        frames_timed * sched.plan.samples_per_frame / gscd.SR / stream_wall
    )

    for sid in sids:
        sched.close_stream(sid)

    payload = {
        "n_streams": N_STREAMS,
        "hop_frames": HOP_FRAMES,
        "frames_per_sec": stream_fps,
        "frame_latency_ms": frame_ms,
        "step_ms_p50": float(step_p50),
        "step_ms_p95": float(step_p95),
        "audio_sec_per_wall_sec": audio_per_wall,
        "baseline_rerun_s": t_rerun,
        "baseline_frames_per_sec": baseline_fps,
        "speedup_vs_rerun": speedup,
        "tops_per_w_equiv": e["tops_per_w_equiv"],
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_stream.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")

    return [
        row("stream.frames_per_sec", f"{stream_fps:.1f}",
            f"B={N_STREAMS} streams"),
        row("stream.frame_latency_ms", f"{frame_ms:.3f}", "per emitted frame"),
        row("stream.realtime_factor", f"{audio_per_wall:.1f}",
            "audio-sec per wall-sec"),
        row("stream.baseline_rerun_fps", f"{baseline_fps:.1f}",
            "full re-run per frame"),
        row("stream.speedup_vs_rerun", f"{speedup:.1f}",
            f"{'PASS' if speedup >= 2 else 'FAIL'} (floor 2x)"),
        row("stream.artifact", "BENCH_stream.json", "perf trajectory"),
    ]
