"""Streaming runtime throughput: in-jit finalization vs the host-peek and
full re-run baselines, steady-state batch sweep, and elastic-pool churn.

The offline path answers "what does this stream say now?" by re-running the
whole utterance through the executor — the cost a deployment would pay per
emitted frame without incremental state.  The streaming scheduler instead
advances all B streams one hop with a single batched step that *includes*
finalization: the fused tail (ghost flush + classifier kernel) emits every
active slot's executor-exact logits on-device, so steady-state hop latency
IS hop-to-logits latency.  Reported:

  * steady-state hop latency p50/p95 and frames/sec at B in {8, 64, 256}
    (every slot active, per-hop logits on)
  * before/after vs the previous committed BENCH_stream.json at B=8
    (acceptance floor: >= 1.5x hop throughput; the in-jit tail replaced a
    host-side numpy peek that was ~40% of steady-state step time)
  * a join/leave churn scenario against the elastic slot pool: staggered
    arrivals/departures, pool resizes counted, hop latency under churn
  * the offline re-run baseline frames/sec and the speedup

Writes BENCH_stream.json next to the repo root so the perf trajectory of
streams/sec is tracked across PRs.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import row
from repro.core import compiler
from repro.core.executor import Executor
from repro.data import gscd
from repro.models import kws
from repro.stream import StreamScheduler

BATCH_SWEEP = (8, 64, 256)
HOP_FRAMES = 2            # matches the BENCH_stream.json trajectory
WARM_ROUNDS = 2
TIMED_ROUNDS = 20
CHURN_STREAMS = 24
CHURN_CAP = 32

_OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_stream.json"


def _steady(spec, weights, thresholds, n_streams: int) -> dict[str, float]:
    """All slots active, per-hop logits on: the always-on steady state."""
    sched = StreamScheduler(
        spec, weights, thresholds, capacity=n_streams,
        initial_capacity=n_streams, min_capacity=n_streams,
        hop_frames=HOP_FRAMES, emit_logits=True,
    )
    plan = sched.plan
    chunk = plan.hop_samples * 4
    need = plan.prime_samples + plan.hop_samples + (
        WARM_ROUNDS + TIMED_ROUNDS
    ) * chunk
    rng = np.random.default_rng(0)
    audio = rng.integers(0, 256, (n_streams, need)).astype(np.uint8)
    sids = [sched.add_stream() for _ in range(n_streams)]

    # prime + trace the jitted step outside the timed region
    pos = plan.prime_samples + plan.hop_samples
    for i, sid in enumerate(sids):
        sched.push_audio(sid, audio[i, :pos])
    sched.run_until_starved()
    for r in range(WARM_ROUNDS):
        for i, sid in enumerate(sids):
            sched.push_audio(sid, audio[i, pos : pos + chunk])
        sched.run_until_starved()
        pos += chunk

    warm_steps = len(sched.metrics.step_wall_s)
    frames_warm = sched.metrics.frames_total()
    t0 = time.perf_counter()
    for r in range(TIMED_ROUNDS):
        for i, sid in enumerate(sids):
            sched.push_audio(sid, audio[i, pos : pos + chunk])
        sched.run_until_starved()
        pos += chunk
    wall = time.perf_counter() - t0

    steady = np.asarray(sched.metrics.step_wall_s[warm_steps:])
    frames = sched.metrics.frames_total() - frames_warm
    p50, p95 = np.percentile(steady, [50, 95]) * 1e3
    return {
        "hop_ms_p50": float(p50),
        "hop_ms_p95": float(p95),
        "frames_per_sec": frames / wall,
        "audio_sec_per_wall_sec": frames * plan.samples_per_frame
        / gscd.SR / wall,
    }


def _churn(spec, weights, thresholds) -> dict[str, float]:
    """Bursty arrivals/departures against the elastic slot pool."""
    sched = StreamScheduler(
        spec, weights, thresholds, capacity=CHURN_CAP,
        hop_frames=HOP_FRAMES, emit_logits=True,
    )
    rng = np.random.default_rng(1)
    clips = [
        gscd.sample(rng, int(c), n=spec.in_len)
        for c in rng.integers(0, gscd.N_CLASSES, CHURN_STREAMS)
    ]
    pending = list(range(CHURN_STREAMS))
    live: dict[int, int] = {}  # sid -> clip index
    pos: dict[int, int] = {}
    t0 = time.perf_counter()
    while pending or live:
        # a burst of arrivals every round (2 at a time)
        for _ in range(2):
            if pending and len(live) < CHURN_CAP:
                j = pending.pop(0)
                sid = sched.add_stream()
                live[sid] = j
                pos[sid] = 0
        for sid, j in list(live.items()):
            n = int(rng.integers(160, 512))
            sched.push_audio(sid, clips[j][pos[sid] : pos[sid] + n])
            pos[sid] += n
        sched.run_until_starved()
        for sid, j in list(live.items()):
            if pos[sid] >= spec.in_len:
                sched.close_stream(sid)
                del live[sid], pos[sid]
    wall = time.perf_counter() - t0
    m = sched.metrics.summary()
    caps = [c for _, c in sched.metrics.capacity_events]
    return {
        "streams": float(CHURN_STREAMS),
        "wall_s": wall,
        "hop_ms_p50": m["step_ms_p50"],
        "resizes": m["resizes"],
        "peak_capacity": float(max(caps)) if caps else float(sched.capacity),
        "final_capacity": float(sched.capacity),
    }


def run() -> list[str]:
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(spec, weights, thresholds)
    prev = json.loads(_OUT.read_text()) if _OUT.exists() else {}

    # ---- offline baseline: full re-run per emitted frame --------------------
    rng = np.random.default_rng(0)
    clip = gscd.sample(rng, 0, n=spec.in_len)
    ex = Executor(prog)
    ex.run(clip[:, None])  # warm caches
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        ex.run(clip[:, None])
    t_rerun = (time.perf_counter() - t0) / reps
    # every new frame on every stream would pay one full re-run
    baseline_fps = BATCH_SWEEP[0] / t_rerun

    # ---- steady-state sweep + churn -----------------------------------------
    sweep = {b: _steady(spec, weights, thresholds, b) for b in BATCH_SWEEP}
    churn = _churn(spec, weights, thresholds)

    b0 = sweep[BATCH_SWEEP[0]]
    speedup = b0["frames_per_sec"] / baseline_fps
    prev_p50 = prev.get("step_ms_p50")
    # None -> null: keeps the committed artifact strict-JSON when there is
    # no prior BENCH_stream.json to compare against
    hop_speedup = (prev_p50 / b0["hop_ms_p50"]) if prev_p50 else None

    payload = {
        "n_streams": BATCH_SWEEP[0],
        "hop_frames": HOP_FRAMES,
        "frames_per_sec": b0["frames_per_sec"],
        "frame_latency_ms": 1e3 / b0["frames_per_sec"],
        "step_ms_p50": b0["hop_ms_p50"],
        "step_ms_p95": b0["hop_ms_p95"],
        "audio_sec_per_wall_sec": b0["audio_sec_per_wall_sec"],
        "baseline_rerun_s": t_rerun,
        "baseline_frames_per_sec": baseline_fps,
        "speedup_vs_rerun": speedup,
        "prev_step_ms_p50": prev_p50,
        "hop_speedup_vs_prev": hop_speedup,
        "sweep": {str(b): sweep[b] for b in BATCH_SWEEP},
        "churn": churn,
    }
    _OUT.write_text(json.dumps(payload, indent=2) + "\n")

    out = [
        row("stream.frames_per_sec", f"{b0['frames_per_sec']:.1f}",
            f"B={BATCH_SWEEP[0]} streams, per-hop logits on"),
        row("stream.hop_ms_p50", f"{b0['hop_ms_p50']:.3f}",
            "steady-state hop -> finalized logits"),
    ]
    for b in BATCH_SWEEP[1:]:
        out.append(row(f"stream.hop_ms_p50_b{b}",
                       f"{sweep[b]['hop_ms_p50']:.3f}",
                       f"B={b}, {sweep[b]['frames_per_sec']:.0f} frames/s"))
    if prev_p50:
        out.append(row("stream.hop_speedup_vs_prev", f"{hop_speedup:.2f}",
                       f"{'PASS' if hop_speedup >= 1.5 else 'FAIL'} "
                       "(floor 1.5x, in-jit finalization tail)"))
    out.extend([
        row("stream.realtime_factor", f"{b0['audio_sec_per_wall_sec']:.1f}",
            "audio-sec per wall-sec"),
        row("stream.baseline_rerun_fps", f"{baseline_fps:.1f}",
            "full re-run per frame"),
        row("stream.speedup_vs_rerun", f"{speedup:.1f}",
            f"{'PASS' if speedup >= 2 else 'FAIL'} (floor 2x)"),
        row("stream.churn_resizes", f"{churn['resizes']:.0f}",
            f"elastic pool peak {churn['peak_capacity']:.0f} -> "
            f"final {churn['final_capacity']:.0f}"),
        row("stream.churn_hop_ms_p50", f"{churn['hop_ms_p50']:.3f}",
            f"{CHURN_STREAMS} streams join/leave, cap {CHURN_CAP}"),
        row("stream.artifact", "BENCH_stream.json", "perf trajectory"),
    ])
    return out
