"""Paper Table I: performance summary of PSCNN running the KWS model.

Reproduces every row our simulation can produce and prints
reproduced-vs-paper side by side.  OPS accounting follows the paper
(1 MAC = 1 OP, DESIGN.md §1).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import compile_kws_full, row
from repro.core import energy as energy_lib
from repro.core.executor import Executor

PAPER = {
    "test_accuracy_pct": 92.53,   # GSCD (we report synthetic-set accuracy)
    "energy_per_inference_uj": 0.399,
    "latency_per_inference_us": 2320.0,
    "macs_per_inference": 350e6,
    "params_kb": 652.0,
    "throughput_gops": 150.8,
    "power_efficiency_tops_w": 885.86,
}


def run() -> list[str]:
    spec, params, prog = compile_kws_full()
    x = np.random.default_rng(0).integers(0, 256, (spec.in_len, 1)).astype(np.uint8)
    rep = Executor(prog).run(x)
    led = rep.ledger
    # calibrate e_mac once to the paper's efficiency target (DESIGN.md §9.4)
    target = led.macs / (PAPER["power_efficiency_tops_w"] * 1e12)
    params_cal = energy_lib.calibrate_e_mac(led, target)
    led2 = Executor(prog, params=params_cal).run(x).ledger

    got = {
        "energy_per_inference_uj": led2.energy_j * 1e6,
        "latency_per_inference_us": led2.latency_s * 1e6,
        "macs_per_inference": float(led2.macs),
        "params_kb": spec.model_size_kb,
        "throughput_gops": led2.gops,
        "power_efficiency_tops_w": led2.tops_per_w,
    }
    rows = []
    for key, paper_val in PAPER.items():
        if key == "test_accuracy_pct":
            continue  # reported by kws_accuracy bench (synthetic corpus)
        g = got[key]
        err = 100.0 * (g - paper_val) / paper_val
        rows.append(row(f"table1.{key}", f"{g:.4g}",
                        f"paper={paper_val:.4g};err={err:+.1f}%"))
    rows.append(row("table1.on_chip_memory_kb", 768,
                    "4x64Kb feature + 512Kb weight SRAM (matches paper)"))
    rows.append(row("table1.cim_array", "1x1024x1024",
                    "single large macro, 128 SAs"))
    rows.append(row("table1.weight_sram_used_bits",
                    prog.wsram.used_bits, "capacity=524288"))
    return rows
