"""Paper Fig. 3: TWM doubles the sensing margin vs BWM.

Monte-Carlo SA-decision flip rate vs noise sigma for both mappings on
KWS-shaped layers; the margin claim manifests as TWM's curve sitting below
BWM's at every sigma.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import twm


def run() -> list[str]:
    rng = np.random.default_rng(0)
    x = jnp.array(rng.integers(0, 2, (128, 768)), jnp.uint32)   # b3-shaped
    w = jnp.array(rng.integers(-1, 2, (768, 64)), jnp.int32)
    key = jax.random.PRNGKey(0)
    rows = [row("twm.margin_ratio",
                twm.sensing_margin_twm() / twm.sensing_margin_bwm(),
                "paper: 2x (Fig. 3c)")]
    for sigma in (0.5, 1.0, 2.0, 4.0):
        ft = float(twm.flip_rate_under_noise(key, x, w, sigma, "twm", trials=24))
        fb = float(twm.flip_rate_under_noise(key, x, w, sigma, "bwm", trials=24))
        rows.append(row(
            f"twm.flip_rate_sigma{sigma}", f"{ft:.4f}",
            f"bwm={fb:.4f};twm_better={ft < fb}",
        ))
    return rows
