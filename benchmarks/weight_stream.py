"""Paper §II-G: weight SRAM + replacement overhead (652Kb model > 512Kb CIM).

Reports the rotation plan of the compiled KWS program: what rotates, the
WREP cycle/energy overhead per inference, and the counterfactual latency if
the whole model had fit the macro (no replacement).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import compile_kws_full, row
from repro.core.executor import Executor


def run() -> list[str]:
    spec, _, prog = compile_kws_full()
    x = np.random.default_rng(0).integers(0, 256, (spec.in_len, 1)).astype(np.uint8)
    rep = Executor(prog).run(x)
    wrep_cyc = rep.layer_cycles.get("wrep", 0)
    total = rep.ledger.cycles
    rot = [c.name for b in prog.bindings for ch in [None] for c in b.chunks
           if c.rotating]
    rows = [
        row("wstream.model_kb", f"{spec.model_size_kb:.1f}", "paper=652Kb"),
        row("wstream.macro_capacity_kb", 512, "1Mb cells / 2 (TWM)"),
        row("wstream.rotating_chunks", len(rot), ";".join(rot)),
        row("wstream.weight_sram_used_bits", prog.wsram.used_bits,
            "capacity=524288"),
        row("wstream.wrep_cycles_per_inference", wrep_cyc,
            f"{100.0 * wrep_cyc / total:.1f}% of latency"),
        row("wstream.latency_overhead_pct",
            f"{100.0 * wrep_cyc / (total - wrep_cyc):.2f}%",
            "vs hypothetical all-resident macro"),
    ]
    return rows
