"""Always-on streaming KWS: many live audio streams, one shared model.

1. build + briefly QAT-train the reduced binary KWS CNN,
2. export ternary weights + SA thresholds (same artifacts the compiler eats),
3. open a StreamScheduler and let several synthetic "microphones" push
   audio in ragged real-world-sized chunks through the vectorized ingest
   plane (push_audio_batch: one quantize + one scatter into the shared
   RingArena; the elastic slot pool grows from its minimum as they join),
4. watch per-hop finalized logits — computed on-device by the in-jit
   finalization tail — feed the hysteresis detector and emit keyword
   events per stream,
5. close each stream and verify the flushed logits are bit-exact with the
   offline executor on the same audio.

Run:  PYTHONPATH=src python examples/kws_stream.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler
from repro.core.executor import Executor
from repro.data import gscd
from repro.models import kws
from repro.stream import DetectorConfig, StreamScheduler
from repro.train import optimizer as opt_lib

STEPS, BATCH, IN_LEN, WIDTH = 80, 24, 2000, 16
N_STREAMS = 4


def main() -> None:
    spec = kws.build_kws_spec(in_len=IN_LEN, width=WIDTH)
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    ocfg = opt_lib.OptConfig(lr=2e-3)
    state = opt_lib.init_opt_state(ocfg, params)

    @jax.jit
    def step(state, params, x, y):
        loss, grads = jax.value_and_grad(kws.kws_loss)(params, x, y, spec)
        state, _ = opt_lib.update(ocfg, state, grads)
        return state, opt_lib.cast_params_like(state["master"], params), loss

    print("training briefly on the synthetic corpus...")
    for i in range(STEPS):
        xb, yb = gscd.batch(seed=0, step=i, batch_size=BATCH, n=IN_LEN)
        state, params, loss = step(state, params, jnp.array(xb), jnp.array(yb))
    print(f"  final loss {float(loss):.3f}")

    weights, thresholds = kws.export_kws(params, spec)
    sched = StreamScheduler(
        spec, weights, thresholds, capacity=N_STREAMS, hop_frames=2,
        detector_cfg=DetectorConfig(smooth_frames=2, on_threshold=0.5),
    )
    plan = sched.plan
    print(f"\nstream plan: hop={plan.hop_samples} samples "
          f"({plan.frames_per_hop} frames), prime={plan.prime_samples}, "
          f"tails={[st.tail for st in plan.convs]}")

    # each stream speaks one keyword; chunks arrive ragged like RTP packets
    rng = np.random.default_rng(3)
    classes = rng.integers(0, 10, N_STREAMS)
    clips = [gscd.sample(rng, int(c), n=IN_LEN) for c in classes]
    sids = [sched.add_stream() for _ in range(N_STREAMS)]
    pos = [0] * N_STREAMS
    while any(p < IN_LEN for p in pos):
        feed_sids, feed_chunks = [], []
        for j, sid in enumerate(sids):
            n = int(rng.integers(80, 400))
            if pos[j] < IN_LEN:
                feed_sids.append(sid)
                feed_chunks.append(clips[j][pos[j] : pos[j] + n])
                pos[j] += n
        # one vectorized quantize+scatter lands every microphone's chunk
        sched.push_audio_batch(feed_sids, feed_chunks)
        for sid, frame, logits, det in sched.step():
            if det is not None:
                print(f"  [stream {sid}] DETECT class {det.cls} "
                      f"@frame {det.frame} score {det.score:.2f}")
    sched.run_until_starved()

    print("\nclosing streams (flush) and checking offline bit-exactness:")
    prog = compiler.compile_model(spec, weights, thresholds)
    ex = Executor(prog)
    for j, sid in enumerate(sids):
        res = sched.close_stream(sid)
        off = ex.run(clips[j][:, None]).output.ravel()
        ok = np.array_equal(res.logits, off)
        pred = int(np.argmax(res.logits))
        print(f"  stream {sid}: true={classes[j]} pred={pred} "
              f"frames={res.frames} events={len(res.events)} "
              f"offline-match={'OK' if ok else 'MISMATCH'}")
        assert ok, "streaming/offline divergence"

    m = sched.metrics.summary()
    e = sched.metrics.energy_summary()
    print(f"\nmetrics: {m['frames_total']:.0f} frames, "
          f"{m['frames_per_sec']:.0f} frames/s, "
          f"step p50 {m['step_ms_p50']:.1f} ms (hop -> on-device logits; "
          f"host pack {m['host_pack_ms_p50']:.2f} ms of it), "
          f"silicon-equivalent {e['tops_per_w_equiv']:.0f} TOPS/W")
    print(f"elastic pool: {m['resizes']:.0f} resizes, "
          f"final capacity {sched.capacity} of max {sched.max_capacity}")


if __name__ == "__main__":
    main()
