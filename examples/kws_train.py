"""End-to-end driver: train the full Fig.-7 KWS model on synthetic GSCD.

The paper's own experiment (§III-A): binary-activation ternary-weight 1-D
CNN, 12 classes, 1 s @ 16 kHz.  With --full this trains the exact 631Kb
reconstruction for a few hundred steps (hours on this CPU container; the
default reduced setting finishes in minutes and exercises the identical
code path).  Training is checkpointed and restartable.

Run:  PYTHONPATH=src python examples/kws_train.py [--full] [--steps N]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler
from repro.core.executor import Executor
from repro.data import gscd
from repro.models import kws
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="exact Fig.-7 reconstruction (16k samples, w=64)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--eval-every", type=int, default=50)
    args = ap.parse_args()

    spec = (kws.build_kws_spec() if args.full
            else kws.build_kws_spec(in_len=4000, width=24))
    print(f"model {spec.name}: {spec.model_size_kb:.0f}Kb, "
          f"{spec.total_macs/1e6:.0f}M MACs/inf, in_len={spec.in_len}")

    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    ocfg = opt_lib.OptConfig(lr=args.lr, clip_norm=1.0)
    state = opt_lib.init_opt_state(ocfg, params)
    start = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        start, (state, params), _ = ckpt.restore(args.ckpt_dir,
                                                 (state, params))
        print(f"resumed from step {start}")

    @jax.jit
    def step(state, params, x, y):
        loss, grads = jax.value_and_grad(kws.kws_loss)(params, x, y, spec)
        state, om = opt_lib.update(ocfg, state, grads)
        return state, opt_lib.cast_params_like(state["master"], params), loss

    t0 = time.time()
    for i in range(start, args.steps):
        xb, yb = gscd.batch(seed=0, step=i, batch_size=args.batch,
                            n=spec.in_len)
        state, params, loss = step(state, params, jnp.array(xb),
                                   jnp.array(yb))
        if (i + 1) % args.eval_every == 0 or i == start:
            xe, ye = gscd.batch(seed=7, step=10_000, batch_size=96,
                                n=spec.in_len)
            acc = float(kws.kws_accuracy(params, jnp.array(xe),
                                         jnp.array(ye), spec))
            print(f"step {i+1:4d} loss {float(loss):.4f} "
                  f"eval-acc {acc:.3f} ({time.time()-t0:.0f}s)")
            if args.ckpt_dir:
                ckpt.save(args.ckpt_dir, i + 1, (state, params))

    # deploy: export -> compile -> CIM execution accuracy (the honest number)
    weights, thresholds = kws.export_kws(params, spec)
    hints = (kws.ROTATE_HINTS, kws.ROWSPLIT_HINTS) if args.full else ((), {})
    prog = compiler.compile_model(spec, weights, thresholds,
                                  rotate_hints=hints[0],
                                  rowsplit_hints=hints[1])
    ex = Executor(prog)
    xe, ye = gscd.batch(seed=7, step=10_000, batch_size=48, n=spec.in_len)
    correct = 0
    for x, y in zip(xe, ye):
        out = ex.run(x[:, None]).output.ravel()
        correct += int(np.argmax(out) == y)
    led = ex.run(xe[0][:, None]).ledger.summary()
    print(f"\nCIM-executed accuracy: {correct}/{len(ye)} "
          f"= {correct/len(ye):.3f} (synthetic GSCD; paper: 0.9253 on real)")
    print(f"hardware: {led['latency_us']:.0f}us/inf, {led['gops']:.1f} GOPS, "
          f"{led['tops_per_w']:.0f} TOPS/W")


if __name__ == "__main__":
    main()
