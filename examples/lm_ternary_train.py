"""Beyond-paper: the PSCNN ternary regime applied to an LM architecture.

Trains a reduced qwen3-family decoder twice — fp baseline vs
quant_mode='ternary' (BitNet-style PSCNN linears) — through the full
distributed-training substrate (AdamW, grad clipping, checkpointing), and
reports the loss gap, plus the serve-time bytes saved by packed TWM planes.

Run:  PYTHONPATH=src python examples/lm_ternary_train.py [--steps N]
"""
import argparse
import dataclasses

import jax

from repro.configs.base import get_arch
from repro.data import lm_data
from repro.models import api
from repro.train import loop as tl
from repro.train import optimizer as opt_lib
from repro.utils.tree import tree_size_bytes


def train(cfg, steps: int, seed: int = 0):
    tcfg = tl.TrainConfig(opt=opt_lib.OptConfig(lr=3e-3), remat="none",
                          warmup_steps=max(steps // 10, 1), total_steps=steps)
    dcfg = lm_data.DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8,
                              seed=seed)
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    tr = tl.Trainer(cfg, tcfg, api.loss_fn(cfg, remat="none"), params,
                    lm_data.iterator(dcfg))
    hist = tr.run(steps)
    return hist, tr.state["params"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    base_cfg = get_arch("qwen3-0.6b", smoke=True)
    tern_cfg = dataclasses.replace(base_cfg, quant_mode="ternary")

    print("== fp baseline ==")
    h_fp, params = train(base_cfg, args.steps)
    print(f"loss {h_fp[0]['loss']:.4f} -> {h_fp[-1]['loss']:.4f}")

    print("== ternary (PSCNN regime) ==")
    h_t, _ = train(tern_cfg, args.steps)
    print(f"loss {h_t[0]['loss']:.4f} -> {h_t[-1]['loss']:.4f}")
    print(f"quantization loss gap: {h_t[-1]['loss'] - h_fp[-1]['loss']:+.4f}")

    dense_bytes = tree_size_bytes(params)
    # TWM packed planes: 2 bits/weight
    from repro.utils.tree import tree_count_params
    packed_bytes = tree_count_params(params) // 4
    print(f"\nserve-time weights: dense bf16 {dense_bytes/1e6:.1f} MB -> "
          f"TWM planes {packed_bytes/1e6:.1f} MB "
          f"({dense_bytes/packed_bytes:.0f}x smaller)")


if __name__ == "__main__":
    main()
