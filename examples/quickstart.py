"""Quickstart: the paper's pipeline end-to-end in ~a minute on CPU.

1. build the binary KWS CNN (reduced width),
2. QAT-train it briefly on the synthetic speech-commands corpus,
3. export ternary weights + SA thresholds,
4. compile to the PSCNN instruction set,
5. execute on the cycle-accurate CIM simulator and compare with QAT.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler, isa
from repro.core.executor import Executor
from repro.data import gscd
from repro.models import kws
from repro.train import optimizer as opt_lib

STEPS, BATCH, IN_LEN, WIDTH = 20, 16, 2000, 16


def main() -> None:
    spec = kws.build_kws_spec(in_len=IN_LEN, width=WIDTH)
    print(f"model: {spec.total_weights} ternary weights "
          f"({spec.model_size_kb:.0f}Kb), {spec.total_macs/1e6:.1f}M MACs")

    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    ocfg = opt_lib.OptConfig(lr=2e-3)
    state = opt_lib.init_opt_state(ocfg, params)

    @jax.jit
    def step(state, params, x, y):
        loss, grads = jax.value_and_grad(kws.kws_loss)(params, x, y, spec)
        state, _ = opt_lib.update(ocfg, state, grads)
        return state, opt_lib.cast_params_like(state["master"], params), loss

    for i in range(STEPS):
        xb, yb = gscd.batch(seed=0, step=i, batch_size=BATCH, n=IN_LEN)
        state, params, loss = step(state, params, jnp.array(xb), jnp.array(yb))
        if i % 5 == 0:
            print(f"  step {i:3d}  loss {float(loss):.4f}")

    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(spec, weights, thresholds)
    print(f"\ncompiled program: {len(prog.words)} instructions")
    print(prog.disassemble()[:600], "...\n")

    x, y = gscd.batch(seed=9, step=0, batch_size=1, n=IN_LEN)
    rep = Executor(prog).run(x[0][:, None])
    qat = np.asarray(kws.kws_forward(params, jnp.array(x[0]), spec))
    print("CIM logits:", rep.output.ravel())
    print("QAT logits:", qat.astype(int))
    print("bit-exact:", np.array_equal(rep.output.ravel().astype(float), qat))
    s = rep.ledger.summary()
    print(f"latency {s['latency_us']:.0f}us | {s['gops']:.1f} GOPS | "
          f"{s['tops_per_w']:.0f} TOPS/W | {s['energy_uj']*1000:.1f} nJ/inf")


if __name__ == "__main__":
    main()
