"""Serve a small LM with batched requests through the continuous-batching
engine (prefill -> slot install -> decode ticks -> retire).

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-0.6b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models import api
from repro.serve.engine import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, batch_slots=args.slots, max_seq=64)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, 10).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    done = eng.run_until_drained()
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"request {r.rid}: {r.out_tokens}")
    print(f"\n{len(done)} requests, {total} tokens, {dt:.1f}s "
          f"({total/dt:.1f} tok/s, {args.slots} slots continuous batching)")


if __name__ == "__main__":
    main()
