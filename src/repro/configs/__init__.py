from repro.configs.base import (
    ArchConfig,
    MoEConfig,
    ShapeConfig,
    SHAPES,
    arch_names,
    get_arch,
)

__all__ = ["ArchConfig", "MoEConfig", "ShapeConfig", "SHAPES",
           "arch_names", "get_arch"]
