"""Architecture + shape configuration schema and registry.

Every assigned architecture is a ``configs/<id>.py`` exporting ``CONFIG``
(exact published dims) and ``SMOKE`` (reduced same-family config for CPU
tests).  ``input_specs`` builds the ShapeDtypeStruct stand-ins the dry-run
lowers against — no device allocation ever happens for the full configs.
"""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_expert: int = 0
    d_shared: int = 0          # shared-expert ffn width (0 = n_shared*d_expert)
    every_k: int = 1           # MoE on every k-th layer (jamba: 2)
    first_k_dense: int = 0     # leading dense layers (deepseek-moe: 1)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | encdec | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0            # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    # recurrent / hybrid structure: one superblock, repeated.
    # entries: 'a' attention, 'm' mamba, 'M' mLSTM, 's' sLSTM
    superblock: tuple[str, ...] = ()
    d_state: int = 16
    ssm_expand: int = 2
    # modality frontend stub (vlm patches / audio frames), prepended tokens
    n_frontend_tokens: int = 0
    frontend_dim: int = 0      # 0 -> d_model (pre-projected embeddings)
    n_enc_layers: int = 0      # encoder-decoder only
    quant_mode: str = "none"   # 'none' | 'ternary' (the paper's regime)
    long_context_ok: bool = False
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 32 (shardable over 16-way model axis)."""
        return ((self.vocab + 31) // 32) * 32

    @property
    def n_superblocks(self) -> int:
        if not self.superblock:
            return 0
        assert self.n_layers % len(self.superblock) == 0, (
            self.name, self.n_layers, len(self.superblock))
        return self.n_layers // len(self.superblock)

    def layer_kind(self, li: int) -> tuple[str, str]:
        """-> (mixer, ffn) for layer li: mixer per superblock pattern; ffn
        'moe'/'dense'/'none' per the MoE interleave rules."""
        mixer = self.superblock[li % len(self.superblock)] if self.superblock else "a"
        if self.d_ff == 0 and self.moe is None:
            ffn = "none"
        elif self.moe is None:
            ffn = "dense"
        elif li < self.moe.first_k_dense:
            ffn = "dense"
        elif (li - self.moe.first_k_dense) % self.moe.every_k == 0:
            ffn = "moe"
        else:
            ffn = "dense"
        return mixer, ffn

    def supports(self, shape: ShapeConfig) -> bool:
        if shape.name == "long_500k" and not self.long_context_ok:
            return False
        return True

    # -- dry-run inputs ------------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        elif shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        else:  # decode: one new token against a seq_len-deep context
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if self.n_frontend_tokens and shape.kind != "decode":
            dim = self.frontend_dim or self.d_model
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, self.n_frontend_tokens, dim), jnp.bfloat16
            )
        if self.family == "encdec" and shape.kind != "decode":
            # audio frames replace 'frontend'; decoder sees tokens
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, s, self.frontend_dim or self.d_model), jnp.bfloat16
            )
        return specs


_REGISTRY: dict[str, str] = {
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "yi-9b": "repro.configs.yi_9b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "phi-3-vision-4.2b": "repro.configs.phi_3_vision_4_2b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "pscnn-kws": "repro.configs.pscnn_kws",
}


def arch_names() -> list[str]:
    return [n for n in _REGISTRY if n != "pscnn-kws"]


def get_arch(name: str, smoke: bool = False):
    mod = importlib.import_module(_REGISTRY[name])
    return mod.SMOKE if smoke else mod.CONFIG
