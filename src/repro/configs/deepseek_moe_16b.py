"""DeepSeekMoE-16B [moe] — 28L d2048 16H (kv=16) vocab=102400, fine-grained
MoE: 64 routed top-6 + 2 shared experts (d_expert=1408), first layer dense
(d_ff=10944).  [arXiv:2401.06066; hf]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=10944, vocab=102400, rope_theta=1e4,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408,
                  d_shared=2816, every_k=1, first_k_dense=1),
    source="arXiv:2401.06066",
)

SMOKE = ArchConfig(
    name="deepseek-moe-16b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=256, vocab=512,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_expert=32,
                  d_shared=64, every_k=1, first_k_dense=1),
)
