"""Jamba-1.5-Large-398B [hybrid] — 72L d8192 64H (GQA kv=8) d_ff=24576
vocab=65536; Mamba:attention 7:1 interleave (superblock m,m,m,a,m,m,m,m x9),
MoE 16 routed top-2 on every other layer.  Mamba layers give O(1) state ->
runs long_500k (9 attention layers keep full 512k KV: 38 MB/device @512).
[arXiv:2403.19887; hf]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=24576, vocab=65536, rope_theta=0.0,  # jamba: no RoPE on attn layers
    superblock=("m", "m", "m", "a", "m", "m", "m", "m"),
    moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_expert=24576,
                  every_k=2, first_k_dense=0),
    d_state=16, ssm_expand=2, long_context_ok=True,
    source="arXiv:2403.19887",
)

SMOKE = ArchConfig(
    name="jamba-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512, rope_theta=0.0,
    superblock=("m", "a"),
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_expert=128,
                  every_k=2, first_k_dense=0),
    d_state=8, ssm_expand=2, long_context_ok=True,
)
