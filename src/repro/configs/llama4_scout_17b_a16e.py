"""Llama-4-Scout-17B-16E [moe] — 48L d5120 40H (GQA kv=8) vocab=202048,
MoE 16 routed top-1 + 1 shared expert (d_expert=8192), every layer.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=202048, rope_theta=5e5,
    moe=MoEConfig(n_experts=16, top_k=1, n_shared=1, d_expert=8192,
                  d_shared=8192, every_k=1, first_k_dense=0),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE = ArchConfig(
    name="llama4-scout-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512,
    moe=MoEConfig(n_experts=4, top_k=1, n_shared=1, d_expert=64,
                  d_shared=64, every_k=1, first_k_dense=0),
)
