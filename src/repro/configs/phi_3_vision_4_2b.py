"""Phi-3-vision-4.2B [vlm] — 32L d3072 32H (kv=32, MHA) d_ff=8192
vocab=32064; CLIP frontend is a stub supplying 576 patch embeddings
(ViT-L/14 @ 336px) pre-projected to d_model.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_head=96,
    d_ff=8192, vocab=32064, rope_theta=1e4, n_frontend_tokens=576,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

SMOKE = ArchConfig(
    name="phi-3-vision-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=512, n_frontend_tokens=8,
)
