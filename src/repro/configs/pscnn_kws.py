"""The paper's own model: binary KWS 1-D CNN (Fig. 7 reconstruction).

Not an LM — exposed through repro.models.kws + the core compiler/executor.
This config module provides the spec builders and compile hints so the
launcher can treat it uniformly (--arch pscnn-kws).
"""
from repro.models.kws import (
    ROTATE_HINTS,
    ROWSPLIT_HINTS,
    build_kws_smoke_spec,
    build_kws_spec,
)

CONFIG = build_kws_spec()
SMOKE = build_kws_smoke_spec()

__all__ = ["CONFIG", "SMOKE", "ROTATE_HINTS", "ROWSPLIT_HINTS",
           "build_kws_spec", "build_kws_smoke_spec"]
