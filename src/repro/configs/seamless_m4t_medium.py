"""SeamlessM4T-medium [audio enc-dec] — 12L enc + 12L dec, d1024 16H (kv=16,
head_dim 64) d_ff=4096 vocab=256206 (padded 256224 for 16-way sharding).
Modality frontend is a stub: input_specs supplies frame embeddings.
[arXiv:2308.11596; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_head=64, d_ff=4096, vocab=256206, rope_theta=1e4, frontend_dim=1024,
    source="arXiv:2308.11596",
)

SMOKE = ArchConfig(
    name="seamless-m4t-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, vocab=512, frontend_dim=64,
)
