"""xLSTM-350M [ssm] — 24L d1024 4H vocab=50304, sLSTM + mLSTM blocks
(superblock: 7x mLSTM + 1x sLSTM, repeated 3x), no FFN (d_ff=0).
Recurrent O(1) state -> runs long_500k.  [arXiv:2405.04517; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, superblock=("M", "M", "M", "M", "M", "M", "M", "s"),
    ssm_expand=2, long_context_ok=True, source="arXiv:2405.04517",
)

SMOKE = ArchConfig(
    name="xlstm-350m-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, d_ff=0, vocab=512,
    superblock=("M", "s"), ssm_expand=2, long_context_ok=True,
)
