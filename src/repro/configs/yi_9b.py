"""Yi-9B [dense] — 48L d4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-arch GQA.  [arXiv:2403.04652; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=11008, vocab=64000, rope_theta=5e6, source="arXiv:2403.04652",
)

SMOKE = ArchConfig(
    name="yi-9b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512,
)
