"""PSCNN core: the paper's contribution as composable subsystems.

quant     — binary/ternary STE quantizers + bit-packing
twm       — ternary weight mapping + sense-amplifier model (Fig. 3)
macro     — 1Mb CIM macro simulator (1024x1024, 128 SAs)
isa       — 32-bit MAC/WREP/PTR/HALT instruction set (Fig. 2)
cnn_spec  — declarative binary 1-D CNN model description
compiler  — spec -> placement + weight SRAM plan + instruction stream
executor  — controller: runs programs against simulated hardware state
pwb       — pooling write-back unit (Fig. 6)
pingpong  — flexible 4x64Kb ping-pong feature SRAM (Fig. 5)
energy    — cycle/energy model calibrated to Table I
"""
from repro.core import quant, twm, macro, isa, cnn_spec, pwb, pingpong, energy
from repro.core.compiler import compile_model, CompiledProgram
from repro.core.executor import Executor, ExecutionReport

__all__ = [
    "quant", "twm", "macro", "isa", "cnn_spec", "pwb", "pingpong", "energy",
    "compile_model", "CompiledProgram", "Executor", "ExecutionReport",
]
