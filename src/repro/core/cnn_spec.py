"""Declarative spec for binary 1-D CNNs — the compiler's input language.

A model is a sequence of layers over a (length, channels) feature map:

  Conv1D : ternary weights (K, Cin, Cout), stride/pad, optional fused pool,
           SA binary output or raw counts; multi-bit input via bit-serial.
  Pool   : standalone max-pool (PWB bypass).
  GAP    : global average pool -> 8-bit counts.
  FC     : dense (Cin, Cout) = Conv1D with K=1 on a length-1 map, but kept
           explicit because its input may be multi-bit GAP counts.

The same spec drives (a) the QAT training graph (models/kws.py), (b) the
ISA compiler, (c) the latency/energy analysis.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Conv1DSpec:
    cin: int
    cout: int
    k: int
    stride: int = 1
    pad: int = 0
    pool: int = 1            # fused max-pool window (1 = none)
    in_bits: int = 1         # input precision (8 for the first layer)
    in_offset: int = 0       # offset-binary zero point (128 for u8 audio)
    out_raw: bool = False    # raw counts instead of SA binary
    name: str = "conv"

    def out_len(self, in_len: int) -> int:
        lo = (in_len + 2 * self.pad - self.k) // self.stride + 1
        return lo // self.pool if self.pool > 1 else lo

    def conv_len(self, in_len: int) -> int:
        return (in_len + 2 * self.pad - self.k) // self.stride + 1

    @property
    def weights(self) -> int:
        return self.k * self.cin * self.cout

    def macs(self, in_len: int) -> int:
        return self.weights * self.conv_len(in_len)

    @property
    def rows(self) -> int:
        """Macro wordlines the layer needs (Cin x K receptive field)."""
        return self.cin * self.k


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    channels: int
    pool: int
    name: str = "pool"

    def out_len(self, in_len: int) -> int:
        return in_len // self.pool


@dataclasses.dataclass(frozen=True)
class GAPSpec:
    channels: int
    name: str = "gap"

    def out_len(self, in_len: int) -> int:
        del in_len
        return 1


@dataclasses.dataclass(frozen=True)
class FCSpec:
    cin: int
    cout: int
    in_bits: int = 1
    in_offset: int = 0
    out_raw: bool = False
    name: str = "fc"

    @property
    def weights(self) -> int:
        return self.cin * self.cout

    @property
    def macs(self) -> int:
        return self.weights

    @property
    def rows(self) -> int:
        return self.cin


LayerSpec = Conv1DSpec | PoolSpec | GAPSpec | FCSpec


@dataclasses.dataclass(frozen=True)
class CNN1DSpec:
    """Whole-model spec: input geometry + layer list."""

    in_len: int
    in_channels: int
    in_bits: int
    layers: tuple[LayerSpec, ...]
    name: str = "cnn1d"

    def trace_shapes(self) -> list[tuple[int, int]]:
        """(length, channels) after each layer (length=1 for GAP/FC)."""
        shapes = []
        l, c = self.in_len, self.in_channels
        for spec in self.layers:
            if isinstance(spec, Conv1DSpec):
                assert spec.cin == c, f"{spec.name}: cin {spec.cin} != {c}"
                l, c = spec.out_len(l), spec.cout
            elif isinstance(spec, PoolSpec):
                assert spec.channels == c
                l = spec.out_len(l)
            elif isinstance(spec, GAPSpec):
                assert spec.channels == c
                l = 1
            elif isinstance(spec, FCSpec):
                assert spec.cin == c, f"{spec.name}: cin {spec.cin} != {c}"
                l, c = 1, spec.cout
            shapes.append((l, c))
        return shapes

    @property
    def total_weights(self) -> int:
        return sum(
            s.weights for s in self.layers if isinstance(s, (Conv1DSpec, FCSpec))
        )

    @property
    def total_macs(self) -> int:
        macs, l = 0, self.in_len
        for spec in self.layers:
            if isinstance(spec, Conv1DSpec):
                macs += spec.macs(l)
                l = spec.out_len(l)
            elif isinstance(spec, PoolSpec):
                l = spec.out_len(l)
            elif isinstance(spec, GAPSpec):
                l = 1
            elif isinstance(spec, FCSpec):
                macs += spec.macs
        return macs

    @property
    def model_size_kb(self) -> float:
        """Paper's unit: weights counted in Kb (1 weight = 1 bit pre-TWM)."""
        return self.total_weights / 1024.0
