"""Compiler: CNN1DSpec -> macro placement + weight SRAM plan + instruction
stream (paper §II-A/C/G, Fig. 2/4).

Pipeline:
  1. chunk       — split each conv/FC layer's output channels into column
                   chunks of <=128 bitline pairs (one SA group per read).
  2. place       — 2D first-fit-decreasing of fixed chunks onto the
                   1024x512-pair macro.  Chunks named in ``rotate_hints``
                   (or that fail placement) become *rotating*: stored in the
                   512Kb weight SRAM and WREP'd into a shared rotation
                   region right before their MAC executes.  Rotation-region
                   sharing is safe because chunks execute sequentially.
  3. ping-pong   — assign IFM/OFM addresses in the 8192-word feature space,
                   alternating low/high ends (flexible allocation, Fig. 5).
  4. emit        — PTR / WREP / MAC / HALT stream + binding table.

Residency planning is 2D bin packing + scheduling (NP-hard); like real
accelerator toolchains we take a good heuristic plus optional placement
pragmas (``rotate_hints`` / ``rowsplit_hints``).  Row-splitting is legal
only for raw-output layers (outmode=1): their digital readout counters can
accumulate row-group partials, whereas SA-binarized layers must see the full
receptive field on one bitline pair (the paper's no-partial-sum principle).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import isa, macro
from repro.core.cnn_spec import CNN1DSpec, Conv1DSpec, FCSpec, GAPSpec, PoolSpec


@dataclasses.dataclass
class Chunk:
    """One column chunk of a layer: ``pairs`` output channels on one SA group."""

    name: str
    layer_idx: int
    exec_idx: int
    rows: int             # wordlines = Cin*K (or a row-split slice)
    pairs: int            # padded output-channel count (multiple of 16)
    ch0: int              # first logical output channel
    ch1: int              # one past last logical output channel
    row0_w: int = 0       # first weight row (for row-splits)
    rotating: bool = False
    page_id: int = -1
    placed: tuple[int, int] | None = None  # (row0, pair0)
    wsram_page: int = -1

    @property
    def weights(self) -> int:
        return self.rows * self.pairs


@dataclasses.dataclass
class LayerBinding:
    """Everything the executor needs to run one layer."""

    layer_idx: int
    spec: object
    chunks: list[Chunk]
    ifm_addr: int = 0
    ofm_addr: int = 0


@dataclasses.dataclass
class CompiledProgram:
    spec: CNN1DSpec
    words: list[int]
    bindings: list[LayerBinding]
    instr_meta: list[tuple[str, object]]  # (kind, payload) per instruction
    cim: macro.CIMMacro
    wsram: macro.WeightSRAM
    rotation_region: tuple[int, int, int, int] | None  # row0, pair0, rows, pairs
    thresholds: dict[int, tuple[np.ndarray, np.ndarray]]  # layer -> (thr, flip)
    weights: dict[int, np.ndarray]  # layer -> ternary weights
    in_addr: int = 0

    def disassemble(self) -> str:
        return isa.disassemble(self.words)


def _pad16(x: int) -> int:
    return ((x + 15) // 16) * 16


def chunk_layer(spec, layer_idx: int, exec_base: int, rowsplit: int = 1) -> list[Chunk]:
    """Split a conv/FC layer into <=128-pair column chunks (x row splits)."""
    if isinstance(spec, Conv1DSpec):
        cout, rows = spec.cout, spec.rows
    elif isinstance(spec, FCSpec):
        cout, rows = spec.cout, spec.rows
    else:
        return []
    n_col = max(1, -(-cout // macro.N_SA))
    chunks: list[Chunk] = []
    e = exec_base
    for rs in range(rowsplit):
        r0 = rs * (rows // rowsplit)
        r1 = rows if rs == rowsplit - 1 else (rs + 1) * (rows // rowsplit)
        for c in range(n_col):
            # SA-group-sized chunks: 128, 128, ..., remainder
            ch0, ch1 = c * macro.N_SA, min((c + 1) * macro.N_SA, cout)
            chunks.append(
                Chunk(
                    name=f"{spec.name}.r{rs}c{c}" if rowsplit > 1 else f"{spec.name}.c{c}",
                    layer_idx=layer_idx,
                    exec_idx=e,
                    rows=r1 - r0,
                    pairs=_pad16(ch1 - ch0),
                    ch0=ch0,
                    ch1=ch1,
                    row0_w=r0,
                )
            )
            e += 1
    return chunks


class _Grid:
    """First-fit 2D occupancy over (1024 rows x 512 pairs)."""

    def __init__(self) -> None:
        self.occ = np.zeros((macro.N_ROWS, macro.N_PAIRS), dtype=bool)

    def place(self, rows: int, pairs: int) -> tuple[int, int] | None:
        """16-aligned first-fit scan (row-major)."""
        for r0 in range(0, macro.N_ROWS - rows + 1, 16):
            for p0 in range(0, macro.N_PAIRS - pairs + 1, 16):
                if not self.occ[r0 : r0 + rows, p0 : p0 + pairs].any():
                    self.occ[r0 : r0 + rows, p0 : p0 + pairs] = True
                    return (r0, p0)
        return None


def compile_model(
    spec: CNN1DSpec,
    weights: dict[int, np.ndarray],
    thresholds: dict[int, tuple[np.ndarray, np.ndarray]],
    rotate_hints: tuple[str, ...] = (),
    rowsplit_hints: dict[str, int] | None = None,
) -> CompiledProgram:
    """Plan placement + emit the instruction stream for one model.

    weights[layer_idx]: (K, Cin, Cout) or (Cin, Cout) ternary int arrays.
    thresholds[layer_idx]: (thr, flip) arrays of length Cout (SA offsets).
    """
    rowsplit_hints = rowsplit_hints or {}
    shapes = spec.trace_shapes()

    # ---- 1. chunk ----------------------------------------------------------
    all_chunks: list[Chunk] = []
    per_layer: dict[int, list[Chunk]] = {}
    e = 0
    for li, lspec in enumerate(spec.layers):
        rs = rowsplit_hints.get(getattr(lspec, "name", ""), 1)
        if rs > 1 and not getattr(lspec, "out_raw", False):
            raise ValueError(
                f"{lspec.name}: row-split needs raw output (digital accumulation)"
            )
        cs = chunk_layer(lspec, li, e, rowsplit=rs)
        e += len(cs)
        per_layer[li] = cs
        all_chunks.extend(cs)

    # ---- 2. residency + placement -----------------------------------------
    rotating = [c for c in all_chunks if c.name in rotate_hints]
    for c in rotating:
        c.rotating = True
    fixed = [c for c in all_chunks if not c.rotating]

    grid = _Grid()
    region = None
    if rotating:
        rr = max(c.rows for c in rotating)
        rp = max(c.pairs for c in rotating)
        pos = grid.place(rr, rp)
        if pos is None:
            raise MemoryError("cannot place rotation region")
        region = (pos[0], pos[1], rr, rp)

    retry: list[Chunk] = []
    for c in sorted(fixed, key=lambda c: -(c.rows * c.pairs)):
        pos = grid.place(c.rows, c.pairs)
        if pos is None:
            retry.append(c)
        else:
            c.placed = pos
    # chunks that failed fixed placement fall back to rotating (auto mode)
    for c in sorted(retry, key=lambda c: c.exec_idx):
        c.rotating = True
        rotating.append(c)
        if region is None or c.rows > region[2] or c.pairs > region[3]:
            rr = max(region[2] if region else 0, c.rows)
            rp = max(region[3] if region else 0, c.pairs)
            pos = grid.place(rr, rp)
            if pos is None:
                raise MemoryError(
                    f"chunk {c.name} fits neither fixed nor rotation region; "
                    "add rotate_hints or shrink the model"
                )
            region = (pos[0], pos[1], rr, rp)
    rotating.sort(key=lambda c: c.exec_idx)

    # ---- 3. build macro + weight SRAM images ------------------------------
    cim = macro.CIMMacro()
    wsram = macro.WeightSRAM()
    page_id = 0
    wsram_page = 0

    def chunk_weights(c: Chunk) -> np.ndarray:
        w = weights[c.layer_idx]
        w2 = w.reshape(-1, w.shape[-1]) if w.ndim == 3 else w
        sl = w2[c.row0_w : c.row0_w + c.rows, c.ch0 : c.ch1]
        out = np.zeros((c.rows, c.pairs), dtype=np.int8)
        out[:, : c.ch1 - c.ch0] = sl
        return out

    for c in all_chunks:
        c.page_id = page_id
        page_id += 1
        if c.rotating:
            c.wsram_page = wsram_page
            wsram.store(wsram_page, chunk_weights(c))
            wsram_page += 1
        else:
            assert c.placed is not None
            cim.claim(macro.Page(c.page_id, c.placed[0], c.placed[1], c.rows, c.pairs))
            cim.write_page(c.page_id, chunk_weights(c))

    # ---- 4. ping-pong addresses + instruction emission ---------------------
    words: list[int] = []
    meta: list[tuple[str, object]] = []
    bindings: list[LayerBinding] = []

    def fmap_words(length: int, channels: int, fmt: str) -> int:
        bits = length * channels * (1 if fmt == "bits" else 8)
        return (bits + 31) // 32

    in_fmt = "u8" if spec.in_bits > 1 else "bits"
    cur_addr = 0  # input lives at the low end
    cur_words = fmap_words(spec.in_len, spec.in_channels, in_fmt)
    low_side = False  # next OFM goes to the high end
    l, c_ch = spec.in_len, spec.in_channels

    for li, lspec in enumerate(spec.layers):
        out_l, out_c = shapes[li]
        if isinstance(lspec, (Conv1DSpec, FCSpec)):
            out_fmt = "u8" if getattr(lspec, "out_raw", False) else "bits"
        elif isinstance(lspec, GAPSpec):
            out_fmt = "u8"
        else:
            out_fmt = "bits"
        out_words = fmap_words(out_l, out_c, out_fmt)
        ofm_addr = 0 if low_side else isa.MAX_ADDR - out_words
        if ofm_addr < 0 or cur_words + out_words > isa.MAX_ADDR:
            raise MemoryError(
                f"layer {li}: IFM {cur_words}w + OFM {out_words}w exceeds "
                f"{isa.MAX_ADDR}-word ping-pong space"
            )
        b = LayerBinding(li, lspec, per_layer[li], ifm_addr=cur_addr, ofm_addr=ofm_addr)
        bindings.append(b)

        words.append(isa.PtrInstr(ifm_addr=cur_addr, ofm_addr=ofm_addr).encode())
        meta.append(("ptr", b))

        if isinstance(lspec, (Conv1DSpec, FCSpec)):
            for ch in per_layer[li]:
                if ch.rotating:
                    r0, p0, _, _ = region
                    words.append(
                        isa.WrepInstr(
                            row_start=r0, n_rows=ch.rows, wsram_page=ch.wsram_page
                        ).encode()
                    )
                    meta.append(("wrep", ch))
                mi = isa.MacInstr(
                    fuse=getattr(lspec, "pool", 1) > 1,
                    ltype=0,
                    k=lspec.k if isinstance(lspec, Conv1DSpec) else 1,
                    stride=lspec.stride if isinstance(lspec, Conv1DSpec) else 1,
                    cin=_pad16(lspec.cin),
                    cout=ch.pairs,
                    bitser=lspec.in_bits,
                    wpage=ch.page_id % 16,
                    pool=getattr(lspec, "pool", 1),
                    outmode=1 if getattr(lspec, "out_raw", False) else 0,
                )
                words.append(mi.encode())
                meta.append(("mac", (b, ch)))
        elif isinstance(lspec, PoolSpec):
            words.append(
                isa.MacInstr(
                    ltype=1, k=lspec.pool, cin=_pad16(lspec.channels),
                    cout=_pad16(lspec.channels), pool=1,
                ).encode()
            )
            meta.append(("pool", b))
        elif isinstance(lspec, GAPSpec):
            words.append(
                isa.MacInstr(
                    ltype=1, k=0, cin=_pad16(lspec.channels),
                    cout=_pad16(lspec.channels), outmode=1,
                ).encode()
            )
            meta.append(("gap", b))

        cur_addr, cur_words = ofm_addr, out_words
        low_side = not low_side
        l, c_ch = out_l, out_c

    words.append(isa.HaltInstr().encode())
    meta.append(("halt", None))

    return CompiledProgram(
        spec=spec,
        words=words,
        bindings=bindings,
        instr_meta=meta,
        cim=cim,
        wsram=wsram,
        rotation_region=region,
        thresholds=thresholds,
        weights={k: np.asarray(v) for k, v in weights.items()},
        in_addr=0,
    )
