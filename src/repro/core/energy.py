"""Component-level energy/latency model, calibrated to Table I.

Accounting follows the paper: 1 MAC = 1 OP (350M "MACs per inference",
150.8 "GOPS" = MACs/latency, 885.86 "TOPS/W" = MACs/energy — the arithmetic
only closes under that convention; see DESIGN.md §1).

The model is component-based:
  E = e_mac * active_MACs                (analog macro read, dominant)
    + e_sa * SA_decisions
    + e_sram_r/w * feature-SRAM bits     (ping-pong system)
    + e_wsram_r * weight-SRAM bits + e_cell_w * macro cells (WREP)
    + e_ctrl * cycles                    (controller + instruction fetch)

e_mac is fitted once so the reconstructed KWS model lands on Table I's
0.399 uJ/inference (DESIGN.md §9.4); every other constant is a plausible
28nm figure and all other models/benchmarks reuse the same fitted params.
"""
from __future__ import annotations

import dataclasses

FREQ_HZ = 10e6  # Table I operating point


@dataclasses.dataclass(frozen=True)
class EnergyParams:
    e_mac: float = 0.625161e-15  # J per active MAC (fitted, see calibrate())
    e_sa: float = 2.0e-15        # J per SA decision
    e_sram_r: float = 50e-15     # J per feature-SRAM bit read
    e_sram_w: float = 60e-15     # J per feature-SRAM bit written
    e_wsram_r: float = 50e-15    # J per weight-SRAM bit read (WREP source)
    e_cell_w: float = 100e-15    # J per macro cell programmed (WREP dest)
    e_ctrl: float = 200e-15      # J per cycle (controller, fetch, clocking)


@dataclasses.dataclass
class EnergyLedger:
    """Mutable per-run accumulator the executor charges into."""

    params: EnergyParams = dataclasses.field(default_factory=EnergyParams)
    macs: int = 0        # logical MACs (paper's GOPS/TOPS-W accounting)
    phys_macs: int = 0   # physical macro MAC activations (x bit-serial passes)
    sa_decisions: int = 0
    sram_read_bits: int = 0
    sram_write_bits: int = 0
    wsram_read_bits: int = 0
    cells_written: int = 0
    cycles: int = 0

    def charge_mac_op(
        self, logical_macs: int, phys_macs: int, sa_decisions: int, cycles: int
    ) -> None:
        self.macs += logical_macs
        self.phys_macs += phys_macs
        self.sa_decisions += sa_decisions
        self.cycles += cycles

    def charge_sram(self, read_bits: int = 0, write_bits: int = 0) -> None:
        self.sram_read_bits += read_bits
        self.sram_write_bits += write_bits

    def charge_wrep(self, bits_read: int, cells_written: int, cycles: int) -> None:
        self.wsram_read_bits += bits_read
        self.cells_written += cells_written
        self.cycles += cycles

    def charge_cycles(self, cycles: int) -> None:
        self.cycles += cycles

    # -- results -------------------------------------------------------------

    @property
    def energy_j(self) -> float:
        p = self.params
        return (
            p.e_mac * self.phys_macs
            + p.e_sa * self.sa_decisions
            + p.e_sram_r * self.sram_read_bits
            + p.e_sram_w * self.sram_write_bits
            + p.e_wsram_r * self.wsram_read_bits
            + p.e_cell_w * self.cells_written
            + p.e_ctrl * self.cycles
        )

    @property
    def latency_s(self) -> float:
        return self.cycles / FREQ_HZ

    @property
    def power_w(self) -> float:
        return self.energy_j / self.latency_s if self.cycles else 0.0

    @property
    def gops(self) -> float:
        """Paper convention: MACs / latency, in G/s."""
        return self.macs / self.latency_s / 1e9 if self.cycles else 0.0

    @property
    def tops_per_w(self) -> float:
        """Paper convention: MACs / energy, in T/J."""
        return self.macs / self.energy_j / 1e12 if self.energy_j else 0.0

    def summary(self) -> dict[str, float]:
        return {
            "macs": float(self.macs),
            "cycles": float(self.cycles),
            "latency_us": self.latency_s * 1e6,
            "energy_uj": self.energy_j * 1e6,
            "power_uw": self.power_w * 1e6,
            "gops": self.gops,
            "tops_per_w": self.tops_per_w,
        }


def calibrate_e_mac(ledger: EnergyLedger, target_energy_j: float) -> EnergyParams:
    """Solve e_mac so that this ledger's totals land on the target energy.

    Used once against the reconstructed KWS model (target 0.399 uJ); the
    resulting e_mac is the default in EnergyParams.
    """
    p = ledger.params
    fixed = (
        p.e_sa * ledger.sa_decisions
        + p.e_sram_r * ledger.sram_read_bits
        + p.e_sram_w * ledger.sram_write_bits
        + p.e_wsram_r * ledger.wsram_read_bits
        + p.e_cell_w * ledger.cells_written
        + p.e_ctrl * ledger.cycles
    )
    if ledger.phys_macs == 0:
        raise ValueError("ledger has no MACs to calibrate against")
    e_mac = (target_energy_j - fixed) / ledger.phys_macs
    if e_mac <= 0:
        raise ValueError(f"fixed components {fixed} exceed target {target_energy_j}")
    return dataclasses.replace(p, e_mac=e_mac)
