"""Instruction-stream executor: the system controller + CIM core (Fig. 1).

Walks the compiled program word by word, decoding each instruction exactly as
the hardware controller would, and executes it against the simulated state:

  PTR   -> latch IFM/OFM pointers
  WREP  -> weight SRAM -> macro rotation region (claim+program the page)
  MAC   -> stream the IFM through the line buffer, activate the chunk's
           wordlines, read SA outputs (or raw counts), PWB pool, write OFM
  HALT  -> stop

All MAC arithmetic is computed FROM THE MACRO CELL STATE (`read_page`), so a
mis-scheduled WREP yields wrong activations, like silicon would.  Cycle and
energy charges follow DESIGN.md §1/§9; the ledger reproduces Table I.

``fuse_pool=False`` runs the paper's baseline: pooling executes as a separate
pass through the PWB bypass (extra SRAM traffic + cycles) instead of fused
into the conv write-back — the §II-H latency-reduction experiment.

The functional math reuses kernels/ref.py so the executor is bit-exact with
the Pallas kernels and the QAT training graph.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import isa, macro, pwb
from repro.core.cnn_spec import Conv1DSpec, FCSpec
from repro.core.compiler import Chunk, CompiledProgram, LayerBinding
from repro.core.energy import EnergyLedger, EnergyParams
from repro.core.pingpong import FmapRef, PingPongSRAM
from repro.kernels import ref

READOUT_CYCLES = 8  # thermometer SA sweep per raw-output position per chunk


@dataclasses.dataclass
class ExecutionReport:
    output: np.ndarray
    ledger: EnergyLedger
    layer_cycles: dict[str, int]
    bank_active_cycles: np.ndarray
    fmaps: dict[int, np.ndarray]


class Executor:
    """Runs a CompiledProgram against fresh macro/SRAM/feature-SRAM state."""

    def __init__(
        self,
        prog: CompiledProgram,
        params: EnergyParams | None = None,
        fuse_pool: bool = True,
    ) -> None:
        self.prog = prog
        self.params = params or EnergyParams()
        self.fuse_pool = fuse_pool

    # -----------------------------------------------------------------------

    def run(self, x: np.ndarray) -> ExecutionReport:
        prog = self.prog
        spec = prog.spec
        ledger = EnergyLedger(params=self.params)
        sram = PingPongSRAM()
        layer_cycles: dict[str, int] = {}
        fmaps: dict[int, np.ndarray] = {}
        stage: dict[int, dict[int, np.ndarray]] = {}
        raw_acc: dict[int, np.ndarray] = {}

        in_fmt = "u8" if spec.in_bits > 1 else "bits"
        in_ref = FmapRef(prog.in_addr, spec.in_len, spec.in_channels, in_fmt)
        (sram.write_u8 if in_fmt == "u8" else sram.write_bits)(in_ref, np.asarray(x))

        ifm_addr = ofm_addr = 0
        binding: LayerBinding | None = None
        cur_len, cur_ch = spec.in_len, spec.in_channels
        out: np.ndarray | None = None

        for pc, word in enumerate(prog.words):
            kind, payload = prog.instr_meta[pc]
            instr = isa.decode(word)

            if isinstance(instr, isa.HaltInstr):
                break

            if isinstance(instr, isa.PtrInstr):
                ifm_addr, ofm_addr = instr.ifm_addr, instr.ofm_addr
                binding = payload
                continue

            if isinstance(instr, isa.WrepInstr):
                chunk: Chunk = payload
                region = prog.rotation_region
                assert region is not None, "WREP without rotation region"
                page = macro.Page(
                    chunk.page_id, region[0], region[1], chunk.rows, chunk.pairs
                )
                prog.cim.claim(page, evict=True)
                prog.cim.write_page(chunk.page_id, prog.wsram.load(chunk.wsram_page))
                bits = chunk.rows * chunk.pairs * 2
                cyc = -(-chunk.rows // macro.WREP_ROWS_PER_CYCLE)
                ledger.charge_wrep(bits_read=bits, cells_written=bits, cycles=cyc)
                layer_cycles["wrep"] = layer_cycles.get("wrep", 0) + cyc
                continue

            assert isinstance(instr, isa.MacInstr) and binding is not None
            lspec = binding.spec
            name = getattr(lspec, "name", f"layer{binding.layer_idx}")

            # ---- standalone pooling (PWB bypass, ltype=1) -------------------
            if instr.ltype == 1:
                ifm = FmapRef(ifm_addr, cur_len, cur_ch, "bits")
                y = sram.read_bits(ifm)
                if instr.k == 0:  # GAP -> 8-bit counts
                    o = pwb.gap_counts(y)[None, :].astype(np.int64)
                    ofm = FmapRef(ofm_addr, 1, cur_ch, "u8")
                    PingPongSRAM.check_layer(ifm, ofm)
                    sram.write_u8(ofm, o.astype(np.uint8))
                    cyc = pwb.gap_cycles(cur_len, cur_ch)
                    wbits, new_len = cur_ch * 8, 1
                else:
                    o = pwb.maxpool_bits(y, instr.k).astype(np.int64)
                    ofm = FmapRef(ofm_addr, o.shape[0], cur_ch, "bits")
                    PingPongSRAM.check_layer(ifm, ofm)
                    sram.write_bits(ofm, o.astype(np.uint8))
                    cyc = pwb.standalone_pool_cycles(cur_len, cur_ch, instr.k)
                    wbits, new_len = o.shape[0] * cur_ch, o.shape[0]
                ledger.charge_cycles(cyc)
                ledger.charge_sram(read_bits=cur_len * cur_ch, write_bits=wbits)
                sram.account_layer(ifm, ofm, cyc)
                layer_cycles[name] = layer_cycles.get(name, 0) + cyc
                fmaps[binding.layer_idx] = o
                out, cur_len = o, new_len
                continue

            # ---- convolution / FC chunk ------------------------------------
            _, chunk = payload
            w_page = prog.cim.read_page(chunk.page_id)
            n_ch = chunk.ch1 - chunk.ch0
            w = w_page[:, :n_ch].astype(np.int32)

            is_fc = isinstance(lspec, FCSpec)
            k = 1 if is_fc else lspec.k
            stride = 1 if is_fc else lspec.stride
            pad = 0 if is_fc else lspec.pad
            in_bits, in_off = lspec.in_bits, lspec.in_offset
            cin = lspec.cin

            ifm = FmapRef(ifm_addr, cur_len, cin, "u8" if in_bits > 1 else "bits")
            xin = sram.read_u8(ifm) if in_bits > 1 else sram.read_bits(ifm)
            if is_fc:
                # row-split chunks see only their slice of the input rows
                xin = xin.reshape(1, -1)[:, chunk.row0_w : chunk.row0_w + chunk.rows]
                wk = w
            else:
                wk = w.reshape(k, cin, n_ch)

            if in_bits > 1:
                fn = ref.ref_bitserial_matmul if is_fc else ref.ref_bitserial_conv1d
                args = (xin, wk, in_bits, in_off) if is_fc else (
                    xin, wk, in_bits, in_off, stride, pad)
                d = np.asarray(fn(*args))
            else:
                if is_fc:
                    d = np.asarray(ref.ref_twm_matmul(xin, wk))
                else:
                    d = np.asarray(ref.ref_bnn_conv1d(xin, wk, stride, pad))

            positions = d.shape[0]
            raw_out = getattr(lspec, "out_raw", False)

            # cycle + energy charges for this chunk
            cyc = positions * in_bits
            if raw_out:
                cyc += positions * READOUT_CYCLES
            phys = chunk.rows * n_ch * positions * in_bits
            logical = chunk.rows * n_ch * positions
            sa = positions * chunk.pairs * in_bits
            ledger.charge_mac_op(logical, phys, sa, cyc)
            ledger.charge_sram(read_bits=cur_len * cin * (in_bits if in_bits > 1 else 1))
            layer_cycles[name] = layer_cycles.get(name, 0) + cyc

            if raw_out:
                acc = raw_acc.setdefault(
                    binding.layer_idx, np.zeros((positions, lspec.cout), np.int64)
                )
                acc[:, chunk.ch0 : chunk.ch1] += d
            else:
                thr, flip = prog.thresholds[binding.layer_idx]
                ge = d >= thr[None, chunk.ch0 : chunk.ch1]
                y = np.where(flip[None, chunk.ch0 : chunk.ch1], ~ge, ge).astype(np.uint8)
                stage.setdefault(binding.layer_idx, {})[chunk.ch0] = y

            # ---- assemble when the layer's last chunk retires ---------------
            if chunk is binding.chunks[-1]:
                if raw_out:
                    o = raw_acc.pop(binding.layer_idx)
                    ofm = FmapRef(ofm_addr, positions, lspec.cout, "u8")
                    PingPongSRAM.check_layer(ifm, ofm)
                    sram.write_u8(ofm, np.clip(o, 0, 255).astype(np.uint8))
                    ledger.charge_sram(write_bits=positions * lspec.cout * 8)
                    new_len = positions
                else:
                    sl = stage.pop(binding.layer_idx)
                    o = np.zeros((positions, lspec.cout), dtype=np.uint8)
                    for ch in binding.chunks:
                        o[:, ch.ch0 : ch.ch1] = sl[ch.ch0]
                    pool = instr.pool if instr.fuse else 1
                    if pool > 1:
                        if self.fuse_pool:
                            o = pwb.maxpool_bits(o, pool)  # in write-back, free
                        else:
                            # baseline: write conv OFM, separate pool pass
                            ledger.charge_sram(write_bits=positions * lspec.cout)
                            extra = pwb.standalone_pool_cycles(
                                positions, lspec.cout, pool
                            )
                            ledger.charge_cycles(extra)
                            ledger.charge_sram(read_bits=positions * lspec.cout)
                            layer_cycles[name + "+pool"] = extra
                            o = pwb.maxpool_bits(o, pool)
                    ofm = FmapRef(ofm_addr, o.shape[0], lspec.cout, "bits")
                    if self.fuse_pool:
                        PingPongSRAM.check_layer(ifm, ofm)
                    sram.write_bits(ofm, o)
                    ledger.charge_sram(write_bits=o.shape[0] * lspec.cout)
                    new_len = o.shape[0]
                    o = o.astype(np.int64)
                sram.account_layer(ifm, ofm, layer_cycles.get(name, 0))
                fmaps[binding.layer_idx] = o
                out, cur_len, cur_ch = o, new_len, lspec.cout

        assert out is not None, "program produced no output"
        return ExecutionReport(
            output=out,
            ledger=ledger,
            layer_cycles=layer_cycles,
            bank_active_cycles=sram.bank_active_cycles.copy(),
            fmaps=fmaps,
        )
