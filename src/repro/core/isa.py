"""PSCNN 32-bit instruction set (paper §II-A, Fig. 2).

Four instruction types selected by the top 3 bits, exactly as the paper
specifies: MAC, weight replacement (WREP), pointer (PTR), halt (HALT).
The paper gives the field *types* but not the bit-level layout; the layout
below is our reconstruction, chosen so every field of the paper's
description fits in 32 bits (documented in DESIGN.md §1/C3):

MAC   op=000 | fuse(1) | ltype(1) | K(5) | stride_log2(2) | cin_g(6) |
      cout_g(5) | bitser_log2(2) | wpage(4) | pool_log2(2) | outmode(1) |
      spare(3)
  - ltype: 0 = convolution, 1 = standalone pooling (PWB bypass, §II-H)
  - fuse: pool fused into the conv write-back (PWB)
  - K: kernel size 1..31 (pool window when ltype=1; 0 means global pool)
  - stride 2^s (1,2,4,8); cin_g = ceil(Cin/16) stored-1 (Cin<=1024);
    cout_g = ceil(Cout/16) stored-1 (Cout<=512 bitline pairs)
  - bitser: input bit-serial passes 2^b (1,2,4,8) for multi-bit inputs
  - wpage: macro weight-page id the layer reads (set by the compiler)
  - pool_log2: fused pool window 2^p
  - outmode: 0 = SA binary output, 1 = raw counts (bit-serial readout,
    used for the final classifier layer and GAP)

WREP  op=001 | row_start(10) | n_rows(10) | wsram_page(9)
  - copy n_rows macro rows from weight-SRAM page (weight update, §II-G)

PTR   op=010 | ifm_addr(13) | ofm_addr(13) | spare(3)
  - flat word addresses into the 4x64Kb ping-pong space (bank = addr>>11),
    "read starting address of the IFM and the write address of the OFM"

HALT  op=011 | spare(29)
"""
from __future__ import annotations

import dataclasses

OP_MAC, OP_WREP, OP_PTR, OP_HALT = 0, 1, 2, 3
_OP_NAMES = {OP_MAC: "MAC", OP_WREP: "WREP", OP_PTR: "PTR", OP_HALT: "HALT"}

# ping-pong space geometry (paper: four 64Kb single-port SRAMs)
BANK_BITS = 65536  # 64 Kb
N_BANKS = 4
WORD = 32
BANK_WORDS = BANK_BITS // WORD  # 2048
ADDR_BITS = 13  # 4 * 2048 = 8192 words
MAX_ADDR = N_BANKS * BANK_WORDS


def _check(val: int, bits: int, what: str) -> int:
    if not (0 <= val < (1 << bits)):
        raise ValueError(f"{what}={val} does not fit in {bits} bits")
    return val


def _log2(x: int, what: str) -> int:
    if x & (x - 1) or x <= 0:
        raise ValueError(f"{what}={x} must be a power of two")
    return x.bit_length() - 1


@dataclasses.dataclass(frozen=True)
class MacInstr:
    fuse: bool = False
    ltype: int = 0            # 0 conv, 1 standalone pool
    k: int = 1                # kernel/pool size (0 = global pool)
    stride: int = 1
    cin: int = 16             # logical channels (encoded /16)
    cout: int = 16
    bitser: int = 1
    wpage: int = 0
    pool: int = 1             # fused pool window
    outmode: int = 0          # 0 SA binary, 1 raw counts

    def encode(self) -> int:
        cin_g = (self.cin + 15) // 16
        cout_g = (self.cout + 15) // 16
        word = OP_MAC << 29
        word |= _check(int(self.fuse), 1, "fuse") << 28
        word |= _check(self.ltype, 1, "ltype") << 27
        word |= _check(self.k, 5, "k") << 22
        word |= _check(_log2(self.stride, "stride"), 2, "stride") << 20
        word |= _check(cin_g - 1, 6, "cin_g") << 14
        word |= _check(cout_g - 1, 5, "cout_g") << 9
        word |= _check(_log2(self.bitser, "bitser"), 2, "bitser") << 7
        word |= _check(self.wpage, 4, "wpage") << 3
        word |= _check(_log2(self.pool, "pool"), 2, "pool") << 1
        word |= _check(self.outmode, 1, "outmode")
        return word

    @staticmethod
    def decode(word: int) -> "MacInstr":
        return MacInstr(
            fuse=bool((word >> 28) & 1),
            ltype=(word >> 27) & 1,
            k=(word >> 22) & 0x1F,
            stride=1 << ((word >> 20) & 0x3),
            cin=(((word >> 14) & 0x3F) + 1) * 16,
            cout=(((word >> 9) & 0x1F) + 1) * 16,
            bitser=1 << ((word >> 7) & 0x3),
            wpage=(word >> 3) & 0xF,
            pool=1 << ((word >> 1) & 0x3),
            outmode=word & 1,
        )


@dataclasses.dataclass(frozen=True)
class WrepInstr:
    row_start: int
    n_rows: int
    wsram_page: int

    def encode(self) -> int:
        word = OP_WREP << 29
        word |= _check(self.row_start, 10, "row_start") << 19
        word |= _check(self.n_rows, 10, "n_rows") << 9
        word |= _check(self.wsram_page, 9, "wsram_page")
        return word

    @staticmethod
    def decode(word: int) -> "WrepInstr":
        return WrepInstr(
            row_start=(word >> 19) & 0x3FF,
            n_rows=(word >> 9) & 0x3FF,
            wsram_page=word & 0x1FF,
        )


@dataclasses.dataclass(frozen=True)
class PtrInstr:
    ifm_addr: int
    ofm_addr: int

    def encode(self) -> int:
        word = OP_PTR << 29
        word |= _check(self.ifm_addr, ADDR_BITS, "ifm_addr") << 16
        word |= _check(self.ofm_addr, ADDR_BITS, "ofm_addr") << 3
        return word

    @staticmethod
    def decode(word: int) -> "PtrInstr":
        return PtrInstr(
            ifm_addr=(word >> 16) & 0x1FFF,
            ofm_addr=(word >> 3) & 0x1FFF,
        )


@dataclasses.dataclass(frozen=True)
class HaltInstr:
    def encode(self) -> int:
        return OP_HALT << 29

    @staticmethod
    def decode(word: int) -> "HaltInstr":
        return HaltInstr()


Instr = MacInstr | WrepInstr | PtrInstr | HaltInstr


def opcode(word: int) -> int:
    return (word >> 29) & 0x7


def decode(word: int) -> Instr:
    op = opcode(word)
    if op == OP_MAC:
        return MacInstr.decode(word)
    if op == OP_WREP:
        return WrepInstr.decode(word)
    if op == OP_PTR:
        return PtrInstr.decode(word)
    if op == OP_HALT:
        return HaltInstr.decode(word)
    raise ValueError(f"unknown opcode {op:#05b}")


def encode_program(instrs: list[Instr]) -> list[int]:
    return [i.encode() for i in instrs]


def decode_program(words: list[int]) -> list[Instr]:
    out = []
    for w in words:
        i = decode(w)
        out.append(i)
        if isinstance(i, HaltInstr):
            break
    return out


def disassemble(words: list[int]) -> str:
    lines = []
    for pc, w in enumerate(words):
        i = decode(w)
        lines.append(f"{pc:04d}: {w:08x}  {_OP_NAMES[opcode(w)]:<5} {i}")
        if isinstance(i, HaltInstr):
            break
    return "\n".join(lines)
