"""Cycle-level simulator of the 1Mb SRAM CIM macro (paper §II-B/C, Fig. 1).

Geometry (paper): 1024 wordlines x 1024 bitlines, 128 sense amplifiers.
Under TWM (§II-D) adjacent bitlines pair up -> 512 bitline *pairs*; the 128
SAs are 4:1 column-muxed, so one macro read cycle activates up to 1024
wordlines and resolves up to 128 output channels.

The simulator stores the two TWM planes explicitly (what is physically in
the cells) and *computes from the stored cells*, so a mis-scheduled weight
replacement produces wrong activations — exactly the failure mode a real
program would hit.

Pages: the compiler places each layer as one or more column-chunk pages
(<=128 pairs per chunk = one SA group), mirroring "weights with the same
output channel index are placed on the same bitline pair" (Fig. 4).
"""
from __future__ import annotations

import dataclasses

import numpy as np

N_ROWS = 1024          # wordlines
N_COLS = 1024          # bitlines (cells per row)
N_PAIRS = N_COLS // 2  # TWM bitline pairs
N_SA = 128             # sense amplifiers -> max pairs resolved per cycle
CELLS = N_ROWS * N_COLS

WEIGHT_SRAM_BITS = 512 * 1024  # §II-G: 512Kb side SRAM
WREP_ROWS_PER_CYCLE = 2        # 2048-bit update bus (DESIGN.md §9)


@dataclasses.dataclass(frozen=True)
class Page:
    """A rectangular weight region: ``rows`` wordlines x ``pairs`` bitline pairs."""

    page_id: int
    row0: int
    pair0: int
    rows: int
    pairs: int

    def __post_init__(self):
        if not (0 <= self.row0 and self.row0 + self.rows <= N_ROWS):
            raise ValueError(f"page {self.page_id}: rows out of range {self}")
        if not (0 <= self.pair0 and self.pair0 + self.pairs <= N_PAIRS):
            raise ValueError(f"page {self.page_id}: pairs out of range {self}")
        if self.pairs > N_SA:
            raise ValueError(
                f"page {self.page_id}: {self.pairs} pairs exceeds one SA group ({N_SA})"
            )

    @property
    def cells(self) -> int:
        return self.rows * self.pairs * 2


class CIMMacro:
    """State + compute of the macro. All compute reads the stored planes."""

    def __init__(self) -> None:
        # physical cell planes, pair-indexed: pos/neg of shape (rows, pairs)
        self.pos = np.zeros((N_ROWS, N_PAIRS), dtype=np.uint8)
        self.neg = np.zeros((N_ROWS, N_PAIRS), dtype=np.uint8)
        self.pages: dict[int, Page] = {}
        self._owner = np.full((N_ROWS, N_PAIRS), -1, dtype=np.int32)

    # -- placement ---------------------------------------------------------

    def region_free(self, row0: int, pair0: int, rows: int, pairs: int,
                    ignore: set[int] | None = None) -> bool:
        ignore = ignore or set()
        region = self._owner[row0 : row0 + rows, pair0 : pair0 + pairs]
        used = np.unique(region)
        return all(o == -1 or o in ignore for o in used.tolist())

    def claim(self, page: Page, evict: bool = False) -> list[int]:
        """Register a page; returns the page-ids it evicted (if allowed)."""
        region = self._owner[
            page.row0 : page.row0 + page.rows, page.pair0 : page.pair0 + page.pairs
        ]
        owners = {int(o) for o in np.unique(region) if o != -1}
        if owners and not evict:
            raise ValueError(f"page {page.page_id} overlaps pages {sorted(owners)}")
        for o in owners:
            old = self.pages.pop(o)
            self._owner[old.row0 : old.row0 + old.rows,
                        old.pair0 : old.pair0 + old.pairs] = -1
        self.pages[page.page_id] = page
        region = self._owner[
            page.row0 : page.row0 + page.rows, page.pair0 : page.pair0 + page.pairs
        ]
        region[...] = page.page_id
        return sorted(owners)

    def write_page(self, page_id: int, w_ternary: np.ndarray) -> None:
        """Program ternary weights (rows, pairs) into the page's cells."""
        p = self.pages[page_id]
        if w_ternary.shape != (p.rows, p.pairs):
            raise ValueError(
                f"page {page_id}: weight shape {w_ternary.shape} != {(p.rows, p.pairs)}"
            )
        self.pos[p.row0 : p.row0 + p.rows, p.pair0 : p.pair0 + p.pairs] = (
            w_ternary > 0
        ).astype(np.uint8)
        self.neg[p.row0 : p.row0 + p.rows, p.pair0 : p.pair0 + p.pairs] = (
            w_ternary < 0
        ).astype(np.uint8)

    def read_page(self, page_id: int) -> np.ndarray:
        """Ternary weights currently held in the page's cells."""
        p = self.pages[page_id]
        pos = self.pos[p.row0 : p.row0 + p.rows, p.pair0 : p.pair0 + p.pairs]
        neg = self.neg[p.row0 : p.row0 + p.rows, p.pair0 : p.pair0 + p.pairs]
        return pos.astype(np.int32) - neg.astype(np.int32)

    # -- compute -----------------------------------------------------------

    def mac_cycle_count(self, page_id: int, n_positions: int, bitser: int) -> int:
        """Macro read cycles for a layer chunk: one cycle per output position
        per bit-serial pass (the chunk is <=128 pairs = one SA group)."""
        del page_id
        return n_positions * bitser

    def utilization(self, page_id: int) -> float:
        p = self.pages[page_id]
        return (p.rows * p.pairs) / float(N_ROWS * N_SA)

    @property
    def used_cells(self) -> int:
        return int(sum(p.cells for p in self.pages.values()))


class WeightSRAM:
    """512Kb side SRAM holding non-resident pages (§II-G).

    Stores ternary weights at 2 bits each, addressed by wsram page id.
    """

    def __init__(self) -> None:
        self.pages: dict[int, np.ndarray] = {}

    def store(self, wsram_page: int, w_ternary: np.ndarray) -> None:
        self.pages[wsram_page] = np.asarray(w_ternary, dtype=np.int8)
        if self.used_bits > WEIGHT_SRAM_BITS:
            raise MemoryError(
                f"weight SRAM overflow: {self.used_bits} > {WEIGHT_SRAM_BITS} bits"
            )

    def load(self, wsram_page: int) -> np.ndarray:
        return self.pages[wsram_page]

    @property
    def used_bits(self) -> int:
        return int(sum(2 * w.size for w in self.pages.values()))
