"""Flexible ping-pong feature SRAM (paper §II-F, Fig. 5).

Four 64Kb single-port SRAM banks form one flat 8192-word (32b) space.  Unlike
a conventional ping-pong buffer (two fixed halves), the IFM read pointer and
OFM write pointer are set *per layer* by PTR instructions, so allocation is
fully flexible: a large feature map may span banks (Fig. 5c), and banks not
addressed by the current layer are powered off (Fig. 5d).

The simulator owns the actual words (the executor reads/writes through it),
checks single-port discipline at layer granularity (IFM region and OFM
region must not share a bank — a shared bank would stall every cycle), and
keeps read/write/energy counters for the power model.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import isa

WORDS = isa.MAX_ADDR           # 8192 x 32b = 256Kb total
BANK_WORDS = isa.BANK_WORDS    # 2048
N_BANKS = isa.N_BANKS          # 4


def banks_of(addr: int, n_words: int) -> set[int]:
    if n_words <= 0:
        return set()
    first = addr // BANK_WORDS
    last = (addr + n_words - 1) // BANK_WORDS
    return set(range(first, last + 1))


@dataclasses.dataclass
class FmapRef:
    """A feature map stored in the ping-pong space.

    Flat stream layout (position-major, channel-minor):
      fmt='bits': (length, channels) binary, 32 values per word
      fmt='u8'  : (length, channels) 8-bit unsigned, 4 per word
    """

    addr: int
    length: int
    channels: int
    fmt: str = "bits"

    @property
    def n_bits(self) -> int:
        per = 1 if self.fmt == "bits" else 8
        return self.length * self.channels * per

    @property
    def n_words(self) -> int:
        return (self.n_bits + 31) // 32

    @property
    def banks(self) -> set[int]:
        return banks_of(self.addr, self.n_words)


class PingPongSRAM:
    def __init__(self) -> None:
        self.mem = np.zeros(WORDS, dtype=np.uint32)
        self.reads_bits = 0
        self.writes_bits = 0
        self.bank_active_cycles = np.zeros(N_BANKS, dtype=np.int64)

    # -- layer-level discipline checks --------------------------------------

    @staticmethod
    def check_layer(ifm: FmapRef, ofm: FmapRef) -> None:
        """IFM and OFM must fit, not overlap, and not share a bank
        (single-port: one side reads while the other writes)."""
        for ref, name in ((ifm, "IFM"), (ofm, "OFM")):
            if ref.addr < 0 or ref.addr + ref.n_words > WORDS:
                raise MemoryError(
                    f"{name} [{ref.addr}, {ref.addr + ref.n_words}) exceeds "
                    f"{WORDS}-word ping-pong space"
                )
        a0, a1 = ifm.addr, ifm.addr + ifm.n_words
        b0, b1 = ofm.addr, ofm.addr + ofm.n_words
        if max(a0, b0) < min(a1, b1):
            raise MemoryError(f"IFM {a0}:{a1} overlaps OFM {b0}:{b1}")
        shared = ifm.banks & ofm.banks
        if shared:
            raise MemoryError(
                f"single-port violation: IFM banks {sorted(ifm.banks)} and "
                f"OFM banks {sorted(ofm.banks)} share {sorted(shared)}"
            )

    def active_banks(self, ifm: FmapRef, ofm: FmapRef) -> set[int]:
        return ifm.banks | ofm.banks

    def account_layer(self, ifm: FmapRef, ofm: FmapRef, cycles: int) -> None:
        """Charge bank-active cycles for a layer (idle banks powered off)."""
        for b in self.active_banks(ifm, ofm):
            self.bank_active_cycles[b] += cycles

    # -- storage -------------------------------------------------------------

    def write_bits(self, ref: FmapRef, bits: np.ndarray) -> None:
        """bits: (length, channels) 0/1 -> flat packed words at ref.addr."""
        assert ref.fmt == "bits" and bits.shape == (ref.length, ref.channels)
        flat = np.zeros(ref.n_words * 32, dtype=np.uint32)
        flat[: bits.size] = bits.reshape(-1).astype(np.uint32)
        grouped = flat.reshape(ref.n_words, 32)
        shifts = np.arange(32, dtype=np.uint32)
        words = (grouped << shifts).sum(axis=-1, dtype=np.uint64).astype(np.uint32)
        self.mem[ref.addr : ref.addr + ref.n_words] = words
        self.writes_bits += bits.size

    def read_bits(self, ref: FmapRef) -> np.ndarray:
        assert ref.fmt == "bits"
        words = self.mem[ref.addr : ref.addr + ref.n_words]
        shifts = np.arange(32, dtype=np.uint32)
        bits = ((words[:, None] >> shifts) & np.uint32(1)).reshape(-1)
        self.reads_bits += ref.length * ref.channels
        return bits[: ref.length * ref.channels].reshape(ref.length, ref.channels)

    def write_u8(self, ref: FmapRef, vals: np.ndarray) -> None:
        assert ref.fmt == "u8" and vals.shape == (ref.length, ref.channels)
        flat = np.zeros(ref.n_words * 4, dtype=np.uint32)
        flat[: vals.size] = vals.reshape(-1).astype(np.uint32) & 0xFF
        grouped = flat.reshape(ref.n_words, 4)
        shifts = np.arange(4, dtype=np.uint32) * 8
        words = (grouped << shifts).sum(axis=-1, dtype=np.uint64).astype(np.uint32)
        self.mem[ref.addr : ref.addr + ref.n_words] = words
        self.writes_bits += vals.size * 8

    def read_u8(self, ref: FmapRef) -> np.ndarray:
        assert ref.fmt == "u8"
        words = self.mem[ref.addr : ref.addr + ref.n_words]
        shifts = np.arange(4, dtype=np.uint32) * 8
        vals = ((words[:, None] >> shifts) & np.uint32(0xFF)).reshape(-1)
        self.reads_bits += ref.length * ref.channels * 8
        return vals[: ref.length * ref.channels].reshape(ref.length, ref.channels)


@dataclasses.dataclass(frozen=True)
class FixedPingPong:
    """Conventional baseline (Fig. 5a): two fixed 128Kb halves.

    Used by the Fig. 5 benchmark to show layers that the fixed scheme cannot
    host but the flexible scheme can.
    """

    half_words: int = WORDS // 2

    def fits(self, ifm: FmapRef, ofm: FmapRef) -> bool:
        return ifm.n_words <= self.half_words and ofm.n_words <= self.half_words
