"""Pooling-Write Block (paper §II-H, Fig. 6).

Two paths:
  * fused: the SA output stream of a convolution passes through the OR-tree
    max-pool before the SRAM write — zero extra cycles (pipelined), and the
    OFM is written once, already pooled.
  * bypass: the macro is bypassed; the PWB reads an existing feature map and
    pools it standalone (max-pool or global-average-pool as popcount
    counters).  Costs read+write cycles through the 128-bit pool unit port.

The functional math lives in kernels/ref.py; this module is the *unit*:
cycle accounting + the mode decision, as programmed by the MAC instruction's
``fuse``/``ltype`` bits.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Pool-unit datapath width in bypass mode.  The paper does not specify it;
# 64 bits puts the reconstructed fused-vs-independent latency reduction
# closest to the paper's 35.9% (see benchmarks/pwb_latency.py for the
# 32/64/128-bit sensitivity sweep).  Fused-mode pooling is width-independent
# (it rides the macro write-back pipeline).
POOL_UNIT_BITS = 64


def fused_pool_extra_cycles() -> int:
    """Fused conv+pool adds no macro cycles (pipelined write-back)."""
    return 0


def standalone_pool_cycles(length: int, channels: int, pool: int) -> int:
    """Bypass-path pooling: stream L positions through the 128-bit unit.

    reads: one cycle per position per 128-bit channel group; writes: one per
    output window per group (single-port feature SRAM, §II-F).
    """
    groups = (channels + POOL_UNIT_BITS - 1) // POOL_UNIT_BITS
    out_len = length // pool if pool > 0 else 1
    return length * groups + out_len * groups


def gap_cycles(length: int, channels: int) -> int:
    """Global average pool (counts accumulate in the PWB counters)."""
    return standalone_pool_cycles(length, channels, pool=0)


def maxpool_bits(y: np.ndarray, pool: int) -> np.ndarray:
    """(L, C) 0/1 -> (L//pool, C): OR over non-overlapping windows."""
    l = (y.shape[0] // pool) * pool
    return y[:l].reshape(l // pool, pool, y.shape[1]).max(axis=1)


def gap_counts(y: np.ndarray) -> np.ndarray:
    """(L, C) 0/1 -> (C,) integer counts (8-bit saturating, as the PWB
    counters are 8 bits wide)."""
    c = y.astype(np.int64).sum(axis=0)
    return np.minimum(c, 255).astype(np.uint8)


@dataclasses.dataclass(frozen=True)
class PoolPlanEntry:
    """How one pooling op executes: fused into the producing conv or not."""

    fused: bool
    pool: int
    length: int     # pre-pool length
    channels: int

    @property
    def extra_cycles(self) -> int:
        if self.fused:
            return fused_pool_extra_cycles()
        return standalone_pool_cycles(self.length, self.channels, self.pool)
