"""Quantization primitives for the PSCNN binary/ternary regime.

The paper's arithmetic domain (Section II-D):
  * activations are binary  {+1, 0}   (one SRAM wordline is either driven or not)
  * weights     are ternary {+1, 0, -1} (one cell pair under TWM)

Training uses straight-through estimators (STE) so the binarized network is
trainable with ordinary SGD/Adam (Hubara et al., "Binarized Neural
Networks", the paper's ref [6], extended to ternary weights a la TWN).

Bit-packing: the TPU kernels consume activations and weight planes packed
32-lanes-per-uint32 along the contraction axis — the digital analogue of the
paper's 1024-wordline bitline (1024 bits = 32 packed words).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

PACK = 32  # bits per packed word (uint32 lanes)


# ---------------------------------------------------------------------------
# Straight-through estimators
# ---------------------------------------------------------------------------

@jax.custom_vjp
def binarize_act(x: jax.Array) -> jax.Array:
    """Binary activation {1, 0}: the sense-amplifier decision of eq. (1).

    Forward: 1 if x >= 0 else 0.  Backward: clipped straight-through
    (gradient passes where |x| <= 1, the standard BNN hard-tanh window).
    """
    return (x >= 0).astype(x.dtype)


def _binarize_act_fwd(x):
    return binarize_act(x), x


def _binarize_act_bwd(x, g):
    pass_through = (jnp.abs(x) <= 1.0).astype(g.dtype)
    return (g * pass_through,)


binarize_act.defvjp(_binarize_act_fwd, _binarize_act_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def ternarize_weight(w: jax.Array, threshold_scale: float = 0.05) -> jax.Array:
    """Ternary weight {-1, 0, +1} with a per-tensor magnitude threshold.

    delta = threshold_scale * mean(|w|)   (TWN-style symmetric threshold).
    Backward: identity STE (full pass-through; weights live in fp32 shadow).
    """
    delta = threshold_scale * jnp.mean(jnp.abs(w))
    return (jnp.sign(w) * (jnp.abs(w) > delta)).astype(w.dtype)


def _ternarize_fwd(w, threshold_scale):
    return ternarize_weight(w, threshold_scale), None


def _ternarize_bwd(threshold_scale, _res, g):
    return (g,)


ternarize_weight.defvjp(_ternarize_fwd, _ternarize_bwd)


# ---------------------------------------------------------------------------
# Plane decomposition (TWM view of a ternary tensor)
# ---------------------------------------------------------------------------

def ternary_planes(w_t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split ternary {-1,0,1} into (positive, negative) 0/1 planes.

    This is exactly the paper's TWM cell-pair assignment: ``w=+1`` programs
    the positive cell, ``w=-1`` the negative cell, ``w=0`` neither
    (Fig. 3(b)).
    """
    pos = (w_t > 0).astype(jnp.uint32)
    neg = (w_t < 0).astype(jnp.uint32)
    return pos, neg


def planes_to_ternary(pos: jax.Array, neg: jax.Array) -> jax.Array:
    return pos.astype(jnp.int32) - neg.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Bit packing along the contraction axis
# ---------------------------------------------------------------------------

def pack_bits(bits: jax.Array, axis: int = -1) -> jax.Array:
    """Pack a 0/1 array into uint32 words along ``axis``.

    ``bits.shape[axis]`` must be a multiple of 32 (pad with zeros first —
    zero lanes contribute nothing to popcount MACs, exactly like inactive
    wordlines in the macro).
    """
    bits = jnp.asarray(bits)
    axis = axis % bits.ndim
    n = bits.shape[axis]
    if n % PACK != 0:
        raise ValueError(f"pack axis length {n} not a multiple of {PACK}")
    moved = jnp.moveaxis(bits, axis, -1).astype(jnp.uint32)
    grouped = moved.reshape(*moved.shape[:-1], n // PACK, PACK)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    packed = jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)
    return jnp.moveaxis(packed, -1, axis)


def unpack_bits(packed: jax.Array, axis: int = -1, n: int | None = None) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns uint32 0/1 array."""
    packed = jnp.asarray(packed)
    axis = axis % packed.ndim
    moved = jnp.moveaxis(packed, axis, -1)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = (moved[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(*moved.shape[:-1], moved.shape[-1] * PACK)
    if n is not None:
        bits = bits[..., :n]
    return jnp.moveaxis(bits, -1, axis)


def pad_to_multiple(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple (inactive wordlines)."""
    axis = axis % x.ndim
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# Folded batch-norm threshold (the "theta" the SA compares against)
# ---------------------------------------------------------------------------

def fold_bn_to_threshold(gamma, beta, mean, var, eps: float = 1e-5):
    """Fold BN + sign into an integer-valued popcount threshold.

    For a pre-activation integer s (popcount difference) the binarized output
    is ``sign(gamma * (s - mean)/sqrt(var+eps) + beta)``.  With gamma>0 this
    is ``s >= mean - beta*sqrt(var+eps)/gamma``; gamma<0 flips the compare.
    Returns (threshold, flip) so inference needs no floating point — the SA
    compares popcount currents against a programmable offset, which is how a
    real CIM macro absorbs BN.
    """
    std = jnp.sqrt(var + eps)
    thr = mean - beta * std / jnp.where(gamma == 0, 1e-9, gamma)
    flip = gamma < 0
    return thr, flip


@functools.partial(jax.jit, static_argnames=())
def apply_threshold(s: jax.Array, thr: jax.Array, flip: jax.Array) -> jax.Array:
    """Binary output of the SA given popcount difference ``s``."""
    ge = s >= thr
    return jnp.where(flip, ~ge, ge).astype(jnp.uint32)


def np_pack_bits(bits: np.ndarray, axis: int = -1) -> np.ndarray:
    """NumPy twin of :func:`pack_bits` for host-side weight preparation."""
    axis = axis % bits.ndim
    n = bits.shape[axis]
    assert n % PACK == 0, f"pack axis length {n} not a multiple of {PACK}"
    moved = np.moveaxis(bits, axis, -1).astype(np.uint32)
    grouped = moved.reshape(*moved.shape[:-1], n // PACK, PACK)
    shifts = np.arange(PACK, dtype=np.uint32)
    packed = (grouped << shifts).sum(axis=-1).astype(np.uint32)
    return np.moveaxis(packed, -1, axis)
