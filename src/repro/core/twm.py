"""Ternary Weight Mapping (TWM) semantics and the sense-amplifier model.

Paper Section II-D / Fig. 3.  Under BWM a bitline current is compared with a
reference bitline; under TWM each ternary weight occupies a *pair* of cells
and the SA compares the positive-popcount current with the negative-popcount
current directly.  Two consequences reproduced here:

  1. functional:   out = SA(pop(x & w+) - pop(x & w-) - theta)
  2. reliability:  the worst-case sensing margin doubles (Fig. 3c).  We model
     the SA as comparing (I+ - I-) with additive Gaussian noise of sigma
     cells; BWM's margin is 1 cell-current unit, TWM's is 2.

The functional path is what the TPU kernels implement; the noisy path drives
the Fig. 3(c) reproduction benchmark.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import quant


@dataclasses.dataclass(frozen=True)
class SAModel:
    """Sense-amplifier behavioural model.

    noise_sigma: std-dev of the current-difference sampling noise, in units
    of one cell current (the paper's "sensing variation").  0.0 = ideal
    digital behaviour.
    """

    noise_sigma: float = 0.0

    def decide(self, diff: jax.Array, key: jax.Array | None = None) -> jax.Array:
        """Eq. (1): Dout = 1 iff diff >= 0 (with optional sampling noise)."""
        if self.noise_sigma > 0.0:
            if key is None:
                raise ValueError("noisy SA needs a PRNG key")
            diff = diff + self.noise_sigma * jax.random.normal(
                key, diff.shape, dtype=jnp.float32
            )
        return (diff >= 0).astype(jnp.uint32)


# ---------------------------------------------------------------------------
# Functional TWM MAC (dense form; the kernels implement the packed form)
# ---------------------------------------------------------------------------

def twm_mac(x_bits: jax.Array, w_ternary: jax.Array) -> jax.Array:
    """Popcount-difference MAC: x_bits (…, K) in {0,1}, w (K, N) in {-1,0,1}.

    Returns the raw integer bitline-pair difference (…, N) — the quantity the
    SA senses.  Equivalent to ``x_bits @ w`` but written as the two popcount
    planes to mirror the hardware exactly.
    """
    pos, neg = quant.ternary_planes(w_ternary)
    xi = x_bits.astype(jnp.int32)
    return xi @ pos.astype(jnp.int32) - xi @ neg.astype(jnp.int32)


def bwm_mac(x_bits: jax.Array, w_binary: jax.Array, n_ref: jax.Array | None = None):
    """Binary-weight-mapping MAC against a reference bitline (Fig. 3a).

    w_binary in {-1,+1} maps to a single cell: +1 programs the cell, -1
    leaves it off; the SA compares against a reference current equal to half
    of the active wordlines.  diff = pop(x & w+) - ref.
    """
    wp = (w_binary > 0).astype(jnp.int32)
    xi = x_bits.astype(jnp.int32)
    pop = xi @ wp
    active = jnp.sum(xi, axis=-1, keepdims=True)
    ref = active.astype(jnp.float32) / 2.0 if n_ref is None else n_ref
    return pop.astype(jnp.float32) - ref


def sensing_margin_twm() -> float:
    """Worst-case margin (cell-current units) for TWM: a ±1 weight flip moves
    the differential current by 2 units (one cell on each bitline)."""
    return 2.0


def sensing_margin_bwm() -> float:
    """Worst-case margin for BWM: 1 unit against the reference."""
    return 1.0


def flip_rate_under_noise(
    key: jax.Array,
    x_bits: jax.Array,
    w_ternary: jax.Array,
    sigma: float,
    mapping: str = "twm",
    trials: int = 32,
) -> jax.Array:
    """Monte-Carlo SA decision flip-rate vs the ideal decision (Fig. 3c).

    For the BWM arm, zero weights are randomly rounded to ±1 (BWM cannot
    represent 0) — exactly the representational handicap the paper cites.
    """
    sa = SAModel(noise_sigma=sigma)
    if mapping == "twm":
        diff = twm_mac(x_bits, w_ternary).astype(jnp.float32)
        # margin-doubling: differential sensing sees 2 units per LSB
        diff = diff * 2.0
    elif mapping == "bwm":
        kb, key = jax.random.split(key)
        rnd = jax.random.rademacher(kb, w_ternary.shape, dtype=jnp.int32)
        w_b = jnp.where(w_ternary == 0, rnd, w_ternary)
        diff = bwm_mac(x_bits, w_b)
    else:
        raise ValueError(mapping)

    ideal = (diff >= 0)
    keys = jax.random.split(key, trials)

    def one(k):
        noisy = sa.decide(diff, k).astype(bool)
        return jnp.mean(noisy != ideal)

    return jnp.mean(jax.vmap(one)(keys))
