"""Synthetic Google-Speech-Commands-like corpus (offline stand-in).

GSCD itself is not available in this container (DESIGN.md §9.1); this
generator produces 12 classes with the same interface: 1 s @ 16 kHz,
quantized to 8-bit offset-binary — class 10 = 'unknown', 11 = 'silence'.

Each keyword class is a distinct formant pattern: 2-3 harmonic chirps with
class-specific base frequencies, amplitude envelopes and onset timing, plus
pink-ish noise.  The classes are well-separated enough for a binary CNN to
learn, but not trivially (additive noise, random shifts, speed jitter).
"""
from __future__ import annotations

import numpy as np

N_CLASSES = 12
SR = 16000

# class-specific formant recipes (f0, f1, chirp rate, envelope)
_RECIPES = [
    (220, 880, 0.0), (330, 1320, 0.2), (440, 660, -0.2), (550, 1100, 0.1),
    (660, 990, -0.1), (290, 1450, 0.3), (370, 740, -0.3), (490, 1470, 0.15),
    (610, 915, -0.15), (260, 1560, 0.25),
]


def _keyword(rng: np.random.Generator, cls: int, n: int = SR) -> np.ndarray:
    """Class signature = formants + *envelope structure* (syllable count,
    AM rate, onset/duration band).  Binary-activation features see signal
    duty-cycles/envelopes far better than carrier phase, so the temporal
    structure is what makes the synthetic task learnable by a BNN — the
    spectral recipe still separates the classes for full-precision models.
    """
    f0, f1, chirp = _RECIPES[cls]
    t = np.arange(n) / SR
    jitter = rng.uniform(0.9, 1.1)
    n_syll = 1 + cls % 3                       # 1-3 "syllables"
    syl_rate = 2.5 + 0.9 * (cls % 4)           # envelope AM rate (Hz)
    onset = 0.05 + 0.02 * (cls % 5) + rng.uniform(0, 0.04)
    dur = (0.30 + 0.05 * (cls % 4)) * rng.uniform(0.9, 1.1)
    env = np.zeros_like(t)
    for s_i in range(n_syll):
        c = onset + dur * (s_i + 0.5) / n_syll
        env += np.exp(-0.5 * ((t - c) / (dur / (2.5 * n_syll))) ** 2)
    env *= 0.75 + 0.25 * np.sin(2 * np.pi * syl_rate * t)
    phase0 = rng.uniform(0, 2 * np.pi)
    f_t0 = f0 * jitter * (1 + chirp * t)
    f_t1 = f1 * jitter * (1 - 0.5 * chirp * t)
    sig = env * (
        np.sin(2 * np.pi * np.cumsum(f_t0) / SR + phase0)
        + 0.6 * np.sin(2 * np.pi * np.cumsum(f_t1) / SR)
        + 0.3 * np.sin(2 * np.pi * np.cumsum(2.1 * f_t0) / SR)
    )
    noise = rng.standard_normal(n) * 0.05
    return sig + noise


def _unknown(rng: np.random.Generator, n: int = SR) -> np.ndarray:
    """Babble: random mixture of two keyword recipes at low coherence."""
    a, b = rng.integers(0, 10, 2)
    return 0.5 * _keyword(rng, a, n) + 0.5 * _keyword(rng, b, n)[::-1]


def _silence(rng: np.random.Generator, n: int = SR) -> np.ndarray:
    return rng.standard_normal(n) * rng.uniform(0.01, 0.06)


def sample(rng: np.random.Generator, cls: int, n: int = SR) -> np.ndarray:
    if cls < 10:
        sig = _keyword(rng, cls, n)
    elif cls == 10:
        sig = _unknown(rng, n)
    else:
        sig = _silence(rng, n)
    # normalize + 8-bit offset-binary quantization (paper: 8-bit fixed point)
    if cls == 11:
        sig = np.clip(sig, -1, 1)  # silence stays quiet (no AGC boost)
    else:
        peak = np.max(np.abs(sig)) + 1e-6
        sig = sig / peak * rng.uniform(0.5, 0.95)
    q = np.clip(np.round(sig * 127) + 128, 0, 255)
    return q.astype(np.uint8)


def batch(seed: int, step: int, batch_size: int, n: int = SR
          ) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic (x (B, n) uint8, y (B,) int32) for a global step."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    ys = rng.integers(0, N_CLASSES, batch_size)
    xs = np.stack([sample(rng, int(c), n) for c in ys])
    return xs, ys.astype(np.int32)


def dataset(seed: int, n_batches: int, batch_size: int, n: int = SR):
    for step in range(n_batches):
        yield batch(seed, step, batch_size, n)
