"""Deterministic synthetic LM token pipeline (host-sharded).

Every batch is a pure function of (seed, step, host_id), so restart after a
failure resumes the exact data order with no loss or duplication — the
fault-tolerance contract the train loop relies on (DESIGN.md §6).

The token stream is a order-2 Markov chain over the vocab so the loss has
learnable structure (tests assert loss decreases), not uniform noise.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    microbatches: int = 1
    frontend_tokens: int = 0
    frontend_dim: int = 0


def _markov_batch(rng: np.random.Generator, b: int, s: int, vocab: int):
    """Tokens with short-range structure: x[t+1] = f(x[t]) + noise."""
    base = rng.integers(0, vocab, (b, 1))
    steps = rng.integers(1, 7, (b, s - 1))
    noise = (rng.random((b, s - 1)) < 0.1) * rng.integers(0, vocab, (b, s - 1))
    toks = np.concatenate([base, steps], axis=1).astype(np.int64)
    toks = np.cumsum(toks, axis=1) % vocab
    toks[:, 1:] = np.where(noise > 0, noise, toks[:, 1:])
    return toks.astype(np.int32)


def batch_at(cfg: DataConfig, step: int) -> dict:
    """The batch for a given global step (deterministic, host-sharded)."""
    assert cfg.global_batch % cfg.n_hosts == 0
    per_host = cfg.global_batch // cfg.n_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id])
    )
    toks = _markov_batch(rng, per_host, cfg.seq_len + 1, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.frontend_tokens:
        batch["frontend"] = rng.standard_normal(
            (per_host, cfg.frontend_tokens, cfg.frontend_dim), dtype=np.float32
        ).astype("bfloat16")
    if cfg.microbatches > 1:
        assert per_host % cfg.microbatches == 0
        mb = per_host // cfg.microbatches
        batch = {
            k: v.reshape(cfg.microbatches, mb, *v.shape[1:])
            for k, v in batch.items()
        }
    return batch


def iterator(cfg: DataConfig, start_step: int = 0):
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1
