"""Pallas TPU kernels for the PSCNN popcount arithmetic.

Kernel files follow the repo convention: <name>.py holds the pallas_call +
BlockSpec, ops.py the jit'd public wrappers, ref.py the pure-jnp oracles.
"""
from repro.kernels.ops import (
    twm_linear,
    twm_linear_mxu,
    bnn_conv1d,
    bnn_conv1d_batched,
    bitserial_conv1d,
    pick_path,
)

__all__ = [
    "twm_linear",
    "twm_linear_mxu",
    "bnn_conv1d",
    "bnn_conv1d_batched",
    "bitserial_conv1d",
    "pick_path",
]
