"""Pallas TPU kernel: binary 1-D convolution with fused SA + pooling (PWB).

Reproduces the PSCNN dataflow (paper §II-E, Fig. 4): the K-tap convolution is
computed as K *shifted* popcount GEMMs accumulated in VMEM — the digital twin
of "shift the IFM downward in the line buffer and activate wordline groups
alternately".  Because the accumulation covers the whole (Cin x K) receptive
field inside one grid cell, each cell emits *finished* activations in IFM
order, which is exactly what lets the paper bolt pooling onto the write-back
path (PWB, §II-H): here the max-pool (an OR on binary data) runs in-register
before the tile is written — the OFM tile that leaves the kernel is already
pooled, so the pooled layer costs zero extra HBM traffic.

Layouts (host side prepares these via ``ops.shifted_strided_views``):
  xs  : (K, L_out, Cw) uint32 — tap-shifted strided views, channel-packed
  wp  : (K, Cw, Cout) uint32  — positive plane per tap
  wn  : (K, Cw, Cout) uint32  — negative plane
  thr : (1, Cout) float32, flip : (1, Cout) int32

Grid: (L_out / bl, Cout / bn).  Output: (L_out / pool, Cout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BL = 512
DEFAULT_BN = 128


def _conv_tile(xs, wp, wn, k: int, cw: int):
    """Accumulate K shifted popcount GEMM taps -> (bl, bn) int32.

    Single-stream view of the batched tile (one accumulation loop to
    maintain, two kernel entry points)."""
    return _batched_conv_tile(xs[None], wp, wn, k, cw)[0]


def _kernel(
    xs_ref, wp_ref, wn_ref, thr_ref, flip_ref, o_ref, *, k: int, cw: int, pool: int
):
    diff = _conv_tile(xs_ref[...], wp_ref[...], wn_ref[...], k, cw)
    ge = diff.astype(jnp.float32) >= thr_ref[0, :][None, :]
    flip = flip_ref[0, :][None, :] != 0
    y = jnp.where(flip, ~ge, ge).astype(jnp.uint32)
    if pool > 1:
        bl, bn = y.shape
        # PWB: OR-reduce the window before write-back (binary max-pool).
        y = jnp.max(y.reshape(bl // pool, pool, bn), axis=1)
    o_ref[...] = y


def _kernel_raw(xs_ref, wp_ref, wn_ref, o_ref, *, k: int, cw: int):
    o_ref[...] = _conv_tile(xs_ref[...], wp_ref[...], wn_ref[...], k, cw)


@functools.partial(
    jax.jit, static_argnames=("pool", "bl", "bn", "mode", "interpret")
)
def bnn_conv1d_packed(
    xs: jax.Array,
    wp: jax.Array,
    wn: jax.Array,
    thr: jax.Array | None = None,
    flip: jax.Array | None = None,
    *,
    pool: int = 1,
    bl: int = DEFAULT_BL,
    bn: int = DEFAULT_BN,
    mode: str = "sa",
    interpret: bool = True,
) -> jax.Array:
    """Fused conv1d -> SA -> pool on pre-shifted packed views.

    L_out must divide into bl blocks and bl into pool windows (pad L_out with
    dead positions first; they pool to whatever the pad computes and are
    sliced off by the caller).
    """
    k, l_out, cw = xs.shape
    k2, cw2, n = wp.shape
    assert k == k2 and cw == cw2 and wn.shape == wp.shape
    bl = min(bl, l_out)
    bn = min(bn, n)
    assert l_out % bl == 0 and n % bn == 0, (l_out, bl, n, bn)
    assert bl % pool == 0, (bl, pool)
    grid = (l_out // bl, n // bn)

    xs_spec = pl.BlockSpec((k, bl, cw), lambda i, j: (0, i, 0))
    w_spec = pl.BlockSpec((k, cw, bn), lambda i, j: (0, 0, j))
    v_spec = pl.BlockSpec((1, bn), lambda i, j: (0, j))

    if mode == "sa":
        assert thr is not None and flip is not None
        o_spec = pl.BlockSpec((bl // pool, bn), lambda i, j: (i, j))
        return pl.pallas_call(
            functools.partial(_kernel, k=k, cw=cw, pool=pool),
            grid=grid,
            in_specs=[xs_spec, w_spec, w_spec, v_spec, v_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((l_out // pool, n), jnp.uint32),
            interpret=interpret,
        )(xs, wp, wn, thr.reshape(1, n), flip.astype(jnp.int32).reshape(1, n))
    elif mode == "raw":
        assert pool == 1, "raw mode has no SA output to pool"
        o_spec = pl.BlockSpec((bl, bn), lambda i, j: (i, j))
        return pl.pallas_call(
            functools.partial(_kernel_raw, k=k, cw=cw),
            grid=grid,
            in_specs=[xs_spec, w_spec, w_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((l_out, n), jnp.int32),
            interpret=interpret,
        )(xs, wp, wn)
    raise ValueError(f"mode {mode!r}")


# ---------------------------------------------------------------------------
# Batched multi-stream step (repro.stream): one CIM macro, many users.
#
# The streaming scheduler packs B concurrent audio streams onto a shared
# batch axis; the ternary weight planes are broadcast across it — exactly the
# "weights stay resident in the macro, activations stream past" economics of
# the silicon, so the batch dimension rides free through the Pallas grid
# (one extra grid axis, zero extra weight traffic).
# ---------------------------------------------------------------------------

DEFAULT_BB = 8


def _batched_conv_tile(xs, wp, wn, k: int, cw: int):
    """Accumulate K shifted popcount GEMM taps -> (bb, bl, bn) int32."""
    bb, _, bl, _ = xs.shape
    bn = wp.shape[2]
    acc = jnp.zeros((bb, bl, bn), jnp.int32)
    for tap in range(k):
        for c in range(cw):
            xa = xs[:, tap, :, c][:, :, None]  # (bb, bl, 1)
            p = jax.lax.population_count(
                jnp.bitwise_and(xa, wp[tap, c][None, None, :])
            )
            n = jax.lax.population_count(
                jnp.bitwise_and(xa, wn[tap, c][None, None, :])
            )
            acc = acc + p.astype(jnp.int32) - n.astype(jnp.int32)
    return acc


def _batched_kernel(
    xs_ref, wp_ref, wn_ref, thr_ref, flip_ref, o_ref, *, k: int, cw: int, pool: int
):
    diff = _batched_conv_tile(xs_ref[...], wp_ref[...], wn_ref[...], k, cw)
    ge = diff.astype(jnp.float32) >= thr_ref[0, :][None, None, :]
    flip = flip_ref[0, :][None, None, :] != 0
    y = jnp.where(flip, ~ge, ge).astype(jnp.uint32)
    if pool > 1:
        bb, bl, bn = y.shape
        y = jnp.max(y.reshape(bb, bl // pool, pool, bn), axis=2)
    o_ref[...] = y


def _batched_kernel_raw(xs_ref, wp_ref, wn_ref, o_ref, *, k: int, cw: int):
    o_ref[...] = _batched_conv_tile(xs_ref[...], wp_ref[...], wn_ref[...], k, cw)


@functools.partial(
    jax.jit, static_argnames=("pool", "bb", "bl", "bn", "mode", "interpret")
)
def bnn_conv1d_step_packed(
    xs: jax.Array,
    wp: jax.Array,
    wn: jax.Array,
    thr: jax.Array | None = None,
    flip: jax.Array | None = None,
    *,
    pool: int = 1,
    bb: int = DEFAULT_BB,
    bl: int = DEFAULT_BL,
    bn: int = DEFAULT_BN,
    mode: str = "sa",
    interpret: bool = True,
) -> jax.Array:
    """Batched fused conv1d step on pre-shifted packed views.

    xs : (B, K, L_out, Cw) uint32 — per-stream tap-shifted packed views
    wp/wn : (K, Cw, Cout) uint32  — shared across the batch axis
    Output: (B, L_out / pool, Cout) uint32 bits (or (B, L_out, Cout) int32).
    """
    b, k, l_out, cw = xs.shape
    k2, cw2, n = wp.shape
    assert k == k2 and cw == cw2 and wn.shape == wp.shape
    bb = min(bb, b)
    bl = min(bl, l_out)
    bn = min(bn, n)
    assert b % bb == 0 and l_out % bl == 0 and n % bn == 0, (b, bb, l_out, bl, n, bn)
    assert bl % pool == 0, (bl, pool)
    grid = (b // bb, l_out // bl, n // bn)

    xs_spec = pl.BlockSpec((bb, k, bl, cw), lambda s, i, j: (s, 0, i, 0))
    w_spec = pl.BlockSpec((k, cw, bn), lambda s, i, j: (0, 0, j))
    v_spec = pl.BlockSpec((1, bn), lambda s, i, j: (0, j))

    if mode == "sa":
        assert thr is not None and flip is not None
        o_spec = pl.BlockSpec((bb, bl // pool, bn), lambda s, i, j: (s, i, j))
        return pl.pallas_call(
            functools.partial(_batched_kernel, k=k, cw=cw, pool=pool),
            grid=grid,
            in_specs=[xs_spec, w_spec, w_spec, v_spec, v_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((b, l_out // pool, n), jnp.uint32),
            interpret=interpret,
        )(xs, wp, wn, thr.reshape(1, n), flip.astype(jnp.int32).reshape(1, n))
    elif mode == "raw":
        assert pool == 1, "raw mode has no SA output to pool"
        o_spec = pl.BlockSpec((bb, bl, bn), lambda s, i, j: (s, i, j))
        return pl.pallas_call(
            functools.partial(_batched_kernel_raw, k=k, cw=cw),
            grid=grid,
            in_specs=[xs_spec, w_spec, w_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((b, l_out, n), jnp.int32),
            interpret=interpret,
        )(xs, wp, wn)
    raise ValueError(f"mode {mode!r}")
