"""Pallas TPU kernel: binary 1-D convolution with fused SA + pooling (PWB).

Reproduces the PSCNN dataflow (paper §II-E, Fig. 4): the K-tap convolution is
computed as K *shifted* popcount GEMMs accumulated in VMEM — the digital twin
of "shift the IFM downward in the line buffer and activate wordline groups
alternately".  Because the accumulation covers the whole (Cin x K) receptive
field inside one grid cell, each cell emits *finished* activations in IFM
order, which is exactly what lets the paper bolt pooling onto the write-back
path (PWB, §II-H): here the max-pool (an OR on binary data) runs in-register
before the tile is written — the OFM tile that leaves the kernel is already
pooled, so the pooled layer costs zero extra HBM traffic.

Layouts (host side prepares these via ``ops.shifted_strided_views``):
  xs  : (K, L_out, Cw) uint32 — tap-shifted strided views, channel-packed
  wp  : (K, Cw, Cout) uint32  — positive plane per tap
  wn  : (K, Cw, Cout) uint32  — negative plane
  thr : (1, Cout) float32, flip : (1, Cout) int32

Grid: (L_out / bl, Cout / bn).  Output: (L_out / pool, Cout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import dispatch

DEFAULT_BL = 512
DEFAULT_BN = 128


def _conv_tile(xs, wp, wn, k: int, cw: int):
    """Accumulate K shifted popcount GEMM taps -> (bl, bn) int32.

    Single-stream view of the batched tile (one accumulation loop to
    maintain, two kernel entry points)."""
    return _batched_conv_tile(xs[None], wp, wn, k, cw)[0]


def _kernel(
    xs_ref, wp_ref, wn_ref, thr_ref, flip_ref, o_ref, *, k: int, cw: int, pool: int
):
    diff = _conv_tile(xs_ref[...], wp_ref[...], wn_ref[...], k, cw)
    ge = diff.astype(jnp.float32) >= thr_ref[0, :][None, :]
    flip = flip_ref[0, :][None, :] != 0
    y = jnp.where(flip, ~ge, ge).astype(jnp.uint32)
    if pool > 1:
        bl, bn = y.shape
        # PWB: OR-reduce the window before write-back (binary max-pool).
        y = jnp.max(y.reshape(bl // pool, pool, bn), axis=1)
    o_ref[...] = y


def _kernel_raw(xs_ref, wp_ref, wn_ref, o_ref, *, k: int, cw: int):
    o_ref[...] = _conv_tile(xs_ref[...], wp_ref[...], wn_ref[...], k, cw)


@functools.partial(
    jax.jit, static_argnames=("pool", "bl", "bn", "mode", "interpret")
)
def bnn_conv1d_packed(
    xs: jax.Array,
    wp: jax.Array,
    wn: jax.Array,
    thr: jax.Array | None = None,
    flip: jax.Array | None = None,
    *,
    pool: int = 1,
    bl: int = DEFAULT_BL,
    bn: int = DEFAULT_BN,
    mode: str = "sa",
    interpret: bool = True,
) -> jax.Array:
    """Fused conv1d -> SA -> pool on pre-shifted packed views.

    L_out must divide into bl blocks and bl into pool windows (pad L_out with
    dead positions first; they pool to whatever the pad computes and are
    sliced off by the caller).
    """
    k, l_out, cw = xs.shape
    k2, cw2, n = wp.shape
    assert k == k2 and cw == cw2 and wn.shape == wp.shape
    bl = min(bl, l_out)
    bn = min(bn, n)
    assert l_out % bl == 0 and n % bn == 0, (l_out, bl, n, bn)
    assert bl % pool == 0, (bl, pool)
    grid = (l_out // bl, n // bn)

    xs_spec = pl.BlockSpec((k, bl, cw), lambda i, j: (0, i, 0))
    w_spec = pl.BlockSpec((k, cw, bn), lambda i, j: (0, 0, j))
    v_spec = pl.BlockSpec((1, bn), lambda i, j: (0, j))

    if mode == "sa":
        assert thr is not None and flip is not None
        o_spec = pl.BlockSpec((bl // pool, bn), lambda i, j: (i, j))
        return dispatch.pallas_call(
            functools.partial(_kernel, k=k, cw=cw, pool=pool),
            grid=grid,
            in_specs=[xs_spec, w_spec, w_spec, v_spec, v_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((l_out // pool, n), jnp.uint32),
            interpret=interpret,
        )(xs, wp, wn, thr.reshape(1, n), flip.astype(jnp.int32).reshape(1, n))
    elif mode == "raw":
        assert pool == 1, "raw mode has no SA output to pool"
        o_spec = pl.BlockSpec((bl, bn), lambda i, j: (i, j))
        return dispatch.pallas_call(
            functools.partial(_kernel_raw, k=k, cw=cw),
            grid=grid,
            in_specs=[xs_spec, w_spec, w_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((l_out, n), jnp.int32),
            interpret=interpret,
        )(xs, wp, wn)
    raise ValueError(f"mode {mode!r}")


# ---------------------------------------------------------------------------
# Batched multi-stream step (repro.stream): one CIM macro, many users.
#
# The streaming scheduler packs B concurrent audio streams onto a shared
# batch axis; the ternary weight planes are broadcast across it — exactly the
# "weights stay resident in the macro, activations stream past" economics of
# the silicon, so the batch dimension rides free through the Pallas grid
# (one extra grid axis, zero extra weight traffic).
#
# Shard-safety contract: pallas_call is opaque to GSPMD, so these kernels
# must never see a mesh-sharded operand directly.  Under the mesh-wide slot
# pool each device invokes the kernel on its LOCAL block of batch rows via
# the shard_map entry points (ops.bnn_conv1d_batched_sharded /
# ops.classifier_tail_sharded); per-shard batches can be as small as one
# row, which the ops-layer entry points absorb (batch-block clamp for the
# conv step, pad-to-block for the classifier tail).
# ---------------------------------------------------------------------------

DEFAULT_BB = 8


def _batched_conv_tile(xs, wp, wn, k: int, cw: int):
    """Accumulate K shifted popcount GEMM taps -> (bb, bl, bn) int32."""
    bb, _, bl, _ = xs.shape
    bn = wp.shape[2]
    acc = jnp.zeros((bb, bl, bn), jnp.int32)
    for tap in range(k):
        for c in range(cw):
            xa = xs[:, tap, :, c][:, :, None]  # (bb, bl, 1)
            p = jax.lax.population_count(
                jnp.bitwise_and(xa, wp[tap, c][None, None, :])
            )
            n = jax.lax.population_count(
                jnp.bitwise_and(xa, wn[tap, c][None, None, :])
            )
            acc = acc + p.astype(jnp.int32) - n.astype(jnp.int32)
    return acc


def _batched_kernel(
    xs_ref, wp_ref, wn_ref, thr_ref, flip_ref, o_ref, *, k: int, cw: int, pool: int
):
    diff = _batched_conv_tile(xs_ref[...], wp_ref[...], wn_ref[...], k, cw)
    ge = diff.astype(jnp.float32) >= thr_ref[0, :][None, None, :]
    flip = flip_ref[0, :][None, None, :] != 0
    y = jnp.where(flip, ~ge, ge).astype(jnp.uint32)
    if pool > 1:
        bb, bl, bn = y.shape
        y = jnp.max(y.reshape(bb, bl // pool, pool, bn), axis=2)
    o_ref[...] = y


def _batched_kernel_raw(*refs, k: int, cw: int, pooled: bool = False):
    """refs = xs, wp, wn, [model (pooled),] out.  ``pooled``: wp/wn carry a
    leading tenant axis (M, K, Cw, Cout); the block's planes are gathered
    once per grid cell (slot blocks are single-tenant by placement)."""
    xs_ref, wp_ref, wn_ref, o_ref = refs[0], refs[1], refs[2], refs[-1]
    wp, wn = wp_ref[...], wn_ref[...]
    if pooled:
        midx = refs[3][0, 0]
        wp = jax.lax.dynamic_index_in_dim(wp, midx, 0, keepdims=False)
        wn = jax.lax.dynamic_index_in_dim(wn, midx, 0, keepdims=False)
    o_ref[...] = _batched_conv_tile(xs_ref[...], wp, wn, k, cw)


@functools.partial(
    jax.jit, static_argnames=("pool", "bb", "bl", "bn", "mode", "interpret")
)
def bnn_conv1d_step_packed(
    xs: jax.Array,
    wp: jax.Array,
    wn: jax.Array,
    thr: jax.Array | None = None,
    flip: jax.Array | None = None,
    model_idx: jax.Array | None = None,
    *,
    pool: int = 1,
    bb: int = DEFAULT_BB,
    bl: int = DEFAULT_BL,
    bn: int = DEFAULT_BN,
    mode: str = "sa",
    interpret: bool = True,
) -> jax.Array:
    """Batched fused conv1d step on pre-shifted packed views.

    xs : (B, K, L_out, Cw) uint32 — per-stream tap-shifted packed views
    wp/wn : (K, Cw, Cout) uint32  — shared across the batch axis; with
        ``model_idx`` (``(B // bb, 1)`` int32, one tenant per slot block)
        a pooled (M, K, Cw, Cout) stack, gathered per grid cell (raw mode
        only — the SA affine runs outside the raw path)
    Output: (B, L_out / pool, Cout) uint32 bits (or (B, L_out, Cout) int32).
    """
    pooled = model_idx is not None
    b, k, l_out, cw = xs.shape
    if pooled:
        assert mode == "raw", "weight pooling is a raw-conv path feature"
        m, k2, cw2, n = wp.shape
    else:
        k2, cw2, n = wp.shape
    assert k == k2 and cw == cw2 and wn.shape == wp.shape
    bb = min(bb, b)
    bl = min(bl, l_out)
    bn = min(bn, n)
    assert b % bb == 0 and l_out % bl == 0 and n % bn == 0, (b, bb, l_out, bl, n, bn)
    assert bl % pool == 0, (bl, pool)
    grid = (b // bb, l_out // bl, n // bn)

    xs_spec = pl.BlockSpec((bb, k, bl, cw), lambda s, i, j: (s, 0, i, 0))
    if pooled:
        w_spec = pl.BlockSpec((m, k, cw, bn), lambda s, i, j: (0, 0, 0, j))
    else:
        w_spec = pl.BlockSpec((k, cw, bn), lambda s, i, j: (0, 0, j))
    v_spec = pl.BlockSpec((1, bn), lambda s, i, j: (0, j))
    mi_spec = pl.BlockSpec((1, 1), lambda s, i, j: (s, 0))

    if mode == "sa":
        assert thr is not None and flip is not None
        o_spec = pl.BlockSpec((bb, bl // pool, bn), lambda s, i, j: (s, i, j))
        return dispatch.pallas_call(
            functools.partial(_batched_kernel, k=k, cw=cw, pool=pool),
            grid=grid,
            in_specs=[xs_spec, w_spec, w_spec, v_spec, v_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((b, l_out // pool, n), jnp.uint32),
            interpret=interpret,
        )(xs, wp, wn, thr.reshape(1, n), flip.astype(jnp.int32).reshape(1, n))
    elif mode == "raw":
        assert pool == 1, "raw mode has no SA output to pool"
        o_spec = pl.BlockSpec((bb, bl, bn), lambda s, i, j: (s, i, j))
        in_specs = [xs_spec, w_spec, w_spec]
        args = [xs, wp, wn]
        if pooled:
            in_specs.append(mi_spec)
            args.append(model_idx.astype(jnp.int32))
        return dispatch.pallas_call(
            functools.partial(_batched_kernel_raw, k=k, cw=cw, pooled=pooled),
            grid=grid,
            in_specs=in_specs,
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((b, l_out, n), jnp.int32),
            interpret=interpret,
        )(*args)
    raise ValueError(f"mode {mode!r}")


# ---------------------------------------------------------------------------
# Fused classifier tail (repro.stream in-jit finalization).
#
# The GAP counters plus the fc cascade are the model's "answer now" path:
# saturate the 8-bit PWB counts, run every fc layer, emit raw logits.  On
# silicon this is one drain of the PWB counters through the macro; here it
# is one kernel so the streaming scheduler's per-hop finalization never
# leaves the device — each grid cell loads the (tiny) fc weight stack once
# and finishes ``bb`` streams end to end.
# ---------------------------------------------------------------------------


def _tail_kernel(*refs, n_fc: int, out_raw: tuple[bool, ...],
                 pooled: bool = False):
    """refs = [gap, [model (pooled),] (w, [thr, flip])* , out].  One cell:
    bb streams.  ``pooled``: fc params carry a leading tenant axis,
    gathered once per cell."""
    gap_ref, o_ref = refs[0], refs[-1]
    params = refs[1:-1]
    if pooled:
        midx = params[0][0, 0]
        params = [
            jax.lax.dynamic_index_in_dim(r[...], midx, 0, keepdims=False)
            for r in params[1:]
        ]
    else:
        params = [r[...] for r in params]
    # 8-bit PWB counter ceiling (executor: gap counts saturate at 255)
    h = jnp.minimum(gap_ref[...], 255)
    idx = 0
    for j in range(n_fc):
        w = params[idx]
        idx += 1
        raw = jax.lax.dot_general(
            h, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        if out_raw[j]:
            h = raw
        else:
            thr = params[idx]
            flip = params[idx + 1]
            idx += 2
            ge = raw.astype(jnp.float32) >= thr[0, :][None, :]
            h = jnp.where(flip[0, :][None, :] != 0, ~ge, ge).astype(jnp.int32)
    o_ref[...] = h


@functools.partial(jax.jit, static_argnames=("out_raw", "bb", "interpret"))
def classifier_tail_packed(
    gap: jax.Array,
    fc_ws: tuple[jax.Array, ...],
    fc_thrs: tuple[jax.Array, ...],
    fc_flips: tuple[jax.Array, ...],
    model_idx: jax.Array | None = None,
    *,
    out_raw: tuple[bool, ...],
    bb: int = DEFAULT_BB,
    interpret: bool = True,
) -> jax.Array:
    """Saturate GAP counts and run the whole fc cascade in one kernel.

    gap : (B, C) int32 GAP counts (possibly already clamped; idempotent)
    fc_ws : per-fc (Cin, Cout) int32 ternary weights — or pooled
        (M, Cin, Cout) stacks when ``model_idx`` (``(B // bb, 1)`` int32,
        one tenant per slot block) is given
    fc_thrs/fc_flips : per-fc (1, Cout) (pooled: (M, 1, Cout)) float32 /
        int32 SA params (entries for ``out_raw`` layers present but unused)
    Output: (B, n_classes) int32 raw logits.
    """
    pooled = model_idx is not None
    b, c = gap.shape
    n_fc = len(fc_ws)
    assert n_fc and b % bb == 0, (b, bb, n_fc)
    assert fc_ws[0].shape[-2] == c

    grid = (b // bb,)
    in_specs = [pl.BlockSpec((bb, c), lambda s: (s, 0))]
    args = [gap]
    if pooled:
        in_specs.append(pl.BlockSpec((1, 1), lambda s: (s, 0)))
        args.append(model_idx.astype(jnp.int32))

    def _rep_spec(x):
        nd = x.ndim
        return pl.BlockSpec(x.shape, lambda s, _n=nd: (0,) * _n)

    for j, w in enumerate(fc_ws):
        in_specs.append(_rep_spec(w))
        args.append(w)
        if not out_raw[j]:
            in_specs.append(_rep_spec(fc_thrs[j]))
            in_specs.append(_rep_spec(fc_flips[j]))
            args.extend([fc_thrs[j], fc_flips[j]])
    n_out = fc_ws[-1].shape[-1]
    return dispatch.pallas_call(
        functools.partial(
            _tail_kernel, n_fc=n_fc, out_raw=out_raw, pooled=pooled
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, n_out), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_out), jnp.int32),
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# Bit-serial batched conv (multi-bit first layer) — ONE kernel launch.
#
# The first layer consumes 8-bit offset-binary audio.  The original path
# dispatched one raw-conv kernel per bit plane and accumulated the `<< b`
# partials in HBM between launches; here the plane loop moves INSIDE the
# kernel (paper §II-F: the macro serializes input bits over cycles, not
# over kernel launches), so the weights load into VMEM once and the
# accumulator never leaves the grid cell.  The offset fold (subtracting
# ``offset * sum(w)``) stays host-side in ops.bitserial_conv1d*, as before.
# ---------------------------------------------------------------------------


def _batched_bitserial_tile(xs, wp, wn, k: int, cw: int, bits: int):
    """Accumulate bits x K x Cw popcount partials -> (bb, bl, bn) int32.

    xs: (bb, bits, K, bl, Cw) uint32 — per-plane tap-shifted packed views.
    """
    bb, _, _, bl, _ = xs.shape
    bn = wp.shape[2]
    acc = jnp.zeros((bb, bl, bn), jnp.int32)
    for b in range(bits):
        scale = jnp.int32(1 << b)
        for tap in range(k):
            for c in range(cw):
                xa = xs[:, b, tap, :, c][:, :, None]  # (bb, bl, 1)
                p = jax.lax.population_count(
                    jnp.bitwise_and(xa, wp[tap, c][None, None, :])
                )
                n = jax.lax.population_count(
                    jnp.bitwise_and(xa, wn[tap, c][None, None, :])
                )
                acc = acc + (p.astype(jnp.int32) - n.astype(jnp.int32)) * scale
    return acc


def _batched_kernel_bitserial(
    *refs, k: int, cw: int, bits: int, pooled: bool = False
):
    """refs = xs, wp, wn, [model (pooled),] out."""
    xs_ref, wp_ref, wn_ref, o_ref = refs[0], refs[1], refs[2], refs[-1]
    wp, wn = wp_ref[...], wn_ref[...]
    if pooled:
        midx = refs[3][0, 0]
        wp = jax.lax.dynamic_index_in_dim(wp, midx, 0, keepdims=False)
        wn = jax.lax.dynamic_index_in_dim(wn, midx, 0, keepdims=False)
    o_ref[...] = _batched_bitserial_tile(xs_ref[...], wp, wn, k, cw, bits)


@functools.partial(
    jax.jit, static_argnames=("bits", "bb", "bl", "bn", "interpret")
)
def bnn_bitserial_step_packed(
    xs: jax.Array,
    wp: jax.Array,
    wn: jax.Array,
    model_idx: jax.Array | None = None,
    *,
    bits: int,
    bb: int = DEFAULT_BB,
    bl: int = DEFAULT_BL,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
) -> jax.Array:
    """Batched bit-serial raw conv on pre-shifted per-plane packed views.

    xs : (B, bits, K, L_out, Cw) uint32; wp/wn : (K, Cw, Cout) uint32
    shared across batch AND planes (the whole point: one weight fetch for
    all ``bits`` passes) — or pooled (M, K, Cw, Cout) stacks when
    ``model_idx`` (``(B // bb, 1)`` int32, one tenant per slot block) is
    given.  Output: (B, L_out, Cout) int32 raw popcount diff already
    accumulated over planes (offset NOT yet folded).
    """
    pooled = model_idx is not None
    b, nbits, k, l_out, cw = xs.shape
    assert nbits == bits, (nbits, bits)
    if pooled:
        m, k2, cw2, n = wp.shape
    else:
        k2, cw2, n = wp.shape
    assert k == k2 and cw == cw2 and wn.shape == wp.shape
    bb = min(bb, b)
    bl = min(bl, l_out)
    bn = min(bn, n)
    assert b % bb == 0 and l_out % bl == 0 and n % bn == 0, (
        b, bb, l_out, bl, n, bn)
    grid = (b // bb, l_out // bl, n // bn)

    xs_spec = pl.BlockSpec(
        (bb, bits, k, bl, cw), lambda s, i, j: (s, 0, 0, i, 0)
    )
    if pooled:
        w_spec = pl.BlockSpec((m, k, cw, bn), lambda s, i, j: (0, 0, 0, j))
    else:
        w_spec = pl.BlockSpec((k, cw, bn), lambda s, i, j: (0, 0, j))
    o_spec = pl.BlockSpec((bb, bl, bn), lambda s, i, j: (s, i, j))
    in_specs = [xs_spec, w_spec, w_spec]
    args = [xs, wp, wn]
    if pooled:
        in_specs.append(pl.BlockSpec((1, 1), lambda s, i, j: (s, 0)))
        args.append(model_idx.astype(jnp.int32))
    return dispatch.pallas_call(
        functools.partial(
            _batched_kernel_bitserial, k=k, cw=cw, bits=bits, pooled=pooled
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((b, l_out, n), jnp.int32),
        interpret=interpret,
    )(*args)
