"""Trace-time device-dispatch accounting for the Pallas kernels.

Every kernel module routes its ``pl.pallas_call`` through :func:`pallas_call`
below, which bumps a module-global counter *at trace time*.  Because jit
executes the wrapper's python exactly once per trace — and a traced program
executes every ``pallas_call`` it captured once per run — the number of
bumps observed while tracing a function IS its per-execution dispatch
count.  That gives the observability plane an exact ``device_dispatches``
figure without any runtime hook into XLA:

* ``_BatchedModel.dispatches_per_hop`` computes the count statically from
  the plan + backend; ``tests/test_megakernel.py`` asserts it equals the
  traced count from this counter, so the static figure reported per hop in
  ``StreamMetrics`` / trace spans / BENCH_stream.json can never drift from
  the kernels actually launched.

The counter is deliberately dumb (no thread-locals): tests that read it
trace under :func:`counting` which snapshots around a single trace.
"""
from __future__ import annotations

import contextlib

from jax.experimental import pallas as pl

_dispatches = 0


def bump(n: int = 1) -> None:
    global _dispatches
    _dispatches += n


def count() -> int:
    """Total pallas_call sites traced since import (monotone)."""
    return _dispatches


def pallas_call(*args, **kwargs):
    """Drop-in ``pl.pallas_call`` that records the launch at trace time."""
    bump()
    return pl.pallas_call(*args, **kwargs)


@contextlib.contextmanager
def counting():
    """Yield a zero-arg callable returning the dispatches traced since
    entry — wrap exactly one ``jax.eval_shape``/first-call trace with it to
    read a function's per-execution dispatch count.  Call
    ``jax.clear_caches()`` first when the function may already be traced:
    a jit cache hit skips the wrapper's python and records nothing."""
    start = count()
    yield lambda: count() - start
