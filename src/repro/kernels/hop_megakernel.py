"""Pallas megakernel: one launch per streaming hop, ping-pong scratch.

The per-stage streaming path (scheduler ``backend="pallas"``) issues one
``pallas_call`` per conv stage per hop — plus the whole cascade again for
the ghost flush on emit hops, plus the classifier tail — bouncing every
intermediate feature map through HBM between launches.  This module fuses
the entire hop into ONE kernel:

  * bit-serial first layer: the ``2^b`` input planes are extracted and
    accumulated *inside* the kernel (the accumulation commutes with the
    integer MAC, so one shared-tap GEMM replaces ``in_bits`` passes — see
    ``_conv_raw_val``), instead of ``in_bits`` separate dispatches;
  * SA binarization, max-pool with the steady pool phase, receptive-field
    tail carry and pending-frame carry for every stage;
  * GAP accumulation saturated at the 8-bit PWB ceiling;
  * the masked-slot merge (rows whose stream had no full hop keep their
    previous state bit-for-bit);
  * on ``emit`` hops, the ghost end-of-stream flush AND the fc classifier
    tail run in the same launch on the merged state, so an emit hop is
    still a single dispatch.

Intermediate feature maps ping-pong between two VMEM scratch buffers
(``scratch_shapes``): stage *i* reads its input from one buffer and parks
its pooled output in the other, so nothing but the hop's audio input and
the updated slot state (tails / pendings / GAP, plus logits on emit) ever
touches HBM.  This is the paper's flexible ping-pong feature SRAM (§II-C)
made literal: layer-to-layer activations never leave the macro.

Grid: ``(B / bb,)`` over slot blocks — weights/thresholds are replicated
per grid cell (one weight fetch serves every stream, the shared-weight CIM
batching economics), per-slot state is block-mapped.

Shard-safety: ``pallas_call`` is GSPMD-opaque, so this kernel must never
see a mesh-sharded operand — the mesh-wide slot pool enters through the
shard_map wrappers ``ops.hop_megakernel_sharded`` /
``ops.finalize_megakernel_sharded``.

Interpret-mode note: on this CPU container the kernel runs with
``interpret=True`` (scratch residency is simulated), which preserves the
dispatch-count and bit-exactness contracts; on TPU the same call site
compiles to one Mosaic kernel where the scratch buffers are real VMEM.
The conv taps use ``dot_general`` with ``preferred_element_type=int32``
(MXU-friendly) rather than the packed popcount primitive — identical
integer semantics, no packing round-trip between fused stages.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import dispatch

try:  # TPU memory-space annotation; interpret mode accepts plain structs
    from jax.experimental.pallas import tpu as pltpu

    def _vmem(shape, dtype):
        return pltpu.VMEM(shape, dtype)
except ImportError:  # pragma: no cover - depends on jax build
    def _vmem(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

# slot-block size: big blocks amortize the weight fetch and keep the grid
# short (the whole local batch in one cell for every bench config); the
# scratch footprint per cell is 2 * bb * SL * SC int32, tiny next to the
# feature maps the per-stage path round-trips
DEFAULT_BB = 256


@dataclasses.dataclass(frozen=True)
class StageGeom:
    """One conv stage's static geometry — the subset of the stream plan's
    ``ConvStage`` the kernel needs, duplicated here so the kernel layer
    never imports the stream runtime (hashable => usable as a jit static
    argument)."""

    k: int
    stride: int
    pad: int
    pool: int
    cin: int
    cout: int
    in_bits: int
    in_offset: int
    tail: int
    phase: int
    n_conv: int
    n_out: int
    flush_in: int
    flush_conv: int
    flush_out: int


def stage_geom(st) -> StageGeom:
    """Build a :class:`StageGeom` from anything with ConvStage's fields."""
    return StageGeom(
        k=st.k, stride=st.stride, pad=st.pad, pool=st.pool, cin=st.cin,
        cout=st.cout, in_bits=st.in_bits, in_offset=st.in_offset,
        tail=st.tail, phase=st.phase, n_conv=st.n_conv, n_out=st.n_out,
        flush_in=st.flush_in, flush_conv=st.flush_conv,
        flush_out=st.flush_out,
    )


def scratch_dims(geoms: tuple[StageGeom, ...], emit: bool) -> tuple[int, int]:
    """(length, channels) of each ping-pong buffer: the max inter-stage
    feature-map footprint across the steady cascade (and the flush
    cascade when it is fused in)."""
    sl = sc = 1
    for g in geoms:
        sl = max(sl, g.n_out)
        sc = max(sc, g.cout)
        if emit:
            sl = max(sl, g.flush_out)
    return sl, sc


class _PingPong:
    """The two scratch buffers; ``park`` writes a stage's output into the
    current buffer and flips sides, so consecutive stages alternate —
    stage *i* reads buffer A while writing buffer B, exactly the paper's
    double-buffered feature SRAM.  Zero-width maps pass through."""

    def __init__(self, a_ref, b_ref):
        self._bufs = (a_ref, b_ref)
        self._side = 0

    def park(self, val):
        n, c = val.shape[1], val.shape[2]
        if n == 0 or c == 0:
            return val
        buf = self._bufs[self._side]
        self._side ^= 1
        buf[:, :n, :c] = val
        return buf[:, :n, :c]


# ---------------------------------------------------------------------------
# Kernel-body math (pure value helpers, shared by hop and finalize modes)
# ---------------------------------------------------------------------------

def _conv_raw_val(g: StageGeom, w, window, n_pos: int):
    """(bb, L, Cin) int32 window -> (bb, n_pos, Cout) raw popcount diff.

    Bit-serial first layer (``in_bits > 1``): the ``2^b`` planes are
    extracted and accumulated in VMEM, then one shared-tap GEMM runs on
    the accumulated code — ``sum_b (plane_b << b)`` telescopes back to the
    integer code, so the plane accumulation commutes with the MAC and is
    bit-exact with the per-plane popcount path at 1/in_bits the GEMM
    passes (and, vs the old per-stage path, 1/in_bits the dispatches).
    """
    if g.in_bits > 1:
        x = jnp.zeros_like(window)
        for b in range(g.in_bits):
            x = x + (((window >> b) & 1) << b)
        x = x - g.in_offset
    else:
        x = window
    span = (n_pos - 1) * g.stride + 1
    acc = None
    for t in range(g.k):
        tap = x[:, t : t + span : g.stride, :]
        d = jax.lax.dot_general(
            tap, w[t], (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = d if acc is None else acc + d
    return acc


def _sa_val(raw, thr, flip):
    """SA binarization, executor-exact (integer thresholds keep the
    float32 compare knife-edge free)."""
    ge = raw.astype(jnp.float32) >= thr[0][None, None, :]
    return jnp.where(flip[0][None, None, :] != 0, ~ge, ge).astype(jnp.int32)


def _steady_cascade(geoms, cur, tails, pends, ws, thrs, flips, pp):
    """The per-hop conv cascade on one slot block; returns the final
    stage's pooled frames plus the carried tails/pendings."""
    new_tails, new_pends = [], []
    for i, g in enumerate(geoms):
        window = (
            jnp.concatenate([tails[i], cur], axis=1) if g.tail else cur
        )
        raw = _conv_raw_val(g, ws[i], window, g.n_conv)
        new_tails.append(window[:, g.n_conv * g.stride :, :])
        y = _sa_val(raw, thrs[i], flips[i])
        if g.pool > 1:
            frames = (
                jnp.concatenate([pends[i], y], axis=1) if g.phase else y
            )
            used = g.n_out * g.pool
            pooled = jnp.max(
                frames[:, :used].reshape(
                    frames.shape[0], g.n_out, g.pool, g.cout
                ),
                axis=2,
            )
            new_pends.append(frames[:, used:, :])
            cur = pp.park(pooled)
        else:
            new_pends.append(pends[i])
            cur = pp.park(y)
    return cur, new_tails, new_pends


def _flush_cascade(geoms, tails, pends, gap, ws, thrs, flips, pp):
    """Ghost end-of-stream flush from (merged) steady state -> saturated
    GAP counts, mirror of ``_BatchedModel._finalize``."""
    bb = gap.shape[0]
    cur = None
    for i, g in enumerate(geoms):
        pieces = []
        if g.tail:
            pieces.append(tails[i])
        if cur is not None and g.flush_in:
            pieces.append(cur)
        if g.pad:
            pad_val = g.in_offset if g.in_bits > 1 else 0
            pieces.append(jnp.full((bb, g.pad, g.cin), pad_val, jnp.int32))
        if g.flush_conv > 0:
            window = (
                pieces[0] if len(pieces) == 1
                else jnp.concatenate(pieces, axis=1)
            )
            y = _sa_val(
                _conv_raw_val(g, ws[i], window, g.flush_conv),
                thrs[i], flips[i],
            )
        else:
            y = jnp.zeros((bb, 0, g.cout), jnp.int32)
        frames = jnp.concatenate([pends[i], y], axis=1) if g.phase else y
        used = g.flush_out * g.pool  # drop-remainder (ref_maxpool1d)
        cur = pp.park(
            jnp.max(
                frames[:, :used].reshape(bb, g.flush_out, g.pool, g.cout),
                axis=2,
            )
        )
    return jnp.minimum(gap + cur.sum(axis=1, dtype=jnp.int32), 255)


def _classifier_val(gap_f, fc_params, fc_raw):
    """Saturated GAP counts (bb, C) -> raw logits (fused fc cascade)."""
    h = jnp.minimum(gap_f, 255)  # idempotent with the flush clamp
    idx = 0
    for j, raw_out in enumerate(fc_raw):
        w = fc_params[idx]
        idx += 1
        raw = jax.lax.dot_general(
            h, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        if raw_out:
            h = raw
        else:
            thr, flip = fc_params[idx], fc_params[idx + 1]
            idx += 2
            ge = raw.astype(jnp.float32) >= thr[0][None, :]
            h = jnp.where(flip[0][None, :] != 0, ~ge, ge).astype(jnp.int32)
    return h


# ---------------------------------------------------------------------------
# The kernel: one grid cell == one slot block through the whole hop
# ---------------------------------------------------------------------------

def _n_fc_params(fc_raw: tuple[bool, ...]) -> int:
    return sum(1 if r else 3 for r in fc_raw)


def _megakernel(
    *refs, geoms: tuple[StageGeom, ...], emit: bool, finalize_only: bool,
    fc_raw: tuple[bool, ...], pooled: bool = False,
):
    """refs = [audio, mask,] tails(tail>0)*, pends(phase>0)*, gap,
    [model (pooled),] (w, thr, flip) per stage, fc params (emit/finalize)
    | outputs | ping, pong.  Outputs: tails*, pends*, gap [, logits]
    (finalize: logits only).

    ``pooled``: every weight/threshold operand carries a leading tenant
    axis ``(K, ...)`` and a per-block ``(1, 1)`` int32 model index follows
    ``gap`` — the block's weight planes are gathered out of the pool ONCE
    per grid cell (each slot block is single-tenant by placement), so the
    pool costs one dynamic row index, not K-way compute.
    """
    ns = len(geoms)
    n_tail = sum(1 for g in geoms if g.tail)
    n_pend = sum(1 for g in geoms if g.phase)
    with_fc = emit or finalize_only
    pos = 0
    if not finalize_only:
        audio_ref, mask_ref = refs[0], refs[1]
        pos = 2
    tail_refs = refs[pos : pos + n_tail]
    pos += n_tail
    pend_refs = refs[pos : pos + n_pend]
    pos += n_pend
    gap_ref = refs[pos]
    pos += 1
    if pooled:
        model_ref = refs[pos]
        pos += 1
    stage_refs = refs[pos : pos + 3 * ns]
    pos += 3 * ns
    n_fcp = _n_fc_params(fc_raw) if with_fc else 0
    fc_refs = refs[pos : pos + n_fcp]
    pos += n_fcp
    out_refs = refs[pos:-2]
    ping_ref, pong_ref = refs[-2], refs[-1]

    bb = gap_ref.shape[0]
    gap = gap_ref[...]
    ti = pi = 0
    tails, pends = [], []
    for g in geoms:
        if g.tail:
            tails.append(tail_refs[ti][...])
            ti += 1
        else:
            tails.append(jnp.zeros((bb, 0, g.cin), jnp.int32))
        if g.phase:
            pends.append(pend_refs[pi][...])
            pi += 1
        else:
            pends.append(jnp.zeros((bb, 0, g.cout), jnp.int32))
    ws = [stage_refs[3 * i][...] for i in range(ns)]
    thrs = [stage_refs[3 * i + 1][...] for i in range(ns)]
    flips = [stage_refs[3 * i + 2][...] for i in range(ns)]
    fc_params = [r[...] for r in fc_refs]
    if pooled:
        midx = model_ref[0, 0]

        def sel(x):
            return jax.lax.dynamic_index_in_dim(x, midx, 0, keepdims=False)

        ws = [sel(w) for w in ws]
        thrs = [sel(t) for t in thrs]
        flips = [sel(f) for f in flips]
        fc_params = [sel(p) for p in fc_params]
    pp = _PingPong(ping_ref, pong_ref)

    if finalize_only:
        gap_f = _flush_cascade(geoms, tails, pends, gap, ws, thrs, flips, pp)
        out_refs[0][...] = _classifier_val(gap_f, fc_params, fc_raw)
        return

    cur, new_tails, new_pends = _steady_cascade(
        geoms, audio_ref[...], tails, pends, ws, thrs, flips, pp
    )
    gap2 = jnp.minimum(gap + cur.sum(axis=1, dtype=jnp.int32), 255)

    # masked-slot merge in-kernel: rows whose stream had no full hop keep
    # their previous state bit-for-bit; the flush below runs on the MERGED
    # state so every primed slot's logits stay valid (scheduler contract)
    m = mask_ref[...] != 0  # (bb, 1)
    m3 = m[:, :, None]
    merged_tails = [
        jnp.where(m3, nt, t) if g.tail else t
        for g, nt, t in zip(geoms, new_tails, tails)
    ]
    merged_pends = [
        jnp.where(m3, np_, p) if g.phase else p
        for g, np_, p in zip(geoms, new_pends, pends)
    ]
    merged_gap = jnp.where(m, gap2, gap)

    oi = 0
    for g, t in zip(geoms, merged_tails):
        if g.tail:
            out_refs[oi][...] = t
            oi += 1
    for g, p in zip(geoms, merged_pends):
        if g.phase:
            out_refs[oi][...] = p
            oi += 1
    out_refs[oi][...] = merged_gap
    oi += 1
    if emit:
        gap_f = _flush_cascade(
            geoms, merged_tails, merged_pends, merged_gap,
            ws, thrs, flips, pp,
        )
        out_refs[oi][...] = _classifier_val(gap_f, fc_params, fc_raw)


# ---------------------------------------------------------------------------
# Packed entry points (ops.py wraps these with padding + shard_map)
# ---------------------------------------------------------------------------

def _block_arg(specs, args, x, bb, replicated):
    nd = x.ndim
    if replicated:
        specs.append(pl.BlockSpec(x.shape, lambda s, _n=nd: (0,) * _n))
    else:
        specs.append(
            pl.BlockSpec(
                (bb,) + x.shape[1:], lambda s, _n=nd: (s,) + (0,) * (_n - 1)
            )
        )
    args.append(x)


def _stage_params(specs, args, ws, thrs, flips, bb):
    for w, t, f in zip(ws, thrs, flips):
        _block_arg(specs, args, w, bb, True)
        _block_arg(specs, args, t, bb, True)
        _block_arg(specs, args, f, bb, True)


def _fc_args(specs, args, fc_ws, fc_thrs, fc_flips, fc_raw, bb):
    for j, raw_out in enumerate(fc_raw):
        _block_arg(specs, args, fc_ws[j], bb, True)
        if not raw_out:
            _block_arg(specs, args, fc_thrs[j], bb, True)
            _block_arg(specs, args, fc_flips[j], bb, True)


def _n_logits(fc_ws, fc_raw, geoms):
    # shape[-1] so a pooled (K, cin, cout) stack reads the same as (cin, cout)
    return fc_ws[-1].shape[-1] if fc_raw else geoms[-1].cout


def _model_arg(specs, args, model_idx, bb):
    """Per-block model index: (nblocks, 1) int32, one row per grid cell."""
    specs.append(pl.BlockSpec((1, 1), lambda s: (s, 0)))
    args.append(model_idx.astype(jnp.int32))


@functools.partial(
    jax.jit,
    static_argnames=("geoms", "emit", "fc_raw", "bb", "interpret"),
)
def hop_megakernel_packed(
    audio: jax.Array,
    mask: jax.Array,
    tails: tuple[jax.Array, ...],
    pendings: tuple[jax.Array, ...],
    gap: jax.Array,
    ws: tuple[jax.Array, ...],
    thrs: tuple[jax.Array, ...],
    flips: tuple[jax.Array, ...],
    fc_ws: tuple[jax.Array, ...],
    fc_thrs: tuple[jax.Array, ...],
    fc_flips: tuple[jax.Array, ...],
    model_idx: jax.Array | None = None,
    *,
    geoms: tuple[StageGeom, ...],
    emit: bool,
    fc_raw: tuple[bool, ...],
    bb: int = DEFAULT_BB,
    interpret: bool = True,
):
    """One fused hop over a slot-block grid.  ``tails``/``pendings`` carry
    one entry per stage with ``tail > 0`` / ``phase > 0`` (zero-width state
    never enters the kernel).  B must divide into ``bb`` blocks (the ops
    wrapper pads).  ``model_idx`` (``(b // bb, 1)`` int32, one tenant per
    slot block) switches every weight operand to a pooled ``(K, ...)``
    stack — same grid, same single launch.  Returns
    ``(tails, pendings, gap[, logits])``.
    """
    b = gap.shape[0]
    bb = min(bb, b)
    assert b % bb == 0, (b, bb)
    grid = (b // bb,)
    pooled = model_idx is not None
    specs: list = []
    args: list = []
    _block_arg(specs, args, audio.astype(jnp.int32), bb, False)
    _block_arg(specs, args, mask.astype(jnp.int32).reshape(b, 1), bb, False)
    for t in tails:
        _block_arg(specs, args, t, bb, False)
    for p in pendings:
        _block_arg(specs, args, p, bb, False)
    _block_arg(specs, args, gap, bb, False)
    if pooled:
        _model_arg(specs, args, model_idx, bb)
    _stage_params(specs, args, ws, thrs, flips, bb)
    if emit:
        _fc_args(specs, args, fc_ws, fc_thrs, fc_flips, fc_raw, bb)

    out_specs: list = []
    out_shapes: list = []

    def out3(shape):
        nd = len(shape)
        out_specs.append(
            pl.BlockSpec(
                (bb,) + shape[1:], lambda s, _n=nd: (s,) + (0,) * (_n - 1)
            )
        )
        out_shapes.append(jax.ShapeDtypeStruct(shape, jnp.int32))

    for t in tails:
        out3(t.shape)
    for p in pendings:
        out3(p.shape)
    out3(gap.shape)
    if emit:
        out3((b, _n_logits(fc_ws, fc_raw, geoms)))

    sl, sc = scratch_dims(geoms, emit)
    out = dispatch.pallas_call(
        functools.partial(
            _megakernel, geoms=geoms, emit=emit, finalize_only=False,
            fc_raw=fc_raw if emit else (), pooled=pooled,
        ),
        grid=grid,
        in_specs=specs,
        out_specs=out_specs,
        out_shape=tuple(out_shapes),
        scratch_shapes=[
            _vmem((bb, sl, sc), jnp.int32),
            _vmem((bb, sl, sc), jnp.int32),
        ],
        interpret=interpret,
    )(*args)
    nt, npend = len(tails), len(pendings)
    tails_out = out[:nt]
    pends_out = out[nt : nt + npend]
    gap_out = out[nt + npend]
    if emit:
        return tails_out, pends_out, gap_out, out[nt + npend + 1]
    return tails_out, pends_out, gap_out


@functools.partial(
    jax.jit, static_argnames=("geoms", "fc_raw", "bb", "interpret")
)
def finalize_megakernel_packed(
    tails: tuple[jax.Array, ...],
    pendings: tuple[jax.Array, ...],
    gap: jax.Array,
    ws: tuple[jax.Array, ...],
    thrs: tuple[jax.Array, ...],
    flips: tuple[jax.Array, ...],
    fc_ws: tuple[jax.Array, ...],
    fc_thrs: tuple[jax.Array, ...],
    fc_flips: tuple[jax.Array, ...],
    model_idx: jax.Array | None = None,
    *,
    geoms: tuple[StageGeom, ...],
    fc_raw: tuple[bool, ...],
    bb: int = DEFAULT_BB,
    interpret: bool = True,
) -> jax.Array:
    """Ghost flush + classifier tail alone (hop-boundary peeks): one
    launch from resident state to logits."""
    b = gap.shape[0]
    bb = min(bb, b)
    assert b % bb == 0, (b, bb)
    grid = (b // bb,)
    pooled = model_idx is not None
    specs: list = []
    args: list = []
    for t in tails:
        _block_arg(specs, args, t, bb, False)
    for p in pendings:
        _block_arg(specs, args, p, bb, False)
    _block_arg(specs, args, gap, bb, False)
    if pooled:
        _model_arg(specs, args, model_idx, bb)
    _stage_params(specs, args, ws, thrs, flips, bb)
    _fc_args(specs, args, fc_ws, fc_thrs, fc_flips, fc_raw, bb)
    n_out = _n_logits(fc_ws, fc_raw, geoms)
    sl, sc = scratch_dims(geoms, True)
    return dispatch.pallas_call(
        functools.partial(
            _megakernel, geoms=geoms, emit=True, finalize_only=True,
            fc_raw=fc_raw, pooled=pooled,
        ),
        grid=grid,
        in_specs=specs,
        out_specs=[pl.BlockSpec((bb, n_out), lambda s: (s, 0))],
        out_shape=(jax.ShapeDtypeStruct((b, n_out), jnp.int32),),
        scratch_shapes=[
            _vmem((bb, sl, sc), jnp.int32),
            _vmem((bb, sl, sc), jnp.int32),
        ],
        interpret=interpret,
    )(*args)[0]
