"""Public jit'd wrappers around the Pallas kernels.

These handle host-visible concerns the kernels do not: bit-packing, padding
to block multiples (padding = inactive wordlines / unused bitline pairs, so
it is numerically inert), tap-shift view construction, and the
popcount-vs-MXU dispatch heuristic (DESIGN.md §2.4).

On this CPU container every kernel runs with ``interpret=True``; on TPU the
same call sites compile to real Mosaic kernels (``interpret=False`` via
``default_interpret``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.kernels import bnn_conv1d as _conv
from repro.kernels import hop_megakernel as _mega
from repro.kernels import twm_matmul as _mm


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x, mult, axis):
    return quant.pad_to_multiple(x, mult, axis)


# ---------------------------------------------------------------------------
# Packing / view helpers (host side of the kernel contract)
# ---------------------------------------------------------------------------

def pack_activations(x_bits: jax.Array) -> jax.Array:
    """(..., C) {0,1} -> (..., ceil(C/32)) uint32."""
    x = _pad_axis(x_bits.astype(jnp.uint32), quant.PACK, -1)
    return quant.pack_bits(x, axis=-1)


def pack_weight_planes(w_t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Ternary (Cin, Cout) or (K, Cin, Cout) -> packed planes along Cin."""
    pos, neg = quant.ternary_planes(w_t)
    axis = -2
    pos = _pad_axis(pos, quant.PACK, axis)
    neg = _pad_axis(neg, quant.PACK, axis)
    return quant.pack_bits(pos, axis=axis), quant.pack_bits(neg, axis=axis)


def shifted_strided_views(
    x_packed: jax.Array, k: int, stride: int, pad: int
) -> jax.Array:
    """(L, Cw) packed -> (K, L_out, Cw) tap views (line-buffer mirror)."""
    l = x_packed.shape[0]
    xp = jnp.pad(x_packed, ((pad, pad), (0, 0)))
    l_out = (l + 2 * pad - k) // stride + 1
    taps = [xp[t : t + (l_out - 1) * stride + 1 : stride] for t in range(k)]
    return jnp.stack(taps, axis=0)


# ---------------------------------------------------------------------------
# Dense layer entry points
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def twm_linear(
    x_bits: jax.Array,
    w_t: jax.Array,
    thr: jax.Array | None = None,
    flip: jax.Array | None = None,
    *,
    mode: str = "sa",
    interpret: bool | None = None,
) -> jax.Array:
    """Binary-activation ternary-weight dense layer via the popcount kernel.

    x_bits (M, K) {0,1}; w_t (K, N) {-1,0,1}.  Returns (M, N): uint32 bits in
    ``sa`` mode, int32 popcount diff in ``raw`` mode.
    """
    interpret = default_interpret() if interpret is None else interpret
    m, kdim = x_bits.shape
    n = w_t.shape[1]
    xq = pack_activations(x_bits)
    wp, wn = pack_weight_planes(w_t)

    bm = _pick_block(m, _mm.DEFAULT_BM)
    bn = _pick_block(n, _mm.DEFAULT_BN)
    xq = _pad_axis(xq, bm, 0)
    wp = _pad_axis(wp, bn, 1)
    wn = _pad_axis(wn, bn, 1)
    if mode == "sa":
        thr_p = _pad_axis(thr.astype(jnp.float32), bn, 0)
        flip_p = _pad_axis(flip.astype(jnp.int32), bn, 0)
        out = _mm.twm_matmul(
            xq, wp, wn, thr_p, flip_p, bm=bm, bn=bn, mode="sa", interpret=interpret
        )
    else:
        out = _mm.twm_matmul(xq, wp, wn, bm=bm, bn=bn, mode="raw", interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def twm_linear_mxu(
    x_bits: jax.Array,
    w_t: jax.Array,
    thr: jax.Array,
    flip: jax.Array,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """MXU int8 path with identical semantics (beyond-paper, compute-bound)."""
    interpret = default_interpret() if interpret is None else interpret
    m, kdim = x_bits.shape
    n = w_t.shape[1]
    bm = _pick_block(m, 256)
    bn = _pick_block(n, 256)
    x8 = _pad_axis(x_bits.astype(jnp.int8), bm, 0)
    w8 = _pad_axis(w_t.astype(jnp.int8), bn, 1)
    thr_p = _pad_axis(thr.astype(jnp.float32), bn, 0)
    flip_p = _pad_axis(flip.astype(jnp.int32), bn, 0)
    out = _mm.twm_matmul_mxu(x8, w8, thr_p, flip_p, bm=bm, bn=bn, interpret=interpret)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Conv layer entry point (PWB-fused)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("stride", "pad", "pool", "mode", "interpret")
)
def bnn_conv1d(
    x_bits: jax.Array,
    w_t: jax.Array,
    thr: jax.Array | None = None,
    flip: jax.Array | None = None,
    *,
    stride: int = 1,
    pad: int = 0,
    pool: int = 1,
    mode: str = "sa",
    interpret: bool | None = None,
) -> jax.Array:
    """Fused binary conv1d -> SA -> max-pool (the paper's conv+PWB pipeline).

    x_bits (L, Cin) {0,1}; w_t (K, Cin, Cout).  Output (L_out//pool, Cout)
    uint32 bits (or (L_out, Cout) int32 when mode='raw').
    """
    interpret = default_interpret() if interpret is None else interpret
    k, cin, cout = w_t.shape
    l = x_bits.shape[0]
    l_out = (l + 2 * pad - k) // stride + 1

    xq = pack_activations(x_bits)  # (L, Cw)
    xs = shifted_strided_views(xq, k, stride, pad)  # (K, L_out, Cw)
    wp, wn = pack_weight_planes(w_t)  # (K, Cw, Cout)

    bn = _pick_block(cout, _conv.DEFAULT_BN)
    # block length: multiple of pool, divides padded L_out
    bl = _pick_block(l_out, _conv.DEFAULT_BL, step=pool)
    xs = _pad_axis(xs, bl, 1)
    wp = _pad_axis(wp, bn, 2)
    wn = _pad_axis(wn, bn, 2)

    if mode == "sa":
        thr_p = _pad_axis(thr.astype(jnp.float32), bn, 0)
        flip_p = _pad_axis(flip.astype(jnp.int32), bn, 0)
        out = _conv.bnn_conv1d_packed(
            xs, wp, wn, thr_p, flip_p,
            pool=pool, bl=bl, bn=bn, mode="sa", interpret=interpret,
        )
        return out[: l_out // pool, :cout]
    out = _conv.bnn_conv1d_packed(
        xs, wp, wn, pool=1, bl=bl, bn=bn, mode="raw", interpret=interpret
    )
    return out[:l_out, :cout]


def bitserial_conv1d(
    x_u: jax.Array,
    w_t: jax.Array,
    bits: int,
    offset: int = 0,
    *,
    stride: int = 1,
    pad: int = 0,
    interpret: bool | None = None,
) -> jax.Array:
    """Multi-bit-input conv in ONE kernel launch (first-layer path).

    The ``<< b`` plane accumulation runs inside the kernel
    (``bnn_bitserial_step_packed``) instead of as ``bits`` separate
    dispatches with HBM-resident partials.  Spatial padding uses the
    offset code (see kernels/ref.py)."""
    return bitserial_conv1d_batched(
        x_u[None], w_t, bits=bits, offset=offset, stride=stride, pad=pad,
        interpret=interpret,
    )[0]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "offset", "stride", "pad", "bb", "interpret"),
)
def bitserial_conv1d_batched(
    x_u: jax.Array,
    w_t: jax.Array,
    model_idx: jax.Array | None = None,
    *,
    bits: int,
    offset: int = 0,
    stride: int = 1,
    pad: int = 0,
    bb: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched multi-bit-input raw conv, all bit planes in one launch.

    x_u (B, L, Cin) integer codes in [0, 2^bits); w_t (K, Cin, Cout) — or
    a pooled (M, K, Cin, Cout) stack with ``model_idx`` ((B,) int32 tenant
    ids, constant per ``bb`` slot block).  Returns (B, L_out, Cout) int32
    raw popcount diff with the offset code already folded out
    (``acc - offset * sum(w)``, per tenant when pooled).  The per-plane
    views are packed host-side; the kernel loops planes x taps with the
    weight planes fetched into VMEM once (paper §II-F bit-serial
    scheduling).
    """
    interpret = default_interpret() if interpret is None else interpret
    pooled = model_idx is not None
    b, l, cin = x_u.shape
    if pooled:
        k, cin2, cout = w_t.shape[1:]
    else:
        k, cin2, cout = w_t.shape
    assert cin == cin2, (cin, cin2)
    x_u = x_u.astype(jnp.uint32)
    if pad:
        x_u = jnp.pad(
            x_u, ((0, 0), (pad, pad), (0, 0)), constant_values=offset
        )
    l_out = (l + 2 * pad - k) // stride + 1
    planes = jnp.stack(
        [(x_u >> bi) & 1 for bi in range(bits)], axis=1
    )  # (B, bits, L_pad, Cin)
    xq = pack_activations(planes)  # (B, bits, L_pad, Cw)
    span = (l_out - 1) * stride + 1
    taps = [xq[:, :, t : t + span : stride] for t in range(k)]
    xs = jnp.stack(taps, axis=2)  # (B, bits, K, L_out, Cw)
    wp, wn = pack_weight_planes(w_t)  # ([M,] K, Cw, Cout)

    bb = _pick_block(b, _conv.DEFAULT_BB if bb is None else bb)
    bn = _pick_block(cout, _conv.DEFAULT_BN)
    bl = _pick_block(l_out, _conv.DEFAULT_BL)
    xs = _pad_axis(xs, bb, 0)
    xs = _pad_axis(xs, bl, 3)
    wp = _pad_axis(wp, bn, -1)
    wn = _pad_axis(wn, bn, -1)
    mi = _block_model_idx(model_idx, b, bb, _round_up(b, bb) - b) \
        if pooled else None
    out = _conv.bnn_bitserial_step_packed(
        xs, wp, wn, mi, bits=bits, bb=bb, bl=bl, bn=bn, interpret=interpret
    )
    acc = out[:b, :l_out, :cout]
    if offset:
        if pooled:
            wsum = jnp.sum(w_t.astype(jnp.int32), axis=(1, 2))  # (M, Cout)
            acc = acc - offset * wsum[
                jnp.asarray(model_idx, jnp.int32)
            ][:, None, :]
        else:
            wsum = jnp.sum(w_t.astype(jnp.int32), axis=(0, 1))
            acc = acc - offset * wsum[None, None, :]
    return acc


# ---------------------------------------------------------------------------
# Batched multi-stream conv entry point (repro.stream scheduler)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("stride", "pad", "pool", "mode", "bb", "interpret"),
)
def bnn_conv1d_batched(
    x_bits: jax.Array,
    w_t: jax.Array,
    thr: jax.Array | None = None,
    flip: jax.Array | None = None,
    model_idx: jax.Array | None = None,
    *,
    stride: int = 1,
    pad: int = 0,
    pool: int = 1,
    mode: str = "sa",
    bb: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched binary conv1d with weights shared across the batch axis.

    x_bits (B, L, Cin) {0,1}; w_t (K, Cin, Cout) broadcast over B.  Output
    (B, L_out//pool, Cout) uint32 bits ((B, L_out, Cout) int32 when raw).
    The batch axis maps straight onto the kernel grid: one weight fetch
    serves every stream, mirroring shared-weight CIM batching.  With
    ``model_idx`` ((B,) int32 tenant ids, constant per ``bb`` slot block)
    ``w_t`` is a pooled (M, K, Cin, Cout) stack (raw mode only).
    """
    interpret = default_interpret() if interpret is None else interpret
    pooled = model_idx is not None
    b = x_bits.shape[0]
    if pooled:
        k, cin, cout = w_t.shape[1:]
    else:
        k, cin, cout = w_t.shape
    l = x_bits.shape[1]
    l_out = (l + 2 * pad - k) // stride + 1

    xq = pack_activations(x_bits)  # (B, L, Cw)
    if pad:
        xq = jnp.pad(xq, ((0, 0), (pad, pad), (0, 0)))
    taps = [
        xq[:, t : t + (l_out - 1) * stride + 1 : stride] for t in range(k)
    ]
    xs = jnp.stack(taps, axis=1)  # (B, K, L_out, Cw)
    wp, wn = pack_weight_planes(w_t)  # ([M,] K, Cw, Cout)

    bb = _pick_block(b, _conv.DEFAULT_BB if bb is None else bb)
    bn = _pick_block(cout, _conv.DEFAULT_BN)
    bl = _pick_block(l_out, _conv.DEFAULT_BL, step=pool)
    xs = _pad_axis(xs, bb, 0)
    xs = _pad_axis(xs, bl, 2)
    wp = _pad_axis(wp, bn, -1)
    wn = _pad_axis(wn, bn, -1)

    if mode == "sa":
        assert not pooled, "weight pooling is a raw-conv path feature"
        thr_p = _pad_axis(thr.astype(jnp.float32), bn, 0)
        flip_p = _pad_axis(flip.astype(jnp.int32), bn, 0)
        out = _conv.bnn_conv1d_step_packed(
            xs, wp, wn, thr_p, flip_p,
            pool=pool, bb=bb, bl=bl, bn=bn, mode="sa", interpret=interpret,
        )
        return out[:b, : l_out // pool, :cout]
    mi = _block_model_idx(model_idx, b, bb, _round_up(b, bb) - b) \
        if pooled else None
    out = _conv.bnn_conv1d_step_packed(
        xs, wp, wn, None, None, mi,
        pool=1, bb=bb, bl=bl, bn=bn, mode="raw", interpret=interpret,
    )
    return out[:b, :l_out, :cout]


# ---------------------------------------------------------------------------
# Shard-safe batched entry points (mesh-wide slot pool)
# ---------------------------------------------------------------------------
#
# pallas_call is opaque to GSPMD: called on operands sharded over a mesh it
# would force an all-gather (or fail to partition).  The shard-safe entry
# points wrap the batched kernels in shard_map over the mesh's data axes,
# so each device runs the kernel on its *local* block of batch rows with
# the (replicated) weights — zero collectives, exactly the semantics of
# the slot pool where a stream's math never leaves its shard.

def _shard_map():
    try:  # moved out of experimental after 0.4.x
        from jax import shard_map  # type: ignore[attr-defined]
        return shard_map
    except ImportError:  # pragma: no cover - depends on jax version
        from jax.experimental.shard_map import shard_map
        return shard_map


def _batch_spec(mesh):
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import dp_axes
    # a PartitionSpec entry takes a tuple of axis names directly
    return P(dp_axes(mesh)), P()


def _data_size(mesh) -> int:
    from repro.launch.mesh import dp_size
    return dp_size(mesh)


def bnn_conv1d_batched_sharded(
    x_bits: jax.Array,
    w_t: jax.Array,
    thr: jax.Array | None = None,
    flip: jax.Array | None = None,
    model_idx: jax.Array | None = None,
    *,
    mesh=None,
    stride: int = 1,
    pad: int = 0,
    pool: int = 1,
    mode: str = "sa",
    bb: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """``bnn_conv1d_batched`` with the batch axis sharded over ``mesh``.

    Each shard convolves its own rows; weights/thresholds are replicated
    (pooled (M, ...) stacks replicate whole, like the single weight set).
    With no mesh (or a 1-device mesh) this IS ``bnn_conv1d_batched`` —
    the single-device path stays byte-identical.
    """
    kw = dict(stride=stride, pad=pad, pool=pool, mode=mode, bb=bb,
              interpret=interpret)
    if mesh is None or _data_size(mesh) == 1:
        return bnn_conv1d_batched(x_bits, w_t, thr, flip, model_idx, **kw)
    bspec, rep = _batch_spec(mesh)
    if mode == "sa":
        fn = lambda x, w, t, f: bnn_conv1d_batched(x, w, t, f, **kw)
        return _shard_map()(
            fn, mesh=mesh, in_specs=(bspec, rep, rep, rep),
            out_specs=bspec, check_rep=False,
        )(x_bits, w_t, thr, flip)
    if model_idx is not None:
        fn = lambda x, w, mi: bnn_conv1d_batched(x, w, None, None, mi, **kw)
        return _shard_map()(
            fn, mesh=mesh, in_specs=(bspec, rep, bspec), out_specs=bspec,
            check_rep=False,
        )(x_bits, w_t, model_idx)
    fn = lambda x, w: bnn_conv1d_batched(x, w, **kw)
    return _shard_map()(
        fn, mesh=mesh, in_specs=(bspec, rep), out_specs=bspec,
        check_rep=False,
    )(x_bits, w_t)


def bitserial_conv1d_batched_sharded(
    x_u: jax.Array,
    w_t: jax.Array,
    model_idx: jax.Array | None = None,
    *,
    mesh=None,
    bits: int,
    offset: int = 0,
    stride: int = 1,
    pad: int = 0,
    bb: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """``bitserial_conv1d_batched`` with the batch axis sharded over
    ``mesh`` (weights replicated, one launch per shard)."""
    kw = dict(bits=bits, offset=offset, stride=stride, pad=pad, bb=bb,
              interpret=interpret)
    if mesh is None or _data_size(mesh) == 1:
        return bitserial_conv1d_batched(x_u, w_t, model_idx, **kw)
    bspec, rep = _batch_spec(mesh)
    if model_idx is not None:
        fn = lambda x, w, mi: bitserial_conv1d_batched(x, w, mi, **kw)
        return _shard_map()(
            fn, mesh=mesh, in_specs=(bspec, rep, bspec), out_specs=bspec,
            check_rep=False,
        )(x_u, w_t, model_idx)
    fn = lambda x, w: bitserial_conv1d_batched(x, w, **kw)
    return _shard_map()(
        fn, mesh=mesh, in_specs=(bspec, rep), out_specs=bspec,
        check_rep=False,
    )(x_u, w_t)


# ---------------------------------------------------------------------------
# Hop megakernel entry points (repro.stream fused hop)
# ---------------------------------------------------------------------------

def _mega_prep(stages, thrs, flips, fc_thrs, fc_flips, pooled=False):
    geoms = tuple(_mega.stage_geom(st) for st in stages)

    def _sa(x, dtype):
        x = jnp.asarray(x).astype(dtype)
        if pooled:  # (K, C) tenant stack -> (K, 1, C)
            return x.reshape(x.shape[0], 1, -1)
        return x.reshape(1, -1)

    thr_p = tuple(_sa(t, jnp.float32) for t in thrs)
    flip_p = tuple(_sa(f, jnp.int32) for f in flips)
    fct_p = tuple(_sa(t, jnp.float32) for t in fc_thrs)
    fcf_p = tuple(_sa(f, jnp.int32) for f in fc_flips)
    return geoms, thr_p, flip_p, fct_p, fcf_p


def _block_model_idx(model_idx, b, bb, pad_b):
    """(B,) per-slot tenant ids -> (B // bb, 1) per-block ids.

    Slot blocks are single-tenant by placement (the scheduler sorts slot
    blocks by tenant at pack time), so the block id is its first row's id;
    tail padding rows inherit the last real block's id harmlessly (their
    outputs are masked/sliced)."""
    mi = jnp.asarray(model_idx, jnp.int32)
    if pad_b:
        mi = jnp.pad(mi, ((0, pad_b),))
    return mi.reshape(-1, bb)[:, :1]


def hop_megakernel(
    audio: jax.Array,
    mask: jax.Array,
    tails: tuple[jax.Array, ...],
    pendings: tuple[jax.Array, ...],
    gap: jax.Array,
    ws: tuple[jax.Array, ...],
    thrs: tuple[jax.Array, ...],
    flips: tuple[jax.Array, ...],
    fc_ws: tuple[jax.Array, ...] = (),
    fc_thrs: tuple[jax.Array, ...] = (),
    fc_flips: tuple[jax.Array, ...] = (),
    model_idx: jax.Array | None = None,
    *,
    stages,
    emit: bool,
    fc_raw: tuple[bool, ...] = (),
    bb: int | None = None,
    interpret: bool | None = None,
):
    """One fused launch for a whole streaming hop (single device / shard).

    audio (B, hop, Cin0) codes; mask (B,) advance flags; tails/pendings
    one per conv stage (zero-width entries pass through untouched); gap
    (B, C) counts.  ``stages`` is the plan's ConvStage tuple.  With
    ``model_idx`` ((B,) int32 per-slot tenant ids, constant within each
    ``bb`` slot block) the weight operands are pooled (K, ...) stacks and
    the launch stays ONE dispatch regardless of K.  Returns
    ``(tails, pendings, gap)`` plus int32 logits when ``emit`` (the ghost
    flush + classifier ride in the SAME launch).  Bit-exact with the
    per-stage path — kernels/hop_megakernel.py is the contract.
    """
    interpret = default_interpret() if interpret is None else interpret
    pooled = model_idx is not None
    geoms, thr_p, flip_p, fct_p, fcf_p = _mega_prep(
        stages, thrs, flips, fc_thrs, fc_flips, pooled
    )
    b = gap.shape[0]
    bb = _mega.DEFAULT_BB if bb is None else bb
    bb = min(bb, b)
    pad_b = _round_up(b, bb) - b
    nz_t = [i for i, g in enumerate(geoms) if g.tail]
    nz_p = [i for i, g in enumerate(geoms) if g.phase]
    t_in = [jnp.asarray(tails[i], jnp.int32) for i in nz_t]
    p_in = [jnp.asarray(pendings[i], jnp.int32) for i in nz_p]
    audio = jnp.asarray(audio, jnp.int32)
    gap = jnp.asarray(gap, jnp.int32)
    if pad_b:
        padb = lambda x: jnp.pad(  # noqa: E731
            x, ((0, pad_b),) + ((0, 0),) * (x.ndim - 1)
        )
        audio, gap = padb(audio), padb(gap)
        mask = jnp.pad(mask.astype(jnp.int32), ((0, pad_b),))
        t_in = [padb(t) for t in t_in]
        p_in = [padb(p) for p in p_in]
    mi = _block_model_idx(model_idx, b, bb, pad_b) if pooled else None
    out = _mega.hop_megakernel_packed(
        audio, mask, tuple(t_in), tuple(p_in), gap,
        tuple(jnp.asarray(w, jnp.int32) for w in ws), thr_p, flip_p,
        tuple(jnp.asarray(w, jnp.int32) for w in fc_ws), fct_p, fcf_p, mi,
        geoms=geoms, emit=emit, fc_raw=tuple(fc_raw), bb=bb,
        interpret=interpret,
    )
    unpad = (lambda x: x[:b]) if pad_b else (lambda x: x)
    tails_out = list(tails)
    for j, i in enumerate(nz_t):
        tails_out[i] = unpad(out[0][j])
    pends_out = list(pendings)
    for j, i in enumerate(nz_p):
        pends_out[i] = unpad(out[1][j])
    gap_out = unpad(out[2])
    if emit:
        return tuple(tails_out), tuple(pends_out), gap_out, unpad(out[3])
    return tuple(tails_out), tuple(pends_out), gap_out


def hop_megakernel_sharded(
    audio: jax.Array,
    mask: jax.Array,
    tails: tuple[jax.Array, ...],
    pendings: tuple[jax.Array, ...],
    gap: jax.Array,
    ws: tuple[jax.Array, ...],
    thrs: tuple[jax.Array, ...],
    flips: tuple[jax.Array, ...],
    fc_ws: tuple[jax.Array, ...] = (),
    fc_thrs: tuple[jax.Array, ...] = (),
    fc_flips: tuple[jax.Array, ...] = (),
    model_idx: jax.Array | None = None,
    *,
    mesh=None,
    stages,
    emit: bool,
    fc_raw: tuple[bool, ...] = (),
    bb: int | None = None,
    interpret: bool | None = None,
):
    """``hop_megakernel`` with per-slot state sharded over ``mesh``: each
    shard runs ONE fused launch on its local slot rows with replicated
    weights (the whole (K, ...) pool replicates exactly like the single
    weight set) — the per-hop dispatch count is 1 per shard, emit
    included, regardless of K."""
    kw = dict(stages=stages, emit=emit, fc_raw=fc_raw, bb=bb,
              interpret=interpret)
    if mesh is None or _data_size(mesh) == 1:
        return hop_megakernel(audio, mask, tails, pendings, gap, ws, thrs,
                              flips, fc_ws, fc_thrs, fc_flips, model_idx,
                              **kw)
    bspec, rep = _batch_spec(mesh)
    nt, npd, ns, nf = len(tails), len(pendings), len(ws), len(fc_ws)
    out_specs = ((bspec,) * nt, (bspec,) * npd, bspec)
    if emit:
        out_specs = out_specs + (bspec,)
    if model_idx is not None:
        fn = lambda a, m, t, p, g, w, th, fl, fw, ft, ff, mi: hop_megakernel(
            a, m, t, p, g, w, th, fl, fw, ft, ff, mi, **kw
        )
        return _shard_map()(
            fn, mesh=mesh,
            in_specs=(bspec, bspec, (bspec,) * nt, (bspec,) * npd, bspec,
                      (rep,) * ns, (rep,) * ns, (rep,) * ns,
                      (rep,) * nf, (rep,) * nf, (rep,) * nf, bspec),
            out_specs=out_specs, check_rep=False,
        )(audio, mask, tuple(tails), tuple(pendings), gap, tuple(ws),
          tuple(thrs), tuple(flips), tuple(fc_ws), tuple(fc_thrs),
          tuple(fc_flips), model_idx)
    fn = lambda a, m, t, p, g, w, th, fl, fw, ft, ff: hop_megakernel(
        a, m, t, p, g, w, th, fl, fw, ft, ff, **kw
    )
    return _shard_map()(
        fn, mesh=mesh,
        in_specs=(bspec, bspec, (bspec,) * nt, (bspec,) * npd, bspec,
                  (rep,) * ns, (rep,) * ns, (rep,) * ns,
                  (rep,) * nf, (rep,) * nf, (rep,) * nf),
        out_specs=out_specs, check_rep=False,
    )(audio, mask, tuple(tails), tuple(pendings), gap, tuple(ws),
      tuple(thrs), tuple(flips), tuple(fc_ws), tuple(fc_thrs),
      tuple(fc_flips))


def finalize_megakernel(
    tails: tuple[jax.Array, ...],
    pendings: tuple[jax.Array, ...],
    gap: jax.Array,
    ws: tuple[jax.Array, ...],
    thrs: tuple[jax.Array, ...],
    flips: tuple[jax.Array, ...],
    fc_ws: tuple[jax.Array, ...],
    fc_thrs: tuple[jax.Array, ...],
    fc_flips: tuple[jax.Array, ...],
    model_idx: jax.Array | None = None,
    *,
    stages,
    fc_raw: tuple[bool, ...],
    bb: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Standalone ghost-flush + classifier launch (hop-boundary peeks)."""
    interpret = default_interpret() if interpret is None else interpret
    pooled = model_idx is not None
    geoms, thr_p, flip_p, fct_p, fcf_p = _mega_prep(
        stages, thrs, flips, fc_thrs, fc_flips, pooled
    )
    b = gap.shape[0]
    bb = _mega.DEFAULT_BB if bb is None else bb
    bb = min(bb, b)
    pad_b = _round_up(b, bb) - b
    t_in = [jnp.asarray(tails[i], jnp.int32)
            for i, g in enumerate(geoms) if g.tail]
    p_in = [jnp.asarray(pendings[i], jnp.int32)
            for i, g in enumerate(geoms) if g.phase]
    gap = jnp.asarray(gap, jnp.int32)
    if pad_b:
        padb = lambda x: jnp.pad(  # noqa: E731
            x, ((0, pad_b),) + ((0, 0),) * (x.ndim - 1)
        )
        gap = padb(gap)
        t_in = [padb(t) for t in t_in]
        p_in = [padb(p) for p in p_in]
    mi = _block_model_idx(model_idx, b, bb, pad_b) if pooled else None
    out = _mega.finalize_megakernel_packed(
        tuple(t_in), tuple(p_in), gap,
        tuple(jnp.asarray(w, jnp.int32) for w in ws), thr_p, flip_p,
        tuple(jnp.asarray(w, jnp.int32) for w in fc_ws), fct_p, fcf_p, mi,
        geoms=geoms, fc_raw=tuple(fc_raw), bb=bb, interpret=interpret,
    )
    return out[:b] if pad_b else out


def finalize_megakernel_sharded(
    tails: tuple[jax.Array, ...],
    pendings: tuple[jax.Array, ...],
    gap: jax.Array,
    ws: tuple[jax.Array, ...],
    thrs: tuple[jax.Array, ...],
    flips: tuple[jax.Array, ...],
    fc_ws: tuple[jax.Array, ...],
    fc_thrs: tuple[jax.Array, ...],
    fc_flips: tuple[jax.Array, ...],
    model_idx: jax.Array | None = None,
    *,
    mesh=None,
    stages,
    fc_raw: tuple[bool, ...],
    bb: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """``finalize_megakernel`` over a mesh-sharded slot pool."""
    kw = dict(stages=stages, fc_raw=fc_raw, bb=bb, interpret=interpret)
    if mesh is None or _data_size(mesh) == 1:
        return finalize_megakernel(tails, pendings, gap, ws, thrs, flips,
                                   fc_ws, fc_thrs, fc_flips, model_idx,
                                   **kw)
    bspec, rep = _batch_spec(mesh)
    nt, npd, ns, nf = len(tails), len(pendings), len(ws), len(fc_ws)
    if model_idx is not None:
        fn = lambda t, p, g, w, th, fl, fw, ft, ff, mi: finalize_megakernel(
            t, p, g, w, th, fl, fw, ft, ff, mi, **kw
        )
        return _shard_map()(
            fn, mesh=mesh,
            in_specs=((bspec,) * nt, (bspec,) * npd, bspec,
                      (rep,) * ns, (rep,) * ns, (rep,) * ns,
                      (rep,) * nf, (rep,) * nf, (rep,) * nf, bspec),
            out_specs=bspec, check_rep=False,
        )(tuple(tails), tuple(pendings), gap, tuple(ws), tuple(thrs),
          tuple(flips), tuple(fc_ws), tuple(fc_thrs), tuple(fc_flips),
          model_idx)
    fn = lambda t, p, g, w, th, fl, fw, ft, ff: finalize_megakernel(
        t, p, g, w, th, fl, fw, ft, ff, **kw
    )
    return _shard_map()(
        fn, mesh=mesh,
        in_specs=((bspec,) * nt, (bspec,) * npd, bspec,
                  (rep,) * ns, (rep,) * ns, (rep,) * ns,
                  (rep,) * nf, (rep,) * nf, (rep,) * nf),
        out_specs=bspec, check_rep=False,
    )(tuple(tails), tuple(pendings), gap, tuple(ws), tuple(thrs),
      tuple(flips), tuple(fc_ws), tuple(fc_thrs), tuple(fc_flips))


@jax.jit
def _gather_rows_keep(x: jax.Array, perm: jax.Array,
                      keep: jax.Array) -> jax.Array:
    out = jnp.take(x, perm, axis=0)
    k = keep.reshape(keep.shape + (1,) * (x.ndim - 1))
    return jnp.where(k, out, jnp.zeros_like(out))


def remap_slot_rows(
    x: jax.Array,
    perm: np.ndarray,
    keep: np.ndarray,
    *,
    mesh=None,
) -> jax.Array:
    """Permute the leading (slot) axis of one batched state array:
    ``out[i] = x[perm[i]]`` where ``keep[i]``, else a zero row.

    This is the device half of a cross-shard slot migration
    (``SlotPlacement.rebalance``): the per-slot ring state lives inside
    arrays the Pallas kernels consume, and ``pallas_call`` is opaque to
    GSPMD, so the row motion cannot ride inside a kernel — it runs as
    this standalone gather, where the partitioner is free to lower the
    cross-shard rows into collectives while vacated rows scrub to zero.
    With ``mesh`` the result is settled back onto the mesh's data-axis
    sharding so subsequent hops see the same layout as after a resize.
    """
    out = _gather_rows_keep(
        x, jnp.asarray(perm, jnp.int32), jnp.asarray(keep, bool)
    )
    if mesh is not None and _data_size(mesh) > 1:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.mesh import dp_axes
        spec = P(dp_axes(mesh), *([None] * (out.ndim - 1)))
        out = jax.device_put(out, NamedSharding(mesh, spec))
    return out


def classifier_tail_sharded(
    gap: jax.Array,
    fc_ws: tuple[jax.Array, ...],
    fc_thrs: tuple[jax.Array, ...],
    fc_flips: tuple[jax.Array, ...],
    model_idx: jax.Array | None = None,
    *,
    mesh=None,
    out_raw: tuple[bool, ...],
    bb: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """``classifier_tail`` over a mesh-sharded batch of GAP counts."""
    kw = dict(out_raw=out_raw, bb=bb, interpret=interpret)
    if mesh is None or _data_size(mesh) == 1:
        return classifier_tail(gap, fc_ws, fc_thrs, fc_flips, model_idx,
                               **kw)
    bspec, rep = _batch_spec(mesh)
    n = len(fc_ws)
    if model_idx is not None:
        fn = lambda g, ws, ts, fs, mi: classifier_tail(
            g, ws, ts, fs, mi, **kw
        )
        return _shard_map()(
            fn, mesh=mesh,
            in_specs=(bspec, (rep,) * n, (rep,) * n, (rep,) * n, bspec),
            out_specs=bspec, check_rep=False,
        )(gap, tuple(fc_ws), tuple(fc_thrs), tuple(fc_flips), model_idx)
    fn = lambda g, ws, ts, fs: classifier_tail(g, ws, ts, fs, **kw)
    return _shard_map()(
        fn, mesh=mesh,
        in_specs=(bspec, (rep,) * n, (rep,) * n, (rep,) * n),
        out_specs=bspec, check_rep=False,
    )(gap, tuple(fc_ws), tuple(fc_thrs), tuple(fc_flips))


# ---------------------------------------------------------------------------
# Fused classifier tail (repro.stream in-jit finalization)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("out_raw", "bb", "interpret"))
def classifier_tail(
    gap: jax.Array,
    fc_ws: tuple[jax.Array, ...],
    fc_thrs: tuple[jax.Array, ...],
    fc_flips: tuple[jax.Array, ...],
    model_idx: jax.Array | None = None,
    *,
    out_raw: tuple[bool, ...],
    bb: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """GAP counts -> raw logits: saturate at the 8-bit PWB ceiling, then the
    whole fc cascade fused in one kernel launch.

    gap (B, C) int32; fc_ws per-layer (Cin, Cout) ternary; fc_thrs/fc_flips
    per-layer (Cout,) SA params.  With ``model_idx`` ((B,) int32 tenant
    ids, constant per ``bb`` slot block) the fc params are pooled
    (M, ...) stacks.  Returns (B, n_classes) int32 raw logits — bit-exact
    with ``StreamState.logits`` (integer thresholds make the float32
    compare exact; counts keep every product inside int32).
    """
    interpret = default_interpret() if interpret is None else interpret
    pooled = model_idx is not None
    b = gap.shape[0]
    bb = _pick_block(b, _conv.DEFAULT_BB if bb is None else bb)
    gap_p = _pad_axis(gap.astype(jnp.int32), bb, 0)
    ws = tuple(w.astype(jnp.int32) for w in fc_ws)
    if pooled:
        thrs = tuple(
            t.astype(jnp.float32).reshape(t.shape[0], 1, -1)
            for t in fc_thrs
        )
        flips = tuple(
            f.astype(jnp.int32).reshape(f.shape[0], 1, -1) for f in fc_flips
        )
        mi = _block_model_idx(model_idx, b, bb, _round_up(b, bb) - b)
    else:
        thrs = tuple(t.astype(jnp.float32).reshape(1, -1) for t in fc_thrs)
        flips = tuple(f.astype(jnp.int32).reshape(1, -1) for f in fc_flips)
        mi = None
    out = _conv.classifier_tail_packed(
        gap_p, ws, thrs, flips, mi,
        out_raw=out_raw, bb=bb, interpret=interpret,
    )
    return out[:b]


# ---------------------------------------------------------------------------
# Dispatch heuristic: popcount (bandwidth) vs MXU (compute)
# ---------------------------------------------------------------------------

def pick_path(m: int, k: int, n: int) -> str:
    """Choose kernel path from arithmetic intensity on v5e constants.

    popcount path: bytes = m*k/8 + 2*k*n/8, "flops" = m*k*n VPU ops at
    ~4e12 ops/s effective; MXU path: bytes = m*k + k*n (int8),
    197e12/2 int8 macs/s.  Pick the lower predicted time.
    """
    t_pop = max((m * k / 8 + 2 * k * n / 8) / 819e9, (m * k * n) / 4e12)
    t_mxu = max((m * k + k * n) / 819e9, (m * k * n) / 98e12)
    return "popcount" if t_pop <= t_mxu else "mxu"


def _pick_block(dim: int, preferred: int, step: int = 1) -> int:
    """Largest block <= preferred that is a multiple of ``step`` and keeps
    padding overhead small; dim is padded up to a block multiple anyway."""
    b = min(preferred, max(step, _round_up(dim, step)))
    b = _round_up(b, step)
    return b


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
