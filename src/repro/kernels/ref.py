"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for kernel tests and the functional backend of the
cycle-level executor (`repro.core.executor`).  Everything here is exact
integer arithmetic — the digital semantics of the PSCNN macro.

Conventions
-----------
* binary activations are 0/1 arrays (uint32) laid out ``(..., L, C)``
* ternary weights are {-1,0,+1} int32 arrays; conv weights are ``(K, Cin,
  Cout)``; linear weights ``(Cin, Cout)``
* ``thr``/``flip`` come from ``repro.core.quant.fold_bn_to_threshold``
* pooling on binary activations is max-pool = OR over the window, matching
  the PWB's OR-tree
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant


# ---------------------------------------------------------------------------
# Dense (FC) layer
# ---------------------------------------------------------------------------

def ref_twm_matmul(x_bits: jax.Array, w_t: jax.Array) -> jax.Array:
    """Raw popcount difference: (M, K) {0,1} x (K, N) {-1,0,1} -> (M, N) int32."""
    pos, neg = quant.ternary_planes(w_t)
    xi = x_bits.astype(jnp.int32)
    return xi @ pos.astype(jnp.int32) - xi @ neg.astype(jnp.int32)


def ref_twm_matmul_sa(
    x_bits: jax.Array, w_t: jax.Array, thr: jax.Array, flip: jax.Array
) -> jax.Array:
    """Popcount difference followed by the SA threshold (binary output)."""
    s = ref_twm_matmul(x_bits, w_t)
    return quant.apply_threshold(s.astype(jnp.float32), thr, flip)


# ---------------------------------------------------------------------------
# 1-D convolution (binary activations, ternary weights)
# ---------------------------------------------------------------------------

def _shifted_views(x_bits: jax.Array, k: int, stride: int, pad: int) -> jax.Array:
    """Stack of K strided views: out[tap, t, c] = x_pad[t*stride + tap, c].

    This is the host-side mirror of the paper's line-buffer shifting ("shift
    the IFM downward, activate wordlines alternately") and the exact layout
    the Pallas conv kernel consumes.
    """
    L, C = x_bits.shape
    x_pad = jnp.pad(x_bits, ((pad, pad), (0, 0)))
    l_out = (L + 2 * pad - k) // stride + 1
    taps = [x_pad[tap : tap + (l_out - 1) * stride + 1 : stride, :] for tap in range(k)]
    return jnp.stack(taps, axis=0)  # (K, L_out, C)


def conv1d_out_len(length: int, k: int, stride: int, pad: int) -> int:
    return (length + 2 * pad - k) // stride + 1


def ref_bnn_conv1d(
    x_bits: jax.Array,
    w_t: jax.Array,
    stride: int = 1,
    pad: int = 0,
) -> jax.Array:
    """Raw conv popcount difference.

    x_bits: (L, Cin) {0,1};  w_t: (K, Cin, Cout) {-1,0,1} -> (L_out, Cout) int32.
    """
    k = w_t.shape[0]
    xs = _shifted_views(x_bits, k, stride, pad).astype(jnp.int32)  # (K,Lo,Ci)
    wt = w_t.astype(jnp.int32)
    return jnp.einsum("klc,kcn->ln", xs, wt)


def ref_bnn_conv1d_sa(
    x_bits: jax.Array,
    w_t: jax.Array,
    thr: jax.Array,
    flip: jax.Array,
    stride: int = 1,
    pad: int = 0,
    pool: int = 1,
) -> jax.Array:
    """Conv -> SA threshold -> (optional) fused max-pool (the PWB path)."""
    s = ref_bnn_conv1d(x_bits, w_t, stride, pad)
    y = quant.apply_threshold(s.astype(jnp.float32), thr, flip)
    if pool > 1:
        y = ref_maxpool1d(y, pool)
    return y


def ref_bnn_conv1d_batched(
    x_bits: jax.Array,
    w_t: jax.Array,
    stride: int = 1,
    pad: int = 0,
) -> jax.Array:
    """Batched raw conv: (B, L, Cin) {0,1} x (K, Cin, Cout) -> (B, L_out, Cout)."""
    return jax.vmap(lambda x: ref_bnn_conv1d(x, w_t, stride, pad))(x_bits)


def ref_maxpool1d(y_bits: jax.Array, pool: int) -> jax.Array:
    """Binary max-pool = OR over non-overlapping windows (drops remainder)."""
    l = (y_bits.shape[0] // pool) * pool
    y = y_bits[:l].reshape(l // pool, pool, *y_bits.shape[1:])
    return jnp.max(y, axis=1)


def ref_gap_counts(y_bits: jax.Array) -> jax.Array:
    """Global-average-pool as integer counts (PWB bypass + popcount counter)."""
    return jnp.sum(y_bits.astype(jnp.int32), axis=0)


# ---------------------------------------------------------------------------
# Bit-serial multi-bit input (first layer: 8-bit audio; FC after GAP counts)
# ---------------------------------------------------------------------------

def ref_bitserial_conv1d(
    x_u: jax.Array,
    w_t: jax.Array,
    bits: int,
    offset: int = 0,
    stride: int = 1,
    pad: int = 0,
) -> jax.Array:
    """Multi-bit-input conv as `bits` binary passes with 2^b weighting.

    x_u: (L, Cin) unsigned integers < 2**bits (offset-binary; ``offset`` is
    subtracted after accumulation: x = x_u - offset).  The offset term equals
    ``offset * sum_k w_k`` per output channel and folds into the threshold —
    exactly how the hardware absorbs it.  Spatial padding uses the *offset
    code* (the line buffer resets to the zero-level, not to code 0, which
    would mean -offset).  Returns raw int32 (L_out, Cout).
    """
    x_u = x_u.astype(jnp.uint32)
    if pad:
        x_u = jnp.pad(x_u, ((pad, pad), (0, 0)), constant_values=offset)
        pad = 0
    acc = None
    for b in range(bits):
        plane = ((x_u >> b) & 1).astype(jnp.uint32)
        d = ref_bnn_conv1d(plane, w_t, stride, pad)
        acc = d * (1 << b) if acc is None else acc + d * (1 << b)
    if offset:
        wsum = jnp.sum(w_t.astype(jnp.int32), axis=(0, 1))  # (Cout,)
        acc = acc - offset * wsum[None, :]
    return acc


def ref_bitserial_matmul(
    x_u: jax.Array, w_t: jax.Array, bits: int, offset: int = 0
) -> jax.Array:
    """Bit-serial dense layer: (M, K) uints x (K, N) ternary -> int32."""
    x_u = x_u.astype(jnp.uint32)
    acc = None
    for b in range(bits):
        plane = ((x_u >> b) & 1).astype(jnp.uint32)
        d = ref_twm_matmul(plane, w_t)
        acc = d * (1 << b) if acc is None else acc + d * (1 << b)
    if offset:
        wsum = jnp.sum(w_t.astype(jnp.int32), axis=0)
        acc = acc - offset * wsum[None, :]
    return acc


# ---------------------------------------------------------------------------
# Packed-domain oracles (operate on the exact uint32 buffers the kernels see)
# ---------------------------------------------------------------------------

def ref_popcount_gemm_packed(
    x_packed: jax.Array, wp_packed: jax.Array, wn_packed: jax.Array
) -> jax.Array:
    """(M, Kw) u32, (Kw, N) u32 planes -> (M, N) int32 popcount difference."""
    pp = jax.lax.population_count(
        jnp.bitwise_and(x_packed[:, :, None], wp_packed[None, :, :])
    ).astype(jnp.int32)
    pn = jax.lax.population_count(
        jnp.bitwise_and(x_packed[:, :, None], wn_packed[None, :, :])
    ).astype(jnp.int32)
    return jnp.sum(pp - pn, axis=1)
