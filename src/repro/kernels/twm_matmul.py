"""Pallas TPU kernel: bit-packed ternary-weight-mapping popcount GEMM.

This is the TPU-native adaptation of the PSCNN macro read (paper §II-C/D):
one grid cell computes a (bm, bn) tile of *finished* activations — popcount
difference, SA threshold, binarize — with the full contraction (≤1024
wordlines = ≤32 packed uint32 words) resident in VMEM, so no partial sums
ever leave the core.  That is the software image of "a single large macro
needs no partial-sum ADCs / adder trees".

Layouts
-------
x_packed : (M, Kw)  uint32   — activations, 32 binary lanes per word
wp, wn   : (Kw, N)  uint32   — positive / negative TWM weight planes
thr      : (1, N)   float32  — folded BN threshold (SA offset)
flip     : (1, N)   int32    — 1 where BN gamma < 0 (compare inverted)

Two output modes:
  * ``raw``  -> int32 popcount difference (final layer / logits)
  * ``sa``   -> uint32 {0,1} binarized activations

VMEM per grid cell (defaults bm=256, bn=256, Kw<=32):
  x 256*32*4 = 32 KiB, planes 2*32*256*4 = 64 KiB, acc 256*256*4 = 256 KiB
  -> ~352 KiB, comfortably inside the ~16 MiB VMEM of a v5e core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import dispatch

DEFAULT_BM = 256
DEFAULT_BN = 256


def _popdiff_tile(x, wp, wn, kw: int):
    """(bm, Kw) u32, (Kw, bn) u32 planes -> (bm, bn) int32 popcount diff.

    The k-loop is a static unroll over packed words: each step is a
    rank-1-broadcast AND + popcount on the VPU — the digital twin of one
    wordline-group activation.
    """
    acc = jnp.zeros((x.shape[0], wp.shape[1]), jnp.int32)
    for k in range(kw):
        xa = x[:, k][:, None]  # (bm, 1)
        p = jax.lax.population_count(jnp.bitwise_and(xa, wp[k][None, :]))
        n = jax.lax.population_count(jnp.bitwise_and(xa, wn[k][None, :]))
        acc = acc + p.astype(jnp.int32) - n.astype(jnp.int32)
    return acc


def _kernel_sa(x_ref, wp_ref, wn_ref, thr_ref, flip_ref, o_ref, *, kw: int):
    diff = _popdiff_tile(x_ref[...], wp_ref[...], wn_ref[...], kw)
    ge = diff.astype(jnp.float32) >= thr_ref[0, :][None, :]
    flip = flip_ref[0, :][None, :] != 0
    o_ref[...] = jnp.where(flip, ~ge, ge).astype(jnp.uint32)


def _kernel_raw(x_ref, wp_ref, wn_ref, o_ref, *, kw: int):
    o_ref[...] = _popdiff_tile(x_ref[...], wp_ref[...], wn_ref[...], kw)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "mode", "interpret")
)
def twm_matmul(
    x_packed: jax.Array,
    wp: jax.Array,
    wn: jax.Array,
    thr: jax.Array | None = None,
    flip: jax.Array | None = None,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    mode: str = "sa",
    interpret: bool = True,
) -> jax.Array:
    """Packed popcount GEMM with optional fused SA epilogue.

    Shapes must be pre-padded: M % bm == 0, N % bn == 0 (pad with zero rows /
    dead columns — inactive wordlines / unused bitline pairs).
    """
    m, kw = x_packed.shape
    kw2, n = wp.shape
    assert kw == kw2 and wn.shape == wp.shape, (x_packed.shape, wp.shape, wn.shape)
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
    grid = (m // bm, n // bn)

    x_spec = pl.BlockSpec((bm, kw), lambda i, j: (i, 0))
    w_spec = pl.BlockSpec((kw, bn), lambda i, j: (0, j))
    v_spec = pl.BlockSpec((1, bn), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))

    if mode == "sa":
        assert thr is not None and flip is not None
        return dispatch.pallas_call(
            functools.partial(_kernel_sa, kw=kw),
            grid=grid,
            in_specs=[x_spec, w_spec, w_spec, v_spec, v_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.uint32),
            interpret=interpret,
        )(x_packed, wp, wn, thr.reshape(1, n), flip.astype(jnp.int32).reshape(1, n))
    elif mode == "raw":
        return dispatch.pallas_call(
            functools.partial(_kernel_raw, kw=kw),
            grid=grid,
            in_specs=[x_spec, w_spec, w_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
            interpret=interpret,
        )(x_packed, wp, wn)
    raise ValueError(f"mode {mode!r}")


# ---------------------------------------------------------------------------
# Beyond-paper MXU path: ternary weights as int8 on the systolic array.
# Wins when the shape is compute-bound (big M); the popcount path wins when
# memory-bound (weights 16x smaller).  See DESIGN.md §2.4.
# ---------------------------------------------------------------------------

def _kernel_mxu(x_ref, w_ref, thr_ref, flip_ref, o_ref):
    acc = jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    ge = acc.astype(jnp.float32) >= thr_ref[0, :][None, :]
    flip = flip_ref[0, :][None, :] != 0
    o_ref[...] = jnp.where(flip, ~ge, ge).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def twm_matmul_mxu(
    x_i8: jax.Array,
    w_i8: jax.Array,
    thr: jax.Array,
    flip: jax.Array,
    *,
    bm: int = 256,
    bn: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """int8 MXU GEMM (x in {0,1}, w in {-1,0,1}) with the same SA epilogue.

    K stays un-tiled (<=1024 fits VMEM at int8: 256 KiB per x tile).
    """
    m, k = x_i8.shape
    k2, n = w_i8.shape
    assert k == k2
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0
    grid = (m // bm, n // bn)
    return dispatch.pallas_call(
        _kernel_mxu,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.uint32),
        interpret=interpret,
    )(x_i8, w_i8, thr.reshape(1, n), flip.astype(jnp.int32).reshape(1, n))
