import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds ShapeDtypeStruct stand-ins (never allocates the
full model), lowers the appropriate step function with explicit shardings,
compiles it for the production mesh, and records:

  * memory_analysis()      — proves the cell fits per-device HBM
  * cost_analysis()        — per-device FLOPs / bytes for §Roofline
  * collective inventory   — parsed from the post-SPMD HLO

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k --multi-pod both --out results/dryrun
Exit code is non-zero if any requested cell fails — the CI gate.
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax

from repro.configs.base import SHAPES, arch_names, get_arch
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.sharding import act
from repro.sharding import specs as sh
from repro.train import loop as tl
from repro.train import optimizer as opt_lib
from repro.utils.logging import get_logger

log = get_logger("dryrun")


ACT_BUDGET_BYTES = 5e9  # scan-saved activations per device, per microbatch


def pick_microbatches(cfg, shape, mesh, profile: str = "megatron") -> int:
    """Grad-accum count so the scan-saved residual stream fits HBM."""
    per_dev_seqs = max(shape.global_batch // sh.dp_total(mesh, profile), 1)
    act_per_seq = cfg.n_layers * shape.seq_len * cfg.d_model * 2
    need = max(1, -(-int(per_dev_seqs * act_per_seq) // int(ACT_BUDGET_BYTES)))
    for m in range(need, per_dev_seqs + 1):
        if per_dev_seqs % m == 0:
            return m
    return per_dev_seqs


def pick_optimizer(cfg) -> str:
    """AdamW where its 12 B/param state fits; Adafactor beyond ~50B params."""
    return "adafactor" if api.param_count(cfg) > 50e9 else "adamw"


def _train_cell(cfg, shape, mesh, report, profile="megatron",
                remat="block", compression="none"):
    """Lower the full train step (fwd+bwd+optimizer) for this cell."""
    micro = pick_microbatches(cfg, shape, mesh, profile)
    tcfg = tl.TrainConfig(
        opt=opt_lib.OptConfig(name=pick_optimizer(cfg)),
        microbatches=micro, remat=remat, compression=compression,
    )
    loss = api.loss_fn(cfg, remat=tcfg.remat)
    step = tl.make_train_step(cfg, tcfg, loss)

    params_s = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0))
    )
    state_s = jax.eval_shape(lambda p: tl.init_train_state(tcfg, p), params_s)
    batch_s = dict(cfg.input_specs(shape))
    if micro > 1:
        batch_s = {
            k: jax.ShapeDtypeStruct(
                (micro, v.shape[0] // micro, *v.shape[1:]), v.dtype
            )
            for k, v in batch_s.items()
        }

    state_sh = sh.params_shardings(state_s, mesh, cfg, report)
    batch_sh = sh.batch_shardings(batch_s, mesh, report, micro=micro > 1,
                                  profile=profile)
    metrics_sh = jax.tree_util.tree_map(
        lambda _: sh.scalar_sharding(mesh),
        {"loss": 0, "grad_norm": 0, "lr": 0},
    )
    fn = jax.jit(
        step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,),
    )
    return fn.lower(state_s, batch_s)


def _prefill_cell(cfg, shape, mesh, report, profile="megatron",
                  shard_prefill_out=True, **_):
    prefill = api.prefill_fn(cfg)
    params_s = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0))
    )
    batch_s = dict(cfg.input_specs(shape))
    params_sh = sh.params_shardings(params_s, mesh, cfg, report)
    batch_sh = sh.batch_shardings(batch_s, mesh, report, profile=profile)
    out_sh = None
    if shard_prefill_out:
        # exported caches dominate prefill memory (62L x 2 x B x 32k x Hk x
        # Dh can be 16+ GB/dev if GSPMD replicates them) — pin them to the
        # decode-state layout.
        out_s = jax.eval_shape(prefill, params_s, batch_s)
        out_sh = sh.decode_state_shardings(out_s, mesh, cfg, report)
    fn = jax.jit(prefill, in_shardings=(params_sh, batch_sh),
                 out_shardings=out_sh)
    return fn.lower(params_s, batch_s)


def _decode_cell(cfg, shape, mesh, report, profile="megatron",
                 kv_replication=0, **_):
    decode = api.decode_fn(cfg)
    b, s = shape.global_batch, shape.seq_len
    if kv_replication == 0:
        # default: replicate kv heads up to the TP degree for zero-comm GQA
        # attention (bounded by 4x cache growth)
        tp = mesh.shape["model"]
        kv_replication = (min(tp // cfg.n_kv_heads, 4)
                          if cfg.family != "encdec" and tp > cfg.n_kv_heads
                          else 1)
    params_s = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0))
    )
    state_s = jax.eval_shape(
        lambda: api.init_decode_state(cfg, b, s,
                                      kv_replication=kv_replication))
    tok_s = cfg.input_specs(shape)["tokens"]
    params_sh = sh.params_shardings(params_s, mesh, cfg, report)
    state_sh = sh.decode_state_shardings(state_s, mesh, cfg, report)
    tok_sh = sh.batch_shardings(tok_s, mesh, report)
    fn = jax.jit(
        decode,
        in_shardings=(params_sh, state_sh, tok_sh),
        out_shardings=(sh.logits_sharding(mesh, b), state_sh),
        donate_argnums=(1,),
    )
    return fn.lower(params_s, state_s, tok_s)


_LOWER = {"train": _train_cell, "prefill": _prefill_cell, "decode": _decode_cell}


def _reduced_cfg(cfg, n_super: int):
    """Same arch with depth = first_k_dense + n_super superblocks."""
    first_k = cfg.moe.first_k_dense if cfg.moe else 0
    pat = len(cfg.superblock) if cfg.superblock else 1
    kw = {"n_layers": first_k + n_super * pat}
    if cfg.family == "encdec":
        kw["n_enc_layers"] = n_super
    return dataclasses.replace(cfg, **kw)


def _full_repeats(cfg) -> int:
    first_k = cfg.moe.first_k_dense if cfg.moe else 0
    pat = len(cfg.superblock) if cfg.superblock else 1
    return (cfg.n_layers - first_k) // pat


def cost_extrapolate(cfg, shape, mesh, **overrides) -> dict:
    """Per-device costs via unrolled reduced-depth lowerings.

    XLA's cost_analysis() does not multiply while-loop bodies by trip count,
    so costs are measured on fully-unrolled 1- and 2-superblock variants and
    extrapolated linearly: total(R) = c1 + (R-1) * (c2 - c1).  Exact for
    scan-homogeneous stacks (every repeat is the same HLO).
    """
    from repro.models import attention as attn_mod
    from repro.models import scan_utils as stk

    stk.SCAN_UNROLL = True
    # widen flash-attention chunks: unrolled block count drops 1024 -> ~16
    # at 32k with identical total FLOPs (chunking only affects memory)
    old_q, old_kv = attn_mod.QUERY_CHUNK, attn_mod.KV_CHUNK
    attn_mod.QUERY_CHUNK = attn_mod.KV_CHUNK = 8192
    try:
        meas = []
        for n in (1, 2):
            lowered = _LOWER[shape.kind](
                _reduced_cfg(cfg, n), shape, mesh, sh.ShardingReport(),
                **overrides
            )
            comp = lowered.compile()
            ca = comp.cost_analysis()
            roof = rl.analyze(comp)
            meas.append(
                {
                    "flops": float(ca.get("flops", 0.0)),
                    "bytes": float(ca.get("bytes accessed", 0.0)),
                    "coll": roof.coll_bytes,
                    "colls": roof.collectives,
                }
            )
    finally:
        stk.SCAN_UNROLL = False
        attn_mod.QUERY_CHUNK, attn_mod.KV_CHUNK = old_q, old_kv
    r = _full_repeats(cfg)
    c1, c2 = meas

    def lin(a, b):
        return a + (r - 1) * (b - a)

    kinds = set(c1["colls"]) | set(c2["colls"])
    colls = {}
    for k in kinds:
        n1, b1 = c1["colls"].get(k, (0, 0))
        n2, b2 = c2["colls"].get(k, (0, 0))
        colls[k] = (int(max(lin(n1, n2), 0)), float(max(lin(b1, b2), 0.0)))
    return {
        "flops_per_dev": max(lin(c1["flops"], c2["flops"]), c1["flops"]),
        "hbm_bytes_per_dev": max(lin(c1["bytes"], c2["bytes"]), c1["bytes"]),
        "coll_bytes_per_dev": max(lin(c1["coll"], c2["coll"]), 0.0),
        "collectives": colls,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             profile: str = "megatron", no_cost: bool = False,
             **overrides) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "profile": profile, **({"overrides": overrides} if overrides else {})}
    if not cfg.supports(shape):
        cell["status"] = "skip"
        cell["reason"] = "full-attention arch: long_500k needs sub-quadratic attention (DESIGN.md)"
        return cell
    t0 = time.monotonic()
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp_axes = (("pod", "data", "model") if multi_pod else ("data", "model")) \
        if profile == "dp_only" else (("pod", "data") if multi_pod else "data")
    act.set_policy(mesh, dp_axes,
                   tp_axis=None if profile == "dp_only" else "model")
    report = sh.ShardingReport()
    try:
        # 1) full-depth scanned compile: the runnability proof + memory
        lowered = _LOWER[shape.kind](cfg, shape, mesh, report,
                                     profile=profile, **overrides)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower
        mem = compiled.memory_analysis()
        # 2) cost extraction: unrolled reduced-depth extrapolation.
        # --no-cost: compile+memory proof only (recurrent archs whose
        # unrolled cost lowering exceeds this container's CPU compile budget)
        if no_cost:
            costs = {"flops_per_dev": 0.0, "hbm_bytes_per_dev": 0.0,
                     "coll_bytes_per_dev": 0.0, "collectives": {}}
        else:
            costs = cost_extrapolate(cfg, shape, mesh, profile=profile,
                                     **overrides)
        n_dev = mesh.size
        # sLSTM layers run a per-token scan that can't be unrolled; add the
        # analytic flop term (w_in + r_in matmuls = 16*D^2/token, x3 for bwd)
        n_slstm = (cfg.superblock.count("s") * _full_repeats(cfg)
                   if cfg.superblock else 0)
        if n_slstm:
            toks = shape.global_batch * (
                shape.seq_len if shape.kind != "decode" else 1
            )
            mult = 3.0 if shape.kind == "train" else 1.0
            costs["flops_per_dev"] += (
                n_slstm * toks * 16.0 * cfg.d_model**2 * mult / n_dev
            )
        roof = rl.Roofline(
            flops=costs["flops_per_dev"],
            hbm_bytes=costs["hbm_bytes_per_dev"],
            coll_bytes=costs["coll_bytes_per_dev"],
            collectives=costs["collectives"],
        )
        tokens = shape.global_batch * (
            shape.seq_len if shape.kind != "decode" else 1
        )
        n_active = api.active_param_count(cfg)
        model_fl = (
            rl.model_flops_train(n_active, tokens)
            if shape.kind == "train"
            else rl.model_flops_infer(n_active, tokens)
        )
        if no_cost:
            cell["cost_note"] = "compile+memory proof only (--no-cost)"
        cell.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            mem={
                "args_gb": mem.argument_size_in_bytes / 1e9,
                "out_gb": mem.output_size_in_bytes / 1e9,
                "temp_gb": mem.temp_size_in_bytes / 1e9,
                "alias_gb": mem.alias_size_in_bytes / 1e9,
                "peak_gb": (
                    mem.argument_size_in_bytes
                    + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes
                    - mem.alias_size_in_bytes
                ) / 1e9,
            },
            roofline=roof.summary(),
            model_flops_per_dev=model_fl / n_dev,
            useful_flops_frac=(
                (model_fl / n_dev) / roof.flops if roof.flops else None
            ),
            degraded=report.degraded,
        )
        log.info(
            "%s/%s/%s ok: compile %.0fs, peak %.2f GB/dev, dominant=%s",
            arch, shape_name, mesh_name, t_compile,
            cell["mem"]["peak_gb"], roof.dominant,
        )
    except Exception as e:  # noqa: BLE001 — report, don't crash the matrix
        cell["status"] = "fail"
        cell["error"] = f"{type(e).__name__}: {e}"
        cell["traceback"] = traceback.format_exc()[-2000:]
        log.error("%s/%s/%s FAILED: %s", arch, shape_name, mesh_name,
                  cell["error"])
    finally:
        act.clear_policy()
    return cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-cost", action="store_true",
                    help="compile+memory proof only (skip cost extraction)")
    args = ap.parse_args()

    archs = arch_names() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    pods = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod
    ]

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                name = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                path = outdir / f"{name}.json"
                if path.exists():
                    prev = json.loads(path.read_text())
                    if prev.get("status") in ("ok", "skip"):
                        log.info("skip cached %s", name)
                        continue
                cell = run_cell(arch, shape, mp, no_cost=args.no_cost)
                path.write_text(json.dumps(cell, indent=2, default=str))
                n_fail += cell["status"] == "fail"
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
