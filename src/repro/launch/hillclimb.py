import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver: re-lower one dry-run cell under named variants and
print the roofline deltas (EXPERIMENTS.md §Perf methodology).

  PYTHONPATH=src python -m repro.launch.hillclimb \
      --arch qwen3-0.6b --shape train_4k --mesh 16x16 \
      --variant dp_only:profile=dp_only \
      --variant dots:remat=dots

Each variant's full cell JSON lands in results/hillclimb/.
"""
import argparse
import json
import pathlib

from repro.launch import dryrun
from repro.utils.logging import get_logger

log = get_logger("hillclimb")


def parse_variant(s: str):
    name, _, kvs = s.partition(":")
    kw = {}
    for kv in filter(None, kvs.split(",")):
        k, v = kv.split("=")
        kw[k] = int(v) if v.isdigit() else v
    return name, kw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["16x16", "2x16x16"], default="16x16")
    ap.add_argument("--variant", action="append", default=[],
                    help="name:key=val,key=val  (keys: profile, remat, "
                         "compression)")
    ap.add_argument("--baseline", action="store_true",
                    help="also re-run the baseline with current code")
    ap.add_argument("--out", default="results/hillclimb")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    multi = args.mesh == "2x16x16"
    variants = [("baseline", {})] if args.baseline else []
    variants += [parse_variant(v) for v in args.variant]

    rows = []
    for name, kw in variants:
        profile = kw.pop("profile", "megatron")
        cell = dryrun.run_cell(args.arch, args.shape, multi,
                               profile=profile, **kw)
        tag = f"{args.arch}__{args.shape}__{args.mesh}__{name}"
        (outdir / f"{tag}.json").write_text(
            json.dumps(cell, indent=2, default=str))
        if cell["status"] == "ok":
            r = cell["roofline"]
            rows.append((name, r["compute_s"], r["memory_s"],
                         r["collective_s"], r["dominant"],
                         cell["mem"]["peak_gb"]))
            log.info("%s: c=%.3f m=%.3f coll=%.3f dom=%s peak=%.1fGB",
                     name, r["compute_s"], r["memory_s"], r["collective_s"],
                     r["dominant"], cell["mem"]["peak_gb"])
        else:
            log.error("%s FAILED: %s", name, cell.get("error"))
            rows.append((name, None, None, None, "FAIL", None))

    print("\nvariant,compute_s,memory_s,collective_s,dominant,peak_gb")
    for row in rows:
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
