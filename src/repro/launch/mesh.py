"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host devices
*before* any jax initialization; tests and benches see the real 1-CPU
topology.

Geometry (per the brief): one v5e pod = 16x16 = 256 chips, axes
("data", "model"); the multi-pod config stacks 2 pods on a leading "pod"
axis (DCN/ICI-superpod) = 512 chips.
"""
from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.x; Auto is the old default behavior
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on jax version
    AxisType = None


def _axis_kw(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel), ("data", "model"),
        **_axis_kw(2),
    )


def make_stream_mesh(n_shards: int | None = None):
    """1-D data-parallel mesh for the streaming runtime's slot pool.

    The streaming model is tiny and always replicated (one CIM macro's
    weights serve every user), so there is no 'model' axis: the mesh is a
    flat ``("data",)`` axis and the slot pool's batch dimension shards over
    it — one logical pool spanning the whole mesh instead of one pool per
    device.  Defaults to every visible device; force a multi-device host
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    n = jax.device_count() if n_shards is None else n_shards
    if n > jax.device_count():
        raise ValueError(
            f"{n} shards > {jax.device_count()} devices (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before jax init)"
        )
    if n == jax.device_count():
        return jax.make_mesh((n,), ("data",), **_axis_kw(1))
    # a strict prefix of the device list (tests sweep 1/2/8-shard meshes)
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:n]), ("data",))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_size(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))
