"""Render results/dryrun/*.json into the EXPERIMENTS.md §Roofline markdown
table, plus the streaming-runtime table from BENCH_stream.json.

  PYTHONPATH=src python -m repro.launch.report [results/dryrun] [BENCH_stream.json]
"""
from __future__ import annotations

import json
import pathlib
import sys


def fmt_s(x: float) -> str:
    return f"{x*1e3:.1f}ms" if x < 1 else f"{x:.2f}s"


def roofline_lines(cells: list[dict]) -> list[str]:
    out = [
        "| arch | shape | mesh | peak GB/dev | compute | memory | "
        "collective | dominant | useful | status |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    n_ok = n_fail = n_skip = 0
    for c in cells:
        if c["status"] == "skip":
            n_skip += 1
            out.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — "
                f"| — | — | — | skip (full-attn @500k) |"
            )
            continue
        if c["status"] == "fail":
            n_fail += 1
            out.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — "
                f"| — | — | — | FAIL: {c.get('error','')[:60]} |"
            )
            continue
        n_ok += 1
        r, m = c["roofline"], c["mem"]
        uf = c.get("useful_flops_frac")
        if c.get("cost_note"):
            out.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} "
                f"| {m['peak_gb']:.1f} | — | — | — | — | — "
                f"| ok (compile+memory proof; cost pass skipped) |"
            )
            continue
        # a measured 0.0 is a legitimate value, not a missing one — only
        # an absent field renders as "—"
        uf_cell = f"{uf:.2f}" if uf is not None else "—"
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {m['peak_gb']:.1f} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | {uf_cell} | ok |"
        )
    out.append(
        f"\n{n_ok} ok / {n_fail} fail / {n_skip} skip "
        f"of {len(cells)} recorded cells"
    )
    return out


def _num(row: dict, key: str, fmt: str) -> str:
    v = row.get(key)
    # v == v filters NaN (an empty latency window reports NaN rather
    # than a fabricated 0.0) — both it and a missing field render as "—"
    return format(v, fmt) if isinstance(v, (int, float)) and v == v else "—"


def stream_lines(bench: dict) -> list[str]:
    """§Streaming table: the BENCH_stream.json steady-state sweep and the
    mesh-sharded 1k-stream sweep, one row per configuration, with each
    hop's latency split into its host-pack and device halves."""
    out = [
        "",
        "## Streaming (BENCH_stream.json)",
        "",
        "| config | streams | shards | hop p50 ms | hop p99 ms | "
        "host-pack ms | device ms | stream-hops/s | uJ/inference |",
        "|---|---|---|---|---|---|---|---|---|",
    ]

    def row(label: str, streams, shards, r: dict) -> str:
        # _num is falsy- and NaN-safe: a measured 0.0 renders as a
        # number; a missing field (pre-arena artifacts) or a NaN (no
        # steps in the window) renders as "—"
        return (
            f"| {label} | {streams} | {shards} "
            f"| {_num(r, 'hop_ms_p50', '.3f')} "
            f"| {_num(r, 'hop_ms_p99', '.3f')} "
            f"| {_num(r, 'host_pack_ms_p50', '.3f')} "
            f"| {_num(r, 'device_ms_p50', '.3f')} "
            f"| {_num(r, 'stream_hops_per_sec', '.0f')} "
            f"| {_num(r, 'uj_per_inference', '.4f')} |"
        )

    for b, r in sorted(
        bench.get("sweep", {}).items(), key=lambda kv: int(kv[0])
    ):
        out.append(row("steady", b, 1, r))
    sharded = bench.get("sharded") or {}  # may be committed as null
    total = sharded.get("total_streams", "—")
    stale = sharded.get("carried_from_prior_run")
    label = "mesh-sharded (prior run)" if stale else "mesh-sharded"
    for s, r in sorted(
        sharded.get("configs", {}).items(), key=lambda kv: int(kv[0])
    ):
        out.append(row(label, total, s, r))
    ratio = sharded.get("multi_vs_single")
    if isinstance(ratio, (int, float)):
        out.append(
            f"\nbest multi-shard vs best single-device at "
            f"{total} streams: {ratio:.2f}x aggregate stream-hops/s"
            + (" (prior run)" if stale else "")
        )
    phases = bench.get("phases") or {}
    if phases:
        parts = [
            f"{p} {_num(d, 'ms_p50', '.3f')}/{_num(d, 'ms_p99', '.3f')} ms "
            f"({d.get('share_of_wall', 0.0) * 100:.0f}%)"
            for p, d in phases.items()
        ]
        out.append(
            "\nper-phase hop breakdown at B="
            f"{bench.get('n_streams', '—')} (p50/p99, share of hop wall): "
            + ", ".join(parts)
        )
    tr = bench.get("trace") or {}
    if isinstance(tr.get("span_coverage"), (int, float)):
        out.append(
            f"\ntrace: {tr.get('events', 0)} spans -> {tr.get('artifact')} "
            f"({tr['span_coverage'] * 100:.1f}% of hop wall covered); "
            "open at ui.perfetto.dev"
        )
    ev = bench.get("event_log") or {}
    if ev.get("counts"):
        out.append(
            f"\nevent log -> {ev.get('artifact')}: "
            + ", ".join(f"{k}={v}" for k, v in sorted(ev["counts"].items()))
        )
    oo = bench.get("obs_overhead") or {}
    if isinstance(oo.get("overhead_frac"), (int, float)):
        out.append(
            f"\nobservability overhead: "
            f"{oo['instrument_ms_per_hop'] * 1e3:.1f} us/hop = "
            f"{oo['overhead_frac'] * 100:.2f}% of hop p50 "
            f"({'within' if oo.get('within_2pct') else 'OVER'} the 2% cap)"
        )
    hp = bench.get("host_pack") or {}
    if isinstance(hp.get("reduction"), (int, float)):
        out.append(
            f"\nhost-side hop packing at {hp.get('streams', 0):.0f} "
            f"streams: {hp['host_pack_ms_before']:.3f} ms (per-slot loop) "
            f"-> {hp['host_pack_ms_after']:.3f} ms (arena gather), "
            f"{hp['reduction']:.1f}x"
        )
    sk = bench.get("skewed_churn") or {}  # may be committed as null
    if isinstance(sk.get("floor_capacity"), (int, float)):
        reb, pin = sk["rebalance"], sk["no_rebalance"]
        out.append(
            f"\nskewed-churn shrink floor ({sk['shards']} shards, "
            f"{sk['active_after_churn']} of {sk['total_streams']} streams "
            f"left, all on one shard): capacity "
            f"{pin['steady_capacity']:.0f} pinned without rebalance -> "
            f"{reb['steady_capacity']:.0f} with it "
            f"({reb['rows_migrated']:.0f} rows migrated; balanced floor "
            f"{sk['floor_capacity']:.0f})"
            + (" (prior run)" if sk.get("carried_from_prior_run") else "")
        )
    mt = bench.get("multi_tenant") or {}  # absent in pre-pool artifacts
    per_k = mt.get("per_k") or {}
    if per_k:
        out += [
            "",
            f"multi-tenant weight pool at {mt.get('total_streams', '—')} "
            "total streams (fused pool vs K separate schedulers):",
            "",
            "| K | hop p50 ms | launches/emit hop | stream-hops/s | "
            "baseline hops/s | speedup |",
            "|---|---|---|---|---|---|",
        ]
        for k, r in sorted(per_k.items(), key=lambda kv: int(kv[0])):
            base = r.get("baseline") or {}
            out.append(
                f"| {k} | {_num(r, 'hop_ms_p50', '.3f')} "
                f"| {_num(r, 'dispatches_per_emit_hop', '.0f')} "
                f"| {_num(r, 'stream_hops_per_sec', '.0f')} "
                f"| {_num(base, 'stream_hops_per_sec', '.0f')} "
                f"| {_num(r, 'speedup_vs_separate', '.2f')}x |"
            )
        if isinstance(mt.get("speedup_at_k4"), (int, float)):
            out.append(
                f"\nK=4 fused vs separate: {mt['speedup_at_k4']:.2f}x "
                f"(floor 2x: {'PASS' if mt.get('k4_target_met') else 'FAIL'}"
                "; launches/hop K-independent: "
                f"{bool(mt.get('launches_k_independent'))})"
            )
    lm = bench.get("lm_elastic") or {}  # absent in pre-runtime artifacts
    lm_cfg = lm.get("configs") or {}
    if lm_cfg:
        out += [
            "",
            f"LM decode on the shared slot pool ({lm.get('arch', '—')}, "
            f"pool starts at {lm.get('min_slots', '—')} slots, "
            "grow/shrink churn per wave):",
            "",
            "| slot ceiling | tokens/s | grows | shrinks | peak cap | "
            "final cap |",
            "|---|---|---|---|---|---|",
        ]
        for s, r in sorted(lm_cfg.items(), key=lambda kv: int(kv[0])):
            out.append(
                f"| {s} | {_num(r, 'tokens_per_sec', '.1f')} "
                f"| {_num(r, 'resizes_grow', '.0f')} "
                f"| {_num(r, 'resizes_shrink', '.0f')} "
                f"| {_num(r, 'peak_capacity', '.0f')} "
                f"| {_num(r, 'final_capacity', '.0f')} |"
            )
    ov = bench.get("overlap") or {}
    if isinstance(ov.get("hidden_frac"), (int, float)):
        out.append(
            f"\nasync overlap at B={ov.get('batch', 0)} open-loop: "
            f"{ov['hidden_frac']*100:.1f}% of pack+detector time hidden "
            f"under device spans ({ov['hidden_ms']:.1f} ms; floor 90%: "
            f"{'PASS' if ov.get('hidden_target_met') else 'FAIL'}), "
            f"device-span utilization {ov['utilization']*100:.1f}%, "
            f"{ov['speedup_vs_sync']:.2f}x vs sync throughput"
        )
    return out


def main() -> None:
    d = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    cells = [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]
    for line in roofline_lines(cells):
        print(line)

    bench_path = pathlib.Path(
        sys.argv[2] if len(sys.argv) > 2 else "BENCH_stream.json"
    )
    if bench_path.exists():
        for line in stream_lines(json.loads(bench_path.read_text())):
            print(line)


if __name__ == "__main__":
    main()
