"""Render results/dryrun/*.json into the EXPERIMENTS.md §Roofline markdown
table.

  PYTHONPATH=src python -m repro.launch.report [results/dryrun]
"""
from __future__ import annotations

import json
import pathlib
import sys


def fmt_s(x: float) -> str:
    return f"{x*1e3:.1f}ms" if x < 1 else f"{x:.2f}s"


def main() -> None:
    d = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    cells = []
    for p in sorted(d.glob("*.json")):
        cells.append(json.loads(p.read_text()))

    print("| arch | shape | mesh | peak GB/dev | compute | memory | "
          "collective | dominant | useful | status |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    n_ok = n_fail = n_skip = 0
    for c in cells:
        if c["status"] == "skip":
            n_skip += 1
            print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — "
                  f"| — | — | — | skip (full-attn @500k) |")
            continue
        if c["status"] == "fail":
            n_fail += 1
            print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — "
                  f"| — | — | — | FAIL: {c.get('error','')[:60]} |")
            continue
        n_ok += 1
        r, m = c["roofline"], c["mem"]
        uf = c.get("useful_flops_frac")
        if c.get("cost_note"):
            print(f"| {c['arch']} | {c['shape']} | {c['mesh']} "
                  f"| {m['peak_gb']:.1f} | — | — | — | — | — "
                  f"| ok (compile+memory proof; cost pass skipped) |")
            continue
        print(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {m['peak_gb']:.1f} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | {uf:.2f} | ok |" if uf else
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {m['peak_gb']:.1f} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | — | ok |"
        )
    print(f"\n{n_ok} ok / {n_fail} fail / {n_skip} skip "
          f"of {len(cells)} recorded cells")


if __name__ == "__main__":
    main()
