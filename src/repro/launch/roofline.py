"""Roofline-term extraction from compiled dry-run artifacts (DESIGN.md §7).

Inputs per cell: ``compiled.cost_analysis()`` (per-partition FLOPs + bytes)
and the post-SPMD HLO text (``compiled.as_text()``), from which collective
traffic is parsed: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction's operand/result sizes, with
per-op byte-movement rules on the v5e ring ICI.

Hardware constants (the brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^)]*)\)"
)
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+?)\s+[a-z]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Collective:
    kind: str
    operand_bytes: int
    result_bytes: int

    @property
    def moved_bytes(self) -> int:
        """Per-device ICI bytes under ring algorithms."""
        if self.kind == "all-gather":
            return max(self.result_bytes - self.operand_bytes, 0)
        if self.kind == "reduce-scatter":
            return max(self.operand_bytes - self.result_bytes, 0)
        if self.kind == "all-reduce":
            return 2 * self.operand_bytes
        return self.operand_bytes  # all-to-all / collective-permute


def parse_collectives(hlo_text: str) -> list[Collective]:
    # first pass: instruction name -> result-type bytes
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            sizes[m.group(1)] = _type_bytes(m.group(2))
    out: list[Collective] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        _, result_type, kind, operands = m.groups()
        rb = _type_bytes(result_type)
        ob = 0
        for op in operands.split(","):
            op = op.strip().lstrip("%")
            ob += sizes.get(op, 0)
        if ob == 0:
            ob = rb  # operand not resolvable; conservative
        out.append(Collective(kind, ob, rb))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float              # per device
    hbm_bytes: float          # per device
    coll_bytes: float         # per device, ring-adjusted
    collectives: dict         # kind -> (count, bytes)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time: dominant term (perfect overlap model)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def summary(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s_lower_bound": self.step_s,
            "collectives": self.collectives,
        }


def analyze(compiled) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returned [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text())
    agg: dict[str, list] = {}
    total = 0
    for c in colls:
        k = agg.setdefault(c.kind, [0, 0])
        k[0] += 1
        k[1] += c.moved_bytes
        total += c.moved_bytes
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=float(total),
        collectives={k: tuple(v) for k, v in agg.items()},
    )


def model_flops_train(n_active_params: int, tokens: int) -> float:
    """6*N*D rule (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_active_params * tokens


def model_flops_infer(n_active_params: int, tokens: int) -> float:
    return 2.0 * n_active_params * tokens
