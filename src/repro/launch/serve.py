"""Serving launcher: batched engine with continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --requests 8 --slots 4 --max-new 16

Production decode shapes (decode_32k / long_500k) are lowered for the 512-
chip mesh by dryrun.py; this launcher exercises the same decode_step
end-to-end on the reduced configs.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models import api
from repro.serve.engine import Engine, Request
from repro.utils.logging import get_logger

log = get_logger("launch.serve")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = Engine(cfg, params, batch_slots=args.slots, max_seq=args.max_seq)

    rng = np.random.default_rng(args.seed)
    t0 = time.monotonic()
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    done = eng.run_until_drained()
    dt = time.monotonic() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    log.info("served %d requests, %d tokens in %.2fs (%.1f tok/s)",
             len(done), n_tok, dt, n_tok / dt)
    for r in done[:4]:
        log.info("request %d -> %s", r.rid, r.out_tokens[:8])


if __name__ == "__main__":
    main()
