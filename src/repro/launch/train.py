"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Full configs target the production mesh (see dryrun.py for the lowering
proof); on this CPU container use --smoke for the reduced configs.  The
launcher wires: config -> model -> sharded data -> Trainer (checkpoint,
restart, straggler monitor) and retries through simulated failures
(--failure-rate) to demonstrate the restart path.
"""
from __future__ import annotations

import argparse
import random

import jax

from repro.configs.base import get_arch
from repro.data import lm_data
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.train import loop as tl
from repro.train import optimizer as opt_lib
from repro.utils.logging import get_logger

log = get_logger("launch.train")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "sgd", "lion"])
    ap.add_argument("--compression", default="none",
                    choices=["none", "sign1bit", "topk"])
    ap.add_argument("--remat", default="none",
                    choices=["none", "block", "dots"])
    ap.add_argument("--quant-mode", default=None, choices=[None, "none", "ternary"],
                    help="override arch quant mode (paper's ternary regime)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--failure-rate", type=float, default=0.0,
                    help="probability per step of a simulated crash+restart")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    if args.quant_mode:
        import dataclasses
        cfg = dataclasses.replace(cfg, quant_mode=args.quant_mode)
    tcfg = tl.TrainConfig(
        opt=opt_lib.OptConfig(name=args.optimizer, lr=args.lr),
        microbatches=args.microbatches,
        warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps,
        remat=args.remat,
        compression=args.compression,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
    )
    dcfg = lm_data.DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed, microbatches=args.microbatches,
        frontend_tokens=(
            cfg.n_frontend_tokens or (args.seq if cfg.family == "encdec" else 0)
        ),
        frontend_dim=cfg.d_model,
    )

    rng = random.Random(args.seed)
    done = 0
    restarts = 0
    while done < args.steps:
        params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
        trainer = tl.Trainer(
            cfg, tcfg, api.loss_fn(cfg, remat=args.remat), params,
            lm_data.iterator(dcfg, start_step=0),
        )
        # fast-forward the data iterator to the restored step
        trainer.data_iter = lm_data.iterator(dcfg, start_step=trainer.step_idx)
        try:
            while trainer.step_idx < args.steps:
                if args.failure_rate and rng.random() < args.failure_rate:
                    raise RuntimeError("simulated node failure")
                h = trainer.run(1)
                m = h[-1]
                if m["step"] % 10 == 0 or m["step"] == 1:
                    log.info("step %4d loss %.4f (%.2fs)", m["step"],
                             m["loss"], m["step_time_s"])
            done = trainer.step_idx
        except RuntimeError as e:
            restarts += 1
            log.warning("%s -> restarting from last checkpoint (restart #%d)",
                        e, restarts)
            if not tcfg.ckpt_dir:
                raise
    log.info("training complete: %d steps, %d restarts survived",
             done, restarts)


if __name__ == "__main__":
    main()
