"""Model zoo entry points, dispatched on ArchConfig.family.

The launcher, dry-run, trainer and server import only this module:

  init_params(cfg, key)                 parameter pytree
  loss_fn(cfg)(params, batch)           training loss (batch dict)
  prefill_fn(cfg)(params, batch)        logits + cache/state
  decode_fn(cfg)(params, state, tok)    one-token step
  init_decode_state(cfg, batch, seq)    zeroed cache/state pytree
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, stack


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    if cfg.family == "encdec":
        return encdec.init_params(cfg, key)
    return stack.init_params(cfg, key)


def loss_fn(cfg: ArchConfig, remat: str = "block"):
    if cfg.family == "encdec":
        def loss(params, batch):
            return encdec.lm_loss(cfg, params, batch["tokens"],
                                  batch["labels"], batch["frontend"],
                                  remat=remat)
        return loss

    def loss(params, batch):
        return stack.lm_loss(cfg, params, batch["tokens"], batch["labels"],
                             frontend=batch.get("frontend"), remat=remat)
    return loss


def prefill_fn(cfg: ArchConfig):
    if cfg.family == "encdec":
        def prefill(params, batch):
            return encdec.forward(cfg, params, batch["tokens"],
                                  batch["frontend"], mode="prefill")
        return prefill

    def prefill(params, batch):
        return stack.forward(cfg, params, batch["tokens"],
                             frontend=batch.get("frontend"), mode="prefill")
    return prefill


def decode_fn(cfg: ArchConfig):
    if cfg.family == "encdec":
        def step(params, state, tokens):
            return encdec.decode_step(cfg, params, state, tokens)
        return step

    def step(params, state, tokens):
        return stack.decode_step(cfg, params, state, tokens)
    return step


def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int,
                      kv_replication: int = 1):
    if cfg.family == "encdec":
        return encdec.init_decode_state(cfg, batch, max_seq, max_seq)
    return stack.init_decode_state(cfg, batch, max_seq,
                                   kv_replication=kv_replication)


def param_count(cfg: ArchConfig) -> int:
    """Analytic parameter count (no allocation) for roofline MODEL_FLOPS."""
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    import numpy as np
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes)))


def active_param_count(cfg: ArchConfig) -> int:
    """Active params per token (MoE: top_k + shared experts only)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    reps = stack.n_repeats(cfg)
    struct = stack.block_structure(cfg)
    n_moe_layers = sum(1 for _, f in struct if f == "moe") * reps
    per_expert = 3 * cfg.d_model * m.d_expert
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return total - inactive
