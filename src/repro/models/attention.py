"""Grouped-query attention with qk-norm, RoPE, KV cache, and cross-attention.

Shapes: x (B, S, D); q heads H, kv heads Hk (H % Hk == 0); d_head Dh.
Causal masking is implicit via position comparison so the same kernel serves
train (full causal), prefill (causal + cache write) and decode (single query
against a cache).  Softmax runs in fp32.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.scan_utils import maybe_unrolled_scan
from repro.models.layers import COMPUTE_DTYPE, apply_linear, apply_rope, dense_init


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   d_head: int, qk_norm: bool = False) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d_model, n_heads * d_head),
        "wk": dense_init(k2, d_model, n_kv_heads * d_head),
        "wv": dense_init(k3, d_model, n_kv_heads * d_head),
        "wo": dense_init(k4, n_heads * d_head, d_model),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((d_head,), jnp.float32)
        p["k_norm"] = jnp.ones((d_head,), jnp.float32)
    return p


def _qkv(p, x, n_heads, n_kv_heads, d_head, positions, rope_theta, quant_mode):
    b, s, _ = x.shape
    q = apply_linear(x, p["wq"], quant_mode).reshape(b, s, n_heads, d_head)
    k = apply_linear(x, p["wk"], quant_mode).reshape(b, s, n_kv_heads, d_head)
    v = apply_linear(x, p["wv"], quant_mode).reshape(b, s, n_kv_heads, d_head)
    if "q_norm" in p:
        q = layers.rmsnorm(q, p["q_norm"])
        k = layers.rmsnorm(k, p["k_norm"])
    if rope_theta:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


QUERY_CHUNK = 1024
KV_CHUNK = 1024


def _sdpa_block(q, k, v, q_pos, k_pos, causal):
    """Unchunked reference block: q (B,Sq,H,Dh), k/v (B,Sk,Hk,Dh).

    The logits constraint pins the decode-path strategy (§Perf iteration
    #5): kv-head TP when heads divide the axis, otherwise keep logits
    *sequence-sharded* — k/v never move (sequence-parallel attention) and
    the softmax adds only tiny cross-shard max/sum reductions.  Without
    this GSPMD all-gathers the whole KV cache per layer (~GB/step)."""
    from repro.sharding import act

    b, sq, h, dh = q.shape
    hk = k.shape[2]
    g = h // hk
    q = q.reshape(b, sq, hk, g, dh)
    scale = 1.0 / math.sqrt(dh)
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q.astype(COMPUTE_DTYPE), k.astype(COMPUTE_DTYPE),
        preferred_element_type=jnp.float32,
    ) * scale
    tp = act.axis_size("tp")
    if tp and hk % tp == 0:
        logits = act.constrain(logits, "dp", "tp", None, None, None)
    else:
        logits = act.constrain(logits, "dp", None, None, None, "tp")
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]  # (Sq, Sk)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(COMPUTE_DTYPE))
    return out.reshape(b, sq, h, dh)


def _sdpa_flash(q, k, v, q_pos, k_pos, causal: bool = True,
                q_chunk: int | None = None, kv_chunk: int | None = None):
    """Online-softmax attention: never materializes (Sq, Sk) logits.

    This is the TPU-native memory discipline of flash attention expressed in
    lax scans (the XLA path MaxText used before splash kernels): an outer
    checkpointed scan over query chunks, an inner scan over KV chunks
    carrying the running (max, denom, acc).  fp32 accumulators.
    """
    # chunk sizes read at trace time so the dry-run cost pass can widen them
    # (total attention FLOPs are chunk-independent; only memory changes, and
    # the cost pass doesn't measure memory — launch/dryrun.py)
    q_chunk = q_chunk or QUERY_CHUNK
    kv_chunk = kv_chunk or KV_CHUNK
    b, sq, h, dh = q.shape
    sk, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = 1.0 / math.sqrt(dh)
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    if sq % qc:
        qc = sq
    if sk % kc:
        kc = sk
    nq, nk = sq // qc, sk // kc

    qs = jnp.moveaxis(
        q.reshape(b, nq, qc, hk, g, dh), 1, 0
    ).astype(COMPUTE_DTYPE)                               # (Nq,B,qc,Hk,G,Dh)
    qps = q_pos.reshape(nq, qc)
    ks = jnp.moveaxis(k.reshape(b, nk, kc, hk, dh), 1, 0).astype(COMPUTE_DTYPE)
    vs = jnp.moveaxis(v.reshape(b, nk, kc, hk, dh), 1, 0).astype(COMPUTE_DTYPE)
    kps = k_pos.reshape(nk, kc)

    def q_step(_, xq):
        q_blk, qp = xq

        def kv_step(carry, xkv):
            m, l, acc = carry
            k_blk, v_blk, kp = xkv
            logits = jnp.einsum(
                "bqhgd,bkhd->bhgqk", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            if causal:
                mask = qp[:, None] >= kp[None, :]
                logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_blk = jnp.max(logits, axis=-1)
            m_new = jnp.maximum(m, m_blk)
            corr = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(COMPUTE_DTYPE), v_blk,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hk, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hk, g, qc), jnp.float32)
        a0 = jnp.zeros((b, hk, g, qc, dh), jnp.float32)
        (m, l, acc), _ = maybe_unrolled_scan(kv_step, (m0, l0, a0), (ks, vs, kps))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.moveaxis(out, 3, 1).reshape(b, qc, hk * g, dh)
        return None, out.astype(COMPUTE_DTYPE)

    step = jax.checkpoint(q_step, prevent_cse=False) if nq > 1 else q_step
    _, outs = maybe_unrolled_scan(step, None, (qs, qps))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, dh)


def _sdpa(q, k, v, q_pos, k_pos, causal: bool = True):
    """q (B,Sq,H,Dh), k/v (B,Sk,Hk,Dh) -> (B,Sq,H,Dh); GQA via head groups.

    Dispatch: tiny problems use the unchunked block (cheap, simple HLO);
    anything that would materialize a big logits tensor goes flash.
    Activations are constrained to batch-DP x head-TP (falling back to
    query-sequence TP when heads don't divide the axis) — see sharding/act.
    """
    from repro.sharding import act

    q = act.constrain(q, "dp", None, "tp", None)
    k = act.constrain(k, "dp", None, "tp", None)
    v = act.constrain(v, "dp", None, "tp", None)
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    if sq * sk <= QUERY_CHUNK * KV_CHUNK:
        out = _sdpa_block(q, k, v, q_pos, k_pos, causal)
    else:
        out = _sdpa_flash(q, k, v, q_pos, k_pos, causal)
    return act.constrain(out, "dp", None, "tp", None)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def attention_train(p, x, *, n_heads, n_kv_heads, d_head, rope_theta=10000.0,
                    qk_norm=False, quant_mode="none", causal=True):
    """Full-sequence self-attention (train / encoder)."""
    del qk_norm  # presence of q_norm in params decides
    b, s, _ = x.shape
    pos = jnp.arange(s)
    q, k, v = _qkv(p, x, n_heads, n_kv_heads, d_head, pos[None, :], rope_theta,
                   quant_mode)
    o = _sdpa(q, k, v, pos, pos, causal=causal)
    return apply_linear(o.reshape(b, s, n_heads * d_head), p["wo"], quant_mode)


def attention_prefill(p, x, *, n_heads, n_kv_heads, d_head, rope_theta=10000.0,
                      quant_mode="none"):
    """Causal attention that also returns the (k, v) cache to install."""
    b, s, _ = x.shape
    pos = jnp.arange(s)
    q, k, v = _qkv(p, x, n_heads, n_kv_heads, d_head, pos[None, :], rope_theta,
                   quant_mode)
    o = _sdpa(q, k, v, pos, pos, causal=True)
    out = apply_linear(o.reshape(b, s, n_heads * d_head), p["wo"], quant_mode)
    return out, (k, v)


def attention_decode(p, x, cache_kv, cache_len, *, n_heads, n_kv_heads, d_head,
                     rope_theta=10000.0, quant_mode="none"):
    """One-token decode: x (B,1,D), cache (k,v) each (B,Smax,Hk_eff,Dh).

    cache_len: scalar int32 — number of valid cache positions.  The new
    token is written at cache_len; masking hides unwritten tail slots.

    Hk_eff may exceed n_kv_heads: KV-head *replication* for TP (each rank
    stores the kv heads its q-heads need locally — zero-comm GQA attention
    at the cost of r x cache memory; §Perf iteration #5).  The replication
    factor is read off the cache shape; new k/v are tiled to match.
    """
    b = x.shape[0]
    k_cache, v_cache = cache_kv
    s_max = k_cache.shape[1]
    pos = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    q, k_new, v_new = _qkv(p, x, n_heads, n_kv_heads, d_head, pos, rope_theta,
                           quant_mode)
    hk_eff = k_cache.shape[2]
    if hk_eff != n_kv_heads:
        rep = hk_eff // n_kv_heads
        k_new = jnp.repeat(k_new, rep, axis=2)
        v_new = jnp.repeat(v_new, rep, axis=2)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), cache_len, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), cache_len, axis=1)
    k_pos = jnp.arange(s_max)
    # mask: positions <= cache_len are attendable (q_pos = cache_len)
    o = _sdpa(q, k_cache, v_cache, jnp.array([cache_len]), k_pos, causal=True)
    out = apply_linear(o.reshape(b, 1, n_heads * d_head), p["wo"], quant_mode)
    return out, (k_cache, v_cache)


def cross_attention(p, x, memory, *, n_heads, n_kv_heads, d_head,
                    quant_mode="none"):
    """Decoder->encoder attention (no RoPE, no causal mask)."""
    b, sq, _ = x.shape
    sk = memory.shape[1]
    q = apply_linear(x, p["wq"], quant_mode).reshape(b, sq, n_heads, d_head)
    k = apply_linear(memory, p["wk"], quant_mode).reshape(b, sk, n_kv_heads, d_head)
    v = apply_linear(memory, p["wv"], quant_mode).reshape(b, sk, n_kv_heads, d_head)
    o = _sdpa(q, k, v, jnp.arange(sq), jnp.arange(sk), causal=False)
    return apply_linear(o.reshape(b, sq, n_heads * d_head), p["wo"], quant_mode)
