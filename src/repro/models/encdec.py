"""Encoder-decoder stack (seamless-m4t backbone; modality frontend is a stub
per the brief — ``input_specs`` supplies precomputed frame embeddings).

Encoder: non-causal self-attention blocks over frame embeddings.
Decoder: causal self-attention + cross-attention + MLP blocks.
Both stacks scan over stacked layer params like models/stack.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import layers
from repro.models.layers import COMPUTE_DTYPE, dense_init, embed_init
from repro.models.stack import _scan, chunked_ce_loss


def _init_enc_layer(cfg: ArchConfig, key) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": attn.init_attention(k1, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim),
        "norm2": jnp.ones((cfg.d_model,), jnp.float32),
        "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def _init_dec_layer(cfg: ArchConfig, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": jnp.ones((cfg.d_model,), jnp.float32),
        "self_attn": attn.init_attention(k1, cfg.d_model, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.head_dim),
        "norm_x": jnp.ones((cfg.d_model,), jnp.float32),
        "cross_attn": attn.init_attention(k2, cfg.d_model, cfg.n_heads,
                                          cfg.n_kv_heads, cfg.head_dim),
        "norm2": jnp.ones((cfg.d_model,), jnp.float32),
        "mlp": layers.init_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def stack(init_fn, k, n):
        ps = [init_fn(cfg, ki) for ki in jax.random.split(k, n)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ps)

    return {
        "embed": embed_init(k1, cfg.padded_vocab, cfg.d_model),
        "enc_blocks": stack(_init_enc_layer, k2, cfg.n_enc_layers),
        "dec_blocks": stack(_init_dec_layer, k3, cfg.n_layers),
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "out_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense_init(k4, cfg.d_model, cfg.padded_vocab),
    }


def encode(cfg: ArchConfig, params, frames) -> jax.Array:
    """frames (B,S,D) -> encoder memory (B,S,D)."""
    kw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
              d_head=cfg.head_dim, rope_theta=cfg.rope_theta,
              quant_mode=cfg.quant_mode)

    def block(h, p):
        h = h + attn.attention_train(p["attn"], layers.rmsnorm(h, p["norm1"]),
                                     causal=False, **kw)
        h = h + layers.apply_mlp(p["mlp"], layers.rmsnorm(h, p["norm2"]),
                                 cfg.quant_mode)
        return h, None

    h, _ = _scan(block, frames.astype(COMPUTE_DTYPE),
                        params["enc_blocks"])
    return layers.rmsnorm(h, params["enc_norm"])


def _dec_block(cfg: ArchConfig, p, h, memory, mode, cache=None, cache_len=None):
    kw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
              d_head=cfg.head_dim, rope_theta=cfg.rope_theta,
              quant_mode=cfg.quant_mode)
    hn = layers.rmsnorm(h, p["norm1"])
    if mode == "train":
        h = h + attn.attention_train(p["self_attn"], hn, **kw)
        new_cache = None
    elif mode == "prefill":
        o, new_cache = attn.attention_prefill(p["self_attn"], hn, **kw)
        h = h + o
    else:
        o, new_cache = attn.attention_decode(p["self_attn"], hn, cache,
                                             cache_len, **kw)
        h = h + o
    h = h + attn.cross_attention(
        p["cross_attn"], layers.rmsnorm(h, p["norm_x"]), memory,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.head_dim,
        quant_mode=cfg.quant_mode,
    )
    h = h + layers.apply_mlp(p["mlp"], layers.rmsnorm(h, p["norm2"]),
                             cfg.quant_mode)
    return h, new_cache


def forward_hidden(cfg: ArchConfig, params, tokens, frontend,
                   mode: str = "train", remat: str = "block"):
    """Encoder + decoder blocks, no output head. -> (h, caches, memory)."""
    memory = encode(cfg, params, frontend)
    h = params["embed"][tokens].astype(COMPUTE_DTYPE)
    policy = layers.RematPolicy(remat)

    def block(h, p):
        h, cache = _dec_block(cfg, p, h, memory, mode)
        return h, cache

    blk = policy.wrap(block) if mode == "train" else block
    h, caches = _scan(blk, h, params["dec_blocks"])
    return h, caches, memory


def forward(cfg: ArchConfig, params, tokens, frontend, mode: str = "train",
            remat: str = "block"):
    """tokens (B,St), frontend frames (B,Sa,D)."""
    h, caches, memory = forward_hidden(cfg, params, tokens, frontend, mode,
                                       remat)
    h = layers.rmsnorm(h, params["out_norm"])
    logits = jax.lax.dot_general(
        h, params["lm_head"].astype(COMPUTE_DTYPE), (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if mode == "prefill":
        return logits, {"self": caches, "memory": memory}
    return logits, jnp.zeros((), jnp.float32)


def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int,
                      mem_seq: int) -> dict:
    kv = lambda: jnp.zeros(
        (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
        jnp.bfloat16,
    )
    return {
        "cache_len": jnp.zeros((), jnp.int32),
        "self": (kv(), kv()),
        "memory": jnp.zeros((batch, mem_seq, cfg.d_model), jnp.bfloat16),
    }


def decode_step(cfg: ArchConfig, params, state, tokens):
    h = params["embed"][tokens].astype(COMPUTE_DTYPE)
    memory = state["memory"]
    cache_len = state["cache_len"]

    def block(h, xs):
        p, cache = xs
        h, new_cache = _dec_block(cfg, p, h, memory, "decode",
                                  cache=cache, cache_len=cache_len)
        return h, new_cache

    h, new_caches = _scan(
        block, h, (params["dec_blocks"], state["self"])
    )
    h = layers.rmsnorm(h, params["out_norm"])
    logits = jax.lax.dot_general(
        h, params["lm_head"].astype(COMPUTE_DTYPE), (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return logits, {
        "cache_len": cache_len + 1,
        "self": new_caches,
        "memory": memory,
    }


def lm_loss(cfg: ArchConfig, params, tokens, labels, frontend,
            remat: str = "block", loss_chunk: int = 512):
    h, _, _ = forward_hidden(cfg, params, tokens, frontend, remat=remat)

    def project(hc):
        hc = layers.rmsnorm(hc, params["out_norm"])
        return jax.lax.dot_general(
            hc, params["lm_head"].astype(COMPUTE_DTYPE),
            (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )

    return chunked_ce_loss(project, h, labels, cfg.vocab, cfg.padded_vocab,
                           chunk=loss_chunk)
