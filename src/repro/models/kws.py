"""The paper's binary keyword-spotting model (Fig. 7) — QAT graph + export.

Fig. 7 is not machine-readable in the source, so the topology is
reconstructed to satisfy every stated constraint simultaneously
(DESIGN.md §9.2).  The reconstruction, with its arithmetic:

  input   16,000 samples (1 s @ 16 kHz), 8-bit offset-binary
  l0   conv( 1->64,  K19, S8, pad9) bitser-8      out 2000   W   1,216  MAC   2.432M
  b1   conv(64->128, K3,  S1, pad1) +pool2        out 1000   W  24,576  MAC  49.152M
  b2   conv(128->256,K5,  S1, pad2) +pool2        out  500   W 163,840  MAC 163.840M
  b3   conv(256->352,K3,  S1, pad1) +pool2        out  250   W 294,912  MAC 135.168M
  gap  250x352 -> 8-bit counts
  fc1  352->512, bitser-8, SA binary                         W 180,224  MAC 180,224
  fc2  512->12, raw logits (row-split 2x256)                 W   6,144  MAC   6,144

  totals: 646,336 weights (631.2Kb, paper: 652Kb, -3.2%)
          350,778,368 MACs (paper: ~350M, +0.2%)
  rotation (weight SRAM): b3.c1, b3.c2, fc1.c2, fc1.c3
          = 262,144 weights = 512Kb = exactly the weight SRAM capacity

QAT recipe (Hubara et al. [6] + TWN-style ternary weights):
  * fp32 shadow weights, ternarized forward with identity STE
  * binary activations {1,0} with clipped STE
  * per-channel affine (a, b) before binarization — the foldable stand-in
    for BN; exported as SA thresholds thr=-b/a, flip=(a<0)
  * final logits are the raw popcount counts (scaled by a scalar
    temperature for the CE loss only, so argmax is preserved exactly)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.cnn_spec import CNN1DSpec, Conv1DSpec, FCSpec, GAPSpec

N_CLASSES = 12
IN_LEN = 16000
IN_OFFSET = 128

ROTATE_HINTS = ("b3.c1", "b3.c2", "fc1.c2", "fc1.c3")
ROWSPLIT_HINTS = {"fc2": 2}


def build_kws_spec(
    in_len: int = IN_LEN,
    width: int = 64,
    n_classes: int = N_CLASSES,
) -> CNN1DSpec:
    """The Fig. 7 reconstruction.  ``width`` scales channels (64 = paper)."""
    w = width
    return CNN1DSpec(
        in_len=in_len,
        in_channels=1,
        in_bits=8,
        name="pscnn_kws",
        layers=(
            Conv1DSpec(1, w, k=19, stride=8, pad=9, in_bits=8,
                       in_offset=IN_OFFSET, name="l0"),
            Conv1DSpec(w, 2 * w, k=3, stride=1, pad=1, pool=2, name="b1"),
            Conv1DSpec(2 * w, 4 * w, k=5, stride=1, pad=2, pool=2, name="b2"),
            Conv1DSpec(4 * w, int(5.5 * w), k=3, stride=1, pad=1, pool=2, name="b3"),
            GAPSpec(int(5.5 * w), name="gap"),
            FCSpec(int(5.5 * w), 8 * w, in_bits=8, name="fc1"),
            FCSpec(8 * w, n_classes, out_raw=True, name="fc2"),
        ),
    )


def build_kws_smoke_spec() -> CNN1DSpec:
    """Reduced config for CPU smoke tests (same family, tiny)."""
    return build_kws_spec(in_len=800, width=16)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_kws_params(key: jax.Array, spec: CNN1DSpec) -> dict:
    params: dict = {}
    for li, lspec in enumerate(spec.layers):
        if isinstance(lspec, Conv1DSpec):
            key, k1 = jax.random.split(key)
            fan_in = lspec.k * lspec.cin
            w = jax.random.normal(k1, (lspec.k, lspec.cin, lspec.cout)) * (
                1.0 / math.sqrt(fan_in)
            )
        elif isinstance(lspec, FCSpec):
            key, k1 = jax.random.split(key)
            fan_in = lspec.cin
            w = jax.random.normal(k1, (lspec.cin, lspec.cout)) * (
                1.0 / math.sqrt(fan_in)
            )
        else:
            continue
        entry = {"w": w.astype(jnp.float32)}
        if not getattr(lspec, "out_raw", False):
            # affine-before-sign (folded-BN stand-in); a>0 at init, scaled so
            # a*s lands inside the STE pass-through window |x|<=1: the
            # pre-activation std is ~sqrt(fan_in)*input_scale (input_scale
            # ~73 for 8-bit offset-binary audio, ~L/8 for GAP counts, ~0.6
            # for binary activations)
            in_bits = getattr(lspec, "in_bits", 1)
            if in_bits > 1:
                input_scale = 74.0 if isinstance(lspec, Conv1DSpec) else 32.0
            else:
                input_scale = 0.6
            entry["a"] = jnp.full(
                (lspec.cout,), 1.0 / (math.sqrt(fan_in) * input_scale),
                jnp.float32,
            )
            entry["b"] = jnp.zeros((lspec.cout,), jnp.float32)
        params[f"layer{li}"] = entry
    # CE logit scale (learnable; argmax-invariant). 0.3 puts raw-count
    # logits in a useful softmax range from step 0 — at 0.05 the first
    # ~150 steps are spent just growing it (single-batch probe, §III-A).
    params["temp"] = jnp.asarray(0.3, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# QAT forward (single example; vmap for batches)
# ---------------------------------------------------------------------------

def _conv1d(x: jax.Array, w: jax.Array, stride: int, pad: int) -> jax.Array:
    """(L, Cin) x (K, Cin, Cout) -> (L_out, Cout), float32 exact-int math."""
    lhs = x.T[None]  # (1, Cin, L)
    rhs = jnp.transpose(w, (2, 1, 0))  # (Cout, Cin, K)
    out = jax.lax.conv_general_dilated(
        lhs, rhs, window_strides=(stride,), padding=[(pad, pad)]
    )
    return out[0].T  # (L_out, Cout)


def _maxpool(x: jax.Array, p: int) -> jax.Array:
    l = (x.shape[0] // p) * p
    return jnp.max(x[:l].reshape(l // p, p, x.shape[1]), axis=1)


def kws_forward(params: dict, x_u8: jax.Array, spec: CNN1DSpec) -> jax.Array:
    """x_u8: (L,) uint8 offset-binary audio -> (n_classes,) raw-count logits."""
    h = (x_u8.astype(jnp.float32) - IN_OFFSET)[:, None]  # (L, 1) integer-valued
    binary = False  # first layer input is multi-bit
    for li, lspec in enumerate(spec.layers):
        p = params.get(f"layer{li}")
        if isinstance(lspec, Conv1DSpec):
            w_t = quant.ternarize_weight(p["w"])
            s = _conv1d(h, w_t, lspec.stride, lspec.pad)
            h = quant.binarize_act(p["a"][None, :] * s + p["b"][None, :])
            if lspec.pool > 1:
                h = _maxpool(h, lspec.pool)
            binary = True
        elif isinstance(lspec, GAPSpec):
            h = jnp.sum(h, axis=0, keepdims=True)  # counts (PWB counters)
        elif isinstance(lspec, FCSpec):
            w_t = quant.ternarize_weight(p["w"])
            s = h.reshape(1, -1) @ w_t
            if getattr(lspec, "out_raw", False):
                h = s  # raw logits
            else:
                h = quant.binarize_act(p["a"][None, :] * s + p["b"][None, :])
    return h[0]


def kws_loss(params: dict, batch_x: jax.Array, batch_y: jax.Array,
             spec: CNN1DSpec) -> jax.Array:
    logits = jax.vmap(lambda x: kws_forward(params, x, spec))(batch_x)
    logits = logits * params["temp"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, batch_y[:, None], axis=-1))


def kws_accuracy(params: dict, batch_x: jax.Array, batch_y: jax.Array,
                 spec: CNN1DSpec) -> jax.Array:
    logits = jax.vmap(lambda x: kws_forward(params, x, spec))(batch_x)
    return jnp.mean(jnp.argmax(logits, -1) == batch_y)


# ---------------------------------------------------------------------------
# Export: QAT params -> (ternary weights, SA thresholds) for the compiler
# ---------------------------------------------------------------------------

def export_kws(params: dict, spec: CNN1DSpec) -> tuple[dict, dict]:
    """Fold BN-affines into *integer* SA thresholds (quant.py docs).

    Pre-activations s are integers, so ``a*s+b >= 0`` is exactly
    ``s >= ceil(-b/a)`` (a>0) / ``s <= floor(-b/a)`` (a<0, flip).  Exporting
    the integer threshold makes hardware execution bit-exact with the QAT
    forward — no knife-edge float disagreements.
    """
    weights: dict[int, np.ndarray] = {}
    thresholds: dict[int, tuple[np.ndarray, np.ndarray]] = {}
    for li, lspec in enumerate(spec.layers):
        p = params.get(f"layer{li}")
        if p is None:
            continue
        w_t = np.asarray(quant.ternarize_weight(p["w"]), dtype=np.int8)
        weights[li] = w_t
        if "a" in p:
            a = np.asarray(p["a"], np.float64)
            b = np.asarray(p["b"], np.float64)
            safe_a = np.where(a == 0, 1.0, a)
            t = -b / safe_a
            thr = np.where(a > 0, np.ceil(t), np.floor(t) + 1)
            # a == 0: output is constant sign(b)
            thr = np.where(a == 0, np.where(b >= 0, -np.inf, np.inf), thr)
            flip = a < 0
            thresholds[li] = (thr.astype(np.float64), flip)
        else:
            thresholds[li] = (
                np.zeros(lspec.cout, np.float64),
                np.zeros(lspec.cout, bool),
            )
    return weights, thresholds
