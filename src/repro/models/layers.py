"""Shared building blocks for the LM-family architectures (pure JAX).

Everything is functional: ``init_*`` builds parameter pytrees, ``apply``-style
functions consume them.  Weights are stored in ``param_dtype`` (bf16 by
default — the fp32 master copy lives in the optimizer, ZeRO-style), compute
runs in bf16 with fp32 accumulations where it matters.

TernaryLinear is the paper's technique lifted into the LM stack: BitNet-style
QAT linears whose weights ternarize {-1,0,+1} with an identity STE and a
per-tensor scale.  At serve time they can execute on the TWM popcount
kernels (packed planes); in training / dry-run they run as masked-sign
matmuls on the MXU (DESIGN.md §2.4 explains when each path wins).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import quant

PARAM_DTYPE = jnp.bfloat16
COMPUTE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype=PARAM_DTYPE) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=PARAM_DTYPE) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Linear (+ ternary QAT mode)
# ---------------------------------------------------------------------------

def linear(x: jax.Array, w: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        x.astype(COMPUTE_DTYPE),
        w.astype(COMPUTE_DTYPE),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=COMPUTE_DTYPE,
    )


def ternary_linear(x: jax.Array, w: jax.Array) -> jax.Array:
    """PSCNN/BitNet-style ternary QAT linear (the paper's arithmetic regime).

    w ternarizes with identity STE; a per-tensor scale keeps magnitudes.
    The matmul stays on the MXU (int-like values in bf16); the serve-time
    packed-popcount path lives in repro.kernels.
    """
    w32 = w.astype(jnp.float32)
    scale = jnp.mean(jnp.abs(w32)) + 1e-8
    w_t = quant.ternarize_weight(w32) * scale
    return jax.lax.dot_general(
        x.astype(COMPUTE_DTYPE),
        w_t.astype(COMPUTE_DTYPE),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=COMPUTE_DTYPE,
    )


def apply_linear(x: jax.Array, w: jax.Array, quant_mode: str = "none") -> jax.Array:
    if quant_mode == "ternary":
        return ternary_linear(x, w)
    return linear(x, w)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm(x, gamma, beta, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)  # (Dh/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,Dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., 0::2], x32[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, d_model, d_ff),
        "wi_up": dense_init(k2, d_model, d_ff),
        "wo": dense_init(k3, d_ff, d_model),
    }


def apply_mlp(p: dict, x: jax.Array, quant_mode: str = "none") -> jax.Array:
    g = apply_linear(x, p["wi_gate"], quant_mode)
    u = apply_linear(x, p["wi_up"], quant_mode)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(COMPUTE_DTYPE) * u
    return apply_linear(h, p["wo"], quant_mode)


@dataclasses.dataclass(frozen=True)
class RematPolicy:
    """Activation checkpointing policy selector for the train loop."""

    mode: str = "block"  # 'none' | 'block' | 'dots'

    def wrap(self, fn):
        if self.mode == "none":
            return fn
        if self.mode == "block":
            return jax.checkpoint(fn, prevent_cse=False)
        if self.mode == "dots":
            return jax.checkpoint(
                fn,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
                prevent_cse=False,
            )
        raise ValueError(self.mode)
