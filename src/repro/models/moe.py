"""Mixture-of-experts blocks: fine-grained routed experts + shared experts.

Covers the assigned MoE recipes:
  * deepseek-moe-16b : 64 routed (top-6) + 2 shared experts, fine-grained
  * llama4-scout     : 16 routed (top-1) + 1 shared
  * jamba-1.5-large  : 16 routed (top-2), every other layer

Dispatch is GShard/MaxText-style tokens-choose with a static expert
capacity: tokens scatter into an (E, C, D) buffer (C = N*K/E * cf), experts
run dense MLPs on their buckets, results gather back weighted by router
gates.  FLOPs scale with top_k (not E) and the (E,...) dimension shards over
the expert/model axis under GSPMD, producing the expected all-to-all pair in
the lowered HLO.  ``impl='dense'`` keeps the reference everything-everywhere
formulation for correctness tests (exact when capacity is unbounded).

Auxiliary load-balance loss is Switch-style: E * sum_e(f_e * p_e).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import COMPUTE_DTYPE, dense_init


def init_moe(key, d_model: int, d_expert: int, n_experts: int,
             n_shared: int = 0, d_shared: int | None = None) -> dict:
    keys = jax.random.split(key, 4)

    def stack_init(k, din, dout):
        ks = jax.random.split(k, n_experts)
        return jnp.stack([dense_init(ki, din, dout) for ki in ks])

    p = {
        "router": dense_init(keys[0], d_model, n_experts, dtype=jnp.float32),
        "wi_gate": stack_init(keys[1], d_model, d_expert),
        "wi_up": stack_init(keys[2], d_model, d_expert),
        "wo": stack_init(keys[3], d_expert, d_model),
    }
    if n_shared:
        ds = d_shared or d_expert * n_shared
        p["shared"] = layers.init_mlp(jax.random.fold_in(key, 7), d_model, ds)
    return p


def _router(p, x, top_k):
    """(B,S,D) -> gates (N,K), experts (N,K), aux loss; N = B*S."""
    b, s, d = x.shape
    n = b * s
    logits = x.reshape(n, d).astype(jnp.float32) @ p["router"]  # (N,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)
    e = probs.shape[-1]
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32), axis=1), axis=0
    ) / top_k
    imp = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * imp)
    return gate_vals, gate_idx, aux


def _expert_mlp(p, xe, quant_mode):
    """xe (E, C, D) -> (E, C, D): per-expert SwiGLU, batched einsum over E.

    Experts shard over the data axis (EP): the dispatch scatter/gather below
    becomes the all-to-all pair; constraints pin that layout."""
    from repro.sharding import act

    xe = act.constrain(xe, "dp", None, None)
    xc = xe.astype(COMPUTE_DTYPE)
    g = jnp.einsum("ecd,edf->ecf", xc, p["wi_gate"].astype(COMPUTE_DTYPE))
    u = jnp.einsum("ecd,edf->ecf", xc, p["wi_up"].astype(COMPUTE_DTYPE))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(COMPUTE_DTYPE) * u
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(COMPUTE_DTYPE))
    return act.constrain(out, "dp", None, None)


# expert banks smaller than this (bytes, at bf16 after TP) dispatch with the
# grouped local-capacity scheme — zero cross-shard token movement (§Perf #8)
GROUPED_BANK_BYTES = 4e9

# test hook: force one dispatch implementation everywhere (e.g. 'dense' for
# exactness checks — capacity dropping is batch-composition-dependent by
# design, so dropping paths are not bitwise prefill/decode-consistent)
FORCE_IMPL: str | None = None


def apply_moe(p: dict, x: jax.Array, *, top_k: int, quant_mode: str = "none",
              capacity_factor: float = 1.25, impl: str = "auto"
              ) -> tuple[jax.Array, jax.Array]:
    """x (B,S,D) -> (out, aux_loss)."""
    if FORCE_IMPL is not None:
        impl = FORCE_IMPL
    if impl == "auto":
        from repro.sharding import act

        e_, d_, f_ = p["wi_gate"].shape[-3:]
        bank = 3 * e_ * d_ * f_ * 2 / max(act.axis_size("tp") or 1, 1)
        dp = act.axis_size("dp")
        impl = "grouped" if (dp and bank <= GROUPED_BANK_BYTES) else "dropping"
    if impl == "dense":
        return _apply_moe_dense(p, x, top_k=top_k, quant_mode=quant_mode)
    if impl == "grouped":
        return _apply_moe_grouped(p, x, top_k=top_k, quant_mode=quant_mode,
                                  capacity_factor=capacity_factor)
    b, s, d = x.shape
    n = b * s
    e = p["router"].shape[1]
    gate_vals, gate_idx, aux = _router(p, x, top_k)
    xf = x.reshape(n, d)

    cap = max(1, int(n * top_k / e * capacity_factor))

    # position-in-expert for each (token, slot), processed slot-major so
    # earlier slots win capacity (standard tokens-choose priority).
    pos_list, keep_list = [], []
    counts = jnp.zeros((e,), jnp.int32)
    for k in range(top_k):
        onehot = jax.nn.one_hot(gate_idx[:, k], e, dtype=jnp.int32)  # (N,E)
        pos_within = jnp.cumsum(onehot, axis=0) - 1  # (N,E)
        pos = jnp.take_along_axis(
            pos_within, gate_idx[:, k : k + 1], axis=1
        )[:, 0] + counts[gate_idx[:, k]]
        counts = counts + jnp.sum(onehot, axis=0)
        keep = pos < cap
        pos_list.append(jnp.where(keep, pos, 0))
        keep_list.append(keep)

    # scatter tokens into expert buckets
    xe = jnp.zeros((e, cap, d), COMPUTE_DTYPE)
    for k in range(top_k):
        contrib = (xf * keep_list[k][:, None]).astype(COMPUTE_DTYPE)
        xe = xe.at[gate_idx[:, k], pos_list[k]].add(contrib)

    he = _expert_mlp(p, xe, quant_mode)  # (E,C,D)

    # gather back, gate-weighted
    out = jnp.zeros((n, d), jnp.float32)
    for k in range(top_k):
        yk = he[gate_idx[:, k], pos_list[k]].astype(jnp.float32)
        out = out + yk * (gate_vals[:, k] * keep_list[k])[:, None]

    out = out.reshape(b, s, d).astype(x.dtype)
    if "shared" in p:
        out = out + layers.apply_mlp(p["shared"], x, quant_mode).astype(x.dtype)
    return out, aux


def _apply_moe_grouped(p: dict, x: jax.Array, *, top_k: int,
                       quant_mode: str = "none",
                       capacity_factor: float = 1.25
                       ) -> tuple[jax.Array, jax.Array]:
    """Grouped local-capacity dispatch (fine-grained MoE, small expert bank).

    Tokens are viewed as (G, N/G) with G = the DP group count; positions-in-
    expert are computed *within each group*, so the scatter into the
    (G, E, Cg, D) buffer never crosses the token's own shard.  Expert
    weights shard only inside the expert (TP on F) — the whole bank is
    resident per DP shard, like PSCNN keeping the full model on-chip — so
    the only collective left is the tiny per-layer wo psum.  Requires
    bank/TP <= GROUPED_BANK_BYTES (deepseek-moe: ~2 GB; llama4/jamba keep
    expert-parallel 'dropping').
    """
    from repro.sharding import act

    b, s, d = x.shape
    n = b * s
    e = p["router"].shape[1]
    g = act.axis_size("dp") or 1
    if n % g:
        g = 1
    ng = n // g
    gate_vals, gate_idx, aux = _router(p, x, top_k)
    xg = x.reshape(g, ng, d)
    xg = act.constrain(xg, "dp", None, None)
    idx_g = gate_idx.reshape(g, ng, top_k)
    val_g = gate_vals.reshape(g, ng, top_k)

    cap = max(1, int(ng * top_k / e * capacity_factor))
    pos_list, keep_list = [], []
    counts = jnp.zeros((g, e), jnp.int32)
    for k in range(top_k):
        onehot = jax.nn.one_hot(idx_g[:, :, k], e, dtype=jnp.int32)  # (G,Ng,E)
        pos_within = jnp.cumsum(onehot, axis=1) - 1
        pos = jnp.take_along_axis(
            pos_within, idx_g[:, :, k:k + 1], axis=2
        )[:, :, 0] + jnp.take_along_axis(
            counts[:, None].repeat(ng, 1), idx_g[:, :, k:k + 1], axis=2
        )[:, :, 0]
        counts = counts + jnp.sum(onehot, axis=1)
        keep = pos < cap
        pos_list.append(jnp.where(keep, pos, 0))
        keep_list.append(keep)

    # Dispatch via a tiny int32 slot->token table: scattering the *indices*
    # (G,E,C int32, ~2MB) instead of the activations avoids GSPMD lowering
    # the token scatter as a full fp32 psum of the (G,E,C,D) buffer —
    # the 4GB x 11/layer all-reduce that dominated the baseline (§Perf #8).
    garange = jnp.arange(g)[:, None]
    slot_tok = jnp.zeros((g, e, cap), jnp.int32)
    slot_keep = jnp.zeros((g, e, cap), jnp.bool_)
    tok_ids = jnp.broadcast_to(jnp.arange(ng)[None], (g, ng))
    for k in range(top_k):
        kmask = keep_list[k]
        slot_tok = slot_tok.at[garange, idx_g[:, :, k], pos_list[k]].max(
            jnp.where(kmask, tok_ids, 0)
        )
        slot_keep = slot_keep.at[garange, idx_g[:, :, k], pos_list[k]].max(
            kmask
        )
    # gather tokens into buckets — group-aligned, no cross-shard movement
    xe = xg[garange[:, :, None], slot_tok].astype(COMPUTE_DTYPE)
    xe = xe * slot_keep[..., None]
    xe = act.constrain(xe, "dp", None, None, None)

    xc = xe  # (G,E,C,D)
    gmat = jnp.einsum("gecd,edf->gecf", xc, p["wi_gate"].astype(COMPUTE_DTYPE))
    umat = jnp.einsum("gecd,edf->gecf", xc, p["wi_up"].astype(COMPUTE_DTYPE))
    hmat = jax.nn.silu(gmat.astype(jnp.float32)).astype(COMPUTE_DTYPE) * umat
    he = jnp.einsum("gecf,efd->gecd", hmat, p["wo"].astype(COMPUTE_DTYPE))
    he = act.constrain(he, "dp", None, None, None)

    out = jnp.zeros((g, ng, d), jnp.float32)
    for k in range(top_k):
        yk = he[garange, idx_g[:, :, k], pos_list[k]].astype(jnp.float32)
        out = out + yk * (val_g[:, :, k] * keep_list[k])[..., None]
    out = out.reshape(b, s, d).astype(x.dtype)
    if "shared" in p:
        out = out + layers.apply_mlp(p["shared"], x, quant_mode).astype(x.dtype)
    return out, aux


def _apply_moe_dense(p: dict, x: jax.Array, *, top_k: int,
                     quant_mode: str = "none") -> tuple[jax.Array, jax.Array]:
    """Reference: run every expert on every token, mask with combine weights.

    Exact (no token dropping); used by tests to validate the dropping path.
    """
    b, s, d = x.shape
    e = p["router"].shape[1]
    gate_vals, gate_idx, aux = _router(p, x, top_k)
    combine = jnp.sum(
        jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)
        * gate_vals[..., None],
        axis=1,
    ).reshape(b, s, e)

    xc = x.astype(COMPUTE_DTYPE)
    g = jnp.einsum("bsd,edf->ebsf", xc, p["wi_gate"].astype(COMPUTE_DTYPE))
    u = jnp.einsum("bsd,edf->ebsf", xc, p["wi_up"].astype(COMPUTE_DTYPE))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(COMPUTE_DTYPE) * u
    eo = jnp.einsum("ebsf,efd->ebsd", h, p["wo"].astype(COMPUTE_DTYPE))
    out = jnp.einsum("ebsd,bse->bsd", eo, combine.astype(COMPUTE_DTYPE))
    if "shared" in p:
        out = out + layers.apply_mlp(p["shared"], xc, quant_mode)
    return out.astype(x.dtype), aux
