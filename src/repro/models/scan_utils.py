"""Shared scan wrapper with a global unroll switch.

``SCAN_UNROLL`` is flipped ONLY by the dry-run cost-extraction pass: XLA's
cost_analysis() does not multiply while-loop bodies by trip count, so costs
are measured on reduced-depth lowerings with every *structural* scan (layer
stacks, attention/SSM chunk loops, loss chunks) fully unrolled, then
extrapolated.  Per-token scans (sLSTM) stay rolled — their cost is added
analytically (launch/dryrun.py).
"""
from __future__ import annotations

import jax

SCAN_UNROLL = False


def maybe_unrolled_scan(f, init, xs, length=None):
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if SCAN_UNROLL else 1)
