"""Recurrent blocks: xLSTM (mLSTM + sLSTM, arXiv:2405.04517) and Mamba (S6).

All three expose the same three entry points used by the stacks:
  *_train  : full-sequence (parallel/chunked where the math allows)
  *_prefill: full-sequence + final recurrent state (for long-context serve)
  *_step   : O(1) single-token state update (decode; the reason these archs
             run the long_500k shape that full attention cannot)

mLSTM uses the chunkwise-parallel linear-attention formulation (matrix
state C = sum_t f..f i_t v_t k_t^T), sLSTM is strictly sequential (lax.scan),
Mamba uses an associative-scan over the diagonal SSM recurrence.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, dense_init
from repro.models.scan_utils import maybe_unrolled_scan

# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM): linear attention with scalar forget/input gates
# ---------------------------------------------------------------------------


def init_mlstm(key, d_model: int, n_heads: int, expand: int = 2) -> dict:
    d_inner = expand * d_model
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d_model, 2 * d_inner),   # x and gate branch
        "w_qkv": dense_init(ks[1], d_inner, 3 * d_inner),
        "w_if": dense_init(ks[2], d_inner, 2 * n_heads, dtype=jnp.float32),
        "w_out": dense_init(ks[3], d_inner, d_model),
        "skip_gamma": jnp.ones((d_inner,), jnp.float32),
    }


def _mlstm_gates(p, xi, n_heads):
    """xi (B,S,Di) -> per-head log input/forget gates (B,S,H) fp32."""
    g = xi.astype(jnp.float32) @ p["w_if"]  # (B,S,2H)
    i_log = g[..., :n_heads]                     # log-space input gate
    f_log = jax.nn.log_sigmoid(g[..., n_heads:])  # forget in (0,1)
    return i_log, f_log


def _mlstm_scan(q, k, v, i_log, f_log):
    """Recurrent reference: per-step state C (B,H,Dk,Dv), n (B,H,Dk).

    Stabilized with a running max m_t (xLSTM eq. 15-19).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]

    def step(carry, t):
        c, n, m = carry
        qt, kt, vt = q[:, t], k[:, t], v[:, t]
        il, fl = i_log[:, t], f_log[:, t]  # (B,H)
        m_new = jnp.maximum(fl + m, il)
        c = c * jnp.exp(fl + m - m_new)[..., None, None] + jnp.exp(
            il - m_new
        )[..., None, None] * (kt[..., :, None] * vt[..., None, :])
        n = n * jnp.exp(fl + m - m_new)[..., None] + jnp.exp(il - m_new)[..., None] * kt
        denom = jnp.maximum(
            jnp.abs(jnp.sum(n * qt, axis=-1)), jnp.exp(-m_new)
        )  # (B,H)
        out = jnp.einsum("bhk,bhkv->bhv", qt, c) / denom[..., None]
        return (c, n, m_new), out

    c0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    n0 = jnp.zeros((b, h, dk), jnp.float32)
    m0 = jnp.zeros((b, h), jnp.float32)
    (cT, nT, mT), outs = jax.lax.scan(step, (c0, n0, m0), jnp.arange(s))
    return jnp.moveaxis(outs, 0, 1), (cT, nT, mT)  # (B,S,H,Dv)


def _mlstm_chunk_parallel(q, k, v, i_log, f_log, chunk: int = 256):
    """Chunkwise-parallel mLSTM (GLA-style): intra-chunk quadratic matmuls +
    inter-chunk matrix-state recurrence.  O(S*c) memory instead of O(S*dk^2)
    — required to train/prefill 32k+ sequences (DESIGN.md §3).

    All in fp32 with running-max stabilization (xLSTM eq. 15-19 lifted to
    chunk granularity).  Matches `_mlstm_scan` to float tolerance.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    if s % c:
        c = s
    n_chunks = s // c

    def resh(t):
        return jnp.moveaxis(t.reshape(b, n_chunks, c, *t.shape[2:]), 1, 0)

    qs, ks, vs = resh(q), resh(k), resh(v)               # (Nc,B,c,H,dk)
    ii, ff = resh(i_log), resh(f_log)                    # (Nc,B,c,H)

    tri = jnp.tril(jnp.ones((c, c), bool))
    tri_strict = jnp.tril(jnp.ones((c, c), bool), k=-1)

    def chunk_step(carry, xs):
        C_in, n_in, m_in = carry           # (B,H,dk,dv), (B,H,dk), (B,H)
        qc, kc, vc, ic, fc = xs            # (B,c,H,*) / (B,c,H)
        F = jnp.cumsum(fc, axis=1)         # inclusive logsum of forgets
        T = F[:, -1]                       # (B,H)
        # log-weights
        a = F + m_in[:, None]                          # inter, per t
        w = F[:, :, None] - F[:, None, :] + ic[:, None]  # (B,t,s,H)
        w = jnp.where(tri[None, :, :, None], w, -jnp.inf)
        u = T[:, None] - F + ic                        # state update, per s
        # per-position stabilizer
        m_intra = jnp.max(w, axis=2)                   # (B,t,H)
        m_t = jnp.maximum(a, m_intra)                  # (B,t,H)
        inter_w = jnp.exp(a - m_t)                     # (B,t,H)
        intra = jnp.exp(w - m_t[:, :, None])           # (B,t,s,H)
        intra = jnp.where(tri[None, :, :, None], intra, 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc) * intra
        out = jnp.einsum("btsh,bshv->bthv", scores, vc)
        out = out + inter_w[..., None] * jnp.einsum("bthd,bhdv->bthv", qc, C_in)
        nvec = jnp.einsum("btsh,bshd->bthd", intra, kc)
        nvec = nvec + inter_w[..., None] * n_in[:, None]
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bthd,bthd->bth", qc, nvec)), jnp.exp(-m_t)
        )
        out = out / denom[..., None]
        # carry update
        m_out = jnp.maximum(m_in + T, jnp.max(u, axis=1))
        su = jnp.exp(u - m_out[:, None])               # (B,s,H)
        C_out = jnp.exp(m_in + T - m_out)[:, :, None, None] * C_in + jnp.einsum(
            "bshd,bshv->bhdv", su[..., None] * kc, vc
        )
        n_out = jnp.exp(m_in + T - m_out)[:, :, None] * n_in + jnp.einsum(
            "bsh,bshd->bhd", su, kc
        )
        return (C_out, n_out, m_out), out

    C0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    n0 = jnp.zeros((b, h, dk), jnp.float32)
    m0 = jnp.zeros((b, h), jnp.float32)
    (Ct, nt, mt), outs = maybe_unrolled_scan(
        jax.checkpoint(chunk_step, prevent_cse=False),
        (C0, n0, m0), (qs, ks, vs, ii, ff),
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, dv)
    return out, (Ct, nt, mt)


def mlstm_train(p, x, *, n_heads: int, expand: int = 2):
    out, _ = mlstm_prefill(p, x, n_heads=n_heads, expand=expand)
    return out


def mlstm_prefill(p, x, *, n_heads: int, expand: int = 2, chunk: int = 256):
    b, s, d = x.shape
    d_inner = expand * d
    up = (x.astype(COMPUTE_DTYPE) @ p["w_up"].astype(COMPUTE_DTYPE))
    xi, zg = up[..., :d_inner], up[..., d_inner:]
    qkv = xi @ p["w_qkv"].astype(COMPUTE_DTYPE)
    dk = d_inner // n_heads
    q, k, v = [
        t.reshape(b, s, n_heads, dk).astype(jnp.float32)
        for t in jnp.split(qkv, 3, axis=-1)
    ]
    q = q / math.sqrt(dk)
    i_log, f_log = _mlstm_gates(p, xi, n_heads)
    h, state = _mlstm_chunk_parallel(q, k, v, i_log, f_log, chunk=chunk)
    h = h.reshape(b, s, d_inner).astype(COMPUTE_DTYPE)
    h = h * jax.nn.silu(zg.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    return (h @ p["w_out"].astype(COMPUTE_DTYPE)), state


def mlstm_prefill_sequential(p, x, *, n_heads: int, expand: int = 2):
    """Step-by-step reference (tests validate the chunked path against it)."""
    b, s, d = x.shape
    d_inner = expand * d
    up = (x.astype(COMPUTE_DTYPE) @ p["w_up"].astype(COMPUTE_DTYPE))
    xi, zg = up[..., :d_inner], up[..., d_inner:]
    qkv = xi @ p["w_qkv"].astype(COMPUTE_DTYPE)
    dk = d_inner // n_heads
    q, k, v = [
        t.reshape(b, s, n_heads, dk).astype(jnp.float32)
        for t in jnp.split(qkv, 3, axis=-1)
    ]
    q = q / math.sqrt(dk)
    i_log, f_log = _mlstm_gates(p, xi, n_heads)
    h, state = _mlstm_scan(q, k, v, i_log, f_log)
    h = h.reshape(b, s, d_inner).astype(COMPUTE_DTYPE)
    h = h * jax.nn.silu(zg.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    return (h @ p["w_out"].astype(COMPUTE_DTYPE)), state


def mlstm_step(p, x, state, *, n_heads: int, expand: int = 2):
    """x (B,1,D) + state -> (out (B,1,D), new state).  O(1) in context."""
    out, (c, n, m) = _mlstm_step_inner(p, x, state, n_heads, expand)
    return out, (c, n, m)


def _mlstm_step_inner(p, x, state, n_heads, expand):
    b, _, d = x.shape
    d_inner = expand * d
    up = x.astype(COMPUTE_DTYPE) @ p["w_up"].astype(COMPUTE_DTYPE)
    xi, zg = up[..., :d_inner], up[..., d_inner:]
    qkv = xi @ p["w_qkv"].astype(COMPUTE_DTYPE)
    dk = d_inner // n_heads
    q, k, v = [
        t.reshape(b, 1, n_heads, dk).astype(jnp.float32)
        for t in jnp.split(qkv, 3, axis=-1)
    ]
    q = q / math.sqrt(dk)
    i_log, f_log = _mlstm_gates(p, xi, n_heads)
    c, n, m = state
    il, fl = i_log[:, 0], f_log[:, 0]
    m_new = jnp.maximum(fl + m, il)
    c = c * jnp.exp(fl + m - m_new)[..., None, None] + jnp.exp(il - m_new)[
        ..., None, None
    ] * (k[:, 0][..., :, None] * v[:, 0][..., None, :])
    n = n * jnp.exp(fl + m - m_new)[..., None] + jnp.exp(il - m_new)[..., None] * k[:, 0]
    denom = jnp.maximum(jnp.abs(jnp.sum(n * q[:, 0], -1)), jnp.exp(-m_new))
    out = jnp.einsum("bhk,bhkv->bhv", q[:, 0], c) / denom[..., None]
    h = out.reshape(b, 1, d_inner).astype(COMPUTE_DTYPE)
    h = h * jax.nn.silu(zg.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    return h @ p["w_out"].astype(COMPUTE_DTYPE), (c, n, m_new)


def mlstm_init_state(batch: int, d_model: int, n_heads: int, expand: int = 2):
    d_inner = expand * d_model
    dk = d_inner // n_heads
    return (
        jnp.zeros((batch, n_heads, dk, dk), jnp.float32),
        jnp.zeros((batch, n_heads, dk), jnp.float32),
        jnp.zeros((batch, n_heads), jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with exponential gating)
# ---------------------------------------------------------------------------


def init_slstm(key, d_model: int, n_heads: int) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], d_model, 4 * d_model, dtype=jnp.float32),
        "r_in": dense_init(ks[1], d_model, 4 * d_model, dtype=jnp.float32),
        "w_out": dense_init(ks[2], d_model, d_model),
    }


def slstm_init_state(batch: int, d_model: int):
    z = jnp.zeros((batch, d_model), jnp.float32)
    return (z, z, z + 1e-6, z)  # c, n, m... (c, h, n, m)


def _slstm_cell(p, xt, state, d):
    c, h, n, m = state
    pre = xt.astype(jnp.float32) @ p["w_in"] + h @ p["r_in"]  # (B,4D)
    zi, ii, fi, oi = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    i_log, f_log = ii, jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(f_log + m, i_log)
    c = c * jnp.exp(f_log + m - m_new) + jnp.exp(i_log - m_new) * z
    n = n * jnp.exp(f_log + m - m_new) + jnp.exp(i_log - m_new)
    h_new = o * c / jnp.maximum(n, 1e-6)
    return (c, h_new, n, m_new), h_new


def slstm_prefill(p, x, *, n_heads: int = 0):
    b, s, d = x.shape
    state = slstm_init_state(b, d)

    def step(carry, xt):
        return _slstm_cell(p, xt, carry, d)

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(x, 0, 1))
    out = jnp.moveaxis(hs, 0, 1).astype(COMPUTE_DTYPE) @ p["w_out"].astype(
        COMPUTE_DTYPE
    )
    return out, state


def slstm_train(p, x, *, n_heads: int = 0):
    return slstm_prefill(p, x)[0]


def slstm_step(p, x, state, *, n_heads: int = 0):
    b, _, d = x.shape
    state, h = _slstm_cell(p, x[:, 0], state, d)
    return (h.astype(COMPUTE_DTYPE) @ p["w_out"].astype(COMPUTE_DTYPE))[:, None], state


# ---------------------------------------------------------------------------
# Mamba (S6 selective SSM) — jamba's recurrent layer
# ---------------------------------------------------------------------------


def init_mamba(key, d_model: int, d_state: int = 16, d_conv: int = 4,
               expand: int = 2, dt_rank: int | None = None) -> dict:
    d_inner = expand * d_model
    dt_rank = dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], d_model, 2 * d_inner),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner), jnp.float32) * 0.1),
        "w_x": dense_init(ks[2], d_inner, dt_rank + 2 * d_state),
        "w_dt": dense_init(ks[3], dt_rank, d_inner, dtype=jnp.float32),
        "a_log": jnp.log(
            jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, 1))
        ),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "w_out": dense_init(ks[5], d_inner, d_model),
    }


def _mamba_ssm_scan(u, dt, a, b_in, c_in, d_skip, chunk: int = 256):
    """Selective scan: chunked associative_scan.

    u (B,S,Di); dt (B,S,Di); a (Di,N); b_in/c_in (B,S,N) -> y (B,S,Di).

    The (B,S,Di,N) decay tensor of a full-length associative scan would be
    catastrophic at 32k+ (DESIGN.md §3); chunking bounds the materialized
    tensor to (B,chunk,Di,N) and carries the (B,Di,N) state across chunks
    via the scan's cumulative-product term.
    """
    b, s, di = u.shape
    n = b_in.shape[-1]
    c = min(chunk, s)
    if s % c:
        c = s
    nc = s // c

    def resh(t):
        return jnp.moveaxis(t.reshape(b, nc, c, *t.shape[2:]), 1, 0)

    us, dts, bs, cs = resh(u), resh(dt), resh(b_in), resh(c_in)

    def combine(l, r):
        al, xl = l
        ar, xr = r
        return al * ar, xr + ar * xl

    def chunk_step(state, xs):
        u_c, dt_c, b_c, c_c = xs
        da = jnp.exp(dt_c[..., None] * a[None, None])     # (B,c,Di,N)
        x_in = dt_c[..., None] * b_c[:, :, None, :] * u_c[..., None]
        cumA, cumX = jax.lax.associative_scan(combine, (da, x_in), axis=1)
        xs_full = cumX + cumA * state[:, None]
        y = jnp.einsum("bcdn,bcn->bcd", xs_full, c_c)
        return xs_full[:, -1], y

    state0 = jnp.zeros((b, di, n), jnp.float32)
    state, ys = maybe_unrolled_scan(
        jax.checkpoint(chunk_step, prevent_cse=False), state0,
        (us, dts, bs, cs),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, di)
    return y + u * d_skip[None, None], state


def mamba_prefill(p, x, *, d_state: int = 16, d_conv: int = 4, expand: int = 2):
    b, s, d = x.shape
    d_inner = expand * d
    up = x.astype(COMPUTE_DTYPE) @ p["w_in"].astype(COMPUTE_DTYPE)
    xi, zg = up[..., :d_inner], up[..., d_inner:]
    xi = xi.astype(jnp.float32)
    # depthwise causal conv (d_conv taps)
    xpad = jnp.pad(xi, ((0, 0), (d_conv - 1, 0), (0, 0)))
    conv = sum(
        xpad[:, t : t + s] * p["conv_w"][t][None, None] for t in range(d_conv)
    )
    xc = jax.nn.silu(conv)
    proj = xc.astype(COMPUTE_DTYPE) @ p["w_x"].astype(COMPUTE_DTYPE)
    dt_rank = p["w_dt"].shape[0]
    dt_r, b_in, c_in = (
        proj[..., :dt_rank].astype(jnp.float32),
        proj[..., dt_rank : dt_rank + d_state].astype(jnp.float32),
        proj[..., dt_rank + d_state :].astype(jnp.float32),
    )
    dt = jax.nn.softplus(dt_r @ p["w_dt"])
    a = -jnp.exp(p["a_log"])
    y, ssm_state = _mamba_ssm_scan(xc, dt, a, b_in, c_in, p["d_skip"])
    y = y.astype(COMPUTE_DTYPE) * jax.nn.silu(zg.astype(jnp.float32)).astype(
        COMPUTE_DTYPE
    )
    out = y @ p["w_out"].astype(COMPUTE_DTYPE)
    # decode state: final ssm state (B,Di,N) + causal-conv tail (B,d_conv-1,Di)
    conv_tail = jnp.pad(xi, ((0, 0), (d_conv - 1, 0), (0, 0)))[:, -(d_conv - 1):]
    return out, (ssm_state, conv_tail)


def mamba_train(p, x, *, d_state: int = 16, d_conv: int = 4, expand: int = 2):
    return mamba_prefill(p, x, d_state=d_state, d_conv=d_conv, expand=expand)[0]


def mamba_init_state(batch: int, d_model: int, d_state: int = 16,
                     d_conv: int = 4, expand: int = 2):
    d_inner = expand * d_model
    return (
        jnp.zeros((batch, d_inner, d_state), jnp.float32),
        jnp.zeros((batch, d_conv - 1, d_inner), jnp.float32),
    )


def mamba_step(p, x, state, *, d_state: int = 16, d_conv: int = 4,
               expand: int = 2):
    """Single-token Mamba update: O(1) state, the long-context decode path."""
    b, _, d = x.shape
    d_inner = expand * d
    ssm_state, conv_tail = state  # (B,Di,N), (B,d_conv-1,Di)
    up = x.astype(COMPUTE_DTYPE) @ p["w_in"].astype(COMPUTE_DTYPE)
    xi, zg = up[..., :d_inner], up[..., d_inner:]
    xi = xi.astype(jnp.float32)  # (B,1,Di)
    window = jnp.concatenate([conv_tail, xi], axis=1)  # (B,d_conv,Di)
    conv = jnp.einsum("btd,td->bd", window, p["conv_w"])
    xc = jax.nn.silu(conv)  # (B,Di)
    proj = xc.astype(COMPUTE_DTYPE) @ p["w_x"].astype(COMPUTE_DTYPE)
    dt_rank = p["w_dt"].shape[0]
    dt = jax.nn.softplus(proj[..., :dt_rank].astype(jnp.float32) @ p["w_dt"])
    b_in = proj[..., dt_rank : dt_rank + d_state].astype(jnp.float32)
    c_in = proj[..., dt_rank + d_state :].astype(jnp.float32)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt[..., None] * a[None])          # (B,Di,N)
    ssm_state = ssm_state * da + dt[..., None] * b_in[:, None, :] * xc[..., None]
    y = jnp.einsum("bdn,bn->bd", ssm_state, c_in) + xc * p["d_skip"][None]
    y = y.astype(COMPUTE_DTYPE) * jax.nn.silu(
        (zg[:, 0]).astype(jnp.float32)
    ).astype(COMPUTE_DTYPE)
    out = (y @ p["w_out"].astype(COMPUTE_DTYPE))[:, None]
    return out, (ssm_state, window[:, 1:])
