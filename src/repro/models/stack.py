"""Unified decoder stack covering dense / MoE / SSM / hybrid / VLM families.

One code path builds all assigned architectures from ArchConfig:

  * the model is ``first_k_dense`` explicit layers + N repeats of a
    *superblock* (a fixed heterogeneous pattern, e.g. jamba's
    [m,m,m,a,m,m,m,m]), scanned with ``jax.lax.scan`` over stacked per-repeat
    parameters — HLO size stays O(superblock), not O(depth), which is what
    makes 62/72-layer models lowerable for 512 partitions (DESIGN.md §3).
  * three entry points per family: full-sequence forward (train), prefill
    (forward + cache/state export), and single-token decode (cache/state
    update) — the three lowering targets of the dry-run matrix.

Mixer codes: 'a' attention, 'm' mamba, 'M' mLSTM, 's' sLSTM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import layers, moe, ssm
from repro.models.layers import COMPUTE_DTYPE, dense_init, embed_init

AUX_LOSS_COEF = 0.01

from repro.models.scan_utils import maybe_unrolled_scan as _scan  # noqa: E402


# ---------------------------------------------------------------------------
# Structure helpers
# ---------------------------------------------------------------------------

def block_structure(cfg: ArchConfig) -> list[tuple[str, str]]:
    """(mixer, ffn) per superblock position (offset past first_k_dense)."""
    first_k = cfg.moe.first_k_dense if cfg.moe else 0
    pattern = cfg.superblock or ("a",)
    return [cfg.layer_kind(first_k + pos) for pos in range(len(pattern))]


def n_repeats(cfg: ArchConfig) -> int:
    first_k = cfg.moe.first_k_dense if cfg.moe else 0
    pattern = cfg.superblock or ("a",)
    scanned = cfg.n_layers - first_k
    assert scanned % len(pattern) == 0, (cfg.name, scanned, len(pattern))
    return scanned // len(pattern)


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def _init_layer(cfg: ArchConfig, key, mixer: str, ffn: str) -> dict:
    k_mix, k_ffn, k_n = jax.random.split(key, 3)
    p: dict = {"norm1": jnp.ones((cfg.d_model,), jnp.float32)}
    if mixer == "a":
        p["attn"] = attn.init_attention(
            k_mix, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qk_norm=cfg.qk_norm,
        )
    elif mixer == "m":
        p["mamba"] = ssm.init_mamba(
            k_mix, cfg.d_model, d_state=cfg.d_state, expand=cfg.ssm_expand
        )
    elif mixer == "M":
        p["mlstm"] = ssm.init_mlstm(k_mix, cfg.d_model, cfg.n_heads,
                                    expand=cfg.ssm_expand)
    elif mixer == "s":
        p["slstm"] = ssm.init_slstm(k_mix, cfg.d_model, cfg.n_heads)
    else:
        raise ValueError(mixer)

    if ffn == "dense":
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["mlp"] = layers.init_mlp(k_ffn, cfg.d_model, cfg.d_ff)
    elif ffn == "moe":
        m = cfg.moe
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["moe"] = moe.init_moe(
            k_ffn, cfg.d_model, m.d_expert, m.n_experts,
            n_shared=m.n_shared, d_shared=m.d_shared or None,
        )
    return p


def _apply_mixer(cfg: ArchConfig, p: dict, h, mixer: str, mode: str,
                 cache=None, cache_len=None):
    """Returns (out, new_cache_or_state).  Cache semantics per mode:
    train -> None; prefill -> exported; decode -> updated."""
    kw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
              d_head=cfg.head_dim, rope_theta=cfg.rope_theta,
              quant_mode=cfg.quant_mode)
    if mixer == "a":
        if mode == "train":
            return attn.attention_train(p["attn"], h, **kw), None
        if mode == "prefill":
            return attn.attention_prefill(p["attn"], h, **kw)
        return attn.attention_decode(p["attn"], h, cache, cache_len, **kw)
    if mixer == "m":
        skw = dict(d_state=cfg.d_state, expand=cfg.ssm_expand)
        if mode == "train":
            return ssm.mamba_train(p["mamba"], h, **skw), None
        if mode == "prefill":
            return ssm.mamba_prefill(p["mamba"], h, **skw)
        return ssm.mamba_step(p["mamba"], h, cache, **skw)
    if mixer == "M":
        skw = dict(n_heads=cfg.n_heads, expand=cfg.ssm_expand)
        if mode == "train":
            return ssm.mlstm_train(p["mlstm"], h, **skw), None
        if mode == "prefill":
            return ssm.mlstm_prefill(p["mlstm"], h, **skw)
        return ssm.mlstm_step(p["mlstm"], h, cache, **skw)
    if mixer == "s":
        if mode == "train":
            return ssm.slstm_train(p["slstm"], h), None
        if mode == "prefill":
            return ssm.slstm_prefill(p["slstm"], h)
        return ssm.slstm_step(p["slstm"], h, cache)
    raise ValueError(mixer)


def apply_layer(cfg: ArchConfig, p: dict, h, mixer: str, ffn: str, mode: str,
                cache=None, cache_len=None):
    """Pre-norm residual layer. Returns (h, new_cache, aux_loss)."""
    from repro.sharding import act

    h = act.constrain(h, "dp", None, None)
    mixed, new_cache = _apply_mixer(
        cfg, p, layers.rmsnorm(h, p["norm1"]), mixer, mode, cache, cache_len
    )
    h = h + mixed
    aux = jnp.zeros((), jnp.float32)
    if ffn == "dense":
        h = h + layers.apply_mlp(p["mlp"], layers.rmsnorm(h, p["norm2"]),
                                 cfg.quant_mode)
    elif ffn == "moe":
        out, aux = moe.apply_moe(
            p["moe"], layers.rmsnorm(h, p["norm2"]),
            top_k=cfg.moe.top_k, quant_mode=cfg.quant_mode,
        )
        h = h + out
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    struct = block_structure(cfg)
    reps = n_repeats(cfg)
    first_k = cfg.moe.first_k_dense if cfg.moe else 0
    keys = jax.random.split(key, 4 + first_k)

    params: dict = {
        "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model),
        "out_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.padded_vocab)

    params["head_layers"] = [
        _init_layer(cfg, keys[4 + i], "a", "dense") for i in range(first_k)
    ]

    # stacked superblock params: per position, leading axis = repeats
    def stack_pos(pos, mixer, ffn):
        ks = jax.random.split(jax.random.fold_in(keys[2], pos), reps)
        ps = [_init_layer(cfg, k, mixer, ffn) for k in ks]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ps)

    params["blocks"] = [
        stack_pos(pos, mixer, ffn) for pos, (mixer, ffn) in enumerate(struct)
    ]
    return params


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ArchConfig, params, tokens, frontend=None):
    from repro.sharding import act

    h = params["embed"][tokens].astype(COMPUTE_DTYPE)
    if frontend is not None:
        h = jnp.concatenate([frontend.astype(COMPUTE_DTYPE), h], axis=1)
    return act.constrain(h, "dp", None, None)


def _logits(cfg: ArchConfig, params, h):
    h = layers.rmsnorm(h, params["out_norm"])
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jax.lax.dot_general(
        h, w.astype(COMPUTE_DTYPE), (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def forward_hidden(cfg: ArchConfig, params, tokens, frontend=None,
                   mode: str = "train", remat: str = "block"):
    """Embed + all blocks, WITHOUT the output projection.

    -> (h, caches, aux): caches only when mode='prefill'."""
    struct = block_structure(cfg)
    h = _embed_inputs(cfg, params, tokens, frontend)
    aux_total = jnp.zeros((), jnp.float32)

    for p in params["head_layers"]:
        h, _, aux = apply_layer(cfg, p, h, "a", "dense", "train")
        aux_total = aux_total + aux

    policy = layers.RematPolicy(remat)

    def superblock(h, rep_params):
        caches = []
        aux_sb = jnp.zeros((), jnp.float32)
        for pos, (mixer, ffn) in enumerate(struct):
            h, cache, aux = apply_layer(
                cfg, rep_params[pos], h, mixer, ffn, mode
            )
            aux_sb = aux_sb + aux
            if mode == "prefill":
                caches.append(cache)
        return h, (tuple(caches), aux_sb)

    sb = policy.wrap(superblock) if mode == "train" else superblock
    h, (caches, auxes) = _scan(
        lambda c, xs: sb(c, xs), h, tuple(params["blocks"])
    )
    aux_total = aux_total + jnp.sum(auxes)
    return h, caches, aux_total


def forward(cfg: ArchConfig, params, tokens, frontend=None,
            mode: str = "train", remat: str = "block"):
    """Full-sequence forward.  mode='prefill' also returns caches/states."""
    h, caches, aux_total = forward_hidden(cfg, params, tokens, frontend,
                                          mode, remat)
    logits = _logits(cfg, params, h)
    if mode == "prefill":
        return logits, caches
    return logits, aux_total


# ---------------------------------------------------------------------------
# Decode (single token, cache/state update)
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int,
                      kv_replication: int = 1) -> dict:
    """Zeroed caches/states, stacked (reps, ...) per superblock position.

    kv_replication=r stores each kv head r times (TP-local GQA attention,
    see attention.attention_decode)."""
    struct = block_structure(cfg)
    reps = n_repeats(cfg)
    state: dict = {"cache_len": jnp.zeros((), jnp.int32), "layers": []}
    hk_eff = cfg.n_kv_heads * kv_replication

    def stacked(shape, dtype=jnp.bfloat16):
        return jnp.zeros((reps, *shape), dtype)

    for mixer, _ in struct:
        if mixer == "a":
            kv = (
                stacked((batch, max_seq, hk_eff, cfg.head_dim)),
                stacked((batch, max_seq, hk_eff, cfg.head_dim)),
            )
            state["layers"].append(kv)
        elif mixer == "m":
            d_inner = cfg.ssm_expand * cfg.d_model
            state["layers"].append(
                (
                    stacked((batch, d_inner, cfg.d_state), jnp.float32),
                    stacked((batch, 3, d_inner), jnp.float32),  # d_conv-1 = 3
                )
            )
        elif mixer == "M":
            d_inner = cfg.ssm_expand * cfg.d_model
            dk = d_inner // cfg.n_heads
            state["layers"].append(
                (
                    stacked((batch, cfg.n_heads, dk, dk), jnp.float32),
                    stacked((batch, cfg.n_heads, dk), jnp.float32),
                    stacked((batch, cfg.n_heads), jnp.float32),
                )
            )
        elif mixer == "s":
            z = stacked((batch, cfg.d_model), jnp.float32)
            state["layers"].append((z, z, z, z))
    # head (unscanned) layers are always attention
    first_k = cfg.moe.first_k_dense if cfg.moe else 0
    state["head"] = [
        (
            jnp.zeros((batch, max_seq, hk_eff, cfg.head_dim), jnp.bfloat16),
            jnp.zeros((batch, max_seq, hk_eff, cfg.head_dim), jnp.bfloat16),
        )
        for _ in range(first_k)
    ]
    return state


def decode_step(cfg: ArchConfig, params, state: dict, tokens):
    """tokens (B,1) -> (logits (B,1,V), new state).  O(1) per step for
    recurrent mixers; O(S) KV attention for 'a' mixers."""
    struct = block_structure(cfg)
    h = _embed_inputs(cfg, params, tokens)
    cache_len = state["cache_len"]

    new_head = []
    for p, cache in zip(params["head_layers"], state["head"]):
        h, c, _ = apply_layer(cfg, p, h, "a", "dense", "decode",
                              cache=cache, cache_len=cache_len)
        new_head.append(c)

    def superblock(h, xs):
        rep_params, rep_caches = xs
        new_caches = []
        for pos, (mixer, ffn) in enumerate(struct):
            h, c, _ = apply_layer(
                cfg, rep_params[pos], h, mixer, ffn, "decode",
                cache=rep_caches[pos], cache_len=cache_len,
            )
            new_caches.append(c)
        return h, tuple(new_caches)

    h, new_layer_caches = _scan(
        superblock, h, (tuple(params["blocks"]), tuple(state["layers"]))
    )
    logits = _logits(cfg, params, h)
    new_state = {
        "cache_len": cache_len + 1,
        "layers": list(new_layer_caches),
        "head": new_head,
    }
    return logits, new_state


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def chunked_ce_loss(project_fn, h, labels, vocab: int, padded_vocab: int,
                    chunk: int = 512) -> jax.Array:
    """Cross-entropy without materializing full (B,S,V) fp32 logits.

    The output projection + log-softmax run per sequence chunk under
    jax.checkpoint, so peak memory holds one (B,chunk,V/TP) logits slab and
    the backward recomputes each chunk (MaxText-style vocab-loss chunking).
    """
    b, s, d = h.shape
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s  # irregular tail: fall back to one chunk
    n = s // chunk
    hc = jnp.moveaxis(h.reshape(b, n, chunk, d), 1, 0)
    yc = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)

    @jax.checkpoint
    def one_chunk(carry, xs):
        h_c, y_c = xs
        logits = project_fn(h_c)  # (B,chunk,V) fp32
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(
            logp, jnp.clip(y_c, 0, padded_vocab - 1)[..., None], axis=-1
        )[..., 0]
        mask = (y_c >= 0) & (y_c < vocab)
        return (
            carry[0] + jnp.sum(nll * mask),
            carry[1] + jnp.sum(mask),
        ), None

    (tot, cnt), _ = _scan(one_chunk, (jnp.zeros(()), jnp.zeros(())), (hc, yc))
    return tot / jnp.maximum(cnt, 1)


def lm_loss(cfg: ArchConfig, params, tokens, labels, frontend=None,
            remat: str = "block", loss_chunk: int = 512):
    """Next-token cross-entropy (labels aligned with tokens positions)."""
    h, _, aux = forward_hidden(cfg, params, tokens, frontend, mode="train",
                               remat=remat)
    if frontend is not None:
        h = h[:, -tokens.shape[1]:]  # loss over text positions only
    loss = chunked_ce_loss(lambda hc: _logits(cfg, params, hc), h, labels,
                           cfg.vocab, cfg.padded_vocab, chunk=loss_chunk)
    return loss + AUX_LOSS_COEF * aux
