"""repro.obs — runtime-wide observability: metrics, traces, events.

The paper's headline results are *cost-accounting* results (Table I
splits every inference into MAC/SA/SRAM/controller energy; the pooling
write-back claim is a latency split), and the ROADMAP's next steps
(async ingest/compute overlap, open-loop SLO harness) are judged by
per-phase hop timing at p99/p999.  This package is the measurement
substrate for all of that, built for the always-on deployment the paper
targets: **every instrument is O(1) memory over unbounded uptime.**

Three planes, one bundle:

* ``MetricsRegistry`` (registry.py) — counters, gauges, fixed-bucket
  log-linear ``Histogram``\\ s (p50..p999 with bounded relative error)
  and exact-while-short ``Reservoir``\\ s, with strict-JSON snapshots.
* ``Tracer`` (trace.py) — lightweight spans over the hop pipeline,
  exported as Chrome trace-event JSON (open in Perfetto), with an
  opt-in ``jax.profiler`` bridge for kernel-level drill-down.
* ``EventLog`` (events.py) — JSONL lifecycle records (join / close /
  resize / rebalance / detection / mass-join) with monotonic
  timestamps, mirrored into ``utils.logging`` behind a per-kind rate
  limit.

``Observability`` glues them together; ``StreamScheduler`` and
``serve.Engine`` accept one via ``obs=`` (and build an enabled default
otherwise, so instrumentation is always on and always bounded).

    >>> from repro.obs import Observability
    >>> obs = Observability.create()
    >>> with obs.trace.span("pack"):
    ...     obs.registry.counter("hops").inc()
    >>> _ = obs.events.emit("join", sid=0)
    >>> obs.registry.snapshot()["hops"]
    1
"""
from __future__ import annotations

import dataclasses

from repro.obs.events import EventLog
from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Reservoir,
)
from repro.obs.trace import Tracer, coverage, overlap_stats


@dataclasses.dataclass
class Observability:
    """One runtime's observability surface: registry + tracer + events."""

    registry: MetricsRegistry
    trace: Tracer
    events: EventLog

    @classmethod
    def create(cls, *, enabled: bool = True, trace_capacity: int = 65536,
               event_path=None, event_capacity: int = 4096,
               jax_profiler: bool = False,
               mirror_events: bool = True) -> "Observability":
        """Build a bundle; ``enabled=False`` keeps the registry (metrics
        stay cheap and bounded) but turns spans into no-ops and stops
        event mirroring — the knob the overhead microbench compares
        against."""
        return cls(
            registry=MetricsRegistry(),
            trace=Tracer(capacity=trace_capacity, enabled=enabled,
                         jax_profiler=jax_profiler),
            events=EventLog(path=event_path, capacity=event_capacity,
                            mirror=enabled and mirror_events),
        )


__all__ = [
    "Counter",
    "EventLog",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Observability",
    "Reservoir",
    "Tracer",
    "coverage",
    "overlap_stats",
]
