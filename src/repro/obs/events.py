"""Structured lifecycle event log: JSONL records, bounded memory.

Latency histograms answer "how fast"; the event log answers "what
happened": every join / close / resize / rebalance / detection /
mass-join lands here as one flat JSON record with a monotonic timestamp
and a process-wide sequence number, so a saturating pool or a rebalance
storm can be reconstructed after the fact without scraping free-text
logs.

Three sinks, independently bounded:

* an in-memory ring (``tail()``) — always on, O(1) memory;
* an optional JSONL file — **every** event is written (the bench
  acceptance requires the artifact to be complete), line-buffered
  append;
* the ``utils.logging`` logger — human-readable mirror, rate-limited
  *per event kind* (``utils.logging.RateLimiter``) so a 1k-stream mass
  join emits 1k JSONL records but only one INFO line (with the
  suppressed count folded into the next line that does get through).
"""
from __future__ import annotations

import collections
import json
import time

from repro.utils.logging import RateLimiter, get_logger

log = get_logger("obs.events")


class EventLog:
    """Append-only structured event sink with a bounded in-memory tail."""

    def __init__(self, path=None, capacity: int = 4096,
                 mirror_interval_s: float = 1.0, mirror: bool = True,
                 mode: str = "a") -> None:
        """``mode="a"`` (default) appends across restarts — the service
        shape; bench artifacts pass ``mode="w"`` so each run's JSONL is
        exactly that run."""
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self._seq = 0
        self._t0 = time.monotonic()
        self._file = (open(path, mode, buffering=1)
                      if path is not None else None)
        self.path = path
        self._mirror = mirror
        self._limiter = RateLimiter(mirror_interval_s)

    @property
    def capacity(self) -> int:
        return self._ring.maxlen or 0

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def seq(self) -> int:
        """Events emitted so far (>= ``len`` once the ring wraps)."""
        return self._seq

    def emit(self, event: str, **fields) -> dict:
        """Record one event; returns the record.  ``ts`` is monotonic
        seconds since the log was created — immune to wall-clock steps,
        and directly comparable with the tracer's span stamps."""
        rec = {
            "ts": time.monotonic() - self._t0,
            "seq": self._seq,
            "event": event,
        }
        rec.update(fields)
        self._seq += 1
        self._ring.append(rec)
        if self._file is not None:
            self._file.write(json.dumps(rec) + "\n")
        if self._mirror:
            ok, suppressed = self._limiter.allow(event)
            if ok:
                extra = f" (+{suppressed} suppressed)" if suppressed else ""
                log.info("%s %s%s", event,
                         " ".join(f"{k}={v}" for k, v in fields.items()),
                         extra)
        return rec

    def tail(self, n: int | None = None) -> list[dict]:
        """The most recent ``n`` retained events (all of them by default)."""
        events = list(self._ring)
        return events if n is None else events[-n:]

    def counts(self) -> dict[str, int]:
        """Retained-tail event-kind histogram (diagnostics, tests)."""
        out: dict[str, int] = {}
        for rec in self._ring:
            out[rec["event"]] = out.get(rec["event"], 0) + 1
        return out

    def flush(self) -> None:
        if self._file is not None:
            self._file.flush()

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None
