"""Bounded metrics registry: counters, gauges, log-linear histograms.

The always-on runtime must report latency quantiles over unbounded
uptime, so every instrument here is O(1) memory regardless of how many
samples it has absorbed:

* ``Counter`` / ``Gauge`` — one scalar each.
* ``Histogram`` — fixed-bucket *log-linear* histogram (HdrHistogram's
  bucket geometry): each power-of-two range ``[2^e, 2^(e+1))`` splits
  into ``lin`` equal sub-buckets, so the worst-case relative quantile
  error is bounded by ``1/lin`` (~3% at the default ``lin=32``) at every
  scale from ``lo`` to ``hi``.  ``count``/``sum``/``min``/``max`` are
  tracked exactly; ``quantile`` interpolates inside the landing bucket.
* ``Reservoir`` — a ring of the *last* ``capacity`` raw samples.  While
  fewer than ``capacity`` samples have been recorded it holds every one
  of them, so short windows (tests, benches) get **exact** percentiles;
  once it wraps, callers fall back to the histogram estimate and label
  it as such (see ``stream/metrics.py``).

``MetricsRegistry`` is a flat name -> instrument namespace with a
JSON-able ``snapshot()`` (strict JSON: empty histograms omit their
quantile fields instead of emitting NaN).
"""
from __future__ import annotations

import json
import math

import numpy as np


class Counter:
    """Monotone event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str = "") -> None:
        self.name = name
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-write-wins instantaneous value (occupancy, capacity, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str = "") -> None:
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self):
        return self.value


class Reservoir:
    """Ring buffer of the last ``capacity`` samples — exact while short.

    ``values()`` returns the retained samples in ring order (order is
    irrelevant to percentiles); ``saturated`` flips once the ring has
    wrapped, i.e. once the retained window no longer covers every sample
    ever recorded.
    """

    __slots__ = ("_data", "count", "capacity")

    def __init__(self, capacity: int = 4096) -> None:
        assert capacity > 0
        self._data = np.zeros(capacity, np.float64)
        self.count = 0
        self.capacity = capacity

    @property
    def saturated(self) -> bool:
        return self.count > self.capacity

    def record(self, v: float) -> None:
        self._data[self.count % self.capacity] = v
        self.count += 1

    def values(self) -> np.ndarray:
        return self._data[: min(self.count, self.capacity)]

    def reset(self) -> None:
        self.count = 0

    @property
    def nbytes(self) -> int:
        return self._data.nbytes


class Histogram:
    """Fixed-bucket log-linear histogram with bounded relative error.

    Bucket ``(e, s)`` covers ``[2^e * (1 + s/lin), 2^e * (1 + (s+1)/lin))``
    for exponents ``e`` spanning ``[lo, hi)``; values outside clamp into
    one underflow and one overflow bucket (tracked, and ``min``/``max``
    stay exact, so clamping is visible).  Memory is a single fixed int64
    count vector — independent of sample count, the property the
    always-on runtime needs.
    """

    __slots__ = ("name", "lin", "_min_exp", "_n_exp", "_lo", "_hi",
                 "_nb", "_counts", "count", "sum", "min", "max")

    def __init__(self, name: str = "", lin: int = 32,
                 lo: float = 1e-7, hi: float = 1e4) -> None:
        assert lin >= 2 and 0 < lo < hi
        self.name = name
        self.lin = lin
        self._min_exp = math.frexp(lo)[1] - 1  # floor(log2(lo))
        self._n_exp = (math.frexp(hi)[1] - 1) - self._min_exp + 1
        self._lo = float(lo)
        self._hi = float(hi)
        # [underflow, body..., overflow]; a plain list keeps the
        # single-sample increment off numpy's scalar-indexing overhead —
        # ``record`` sits on the per-hop hot path
        self._nb = self._n_exp * lin + 2
        self._counts = [0] * self._nb
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # -- recording -----------------------------------------------------------

    def _index(self, v: float) -> int:
        if v < self._lo:
            return 0
        if v >= self._hi:
            return self._nb - 1
        m, e = math.frexp(v)           # v = m * 2^e, m in [0.5, 1)
        sub = int((2.0 * m - 1.0) * self.lin)
        return 1 + (e - 1 - self._min_exp) * self.lin + min(sub, self.lin - 1)

    def record(self, v: float) -> None:
        v = float(v)
        self._counts[self._index(v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def record_many(self, values: np.ndarray) -> None:
        """Vectorized ``record`` for bulk backfill (one ``np.add.at``) —
        how a wrapping ``Reservoir``'s retained window folds in (see
        ``stream/metrics.py``) without ever paying per-sample cost."""
        v = np.asarray(values, np.float64).reshape(-1)
        if v.size == 0:
            return
        m, e = np.frexp(np.clip(v, self._lo, None))
        sub = np.minimum((2.0 * m - 1.0) * self.lin, self.lin - 1).astype(
            np.int64
        )
        idx = 1 + (e - 1 - self._min_exp) * self.lin + sub
        idx = np.where(v < self._lo, 0, idx)
        idx = np.where(v >= self._hi, self._nb - 1, idx)
        binc = np.zeros(self._nb, np.int64)
        np.add.at(binc, idx, 1)
        counts = self._counts
        for i in np.nonzero(binc)[0]:
            counts[i] += int(binc[i])
        self.count += v.size
        self.sum += float(v.sum())
        self.min = min(self.min, float(v.min()))
        self.max = max(self.max, float(v.max()))

    def reset(self) -> None:
        self._counts = [0] * self._nb
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # -- reporting -----------------------------------------------------------

    def _edges(self, i: int) -> tuple[float, float]:
        """[lower, upper) value edges of body bucket index ``i`` (0-based
        within the body, i.e. ``counts`` index ``i + 1``)."""
        e = self._min_exp + i // self.lin
        s = i % self.lin
        base = math.ldexp(1.0, e)
        return base * (1 + s / self.lin), base * (1 + (s + 1) / self.lin)

    def quantile(self, q: float) -> float:
        """Estimated ``q``-quantile (0..1); NaN when empty.

        Interpolates linearly inside the landing bucket, clamped to the
        exact observed ``min``/``max`` so the estimate never leaves the
        recorded range (and under/overflow buckets report those exact
        extremes rather than a fabricated edge).
        """
        if self.count == 0:
            return math.nan
        rank = q * (self.count - 1)
        cum = 0
        for i, c in enumerate(self._counts):
            if c == 0:
                continue
            if rank < cum + c:
                if i == 0:
                    return self.min
                if i == self._nb - 1:
                    return self.max
                vlo, vhi = self._edges(i - 1)
                frac = (rank - cum + 0.5) / c
                est = vlo + (vhi - vlo) * frac
                return min(max(est, self.min), self.max)
            cum += c
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    @property
    def nbytes(self) -> int:
        return 8 * self._nb

    def snapshot(self) -> dict[str, float]:
        """Strict-JSON summary: quantiles appear only when non-empty."""
        out: dict[str, float] = {"count": float(self.count), "sum": self.sum}
        if self.count:
            out.update(
                min=self.min, max=self.max,
                p50=self.quantile(0.50), p95=self.quantile(0.95),
                p99=self.quantile(0.99), p999=self.quantile(0.999),
            )
        return out


class MetricsRegistry:
    """Flat name -> instrument namespace with get-or-create accessors.

    One registry serves a whole runtime (scheduler + engine + benches);
    ``snapshot()`` is a plain dict safe for ``json.dumps(...,
    allow_nan=False)``, the export the bench artifact embeds.
    """

    def __init__(self) -> None:
        self._series: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, cls, **kw):
        inst = self._series.get(name)
        if inst is None:
            inst = self._series[name] = cls(name, **kw)
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(inst).__name__}, not {cls.__name__}"
            )
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kw) -> Histogram:
        return self._get(name, Histogram, **kw)

    def __contains__(self, name: str) -> bool:
        return name in self._series

    def names(self) -> list[str]:
        return sorted(self._series)

    def snapshot(self) -> dict[str, object]:
        return {k: self._series[k].snapshot() for k in self.names()}

    def to_json(self, **kw) -> str:
        kw.setdefault("allow_nan", False)
        return json.dumps(self.snapshot(), **kw)
