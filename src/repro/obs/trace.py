"""Per-hop trace spans with Chrome trace-event export.

The streaming hop is a pipeline (pack -> dispatch -> device -> detector)
and the ROADMAP's async-overlap work will be judged by *where inside the
hop* the time goes, not by one aggregate number.  ``Tracer`` records
lightweight spans into a bounded ring (O(1) memory over unbounded
uptime, same discipline as the metrics registry) and exports them as
Chrome trace-event JSON — load the file at ``ui.perfetto.dev`` (or
``chrome://tracing``) to see every hop's phase breakdown on a timeline.

Two recording APIs:

* ``with tracer.span("pack"):`` — the general context-manager form
  (lifecycle work: resize, rebalance, prime_batch, LM prefill).
* ``tracer.add("pack", t0, dur)`` — raw form for the hop hot path,
  where the caller already holds ``time.perf_counter()`` stamps for the
  metrics phases and a second clock read per phase would be waste.

Timestamps are monotonic (``perf_counter``) relative to the tracer's
epoch, exported in microseconds as the trace-event spec requires.
Consecutive phases share boundary stamps, so the exported spans tile
their parent ``hop`` span exactly (the bench asserts >= 95% coverage).

``jax_profiler=True`` additionally wraps each ``span`` in
``jax.profiler.TraceAnnotation`` so the phase names show up inside a
captured XLA device profile for kernel-level drill-down — opt-in, since
it costs a TraceMe even when no profile is being captured.
"""
from __future__ import annotations

import collections
import contextlib
import json
import threading
import time


class Tracer:
    """Bounded span recorder; disabled mode is a near-free no-op."""

    def __init__(self, capacity: int = 65536, enabled: bool = True,
                 jax_profiler: bool = False,
                 process_name: str = "repro") -> None:
        self.enabled = enabled
        self.process_name = process_name
        self._events: collections.deque = collections.deque(maxlen=capacity)
        self._epoch = time.perf_counter()
        self.dropped = 0  # spans evicted from the ring (uptime > capacity)
        self._jax = None
        if jax_profiler:
            import jax.profiler  # deferred: opt-in only

            self._jax = jax.profiler

    @property
    def capacity(self) -> int:
        return self._events.maxlen or 0

    def __len__(self) -> int:
        return len(self._events)

    # -- recording -----------------------------------------------------------

    def add(self, name: str, t0: float, dur_s: float, **args) -> None:
        """Record a completed span: ``t0`` is a ``time.perf_counter()``
        stamp, ``dur_s`` its duration.  One deque append — cheap enough
        for several calls per hop (the bench pins overhead <= 2% of hop
        p50)."""
        if not self.enabled:
            return
        ev = self._events
        if len(ev) == ev.maxlen:
            self.dropped += 1
        ev.append((name, t0 - self._epoch, dur_s, threading.get_ident(), args))

    def add_batch(self, spans) -> None:
        """Record several completed spans in one call.

        The hop hot path stamps every phase with consecutive
        ``perf_counter`` reads and hands them all over at once — one
        python call per hop instead of one per phase.  ``spans`` is an
        iterable of ``(name, t0, dur_s, args_dict)`` tuples.
        """
        if not self.enabled:
            return
        ev = self._events
        epoch = self._epoch
        tid = threading.get_ident()
        maxlen = ev.maxlen
        for name, t0, dur_s, args in spans:
            if len(ev) == maxlen:
                self.dropped += 1
            ev.append((name, t0 - epoch, dur_s, tid, args))

    @contextlib.contextmanager
    def span(self, name: str, **args):
        """Context-managed span; body exceptions still close the span."""
        if not self.enabled:
            yield
            return
        if self._jax is not None:
            with self._jax.TraceAnnotation(name):
                t0 = time.perf_counter()
                try:
                    yield
                finally:
                    self.add(name, t0, time.perf_counter() - t0, **args)
            return
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, t0, time.perf_counter() - t0, **args)

    def instant(self, name: str, **args) -> None:
        """Zero-duration marker (joins, detections, ...)."""
        self.add(name, time.perf_counter(), 0.0, **args)

    def reset(self) -> None:
        self._events.clear()
        self.dropped = 0

    # -- reporting -----------------------------------------------------------

    def spans(self, name: str | None = None) -> list[dict]:
        """Retained spans as dicts (seconds, tracer-epoch-relative)."""
        return [
            {"name": n, "t0": t0, "dur_s": dur, "tid": tid, "args": args}
            for n, t0, dur, tid, args in self._events
            if name is None or n == name
        ]

    def export_chrome(self, path=None, last: int | None = None):
        """Chrome trace-event JSON: a list when ``path`` is None, else
        written to ``path`` (``{"traceEvents": [...]}`` object form) and
        the event count returned.  ``last`` keeps only the trailing N
        spans — bench artifacts stay small without truncating the ring.

        Spans export as ``ph: "X"`` complete events (microsecond ``ts`` +
        ``dur``), which Perfetto nests by containment per thread.
        """
        events = list(self._events)
        if last is not None:
            events = events[-last:]
        tids = {}
        out = []
        for name, t0, dur, tid, args in events:
            tids.setdefault(tid, len(tids))
            ev = {
                "name": name,
                "ph": "X",
                "ts": t0 * 1e6,
                "dur": dur * 1e6,
                "pid": 0,
                "tid": tids[tid],
            }
            if args:
                ev["args"] = args
            out.append(ev)
        meta = [
            {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": self.process_name}},
        ]
        if path is None:
            return meta + out
        with open(path, "w") as f:
            json.dump({"traceEvents": meta + out, "displayTimeUnit": "ms"},
                      f)
            f.write("\n")
        return len(out)


def _dur(e: dict) -> float:
    return e["dur"] if "dur" in e else e["dur_s"]


def _start(e: dict) -> float:
    return e["ts"] if "dur" in e else e["t0"]


def _intervals(events: list[dict], names) -> list[tuple[float, float]]:
    """(start, end) of every span named in ``names``, in input units
    (Chrome events: microseconds; ``Tracer.spans()`` dicts: seconds)."""
    return [
        (_start(e), _start(e) + _dur(e))
        for e in events
        if e["name"] in names and ("dur" in e or "dur_s" in e)
    ]


def _union(iv: list[tuple[float, float]]) -> list[tuple[float, float]]:
    """Merge intervals into a disjoint sorted union."""
    out: list[list[float]] = []
    for a, b in sorted(iv):
        if out and a <= out[-1][1]:
            out[-1][1] = max(out[-1][1], b)
        else:
            out.append([a, b])
    return [(a, b) for a, b in out]


def _measure(iv: list[tuple[float, float]]) -> float:
    return sum(b - a for a, b in iv)


def _intersect(xs: list[tuple[float, float]],
               ys: list[tuple[float, float]]) -> float:
    """Total overlap between two disjoint sorted interval unions."""
    tot, i, j = 0.0, 0, 0
    while i < len(xs) and j < len(ys):
        a = max(xs[i][0], ys[j][0])
        b = min(xs[i][1], ys[j][1])
        if b > a:
            tot += b - a
        if xs[i][1] <= ys[j][1]:
            i += 1
        else:
            j += 1
    return tot


def coverage(events: list[dict], parent: str = "hop",
             phases: tuple[str, ...] = ("pack", "dispatch", "device",
                                        "detector", "push_fold"),
             mode: str = "tile") -> float:
    """Fraction of ``parent`` span wall time covered by phase spans.

    Operates on exported Chrome events (or ``Tracer.spans()`` dicts with
    ``dur_s``).  ``mode="tile"`` (the synchronous invariant) ratios
    summed durations: the hop phases are stamped back-to-back, so
    anything under the 0.95 acceptance floor means a phase went missing
    from the instrumentation — but under the async plane it double
    counts, because hop N+1's pack/dispatch legitimately overlap hop N's
    device span (the ratio can exceed 1.0).  ``mode="overlap"`` is the
    overlap-aware invariant: the measure of the *union* of phase
    intervals clipped to the union of parent intervals, over the parent
    union's measure — overlap never double counts and a missing phase
    still drops it below the floor.
    """
    if mode == "tile":
        tot = sum(_dur(e) for e in events if e["name"] == parent)
        cov = sum(_dur(e) for e in events if e["name"] in phases)
        return cov / tot if tot else 0.0
    assert mode == "overlap", mode
    par = _union(_intervals(events, (parent,)))
    phs = _union(_intervals(events, phases))
    tot = _measure(par)
    return _intersect(phs, par) / tot if tot else 0.0


def overlap_stats(events: list[dict], busy: tuple[str, ...] = ("device",),
                  hidden_under: tuple[str, ...] = ("pack", "detector"),
                  ) -> dict[str, float]:
    """Union-interval account of how much host work hid under device
    compute — the async plane's acceptance measure.

    ``busy`` spans (device execution, including queue wait at retire)
    merge into one busy union; every ``hidden_under`` span's overlap
    with that union counts as hidden.  Returns totals in the input's
    time unit (seconds for ``Tracer.spans()`` dicts, microseconds for
    exported Chrome events) plus the unit-free ``hidden_frac`` and
    ``utilization`` (busy fraction of the overall span extent).
    """
    busy_u = _union(_intervals(events, busy))
    host_iv = _intervals(events, hidden_under)
    host_u = _union(host_iv)
    host_total = _measure(host_u)
    hidden = _intersect(host_u, busy_u)
    everything = _union(_intervals(
        events, {e["name"] for e in events if "dur" in e or "dur_s" in e}
    ))
    extent = (everything[-1][1] - everything[0][0]) if everything else 0.0
    busy_total = _measure(busy_u)
    return {
        "busy_total": busy_total,
        "host_total": host_total,
        "hidden": hidden,
        "hidden_frac": hidden / host_total if host_total else 0.0,
        "extent": extent,
        "utilization": busy_total / extent if extent else 0.0,
    }
