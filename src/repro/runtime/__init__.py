"""One generic continuous-batching runtime, many workloads.

The slot-pool plane extracted from the KWS streaming scheduler (PRs 2-9)
and shared with the LM serving engine — the software twin of the paper's
one-large-programmable-macro argument (§II-A):

  * :mod:`repro.runtime.pool` — :class:`SlotPool`: slot<->tenant binding,
    pow-2 elastic grow/shrink with a ``min_capacity`` floor, idle-time
    prewarm, pool-emitted lifecycle observability;
  * :mod:`repro.runtime.placement` — :class:`SlotPlacement`: slot->shard
    mapping over contiguous per-shard blocks, cross-shard rebalance
    planning, single-model tenant blocks;
  * :mod:`repro.runtime.remap` — the row-remap contract (host
    ``remap_rows``, device ``remap_device_rows``/``perm_keep``);
  * :mod:`repro.runtime.async_plane` — :class:`InFlightQueue` (double
    buffering, deferred FIFO fold, epoch barriers) and
    :class:`IngestPump`.

Workloads implement the small :class:`SlotPoolClient` surface (state
pytree + slot axes + shard/remap hooks); everything structural — elastic
capacity, mesh sharding of the slot axis, migrate-on-idle rebalance,
epoch-barrier-correct async — comes from here.  New workloads must build
on this package rather than re-implementing slot logic (enforced by
tests/test_no_dup_runtime.py).

See docs/RUNTIME.md for the contracts and a doctested two-workload
quickstart.
"""
from repro.runtime.async_plane import InFlightQueue, IngestPump
from repro.runtime.placement import SlotPlacement
from repro.runtime.pool import (
    SlotPool,
    SlotPoolClient,
    infer_slot_axes,
    next_pow2,
)
from repro.runtime.remap import perm_keep, remap_device_rows, remap_rows

__all__ = [
    "InFlightQueue",
    "IngestPump",
    "SlotPlacement",
    "SlotPool",
    "SlotPoolClient",
    "infer_slot_axes",
    "next_pow2",
    "perm_keep",
    "remap_device_rows",
    "remap_rows",
]
