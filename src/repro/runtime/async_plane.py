"""Generic async execution plane: double buffering + epoch barriers.

Every async workload on the slot pool follows the same discipline the
streaming scheduler pioneered (PR 7):

  * **double-buffered dispatch** — launch step N+1 on step N's unforced
    result futures (JAX async dispatch chains them device-side) and run
    step N's host-side fold at its *retirement*, when N+1 is already
    executing, so host work hides under device compute;
  * **deferred FIFO fold** — retirements apply results strictly in
    dispatch order, keeping every per-slot sequence bit-identical to the
    synchronous schedule;
  * **epoch barriers** — any structural pool operation (resize,
    rebalance, priming, teardown) first drains every in-flight step, so
    a slot remap can never invalidate in-flight row indices.

:class:`InFlightQueue` packages that protocol: workloads push opaque
in-flight records with a retire function, and the queue owns the depth
policy, the FIFO drain, and the barrier.  Wiring ``queue.barrier`` as the
pool's ``pre_structural`` hook makes barriers *declared*, not
hand-rolled: the pool calls it before every structural mutation, on every
path (grow-on-alloc, shrink-on-free, rebalance), for every workload.

:class:`IngestPump` is the host-ingest half of the same plane — a daemon
worker that lands queued pushes through a workload-supplied apply
function (which must take the workload's ingest lock), with deferred
error surfacing at ``flush``.

Pipeline depth is 1 by default (classic double buffering); deeper
pipelines only add queue latency before the fold without increasing
overlap, since one step's compute already hides the next step's host
work.
"""
from __future__ import annotations

import queue
import threading

__all__ = ["InFlightQueue", "IngestPump"]

_SENTINEL = object()


class InFlightQueue:
    """FIFO of dispatched-but-unretired steps with a declared depth.

    ``retire_fn(item, still_in_flight)`` fences on the item's device
    futures and applies its deferred fold; ``still_in_flight`` tells the
    fold whether a later step is executing underneath it (its host work
    is then hidden under device compute).  The retire policy matches the
    double-buffered contract: retire once the queue is past its depth, or
    when the workload is starved (nothing newly dispatched) and work
    remains to drain.
    """

    def __init__(self, retire_fn, depth: int = 1) -> None:
        assert depth >= 1, depth
        self._retire = retire_fn
        self.depth = depth
        self._items: list = []

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def push(self, item) -> None:
        self._items.append(item)

    def retire_oldest(self):
        """Fence + fold the oldest in-flight step (FIFO)."""
        item = self._items.pop(0)
        return self._retire(item, bool(self._items))

    def settle(self, dispatched: bool, max_retire: int | None = 1) -> list:
        """Apply the depth policy for one pipeline turn: retire while the
        queue is past its depth, or — when nothing was dispatched — while
        anything is in flight.  ``max_retire`` bounds the retirements per
        turn (``None`` = drain to policy); returns the retired results in
        dispatch order."""
        out: list = []
        while self._items and (len(self._items) > self.depth
                               or not dispatched):
            out.append(self.retire_oldest())
            if max_retire is not None and len(out) >= max_retire:
                break
        return out

    def barrier(self) -> list:
        """Epoch barrier: retire EVERY in-flight step.  Callers then hold
        the invariant a synchronous workload has between steps — all
        folds applied, no future references any slot row — so structural
        remaps run exactly as they do synchronously."""
        out: list = []
        while self._items:
            out.append(self.retire_oldest())
        return out


class IngestPump:
    """Background ingest worker: queued ``(sids, chunks)`` batches land
    in the arena from a daemon thread via ``apply_fn`` (which must take
    the scheduler's ingest lock).  ``submit`` never blocks on the
    device; ``flush`` waits until every queued push has landed and
    re-raises the first error a push hit (unknown sid, arena overflow —
    all raised *before* any sample lands, so a failed push never
    half-applies)."""

    def __init__(self, apply_fn) -> None:
        self._apply = apply_fn
        self._q: queue.Queue = queue.Queue()
        self._err: BaseException | None = None
        self.pushed_batches = 0
        self._thread = threading.Thread(
            target=self._run, name="ingest-pump", daemon=True
        )
        self._thread.start()

    def _run(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _SENTINEL:
                    return
                sids, chunks = item
                try:
                    self._apply(sids, chunks)
                    self.pushed_batches += 1
                except BaseException as e:  # surfaced at the next flush
                    if self._err is None:
                        self._err = e
            finally:
                self._q.task_done()

    def submit(self, sids, chunks) -> None:
        self._q.put((list(sids), list(chunks)))

    def flush(self) -> None:
        """Barrier: every push submitted before this call has landed (or
        failed).  Raises the first deferred push error, once."""
        self._q.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def close(self) -> None:
        """Flush, then stop the worker thread (errors still surface)."""
        self._q.join()
        self._q.put(_SENTINEL)
        self._q.join()
        self._thread.join(timeout=10.0)
        if self._err is not None:
            err, self._err = self._err, None
            raise err
