"""Slot -> shard placement for the generic continuous-batching runtime.

``SlotPlacement`` is pure bookkeeping (plain python ints) shared by every
workload that rides the :class:`repro.runtime.pool.SlotPool`: the KWS
streaming scheduler (``repro.stream``) and the LM serving engine
(``repro.serve``) both place their per-slot state through this one class.
It grew up inside ``stream/state.py`` (PRs 3, 5, 9) and moved here verbatim
when the slot-pool plane was extracted — the mesh/block invariants below
are workload-agnostic:

  * one logical pool of ``n_shards * shard_capacity`` rows; shard ``s``
    owns the contiguous block ``[s * shard_capacity, (s+1) *
    shard_capacity)``;
  * ``alloc`` -> least-loaded shard, lowest free local slot;
  * ``grow``/``shrink`` scale the per-shard capacity — a resize never
    moves a row across devices;
  * ``rebalance`` is the ONE deliberate cross-shard path (migrate-on-idle
    row moves at workload-defined barriers);
  * ``tenant_block`` keeps aligned slot blocks single-model so pooled
    kernels can gather one weight row per block.
"""
from __future__ import annotations

__all__ = ["SlotPlacement"]


class SlotPlacement:
    """Slot -> shard mapping for the mesh-wide slot pool.

    The pool's batch axis is one global array of ``n_shards *
    shard_capacity`` rows; under a mesh sharding over the ``"data"`` axis,
    shard ``s`` owns the contiguous row block ``[s * shard_capacity, (s +
    1) * shard_capacity)``.  All placement decisions respect that block
    structure so *no resize or allocation ever moves a row across
    devices*:

      * ``alloc`` places a joining stream on the least-loaded shard
        (lowest shard wins ties) at its lowest free local slot — with one
        shard this degenerates to "lowest free slot", the pre-mesh
        behavior;
      * ``grow``/``shrink`` change the *per-shard* capacity: a grow
        appends rows at the end of every shard block, a shrink compacts
        each shard's tenants into its own surviving local slots and drops
        the block tails.  A resize never moves a row across devices,
        which is why an elastic resize under sharding costs zero
        collective communication;
      * ``rebalance`` is the ONE deliberate cross-shard path — the
        software twin of re-laying-out the paper's flexible ping-pong
        feature SRAM when the workload shape changes (§II-E): at hop
        boundaries, churn-induced occupancy skew is leveled by migrating
        tenants from over-full shards to under-full ones, so the shrink
        floor is ``ceil(active / n_shards)`` per shard instead of the
        fullest shard's tenant count.

    **Multi-tenant mode** (``tenant_block`` set): every shard block is
    further partitioned into aligned *tenant blocks* of ``min(tenant_block,
    shard_capacity)`` slots, and placement keeps each tenant block
    single-model — the invariant that lets the pooled kernels gather ONE
    weight row per grid cell (`kernels/hop_megakernel.py` ``pooled``).
    A block's model binding is *derived* (the model of any occupied slot;
    an empty block is unbound), which makes it automatically correct
    across grow (local indices are preserved and old blocks nest inside
    new ones) and shrink (new blocks are equal-or-finer partitions of the
    surviving region).

    The placement is pure bookkeeping (plain python ints); the scheduler
    applies the returned remaps/moves to the batched device arrays.
    """

    def __init__(self, n_shards: int, shard_capacity: int,
                 tenant_block: int | None = None) -> None:
        assert n_shards >= 1 and shard_capacity >= 1
        # power-of-two so tenant blocks nest across pow-2 grow/shrink
        assert tenant_block is None or (
            tenant_block >= 1 and tenant_block & (tenant_block - 1) == 0
        )
        self.n_shards = n_shards
        self.shard_capacity = shard_capacity
        self.tenant_block = tenant_block
        self.slots: list[int | None] = [None] * (n_shards * shard_capacity)
        # model key per slot (None when free / untracked); parallel to
        # ``slots`` and remapped alongside it by every placement op
        self.slot_model: list = [None] * (n_shards * shard_capacity)

    @property
    def capacity(self) -> int:
        return self.n_shards * self.shard_capacity

    @property
    def block_size(self) -> int | None:
        """Effective tenant-block size (None in single-model mode)."""
        if self.tenant_block is None:
            return None
        return min(self.tenant_block, self.shard_capacity)

    def shard_of(self, slot: int) -> int:
        return slot // self.shard_capacity

    def occupancy(self) -> list[int]:
        """Tenant count per shard."""
        occ = [0] * self.n_shards
        for slot, sid in enumerate(self.slots):
            if sid is not None:
                occ[self.shard_of(slot)] += 1
        return occ

    def _block_model(self, start: int, tbe: int,
                     slots=None, slot_model=None):
        """Derived model binding of the block at ``start``: the model of
        any occupied slot (single-model invariant), None when empty."""
        slots = self.slots if slots is None else slots
        slot_model = self.slot_model if slot_model is None else slot_model
        for s in range(start, start + tbe):
            if slots[s] is not None:
                return slot_model[s]
        return None

    def block_models(self) -> dict[int, object]:
        """{block_start: model} for every non-empty tenant block."""
        tbe = self.block_size
        assert tbe is not None, "single-model placement has no blocks"
        out = {}
        for start in range(0, self.capacity, tbe):
            m = self._block_model(start, tbe)
            if m is not None:
                out[start] = m
        return out

    def alloc(self, sid: int, model=None) -> int | None:
        """Place ``sid`` on the least-loaded shard; None when pool full.

        With ``tenant_block`` set, only slots inside a block already bound
        to ``model`` (or an empty block, which this alloc binds) are
        eligible — shards are scanned in least-loaded order, preferring
        partially-filled compatible blocks over opening a fresh one.
        """
        occ = self.occupancy()
        c = self.shard_capacity
        order = sorted(range(self.n_shards), key=lambda s: (occ[s], s))
        if self.tenant_block is None:
            for sh in order:
                if occ[sh] == c:
                    continue
                base = sh * c
                for loc in range(c):
                    if self.slots[base + loc] is None:
                        self.slots[base + loc] = sid
                        self.slot_model[base + loc] = model
                        return base + loc
            return None
        tbe = self.block_size
        # pass 1: a compatible partially-filled block on the least-loaded
        # shard; pass 2: open an empty block
        for want_empty in (False, True):
            for sh in order:
                if occ[sh] == c:
                    continue
                base = sh * c
                for start in range(base, base + c, tbe):
                    bm = self._block_model(start, tbe)
                    ok = (bm is None) if want_empty else (
                        bm is not None and bm == model
                    )
                    if not ok:
                        continue
                    for s in range(start, start + tbe):
                        if self.slots[s] is None:
                            self.slots[s] = sid
                            self.slot_model[s] = model
                            return s
        return None

    def free(self, slot: int) -> None:
        assert self.slots[slot] is not None
        self.slots[slot] = None
        self.slot_model[slot] = None

    def grow(self, new_shard_capacity: int) -> dict[int, int]:
        """Grow every shard block; returns {old_slot: new_slot} remap.

        Tenant blocks stay single-model for free: local indices are
        preserved, and the old blocks (size ``min(tb, old_c)``) nest
        inside the new ones (size ``min(tb, c)``) — when ``old_c < tb``
        the whole old shard was one block, so the containing new block
        inherits a single model either way.
        """
        old_c, c = self.shard_capacity, new_shard_capacity
        assert c > old_c
        remap: dict[int, int] = {}
        slots: list[int | None] = [None] * (self.n_shards * c)
        models: list = [None] * (self.n_shards * c)
        for slot, sid in enumerate(self.slots):
            new_slot = self.shard_of(slot) * c + slot % old_c
            slots[new_slot] = sid
            models[new_slot] = self.slot_model[slot]
            remap[slot] = new_slot
        self.slots, self.slot_model = slots, models
        self.shard_capacity = c
        return remap

    def shrink(
        self, new_shard_capacity: int
    ) -> tuple[list[tuple[int, int]], dict[int, int]]:
        """Shrink every shard block to ``new_shard_capacity`` local slots.

        Returns ``(moves, remap)``: ``moves`` are (dst, src) row copies in
        the OLD global indexing — each within one shard block — that
        compact tenants out of the doomed upper local slots; ``remap`` is
        {old_slot: new_slot} for every surviving tenant after the slice.
        """
        old_c, c = self.shard_capacity, new_shard_capacity
        assert c < old_c
        if self.tenant_block is not None:
            return self._shrink_tenant(c)
        moves: list[tuple[int, int]] = []
        moved: dict[int, int] = {}  # original old slot -> post-move old slot
        for sh in range(self.n_shards):
            base = sh * old_c
            if sum(s is not None for s in
                   self.slots[base : base + old_c]) > c:
                raise ValueError(
                    f"shard {sh} holds more than {c} tenants; cross-shard "
                    "relocation is not allowed"
                )
            free_low = [
                base + loc for loc in range(c)
                if self.slots[base + loc] is None
            ]
            for loc in range(c, old_c):
                sid = self.slots[base + loc]
                if sid is None:
                    continue
                dst = free_low.pop(0)
                moves.append((dst, base + loc))
                moved[base + loc] = dst
                self.slots[dst] = sid
                self.slot_model[dst] = self.slot_model[base + loc]
                self.slots[base + loc] = None
                self.slot_model[base + loc] = None
        return moves, self._commit_shrink(
            self.slots, self.slot_model, moved, c
        )

    def _commit_shrink(self, slots, models, moved, c):
        """Slice each shard's surviving region and build the {original
        old slot: new slot} remap (shared by both shrink flavors)."""
        old_c = self.shard_capacity
        remap: dict[int, int] = {}
        new_slots: list[int | None] = [None] * (self.n_shards * c)
        new_models: list = [None] * (self.n_shards * c)
        survivor_new = {}  # post-move old slot -> new slot
        for sh in range(self.n_shards):
            for loc in range(c):
                sid = slots[sh * old_c + loc]
                new_slots[sh * c + loc] = sid
                new_models[sh * c + loc] = models[sh * old_c + loc]
                if sid is not None:
                    survivor_new[sh * old_c + loc] = sh * c + loc
        for old_slot, new_slot in survivor_new.items():
            remap[old_slot] = new_slot
        for orig, interim in moved.items():
            remap[orig] = survivor_new[interim]
        self.slots, self.slot_model = new_slots, new_models
        self.shard_capacity = c
        return remap

    def _shrink_tenant(
        self, c: int
    ) -> tuple[list[tuple[int, int]], dict[int, int]]:
        """Tenant-aware shrink: compact doomed-region tenants into
        surviving blocks WITHOUT splitting a single-model block.  The
        whole plan runs over copies first, so an impossible shrink raises
        before any placement state mutates (the scheduler treats that as
        "stay at the current capacity").
        """
        old_c = self.shard_capacity
        tbe = min(self.tenant_block, c)
        slots = list(self.slots)
        models = list(self.slot_model)
        moves: list[tuple[int, int]] = []
        moved: dict[int, int] = {}
        for sh in range(self.n_shards):
            base = sh * old_c
            for loc in range(c, old_c):
                src = base + loc
                sid = slots[src]
                if sid is None:
                    continue
                m = models[src]
                dst = None
                for want_empty in (False, True):
                    for start in range(base, base + c, tbe):
                        bm = self._block_model(start, tbe, slots, models)
                        ok = (bm is None) if want_empty else (
                            bm is not None and bm == m
                        )
                        if not ok:
                            continue
                        dst = next(
                            (s for s in range(start, start + tbe)
                             if slots[s] is None), None
                        )
                        if dst is not None:
                            break
                    if dst is not None:
                        break
                if dst is None:
                    raise ValueError(
                        f"shard {sh} cannot pack its tenants into {c} "
                        "slots without splitting a tenant block"
                    )
                moves.append((dst, src))
                moved[src] = dst
                slots[dst], models[dst] = sid, m
                slots[src] = models[src] = None
        return moves, self._commit_shrink(slots, models, moved, c)

    def rebalance(self) -> tuple[list[tuple[int, int]], dict[int, int]]:
        """Plan cross-shard migrations that level shard occupancy.

        Tenants move from shards above ``target = ceil(active /
        n_shards)`` to shards below it until no shard exceeds the target
        — the leveled pool can then shrink to ``ceil(active / S)`` local
        slots where the skewed pool was pinned at the fullest shard's
        tenant count.  Donors give up their *highest* occupied local slot
        (freeing the block tail a later shrink slices off); receivers
        fill their *lowest* free local slot.  Deterministic: ties break
        to the lowest shard index.

        Returns ``(moves, remap)`` with capacity unchanged: ``moves`` are
        (dst, src) row copies in the current global indexing — each one
        crossing a shard block, unlike every other placement operation —
        and ``remap`` is {original_slot: current_slot} for EVERY tenant
        (identity when unmoved), i.e. ``RingArena.apply_remap``'s
        contract.
        """
        if self.tenant_block is not None:
            return self._rebalance_tenant()
        c = self.shard_capacity
        occ = self.occupancy()
        active = sum(occ)
        target = -(-active // self.n_shards) if active else 0
        moves: list[tuple[int, int]] = []
        while True:
            hi = max(range(self.n_shards), key=lambda s: (occ[s], -s))
            if occ[hi] <= target:
                break
            lo = min(range(self.n_shards), key=lambda s: (occ[s], s))
            src = next(hi * c + loc for loc in range(c - 1, -1, -1)
                       if self.slots[hi * c + loc] is not None)
            dst = next(lo * c + loc for loc in range(c)
                       if self.slots[lo * c + loc] is None)
            self.slots[dst] = self.slots[src]
            self.slot_model[dst] = self.slot_model[src]
            self.slots[src] = None
            self.slot_model[src] = None
            moves.append((dst, src))
            occ[hi] -= 1
            occ[lo] += 1
        # every move is a single hop (donor shards only lose, receiver
        # shards only gain), so {dst: src} inverts to the original slots
        came_from = {dst: src for dst, src in moves}
        remap = {
            came_from.get(slot, slot): slot
            for slot, sid in enumerate(self.slots) if sid is not None
        }
        return moves, remap

    def _rebalance_tenant(
        self,
    ) -> tuple[list[tuple[int, int]], dict[int, int]]:
        """Tenant-aware rebalance: migrate WHOLE tenant blocks (offset-
        preserving) from the fullest shard into empty aligned blocks on
        the emptiest shard — slot-level moves would split single-model
        blocks.  Each migration strictly decreases the occupancy
        potential sum(occ^2) (it requires ``occ[hi] - occ[lo] > n``), so
        the loop terminates; a block can move more than once across
        rounds, so ``came_from`` chain-resolves back to original slots.
        """
        c = self.shard_capacity
        tbe = self.block_size
        occ = self.occupancy()
        moves: list[tuple[int, int]] = []
        came_from: dict[int, int] = {}
        while True:
            hi = max(range(self.n_shards), key=lambda s: (occ[s], -s))
            lo = min(range(self.n_shards), key=lambda s: (occ[s], s))
            # smallest non-empty block on the donor: cheapest to move and
            # the most likely to satisfy the potential-decrease gate
            best = None
            for start in range(hi * c, (hi + 1) * c, tbe):
                n = sum(1 for s in range(start, start + tbe)
                        if self.slots[s] is not None)
                if n and (best is None or n < best[1]):
                    best = (start, n)
            if best is None:
                break
            src_start, n = best
            if occ[hi] - occ[lo] <= n:
                break
            dst_start = next(
                (s0 for s0 in range(lo * c, (lo + 1) * c, tbe)
                 if all(self.slots[s] is None
                        for s in range(s0, s0 + tbe))),
                None,
            )
            if dst_start is None:
                break
            for off in range(tbe):
                src, dst = src_start + off, dst_start + off
                if self.slots[src] is None:
                    continue
                self.slots[dst] = self.slots[src]
                self.slot_model[dst] = self.slot_model[src]
                self.slots[src] = None
                self.slot_model[src] = None
                moves.append((dst, src))
                came_from[dst] = came_from.pop(src, src)
            occ[hi] -= n
            occ[lo] += n
        remap = {
            came_from.get(slot, slot): slot
            for slot, sid in enumerate(self.slots) if sid is not None
        }
        return moves, remap
