"""SlotPool: the generic continuous-batching slot plane.

One slot-pool machine, many workloads — the runtime mirror of the paper's
one-large-programmable-macro argument (§II-A).  ``SlotPool`` owns
everything that is workload-independent about a pool of batch slots:

  * slot <-> tenant binding through :class:`~repro.runtime.placement.
    SlotPlacement` (least-loaded shard alloc, per-shard pow-2 elastic
    grow/shrink with a ``min_capacity`` floor, cross-shard rebalance);
  * the elastic resize itself: pad/slice of every device state leaf along
    its declared slot axis, per shard block, plus the host-side remap;
  * migrate-on-idle rebalance at workload-declared barriers
    (``hop_barrier``), with the device row gather from
    :mod:`repro.runtime.remap`;
  * idle-time jit prewarm of the next pow-2 capacity;
  * lifecycle observability: ``{prefix}resize`` / ``{prefix}rebalance``
    trace spans and structured events are emitted HERE, so every workload
    gets them for free (the KWS scheduler keeps its historical unprefixed
    kinds; the LM engine emits ``lm_resize``/``lm_rebalance``).

The workload plugs in as a **client** object with a small duck-typed
surface (see :class:`SlotPoolClient`): a per-slot device-state pytree,
the slot axis of each leaf, a shard-pinning hook, and a host-side remap
hook.  The pool never interprets the state — rows travel unchanged
through every structural operation, which is what makes resizes and
migrations bit-invisible to the tenants riding through them.

Structural operations (resize, rebalance) call the client's optional
``pre_structural`` hook first; an async execution plane installs its
epoch barrier there, so "drain every in-flight step before any slot
remap" is declared once instead of hand-rolled per workload.
"""
from __future__ import annotations

import time
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.obs import Observability
from repro.runtime.placement import SlotPlacement
from repro.runtime.remap import perm_keep, remap_device_rows

__all__ = ["SlotPool", "SlotPoolClient", "next_pow2", "infer_slot_axes"]


def next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def infer_slot_axes(make_state, b1: int = 2, b2: int = 3):
    """Derive the slot axis of every leaf of a workload's state pytree by
    shape-diffing ``make_state(batch)`` at two batch sizes (via
    ``jax.eval_shape`` — nothing is materialized).  Leaves whose shape
    does not depend on the batch (shared scalar clocks, replicated
    params) map to ``-1`` ("not slot-indexed"); the pool leaves them
    untouched across resizes and rebalances."""
    s1 = jax.eval_shape(lambda: make_state(b1))
    s2 = jax.eval_shape(lambda: make_state(b2))

    def ax(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        return -1

    return jax.tree_util.tree_map(ax, s1, s2)


@runtime_checkable
class SlotPoolClient(Protocol):
    """Duck-typed workload surface the pool drives.

    Required:

    * ``device_state()`` — the per-slot device-state pytree (leaves are
      jax arrays; non-slot leaves allowed when ``slot_axes`` marks them
      ``-1``).
    * ``set_device_state(state)`` — install the pool-transformed pytree.
    * ``slot_axes()`` — pytree of ints matching ``device_state()``: the
      slot axis of each leaf, ``-1`` for leaves with no slot axis.
    * ``shard(x, axis)`` — settle one array's slot ``axis`` onto the
      workload's mesh sharding (identity with no mesh).
    * ``apply_host_remap(remap, new_capacity)`` — ride the host-side
      planes (bookkeeping vectors, arenas, caches, slot handles) through
      a ``{old_slot: new_slot}`` remap at ``new_capacity`` rows.

    Optional (checked with ``getattr``):

    * ``warm(capacity)`` — compile the workload's step at ``capacity``
      slots (idle-time prewarm target).
    * ``pre_structural()`` — called before any structural mutation; an
      async plane installs its epoch barrier here.
    """

    def device_state(self): ...
    def set_device_state(self, state) -> None: ...
    def slot_axes(self): ...
    def shard(self, x, axis: int): ...
    def apply_host_remap(self, remap: dict[int, int],
                         new_capacity: int) -> None: ...


class SlotPool:
    """Elastic, shardable, observable pool of batch slots.

    ``capacity`` is the *ceiling*: the pool starts at ``initial_capacity``
    (default ``min_capacity``) and doubles on demand up to the ceiling;
    ``maybe_shrink`` halves it once occupancy falls to a quarter (never
    below ``min_capacity`` — set ``min_capacity == capacity`` to pin a
    fixed-size pool).  All capacities are multiples of ``n_shards`` and
    every resize scales the *per-shard* capacity, so rows never cross
    devices outside the one deliberate ``rebalance`` path.
    """

    def __init__(
        self,
        client: SlotPoolClient,
        capacity: int,
        *,
        initial_capacity: int | None = None,
        min_capacity: int | None = None,
        n_shards: int = 1,
        mesh=None,
        tenant_block: int | None = None,
        rebalance_threshold: int | None = 1,
        obs: Observability | None = None,
        event_prefix: str = "",
        noun: str = "stream",
        on_resize=None,
        on_rebalance=None,
        prewarm: bool = False,
        clock=time.perf_counter,
    ) -> None:
        S = n_shards
        assert S >= 1
        assert capacity % S == 0, (
            f"capacity {capacity} not a multiple of {S} mesh shards"
        )
        self.client = client
        self.mesh = mesh
        self.n_shards = S
        self.max_capacity = capacity
        self.min_capacity = (
            min_capacity if min_capacity is not None
            else S * min(2, capacity // S)
        )
        assert S <= self.min_capacity <= capacity
        assert self.min_capacity % S == 0
        cap0 = initial_capacity if initial_capacity is not None else (
            self.min_capacity
        )
        assert self.min_capacity <= cap0 <= capacity, (cap0, capacity)
        assert cap0 % S == 0
        if tenant_block is not None:
            # tenant blocks only nest across resizes when every per-shard
            # capacity the pool can visit is a power of two
            for c in (self.min_capacity, cap0, capacity):
                sc = c // S
                assert sc & (sc - 1) == 0, (
                    f"tenant pooling needs pow-2 per-shard capacities; "
                    f"got {sc} (capacity {c} over {S} shards)"
                )
        self._capacity = cap0
        self.placement = SlotPlacement(S, cap0 // S,
                                       tenant_block=tenant_block)
        if rebalance_threshold is not None:
            assert rebalance_threshold >= 1, rebalance_threshold
        self.rebalance_threshold = rebalance_threshold
        self.skew_dirty = False  # set on free; checked at hop barriers
        self.obs = obs if obs is not None else Observability.create()
        self._prefix = event_prefix
        self._noun = noun
        self._on_resize = on_resize
        self._on_rebalance = on_rebalance
        self._prewarm_enabled = prewarm
        self._clock = clock
        # an async plane reassigns this to its epoch barrier after
        # construction; None = synchronous workload, no barrier needed
        self.pre_structural = getattr(client, "pre_structural", None)

    # -- capacity ------------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Current pool size (<= ``max_capacity``)."""
        return self._capacity

    @property
    def shard_capacity(self) -> int:
        """Current per-shard pool size (== ``capacity`` with no mesh)."""
        return self.placement.shard_capacity

    @property
    def active(self) -> int:
        """Occupied slot count."""
        return sum(s is not None for s in self.placement.slots)

    # -- tenant lifecycle ----------------------------------------------------

    def alloc(self, sid: int, model=None) -> int:
        """Claim a slot for ``sid`` on the least-loaded shard, growing the
        pool (pow-2 doubling) on demand; raises ``MemoryError`` at the
        capacity ceiling."""
        slot = self.placement.alloc(sid, model=model)
        while slot is None:
            if self._capacity >= self.max_capacity:
                raise MemoryError(
                    f"all {self.max_capacity} {self._noun} slots busy; "
                    f"close a {self._noun} first"
                )
            # one grow may still not open a compatible tenant block (a
            # one-block shard bound to another model), so keep doubling
            self.resize(min(self._capacity * 2, self.max_capacity))
            slot = self.placement.alloc(sid, model=model)
        return slot

    def free(self, slot: int) -> None:
        """Release one slot (placement only — the workload scrubs its own
        state rows).  Marks the pool skew-dirty: the next ``hop_barrier``
        re-levels shard occupancy if leave churn skewed it."""
        self.placement.free(slot)
        self.skew_dirty = True

    # -- elastic resize ------------------------------------------------------

    def resize(self, new_cap: int) -> None:
        """Per-shard pad/slice of the batched state to ``new_cap`` slots.

        Rows travel unchanged and never cross shard blocks (a slot's math
        never depends on the batch size or its neighbors), so resizes are
        invisible to the tenants riding through them and cost zero
        collective communication; jit re-traces once per capacity visited.
        """
        old = self._capacity
        if new_cap == old:
            return
        if self.pre_structural is not None:
            self.pre_structural()  # remaps must never race in-flight steps
        with self.obs.trace.span(self._prefix + "resize",
                                 old=old, new=new_cap):
            self._resize_inner(new_cap)

    def _resize_inner(self, new_cap: int) -> None:
        old = self._capacity
        S = self.n_shards
        old_sc, new_sc = old // S, new_cap // S
        if new_cap > old:
            remap = self.placement.grow(new_sc)
            moves = None
        else:
            # compact tenants out of each shard's doomed upper slots, then
            # slice every shard block; vacated destinations are already
            # zero (scrubbed by the workload on free)
            moves, remap = self.placement.shrink(new_sc)

        def adjust(a, ax):
            if ax < 0:
                return a  # not slot-indexed (shared clocks, replicated)
            m = jnp.moveaxis(a, ax, 0) if ax else a
            if moves is None:
                m2 = m.reshape(S, old_sc, *m.shape[1:])
                m2 = jnp.pad(m2, ((0, 0), (0, new_sc - old_sc))
                             + ((0, 0),) * (m.ndim - 1))
            else:
                for dst, src in moves:
                    m = m.at[dst].set(m[src])
                m2 = m.reshape(S, old_sc, *m.shape[1:])[:, :new_sc]
            out = m2.reshape(S * new_sc, *m.shape[1:])
            if ax:
                out = jnp.moveaxis(out, 0, ax)
            return self.client.shard(out, ax)

        self.client.set_device_state(jax.tree_util.tree_map(
            adjust, self.client.device_state(), self.client.slot_axes()
        ))
        # the host-side planes ride the same placement remap, so a
        # tenant's bookkeeping rows stay glued to its slot
        self.client.apply_host_remap(remap, new_cap)
        self._capacity = new_cap
        if self._on_resize is not None:
            self._on_resize(new_cap)
        self.obs.events.emit(self._prefix + "resize", old=old, new=new_cap,
                             active=self.active, shards=S)

    def maybe_shrink(self) -> None:
        """Halve the pool while occupancy sits at or below a quarter,
        floored by ``min_capacity`` and — because shrink compaction is
        per-shard — the fullest shard's tenant count.  The rebalance plane
        levels occupancy at hop barriers, so under churn this floor
        settles at ceil(active / S) instead of wherever the most crowded
        shard happens to sit."""
        S = self.n_shards
        sc = self._capacity // S
        min_sc = self.min_capacity // S
        active = self.active
        while sc > min_sc and active <= (S * sc) // 4:
            sc //= 2
        sc = max(sc, min_sc, next_pow2(max(self.placement.occupancy())))
        while S * sc < self._capacity:
            try:
                self.resize(S * sc)
                return
            except ValueError:
                # tenant-block packing can refuse a depth occupancy alone
                # would allow (blocks never split across models); retry
                # shallower.  Un-pooled placement never raises here.
                sc *= 2

    # -- cross-shard rebalance -----------------------------------------------

    def maybe_rebalance(self) -> bool:
        """Migrate-on-idle: level shard occupancy with cross-shard slot
        moves when churn has skewed it past ``rebalance_threshold``.

        The device half is one row gather per state leaf
        (:func:`repro.runtime.remap.remap_device_rows`) — rows travel
        unchanged, so the migration is bit-invisible to the tenants
        riding through it; the host half is the same remap contract every
        resize already takes.  Returns True when any row moved (the
        caller then re-checks the shrink, whose per-shard floor the
        migration just lifted).
        """
        thr = self.rebalance_threshold
        if self.n_shards == 1 or thr is None:
            return False
        occ = self.placement.occupancy()
        if max(occ) - min(occ) <= thr:
            return False
        if self.pre_structural is not None:
            self.pre_structural()
        moves, remap = self.placement.rebalance()
        if not moves:
            return False
        with self.obs.trace.span(self._prefix + "rebalance",
                                 moves=len(moves)):
            self._execute_rebalance(moves, remap, occ)
        return True

    def _execute_rebalance(self, moves, remap, occ) -> None:
        cap = self._capacity
        perm, keep = perm_keep(remap, cap)

        def gather(a, ax):
            if ax < 0:
                return a
            out = remap_device_rows(a, perm, keep, axis=ax, mesh=self.mesh)
            # remap_device_rows re-pins axis 0 itself; interior axes are
            # settled through the workload's shard hook
            return out if ax == 0 else self.client.shard(out, ax)

        self.client.set_device_state(jax.tree_util.tree_map(
            gather, self.client.device_state(), self.client.slot_axes()
        ))
        self.client.apply_host_remap(remap, cap)
        if self._on_rebalance is not None:
            self._on_rebalance(len(moves))
        self.obs.events.emit(
            self._prefix + "rebalance", moves=len(moves),
            shards=self.n_shards, occupancy_before=list(occ),
            occupancy_after=list(self.placement.occupancy()),
        )

    # -- workload-declared barriers ------------------------------------------

    def hop_barrier(self) -> None:
        """Structural housekeeping at a workload step boundary:
        rebalance-on-skew, then the shrink the migration may have
        unpinned.  Async workloads call this behind their epoch barrier
        (the pool's ``pre_structural`` hook covers the paths that reach
        structural mutations any other way)."""
        if self.skew_dirty:
            self.skew_dirty = False
            if self.maybe_rebalance():
                self.maybe_shrink()

    def maybe_prewarm(self) -> None:
        """Idle-time prewarm: compile the NEXT pow-2 capacity's step via
        the client's ``warm`` hook while the workload is starved, so the
        first step after a grow pays no compile spike."""
        if not self._prewarm_enabled:
            return
        warm = getattr(self.client, "warm", None)
        if warm is None:
            return
        nxt = min(self._capacity * 2, self.max_capacity)
        if nxt > self._capacity:
            warm(nxt)
