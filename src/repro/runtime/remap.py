"""Row-remap contract of the slot-pool plane: host and device halves.

Every structural pool operation (elastic resize, cross-shard rebalance)
reduces to ONE slot remap ``{old_slot: new_slot}`` that the workload's
state must ride through:

  * **host half** — :func:`remap_rows` reindexes any numpy per-slot plane
    (bookkeeping vectors, detector state, the ``RingArena``'s
    ``apply_remap`` is built on the same contract) with one vectorized
    gather; rows without a surviving tenant reset to ``fill``.
  * **device half** — :func:`remap_device_rows` permutes the slot axis of
    a device-resident state array.  For the canonical leading-axis layout
    it is exactly ``kernels.ops.remap_slot_rows`` (standalone because
    ``pallas_call`` is GSPMD-opaque — the partitioner must be free to
    lower cross-shard rows into collectives); for workloads whose slot
    axis is interior (the LM engine's ``(reps, batch, ...)`` KV cache) the
    same gather runs through a moveaxis.
  * :func:`perm_keep` converts the remap dict into the dense
    ``(perm, keep)`` arrays the device gather consumes: ``out[i] =
    x[perm[i]] where keep[i] else 0``.

``SlotPool`` drives both halves; workloads only declare which axis of
each state leaf is the slot axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

__all__ = ["remap_rows", "perm_keep", "remap_device_rows"]


def remap_rows(a: np.ndarray, remap: dict[int, int], new_rows: int,
               fill=0) -> np.ndarray:
    """Reindex the leading axis through a slot remap (one vectorized
    gather); rows without a surviving tenant reset to ``fill``."""
    out = np.full((new_rows,) + a.shape[1:], fill, a.dtype)
    if remap:
        olds = np.fromiter(remap.keys(), np.int64, len(remap))
        news = np.fromiter(remap.values(), np.int64, len(remap))
        out[news] = a[olds]
    return out


def perm_keep(remap: dict[int, int],
              capacity: int) -> tuple[np.ndarray, np.ndarray]:
    """Densify ``{old_slot: new_slot}`` into the ``(perm, keep)`` pair of
    the device gather: ``perm[new] = old`` for every surviving tenant,
    ``keep`` False rows scrub to zero."""
    perm = np.arange(capacity, dtype=np.int64)
    keep = np.zeros(capacity, bool)
    for old, new in remap.items():
        perm[new] = old
        keep[new] = True
    return perm, keep


def remap_device_rows(x: jax.Array, perm: np.ndarray, keep: np.ndarray,
                      *, axis: int = 0, mesh=None) -> jax.Array:
    """Permute the slot ``axis`` of one device state array: ``out[i] =
    x[perm[i]] where keep[i] else 0`` along that axis.

    ``axis == 0`` is the canonical layout and dispatches to
    ``ops.remap_slot_rows`` (which also re-pins the result onto the
    mesh's data-axis sharding).  Interior axes run the identical gather
    through a moveaxis; the caller re-settles sharding (the pool calls
    the workload's ``shard`` hook).
    """
    if axis == 0:
        return ops.remap_slot_rows(x, perm, keep, mesh=mesh)
    m = jnp.moveaxis(x, axis, 0)
    out = jnp.take(m, jnp.asarray(perm, jnp.int32), axis=0)
    k = jnp.asarray(keep, bool).reshape((-1,) + (1,) * (m.ndim - 1))
    out = jnp.where(k, out, jnp.zeros_like(out))
    return jnp.moveaxis(out, 0, axis)
