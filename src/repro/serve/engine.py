"""Batched serving engine: prefill + decode with continuous batching.

The engine owns one jitted prefill function and one jitted decode step per
(arch, batch-slot geometry).  Requests enter a queue; free batch slots are
filled per decode tick (continuous batching), finished sequences vacate
their slot.  On this container it runs the smoke configs end-to-end; the
same code lowers the production decode_32k / long_500k shapes in the
dry-run (launch/dryrun.py lowers exactly ``self.decode_step``).

Slot state is the stacked cache pytree from models.api.init_decode_state;
per-slot fill is a dynamic-update into the batch axis.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api
from repro.obs import Observability
from repro.serve import sampler
from repro.utils.logging import get_logger

log = get_logger("serve")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ArchConfig, params, batch_slots: int = 4,
                 max_seq: int = 128, seed: int = 0,
                 obs: Observability | None = None):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.key = jax.random.PRNGKey(seed)

        # same observability plane as the streaming runtime: prefill and
        # decode-tick latencies land in bounded histograms, spans cover
        # both jitted paths (fenced — decode is async-dispatched), and
        # request lifecycle goes to the structured event log
        self.obs = obs if obs is not None else Observability.create()
        self._prefill_hist = self.obs.registry.histogram("serve.prefill_s")
        self._decode_hist = self.obs.registry.histogram("serve.decode_tick_s")
        self._decode = jax.jit(api.decode_fn(cfg))
        self._prefill_one = jax.jit(self._make_prefill())
        self.state = api.init_decode_state(cfg, batch_slots, max_seq)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.slot_remaining = np.zeros(batch_slots, np.int32)
        self.queue: list[Request] = []

    # -- prefill -------------------------------------------------------------

    def _make_prefill(self):
        """Sequential prefill via the decode step (token-by-token through a
        scan) — shape-stable for any prompt padded to max_seq.  Production
        prefill uses the parallel path (api.prefill_fn), which the dry-run
        lowers; this engine variant keeps per-slot cache surgery trivial."""
        cfg = self.cfg
        decode = api.decode_fn(cfg)

        def prefill(params, state, prompt, length):
            def step(carry, tok):
                st, last = carry
                logits, st = decode(params, st, tok[None, None])
                return (st, logits[0, -1]), None

            (state, last_logits), _ = jax.lax.scan(
                step, (state, jnp.zeros((self.cfg.padded_vocab,))), prompt
            )
            del length
            return state, last_logits

        return prefill

    # -- queue management ------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)
        self.obs.events.emit("lm_submit", rid=req.rid,
                             prompt_tokens=len(req.prompt),
                             max_new=req.max_new_tokens)

    def _fill_slots(self) -> None:
        for slot in range(self.slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                with self.obs.trace.span("prefill", rid=req.rid,
                                         tokens=len(req.prompt)):
                    t0 = time.perf_counter()
                    st1 = api.init_decode_state(self.cfg, 1, self.max_seq)
                    st1, last_logits = self._prefill_one(
                        self.params, st1, jnp.asarray(req.prompt),
                        len(req.prompt)
                    )
                    tok = int(
                        sampler.greedy(last_logits[None], self.cfg.vocab)[0]
                    )
                    self._prefill_hist.record(time.perf_counter() - t0)
                req.out_tokens.append(tok)
                self._install(slot, st1)
                self.slot_req[slot] = req
                self.slot_remaining[slot] = req.max_new_tokens - 1
                self.obs.events.emit("lm_slot_fill", slot=slot, rid=req.rid,
                                     prompt_tokens=len(req.prompt))
                log.info("slot %d <- request %d (prompt %d toks)",
                         slot, req.rid, len(req.prompt))

    def _install(self, slot: int, st1) -> None:
        """Copy a 1-batch cache pytree into batch row ``slot``."""
        def put(full, one):
            if full.ndim == 0:
                return jnp.maximum(full, one)  # cache_len: shared scalar clock
            # find the batch axis: st1 has size-1 where full has slots
            for ax in range(full.ndim):
                if full.shape[ax] == self.slots and one.shape[ax] == 1:
                    idx = [slice(None)] * full.ndim
                    idx[ax] = slice(slot, slot + 1)
                    return full.at[tuple(idx)].set(one)
            return full

        self.state = jax.tree_util.tree_map(put, self.state, st1)

    # -- decode tick -----------------------------------------------------------

    def step(self) -> list[Request]:
        """One continuous-batching tick: fill slots, decode, retire."""
        self._fill_slots()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return []
        last = jnp.asarray(
            [
                (r.out_tokens[-1] if r is not None and r.out_tokens else 0)
                for r in self.slot_req
            ],
            jnp.int32,
        )[:, None]
        with self.obs.trace.span("decode", active=len(active)):
            t0 = time.perf_counter()
            logits, self.state = self._decode(self.params, self.state, last)
            # fence: decode is async-dispatched — without it the recorded
            # tick would measure enqueue latency, not the decode step
            toks = np.asarray(sampler.greedy(logits[:, -1], self.cfg.vocab))
            self._decode_hist.record(time.perf_counter() - t0)
        self.key, sk = jax.random.split(self.key)
        finished = []
        for slot in active:
            req = self.slot_req[slot]
            req.out_tokens.append(int(toks[slot]))
            self.slot_remaining[slot] -= 1
            if self.slot_remaining[slot] <= 0:
                req.done = True
                finished.append(req)
                self.slot_req[slot] = None
                self.obs.events.emit("lm_finish", rid=req.rid, slot=slot,
                                     tokens=len(req.out_tokens))
        return finished

    def run_until_drained(self, max_ticks: int = 1000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not self.queue and all(r is None for r in self.slot_req):
                break
        return done
