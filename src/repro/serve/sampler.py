"""Token samplers (greedy / temperature / top-k) over padded-vocab logits."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mask_padded(logits: jax.Array, vocab: int) -> jax.Array:
    """Kill the vocab-padding columns so they can never be sampled."""
    v = logits.shape[-1]
    if v == vocab:
        return logits
    mask = jnp.arange(v) < vocab
    return jnp.where(mask, logits, -jnp.inf)


def greedy(logits: jax.Array, vocab: int) -> jax.Array:
    return jnp.argmax(mask_padded(logits, vocab), axis=-1).astype(jnp.int32)


def sample(key: jax.Array, logits: jax.Array, vocab: int,
           temperature: float = 1.0, top_k: int = 0) -> jax.Array:
    logits = mask_padded(logits, vocab).astype(jnp.float32)
    if temperature <= 0:
        return greedy(logits, vocab)
    logits = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits >= kth, logits, -jnp.inf)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
