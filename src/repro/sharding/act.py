"""Activation sharding constraints with logical axis names.

Model code never imports a mesh; it annotates activations with *logical*
axes ("dp" batch, "tp" tensor/model).  The launcher installs a policy
mapping logical -> mesh axes before lowering; without a policy (unit tests,
CPU smoke runs) constraints are no-ops.  Dims that do not divide the mesh
axis are silently left unconstrained (the GSPMD-legal fallback).
"""
from __future__ import annotations

import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_POLICY: dict | None = None


def set_policy(mesh, dp_axes, tp_axis="model") -> None:
    """tp_axis=None disables TP constraints (dp_only/FSDP profile)."""
    global _POLICY
    _POLICY = {"mesh": mesh, "dp": dp_axes, "tp": tp_axis}


def clear_policy() -> None:
    global _POLICY
    _POLICY = None


def _axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def axis_size(name: str) -> int | None:
    """Size of a logical axis under the active policy (None = no policy)."""
    if _POLICY is None or _POLICY.get(name) is None:
        return None
    return _axis_size(_POLICY["mesh"], _POLICY[name])


def constrain(x, *logical):
    """constrain(x, 'dp', None, 'tp', None) — skip non-divisible dims."""
    if _POLICY is None:
        return x
    import jax

    mesh = _POLICY["mesh"]
    spec = []
    for dim, name in zip(x.shape, logical):
        if name is None:
            spec.append(None)
            continue
        axes = _POLICY.get(name)
        if axes is None:
            spec.append(None)
            continue
        if dim % _axis_size(mesh, axes) == 0 and dim >= _axis_size(mesh, axes):
            spec.append(axes)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )
