"""PartitionSpec rules: DP / TP (Megatron) / EP / SP-lite per DESIGN.md §5.

Rules key on parameter path names (last components) and assign mesh axes to
the *trailing* dims; leading axes (scan repeats, expert stacking handled
explicitly) get None.  Divisibility is checked against the mesh so an
incompatible dim degrades to replication instead of a compile failure —
degradations are collected for the dry-run report.

Megatron pairing:
  column-parallel (output feature sharded): wq wk wv, wi_gate wi_up, w_up,
    w_in, lm_head, r_in/w_in (sLSTM), w_dt, conv_w
  row-parallel (input feature sharded, psum after): wo, w_out, w_qkv, w_if,
    w_x, a_log
  expert-parallel: moe wi_gate/wi_up/wo on the expert axis
  vocab-parallel: embed rows
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.utils.tree import tree_map_with_path_names

# rule: name -> (spec for trailing dims, from the right)
_COL = (None, "model")       # (in, out-sharded)
_ROW = ("model", None)       # (in-sharded, out)
_TRAILING_RULES: dict[str, tuple] = {
    "embed": _ROW,           # vocab rows sharded
    "lm_head": _COL,
    "wq": _COL, "wk": _COL, "wv": _COL, "wo": _ROW,
    "wi_gate": _COL, "wi_up": _COL,
    "w_up": _COL, "w_in": _COL, "r_in": _COL,
    "w_qkv": _ROW, "w_if": _ROW, "w_x": _ROW, "w_out": _ROW,
    "w_dt": _COL, "conv_w": _COL, "a_log": _ROW,
    "d_skip": ("model",), "skip_gamma": ("model",),
    "router": (None, None),
}
_MOE_NAMES = {"wi_gate", "wi_up", "wo"}

# Per-model-shard size above which a parameter additionally shards over the
# 'data' axis (ZeRO-3/FSDP storage sharding; GSPMD inserts the per-layer
# all-gather).  Small models stay pure-TP, 33B+ models go TP x FSDP — the
# only way 398B params + optimizer state fit a 16 GB/chip pod.
FSDP_THRESHOLD_BYTES = 32 * 1024 * 1024


@dataclasses.dataclass
class ShardingReport:
    degraded: list[str] = dataclasses.field(default_factory=list)


def _fits(shape, spec, mesh) -> bool:
    for dim, axis in zip(shape[-len(spec):], spec):
        if axis is None:
            continue
        if dim % mesh.shape[axis] != 0:
            return False
    return True


def param_pspec(name: str, leaf, mesh, cfg=None,
                report: ShardingReport | None = None) -> P:
    shape = leaf.shape
    parts = name.split("/")
    last = parts[-1]
    if last in ("step",):
        return P()

    # expert placement: fine-grained banks that fit per-shard after TP keep
    # experts UNSHARDED (grouped local-capacity dispatch, zero token
    # movement — models/moe.py); big-expert banks go expert-parallel over
    # 'data' + TP inside each expert.  Shared experts are ordinary MLPs.
    if ("moe" in parts and "shared" not in parts and last in _MOE_NAMES
            and len(shape) >= 3):
        e_, a_, b_ = shape[-3], shape[-2], shape[-1]
        bank = 3 * e_ * a_ * b_ * 2 / mesh.shape["model"]
        from repro.models.moe import GROUPED_BANK_BYTES
        if bank <= GROUPED_BANK_BYTES:
            spec = ((None, "model", None) if last == "wo"
                    else (None, None, "model"))
        elif last == "wo":        # (…, E, F, D)
            spec = ("data", "model", None)
        else:                     # wi_gate/wi_up (…, E, D, F)
            spec = ("data", None, "model")
    elif last in _TRAILING_RULES:
        spec = _TRAILING_RULES[last]
    else:
        spec = ()  # norms, scalars, biases -> replicate

    if spec and len(shape) < len(spec):
        spec = spec[-len(shape):]
    if spec and not _fits(shape, spec, mesh):
        if report is not None:
            report.degraded.append(f"{name}{tuple(shape)} !%{spec}")
        spec = ()
    full = [None] * (len(shape) - len(spec)) + list(spec)

    # FSDP: large per-shard params also shard over 'data' (storage sharding)
    if ("data" in mesh.axis_names and len(shape) >= 2
            and "data" not in full):
        shards = 1
        for ax in full:
            if ax is not None:
                shards *= mesh.shape[ax]
        itemsize = getattr(getattr(leaf, "dtype", None), "itemsize", 4)
        per_shard = int(np.prod(shape)) // shards * itemsize
        if per_shard > FSDP_THRESHOLD_BYTES:
            dsz = mesh.shape["data"]
            # largest unsharded dim divisible by the data axis, prefer trailing
            cands = [
                i for i in range(len(shape) - 1, -1, -1)
                if full[i] is None and shape[i] % dsz == 0 and shape[i] >= dsz
            ]
            if cands:
                best = max(cands, key=lambda i: shape[i])
                full[best] = "data"
    return P(*full)


def params_shardings(tree, mesh, cfg=None, report=None):
    return tree_map_with_path_names(
        lambda n, l: NamedSharding(mesh, param_pspec(n, l, mesh, cfg, report)),
        tree,
    )


# ---------------------------------------------------------------------------
# Batch / cache / state shardings
# ---------------------------------------------------------------------------

def _dp(mesh, profile: str = "megatron") -> tuple:
    """Data-parallel axes under a sharding profile.

    megatron: DP on non-model axes, TP on 'model'.
    dp_only : DP over EVERY axis (FSDP/ZeRO — the right call for models too
              small to amortize TP activation psums; §Perf).
    """
    if profile == "dp_only":
        axes = tuple(mesh.axis_names)
    else:
        axes = tuple(a for a in mesh.axis_names if a != "model")
    return axes if len(axes) > 1 else axes[0]


def dp_total(mesh, profile: str = "megatron") -> int:
    axes = _dp(mesh, profile)
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def batch_pspec(name: str, leaf, mesh, report=None, micro: bool = False,
                profile: str = "megatron") -> P:
    """tokens/labels (B, S); frontend (B, S, D); micro=True -> (M, B, …)."""
    shape = leaf.shape
    dp = _dp(mesh, profile)
    dp_size = dp_total(mesh, profile)
    b_ax = 1 if micro else 0
    if len(shape) <= b_ax or shape[b_ax] % dp_size != 0:
        if profile == "dp_only":  # fall back to the smaller dp group
            return batch_pspec(name, leaf, mesh, report, micro, "megatron")
        if report is not None:
            report.degraded.append(f"batch {name}{tuple(shape)} replicated")
        return P()
    spec = [None] * len(shape)
    spec[b_ax] = dp
    return P(*spec)


def batch_shardings(batch, mesh, report=None, micro: bool = False,
                    profile: str = "megatron"):
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(
            mesh, batch_pspec("batch", l, mesh, report, micro, profile)
        ),
        batch,
    )


def decode_state_pspec(name: str, leaf, mesh, cfg=None, report=None) -> P:
    """KV caches (reps, B, S, Hk, Dh) & recurrent states (reps, B, …).

    Batch shards over DP when divisible; otherwise (long_500k B=1) the KV
    *sequence* dim shards over the data axis (SP-lite) and recurrent state
    feature dims shard over model.
    """
    shape = leaf.shape
    if not shape:
        return P()  # cache_len scalar
    dp = _dp(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                           if a != "model"]))
    # batch axis by structure: state['layers']/'self' leaves carry a leading
    # scan-repeats axis; 'head'/'memory' leaves do not.
    top = name.split("/")[0]
    b_ax = 0 if top in ("head", "memory") else min(1, len(shape) - 1)
    if shape[b_ax] % dp_size == 0 and shape[b_ax] >= dp_size:
        spec = [None] * len(shape)
        spec[b_ax] = dp
        # caches also shard a feature dim over 'model' (a 32k x 128-batch KV
        # cache is ~500GB global — batch sharding alone cannot fit HBM).
        # For 5D KV (reps,B,S,Hk,Dh) prefer the kv-head dim (zero-comm
        # attention when Hk % model == 0), falling back to Dh (costs one
        # small logits psum, forced by the act constraint in attention.py).
        msz = mesh.shape["model"]
        if len(shape) == 5:
            # KV (reps,B,S,Hk,Dh): kv-heads first (zero-comm attention),
            # then the sequence dim (sequence-parallel attention: k/v stay
            # put, softmax reduces tiny cross-shard stats), then Dh.
            order = [3, 2, 4]
        else:
            order = list(range(len(shape) - 1, b_ax, -1))
        for ax in order:
            if ax != b_ax and shape[ax] % msz == 0 and shape[ax] >= msz:
                spec[ax] = "model"
                break
        return P(*spec)
    # batch unshardable (long_500k B=1): SP-lite — shard KV sequence over
    # 'data'; recurrent states shard a feature dim over 'model'.
    s_ax = b_ax + 1
    if len(shape) >= s_ax + 2 and shape[s_ax] % mesh.shape["data"] == 0 \
            and shape[s_ax] >= 4 * mesh.shape["data"]:
        spec = [None] * len(shape)
        spec[s_ax] = "data"
        return P(*spec)
    spec = [None] * len(shape)
    for ax in range(len(shape) - 1, b_ax, -1):
        if shape[ax] % mesh.shape["model"] == 0 and shape[ax] >= mesh.shape["model"]:
            spec[ax] = "model"
            break
    else:
        if report is not None:
            report.degraded.append(f"state {name}{tuple(shape)} replicated")
    return P(*spec)


def decode_state_shardings(state, mesh, cfg=None, report=None):
    return tree_map_with_path_names(
        lambda n, l: NamedSharding(
            mesh, decode_state_pspec(n, l, mesh, cfg, report)
        ),
        state,
    )


def logits_sharding(mesh, global_batch: int | None = None):
    dp = _dp(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                           if a != "model"]))
    if global_batch is not None and global_batch % dp_size != 0:
        return NamedSharding(mesh, P(None, None, "model"))
    return NamedSharding(mesh, P(dp, None, "model"))


def scalar_sharding(mesh):
    return NamedSharding(mesh, P())
