"""repro.stream — always-on multi-stream keyword-spotting runtime.

The offline pipeline (core/compiler + core/executor) runs one compiled
program over one whole utterance.  This package turns the same exported
model (spec + ternary weights + SA thresholds) into an *incremental*
runtime: audio arrives chunk by chunk on thousands of concurrent streams,
each new hop only computes the receptive-field tail of every conv layer,
and all active streams share one batched, jitted step (one CIM macro, many
users).  Per-hop finalized logits are computed *inside* that step by the
fused finalization tail, and the slot pool grows/shrinks elastically at
power-of-two batch sizes.  The streaming math is bit-exact with the
offline executor — see tests/test_stream.py for the golden-equivalence
proof and docs/ARCHITECTURE.md for the full data-flow walkthrough.

The slot pool can also span a whole device mesh (one logical pool, not
one pool per device — the paper's one-large-macro argument): pass
``mesh=launch.mesh.make_stream_mesh()`` and every batched state array
shards its batch axis over the mesh's ``"data"`` axis with the weights
replicated, bit-exactly (tests/test_stream_sharded.py).

The batched step is also *multi-tenant*: construct with ``max_models=K``
and ``register_model(id, weights, thresholds)`` admits up to K complete
model variants (same plan geometry) into one stacked ``WeightPool``;
streams bound to different tenants ride the SAME hop dispatch — the
kernels gather each slot-block's weight planes by a per-slot model index,
so launches/hop stay K-independent (docs/ARCHITECTURE.md, "Multi-tenant
weight pools").

The host ingest plane is struct-of-arrays: every stream's sample inbox is
one row of a shared ``RingArena`` (uint8, widened to int32 only at pack
time), so the steady-state hop packs all ready inboxes with one vectorized
gather, pushes land via ``StreamScheduler.push_audio_batch`` (one quantize
+ one scatter for many streams), and detection advances through the
slot-vectorized ``BatchedDetector`` — zero per-slot python on the hop hot
path.

Modules:
  frontend   incremental PCM -> 8-bit offset-binary model frames (thin
             per-stream facade over the shared RingArena)
  state      stream plan, ring buffers + shared RingArena, per-stream +
             batched conv state, slot->shard placement (SlotPlacement)
  scheduler  elastic continuous-batching scheduler (jitted step with
             in-jit finalization tail, optional mesh sharding)
  detector   posterior smoothing + hysteresis/refractory event logic
             (per-stream oracle + slot-vectorized BatchedDetector)
  metrics    fleet counters split host-pack vs device per hop + measured
             EnergyLedger charges
  async_plane  AsyncStreamScheduler: background ingest pump +
             double-buffered hop dispatch with deferred FIFO folds —
             bit-identical results, host work hidden under device
             compute (epoch barriers around resize/rebalance/priming)

Quickstart — join / feed / poll / close (``pydoc repro.stream``):

    import numpy as np
    from repro.models import kws
    from repro.stream import StreamScheduler

    # any exported model works; here: untrained smoke-size weights
    import jax
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)

    sched = StreamScheduler(spec, weights, thresholds, capacity=64)
    sid = sched.add_stream()                      # join (pool auto-grows)
    mic = np.zeros(16000, np.uint8) + 128         # 1 s of silence codes
    for i in range(0, len(mic), 160):
        sched.push_audio(sid, mic[i : i + 160])   # feed ~10 ms chunks
        for sid_, frame, logits, event in sched.step():   # poll
            if event is not None:
                print("keyword", event.cls, "on stream", sid_)
    result = sched.close_stream(sid)              # flush; slot pool shrinks
    print(result.logits)  # bit-exact with the offline executor

Every ``step()`` advances all streams holding a full hop with ONE jitted
batched call and returns ``(sid, frame, logits, event)`` per advanced
stream, where ``logits`` are the exact logits the offline executor would
produce if that stream's utterance ended at this hop.
"""
from repro.stream.async_plane import AsyncStreamScheduler, IngestPump
from repro.stream.detector import (
    BatchedDetector,
    Detection,
    DetectorConfig,
    PosteriorDetector,
)
from repro.stream.frontend import AudioFrontend, quantize_pcm
from repro.stream.metrics import StreamMetrics, plan_hop_ledger
from repro.stream.scheduler import (
    DEFAULT_MODEL,
    HopBatch,
    StreamResult,
    StreamScheduler,
    WeightPool,
    param_cache_stats,
    prepared_model_params,
)
from repro.stream.state import (
    FrameRing,
    RingArena,
    SlotPlacement,
    StreamPlan,
    StreamState,
    plan_stream,
    prime_batch,
)

__all__ = [
    "AsyncStreamScheduler",
    "AudioFrontend",
    "DEFAULT_MODEL",
    "WeightPool",
    "param_cache_stats",
    "prepared_model_params",
    "BatchedDetector",
    "Detection",
    "DetectorConfig",
    "FrameRing",
    "HopBatch",
    "IngestPump",
    "PosteriorDetector",
    "RingArena",
    "SlotPlacement",
    "StreamMetrics",
    "StreamPlan",
    "StreamResult",
    "StreamScheduler",
    "StreamState",
    "plan_hop_ledger",
    "plan_stream",
    "prime_batch",
    "quantize_pcm",
]
