"""repro.stream — always-on multi-stream keyword-spotting runtime.

The offline pipeline (core/compiler + core/executor) runs one compiled
program over one whole utterance.  This package turns the same exported
model (spec + ternary weights + SA thresholds) into an *incremental*
runtime: audio arrives chunk by chunk on thousands of concurrent streams,
each new hop only computes the receptive-field tail of every conv layer,
and all active streams share one batched, jitted step (one CIM macro, many
users).  The streaming math is bit-exact with the offline executor — see
tests/test_stream.py for the golden-equivalence proof.

Modules:
  frontend   incremental PCM -> 8-bit offset-binary model frames
  state      stream plan, ring buffers, per-stream + batched conv state
  scheduler  continuous-batching multi-stream scheduler (jitted step)
  detector   posterior smoothing + hysteresis/refractory event logic
  metrics    per-stream latency/throughput counters + energy estimates
"""
from repro.stream.detector import Detection, DetectorConfig, PosteriorDetector
from repro.stream.frontend import AudioFrontend, quantize_pcm
from repro.stream.metrics import StreamMetrics
from repro.stream.scheduler import StreamScheduler
from repro.stream.state import FrameRing, StreamPlan, StreamState, plan_stream

__all__ = [
    "AudioFrontend",
    "Detection",
    "DetectorConfig",
    "FrameRing",
    "PosteriorDetector",
    "StreamMetrics",
    "StreamPlan",
    "StreamScheduler",
    "StreamState",
    "plan_stream",
    "quantize_pcm",
]
