"""Async execution plane: overlap ingest, pack, and device compute.

The synchronous ``StreamScheduler.step_batch`` is strictly serial —
push -> pack -> dispatch -> block -> fold — so ~1 ms of host pack and
the detector fold sit on the critical path even though device compute
dominates at scale.  This module is the runtime-level twin of the
paper's flexible ping-pong feature SRAM (§II-C): stage the next tile
while the current one computes.

``AsyncStreamScheduler`` keeps the scheduler's math, state, and slot
machinery byte-for-byte identical and changes only *when* the host-side
stages run:

  * **Ingest pump** — ``push_audio_batch`` enqueues to a daemon thread
    that lands samples in the shared ``RingArena`` (one flat scatter,
    PR 4) while the main thread packs and dispatches.  Arena mutations
    are serialized by the scheduler's ingest lock and marked by the
    arena's seqlock generation, so lock-free observers can detect (and
    retry past) a torn read instead of consuming one.
  * **Double-buffered hop dispatch** — pack hop N+1 and launch it on
    hop N's *unforced* result futures (JAX async dispatch chains them
    device-side).  With ``donate_buffers`` (default on) the slot-state
    operands are donated to each step, so a restep aliases instead of
    copying tails/pendings.  The fence + fold for hop N run at its
    *retirement*, when hop N+1 is already executing — the pack,
    detector, and metrics work hide under device compute.
  * **Deferred FIFO fold** — retirements apply detector/metrics/event
    results strictly in dispatch order, so every slot sees the exact
    posterior sequence the synchronous schedule would produce:
    detections, hysteresis state, frame counts, and the event log's
    per-stream lifecycle are bit-identical (tests/test_async.py).
  * **Epoch barriers** — elastic resize, cross-shard rebalance,
    mass-join priming, ``peek``, and ``close_stream`` first drain every
    in-flight hop, then remap/prime exactly as the synchronous path
    would, then let the pipeline refill.  ``SlotPlacement``,
    ``ops.remap_slot_rows``, and ``prime_batch`` are untouched; a remap
    can never invalidate an in-flight hop's row indices.

Pipeline depth is 1 by default (classic double buffering); deeper
pipelines only add queue latency before the fold without increasing
overlap, since one hop's compute already hides the next hop's host work.
"""
from __future__ import annotations

import dataclasses
import threading

import jax
import numpy as np

# the double-buffered in-flight queue, epoch-barrier protocol, and the
# ingest pump are the generic async plane (shared with the LM engine);
# IngestPump is re-exported because this module is its historical home
from repro.runtime.async_plane import InFlightQueue, IngestPump
from repro.stream.detector import Detection
from repro.stream.scheduler import HopBatch, StreamResult, StreamScheduler

__all__ = ["AsyncStreamScheduler", "IngestPump"]


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unretired hop: the host-side inputs its
    deferred fold needs, plus the device-result futures to fence on."""

    ready_slots: np.ndarray
    shard_counts: np.ndarray
    logits: object | None     # device future ((capacity, classes))
    post: object | None
    t0: float
    t_pack: float
    t_dispatch: float
    hidden_s: float           # pack+dispatch wall already under device


class AsyncStreamScheduler(StreamScheduler):
    """``StreamScheduler`` with the async execution plane switched on.

    Drop-in: the constructor, ``push_audio*``, ``step``/``step_batch``,
    ``drain``, ``peek``, ``close_stream`` signatures are unchanged and
    the results are bit-identical to the synchronous scheduler for any
    interleaving of calls.  The differences are operational:

      * ``push_audio_batch`` returns before samples land (the pump
        applies them; push errors surface at the next ``flush``/
        ``drain``/``peek``/``close_stream``);
      * ``step_batch`` may return ``None`` for a hop it *dispatched*
        (still in flight) and returns hop N's results while hop N+1
        executes — results arrive one call later than the sync path,
        in the same order;
      * ``drain()`` is the safe settling point: pump flushed, every
        in-flight hop retired, every ghost end-of-stream flush
        performed before it returns.

    Use ``shutdown()`` (or rely on the daemon pump dying with the
    process) when discarding the scheduler.
    """

    def __init__(self, *args, pipeline_depth: int = 1,
                 use_pump: bool = True, **kwargs) -> None:
        kwargs.setdefault("donate_buffers", True)
        super().__init__(*args, **kwargs)
        assert pipeline_depth >= 1, pipeline_depth
        self._depth = pipeline_depth
        self._inflight = InFlightQueue(self._retire_inflight,
                                       depth=pipeline_depth)
        self._dispatched_total = 0
        # serializes arena/placement/bookkeeping mutations between the
        # main thread (pack/fold/lifecycle) and the pump (push scatter);
        # the device queue itself needs no lock — only the main thread
        # dispatches
        self._lock = threading.RLock()
        # declare the epoch barrier to the slot pool: EVERY structural
        # mutation (grow-on-alloc, shrink-on-close, cross-shard
        # rebalance) drains the pipeline first, on every path, instead of
        # per-call-site overrides
        self._slots.pre_structural = self._pre_structural
        self._pump = IngestPump(self._apply_push) if use_pump else None

    # -- ingest (pumped) -----------------------------------------------------

    def _apply_push(self, sids, chunks) -> None:
        with self._lock:
            StreamScheduler.push_audio_batch(self, sids, chunks)

    def push_audio_batch(self, sids, chunks) -> None:
        if self._pump is None:
            self._apply_push(sids, chunks)
        else:
            self._pump.submit(sids, chunks)

    def push_audio(self, sid: int, audio: np.ndarray) -> None:
        # route the scalar push through the pump too (one-element batch:
        # same arena counters, same quantize math)
        self.push_audio_batch([sid], [audio])

    def flush_ingest(self) -> None:
        """Wait until every submitted push has landed in the arena."""
        if self._pump is not None:
            self._pump.flush()

    # -- pipeline core -------------------------------------------------------

    @property
    def in_flight(self) -> int:
        """Dispatched hops whose fold has not retired yet."""
        return len(self._inflight)

    def _retire_inflight(self, f: _InFlight, still_in_flight: bool
                         ) -> HopBatch:
        """Retire function the ``InFlightQueue`` drives: fence on one hop
        and run its deferred fold.  The fence blocks OUTSIDE the ingest
        lock so pushes keep landing while the device finishes; the fold
        itself (detector, metrics, events, emit cache) runs under the
        lock, in FIFO dispatch order."""
        if f.logits is not None:
            jax.block_until_ready(f.logits)
            logits_h = np.asarray(f.logits)  # one bulk transfer per hop
            post_h = np.asarray(f.post)
        else:
            # emit off: no per-hop output future survives donation, so
            # fence the resident state (syncs every queued hop <= now)
            jax.block_until_ready((self._tails, self._pendings, self._gap))
            logits_h = post_h = None
        t_device = self._clock()
        with self._lock:
            return self._fold_hop(
                f.ready_slots, f.shard_counts, logits_h, post_h,
                f.t0, f.t_pack, f.t_dispatch, t_device,
                hidden_s=f.hidden_s, fold_hidden=still_in_flight,
            )

    def _retire_one(self) -> HopBatch:
        """Fence on the oldest in-flight hop and run its deferred fold."""
        return self._inflight.retire_oldest()

    def _epoch_barrier(self) -> None:
        """Retire every in-flight hop.  Callers then hold the invariant
        the synchronous scheduler has between steps: all folds applied,
        no future references any slot row — so resize / rebalance /
        priming / teardown remaps run exactly as they do synchronously."""
        self._inflight.barrier()

    def _pre_structural(self) -> None:
        """SlotPool hook: a structural slot mutation is about to run —
        drain the pipeline so a remap never invalidates in-flight row
        indices (the epoch-barrier protocol, declared once)."""
        with self._lock:
            self._epoch_barrier()

    def _advance(self) -> tuple[bool, HopBatch | None]:
        """One pipeline turn: dispatch a hop if any stream is ready, and
        retire the oldest in-flight hop once the pipeline is past its
        depth (or when starved).  Returns ``(dispatched, retired)``."""
        with self._lock:
            if self._skew_dirty or self._unprimed:
                # epoch barrier: drain the pipeline, then rebalance /
                # shrink / prime at the same logical point the sync
                # scheduler would
                self._epoch_barrier()
                self._hop_barriers()
            packed = self._pack_ready()
            if packed is not None:
                (ready_slots, ready_mask, audio, shard_counts,
                 t0, t_pack) = packed
                was_busy = bool(self._inflight)
                logits, post = self._dispatch_hop(ready_mask, audio)
                t_dispatch = self._clock()
                self._inflight.push(_InFlight(
                    ready_slots=ready_slots, shard_counts=shard_counts,
                    logits=logits, post=post,
                    t0=t0, t_pack=t_pack, t_dispatch=t_dispatch,
                    # this hop's pack+dispatch ran while an earlier hop
                    # was executing: that host wall is hidden
                    hidden_s=(t_dispatch - t0) if was_busy else 0.0,
                ))
                self._dispatched_total += 1
            else:
                self._maybe_prewarm()  # starved turn: warm next capacity
        dispatched = packed is not None
        # depth policy (retire at most one per turn): the queue retires
        # once the pipeline is past its depth, or when starved and hops
        # remain to drain
        retired_list = self._inflight.settle(dispatched, max_retire=1)
        return dispatched, (retired_list[0] if retired_list else None)

    # -- public stepping -----------------------------------------------------

    def step_batch(self) -> HopBatch | None:
        """One pipeline turn.  Unlike the sync scheduler, ``None`` can
        mean "hop dispatched, results not retired yet" — callers that
        need everything settled use ``drain()`` (or ``peek``/
        ``close_stream``, which barrier internally)."""
        return self._advance()[1]

    def run_until_starved(self):
        """Step until no stream has a full hop buffered AND every
        dispatched hop has retired; returns the collated tuples."""
        self.flush_ingest()
        out = []
        while True:
            dispatched, retired = self._advance()
            if retired is not None:
                out.extend(self._collate(retired))
            if not dispatched and not self._inflight:
                return out

    def drain(self) -> int:
        """Flush the pump, run the pipeline until starved, and retire
        every in-flight hop; returns hops *dispatched* by this call
        (== hops the sync scheduler would have executed)."""
        self.flush_ingest()
        before = self._dispatched_total
        while True:
            dispatched, _ = self._advance()
            if not dispatched and not self._inflight:
                return self._dispatched_total - before

    # -- epoch-barrier lifecycle overrides -----------------------------------
    #
    # resize and rebalance need NO overrides here: the SlotPool calls
    # ``_pre_structural`` (declared in __init__) before every structural
    # mutation, whichever path reaches it.

    def add_stream(self, *args, **kwargs) -> int:
        with self._lock:  # placement/arena bookkeeping vs pump pushes
            return super().add_stream(*args, **kwargs)

    def register_model(self, *args, **kwargs) -> int:
        with self._lock:
            # pool swap = epoch barrier: an in-flight hop still references
            # the weight row an admission may overwrite (LRU eviction)
            self._epoch_barrier()
            return super().register_model(*args, **kwargs)

    def peek(self, sid: int) -> np.ndarray:
        self.flush_ingest()  # the contract covers "audio pushed so far"
        with self._lock:
            self._epoch_barrier()
            return super().peek(sid)

    def close_stream(self, sid: int) -> StreamResult:
        self.flush_ingest()  # pending pushes for this sid must land
        with self._lock:
            self._epoch_barrier()  # fold in-flight hops, then ghost-flush
            return super().close_stream(sid)

    def detections(self, sid: int) -> list[Detection]:
        """Events recorded so far for ``sid`` (settles the pipeline)."""
        with self._lock:
            self._epoch_barrier()
            return list(self._require(sid).events)

    def shutdown(self) -> None:
        """Settle everything and stop the pump thread."""
        if self._pump is not None:
            self._pump.close()
            self._pump = None
        with self._lock:
            self._epoch_barrier()
