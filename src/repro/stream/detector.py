"""Keyword-detection event logic on per-frame streaming logits.

The model emits raw popcount-count logits once per hop.  A deployed KWS
front door never acts on a single frame: posteriors are smoothed over a
short window, a keyword fires only when the smoothed posterior crosses an
*on* threshold, and the detector then holds (refractory) until both the
posterior has fallen below a lower *off* threshold and a minimum number of
frames has elapsed — classic hysteresis, so one utterance produces exactly
one event instead of a burst.

Two implementations share the exact same semantics:

* ``PosteriorDetector`` — one python state machine per stream.  ``update``
  takes raw logits and softmaxes them on the host; ``update_posterior``
  consumes posteriors already computed on-device.  Kept as the oracle and
  for standalone use.
* ``BatchedDetector`` — the whole fleet's detector state as slot-indexed
  numpy vectors (struct-of-arrays, like ``state.RingArena``): smoothing
  windows, hold flags, refractory clocks.  One ``update_batch`` call
  advances every ready slot with array ops; per-slot python survives only
  for rows that actually fire (rare by construction).  This is what the
  scheduler drives on the hop hot path; equivalence with the per-stream
  machine is pinned by tests/test_ingest.py.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.stream.state import remap_rows


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    smooth_frames: int = 4        # moving-average window over posteriors
    on_threshold: float = 0.6     # smoothed posterior to fire
    off_threshold: float = 0.4    # smoothed posterior to re-arm
    refractory_frames: int = 10   # min frames between events
    keyword_classes: tuple[int, ...] = tuple(range(10))  # 10/11 = unk/sil


@dataclasses.dataclass(frozen=True)
class Detection:
    stream_id: int
    cls: int
    frame: int      # final-conv frame index at which the event fired
    score: float    # smoothed posterior at fire time


def _softmax(logits: np.ndarray) -> np.ndarray:
    z = logits.astype(np.float64) - logits.max()
    e = np.exp(z)
    return e / e.sum()


class PosteriorDetector:
    """Per-stream smoothing + hysteresis/refractory state machine."""

    def __init__(self, stream_id: int, cfg: DetectorConfig | None = None) -> None:
        self.stream_id = stream_id
        self.cfg = cfg or DetectorConfig()
        self._window: collections.deque[np.ndarray] = collections.deque(
            maxlen=self.cfg.smooth_frames
        )
        self._holding = False
        self._hold_cls = -1
        self._fired_at = -(10**9)
        self.events: list[Detection] = []

    def smoothed(self) -> np.ndarray:
        assert self._window, "no frames seen yet"
        return np.mean(np.stack(self._window), axis=0)

    def update(self, frame: int, logits: np.ndarray) -> Detection | None:
        """Feed one frame of raw logits (host-side softmax); returns a
        Detection iff one fires."""
        return self.update_posterior(frame, _softmax(np.asarray(logits)))

    def update_posterior(self, frame: int,
                         posterior: np.ndarray) -> Detection | None:
        """Feed one frame of already-normalized posteriors (e.g. the
        on-device softmax from the scheduler's finalization tail)."""
        cfg = self.cfg
        self._window.append(np.asarray(posterior, np.float64))
        if len(self._window) < cfg.smooth_frames:
            # a partial window would let one confident-wrong frame (common
            # right after priming, when the field is mostly padding) bypass
            # the glitch suppression the smoother exists for
            return None
        post = self.smoothed()
        kw = np.asarray(cfg.keyword_classes)
        best = int(kw[np.argmax(post[kw])])
        score = float(post[best])

        if self._holding:
            # re-arm only after the held keyword decays AND refractory passes
            held = float(post[self._hold_cls])
            if (held <= cfg.off_threshold
                    and frame - self._fired_at >= cfg.refractory_frames):
                self._holding = False
            return None

        if score >= cfg.on_threshold:
            self._holding = True
            self._hold_cls = best
            self._fired_at = frame
            det = Detection(self.stream_id, best, frame, score)
            self.events.append(det)
            return det
        return None


_NEVER = -(10**9)  # "fired long ago": refractory never blocks the first event


class BatchedDetector:
    """Slot-vectorized smoothing + hysteresis for the whole slot pool.

    State per slot: a ring of the last ``smooth_frames`` posteriors (kept
    in arrival order at read time so the float64 mean accumulates in the
    same order as the per-stream deque — bit-identical smoothing), the
    hold flag/class, and the last fire frame.  ``update_batch`` advances
    many slots with pure array ops and returns only the rows that fired;
    ``apply_remap`` follows ``SlotPlacement`` through elastic resizes like
    every other slot-indexed array.
    """

    def __init__(self, capacity: int, n_classes: int,
                 cfg: DetectorConfig | None = None) -> None:
        self.cfg = cfg or DetectorConfig()
        self.n_classes = n_classes
        self._kw = np.asarray(self.cfg.keyword_classes, np.int64)
        W = self.cfg.smooth_frames
        self._win = np.zeros((capacity, W, n_classes), np.float64)
        self._count = np.zeros(capacity, np.int64)
        self._holding = np.zeros(capacity, bool)
        self._hold_cls = np.zeros(capacity, np.int64)
        self._fired_at = np.full(capacity, _NEVER, np.int64)

    @property
    def capacity(self) -> int:
        return self._count.shape[0]

    def reset_slot(self, slot: int) -> None:
        """Scrub one slot for its next tenant."""
        self._win[slot] = 0.0
        self._count[slot] = 0
        self._holding[slot] = False
        self._hold_cls[slot] = 0
        self._fired_at[slot] = _NEVER

    def state_digest(self, slot: int) -> tuple:
        """One slot's full hysteresis state as hashable plain values —
        the concurrency suite's equality probe: after any interleaving,
        the async scheduler's detector must hold bit-identical state to
        the synchronous one (deferred folds retire in FIFO dispatch
        order, so each slot sees the same posterior sequence)."""
        return (
            self._win[slot].tobytes(),
            int(self._count[slot]),
            bool(self._holding[slot]),
            int(self._hold_cls[slot]),
            int(self._fired_at[slot]),
        )

    def apply_remap(self, remap: dict[int, int], new_capacity: int) -> None:
        self._win = remap_rows(self._win, remap, new_capacity)
        self._count = remap_rows(self._count, remap, new_capacity)
        self._holding = remap_rows(self._holding, remap, new_capacity)
        self._hold_cls = remap_rows(self._hold_cls, remap, new_capacity)
        self._fired_at = remap_rows(self._fired_at, remap, new_capacity,
                                    fill=_NEVER)

    def update_batch(self, slots: np.ndarray, frames: np.ndarray,
                     posteriors: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Feed one posterior frame to each of ``slots``; returns
        ``(rows, cls, score)`` — indices INTO ``slots`` that fired, with
        the detected class and smoothed score.  No python loop over slots.
        """
        cfg = self.cfg
        W = cfg.smooth_frames
        slots = np.asarray(slots, np.int64)
        frames = np.asarray(frames, np.int64)
        self._win[slots, self._count[slots] % W] = posteriors
        self._count[slots] += 1
        count = self._count[slots]
        full = count >= W
        # gather each slot's window in ARRIVAL order (oldest first) so the
        # float64 mean sums in the same order as PosteriorDetector's deque
        order = (count[:, None] + np.arange(W)[None, :]) % W
        post = self._win[slots[:, None], order].mean(axis=1)
        r = np.arange(slots.size)
        best = self._kw[np.argmax(post[:, self._kw], axis=1)]
        score = post[r, best]
        holding = self._holding[slots].copy()
        # holding rows re-arm only after the held keyword decays AND the
        # refractory passes; a row released this frame cannot also fire
        held = post[r, self._hold_cls[slots]]
        release = holding & full & (held <= cfg.off_threshold) & (
            frames - self._fired_at[slots] >= cfg.refractory_frames
        )
        self._holding[slots[release]] = False
        fire = full & ~holding & (score >= cfg.on_threshold)
        rows = np.nonzero(fire)[0]
        self._holding[slots[rows]] = True
        self._hold_cls[slots[rows]] = best[rows]
        self._fired_at[slots[rows]] = frames[rows]
        return rows, best[rows], score[rows]
