"""Keyword-detection event logic on per-frame streaming logits.

The model emits raw popcount-count logits once per hop.  A deployed KWS
front door never acts on a single frame: posteriors are smoothed over a
short window, a keyword fires only when the smoothed posterior crosses an
*on* threshold, and the detector then holds (refractory) until both the
posterior has fallen below a lower *off* threshold and a minimum number of
frames has elapsed — classic hysteresis, so one utterance produces exactly
one event instead of a burst.

Two entry points feed the state machine: ``update`` takes raw logits and
softmaxes them on the host, while ``update_posterior`` consumes posteriors
that were already computed on-device — the scheduler's in-jit finalization
tail emits softmax posteriors alongside the logits, so the per-hop hot
path never re-derives them here.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    smooth_frames: int = 4        # moving-average window over posteriors
    on_threshold: float = 0.6     # smoothed posterior to fire
    off_threshold: float = 0.4    # smoothed posterior to re-arm
    refractory_frames: int = 10   # min frames between events
    keyword_classes: tuple[int, ...] = tuple(range(10))  # 10/11 = unk/sil


@dataclasses.dataclass(frozen=True)
class Detection:
    stream_id: int
    cls: int
    frame: int      # final-conv frame index at which the event fired
    score: float    # smoothed posterior at fire time


def _softmax(logits: np.ndarray) -> np.ndarray:
    z = logits.astype(np.float64) - logits.max()
    e = np.exp(z)
    return e / e.sum()


class PosteriorDetector:
    """Per-stream smoothing + hysteresis/refractory state machine."""

    def __init__(self, stream_id: int, cfg: DetectorConfig | None = None) -> None:
        self.stream_id = stream_id
        self.cfg = cfg or DetectorConfig()
        self._window: collections.deque[np.ndarray] = collections.deque(
            maxlen=self.cfg.smooth_frames
        )
        self._holding = False
        self._hold_cls = -1
        self._fired_at = -(10**9)
        self.events: list[Detection] = []

    def smoothed(self) -> np.ndarray:
        assert self._window, "no frames seen yet"
        return np.mean(np.stack(self._window), axis=0)

    def update(self, frame: int, logits: np.ndarray) -> Detection | None:
        """Feed one frame of raw logits (host-side softmax); returns a
        Detection iff one fires."""
        return self.update_posterior(frame, _softmax(np.asarray(logits)))

    def update_posterior(self, frame: int,
                         posterior: np.ndarray) -> Detection | None:
        """Feed one frame of already-normalized posteriors (e.g. the
        on-device softmax from the scheduler's finalization tail)."""
        cfg = self.cfg
        self._window.append(np.asarray(posterior, np.float64))
        if len(self._window) < cfg.smooth_frames:
            # a partial window would let one confident-wrong frame (common
            # right after priming, when the field is mostly padding) bypass
            # the glitch suppression the smoother exists for
            return None
        post = self.smoothed()
        kw = np.asarray(cfg.keyword_classes)
        best = int(kw[np.argmax(post[kw])])
        score = float(post[best])

        if self._holding:
            # re-arm only after the held keyword decays AND refractory passes
            held = float(post[self._hold_cls])
            if (held <= cfg.off_threshold
                    and frame - self._fired_at >= cfg.refractory_frames):
                self._holding = False
            return None

        if score >= cfg.on_threshold:
            self._holding = True
            self._hold_cls = best
            self._fired_at = frame
            det = Detection(self.stream_id, best, frame, score)
            self.events.append(det)
            return det
        return None
