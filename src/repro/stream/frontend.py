"""Incremental audio frontend: raw PCM chunks -> model-input frames.

The PSCNN model eats 8-bit offset-binary samples directly (the first conv
layer is the feature extractor), so the streaming frontend's job is
(1) quantization of float PCM with a fixed gain — streaming cannot use the
offline corpus's per-clip peak normalization because the clip never ends —
and (2) reassembly of arbitrary-sized network chunks into whole hops via a
ring buffer, absorbing jitter between producer (mic/RTP packets) and
consumer (the batched scheduler step).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.stream.state import FrameRing

IN_OFFSET = 128  # offset-binary zero code (models/kws.py)


def quantize_pcm(x: np.ndarray, gain: float = 1.0) -> np.ndarray:
    """float PCM in [-1, 1] -> u8 offset-binary codes (fixed gain)."""
    q = np.round(np.clip(x * gain, -1.0, 1.0) * 127.0) + IN_OFFSET
    return np.clip(q, 0, 255).astype(np.uint8)


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    gain: float = 1.0
    capacity_samples: int = 1 << 16  # jitter buffer depth


class AudioFrontend:
    """Per-stream inbox: push float or u8 audio, pop whole hops.

    ``push`` accepts either u8 offset-binary codes (passed through
    untouched, preserving bit-exactness with offline runs) or float PCM
    (quantized with the fixed gain).
    """

    def __init__(self, cfg: FrontendConfig | None = None) -> None:
        self.cfg = cfg or FrontendConfig()
        self._ring = FrameRing(self.cfg.capacity_samples, 1, np.int32)
        self.samples_in = 0

    def __len__(self) -> int:
        return len(self._ring)

    def push(self, audio: np.ndarray) -> None:
        audio = np.asarray(audio)
        if audio.dtype.kind == "f":
            audio = quantize_pcm(audio, self.cfg.gain)
        audio = audio.reshape(-1, 1).astype(np.int32)
        self._ring.push(audio)
        self.samples_in += audio.shape[0]

    def pop(self, n: int) -> np.ndarray:
        """Oldest n samples as (n,) int32 u8-codes."""
        return self._ring.pop(n)[:, 0]

    def pop_all(self) -> np.ndarray:
        return self.pop(len(self._ring))

    def peek_all(self) -> np.ndarray:
        """Buffered samples without consuming them."""
        return self._ring.peek()[:, 0]
