"""Incremental audio frontend: raw PCM chunks -> model-input frames.

The PSCNN model eats 8-bit offset-binary samples directly (the first conv
layer is the feature extractor), so the streaming frontend's job is
(1) quantization of float PCM with a fixed gain — streaming cannot use the
offline corpus's per-clip peak normalization because the clip never ends —
and (2) reassembly of arbitrary-sized network chunks into whole hops,
absorbing jitter between producer (mic/RTP packets) and consumer (the
batched scheduler step).

The storage itself lives in ``state.RingArena``: ONE shared uint8 sample
buffer for every stream slot, so the scheduler's hop hot path quantizes,
scatters and gathers all inboxes with vectorized calls instead of walking
per-stream ring objects.  ``AudioFrontend`` survives as the thin
per-stream facade over one arena row — same push/pop/peek API as the
pre-arena per-stream ring, now O(1) python objects per stream instead of
O(1) python *work per stream per hop*.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.stream.state import IN_OFFSET, RingArena, quantize_pcm

__all__ = ["IN_OFFSET", "AudioFrontend", "FrontendConfig", "quantize_pcm"]


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    gain: float = 1.0
    capacity_samples: int = 1 << 16  # jitter buffer depth


class AudioFrontend:
    """Per-stream inbox view: push float or u8 audio, pop whole hops.

    ``push`` accepts either u8 offset-binary codes (passed through
    untouched, preserving bit-exactness with offline runs; out-of-range
    integer codes are rejected with a clear error) or float PCM (quantized
    with the fixed gain).

    Constructed standalone it owns a private 1-row arena (the old
    per-stream-ring contract); the scheduler instead binds every stream's
    facade to a row of ONE shared ``RingArena`` so the hop hot path never
    touches these objects.  ``capacity_samples`` is a property of the
    arena: under a scheduler, the pool-wide ``inbox_samples`` wins over
    the per-stream config value.
    """

    def __init__(self, cfg: FrontendConfig | None = None, *,
                 arena: RingArena | None = None, slot: int = 0) -> None:
        self.cfg = cfg or FrontendConfig()
        if arena is None:
            arena = RingArena(1, self.cfg.capacity_samples)
            slot = 0
        self._arena = arena
        self._slot = slot
        arena.set_gain(slot, self.cfg.gain)

    def __len__(self) -> int:
        return self._arena.fill_of(self._slot)

    @property
    def samples_in(self) -> int:
        return int(self._arena.samples_in[self._slot])

    @property
    def chunks_in(self) -> int:
        """Chunks this stream has pushed (arena-counted, like
        ``samples_in``; duplicate-sid batch pushes count each chunk)."""
        return int(self._arena.chunks_in[self._slot])

    def push(self, audio: np.ndarray) -> None:
        self._arena.push(self._slot, audio)

    def pop(self, n: int) -> np.ndarray:
        """Oldest n samples as (n,) int32 u8-codes."""
        return self._arena.pop(self._slot, n)

    def pop_all(self) -> np.ndarray:
        return self.pop(len(self))

    def peek_all(self) -> np.ndarray:
        """Buffered samples without consuming them."""
        return self._arena.peek(self._slot)
