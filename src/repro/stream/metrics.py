"""Per-stream and fleet-level counters for the streaming runtime.

Tracks what a serving dashboard needs — frames/sec, streams/sec, step
latency percentiles, real-time factor, slot-pool resizes — and bridges
into the existing energy model (core/energy.py): each steady-state hop has
a statically known MAC/SA budget from the StreamPlan, so the aggregator
can report the silicon-equivalent energy/inference-second the fleet would
draw, in the paper's Table-I accounting convention.

Step timing covers the whole per-hop pipeline *including* per-slot
finalized logits: finalization runs inside the jitted step (the fused
tail), so there is no separate host-side peek bucket to account for — the
step latency percentile IS the hop-to-logits latency.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.energy import EnergyParams
from repro.stream.state import StreamPlan


@dataclasses.dataclass
class StreamCounters:
    stream_id: int
    joined_at: float
    samples_in: int = 0
    frames_out: int = 0
    steps: int = 0
    detections: int = 0
    closed_at: float | None = None


class StreamMetrics:
    """Aggregates per-stream counters + per-step wall latencies."""

    def __init__(self, plan: StreamPlan, sample_rate: int = 16000) -> None:
        self.plan = plan
        self.sample_rate = sample_rate
        self.streams: dict[int, StreamCounters] = {}
        self.retired: list[StreamCounters] = []  # closed tenants of reused sids
        self.step_wall_s: list[float] = []
        self.step_streams: list[int] = []
        self.capacity_events: list[tuple[float, int]] = []  # (t, new_cap)
        self._t0 = time.perf_counter()

    # -- recording -----------------------------------------------------------

    def on_join(self, sid: int) -> None:
        old = self.streams.get(sid)
        if old is not None:  # sid reuse: keep the first tenant's totals
            self.retired.append(old)
        self.streams[sid] = StreamCounters(sid, time.perf_counter() - self._t0)

    def on_audio(self, sid: int, n_samples: int) -> None:
        self.streams[sid].samples_in += n_samples

    def on_step(self, ready_sids: list[int], frames_each: int, wall_s: float) -> None:
        self.step_wall_s.append(wall_s)
        self.step_streams.append(len(ready_sids))
        for sid in ready_sids:
            c = self.streams[sid]
            c.steps += 1
            c.frames_out += frames_each

    def on_detection(self, sid: int) -> None:
        self.streams[sid].detections += 1

    def on_resize(self, new_capacity: int) -> None:
        """Elastic slot pool grew or shrank (scheduler._resize)."""
        self.capacity_events.append(
            (time.perf_counter() - self._t0, new_capacity)
        )

    def on_close(self, sid: int) -> None:
        self.streams[sid].closed_at = time.perf_counter() - self._t0

    # -- reporting -----------------------------------------------------------

    def frames_total(self) -> int:
        return sum(c.frames_out for c in self.streams.values()) + sum(
            c.frames_out for c in self.retired
        )

    def summary(self) -> dict[str, float]:
        wall = np.asarray(self.step_wall_s) if self.step_wall_s else np.zeros(1)
        frames = self.frames_total()
        elapsed = sum(self.step_wall_s) or 1e-12
        audio_s = frames * self.plan.samples_per_frame / self.sample_rate
        return {
            "streams": float(len(self.streams) + len(self.retired)),
            "steps": float(len(self.step_wall_s)),
            "frames_total": float(frames),
            "frames_per_sec": frames / elapsed,
            "audio_sec_per_wall_sec": audio_s / elapsed,  # real-time factor
            "step_ms_p50": float(np.percentile(wall, 50) * 1e3),
            "step_ms_p95": float(np.percentile(wall, 95) * 1e3),
            "mean_batch_occupancy": float(np.mean(self.step_streams))
            if self.step_streams else 0.0,
            "resizes": float(len(self.capacity_events)),
            "capacity_last": float(self.capacity_events[-1][1])
            if self.capacity_events else 0.0,
        }

    def energy_summary(self, params: EnergyParams | None = None) -> dict[str, float]:
        """Silicon-equivalent cost of the work done so far (Table-I terms).

        Conv MACs per hop come from the plan; fc MACs are charged once per
        emitted logit frame.  Bit-serial first-layer passes multiply the
        physical activations exactly as the executor charges them.
        """
        p = params or EnergyParams()
        hops = self.frames_total() / max(1, self.plan.frames_per_hop)
        conv_macs = self.plan.macs_per_hop() * hops
        fc_macs = self.plan.fc_macs() * self.frames_total()
        phys = sum(
            c.n_conv * c.k * c.cin * c.cout * c.in_bits for c in self.plan.convs
        ) * hops + fc_macs * 8  # fc input is 8-bit counts
        macs = conv_macs + fc_macs
        energy_j = p.e_mac * phys
        return {
            "macs_total": float(macs),
            "phys_macs_total": float(phys),
            "energy_uj": energy_j * 1e6,
            "tops_per_w_equiv": (macs / energy_j / 1e12) if energy_j else 0.0,
        }
