"""Per-stream, per-shard and fleet-level counters for the streaming runtime.

Tracks what a serving dashboard needs — frames/sec, streams/sec, step
latency percentiles, real-time factor, slot-pool resizes, per-shard
occupancy under a mesh — and bridges into the existing energy model
(core/energy.py): each steady-state hop has a statically known
MAC/SA/SRAM/cycle budget from the StreamPlan, so every hop charges a real
``EnergyLedger`` (the executor's accumulator, all components — not just
``e_mac``) and ``energy_summary`` reports the *measured*
silicon-equivalent TOPS/W the fleet would draw, in the paper's Table-I
accounting convention.

Step timing covers the whole per-hop pipeline *including* per-slot
finalized logits: finalization runs inside the jitted step (the fused
tail), so there is no separate host-side peek bucket to account for — the
step latency percentile IS the hop-to-logits latency.  Each step records
the split between *host packing* (building the batched audio/mask from
the shared ``RingArena`` — the part the vectorized ingest plane exists to
shrink) and everything else (device step + transfers + batched detector),
so a regression in either half is visible on its own
(``host_pack_ms_p50`` / ``device_ms_p50`` in ``summary``).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import macro
from repro.core.compiler import _pad16
from repro.core.energy import EnergyLedger, EnergyParams
from repro.core.executor import READOUT_CYCLES
from repro.stream.state import StreamPlan

# compiler.chunk_layer splits columns into one-SA-group chunks
_SA_GROUP = macro.N_SA


def plan_hop_ledger(plan: StreamPlan,
                    params: EnergyParams | None = None) -> EnergyLedger:
    """Ledger for ONE stream advancing ONE steady-state hop.

    Charges exactly what the executor's per-chunk formulas would for the
    hop's incremental work: the conv cascade reads each layer's
    receptive-field window (tail ++ new frames) once per <=128-pair column
    chunk, activates ``rows x channels x positions x in_bits`` physical
    MACs, makes one SA decision per (position, pair, bit pass), and
    writes the pooled OFM back — the streaming specialization of
    ``Executor.run``'s MAC accounting, with the window length taken from
    the plan instead of the whole clip.  The classifier tail (fc cascade
    per emitted finalization) is charged separately by
    ``plan_tail_ledger`` so logits-off deployments don't pay for it.
    """
    led = EnergyLedger(params=params or EnergyParams())
    for st in plan.convs:
        rows = st.k * st.cin
        window = st.tail + st.n_in  # frames the hop streams past the macro
        positions = st.n_conv
        for c0 in range(0, st.cout, _SA_GROUP):
            n_ch = min(_SA_GROUP, st.cout - c0)
            pairs = _pad16(n_ch)
            led.charge_mac_op(
                rows * n_ch * positions,
                rows * n_ch * positions * st.in_bits,
                positions * pairs * st.in_bits,
                positions * st.in_bits,
            )
            led.charge_sram(
                read_bits=window * st.cin
                * (st.in_bits if st.in_bits > 1 else 1)
            )
        led.charge_sram(write_bits=st.n_out * st.cout)  # pooled OFM (PWB)
    # GAP: read the final frames, bump the saturating 8-bit counters
    last = plan.convs[-1]
    led.charge_sram(read_bits=last.n_out * plan.gap_channels,
                    write_bits=plan.gap_channels * 8)
    return led


def plan_tail_ledger(plan: StreamPlan,
                     params: EnergyParams | None = None) -> EnergyLedger:
    """Ledger for ONE finalization (classifier tail) of one stream.

    Drains the saturated GAP counts through the fc cascade: 8-bit counts
    feed the first fc bit-serially, raw-output layers pay the thermometer
    SA readout sweep, and each layer writes its activations back.
    """
    led = EnergyLedger(params=params or EnergyParams())
    for st in plan.fcs:
        rows = st.cin
        for c0 in range(0, st.cout, _SA_GROUP):
            n_ch = min(_SA_GROUP, st.cout - c0)
            pairs = _pad16(n_ch)
            cyc = st.in_bits + (READOUT_CYCLES if st.out_raw else 0)
            led.charge_mac_op(
                rows * n_ch,
                rows * n_ch * st.in_bits,
                pairs * st.in_bits,
                cyc,
            )
            led.charge_sram(
                read_bits=rows * (st.in_bits if st.in_bits > 1 else 1)
            )
        led.charge_sram(write_bits=st.cout * (8 if st.out_raw else 1))
    return led


def _charge_scaled(dst: EnergyLedger, src: EnergyLedger, n: int) -> None:
    """Accumulate ``n`` copies of ``src``'s charges into ``dst``.

    Field-generic so a counter added to EnergyLedger can never be
    silently dropped from the streaming accumulation.
    """
    for f in dataclasses.fields(EnergyLedger):
        if f.name == "params":
            continue
        setattr(dst, f.name, getattr(dst, f.name) + getattr(src, f.name) * n)


@dataclasses.dataclass
class StreamCounters:
    """Per-stream dashboard counters.

    ``detections`` updates live; ``samples_in`` (owned live by the shared
    arena's vectorized per-slot counter) and ``frames_out`` fold in when
    the stream closes — neither the hop hot path nor the bulk ingest path
    walks per-stream counter objects (fleet totals come from the
    step-level aggregates in ``StreamMetrics``).
    """

    stream_id: int
    joined_at: float
    samples_in: int = 0
    chunks_in: int = 0
    frames_out: int = 0
    detections: int = 0
    closed_at: float | None = None


class StreamMetrics:
    """Aggregates per-stream counters + per-step wall latencies.

    Under a mesh (``n_shards > 1``) each step also records how many ready
    streams each shard advanced, so ``shard_summary`` can report per-shard
    occupancy/throughput next to the fleet aggregate.
    """

    def __init__(self, plan: StreamPlan, sample_rate: int = 16000,
                 n_shards: int = 1) -> None:
        self.plan = plan
        self.sample_rate = sample_rate
        self.n_shards = n_shards
        self.streams: dict[int, StreamCounters] = {}
        self.retired: list[StreamCounters] = []  # closed tenants of reused sids
        self.step_wall_s: list[float] = []
        self.step_pack_s: list[float] = []  # host-side packing share of wall
        self.step_streams: list[int] = []
        self.step_shard_streams: list[list[int]] = []  # per step, per shard
        self._frames_emitted = 0  # fleet total, accumulated per step
        self.capacity_events: list[tuple[float, int]] = []  # (t, new_cap)
        # cross-shard migrations (scheduler._maybe_rebalance)
        self.rebalances = 0
        self.rows_migrated = 0
        # push-side fleet totals, folded from the arena's monotone scalar
        # counters at hop boundaries — the push path itself never touches
        # per-sid counter objects
        self.samples_pushed = 0
        self.chunks_pushed = 0
        # silicon-equivalent energy: static per-hop/-finalize charges from
        # the plan, accumulated into one fleet ledger as hops execute
        self._hop_ledger = plan_hop_ledger(plan)
        self._tail_ledger = plan_tail_ledger(plan)
        self.ledger = EnergyLedger()
        self.finalizations = 0
        self._t0 = time.perf_counter()

    # -- recording -----------------------------------------------------------

    def on_join(self, sid: int) -> None:
        old = self.streams.get(sid)
        if old is not None:  # sid reuse: keep the first tenant's totals
            self.retired.append(old)
        self.streams[sid] = StreamCounters(sid, time.perf_counter() - self._t0)

    def on_step(self, n_ready: int, frames_each: int, wall_s: float,
                host_pack_s: float = 0.0,
                shard_counts: list[int] | None = None,
                finalized: bool = True) -> None:
        """Record one batched hop: ``n_ready`` streams advanced in
        ``wall_s`` seconds of which ``host_pack_s`` was host-side batch
        packing.  Aggregate-only — the hot path never walks per-stream
        counter objects (that was the pre-arena serial floor)."""
        if shard_counts is None:
            # only unambiguous without a mesh; sharded callers must say
            # which shard advanced what or shard_summary would lie
            assert self.n_shards == 1, "shard_counts required when sharded"
            shard_counts = [n_ready]
        assert len(shard_counts) == self.n_shards, (shard_counts, self.n_shards)
        self.step_wall_s.append(wall_s)
        self.step_pack_s.append(host_pack_s)
        self.step_streams.append(n_ready)
        self.step_shard_streams.append(list(shard_counts))
        self._frames_emitted += n_ready * frames_each
        _charge_scaled(self.ledger, self._hop_ledger, n_ready)
        if finalized:
            _charge_scaled(self.ledger, self._tail_ledger, n_ready)
            self.finalizations += n_ready

    def on_detection(self, sid: int) -> None:
        self.streams[sid].detections += 1

    def on_resize(self, new_capacity: int) -> None:
        """Elastic slot pool grew or shrank (scheduler._resize)."""
        self.capacity_events.append(
            (time.perf_counter() - self._t0, new_capacity)
        )

    def on_rebalance(self, n_moves: int) -> None:
        """One cross-shard migration leveled the pool with ``n_moves``
        slot rows crossing shard blocks."""
        self.rebalances += 1
        self.rows_migrated += n_moves

    def on_push_fold(self, samples_total: int, chunks_total: int) -> None:
        """Hop-boundary fold of the arena's monotone push counters (two
        absolute scalars — O(1) regardless of stream count)."""
        self.samples_pushed = int(samples_total)
        self.chunks_pushed = int(chunks_total)

    def on_close(self, sid: int, frames_out: int = 0,
                 samples_in: int | None = None,
                 chunks_in: int | None = None) -> None:
        c = self.streams[sid]
        c.closed_at = time.perf_counter() - self._t0
        c.frames_out = frames_out
        if samples_in is not None:
            # the shared arena's vectorized per-slot counters are the
            # truth; they fold in here instead of being twinned per push
            c.samples_in = samples_in
        if chunks_in is not None:
            c.chunks_in = chunks_in

    # -- reporting -----------------------------------------------------------

    def frames_total(self) -> int:
        """Fleet total of final-conv frames emitted by batched hops."""
        return self._frames_emitted

    def summary(self) -> dict[str, float]:
        wall = np.asarray(self.step_wall_s) if self.step_wall_s else np.zeros(1)
        pack = np.asarray(self.step_pack_s) if self.step_pack_s else np.zeros(1)
        frames = self.frames_total()
        elapsed = sum(self.step_wall_s) or 1e-12
        audio_s = frames * self.plan.samples_per_frame / self.sample_rate
        return {
            "streams": float(len(self.streams) + len(self.retired)),
            "steps": float(len(self.step_wall_s)),
            "frames_total": float(frames),
            "frames_per_sec": frames / elapsed,
            "stream_hops_per_sec": sum(self.step_streams) / elapsed,
            "audio_sec_per_wall_sec": audio_s / elapsed,  # real-time factor
            "step_ms_p50": float(np.percentile(wall, 50) * 1e3),
            "step_ms_p95": float(np.percentile(wall, 95) * 1e3),
            # the hop's host/device split: pack = building the batched
            # audio+mask from the arena; device = step + transfers +
            # batched detector.  Regressions in either half show alone.
            "host_pack_ms_p50": float(np.percentile(pack, 50) * 1e3),
            "host_pack_ms_p95": float(np.percentile(pack, 95) * 1e3),
            "device_ms_p50": float(np.percentile(wall - pack, 50) * 1e3),
            "mean_batch_occupancy": float(np.mean(self.step_streams))
            if self.step_streams else 0.0,
            "resizes": float(len(self.capacity_events)),
            "capacity_last": float(self.capacity_events[-1][1])
            if self.capacity_events else 0.0,
            "n_shards": float(self.n_shards),
            "rebalances": float(self.rebalances),
            "rows_migrated": float(self.rows_migrated),
            "samples_pushed": float(self.samples_pushed),
            "chunks_pushed": float(self.chunks_pushed),
        }

    def shard_summary(self) -> dict[str, object]:
        """Per-shard occupancy/throughput + the fleet aggregate.

        ``per_shard[s]`` reports how many stream-hops shard ``s`` advanced
        and its mean per-step occupancy; ``imbalance`` is the max/mean
        stream-hop ratio (1.0 = perfectly balanced placement).
        """
        S = self.n_shards
        hops = np.zeros(S, np.int64)
        for counts in self.step_shard_streams:
            for sh, n in enumerate(counts[:S]):
                hops[sh] += n
        steps = max(1, len(self.step_shard_streams))
        mean_hops = float(hops.mean()) if S else 0.0
        return {
            "n_shards": S,
            "per_shard": [
                {
                    "shard": sh,
                    "stream_hops": int(hops[sh]),
                    "mean_occupancy": float(hops[sh] / steps),
                }
                for sh in range(S)
            ],
            "fleet_stream_hops": int(hops.sum()),
            "imbalance": float(hops.max() / mean_hops) if hops.sum() else 1.0,
        }

    def energy_summary(self, params: EnergyParams | None = None) -> dict[str, float]:
        """Measured silicon-equivalent cost of the work done so far.

        Every hop charged the fleet ``EnergyLedger`` with the full Table-I
        component model (macro MACs, SA decisions, feature-SRAM traffic,
        controller cycles) from the plan's static per-hop geometry, so
        this is the executor's accounting applied to the streaming
        workload — not an e_mac-only estimate.  ``uj_per_inference`` is
        the energy per finalized per-hop decision (the always-on "answer
        now" cost).
        """
        led = self.ledger
        if params is not None:
            led = dataclasses.replace(led, params=params)
        p = led.params
        energy_j = led.energy_j
        return {
            "macs_total": float(led.macs),
            "phys_macs_total": float(led.phys_macs),
            "sa_decisions_total": float(led.sa_decisions),
            "sram_bits_total": float(
                led.sram_read_bits + led.sram_write_bits
            ),
            "cycles_total": float(led.cycles),
            "energy_uj": energy_j * 1e6,
            "e_mac_uj": p.e_mac * led.phys_macs * 1e6,
            "e_sa_uj": p.e_sa * led.sa_decisions * 1e6,
            "e_sram_uj": (p.e_sram_r * led.sram_read_bits
                          + p.e_sram_w * led.sram_write_bits) * 1e6,
            "e_ctrl_uj": p.e_ctrl * led.cycles * 1e6,
            "tops_per_w_equiv": led.tops_per_w,
            "uj_per_inference": (energy_j * 1e6 / self.finalizations)
            if self.finalizations else 0.0,
        }
