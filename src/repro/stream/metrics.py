"""Per-stream, per-shard and fleet-level counters for the streaming runtime.

Tracks what a serving dashboard needs — frames/sec, streams/sec, step
latency percentiles, real-time factor, slot-pool resizes, per-shard
occupancy under a mesh — and bridges into the existing energy model
(core/energy.py): each steady-state hop has a statically known
MAC/SA/SRAM/cycle budget from the StreamPlan, so every hop charges a real
``EnergyLedger`` (the executor's accumulator, all components — not just
``e_mac``) and ``energy_summary`` reports the *measured*
silicon-equivalent TOPS/W the fleet would draw, in the paper's Table-I
accounting convention.

Step timing covers the whole per-hop pipeline *including* per-slot
finalized logits: finalization runs inside the jitted step (the fused
tail), so there is no separate host-side peek bucket to account for — the
step latency percentile IS the hop-to-logits latency.  Each step records
the split between *host packing* (building the batched audio/mask from
the shared ``RingArena`` — the part the vectorized ingest plane exists to
shrink) and everything else (device step + transfers + batched detector),
so a regression in either half is visible on its own
(``host_pack_ms_p50`` / ``device_ms_p50`` in ``summary``), plus the
finer per-phase split (pack / dispatch / device / detector) the
scheduler's fenced trace spans measure.

**Bounded over unbounded uptime.**  Nothing here grows with step count
or stream count: latencies land in fixed-size ring ``Reservoir``\\ s
(exact percentiles while the run is shorter than the window — every
test and bench — bit-identical to the old grow-forever lists) *and*
log-linear ``Histogram``\\ s (O(1)-memory estimates that cover every
sample ever recorded; ``summary()`` switches to them once a reservoir
wraps and says so via ``latency_estimated``).  Aggregates (frames,
stream-hops, per-shard hop totals, wall time) are running scalars, and
per-stream counter objects for closed streams retire into a bounded
ring.  ``footprint_bytes()`` exposes the retained size so the constant-
memory property is testable.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time

import numpy as np

from repro.core import macro
from repro.core.compiler import _pad16
from repro.core.energy import EnergyLedger, EnergyParams
from repro.core.executor import READOUT_CYCLES
from repro.obs.registry import Histogram, MetricsRegistry, Reservoir
from repro.stream.state import StreamPlan

# compiler.chunk_layer splits columns into one-SA-group chunks
_SA_GROUP = macro.N_SA


def plan_hop_ledger(plan: StreamPlan,
                    params: EnergyParams | None = None) -> EnergyLedger:
    """Ledger for ONE stream advancing ONE steady-state hop.

    Charges exactly what the executor's per-chunk formulas would for the
    hop's incremental work: the conv cascade reads each layer's
    receptive-field window (tail ++ new frames) once per <=128-pair column
    chunk, activates ``rows x channels x positions x in_bits`` physical
    MACs, makes one SA decision per (position, pair, bit pass), and
    writes the pooled OFM back — the streaming specialization of
    ``Executor.run``'s MAC accounting, with the window length taken from
    the plan instead of the whole clip.  The classifier tail (fc cascade
    per emitted finalization) is charged separately by
    ``plan_tail_ledger`` so logits-off deployments don't pay for it.
    """
    led = EnergyLedger(params=params or EnergyParams())
    for st in plan.convs:
        rows = st.k * st.cin
        window = st.tail + st.n_in  # frames the hop streams past the macro
        positions = st.n_conv
        for c0 in range(0, st.cout, _SA_GROUP):
            n_ch = min(_SA_GROUP, st.cout - c0)
            pairs = _pad16(n_ch)
            led.charge_mac_op(
                rows * n_ch * positions,
                rows * n_ch * positions * st.in_bits,
                positions * pairs * st.in_bits,
                positions * st.in_bits,
            )
            led.charge_sram(
                read_bits=window * st.cin
                * (st.in_bits if st.in_bits > 1 else 1)
            )
        led.charge_sram(write_bits=st.n_out * st.cout)  # pooled OFM (PWB)
    # GAP: read the final frames, bump the saturating 8-bit counters
    last = plan.convs[-1]
    led.charge_sram(read_bits=last.n_out * plan.gap_channels,
                    write_bits=plan.gap_channels * 8)
    return led


def plan_tail_ledger(plan: StreamPlan,
                     params: EnergyParams | None = None) -> EnergyLedger:
    """Ledger for ONE finalization (classifier tail) of one stream.

    Drains the saturated GAP counts through the fc cascade: 8-bit counts
    feed the first fc bit-serially, raw-output layers pay the thermometer
    SA readout sweep, and each layer writes its activations back.
    """
    led = EnergyLedger(params=params or EnergyParams())
    for st in plan.fcs:
        rows = st.cin
        for c0 in range(0, st.cout, _SA_GROUP):
            n_ch = min(_SA_GROUP, st.cout - c0)
            pairs = _pad16(n_ch)
            cyc = st.in_bits + (READOUT_CYCLES if st.out_raw else 0)
            led.charge_mac_op(
                rows * n_ch,
                rows * n_ch * st.in_bits,
                pairs * st.in_bits,
                cyc,
            )
            led.charge_sram(
                read_bits=rows * (st.in_bits if st.in_bits > 1 else 1)
            )
        led.charge_sram(write_bits=st.cout * (8 if st.out_raw else 1))
    return led


_LEDGER_FIELDS: dict[type, tuple[str, ...]] = {}


def _charge_scaled(dst: EnergyLedger, src: EnergyLedger, n: int) -> None:
    """Accumulate ``n`` copies of ``src``'s charges into ``dst``.

    Field-generic — iterating ``dst``'s *runtime* dataclass fields
    (cached per runtime type; this runs twice per hop), not the static
    EnergyLedger class — so a counter added to EnergyLedger (or a
    subclass) can never be silently dropped from the streaming
    accumulation (tests/test_obs.py pins this with a grown ledger).
    """
    names = _LEDGER_FIELDS.get(type(dst))
    if names is None:
        names = tuple(f.name for f in dataclasses.fields(dst)
                      if f.name != "params")
        _LEDGER_FIELDS[type(dst)] = names
    for name in names:
        setattr(dst, name, getattr(dst, name) + getattr(src, name) * n)


@dataclasses.dataclass
class StreamCounters:
    """Per-stream dashboard counters.

    ``detections`` updates live; ``samples_in`` (owned live by the shared
    arena's vectorized per-slot counter) and ``frames_out`` fold in when
    the stream closes — neither the hop hot path nor the bulk ingest path
    walks per-stream counter objects (fleet totals come from the
    step-level aggregates in ``StreamMetrics``).
    """

    stream_id: int
    joined_at: float
    samples_in: int = 0
    chunks_in: int = 0
    frames_out: int = 0
    detections: int = 0
    closed_at: float | None = None


# the fenced per-phase split of one hop (scheduler.step_batch's span
# stamps): host pack, dispatch (staging + jitted call returning its
# futures), device (block_until_ready fence + result transfers), and the
# batched detector + bookkeeping
PHASES = ("pack", "dispatch", "device", "detector")


class StreamMetrics:
    """Aggregates per-stream counters + per-step wall latencies.

    Under a mesh (``n_shards > 1``) each step also records how many ready
    streams each shard advanced, so ``shard_summary`` can report per-shard
    occupancy/throughput next to the fleet aggregate.

    Every retained structure is bounded (see module docstring):
    ``reservoir`` raw samples per latency series, ``max_retained`` closed
    per-stream counter objects / capacity events.  Histograms registered
    in ``registry`` (a shared ``obs.MetricsRegistry``, or a private one)
    cover *all* samples in O(1) memory, so quantiles never go blind —
    they just degrade from exact to bounded-error once a window wraps.
    """

    def __init__(self, plan: StreamPlan, sample_rate: int = 16000,
                 n_shards: int = 1, registry: MetricsRegistry | None = None,
                 reservoir: int = 4096, max_retained: int = 1024) -> None:
        self.plan = plan
        self.sample_rate = sample_rate
        self.n_shards = n_shards
        self.registry = registry if registry is not None else MetricsRegistry()
        self.max_retained = max_retained
        self.streams: dict[int, StreamCounters] = {}
        # closed tenants of reused sids (bounded ring + exact total)
        self.retired: collections.deque[StreamCounters] = collections.deque(
            maxlen=max_retained
        )
        self.retired_total = 0
        # closed streams linger in ``streams`` for post-close inspection,
        # then the oldest are evicted so always-on churn can't leak
        self._closed_order: collections.deque = collections.deque()
        self.streams_total = 0   # every sid ever joined (exact)
        self.closed_total = 0
        self.detections_total = 0
        # latency series: exact ring reservoirs + all-sample histograms
        self._wall_res = Reservoir(reservoir)
        self._pack_res = Reservoir(reservoir)
        self._dev_res = Reservoir(reservoir)   # wall - pack (legacy split)
        self._wall_hist = self._hist("stream.step_wall_s")
        self._pack_hist = self._hist("stream.step_pack_s")
        self._dev_hist = self._hist("stream.step_device_s")
        # the fenced per-phase split (pack shares the series above)
        self._phase_res = {p: Reservoir(reservoir) for p in PHASES[1:]}
        self._phase_hist = {p: self._hist(f"stream.phase_{p}_s")
                            for p in PHASES[1:]}
        # per-phase running totals (plain float adds on the hot path)
        self._phase_total = dict.fromkeys(PHASES, 0.0)
        # host work that ran under an in-flight device hop (async plane)
        self.hidden_total_s = 0.0
        self.steps = 0
        self.wall_total_s = 0.0
        self.stream_hops_total = 0
        self._shard_hops = np.zeros(n_shards, np.int64)
        self._frames_emitted = 0  # fleet total, accumulated per step
        # (t, new_cap) ring + exact resize count
        self.capacity_events: collections.deque = collections.deque(
            maxlen=max_retained
        )
        self.resize_count = 0
        # cross-shard migrations (scheduler._maybe_rebalance)
        self.rebalances = 0
        self.rows_migrated = 0
        # push-side fleet totals, folded from the arena's monotone scalar
        # counters at hop boundaries — the push path itself never touches
        # per-sid counter objects
        self.samples_pushed = 0
        self.chunks_pushed = 0
        # silicon-equivalent energy: static per-hop/-finalize charges from
        # the plan, accumulated into one fleet ledger as hops execute
        self._hop_ledger = plan_hop_ledger(plan)
        self._tail_ledger = plan_tail_ledger(plan)
        self.ledger = EnergyLedger()
        self.finalizations = 0
        # per-shard device launches: running total + last hop's static
        # per-hop figure (``_BatchedModel.dispatches_per_hop``)
        self.device_dispatches_total = 0
        self._dispatches_per_hop = 0
        # tenant weight pool: admissions/evictions plus per-model
        # stream-hop counters.  Bounded — ``model_hops`` only holds
        # RESIDENT variants (<= pool size); an evicted model's count
        # retires into one scalar so always-on churn can't leak keys.
        self.models_admitted = 0
        self.models_evicted = 0
        self.model_hops: collections.Counter = collections.Counter()
        self.evicted_model_hops = 0
        self._t0 = time.perf_counter()

    def _hist(self, name: str) -> Histogram:
        return self.registry.histogram(name)

    @staticmethod
    def _rec(res: Reservoir, hist: Histogram, v: float) -> None:
        """One latency sample into its reservoir + all-sample histogram.

        The histogram is *lazily backfilled*: while the reservoir still
        holds every sample (the exact regime) the histogram isn't
        touched; the moment the ring is about to wrap, the retained
        window bulk-folds in (``record_many``) and per-sample recording
        takes over — so the histogram still covers every sample ever,
        but the common pre-wrap hot path pays one ring write per series.
        """
        if res.count == res.capacity:
            hist.record_many(res.values())
        res.record(v)
        if res.count > res.capacity:
            hist.record(v)

    # -- recording -----------------------------------------------------------

    def on_join(self, sid: int) -> None:
        old = self.streams.get(sid)
        if old is not None:  # sid reuse: keep the first tenant's totals
            self.retired.append(old)
            self.retired_total += 1
        self.streams[sid] = StreamCounters(sid, time.perf_counter() - self._t0)
        self.streams_total += 1

    def on_step(self, n_ready: int, frames_each: int, wall_s: float,
                host_pack_s: float = 0.0,
                shard_counts: list[int] | None = None,
                finalized: bool = True,
                dispatch_s: float = 0.0, device_s: float = 0.0,
                detector_s: float = 0.0, hidden_s: float = 0.0,
                dispatches: int = 0,
                model_counts: dict[str, int] | None = None) -> None:
        """Record one batched hop: ``n_ready`` streams advanced in
        ``wall_s`` seconds of which ``host_pack_s`` was host-side batch
        packing; ``dispatch_s``/``device_s``/``detector_s`` are the
        fenced phase durations from the scheduler's trace spans (device
        time is real execution — the span boundary blocks until ready).
        ``hidden_s`` is the portion of this hop's host work (pack /
        dispatch / deferred fold) that ran while an earlier or later hop
        was executing on the device — zero on the synchronous path,
        reported by the async plane's pipelined dispatch.  ``dispatches``
        is the per-shard device-launch (``pallas_call``) count for this
        hop — a static plan+backend figure (``dispatches_per_hop``), 0
        for plain-XLA backends.  ``model_counts`` (tenant pools only)
        says how many of this hop's stream-hops each resident model
        advanced — one small dict add per hop, K-bounded.
        Aggregate-only — the hot path never walks per-stream counter
        objects (that was the pre-arena serial floor)."""
        if shard_counts is None:
            # only unambiguous without a mesh; sharded callers must say
            # which shard advanced what or shard_summary would lie
            assert self.n_shards == 1, "shard_counts required when sharded"
            shard_counts = [n_ready]
        assert len(shard_counts) == self.n_shards, (shard_counts, self.n_shards)
        self._rec(self._wall_res, self._wall_hist, wall_s)
        self._rec(self._pack_res, self._pack_hist, host_pack_s)
        self._rec(self._dev_res, self._dev_hist, wall_s - host_pack_s)
        pt = self._phase_total
        pt["pack"] += host_pack_s
        for p, v in (("dispatch", dispatch_s), ("device", device_s),
                     ("detector", detector_s)):
            self._rec(self._phase_res[p], self._phase_hist[p], v)
            pt[p] += v
        self.hidden_total_s += hidden_s
        self.device_dispatches_total += dispatches
        self._dispatches_per_hop = dispatches
        self.steps += 1
        self.wall_total_s += wall_s
        self.stream_hops_total += n_ready
        if self.n_shards == 1:
            self._shard_hops[0] += shard_counts[0]
        else:
            self._shard_hops += np.asarray(shard_counts, np.int64)
        self._frames_emitted += n_ready * frames_each
        if model_counts:
            self.model_hops.update(model_counts)
        _charge_scaled(self.ledger, self._hop_ledger, n_ready)
        if finalized:
            _charge_scaled(self.ledger, self._tail_ledger, n_ready)
            self.finalizations += n_ready

    def on_detection(self, sid: int) -> None:
        self.streams[sid].detections += 1
        self.detections_total += 1

    def on_resize(self, new_capacity: int) -> None:
        """Elastic slot pool grew or shrank (scheduler._resize)."""
        self.capacity_events.append(
            (time.perf_counter() - self._t0, new_capacity)
        )
        self.resize_count += 1

    def on_rebalance(self, n_moves: int) -> None:
        """One cross-shard migration leveled the pool with ``n_moves``
        slot rows crossing shard blocks."""
        self.rebalances += 1
        self.rows_migrated += n_moves

    def on_model_admit(self, model_id: str) -> None:
        """One tenant variant admitted to the weight pool."""
        self.models_admitted += 1
        self.model_hops.setdefault(model_id, 0)

    def on_model_evict(self, model_id: str) -> None:
        """One tenant variant evicted (LRU): its hop count retires into
        the scalar so ``model_hops`` stays bounded by pool size."""
        self.models_evicted += 1
        self.evicted_model_hops += self.model_hops.pop(model_id, 0)

    def on_push_fold(self, samples_total: int, chunks_total: int) -> None:
        """Hop-boundary fold of the arena's monotone push counters (two
        absolute scalars — O(1) regardless of stream count)."""
        self.samples_pushed = int(samples_total)
        self.chunks_pushed = int(chunks_total)

    def on_close(self, sid: int, frames_out: int = 0,
                 samples_in: int | None = None,
                 chunks_in: int | None = None) -> None:
        c = self.streams[sid]
        c.closed_at = time.perf_counter() - self._t0
        c.frames_out = frames_out
        if samples_in is not None:
            # the shared arena's vectorized per-slot counters are the
            # truth; they fold in here instead of being twinned per push
            c.samples_in = samples_in
        if chunks_in is not None:
            c.chunks_in = chunks_in
        self.closed_total += 1
        # closed counters stay inspectable for a while, then the oldest
        # evict — an always-on runtime churns through millions of sids
        self._closed_order.append((sid, c))
        while len(self._closed_order) > self.max_retained:
            old_sid, old_c = self._closed_order.popleft()
            if self.streams.get(old_sid) is old_c:
                del self.streams[old_sid]

    def begin_window(self) -> None:
        """Start a fresh measurement window: resets the latency series
        and the step/throughput aggregates (NOT lifecycle counters or the
        energy ledger, which stay cumulative).  Benches call this after
        warm-up so ``summary()`` reports steady-state quantiles."""
        for r in (self._wall_res, self._pack_res, self._dev_res,
                  *self._phase_res.values()):
            r.reset()
        for h in (self._wall_hist, self._pack_hist, self._dev_hist,
                  *self._phase_hist.values()):
            h.reset()
        self._phase_total = dict.fromkeys(PHASES, 0.0)
        self.hidden_total_s = 0.0
        self.device_dispatches_total = 0
        self.steps = 0
        self.wall_total_s = 0.0
        self.stream_hops_total = 0
        self._shard_hops[:] = 0
        self._frames_emitted = 0

    # -- reporting -----------------------------------------------------------

    def frames_total(self) -> int:
        """Fleet total of final-conv frames emitted by batched hops
        (since construction or the last ``begin_window``)."""
        return self._frames_emitted

    @property
    def latency_estimated(self) -> bool:
        """True once any latency reservoir has wrapped: quantiles now
        come from the log-linear histograms (bounded relative error,
        covering every sample) instead of exact order statistics."""
        return self._wall_res.saturated

    def _q(self, res: Reservoir, hist: Histogram, q: float) -> float:
        """Quantile in ms: exact from the reservoir while it still holds
        every sample, histogram estimate (all samples, bounded error)
        after it wraps; NaN when nothing was recorded."""
        if res.count == 0:
            return math.nan
        if not res.saturated:
            return float(np.percentile(res.values(), q) * 1e3)
        return hist.quantile(q / 100.0) * 1e3

    def summary(self) -> dict[str, float]:
        """Fleet aggregate.  Latency fields are NaN (not a fabricated
        0.0) when no step has been recorded; ``latency_estimated`` flips
        to 1.0 once quantiles switch from exact to histogram-estimated.
        """
        frames = self.frames_total()
        elapsed = self.wall_total_s or 1e-12
        audio_s = frames * self.plan.samples_per_frame / self.sample_rate
        return {
            "streams": float(self.streams_total),
            "steps": float(self.steps),
            "frames_total": float(frames),
            "frames_per_sec": frames / elapsed,
            "stream_hops_per_sec": self.stream_hops_total / elapsed,
            "audio_sec_per_wall_sec": audio_s / elapsed,  # real-time factor
            "step_ms_p50": self._q(self._wall_res, self._wall_hist, 50),
            "step_ms_p95": self._q(self._wall_res, self._wall_hist, 95),
            "step_ms_p99": self._q(self._wall_res, self._wall_hist, 99),
            "step_ms_p999": self._q(self._wall_res, self._wall_hist, 99.9),
            # the hop's host/device split: pack = building the batched
            # audio+mask from the arena; device = step + transfers +
            # batched detector.  Regressions in either half show alone.
            "host_pack_ms_p50": self._q(self._pack_res, self._pack_hist, 50),
            "host_pack_ms_p95": self._q(self._pack_res, self._pack_hist, 95),
            "device_ms_p50": self._q(self._dev_res, self._dev_hist, 50),
            "device_ms_p95": self._q(self._dev_res, self._dev_hist, 95),
            "device_ms_p99": self._q(self._dev_res, self._dev_hist, 99),
            "latency_estimated": float(self.latency_estimated),
            "mean_batch_occupancy": self.stream_hops_total / self.steps
            if self.steps else 0.0,
            "resizes": float(self.resize_count),
            "capacity_last": float(self.capacity_events[-1][1])
            if self.capacity_events else 0.0,
            "n_shards": float(self.n_shards),
            "rebalances": float(self.rebalances),
            "rows_migrated": float(self.rows_migrated),
            "samples_pushed": float(self.samples_pushed),
            "chunks_pushed": float(self.chunks_pushed),
            # per-shard device-launch accounting: last hop's static
            # pallas_call count and the cumulative total (0 under jnp)
            "device_dispatches_per_hop": float(self._dispatches_per_hop),
            "device_dispatches_total": float(self.device_dispatches_total),
        }

    def tenant_summary(self) -> dict[str, object]:
        """Weight-pool accounting: admissions/evictions plus stream-hops
        advanced per resident tenant.  ``per_model`` is bounded by the
        pool's ``max_models`` — evicted tenants' hop counts retire into
        the ``evicted_model_hops`` scalar instead of growing the dict."""
        return {
            "models_admitted": float(self.models_admitted),
            "models_evicted": float(self.models_evicted),
            "evicted_model_hops": float(self.evicted_model_hops),
            "per_model": {m: int(c) for m, c in self.model_hops.items()},
        }

    def phase_summary(self) -> dict[str, dict[str, float]]:
        """Per-phase hop breakdown (pack / dispatch / device / detector):
        quantiles in ms plus each phase's share of total hop wall time.
        The fenced spans tile the hop, so shares sum to ~1 when the
        scheduler recorded all phases (0 for phases never recorded)."""
        series: dict[str, tuple[Reservoir, Histogram]] = {
            "pack": (self._pack_res, self._pack_hist)
        }
        series.update({p: (self._phase_res[p], self._phase_hist[p])
                       for p in PHASES[1:]})
        wall_total = self.wall_total_s
        out: dict[str, dict[str, float]] = {}
        for name, (res, hist) in series.items():
            total = self._phase_total[name]
            out[name] = {
                "ms_p50": self._q(res, hist, 50),
                "ms_p95": self._q(res, hist, 95),
                "ms_p99": self._q(res, hist, 99),
                "ms_p999": self._q(res, hist, 99.9),
                "total_s": total,
                "share_of_wall": total / wall_total if wall_total else 0.0,
            }
        return out

    def overlap_summary(self) -> dict[str, float]:
        """How much host-side hop work the async plane hid under device
        compute this window.  ``hidden_frac`` is hidden host seconds over
        total host seconds (pack + dispatch + detector); always 0.0 under
        the synchronous scheduler.  The trace-derived union-interval
        stats (``obs.trace.overlap_stats``) are the precise wall-clock
        account; this is the O(1) running-counter view."""
        pt = self._phase_total
        host = pt["pack"] + pt["dispatch"] + pt["detector"]
        return {
            "hidden_ms": self.hidden_total_s * 1e3,
            "host_ms": host * 1e3,
            "hidden_frac": self.hidden_total_s / host if host else 0.0,
            "device_busy_ms": pt["device"] * 1e3,
        }

    def shard_summary(self) -> dict[str, object]:
        """Per-shard occupancy/throughput + the fleet aggregate.

        ``per_shard[s]`` reports how many stream-hops shard ``s`` advanced
        and its mean per-step occupancy; ``imbalance`` is the max/mean
        stream-hop ratio (1.0 = perfectly balanced placement — a dead
        shard with zero hops inflates it, since the mean keeps counting
        that shard).
        """
        S = self.n_shards
        hops = self._shard_hops
        steps = max(1, self.steps)
        mean_hops = float(hops.mean()) if S else 0.0
        return {
            "n_shards": S,
            "per_shard": [
                {
                    "shard": sh,
                    "stream_hops": int(hops[sh]),
                    "mean_occupancy": float(hops[sh] / steps),
                }
                for sh in range(S)
            ],
            "fleet_stream_hops": int(hops.sum()),
            "imbalance": float(hops.max() / mean_hops) if hops.sum() else 1.0,
        }

    def footprint_bytes(self) -> int:
        """Retained-memory proxy: array bytes of every bounded instrument
        plus an entry-count charge for the dict/deque containers.  The
        constant-memory-over-10k-steps test pins this value flat."""
        n = sum(r.nbytes for r in (self._wall_res, self._pack_res,
                                   self._dev_res,
                                   *self._phase_res.values()))
        n += sum(h.nbytes for h in (self._wall_hist, self._pack_hist,
                                    self._dev_hist,
                                    *self._phase_hist.values()))
        n += self._shard_hops.nbytes
        n += 64 * (len(self.streams) + len(self.retired)
                   + len(self.capacity_events) + len(self._closed_order)
                   + len(self.model_hops))
        return n

    def energy_summary(self, params: EnergyParams | None = None) -> dict[str, float]:
        """Measured silicon-equivalent cost of the work done so far.

        Every hop charged the fleet ``EnergyLedger`` with the full Table-I
        component model (macro MACs, SA decisions, feature-SRAM traffic,
        controller cycles) from the plan's static per-hop geometry, so
        this is the executor's accounting applied to the streaming
        workload — not an e_mac-only estimate.  ``uj_per_inference`` is
        the energy per finalized per-hop decision (the always-on "answer
        now" cost).
        """
        led = self.ledger
        if params is not None:
            led = dataclasses.replace(led, params=params)
        p = led.params
        energy_j = led.energy_j
        return {
            "macs_total": float(led.macs),
            "phys_macs_total": float(led.phys_macs),
            "sa_decisions_total": float(led.sa_decisions),
            "sram_bits_total": float(
                led.sram_read_bits + led.sram_write_bits
            ),
            "cycles_total": float(led.cycles),
            "energy_uj": energy_j * 1e6,
            "e_mac_uj": p.e_mac * led.phys_macs * 1e6,
            "e_sa_uj": p.e_sa * led.sa_decisions * 1e6,
            "e_sram_uj": (p.e_sram_r * led.sram_read_bits
                          + p.e_sram_w * led.sram_write_bits) * 1e6,
            "e_ctrl_uj": p.e_ctrl * led.cycles * 1e6,
            "tops_per_w_equiv": led.tops_per_w,
            "uj_per_inference": (energy_j * 1e6 / self.finalizations)
            if self.finalizations else 0.0,
        }
