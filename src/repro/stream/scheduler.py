"""Continuous-batching multi-stream scheduler for always-on KWS.

Thousands of concurrent audio streams each produce frames continuously;
the model weights are shared across all of them (one CIM macro, many
users).  This scheduler packs the active streams onto a fixed batch axis
and advances them with ONE jitted step per hop:

  * streams join/leave at any time — a free slot is primed from the
    stream's first ``prime_samples`` (generic numpy path in state.py) and
    from then on rides the static-shape batched step;
  * streams whose inbox holds less than a hop are masked out of the step
    (their state passes through untouched), so stragglers never force a
    re-trace — continuous batching, not synchronized batching;
  * the batched step is built on the batched Pallas conv kernel
    (kernels/bnn_conv1d.bnn_conv1d_step_packed) or an equivalent pure-jnp
    einsum path (default on CPU, where Pallas runs interpreted).

Per emitted hop the scheduler computes the stream's *finalized* logits
(the exact logits the offline executor would produce if the utterance
ended now — see StreamState.peek_logits), feeds the detector, and updates
the metrics registry.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cnn_spec import CNN1DSpec
from repro.kernels import ops
from repro.stream.detector import Detection, DetectorConfig, PosteriorDetector
from repro.stream.frontend import AudioFrontend, FrontendConfig
from repro.stream.metrics import StreamMetrics
from repro.stream.state import StreamPlan, StreamState, plan_stream
from repro.utils.logging import get_logger

log = get_logger("stream")


@dataclasses.dataclass
class StreamResult:
    """Returned by close_stream: the stream's final, flushed inference."""

    stream_id: int
    logits: np.ndarray        # executor-exact raw logits
    frames: int               # final-conv frames accumulated
    samples: int
    events: list[Detection]


@dataclasses.dataclass
class _Stream:
    sid: int
    slot: int
    frontend: AudioFrontend
    detector: PosteriorDetector
    primed: bool = False
    frames: int = 0


def _build_step(plan: StreamPlan, weights, thresholds, capacity: int,
                backend: str, interpret: bool | None):
    """One jitted batched hop: (audio, mask, tails, pendings, gap) ->
    (tails', pendings', gap', frames).  All shapes static."""
    B = capacity
    stages = plan.convs
    w_jnp = [jnp.asarray(weights[st.layer_idx].reshape(st.k, st.cin, st.cout),
                         jnp.int32) for st in stages]
    thr_jnp = [jnp.asarray(thresholds[st.layer_idx][0], jnp.float32)
               for st in stages]
    flip_jnp = [jnp.asarray(thresholds[st.layer_idx][1], bool)
                for st in stages]
    wsum = [jnp.sum(w, axis=(0, 1)) for w in w_jnp]  # offset fold, layer 0

    def conv_raw(i: int, window: jax.Array) -> jax.Array:
        """(B, tail+n_in, Cin) -> (B, n_conv, Cout) raw popcount diff."""
        st = stages[i]
        n = st.n_conv
        if st.in_bits > 1:
            # bit-serial first layer; offset folds out after accumulation
            if backend == "pallas":
                acc = None
                for b in range(st.in_bits):
                    plane = ((window >> b) & 1).astype(jnp.uint32)
                    d = ops.bnn_conv1d_batched(
                        plane, w_jnp[i], stride=st.stride, pad=0,
                        mode="raw", interpret=interpret,
                    )
                    acc = d * (1 << b) if acc is None else acc + d * (1 << b)
                return acc - st.in_offset * wsum[i][None, None, :]
            xi = window.astype(jnp.int32) - st.in_offset
            taps = [
                xi[:, t : t + (n - 1) * st.stride + 1 : st.stride]
                for t in range(st.k)
            ]
            xs = jnp.stack(taps, axis=1)  # (B, K, n, Cin)
            return jnp.einsum("bknc,kco->bno", xs, w_jnp[i])
        if backend == "pallas":
            return ops.bnn_conv1d_batched(
                window.astype(jnp.uint32), w_jnp[i], stride=st.stride,
                pad=0, mode="raw", interpret=interpret,
            )
        taps = [
            window[:, t : t + (n - 1) * st.stride + 1 : st.stride]
            for t in range(st.k)
        ]
        xs = jnp.stack(taps, axis=1).astype(jnp.int32)
        return jnp.einsum("bknc,kco->bno", xs, w_jnp[i])

    def step(audio, mask, tails, pendings, gap):
        cur = audio.reshape(B, plan.hop_samples, stages[0].cin)
        new_tails, new_pendings = [], []
        for i, st in enumerate(stages):
            window = jnp.concatenate([tails[i], cur], axis=1)
            raw = conv_raw(i, window)
            new_tails.append(window[:, st.n_conv * st.stride :])
            ge = raw.astype(jnp.float32) >= thr_jnp[i][None, None, :]
            y = jnp.where(flip_jnp[i][None, None, :], ~ge, ge).astype(jnp.int32)
            if st.pool > 1:
                frames = (
                    jnp.concatenate([pendings[i], y], axis=1)
                    if st.phase else y
                )
                used = st.n_out * st.pool
                pooled = frames[:, :used].reshape(
                    B, st.n_out, st.pool, st.cout
                ).max(axis=2)
                new_pendings.append(frames[:, used:])
                cur = pooled
            else:
                new_pendings.append(pendings[i])
                cur = y
        # saturate at the 8-bit PWB counter ceiling inside the step: the
        # accumulation is monotone non-negative, so incremental clamping
        # equals clamping the int64 total (pwb.gap_counts semantics) and
        # int32 can never wrap on always-on streams
        gap2 = jnp.minimum(gap + cur.sum(axis=1, dtype=jnp.int32), 255)

        m3 = mask[:, None, None]
        new_tails = [jnp.where(m3, nt, t) for nt, t in zip(new_tails, tails)]
        new_pendings = [
            jnp.where(m3, np_, p) if p.shape[1] else p
            for np_, p in zip(new_pendings, pendings)
        ]
        gap2 = jnp.where(mask[:, None], gap2, gap)
        return tuple(new_tails), tuple(new_pendings), gap2, cur

    return jax.jit(step)


class StreamScheduler:
    """Continuous batching over a fixed number of stream slots."""

    def __init__(
        self,
        spec: CNN1DSpec,
        weights: dict[int, np.ndarray],
        thresholds: dict[int, tuple[np.ndarray, np.ndarray]],
        capacity: int = 8,
        hop_frames: int = 1,
        backend: str = "jnp",
        interpret: bool | None = None,
        detector_cfg: DetectorConfig | None = None,
        emit_logits: bool = True,
        sample_rate: int = 16000,
    ) -> None:
        assert backend in ("jnp", "pallas"), backend
        self.plan = plan_stream(spec, hop_frames=hop_frames)
        self.weights = {k: np.asarray(v) for k, v in weights.items()}
        self.thresholds = thresholds
        self.capacity = capacity
        self.backend = backend
        self.detector_cfg = detector_cfg or DetectorConfig()
        self.emit_logits = emit_logits
        self.metrics = StreamMetrics(self.plan, sample_rate)
        self._step_fn = _build_step(
            self.plan, self.weights, thresholds, capacity, backend, interpret
        )

        # batched state lives device-resident between hops; host copies are
        # made only on join/leave/peek (lifecycle events, not the hot loop)
        B = capacity
        self._tails = [
            jnp.zeros((B, st.tail, st.cin), jnp.int32) for st in self.plan.convs
        ]
        self._pendings = [
            jnp.zeros((B, st.phase, st.cout), jnp.int32)
            for st in self.plan.convs
        ]
        self._gap = jnp.zeros((B, self.plan.gap_channels), jnp.int32)
        self._slots: list[int | None] = [None] * B
        self._streams: dict[int, _Stream] = {}
        self._next_sid = 0

    # -- stream lifecycle ----------------------------------------------------

    def add_stream(self, sid: int | None = None,
                   frontend_cfg: FrontendConfig | None = None) -> int:
        """Claim a free slot for a new stream; returns the stream id."""
        try:
            slot = self._slots.index(None)
        except ValueError:
            raise MemoryError(
                f"all {self.capacity} stream slots busy; close a stream first"
            ) from None
        sid = self._next_sid if sid is None else sid
        assert sid not in self._streams, f"stream {sid} already exists"
        self._next_sid = max(self._next_sid, sid) + 1
        self._slots[slot] = sid
        self._streams[sid] = _Stream(
            sid=sid,
            slot=slot,
            frontend=AudioFrontend(frontend_cfg),
            detector=PosteriorDetector(sid, self.detector_cfg),
        )
        self.metrics.on_join(sid)
        return sid

    def push_audio(self, sid: int, audio: np.ndarray) -> None:
        s = self._streams[sid]
        s.frontend.push(audio)
        self.metrics.on_audio(sid, np.asarray(audio).shape[0])

    @property
    def active(self) -> list[int]:
        return sorted(self._streams)

    # -- the batched hop -----------------------------------------------------

    def _prime_ready(self) -> None:
        for s in self._streams.values():
            if not s.primed and len(s.frontend) >= self.plan.prime_samples:
                st = StreamState(self.plan, self.weights, self.thresholds)
                st.advance(s.frontend.pop(self.plan.prime_samples))
                steady = st.export_steady()
                self._write_slot(s.slot, steady)
                s.frames = st.frames
                s.primed = True

    def _write_slot(self, slot: int, steady: dict) -> None:
        for i in range(len(self.plan.convs)):
            self._tails[i] = self._tails[i].at[slot].set(steady["tails"][i])
            if self._pendings[i].shape[1]:
                self._pendings[i] = self._pendings[i].at[slot].set(
                    steady["pendings"][i]
                )
        self._gap = self._gap.at[slot].set(steady["gap"].astype(np.int32))

    def _clear_slot(self, slot: int) -> None:
        for i in range(len(self.plan.convs)):
            self._tails[i] = self._tails[i].at[slot].set(0)
            if self._pendings[i].shape[1]:
                self._pendings[i] = self._pendings[i].at[slot].set(0)
        self._gap = self._gap.at[slot].set(0)

    def _host_state(self):
        """One bulk device->host view of the batched state (zero-copy on
        CPU); per-slot rows are then plain numpy indexing."""
        return (
            [np.asarray(t) for t in self._tails],
            [np.asarray(p) for p in self._pendings],
            np.asarray(self._gap),
        )

    def _extract_slot(self, s: _Stream, host=None) -> StreamState:
        tails, pendings, gap = host if host is not None else self._host_state()
        st = StreamState(self.plan, self.weights, self.thresholds)
        st.import_steady(
            [t[s.slot] for t in tails],
            [p[s.slot] for p in pendings],
            gap[s.slot],
            s.frames,
        )
        st.samples_seen = s.frontend.samples_in - len(s.frontend)
        return st

    def step(self) -> list[tuple[int, int, np.ndarray | None, Detection | None]]:
        """Advance every stream that has a full hop buffered.

        Returns one (sid, frame_idx, logits, detection) tuple per advanced
        stream; logits is None when ``emit_logits`` is off.
        """
        self._prime_ready()  # numpy warm-up path, excluded from step timing
        hop = self.plan.hop_samples
        ready = [
            s for s in self._streams.values()
            if s.primed and len(s.frontend) >= hop
        ]
        if not ready:
            return []
        t0 = time.perf_counter()
        B = self.capacity
        audio = np.zeros((B, hop), np.int32)
        mask = np.zeros((B,), bool)
        for s in ready:
            audio[s.slot] = s.frontend.pop(hop)
            mask[s.slot] = True

        tails, pendings, gap, _frames = self._step_fn(
            jnp.asarray(audio), jnp.asarray(mask),
            tuple(self._tails), tuple(self._pendings), self._gap,
        )
        self._tails = list(tails)
        self._pendings = list(pendings)
        self._gap = gap

        out = []
        host = self._host_state() if self.emit_logits else None
        for s in ready:
            s.frames += self.plan.frames_per_hop
            logits = det = None
            if self.emit_logits:
                logits = self._peek_stream(s, host)
                det = s.detector.update(s.frames, logits)
                if det is not None:
                    self.metrics.on_detection(s.sid)
            out.append((s.sid, s.frames, logits, det))
        self.metrics.on_step(
            [s.sid for s in ready], self.plan.frames_per_hop,
            time.perf_counter() - t0,
        )
        return out

    def run_until_starved(self) -> list[tuple[int, int, np.ndarray | None,
                                              Detection | None]]:
        """Step until no stream has a full hop buffered."""
        out = []
        while True:
            r = self.step()
            if not r:
                return out
            out.extend(r)

    # -- inspection / teardown ----------------------------------------------

    def peek(self, sid: int) -> np.ndarray:
        """Finalized logits if the stream ended now (inbox included) —
        bit-exact with the offline executor on the audio pushed so far."""
        return self._peek_stream(self._streams[sid], None)

    def _peek_stream(self, s: _Stream, host) -> np.ndarray:
        if s.primed:
            st = self._extract_slot(s, host)
        else:
            st = StreamState(self.plan, self.weights, self.thresholds)
        leftover = s.frontend.peek_all() if len(s.frontend) else None
        return st.peek_logits(leftover)

    def close_stream(self, sid: int) -> StreamResult:
        """Flush (right-pad + drop incomplete pools), free the slot."""
        s = self._streams.pop(sid)
        if s.primed:
            st = self._extract_slot(s)
        else:
            st = StreamState(self.plan, self.weights, self.thresholds)
        st.advance(s.frontend.pop_all(), flush=True)
        logits = st.logits()
        det = s.detector.update(st.frames, logits)
        if det is not None:
            self.metrics.on_detection(sid)
        self._slots[s.slot] = None
        self._clear_slot(s.slot)  # scrub so the next tenant starts clean
        self.metrics.on_close(sid)
        return StreamResult(
            stream_id=sid,
            logits=logits,
            frames=st.frames,
            samples=st.samples_seen,
            events=list(s.detector.events),
        )
