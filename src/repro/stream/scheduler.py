"""Continuous-batching multi-stream scheduler for always-on KWS.

Thousands of concurrent audio streams each produce frames continuously;
the model weights are shared across all of them (one CIM macro, many
users).  This scheduler packs the active streams onto an *elastic* batch
axis and advances them with ONE jitted step per hop:

  * streams join/leave at any time — a free slot is primed from the
    stream's first ``prime_samples`` (generic numpy path in state.py) and
    from then on rides the static-shape batched step;
  * streams whose inbox holds less than a hop are masked out of the step
    (their state passes through untouched), so stragglers never force a
    re-trace — continuous batching, not synchronized batching;
  * **the ingest plane is struct-of-arrays** (``state.RingArena``): every
    stream's inbox is one row of a shared uint8 sample arena, so the
    steady-state hop packs all ready inboxes with ONE vectorized gather
    (``pack_hops``), readiness is one compare, audio lands via one
    scatter (``push_audio_batch``), and detection advances through the
    slot-vectorized ``BatchedDetector`` — zero per-slot python anywhere
    on the hop hot path (``step_batch``; the tuple-per-stream ``step``
    API survives as a thin collation wrapper);
  * the slot pool grows and shrinks at power-of-two sizes: a resize
    pads/slices the batched ring state along the batch axis and lets jit
    re-trace at the new static shape, so bursty arrivals are absorbed
    without provisioning for the peak and results stay bit-exact across
    the resize boundary;
  * the batched step is built on the batched Pallas conv kernel
    (kernels/bnn_conv1d.bnn_conv1d_step_packed) or an equivalent pure-jnp
    einsum path (default on CPU, where Pallas runs interpreted).

**Mesh sharding (one pool, whole mesh).**  Pass ``mesh`` (see
``launch.mesh.make_stream_mesh``) and the batch axis of every piece of
per-stream state — conv tails, pool pendings, GAP counters — shards over
the mesh's ``"data"`` axis while the (tiny) model weights replicate: the
software analogue of the paper's one-large-macro argument (§II-A), one
logical slot pool spanning every device instead of one pool per device.
``SlotPlacement`` (state.py) keeps each stream's row inside one shard's
contiguous block and performs the elastic pow-2 resize *per shard*, so
grow/shrink never reshuffles rows across devices and a sharded run is
bit-exact with the single-device scheduler (tests/test_stream_sharded.py).
With no mesh (or a 1-device mesh) every code path collapses to the
single-device behavior.

**Cross-shard rebalance (migrate-on-idle).**  Resizes never move rows
across devices, so churn that leaves one shard crowded would pin the
whole pool's shrink floor at that shard's tenant count.  At hop
boundaries, when occupancy skew exceeds ``rebalance_threshold``, the
scheduler executes ``SlotPlacement.rebalance()``'s cross-shard (dst,
src) moves: one device-side row gather over the sharded
tails/pendings/GAP state (``ops.remap_slot_rows`` — standalone because
``pallas_call`` is GSPMD-opaque) plus the usual host-side
``remap_rows``/``RingArena.apply_remap`` remap, after which
``_maybe_shrink``'s floor is ``ceil(active / n_shards)`` per shard
instead of the fullest shard's count — the paper's flexible ping-pong
re-layout argument (§II-E) applied to the slot pool.  Migrations are
bit-invisible to the streams riding through them (rows travel
unchanged); ``rebalance_threshold=None`` restores the PR 3 no-migration
behavior.

Per emitted hop the step also runs the *in-jit finalization tail*: a ghost
end-of-stream flush with statically known emission counts (the plan's
``flush_*`` geometry) followed by the fused classifier tail
(kernels/ops.classifier_tail), so every active slot's finalized logits —
the exact logits the offline executor would produce if the utterance ended
now — and softmax posteriors leave the device with the hop itself.  The
host-side ``StreamState.peek_logits`` clone-and-flush survives only as the
exact fallback for mid-hop peeks over leftover sub-hop samples.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.cnn_spec import CNN1DSpec
from repro.kernels import ops
from repro.launch.mesh import dp_axes, dp_size
from repro.obs import Observability
from repro.stream.detector import (
    BatchedDetector,
    Detection,
    DetectorConfig,
    _softmax,
)
from repro.stream.frontend import AudioFrontend, FrontendConfig
from repro.stream.metrics import StreamMetrics
from repro.runtime.pool import SlotPool
# the pow-2 helper moved into the generic runtime with the slot pool; the
# historical name is re-exported because benches/tests import it from here
from repro.runtime.pool import next_pow2 as _next_pow2  # noqa: F401
from repro.stream.state import (
    RingArena,
    StreamPlan,
    StreamState,
    plan_stream,
    prime_batch,
    quantize_pcm,
    remap_rows,
)
from repro.utils.logging import get_logger

log = get_logger("stream")

#: pool row 0 always holds the scheduler's construction weights
DEFAULT_MODEL = "default"

# ---------------------------------------------------------------------------
# Memoized parameter prep (weights dict -> device-ready arrays)
# ---------------------------------------------------------------------------
#
# Building a _BatchedModel converts every layer's ternary weights and SA
# thresholds into device arrays; re-constructing a scheduler over the
# same exported model (K-tenant admission, bench baselines, test
# fixtures) used to redo that prep — and the wp/wn plane packing it
# feeds — from scratch every time.  The cache keys on the *identity* of
# the weights/thresholds dicts plus the plan geometry, holds strong
# references to the keyed dicts (so an id can never be recycled under
# us; an identity check guards the lookup anyway), and is bounded LRU.

_PARAM_CACHE: collections.OrderedDict = collections.OrderedDict()
_PARAM_CACHE_MAX = 64
_param_cache_hits = 0
_param_cache_misses = 0


def prepared_model_params(plan: StreamPlan, weights, thresholds) -> dict:
    """Device-ready per-stage params for one model variant, memoized by
    ``(id(weights), id(thresholds), plan geometry)``.

    Returns ``{"w", "thr", "flip", "fc_w", "fc_thr", "fc_flip"}`` —
    exactly the arrays ``_BatchedModel`` loads — so pool admission,
    scheduler reconstruction, and grow/shrink cycles over an unchanged
    variant never re-run the conversion (or the wp/wn packing derived
    from it downstream).
    """
    global _param_cache_hits, _param_cache_misses
    key = (id(weights), id(thresholds), plan.convs, plan.fcs)
    hit = _PARAM_CACHE.get(key)
    if (hit is not None and hit["weights"] is weights
            and hit["thresholds"] is thresholds):
        _param_cache_hits += 1
        _PARAM_CACHE.move_to_end(key)
        return hit
    _param_cache_misses += 1
    stages = plan.convs
    prep = {
        # strong refs pin the keyed ids for the cache's lifetime
        "weights": weights,
        "thresholds": thresholds,
        "w": [
            jnp.asarray(weights[st.layer_idx].reshape(st.k, st.cin, st.cout),
                        jnp.int32) for st in stages
        ],
        "thr": [jnp.asarray(thresholds[st.layer_idx][0], jnp.float32)
                for st in stages],
        "flip": [jnp.asarray(thresholds[st.layer_idx][1], bool)
                 for st in stages],
        "fc_w": tuple(jnp.asarray(weights[st.layer_idx], jnp.int32)
                      for st in plan.fcs),
        "fc_thr": tuple(jnp.asarray(thresholds[st.layer_idx][0],
                                    jnp.float32) for st in plan.fcs),
        "fc_flip": tuple(jnp.asarray(thresholds[st.layer_idx][1],
                                     jnp.int32) for st in plan.fcs),
    }
    _PARAM_CACHE[key] = prep
    while len(_PARAM_CACHE) > _PARAM_CACHE_MAX:
        _PARAM_CACHE.popitem(last=False)
    return prep


def param_cache_stats() -> dict[str, int]:
    """Hit/miss counters for the memoized parameter prep (tests)."""
    return {
        "hits": _param_cache_hits,
        "misses": _param_cache_misses,
        "size": len(_PARAM_CACHE),
    }


class WeightPool:
    """K complete model variants sharing one plan geometry, one device.

    The pool owns the *host* side of multi-tenancy: which model ids are
    resident, which pool row (0..max_models-1) each occupies, how many
    live streams pin each variant, and LRU admission/eviction.  Row
    indices are stable for a variant's whole residency and the row count
    is FIXED at ``max_models`` from construction, so the device-side
    ``(K, ...)`` weight stacks never change shape — admission is a row
    write, never a retrace.

    Row 0 conventionally holds the scheduler's default model
    (``DEFAULT_MODEL``), admitted at construction and never evicted
    while default-bound streams exist (refcounting covers it like any
    other variant).
    """

    def __init__(self, max_models: int) -> None:
        assert max_models >= 1, max_models
        self.max_models = max_models
        self._index: dict[str, int] = {}
        self._lru: collections.OrderedDict = collections.OrderedDict()
        self._weights: dict[str, dict] = {}
        self._thresholds: dict[str, dict] = {}
        self._refs: dict[str, int] = {}
        self._free = list(range(max_models - 1, -1, -1))  # pop() -> row 0
        self.admits = 0
        self.evictions = 0

    def __contains__(self, model_id: str) -> bool:
        return model_id in self._index

    def __len__(self) -> int:
        return len(self._index)

    def models(self) -> list[tuple[str, int]]:
        """Resident variants as ``(model_id, pool row)``, row order."""
        return sorted(self._index.items(), key=lambda kv: kv[1])

    def index_of(self, model_id: str) -> int:
        return self._index[model_id]

    def refcount(self, model_id: str) -> int:
        return self._refs[model_id]

    def params_for(self, model_id: str):
        """The pool-held (weights, thresholds) host copies."""
        return self._weights[model_id], self._thresholds[model_id]

    def admit(self, model_id: str, weights, thresholds
              ) -> tuple[int, str | None]:
        """Bind a variant to a pool row; returns ``(row, evicted_id)``.

        A resident id is an LRU touch (its stored params stay — the
        caller re-registers, the pool does not re-copy).  When full, the
        least-recently-used variant with NO live streams is evicted;
        if every row is pinned, MemoryError.
        """
        if model_id in self._index:
            self._lru.move_to_end(model_id)
            return self._index[model_id], None
        evicted = None
        if self._free:
            row = self._free.pop()
        else:
            victim = next(
                (m for m in self._lru if self._refs[m] == 0), None
            )
            if victim is None:
                raise MemoryError(
                    f"weight pool full: all {self.max_models} variants "
                    "have live streams; close streams or raise max_models"
                )
            row = self._evict(victim)
            evicted = victim
            self.evictions += 1
        # store the caller's mappings as-is: the memoized prep
        # (prepared_model_params) keys on their identity, so re-admitting
        # the same arrays — here or in another scheduler — never re-packs
        self._weights[model_id] = weights
        self._thresholds[model_id] = thresholds
        self._index[model_id] = row
        self._refs[model_id] = 0
        self._lru[model_id] = None
        self.admits += 1
        return row, evicted

    def _evict(self, model_id: str) -> int:
        row = self._index.pop(model_id)
        del self._weights[model_id]
        del self._thresholds[model_id]
        del self._refs[model_id]
        del self._lru[model_id]
        return row

    def acquire(self, model_id: str) -> int:
        """Pin a variant for one joining stream; returns its row."""
        self._refs[model_id] += 1
        self._lru.move_to_end(model_id)
        return self._index[model_id]

    def release(self, model_id: str) -> None:
        self._refs[model_id] -= 1
        assert self._refs[model_id] >= 0, model_id


@dataclasses.dataclass
class StreamResult:
    """Returned by close_stream: the stream's final, flushed inference."""

    stream_id: int
    logits: np.ndarray        # executor-exact raw logits
    frames: int               # final-conv frames accumulated
    samples: int
    events: list[Detection]


@dataclasses.dataclass
class HopBatch:
    """One batched hop's results in columnar (struct-of-arrays) form —
    what ``step_batch`` returns without ever materializing per-stream
    python objects.  ``detections`` is sparse: one entry per fired event,
    usually empty."""

    sids: np.ndarray                 # (R,) stream ids advanced this hop
    frames: np.ndarray               # (R,) final-conv frame counts after it
    logits: np.ndarray | None        # (R, n_classes) finalized logits
    posteriors: np.ndarray | None    # (R, n_classes) on-device softmax
    detections: list[Detection]


@dataclasses.dataclass
class _Stream:
    sid: int
    slot: int
    frontend: AudioFrontend   # facade over the shared arena row
    events: list[Detection]
    primed: bool = False
    stamp: int = 0  # emit-step from which cached hop logits cover this slot
    model: str = DEFAULT_MODEL  # tenant variant this stream computes with


def _mesh_data_axes(mesh):
    """The mesh's data-parallel axes as a PartitionSpec entry (a tuple of
    axis names is a valid single-dim entry)."""
    return dp_axes(mesh)


class _BatchedModel:
    """Device-resident model + jitted batched hop/finalize for one plan.

    Batch-size polymorphic: every entry point derives B from its operands,
    so the elastic slot pool only pays one re-trace per power-of-two
    capacity it ever visits (jit's shape-keyed cache does the rest).

    With ``mesh`` the weights are replicated across it and the batch axis
    of every operand/result is pinned to the data axes, so GSPMD keeps
    each slot's row resident on its shard through the whole hop (the
    Pallas backend routes through the shard_map entry points in
    kernels/ops.py, which are opaque-kernel-safe).
    """

    def __init__(self, plan: StreamPlan, weights, thresholds,
                 backend: str, interpret: bool | None, mesh=None,
                 donate: bool = False, pool_size: int | None = None,
                 tenant_block: int | None = None,
                 params: dict | None = None) -> None:
        self.plan = plan
        self.backend = backend
        self.interpret = interpret
        self.mesh = mesh
        self.pool_size = pool_size
        self._tenant_block = tenant_block
        prep = params if params is not None else prepared_model_params(
            plan, weights, thresholds
        )
        self._w = list(prep["w"])
        self._thr = list(prep["thr"])
        self._flip = list(prep["flip"])
        self._fc_w = tuple(prep["fc_w"])
        self._fc_thr = tuple(prep["fc_thr"])
        self._fc_flip = tuple(prep["fc_flip"])
        self._fc_raw = tuple(st.out_raw for st in plan.fcs)
        if pool_size is not None:
            # tenant pool: axis 0 stacks K complete variants.  Unfilled
            # rows hold the default model, so the stack SHAPES are fixed
            # at max_models from construction — admitting a variant is a
            # row write (set_model_row), never a retrace.
            stack = lambda t: jnp.stack([t] * pool_size)  # noqa: E731
            self._w = [stack(w) for w in self._w]
            self._thr = [stack(t) for t in self._thr]
            self._flip = [stack(f) for f in self._flip]
            self._fc_w = tuple(stack(w) for w in self._fc_w)
            self._fc_thr = tuple(stack(t) for t in self._fc_thr)
            self._fc_flip = tuple(stack(f) for f in self._fc_flip)
        # offset fold (per tenant row when pooled)
        self._wsum = [
            jnp.sum(w, axis=(1, 2) if pool_size is not None else (0, 1))
            for w in self._w
        ]
        if mesh is not None:
            # one macro, many shards: weights live replicated on every
            # device (the whole (K, ...) pool replicates exactly like
            # the single weight set); only per-stream state is sharded
            put = self._rep_put
            self._w = [put(w) for w in self._w]
            self._thr = [put(t) for t in self._thr]
            self._flip = [put(f) for f in self._flip]
            self._wsum = [put(w) for w in self._wsum]
            self._fc_w = tuple(put(w) for w in self._fc_w)
            self._fc_thr = tuple(put(t) for t in self._fc_thr)
            self._fc_flip = tuple(put(f) for f in self._fc_flip)
            self._baxes = _mesh_data_axes(mesh)
        # with donate=True the slot-state operands (tails, pendings, gap)
        # are donated to each hop: XLA aliases the output state onto the
        # input buffers, so a restep never copies the resident state.  The
        # caller must treat the passed-in state arrays as consumed (the
        # scheduler reassigns them from the step's results immediately).
        self.step = jax.jit(
            self._step, static_argnames=("emit",),
            donate_argnums=(2, 3, 4) if donate else (),
        )
        self.finalize = jax.jit(self._finalize)

    def _rep_put(self, t: jax.Array) -> jax.Array:
        """Replicate a weight array across the mesh (identity without)."""
        if self.mesh is None:
            return t
        return jax.device_put(t, NamedSharding(self.mesh, P()))

    def _pin(self, x: jax.Array) -> jax.Array:
        """Constrain the leading (batch) axis to the mesh's data sharding."""
        if self.mesh is None:
            return x
        spec = P(self._baxes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec)
        )

    # -- tenant pool device side ---------------------------------------------

    def set_model_row(self, idx: int, weights, thresholds) -> None:
        """Write one tenant variant into pool row ``idx`` (admission).

        Row updates keep every stacked shape fixed, so the jitted step's
        shape-keyed cache survives; under a mesh the updated stacks
        re-replicate like the originals.  The variant must share the
        plan geometry (same spec/hop) — shapes are asserted by the
        ``.at[idx].set`` writes themselves.
        """
        assert self.pool_size is not None, "not a pooled model"
        assert 0 <= idx < self.pool_size, (idx, self.pool_size)
        prep = prepared_model_params(self.plan, weights, thresholds)
        put = self._rep_put
        for i in range(len(self.plan.convs)):
            self._w[i] = put(self._w[i].at[idx].set(prep["w"][i]))
            self._thr[i] = put(self._thr[i].at[idx].set(prep["thr"][i]))
            self._flip[i] = put(self._flip[i].at[idx].set(prep["flip"][i]))
            self._wsum[i] = put(jnp.sum(self._w[i], axis=(1, 2)))
        self._fc_w = tuple(
            put(w.at[idx].set(v)) for w, v in zip(self._fc_w, prep["fc_w"])
        )
        self._fc_thr = tuple(
            put(t.at[idx].set(v))
            for t, v in zip(self._fc_thr, prep["fc_thr"])
        )
        self._fc_flip = tuple(
            put(f.at[idx].set(v))
            for f, v in zip(self._fc_flip, prep["fc_flip"])
        )

    def _bb(self, b: int) -> int | None:
        """Tenant-aligned batch block for the pooled kernels.

        Placement keeps each ``min(tenant_block, shard_capacity)`` slot
        block single-model, so forcing the kernel's batch block to the
        same size keeps every grid block's weight gather one row.  None
        (backend default) when un-pooled.
        """
        if self.pool_size is None:
            return None
        S = 1 if self.mesh is None else dp_size(self.mesh)
        return min(self._tenant_block, max(1, b // S))

    def _block_gather(self, stack: jax.Array, model_idx: jax.Array
                      ) -> tuple[jax.Array, int]:
        """One weight row per tenant block instead of per slot.

        Placement keeps every block single-model (``_sync_model_rows``),
        so the naive per-slot gather — B full weight copies driving a
        per-example batched matmul — collapses to one gather per block
        and a per-block matmul: tb-fold fewer, tb-fold larger GEMMs.
        Exact: the contractions are int32, so regrouping rows into
        blocks cannot change a single accumulation.
        """
        tb = self._bb(model_idx.shape[0])
        return stack[model_idx.reshape(-1, tb)[:, 0]], tb

    # -- shared conv math ----------------------------------------------------

    def _conv_raw(self, i: int, window: jax.Array, n_conv: int,
                  model_idx: jax.Array | None = None) -> jax.Array:
        """(B, len, Cin) window -> (B, n_conv, Cout) raw popcount diff.
        With ``model_idx`` the weights are the pooled (K, ...) stacks —
        one gather per tenant block inside the kernel, one per-row
        gather on the jnp path."""
        st = self.plan.convs[i]
        w = self._w[i]
        if st.in_bits > 1:
            # bit-serial first layer; offset folds out after accumulation.
            # ONE launch accumulates every bit plane in-kernel (PR 8) —
            # the fallback path no longer pays per-plane dispatch.
            if self.backend == "pallas":
                return ops.bitserial_conv1d_batched_sharded(
                    window.astype(jnp.uint32), w, model_idx,
                    mesh=self.mesh, bits=st.in_bits, offset=st.in_offset,
                    stride=st.stride, pad=0,
                    bb=self._bb(window.shape[0]), interpret=self.interpret,
                )
            xi = window.astype(jnp.int32) - st.in_offset
            taps = [
                xi[:, t : t + (n_conv - 1) * st.stride + 1 : st.stride]
                for t in range(st.k)
            ]
            xs = jnp.stack(taps, axis=1)  # (B, K, n_conv, Cin)
            if model_idx is not None:
                wg, tb = self._block_gather(w, model_idx)
                xg = xs.reshape(-1, tb, *xs.shape[1:])
                return jnp.einsum("gtknc,gkco->gtno", xg, wg).reshape(
                    xs.shape[0], n_conv, -1)
            return jnp.einsum("bknc,kco->bno", xs, w)
        if self.backend == "pallas":
            return ops.bnn_conv1d_batched_sharded(
                window.astype(jnp.uint32), w, None, None, model_idx,
                mesh=self.mesh, stride=st.stride, pad=0, mode="raw",
                bb=self._bb(window.shape[0]), interpret=self.interpret,
            )
        taps = [
            window[:, t : t + (n_conv - 1) * st.stride + 1 : st.stride]
            for t in range(st.k)
        ]
        xs = jnp.stack(taps, axis=1).astype(jnp.int32)
        if model_idx is not None:
            wg, tb = self._block_gather(w, model_idx)
            xg = xs.reshape(-1, tb, *xs.shape[1:])
            return jnp.einsum("gtknc,gkco->gtno", xg, wg).reshape(
                xs.shape[0], n_conv, -1)
        return jnp.einsum("bknc,kco->bno", xs, w)

    def _sa(self, i: int, raw: jax.Array,
            model_idx: jax.Array | None = None) -> jax.Array:
        """SA binarization, executor-exact: integer thresholds make the
        float32 compare knife-edge free."""
        if model_idx is not None:
            thr = self._thr[i][model_idx][:, None, :]
            flip = self._flip[i][model_idx][:, None, :]
        else:
            thr = self._thr[i][None, None, :]
            flip = self._flip[i][None, None, :]
        ge = raw.astype(jnp.float32) >= thr
        return jnp.where(flip, ~ge, ge).astype(jnp.int32)

    # -- the hop -------------------------------------------------------------

    def _step(self, audio, mask, tails, pendings, gap, model_idx=None,
              *, emit: bool):
        """One batched hop; with ``emit`` the in-jit finalization tail also
        returns per-slot finalized logits + posteriors.  Shapes static.
        ``model_idx`` ((B,) int32, pooled models only) selects each
        slot's tenant variant — constant per tenant block by placement,
        so the launch count stays K-independent."""
        plan = self.plan
        stages = plan.convs
        if self.backend == "megakernel":
            # the whole cascade — bit-serial layer 0, SA, pool phases,
            # tail/pending carry, GAP, mask merge, and (on emit) the ghost
            # flush + classifier — is ONE fused launch per shard; only the
            # hop input and the updated slot state touch HBM
            audio = audio.reshape(
                audio.shape[0], plan.hop_samples, stages[0].cin
            )
            out = ops.hop_megakernel_sharded(
                audio, mask.astype(jnp.int32), tuple(tails), tuple(pendings),
                gap, tuple(self._w), tuple(self._thr), tuple(self._flip),
                self._fc_w, self._fc_thr, self._fc_flip, model_idx,
                mesh=self.mesh, stages=stages, emit=emit,
                fc_raw=self._fc_raw, bb=self._bb(gap.shape[0]),
                interpret=self.interpret,
            )
            new_tails = tuple(self._pin(t) for t in out[0])
            new_pendings = tuple(self._pin(p) for p in out[1])
            gap2 = self._pin(out[2])
            state = new_tails, new_pendings, gap2
            if not emit:
                return state
            logits = self._pin(out[3])
            post = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
            return (*state, logits, post)
        cur = audio.reshape(audio.shape[0], plan.hop_samples, stages[0].cin)
        new_tails, new_pendings = [], []
        for i, st in enumerate(stages):
            window = jnp.concatenate([tails[i], cur], axis=1)
            raw = self._conv_raw(i, window, st.n_conv, model_idx)
            new_tails.append(window[:, st.n_conv * st.stride :])
            y = self._sa(i, raw, model_idx)
            if st.pool > 1:
                frames = (
                    jnp.concatenate([pendings[i], y], axis=1)
                    if st.phase else y
                )
                used = st.n_out * st.pool
                pooled = frames[:, :used].reshape(
                    frames.shape[0], st.n_out, st.pool, st.cout
                ).max(axis=2)
                new_pendings.append(frames[:, used:])
                cur = pooled
            else:
                new_pendings.append(pendings[i])
                cur = y
        # saturate at the 8-bit PWB counter ceiling inside the step: the
        # accumulation is monotone non-negative, so incremental clamping
        # equals clamping the int64 total (pwb.gap_counts semantics) and
        # int32 can never wrap on always-on streams
        gap2 = jnp.minimum(gap + cur.sum(axis=1, dtype=jnp.int32), 255)

        m3 = mask[:, None, None]
        new_tails = [
            self._pin(jnp.where(m3, nt, t))
            for nt, t in zip(new_tails, tails)
        ]
        new_pendings = [
            self._pin(jnp.where(m3, np_, p)) if p.shape[1] else p
            for np_, p in zip(new_pendings, pendings)
        ]
        gap2 = self._pin(jnp.where(mask[:, None], gap2, gap))
        state = tuple(new_tails), tuple(new_pendings), gap2
        if not emit:
            return state
        # finalization tail on the merged state: masked-out rows hold their
        # previous (still steady) state, so every primed slot's logits are
        # valid — ready rows are simply the ones the scheduler reads
        logits, post = self._finalize(*state, model_idx)
        return (*state, logits, post)

    # -- in-jit finalization tail --------------------------------------------

    def _finalize(self, tails, pendings, gap, model_idx=None):
        """Logits/posteriors as if every stream ended at this hop boundary.

        A *ghost* end-of-stream flush — statically sized by the plan's
        ``flush_*`` geometry — cascades each layer's right pad through the
        conv stack without touching the live state, then the fused
        classifier tail drains the saturated GAP counts through the fc
        stack.  Bit-exact with ``StreamState.peek_logits()`` on an empty
        inbox (tests/test_stream.py).
        """
        if self.backend == "megakernel":
            logits = self._pin(ops.finalize_megakernel_sharded(
                tuple(tails), tuple(pendings), gap,
                tuple(self._w), tuple(self._thr), tuple(self._flip),
                self._fc_w, self._fc_thr, self._fc_flip, model_idx,
                mesh=self.mesh, stages=self.plan.convs,
                fc_raw=self._fc_raw, bb=self._bb(gap.shape[0]),
                interpret=self.interpret,
            ))
            post = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
            return logits, post
        stages = self.plan.convs
        B = gap.shape[0]
        cur = None  # frames flowing down from the layer above's flush
        for i, st in enumerate(stages):
            pieces = [tails[i]]
            if cur is not None and st.flush_in:
                pieces.append(cur)
            if st.pad:
                pad_val = st.in_offset if st.in_bits > 1 else 0
                pieces.append(
                    self._pin(
                        jnp.full((B, st.pad, st.cin), pad_val, jnp.int32)
                    )
                )
            if st.flush_conv > 0:
                window = jnp.concatenate(pieces, axis=1)
                y = self._sa(i, self._conv_raw(i, window, st.flush_conv,
                                               model_idx), model_idx)
            else:
                y = jnp.zeros((B, 0, st.cout), jnp.int32)
            frames = jnp.concatenate([pendings[i], y], axis=1)
            used = st.flush_out * st.pool  # drop-remainder (ref_maxpool1d)
            cur = frames[:, :used].reshape(
                B, st.flush_out, st.pool, st.cout
            ).max(axis=2)
        gap_f = jnp.minimum(gap + cur.sum(axis=1, dtype=jnp.int32), 255)
        logits = self._pin(self._classifier(gap_f, model_idx))
        post = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        return logits, post

    def _classifier(self, gap_f: jax.Array,
                    model_idx: jax.Array | None = None) -> jax.Array:
        """Saturated GAP counts (B, C) -> raw logits (B, n_classes)."""
        if self.backend == "pallas":
            return ops.classifier_tail_sharded(
                gap_f, self._fc_w, self._fc_thr, self._fc_flip, model_idx,
                mesh=self.mesh, out_raw=self._fc_raw,
                bb=self._bb(gap_f.shape[0]), interpret=self.interpret,
            )
        h = gap_f
        for j, st in enumerate(self.plan.fcs):
            if model_idx is not None:
                wg, tb = self._block_gather(self._fc_w[j], model_idx)
                hg = h.reshape(-1, tb, h.shape[1])
                raw = jnp.einsum("gtc,gco->gto", hg, wg).reshape(
                    h.shape[0], -1)
                thr = self._fc_thr[j][model_idx]
                flip = self._fc_flip[j][model_idx]
            else:
                raw = h @ self._fc_w[j]
                thr = self._fc_thr[j][None, :]
                flip = self._fc_flip[j][None, :]
            if st.out_raw:
                h = raw
            else:
                ge = raw.astype(jnp.float32) >= thr
                h = jnp.where(flip != 0, ~ge, ge).astype(jnp.int32)
        return h

    def dispatches_per_hop(self, emit: bool) -> int:
        """Static per-shard ``pallas_call`` count for one hop.

        Derived from the plan + backend alone; tests/test_megakernel.py
        asserts it equals the count actually traced through
        ``kernels.dispatch``, so this figure (surfaced per hop by
        ``StreamMetrics`` and BENCH_stream.json) cannot drift from the
        kernels launched.  ``jnp`` lowers to plain XLA: 0 by definition.
        """
        if self.backend == "jnp":
            return 0
        if self.backend == "megakernel":
            return 1  # emit's flush + classifier ride the same launch
        # per-stage pallas: one launch per conv stage (the bit-serial
        # first layer is a single plane-accumulating launch since PR 8),
        # plus — on emit — the ghost flush's conv launches and the fused
        # classifier tail
        n = len(self.plan.convs)
        if emit:
            n += sum(1 for st in self.plan.convs if st.flush_conv > 0) + 1
        return n


class StreamScheduler:
    """Continuous batching over an elastic pool of stream slots.

    ``capacity`` is the *ceiling*: the pool starts at ``initial_capacity``
    (default ``min_capacity``) and doubles on demand up to the ceiling;
    ``close_stream`` halves it once occupancy falls to a quarter (never
    below ``min_capacity`` — set ``min_capacity == capacity`` to pin a
    fixed-size pool).  Each resize is a pure pad/slice of the batched ring
    state, so a stream fed across a resize boundary produces bit-identical
    logits to one fed at a fixed capacity.

    With ``mesh`` the pool spans the mesh: every capacity is ``n_shards *
    per_shard`` rows, a joining stream lands on the least-loaded shard,
    and the elastic resize scales the *per-shard* capacity so rows never
    cross devices (``SlotPlacement``).  ``capacity`` (and, if given,
    ``min_capacity``/``initial_capacity``) must be multiples of the mesh's
    data-axis size.  When leave churn skews occupancy by more than
    ``rebalance_threshold`` tenants between the fullest and emptiest
    shard, the next hop boundary migrates tenants across shards to level
    the pool (and re-checks the shrink); ``None`` disables migration.
    """

    def __init__(
        self,
        spec: CNN1DSpec,
        weights: dict[int, np.ndarray],
        thresholds: dict[int, tuple[np.ndarray, np.ndarray]],
        capacity: int = 8,
        hop_frames: int = 1,
        backend: str = "jnp",
        interpret: bool | None = None,
        detector_cfg: DetectorConfig | None = None,
        emit_logits: bool = True,
        sample_rate: int = 16000,
        initial_capacity: int | None = None,
        min_capacity: int | None = None,
        mesh=None,
        inbox_samples: int | None = None,
        rebalance_threshold: int | None = 1,
        obs: Observability | None = None,
        clock=time.perf_counter,
        donate_buffers: bool = False,
        max_models: int = 1,
        tenant_block: int = 8,
        prewarm: bool = False,
    ) -> None:
        assert backend in ("jnp", "pallas", "megakernel"), backend
        # every hop stamp (metrics, trace spans) reads this clock, so the
        # concurrency suite can drive sync and async schedulers with one
        # controllable fake clock and compare their traces structurally
        self._clock = clock
        self.plan = plan_stream(spec, hop_frames=hop_frames)
        self.weights = {k: np.asarray(v) for k, v in weights.items()}
        self.thresholds = thresholds
        self.mesh = mesh
        if mesh is not None:
            self.n_shards = dp_size(mesh)
            self._baxes = _mesh_data_axes(mesh)
        else:
            self.n_shards = 1
        S = self.n_shards
        self.backend = backend
        self.detector_cfg = detector_cfg or DetectorConfig()
        self.emit_logits = emit_logits
        # the observability plane: bounded metrics registry + hop trace
        # spans + structured lifecycle events (always on, always O(1)
        # memory; pass obs= to share one plane across runtimes or to
        # write an event JSONL / enable the jax.profiler bridge)
        self.obs = obs if obs is not None else Observability.create()
        self.metrics = StreamMetrics(self.plan, sample_rate, n_shards=S,
                                     registry=self.obs.registry)
        # tenant weight pool: with max_models > 1 the device weights are
        # (K, ...) stacks and each stream binds a registered variant at
        # join time; row 0 always holds the construction weights
        assert max_models >= 1, max_models
        self._pool = WeightPool(max_models) if max_models > 1 else None
        self._tenant_block = tenant_block
        if self._pool is not None:
            assert tenant_block >= 1 and tenant_block & (tenant_block - 1) \
                == 0, f"tenant_block {tenant_block} not a power of two"
            self._pool.admit(DEFAULT_MODEL, self.weights, self.thresholds)
        self._params = prepared_model_params(self.plan, weights, thresholds)
        self._model = _BatchedModel(
            self.plan, self.weights, thresholds, backend, interpret, mesh,
            donate=donate_buffers,
            pool_size=max_models if max_models > 1 else None,
            tenant_block=tenant_block, params=self._params,
        )

        # the generic slot-pool plane (repro.runtime): slot<->sid binding,
        # per-shard pow-2 elastic resize, cross-shard rebalance, idle-time
        # prewarm, and the resize/rebalance observability all live there —
        # this scheduler is one SlotPool *client* (the KWS workload), the
        # LM serving engine is another.  The client surface is the
        # device_state/slot_axes/shard/apply_host_remap methods below.
        self._slots = SlotPool(
            self, capacity,
            initial_capacity=initial_capacity,
            min_capacity=min_capacity,
            n_shards=S, mesh=mesh,
            tenant_block=tenant_block if self._pool is not None else None,
            rebalance_threshold=rebalance_threshold,
            obs=self.obs,
            on_resize=self.metrics.on_resize,
            on_rebalance=self.metrics.on_rebalance,
            prewarm=prewarm,
            clock=self._clock,
        )
        cap0 = self._slots.capacity
        # batched state lives device-resident between hops; host copies are
        # made only on join/leave or fallback peeks — never the hot loop
        self._tails = [
            self._shard(jnp.zeros((cap0, st.tail, st.cin), jnp.int32))
            for st in self.plan.convs
        ]
        self._pendings = [
            self._shard(jnp.zeros((cap0, st.phase, st.cout), jnp.int32))
            for st in self.plan.convs
        ]
        self._gap = self._shard(
            jnp.zeros((cap0, self.plan.gap_channels), jnp.int32)
        )
        # the ingest plane: ONE shared sample arena + slot-vectorized
        # detector + slot-indexed bookkeeping vectors, all resized through
        # the same SlotPlacement remap as the device arrays
        base_inbox = (
            inbox_samples if inbox_samples is not None
            else FrontendConfig().capacity_samples
        )
        # whole hops only: keeps primed slots on pack_hops' block-aligned
        # contiguous fast path (see RingArena.rebase)
        hop = self.plan.hop_samples
        self._inbox_samples = -(-base_inbox // hop) * hop
        self._arena = RingArena(cap0, self._inbox_samples)
        self._detector = BatchedDetector(
            cap0, self.plan.fcs[-1].cout, self.detector_cfg
        )
        self._slot_sid = np.full(cap0, -1, np.int64)
        self._primed_mask = np.zeros(cap0, bool)
        self._frames_v = np.zeros(cap0, np.int64)  # frames per slot
        # per-slot tenant rows (pool row 0 = default model); staged to the
        # device with each hop when pooled, remapped with every resize/
        # rebalance like the other slot-indexed vectors
        self._model_idx_v = np.zeros(cap0, np.int32)
        self._model_rows_dirty = False
        self._model_idx_dev = None  # cached device upload of the rows
        self._streams: dict[int, _Stream] = {}
        self._unprimed: set[int] = set()  # empty in steady state
        self._next_sid = 0
        # hop-boundary peeks are served from the last emit step's logits:
        # _finalize covers EVERY primed slot (masked rows hold steady
        # state), so the row stays valid until the slot is rewritten on
        # the host (priming) or remapped (resize)
        self._emit_step = 0
        self._emit_cache: np.ndarray | None = None
        self._emit_cache_step = -1
        # idle-time jit pre-warm of the next pow-2 capacity (satellite of
        # the tenant-pool PR: grow spikes hide behind starved steps);
        # the dedup set lives here because its key includes emit_logits
        self._warmed: set[tuple[int, bool]] = set()

    # -- elastic slot pool (delegated to repro.runtime.SlotPool) -------------

    @property
    def capacity(self) -> int:
        """Current pool size (<= ``max_capacity``)."""
        return self._slots.capacity

    @property
    def shard_capacity(self) -> int:
        """Current per-shard pool size (== ``capacity`` with no mesh)."""
        return self._slots.shard_capacity

    @property
    def max_capacity(self) -> int:
        """Capacity ceiling the elastic pool doubles toward."""
        return self._slots.max_capacity

    # internal aliases kept for the concurrency suite and subclasses: the
    # pool owns the state; these names predate the runtime extraction
    @property
    def _capacity(self) -> int:
        return self._slots.capacity

    @property
    def _min_capacity(self) -> int:
        return self._slots.min_capacity

    @property
    def _placement(self):
        return self._slots.placement

    @property
    def _skew_dirty(self) -> bool:
        return self._slots.skew_dirty

    @_skew_dirty.setter
    def _skew_dirty(self, v: bool) -> None:
        self._slots.skew_dirty = v

    def _shard(self, x):
        """Settle an array's batch axis onto the mesh's data sharding."""
        if self.mesh is None:
            return x
        spec = P(self._baxes, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(self.mesh, spec))

    # -- SlotPool client surface (see repro.runtime.pool.SlotPoolClient) ----

    def device_state(self):
        """The per-slot device pytree the pool resizes/remaps: conv tails,
        pool pendings, GAP counters (slot axis 0 everywhere)."""
        return (tuple(self._tails), tuple(self._pendings), self._gap)

    def set_device_state(self, state) -> None:
        tails, pendings, gap = state
        self._tails = list(tails)
        self._pendings = list(pendings)
        self._gap = gap

    def slot_axes(self):
        n = len(self.plan.convs)
        return ((0,) * n, (0,) * n, 0)

    def shard(self, x, axis: int = 0):
        return self._shard(x)

    def apply_host_remap(self, remap: dict[int, int], new_cap: int) -> None:
        """Ride the host-side ingest plane through a slot remap, so a
        stream's inbox/detector/bookkeeping rows stay glued to its slot."""
        self._arena.apply_remap(remap, new_cap)
        self._detector.apply_remap(remap, new_cap)
        self._slot_sid = remap_rows(self._slot_sid, remap, new_cap, fill=-1)
        self._primed_mask = remap_rows(self._primed_mask, remap, new_cap)
        self._frames_v = remap_rows(self._frames_v, remap, new_cap)
        self._model_idx_v = remap_rows(self._model_idx_v, remap, new_cap)
        self._model_rows_dirty = True
        for s in self._streams.values():
            s.slot = remap[s.slot]
            s.frontend._slot = s.slot
        self._emit_cache = None  # cached rows are indexed by old slots

    def warm(self, capacity: int) -> None:
        self._warm_capacity(capacity)

    # -- tenant weight pool --------------------------------------------------

    @property
    def models(self) -> list[tuple[str, int]]:
        """Resident pool variants as ``(model_id, pool row)`` pairs."""
        if self._pool is None:
            return [(DEFAULT_MODEL, 0)]
        return self._pool.models()

    def register_model(self, model_id: str, weights, thresholds) -> int:
        """Admit one tenant variant into the weight pool; returns its row.

        The variant must share the default model's plan geometry (same
        spec, same hop).  Admission writes one row of the device-resident
        ``(K, ...)`` stacks — shapes never change, so the jitted step's
        cache survives.  When the pool is full, the least-recently-used
        variant with NO live streams is evicted (MemoryError when every
        row is pinned).  Re-admitting a resident id is an LRU touch.
        """
        if self._pool is None:
            raise ValueError(
                "single-model scheduler: construct with max_models > 1 "
                "to enable the tenant weight pool"
            )
        if model_id in self._pool:
            row, _ = self._pool.admit(model_id, weights, thresholds)
            return row
        row, evicted = self._pool.admit(model_id, weights, thresholds)
        w, t = self._pool.params_for(model_id)
        self._model.set_model_row(row, w, t)
        if evicted is not None:
            self.metrics.on_model_evict(evicted)
            self.obs.events.emit("model_evict", model=evicted, row=row)
        self.metrics.on_model_admit(model_id)
        self.obs.events.emit("model_admit", model=model_id, row=row,
                             evicted=evicted)
        return row

    def _stream_params(self, s: _Stream):
        """The weights/thresholds the stream's slot computes with."""
        if self._pool is None or s.model == DEFAULT_MODEL:
            return self.weights, self.thresholds
        return self._pool.params_for(s.model)

    def _sync_model_rows(self) -> None:
        """Rebuild the per-slot tenant rows block-uniformly from the live
        streams.  The kernels gather ONE weight row per tenant block, so
        every slot of a block — free slots included — must carry the
        block's bound row: a freed or remapped slot left stale (or reset
        to 0) would steer its whole block to the wrong weights.  Coalesced
        by a dirty flag so joins/closes/resizes pay it once per hop."""
        if self._pool is None or not self._model_rows_dirty:
            return
        v = np.zeros(self._capacity, np.int32)
        tb = min(self._tenant_block, self._placement.shard_capacity)
        for s in self._streams.values():
            b0 = (s.slot // tb) * tb
            v[b0:b0 + tb] = self._pool.index_of(s.model)
        self._model_idx_v = v
        self._model_rows_dirty = False
        self._model_idx_dev = None  # rows changed: next hop re-uploads

    # -- stream lifecycle ----------------------------------------------------

    def add_stream(self, sid: int | None = None,
                   frontend_cfg: FrontendConfig | None = None,
                   model: str | None = None) -> int:
        """Claim a slot for a new stream on the least-loaded shard (growing
        the pool if needed); returns the stream id.  With a tenant pool,
        ``model`` binds the stream to a registered variant (default: the
        construction weights); placement keeps every ``tenant_block``
        slot block single-model, so the batched hop's per-block weight
        gather stays one row."""
        sid = self._next_sid if sid is None else sid
        assert sid not in self._streams, f"stream {sid} already exists"
        if self._pool is not None:
            model_id = DEFAULT_MODEL if model is None else model
            if model_id not in self._pool:
                raise KeyError(
                    f"unknown model {model_id!r}; register_model() first"
                )
            midx = self._pool.acquire(model_id)
        else:
            if model is not None:
                raise ValueError(
                    "model binding needs a tenant pool (max_models > 1)"
                )
            model_id, midx = DEFAULT_MODEL, 0
        try:
            # grow-on-demand alloc (pow-2 doubling to the ceiling) is the
            # pool's; it raises MemoryError when every slot stays busy
            slot = self._slots.alloc(sid, model=model_id)
        except MemoryError:
            if self._pool is not None:
                self._pool.release(model_id)
            raise
        self._next_sid = max(self._next_sid, sid) + 1
        self._streams[sid] = _Stream(
            sid=sid,
            slot=slot,
            frontend=AudioFrontend(frontend_cfg, arena=self._arena,
                                   slot=slot),
            events=[],
            model=model_id,
        )
        self._slot_sid[slot] = sid
        self._model_idx_v[slot] = midx
        self._model_rows_dirty = True  # block fill happens at sync
        self._detector.reset_slot(slot)
        self._unprimed.add(sid)
        self.metrics.on_join(sid)
        self.obs.events.emit("join", sid=sid, slot=slot,
                             shard=slot // self._placement.shard_capacity)
        return sid

    def _require(self, sid: int) -> _Stream:
        s = self._streams.get(sid)
        if s is None:
            live = sorted(self._streams)
            shown = live if len(live) <= 8 else live[:8] + ["..."]
            raise KeyError(
                f"unknown or already-closed stream sid {sid}; "
                f"{len(live)} live sid(s): {shown}"
            )
        return s

    def push_audio(self, sid: int, audio: np.ndarray) -> None:
        s = self._require(sid)
        s.frontend.push(audio)  # arena counts samples_in; folded at close

    def push_audio_batch(self, sids: list[int],
                         chunks: list[np.ndarray]) -> None:
        """Bulk twin of ``push_audio``: one vectorized quantize + scatter
        lands every stream's chunk in the shared arena
        (``RingArena.push_batch``) — the ingest half of the zero-per-slot
        hop path.  Float PCM and u8 chunks may be mixed, and a sid may
        appear multiple times: duplicate-sid chunks coalesce in arrival
        order (float chunks pre-quantized with the slot's gain — the
        exact math the arena would apply — so the single scatter stays
        bit-identical to sequential pushes).  Per-stream ``samples_in``
        counters are NOT walked here — the arena's vectorized counter is
        the truth and folds into the stream's metrics at close."""
        streams = [self._require(sid) for sid in sids]
        slots = np.fromiter((s.slot for s in streams), np.int64, len(streams))
        if np.unique(slots).size != slots.size:
            slots, chunks, extra = self._coalesce_chunks(slots, chunks)
        else:
            extra = None
        self._arena.push_batch(slots, chunks)
        if extra is not None:
            # credit the chunks the coalesce merged away (push_batch
            # counted one per slot) so chunks_in stays arrival-accurate
            self._arena.chunks_in[slots] += extra
            self._arena.total_chunks_in += int(extra.sum())

    def _coalesce_chunks(self, slots: np.ndarray, chunks: list[np.ndarray]
                         ) -> tuple[np.ndarray, list[np.ndarray], np.ndarray]:
        """Merge duplicate-slot chunks into one chunk per slot (arrival
        order preserved).  Float PCM is quantized here with the slot's
        gain — identical to ``RingArena.push_batch``'s vectorized pass —
        so a float chunk followed by a u8 chunk concatenates without the
        dtype of one corrupting the other."""
        merged: dict[int, list[np.ndarray]] = {}
        for slot, chunk in zip(slots.tolist(), chunks):
            c = np.asarray(chunk).reshape(-1)
            if c.dtype.kind == "f":
                c = quantize_pcm(c, self._arena.gain[slot])
            elif c.dtype.kind not in "iu":
                raise TypeError(
                    f"audio must be float PCM or integer u8 codes, "
                    f"got dtype {c.dtype}"
                )
            merged.setdefault(slot, []).append(c)
        out_slots = np.fromiter(merged.keys(), np.int64, len(merged))
        out_chunks = [
            cs[0] if len(cs) == 1 else np.concatenate(cs)
            for cs in merged.values()
        ]
        extra = np.fromiter(
            (len(cs) - 1 for cs in merged.values()), np.int64, len(merged)
        )
        return out_slots, out_chunks, extra

    @property
    def active(self) -> list[int]:
        return sorted(self._streams)

    # -- the batched hop -----------------------------------------------------

    def _prime_ready(self) -> None:
        """Batched mass-join primer: every unprimed stream whose inbox
        holds ``prime_samples`` warms up through ONE vectorized numpy
        advance (``state.prime_batch`` — bit-exact with the per-stream
        ``StreamState`` warm-up) and lands in the slot pool via one
        batched scatter per state array, so a 256-stream mass join costs
        one cascade instead of 256 per-stream numpy warm-ups.  Runs only
        while ``self._unprimed`` is non-empty — never in steady state."""
        prime = self.plan.prime_samples
        sids = sorted(self._unprimed)
        slots = np.fromiter(
            (self._streams[sid].slot for sid in sids), np.int64, len(sids)
        )
        ready = (self._arena.wr[slots] - self._arena.rd[slots]) >= prime
        if not ready.any():
            return
        t0 = self._clock()
        sids = [sid for sid, r in zip(sids, ready.tolist()) if r]
        slots = slots[ready]
        samples = self._arena.pop_batch(slots, prime)
        # priming consumed a non-hop-multiple; realign the inboxes so
        # every future hop window is one contiguous block
        self._arena.rebase_batch(slots)
        # one vectorized warm-up per tenant model (a single group without
        # a pool): each group's rows land via the same batched scatters
        if self._pool is None:
            groups = [(self.weights, self.thresholds,
                       np.arange(len(sids), dtype=np.int64))]
        else:
            by_model: dict[str, list[int]] = {}
            for j, sid in enumerate(sids):
                by_model.setdefault(self._streams[sid].model, []).append(j)
            groups = [
                (*self._stream_params(self._streams[sids[pos[0]]]),
                 np.asarray(pos, np.int64))
                for pos in by_model.values()
            ]
        for w, t, pos in groups:
            steady = prime_batch(self.plan, w, t, samples[pos])
            gslots = slots[pos]
            jslots = jnp.asarray(gslots)
            for i in range(len(self.plan.convs)):
                self._tails[i] = self._tails[i].at[jslots].set(
                    jnp.asarray(steady["tails"][i])
                )
                if self._pendings[i].shape[1]:
                    self._pendings[i] = self._pendings[i].at[jslots].set(
                        jnp.asarray(steady["pendings"][i])
                    )
            self._gap = self._gap.at[jslots].set(
                jnp.asarray(steady["gap"].astype(np.int32))
            )
            self._frames_v[gslots] = steady["frames"]
        self._primed_mask[slots] = True
        for sid in sids:
            s = self._streams[sid]
            s.primed = True
            self._unprimed.discard(sid)
            # host wrote the slot: earlier cached logits don't cover it;
            # the NEXT emit step (which includes this write) does
            s.stamp = self._emit_step + 1
        self.obs.trace.add("prime_batch", t0, self._clock() - t0,
                           n=len(sids))
        self.obs.events.emit("mass_join", n=len(sids))

    def _clear_slot(self, slot: int) -> None:
        for i in range(len(self.plan.convs)):
            self._tails[i] = self._tails[i].at[slot].set(0)
            if self._pendings[i].shape[1]:
                self._pendings[i] = self._pendings[i].at[slot].set(0)
        self._gap = self._gap.at[slot].set(0)

    def _host_state(self):
        """One bulk device->host view of the batched state (zero-copy on
        CPU, a gather across shards under a mesh); per-slot rows are then
        plain numpy indexing."""
        return (
            [np.asarray(t) for t in self._tails],
            [np.asarray(p) for p in self._pendings],
            np.asarray(self._gap),
        )

    def _extract_slot(self, s: _Stream, host=None) -> StreamState:
        tails, pendings, gap = host if host is not None else self._host_state()
        w, t = self._stream_params(s)
        st = StreamState(self.plan, w, t)
        st.import_steady(
            [t[s.slot] for t in tails],
            [p[s.slot] for p in pendings],
            gap[s.slot],
            int(self._frames_v[s.slot]),
        )
        st.samples_seen = s.frontend.samples_in - len(s.frontend)
        return st

    def _hop_barriers(self) -> None:
        """Hop-boundary housekeeping: rebalance-on-skew (plus the shrink
        the migration may unpin) and the mass-join primer.  The async
        plane only calls this behind an epoch barrier (no hop in flight),
        so a slot remap can never invalidate in-flight row indices."""
        # leave churn since the last hop may have skewed the shards —
        # the pool migrates-on-idle, then re-checks the shrink the
        # migration may have unpinned
        self._slots.hop_barrier()
        if self._unprimed:
            self._prime_ready()  # numpy warm-up, excluded from step timing

    def _pack_ready(self):
        """Pack stage: consume one hop window from every ready slot.
        Returns ``None`` when no stream is ready, else ``(ready_slots,
        ready_mask, audio, shard_counts, t0, t_pack)``."""
        hop = self.plan.hop_samples
        t0 = self._clock()
        ready_mask = self._primed_mask & self._arena.ready_mask(hop)
        ready_slots = np.nonzero(ready_mask)[0]
        if ready_slots.size == 0:
            return None
        audio = self._arena.pack_hops(ready_slots, hop)
        shard_counts = np.bincount(
            ready_slots // self._placement.shard_capacity,
            minlength=self.n_shards,
        )
        # pack phase ends here; staging (jnp.asarray/device_put) and the
        # jitted call itself are the dispatch phase
        t_pack = self._clock()
        return ready_slots, ready_mask, audio, shard_counts, t0, t_pack

    def _dispatch_hop(self, ready_mask, audio):
        """Dispatch stage: stage operands, launch the jitted hop, and
        reassign the resident state from its (still unforced) result
        futures.  Nothing here blocks — JAX's async dispatch returns
        immediately — and with donated buffers the previous state arrays
        are consumed by the call, so they must not be read afterwards.
        Returns the logits/posterior futures (None with emit off)."""
        args = (
            self._shard(jnp.asarray(audio)),
            self._shard(jnp.asarray(ready_mask)),
            tuple(self._tails), tuple(self._pendings), self._gap,
        )
        if self._pool is not None:
            self._sync_model_rows()
            if self._model_idx_dev is None:
                # steady state reuses one device copy: the rows only
                # move on join/close/resize, not per hop
                self._model_idx_dev = self._shard(
                    jnp.asarray(self._model_idx_v))
            args = args + (self._model_idx_dev,)
        n_entries = self._jit_entries()
        if self.emit_logits:
            tails, pendings, gap, logits, post = self._model.step(
                *args, emit=True
            )
        else:
            tails, pendings, gap = self._model.step(*args, emit=False)
            logits = post = None
        if n_entries is not None and self._jit_entries() != n_entries:
            # this hop traced a new (capacity, emit) shape — the compile
            # spike idle pre-warming exists to hide (the multi-tenant
            # suite pins the post-grow hop clean when prewarm=True)
            self.obs.trace.add("compile", self._clock(), 0.0,
                               capacity=self._capacity)
        self._tails = list(tails)
        self._pendings = list(pendings)
        self._gap = gap
        return logits, post

    def _jit_entries(self) -> int | None:
        """Jit-cache entry count of the batched step (None when the jax
        version exposes no cache introspection)."""
        try:
            return self._model.step._cache_size()
        except AttributeError:  # pragma: no cover - jax-version dependent
            return None

    def _fold_hop(self, ready_slots, shard_counts, logits_h, post_h,
                  t0, t_pack, t_dispatch, t_device,
                  hidden_s: float = 0.0, fold_hidden: bool = False
                  ) -> HopBatch:
        """Fold stage: apply one resolved hop's results to the host-side
        planes — emit cache, frame counters, slot-vectorized detector,
        metrics, lifecycle events, trace spans.  The sync path runs it
        inline right after the fence; the async plane defers it to the
        hop's retirement, strictly in FIFO dispatch order, which keeps
        every per-slot sequence (frames, detector state, events)
        bit-identical to the synchronous schedule."""
        if self.emit_logits:
            self._emit_step += 1
            self._emit_cache = logits_h
            self._emit_cache_step = self._emit_step
        self._frames_v[ready_slots] += self.plan.frames_per_hop
        sids = self._slot_sid[ready_slots]
        frames = self._frames_v[ready_slots]
        rows_logits = rows_post = None
        detections: list[Detection] = []
        if self.emit_logits:
            rows_logits = logits_h[ready_slots]
            rows_post = post_h[ready_slots]
            fired, f_cls, f_score = self._detector.update_batch(
                ready_slots, frames, rows_post
            )
            for r, c, sc in zip(fired.tolist(), f_cls.tolist(),
                                f_score.tolist()):
                det = Detection(int(sids[r]), int(c), int(frames[r]),
                                float(sc))
                self._streams[det.stream_id].events.append(det)
                self.metrics.on_detection(det.stream_id)
                self.obs.events.emit("detection", sid=det.stream_id,
                                     cls=det.cls, frame=det.frame,
                                     score=det.score)
                detections.append(det)
        t_detector = self._clock()
        if fold_hidden:
            # a later hop is still executing while this fold runs, so the
            # detector phase is hidden under device compute
            hidden_s += t_detector - t_device
        n_disp = self._model.dispatches_per_hop(self.emit_logits)
        model_counts = None
        if self._pool is not None:
            mc = np.bincount(self._model_idx_v[ready_slots],
                             minlength=self._pool.max_models)
            model_counts = {
                m: int(mc[row]) for m, row in self._pool.models()
                if mc[row]
            }
        self.metrics.on_step(
            ready_slots.size, self.plan.frames_per_hop,
            t_detector - t0, host_pack_s=t_pack - t0,
            shard_counts=shard_counts.tolist(), finalized=self.emit_logits,
            dispatch_s=t_dispatch - t_pack, device_s=t_device - t_dispatch,
            detector_s=t_detector - t_device, hidden_s=hidden_s,
            dispatches=n_disp, model_counts=model_counts,
        )
        # fold the arena's push-side counters into the metrics at the hop
        # boundary: two scalar reads, so neither the push path nor this
        # hot path ever walks per-sid counter objects
        self.metrics.on_push_fold(self._arena.total_samples_in,
                                  self._arena.total_chunks_in)
        t_end = self._clock()
        # hop trace: on the sync path the stamps are consecutive, so the
        # phase spans tile the hop span exactly (the bench asserts >= 95%
        # coverage).  Under the async plane, hop N+1's pack/dispatch
        # spans legitimately overlap hop N's device span — union-interval
        # coverage (``trace.coverage(mode="overlap")``) accounts for
        # that.  One batched call, six deque appends — B-independent.
        n_ready = int(ready_slots.size)
        self.obs.trace.add_batch((
            ("pack", t0, t_pack - t0, {"n": n_ready}),
            ("dispatch", t_pack, t_dispatch - t_pack, {}),
            ("device", t_dispatch, t_device - t_dispatch,
             {"dispatches": n_disp}),
            ("detector", t_device, t_detector - t_device, {}),
            ("push_fold", t_detector, t_end - t_detector, {}),
            ("hop", t0, t_end - t0, {"n": n_ready}),
        ))
        return HopBatch(sids=sids, frames=frames, logits=rows_logits,
                        posteriors=rows_post, detections=detections)

    def step_batch(self) -> HopBatch | None:
        """Advance every stream that has a full hop buffered; None when no
        stream is ready.

        This is the steady-state hot path and it contains NO python loop
        over slots: readiness is one vectorized compare over the arena,
        hop packing is one gather (``RingArena.pack_hops``), shard counts
        come from ``np.bincount``, bookkeeping updates are fancy-indexed
        vector ops, and detection advances through the slot-vectorized
        ``BatchedDetector``.  Per-slot python survives only off this path
        (priming, teardown, fallback peeks) and for detections that
        actually fire.

        The body is pack -> dispatch -> fence -> fold, each stage a
        method the async plane (``AsyncStreamScheduler``) reuses with the
        fence+fold deferred to the hop's retirement.
        """
        self._hop_barriers()
        packed = self._pack_ready()
        if packed is None:
            self._maybe_prewarm()  # starved step = idle; warm the grow
            return None
        ready_slots, ready_mask, audio, shard_counts, t0, t_pack = packed
        logits, post = self._dispatch_hop(ready_mask, audio)
        # dispatch phase ends when the jitted call has returned its
        # futures; the device phase is the explicit fence + transfers.
        # Without the fence, JAX's async dispatch would let wall time
        # measure *enqueue* rather than execution (egregiously so with
        # emit_logits off, where nothing else forces a sync), and
        # device_ms percentiles would be fiction.
        t_dispatch = self._clock()
        jax.block_until_ready((self._tails, self._pendings, self._gap))
        logits_h = post_h = None
        if self.emit_logits:
            logits_h = np.asarray(logits)  # one bulk transfer per hop
            post_h = np.asarray(post)
        t_device = self._clock()
        return self._fold_hop(ready_slots, shard_counts, logits_h, post_h,
                              t0, t_pack, t_dispatch, t_device)

    # -- idle-time jit pre-warm ----------------------------------------------

    def _maybe_prewarm(self) -> None:
        """Compile the NEXT pow-2 capacity's hop while starved, so the
        first hop after a grow pays no compile spike (``prewarm=True``;
        the trace stays free of ``compile`` events across the resize —
        pinned by tests/test_multitenant.py).  The pool picks the target
        capacity and calls back into ``warm``."""
        self._slots.maybe_prewarm()

    def _warm_capacity(self, cap: int) -> None:
        """Run the jitted step once on zero dummies at ``cap`` slots —
        same shapes/dtypes/shardings as a real hop, so jit's shape-keyed
        cache is hot before the resize ever happens."""
        key = (cap, self.emit_logits)
        if key in self._warmed:
            return
        self._warmed.add(key)
        t0 = self._clock()
        plan = self.plan
        z = lambda shape, dt: self._shard(jnp.zeros(shape, dt))  # noqa: E731
        args = (
            z((cap, plan.hop_samples), jnp.int32),      # pack_hops dtype
            z((cap,), bool),
            tuple(z((cap, st.tail, st.cin), jnp.int32)
                  for st in plan.convs),
            tuple(z((cap, st.phase, st.cout), jnp.int32)
                  for st in plan.convs),
            z((cap, plan.gap_channels), jnp.int32),
        )
        if self._pool is not None:
            args = args + (z((cap,), jnp.int32),)
        out = self._model.step(*args, emit=self.emit_logits)
        jax.block_until_ready(out)
        self.obs.trace.add("prewarm", t0, self._clock() - t0, capacity=cap)
        self.obs.events.emit("prewarm", capacity=cap)

    def step(self) -> list[tuple[int, int, np.ndarray | None, Detection | None]]:
        """Advance every stream that has a full hop buffered.

        Returns one (sid, frame_idx, logits, detection) tuple per advanced
        stream; logits is None when ``emit_logits`` is off.  With
        ``emit_logits`` the logits/posteriors come from the in-jit
        finalization tail — no host-side re-inference per hop.

        This is a compatibility collation of ``step_batch`` — building
        one tuple per stream is inherently O(ready) python, so throughput
        callers (the benchmark's steady loop) should consume the columnar
        ``HopBatch`` directly.
        """
        return self._collate(self.step_batch())

    @staticmethod
    def _collate(batch: HopBatch | None
                 ) -> list[tuple[int, int, np.ndarray | None,
                                 Detection | None]]:
        if batch is None:
            return []
        det_by_sid = {d.stream_id: d for d in batch.detections}
        if batch.logits is None:
            return [
                (int(sid), int(fr), None, None)
                for sid, fr in zip(batch.sids.tolist(), batch.frames.tolist())
            ]
        return [
            (int(sid), int(fr), batch.logits[r].copy(), det_by_sid.get(sid))
            for r, (sid, fr) in enumerate(
                zip(batch.sids.tolist(), batch.frames.tolist())
            )
        ]

    def run_until_starved(self) -> list[tuple[int, int, np.ndarray | None,
                                              Detection | None]]:
        """Step until no stream has a full hop buffered."""
        out = []
        while True:
            r = self.step()
            if not r:
                return out
            out.extend(r)

    def drain(self) -> int:
        """Run ``step_batch`` until starved; returns hops executed.  The
        zero-collation twin of ``run_until_starved`` for callers that read
        results from metrics/peeks instead of per-stream tuples."""
        hops = 0
        while self.step_batch() is not None:
            hops += 1
        return hops

    # -- inspection / teardown ----------------------------------------------

    def peek(self, sid: int) -> np.ndarray:
        """Finalized logits if the stream ended now (inbox included) —
        bit-exact with the offline executor on the audio pushed so far.

        On a hop boundary (empty inbox) this reads the last emit step's
        cached logits — the finalization tail already covered every primed
        slot, so no recompute — or re-runs the in-jit tail when no emit
        covers this slot yet; with leftover sub-hop samples it drops to
        the exact numpy fallback (``StreamState.peek_logits``)."""
        s = self._require(sid)
        if s.primed and len(s.frontend) == 0:
            if (self._emit_cache is not None
                    and s.stamp <= self._emit_cache_step):
                return self._emit_cache[s.slot].copy()
            fargs = (tuple(self._tails), tuple(self._pendings), self._gap)
            if self._pool is not None:
                self._sync_model_rows()
                fargs = fargs + (
                    self._shard(jnp.asarray(self._model_idx_v)),
                )
            logits, _ = self._model.finalize(*fargs)
            return np.asarray(logits[s.slot])
        return self._peek_fallback(s)

    def _peek_fallback(self, s: _Stream) -> np.ndarray:
        if s.primed:
            st = self._extract_slot(s)
        else:
            w, t = self._stream_params(s)
            st = StreamState(self.plan, w, t)
        leftover = s.frontend.peek_all() if len(s.frontend) else None
        return st.peek_logits(leftover)

    def close_stream(self, sid: int) -> StreamResult:
        """Flush (right-pad + drop incomplete pools), free the slot, and
        shrink the pool once occupancy drops to a quarter."""
        s = self._require(sid)
        del self._streams[sid]
        self._unprimed.discard(sid)
        # before the slot is scrubbed
        samples_in = s.frontend.samples_in
        chunks_in = s.frontend.chunks_in
        if s.primed:
            st = self._extract_slot(s)
        else:
            w, t = self._stream_params(s)
            st = StreamState(self.plan, w, t)
        st.advance(s.frontend.pop_all(), flush=True)
        logits = st.logits()
        # one last detector update with the flushed logits (host softmax),
        # through the same slot-vectorized state machine the hops drove
        fired, f_cls, f_score = self._detector.update_batch(
            np.array([s.slot], np.int64), np.array([st.frames], np.int64),
            _softmax(logits)[None, :],
        )
        if fired.size:
            det = Detection(sid, int(f_cls[0]), st.frames, float(f_score[0]))
            s.events.append(det)
            self.metrics.on_detection(sid)
        self._slots.free(s.slot)  # also marks the pool skew-dirty
        if self._pool is not None:
            self._pool.release(s.model)  # unpin; LRU may now evict it
        self._clear_slot(s.slot)  # scrub so the next tenant starts clean
        self._arena.clear_slot(s.slot)
        self._detector.reset_slot(s.slot)
        self._slot_sid[s.slot] = -1
        self._primed_mask[s.slot] = False
        self._frames_v[s.slot] = 0
        self._model_rows_dirty = True  # never zero the slot: its block
        # may still be bound to a tenant; sync rebuilds block-uniformly
        self.metrics.on_close(sid, frames_out=st.frames,
                              samples_in=samples_in, chunks_in=chunks_in)
        self.obs.events.emit("close", sid=sid, frames=st.frames,
                             samples=samples_in, events=len(s.events))
        # a leave can skew the shards; the migration itself waits for the
        # next hop boundary (migrate-on-idle), but the shrink runs now so
        # an emptying pool releases capacity without needing another hop
        self._slots.maybe_shrink()
        return StreamResult(
            stream_id=sid,
            logits=logits,
            frames=st.frames,
            samples=st.samples_seen,
            events=list(s.events),
        )
