"""Per-stream sliding-window state for incremental KWS inference.

The offline executor re-reads the whole feature map per layer.  Streaming
instead keeps, per conv layer, only the *receptive-field tail*: the suffix
of the (padded) input stream that future output positions still need.  The
tail lives in a ``FrameRing`` — a fixed-capacity ring whose read/write
pointers mirror the flexible ping-pong SRAM discipline of
``core/pingpong.py`` (paper §II-F): instead of re-allocating a buffer per
layer invocation, the pointers chase each other through a fixed region and
wrap, and over/under-runs raise ``MemoryError`` exactly like the ping-pong
model's bank checks.

Steady-state geometry (``plan_stream``): once a stream has been primed with
``prime_samples``, every hop of ``hop_samples`` audio makes each layer
consume/emit a *constant* number of frames and keeps each tail at a
*constant* length with a *constant* pool phase.  That is what lets the
scheduler run one jitted batched step with fully static shapes — including
the per-hop *finalization tail* (ghost flush + classifier), whose emission
counts are the ``flush_*`` fields below.  Priming, odd-sized chunks,
end-of-stream flush and mid-hop peeks over leftover (sub-hop) samples run
through the generic numpy path in ``StreamState`` — the bit-exact
reference implementation of the same math, kept as the oracle and the
exact fallback.

Bit-exactness contract with core/executor.py (verified in test_stream.py):
  * layer-0 spatial padding uses the offset code (ref_bitserial_conv1d)
  * binary layers pad with zeros
  * fused max-pool = OR over non-overlapping windows, remainder dropped
  * GAP counts saturate at 255 (8-bit PWB counters)
  * fc layers run on the saturated counts; final layer emits raw logits
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cnn_spec import CNN1DSpec, Conv1DSpec, FCSpec, GAPSpec


# ---------------------------------------------------------------------------
# Ring buffer
# ---------------------------------------------------------------------------

class FrameRing:
    """Fixed-capacity FIFO of (channels,) frames with wrapping pointers.

    ``wr``/``rd`` are monotonic frame counters; the physical slot is the
    counter mod capacity, so the region is reused forever without copies —
    the software twin of the ping-pong SRAM's per-layer pointer latching
    (PTR instructions move pointers, never data).
    """

    def __init__(self, capacity: int, channels: int, dtype=np.int32) -> None:
        assert capacity > 0 and channels > 0
        self.capacity = capacity
        self.channels = channels
        self.data = np.zeros((capacity, channels), dtype=dtype)
        self.rd = 0  # next frame to read (monotonic)
        self.wr = 0  # next frame to write (monotonic)

    def __len__(self) -> int:
        return self.wr - self.rd

    @property
    def free(self) -> int:
        return self.capacity - len(self)

    def push(self, frames: np.ndarray) -> None:
        frames = np.atleast_2d(frames)
        n = frames.shape[0]
        if n == 0:
            return
        assert frames.shape[1] == self.channels, (frames.shape, self.channels)
        if n > self.free:
            raise MemoryError(
                f"ring overflow: push {n} into {self.free} free of "
                f"{self.capacity} frames"
            )
        idx = (self.wr + np.arange(n)) % self.capacity
        self.data[idx] = frames
        self.wr += n

    def pop(self, n: int) -> np.ndarray:
        out = self.peek(n)
        self.rd += n
        return out

    def peek(self, n: int | None = None) -> np.ndarray:
        """Oldest ``n`` frames (default: all) in time order, without consuming."""
        n = len(self) if n is None else n
        if n > len(self):
            raise MemoryError(f"ring underflow: peek {n} of {len(self)}")
        idx = (self.rd + np.arange(n)) % self.capacity
        return self.data[idx].copy()

    def drop(self, n: int) -> None:
        if n > len(self):
            raise MemoryError(f"ring underflow: drop {n} of {len(self)}")
        self.rd += n

    def clone(self) -> "FrameRing":
        r = FrameRing(self.capacity, self.channels, self.data.dtype)
        r.data = self.data.copy()
        r.rd, r.wr = self.rd, self.wr
        return r

    def load(self, frames: np.ndarray) -> None:
        """Reset contents to exactly ``frames`` (keeps pointer positions
        rolling forward — the region is reused, not reallocated)."""
        frames = np.atleast_2d(frames)
        self.rd = self.wr
        self.push(frames)


# ---------------------------------------------------------------------------
# Slot placement: one logical pool sharded over a device mesh
# ---------------------------------------------------------------------------

class SlotPlacement:
    """Slot -> shard mapping for the mesh-wide slot pool.

    The pool's batch axis is one global array of ``n_shards *
    shard_capacity`` rows; under a mesh sharding over the ``"data"`` axis,
    shard ``s`` owns the contiguous row block ``[s * shard_capacity, (s +
    1) * shard_capacity)``.  All placement decisions respect that block
    structure so *no resize or allocation ever moves a row across
    devices*:

      * ``alloc`` places a joining stream on the least-loaded shard
        (lowest shard wins ties) at its lowest free local slot — with one
        shard this degenerates to "lowest free slot", the pre-mesh
        behavior;
      * ``grow``/``shrink`` change the *per-shard* capacity: a grow
        appends rows at the end of every shard block, a shrink compacts
        each shard's tenants into its own surviving local slots and drops
        the block tails.  Cross-shard motion is structurally impossible,
        which is why an elastic resize under sharding costs zero
        collective communication.

    The placement is pure bookkeeping (plain python ints); the scheduler
    applies the returned remaps/moves to the batched device arrays.
    """

    def __init__(self, n_shards: int, shard_capacity: int) -> None:
        assert n_shards >= 1 and shard_capacity >= 1
        self.n_shards = n_shards
        self.shard_capacity = shard_capacity
        self.slots: list[int | None] = [None] * (n_shards * shard_capacity)

    @property
    def capacity(self) -> int:
        return self.n_shards * self.shard_capacity

    def shard_of(self, slot: int) -> int:
        return slot // self.shard_capacity

    def occupancy(self) -> list[int]:
        """Tenant count per shard."""
        occ = [0] * self.n_shards
        for slot, sid in enumerate(self.slots):
            if sid is not None:
                occ[self.shard_of(slot)] += 1
        return occ

    def alloc(self, sid: int) -> int | None:
        """Place ``sid`` on the least-loaded shard; None when pool full."""
        occ = self.occupancy()
        c = self.shard_capacity
        for sh in sorted(range(self.n_shards), key=lambda s: (occ[s], s)):
            if occ[sh] == c:
                continue
            base = sh * c
            for loc in range(c):
                if self.slots[base + loc] is None:
                    self.slots[base + loc] = sid
                    return base + loc
        return None

    def free(self, slot: int) -> None:
        assert self.slots[slot] is not None
        self.slots[slot] = None

    def grow(self, new_shard_capacity: int) -> dict[int, int]:
        """Grow every shard block; returns {old_slot: new_slot} remap."""
        old_c, c = self.shard_capacity, new_shard_capacity
        assert c > old_c
        remap: dict[int, int] = {}
        slots: list[int | None] = [None] * (self.n_shards * c)
        for slot, sid in enumerate(self.slots):
            new_slot = self.shard_of(slot) * c + slot % old_c
            slots[new_slot] = sid
            remap[slot] = new_slot
        self.slots, self.shard_capacity = slots, c
        return remap

    def shrink(
        self, new_shard_capacity: int
    ) -> tuple[list[tuple[int, int]], dict[int, int]]:
        """Shrink every shard block to ``new_shard_capacity`` local slots.

        Returns ``(moves, remap)``: ``moves`` are (dst, src) row copies in
        the OLD global indexing — each within one shard block — that
        compact tenants out of the doomed upper local slots; ``remap`` is
        {old_slot: new_slot} for every surviving tenant after the slice.
        """
        old_c, c = self.shard_capacity, new_shard_capacity
        assert c < old_c
        moves: list[tuple[int, int]] = []
        moved: dict[int, int] = {}  # original old slot -> post-move old slot
        for sh in range(self.n_shards):
            base = sh * old_c
            if sum(s is not None for s in
                   self.slots[base : base + old_c]) > c:
                raise ValueError(
                    f"shard {sh} holds more than {c} tenants; cross-shard "
                    "relocation is not allowed"
                )
            free_low = [
                base + loc for loc in range(c)
                if self.slots[base + loc] is None
            ]
            for loc in range(c, old_c):
                sid = self.slots[base + loc]
                if sid is None:
                    continue
                dst = free_low.pop(0)
                moves.append((dst, base + loc))
                moved[base + loc] = dst
                self.slots[dst] = sid
                self.slots[base + loc] = None
        # remap keys are the tenants' ORIGINAL old-capacity slots
        remap: dict[int, int] = {}
        slots: list[int | None] = [None] * (self.n_shards * c)
        survivor_new = {}  # post-move old slot -> new slot
        for sh in range(self.n_shards):
            for loc in range(c):
                sid = self.slots[sh * old_c + loc]
                slots[sh * c + loc] = sid
                if sid is not None:
                    survivor_new[sh * old_c + loc] = sh * c + loc
        for old_slot, new_slot in survivor_new.items():
            remap[old_slot] = new_slot
        for orig, interim in moved.items():
            remap[orig] = survivor_new[interim]
        self.slots, self.shard_capacity = slots, c
        return moves, remap


# ---------------------------------------------------------------------------
# Stream plan: static per-hop geometry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvStage:
    """One conv layer's static streaming geometry.

    The ``flush_*`` fields describe the *finalization tail*: the extra work
    an end-of-stream flush performs from the steady state (append the right
    pad, convolve what fits, pool with drop-remainder).  Because the steady
    tail/phase lengths are constants of the plan, so are these counts —
    which is what lets the scheduler compute "logits as if the stream ended
    now" *inside* the jitted batched step instead of on the host.
    """

    layer_idx: int
    name: str
    k: int
    stride: int
    pad: int
    pool: int
    cin: int
    cout: int
    in_bits: int
    in_offset: int
    tail: int      # steady-state receptive-field tail length (frames)
    phase: int     # steady-state pool phase (frames pending in the window)
    n_in: int      # frames consumed per hop
    n_conv: int    # conv positions emitted per hop
    n_out: int     # pooled frames emitted per hop
    flush_in: int    # extra frames received from the layer above at flush
    flush_conv: int  # extra conv positions a flush emits (tail + right pad)
    flush_out: int   # extra pooled frames a flush emits (remainder dropped)


@dataclasses.dataclass(frozen=True)
class FCStage:
    layer_idx: int
    name: str
    cin: int
    cout: int
    in_bits: int
    out_raw: bool


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Static schedule for one model: hop/prime sizes + per-layer geometry."""

    spec: CNN1DSpec
    hop_samples: int
    prime_samples: int
    convs: tuple[ConvStage, ...]
    fcs: tuple[FCStage, ...]
    gap_channels: int

    @property
    def frames_per_hop(self) -> int:
        return self.convs[-1].n_out

    @property
    def samples_per_frame(self) -> int:
        return self.hop_samples // self.frames_per_hop

    def macs_per_hop(self) -> int:
        """Logical MACs of one steady-state hop (conv cascade only)."""
        return sum(c.n_conv * c.k * c.cin * c.cout for c in self.convs)

    def fc_macs(self) -> int:
        return sum(f.cin * f.cout for f in self.fcs)


def _conv_layers(spec: CNN1DSpec) -> tuple[list[tuple[int, Conv1DSpec]],
                                           int, list[tuple[int, FCSpec]]]:
    """Split the spec into conv prefix / GAP / fc suffix (the streamable
    topology); anything else is rejected."""
    convs: list[tuple[int, Conv1DSpec]] = []
    fcs: list[tuple[int, FCSpec]] = []
    gap_at = None
    for li, lspec in enumerate(spec.layers):
        if isinstance(lspec, Conv1DSpec):
            if gap_at is not None:
                raise ValueError("conv after GAP is not streamable")
            if lspec.out_raw:
                raise ValueError(f"{lspec.name}: raw-output conv mid-stream")
            convs.append((li, lspec))
        elif isinstance(lspec, GAPSpec):
            if gap_at is not None:
                raise ValueError("multiple GAP layers")
            gap_at = li
        elif isinstance(lspec, FCSpec):
            if gap_at is None:
                raise ValueError("FC before GAP is not streamable")
            fcs.append((li, lspec))
        else:
            raise ValueError(f"layer {li} ({type(lspec).__name__}) not streamable")
    if not convs or gap_at is None or not fcs:
        raise ValueError("streamable spec needs convs -> GAP -> FCs")
    return convs, gap_at, fcs


def _simulate_counts(convs: list[tuple[int, Conv1DSpec]], pushes: list[int]
                     ) -> tuple[list[int], list[int], list[list[int]]]:
    """Feed ``pushes`` chunks through the count-level model.

    Returns (tail lengths, pool phases, per-push emissions per layer) after
    all pushes; tails include the layer's left pad on the first push.
    """
    fed = [0] * len(convs)       # frames of the *padded* stream received
    emitted = [0] * len(convs)   # conv positions emitted so far
    pooled = [0] * len(convs)    # pooled frames emitted so far
    per_push: list[list[int]] = []
    for push in pushes:
        cur = push
        outs = []
        for i, (_, L) in enumerate(convs):
            if fed[i] == 0 and cur > 0:
                fed[i] += L.pad  # left pad arrives with the first real frame
            fed[i] += cur
            total = max(0, (fed[i] - L.k) // L.stride + 1) if fed[i] >= L.k else 0
            new_conv = total - emitted[i]
            emitted[i] = total
            new_pool = (emitted[i] // L.pool) - pooled[i]
            pooled[i] += new_pool
            cur = new_pool
            outs.append(new_conv)
        per_push.append(outs)
    tails = [
        fed[i] - emitted[i] * L.stride for i, (_, L) in enumerate(convs)
    ]
    phases = [emitted[i] % L.pool for i, (_, L) in enumerate(convs)]
    return tails, phases, per_push


def plan_stream(
    spec: CNN1DSpec,
    hop_frames: int = 1,
    prime_samples: int | None = None,
) -> StreamPlan:
    """Derive the static streaming schedule for ``spec``.

    ``hop_frames``: final-layer frames per scheduler step; the hop size in
    samples is ``hop_frames * prod(stride*pool)``.  ``prime_samples`` is the
    warm-up prefix a stream must deliver before it enters the steady-state
    batched step; the default is the smallest stride-aligned prefix that
    fills every layer's tail.
    """
    convs, _, fcs = _conv_layers(spec)
    unit = 1
    for _, L in convs:
        unit *= L.stride * L.pool
    hop = hop_frames * unit

    s0 = convs[0][1].stride
    if prime_samples is None:
        # smallest stride-aligned prefix after which every layer has seen a
        # full receptive field (fed >= k), i.e. every tail is at steady size
        prime_samples = 0
        for p in range(s0, 64 * unit + 1, s0):
            f, ok = p, True
            for _, L in convs:
                f_padded = L.pad + f
                if f_padded < L.k:
                    ok = False
                    break
                f = ((f_padded - L.k) // L.stride + 1) // L.pool
            if ok:
                prime_samples = p
                break
        if prime_samples == 0:
            raise ValueError("could not find a priming prefix")

    # verify steady state: two extra hops give identical emissions + tails
    tails, phases, per = _simulate_counts(convs, [prime_samples, hop, hop])
    tails2, phases2, per2 = _simulate_counts(
        convs, [prime_samples, hop, hop, hop]
    )
    if per[1] != per[2] or per2[2] != per2[3] or tails != tails2 or phases != phases2:
        raise ValueError(
            f"hop {hop} / prime {prime_samples} does not reach steady state"
        )

    # finalization-tail geometry: what an end-of-stream flush emits from the
    # steady state (mirrors StreamState._advance_once with flush=True)
    flush_geom = []
    f_in = 0
    for i, (_, L) in enumerate(convs):
        avail = tails[i] + f_in + L.pad  # tail ++ upstream flush ++ right pad
        f_conv = (avail - L.k) // L.stride + 1 if avail >= L.k else 0
        f_out = (phases[i] + f_conv) // L.pool
        flush_geom.append((f_in, f_conv, f_out))
        f_in = f_out

    stages = []
    n_in = hop
    for i, (li, L) in enumerate(convs):
        n_conv = per[1][i]
        if n_conv % L.pool:
            raise ValueError(
                f"{L.name}: {n_conv} conv frames/hop not divisible by pool "
                f"{L.pool}; raise hop_frames"
            )
        stages.append(
            ConvStage(
                layer_idx=li, name=L.name, k=L.k, stride=L.stride, pad=L.pad,
                pool=L.pool, cin=L.cin, cout=L.cout, in_bits=L.in_bits,
                in_offset=L.in_offset, tail=tails[i], phase=phases[i],
                n_in=n_in, n_conv=n_conv, n_out=n_conv // L.pool,
                flush_in=flush_geom[i][0], flush_conv=flush_geom[i][1],
                flush_out=flush_geom[i][2],
            )
        )
        assert n_conv * L.stride == n_in, (L.name, n_conv, n_in)
        n_in = n_conv // L.pool

    fc_stages = tuple(
        FCStage(li, F.name, F.cin, F.cout, F.in_bits, F.out_raw)
        for li, F in fcs
    )
    return StreamPlan(
        spec=spec,
        hop_samples=hop,
        prime_samples=prime_samples,
        convs=tuple(stages),
        fcs=fc_stages,
        gap_channels=convs[-1][1].cout,
    )


# ---------------------------------------------------------------------------
# Reference per-stream state (numpy; priming / flush / peek path)
# ---------------------------------------------------------------------------

def _threshold(raw: np.ndarray, thr: np.ndarray, flip: np.ndarray) -> np.ndarray:
    """Executor-exact SA binarization (float64 compare, flip channels)."""
    ge = raw >= thr[None, :]
    return np.where(flip[None, :], ~ge, ge).astype(np.uint8)


def _conv_raw(window: np.ndarray, w: np.ndarray, stage: ConvStage,
              n_conv: int) -> np.ndarray:
    """n_conv positions of the layer over ``window`` (tail ++ new frames)."""
    x = window.astype(np.int64)
    if stage.in_bits > 1:
        x = x - stage.in_offset  # offset-binary input (pads carry the code)
    taps = np.stack(
        [
            x[t : t + (n_conv - 1) * stage.stride + 1 : stage.stride]
            for t in range(stage.k)
        ],
        axis=0,
    )  # (K, n_conv, Cin)
    return np.einsum("knc,kco->no", taps, w.astype(np.int64))


class StreamState:
    """One stream's incremental inference state (bit-exact numpy path).

    Handles arbitrary chunk sizes: warm-up, steady hops, end-of-stream flush
    with right padding, and non-destructive mid-stream peeks.  The jitted
    batched scheduler path is the steady-state specialization of exactly
    this code.
    """

    def __init__(
        self,
        plan: StreamPlan,
        weights: dict[int, np.ndarray],
        thresholds: dict[int, tuple[np.ndarray, np.ndarray]],
        ring_slack: int | None = None,
    ) -> None:
        self.plan = plan
        self.weights = weights
        self.thresholds = thresholds
        slack = ring_slack if ring_slack is not None else max(
            plan.prime_samples, 2 * plan.hop_samples
        )
        self._max_chunk = slack  # advance() splits larger inputs
        self.hists: list[FrameRing] = []
        self.pendings: list[FrameRing] = []
        for st in plan.convs:
            cap = st.tail + 2 * st.pad + st.k + max(slack, st.n_in) + 1
            self.hists.append(FrameRing(cap, st.cin, np.int32))
            self.pendings.append(
                FrameRing(st.pool + st.k + st.pad + max(slack, st.n_conv) + 1,
                          st.cout, np.int32)
            )
            slack = max(1, -(-slack // max(1, st.stride)))
        self.started = [False] * len(plan.convs)
        self.gap = np.zeros(plan.gap_channels, np.int64)
        self.frames = 0          # final-conv pooled frames accumulated in GAP
        self.samples_seen = 0
        self.flushed = False

    # -- core advance --------------------------------------------------------

    def advance(self, samples: np.ndarray, flush: bool = False) -> np.ndarray:
        """Feed u8 samples (n,) or (n, Cin0); returns newly emitted
        final-conv frames (m, C).  ``flush`` appends each layer's right pad
        and drops incomplete pool windows (end-of-stream semantics)."""
        samples = np.asarray(samples)
        cur = samples.reshape(-1, self.plan.convs[0].cin)
        if cur.shape[0] > self._max_chunk:
            # split oversized inputs so the fixed-capacity rings never
            # overflow (the pointers just wrap more often)
            outs = []
            for i in range(0, cur.shape[0], self._max_chunk):
                seg = cur[i : i + self._max_chunk]
                last = i + self._max_chunk >= cur.shape[0]
                outs.append(self._advance_once(seg, flush=flush and last))
            return np.concatenate(outs, axis=0)
        return self._advance_once(cur, flush=flush)

    def _advance_once(self, samples: np.ndarray, flush: bool) -> np.ndarray:
        assert not self.flushed, "stream already flushed"
        cur = samples.reshape(-1, self.plan.convs[0].cin).astype(np.int32)
        self.samples_seen += cur.shape[0]
        for i, st in enumerate(self.plan.convs):
            hist = self.hists[i]
            w = self.weights[st.layer_idx]
            wk = w.reshape(st.k, st.cin, st.cout)
            if not self.started[i] and (cur.shape[0] > 0 or flush):
                # left pad arrives with the first real frame (offset code
                # for the multi-bit first layer, zeros for binary layers)
                pad_val = st.in_offset if st.in_bits > 1 else 0
                hist.push(np.full((st.pad, st.cin), pad_val, np.int32))
                self.started[i] = True
            hist.push(cur)
            if flush:
                pad_val = st.in_offset if st.in_bits > 1 else 0
                hist.push(np.full((st.pad, st.cin), pad_val, np.int32))
            avail = len(hist)
            n_conv = (avail - st.k) // st.stride + 1 if avail >= st.k else 0
            if n_conv > 0:
                window = hist.peek(avail)
                raw = _conv_raw(window, wk, st, n_conv)
                thr, flip = self.thresholds[st.layer_idx]
                y = _threshold(raw, thr, flip)
                hist.drop(n_conv * st.stride)
            else:
                y = np.zeros((0, st.cout), np.uint8)
            # pool: OR over non-overlapping windows, absolute alignment
            pend = self.pendings[i]
            pend.push(y.astype(np.int32))
            n_pool = len(pend) // st.pool
            if n_pool > 0:
                frames = pend.pop(n_pool * st.pool)
                cur = frames.reshape(n_pool, st.pool, st.cout).max(axis=1)
            else:
                cur = np.zeros((0, st.cout), np.int32)
            if flush:
                pend.drop(len(pend))  # drop-remainder (ref_maxpool1d)
        self.gap += cur.astype(np.int64).sum(axis=0)
        self.frames += cur.shape[0]
        if flush:
            self.flushed = True
        return cur

    # -- logits --------------------------------------------------------------

    def logits(self) -> np.ndarray:
        """fc cascade over the (saturated) GAP counts — executor-exact."""
        h = np.minimum(self.gap, 255).astype(np.int64)[None, :]  # 8-bit PWB
        for st in self.plan.fcs:
            w = self.weights[st.layer_idx].astype(np.int64)
            raw = h @ w
            if st.out_raw:
                h = raw
            else:
                thr, flip = self.thresholds[st.layer_idx]
                h = _threshold(raw, thr, flip).astype(np.int64)
        return h[0]

    def peek_logits(self, extra_samples: np.ndarray | None = None) -> np.ndarray:
        """Logits as if the stream ended now (plus ``extra_samples``),
        without disturbing the live state — the per-frame logits contract:
        peek after feeding audio[:L] == offline executor on audio[:L].

        This is the *exact fallback* path: the scheduler computes per-hop
        finalized logits inside the jitted batched step (the fused
        finalization tail) and only drops to this clone-and-flush numpy
        path for mid-hop peeks that must include leftover sub-hop samples,
        or for streams that are not yet primed."""
        ghost = self.clone()
        if extra_samples is None:
            extra_samples = np.zeros((0,), np.int32)
        ghost.advance(extra_samples, flush=True)
        return ghost.logits()

    def clone(self) -> "StreamState":
        c = StreamState.__new__(StreamState)
        c.plan, c.weights, c.thresholds = self.plan, self.weights, self.thresholds
        c._max_chunk = self._max_chunk
        c.hists = [h.clone() for h in self.hists]
        c.pendings = [p.clone() for p in self.pendings]
        c.started = list(self.started)
        c.gap = self.gap.copy()
        c.frames = self.frames
        c.samples_seen = self.samples_seen
        c.flushed = self.flushed
        return c

    # -- steady-state interchange with the batched scheduler -----------------

    def export_steady(self) -> dict[str, list[np.ndarray] | np.ndarray]:
        """Tail/pending/gap arrays at the plan's steady-state shapes."""
        tails, pends = [], []
        for i, st in enumerate(self.plan.convs):
            h = self.hists[i]
            if len(h) != st.tail:
                raise ValueError(
                    f"{st.name}: tail {len(h)} != steady {st.tail} "
                    "(stream not primed?)"
                )
            tails.append(h.peek(st.tail))
            p = self.pendings[i]
            if len(p) != st.phase:
                raise ValueError(
                    f"{st.name}: pool phase {len(p)} != steady {st.phase}"
                )
            pends.append(p.peek(st.phase))  # exactly (phase, cout)
        return {"tails": tails, "pendings": pends, "gap": self.gap.copy()}

    def import_steady(self, tails, pendings, gap, frames: int) -> None:
        for i, st in enumerate(self.plan.convs):
            self.hists[i].load(np.asarray(tails[i], np.int32))
            self.pendings[i].load(
                np.asarray(pendings[i][: st.phase], np.int32)
            )
            self.started[i] = True
        self.gap = np.asarray(gap, np.int64).copy()
        self.frames = frames
