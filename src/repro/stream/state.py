"""Per-stream sliding-window state for incremental KWS inference.

The offline executor re-reads the whole feature map per layer.  Streaming
instead keeps, per conv layer, only the *receptive-field tail*: the suffix
of the (padded) input stream that future output positions still need.  The
tail lives in a ``FrameRing`` — a fixed-capacity ring whose read/write
pointers mirror the flexible ping-pong SRAM discipline of
``core/pingpong.py`` (paper §II-F): instead of re-allocating a buffer per
layer invocation, the pointers chase each other through a fixed region and
wrap, and over/under-runs raise ``MemoryError`` exactly like the ping-pong
model's bank checks.

Steady-state geometry (``plan_stream``): once a stream has been primed with
``prime_samples``, every hop of ``hop_samples`` audio makes each layer
consume/emit a *constant* number of frames and keeps each tail at a
*constant* length with a *constant* pool phase.  That is what lets the
scheduler run one jitted batched step with fully static shapes — including
the per-hop *finalization tail* (ghost flush + classifier), whose emission
counts are the ``flush_*`` fields below.  Priming, odd-sized chunks,
end-of-stream flush and mid-hop peeks over leftover (sub-hop) samples run
through the generic numpy path in ``StreamState`` — the bit-exact
reference implementation of the same math, kept as the oracle and the
exact fallback.

Bit-exactness contract with core/executor.py (verified in test_stream.py):
  * layer-0 spatial padding uses the offset code (ref_bitserial_conv1d)
  * binary layers pad with zeros
  * fused max-pool = OR over non-overlapping windows, remainder dropped
  * GAP counts saturate at 255 (8-bit PWB counters)
  * fc layers run on the saturated counts; final layer emits raw logits
"""
from __future__ import annotations

import contextlib
import dataclasses

import numpy as np

from repro.core.cnn_spec import CNN1DSpec, Conv1DSpec, FCSpec, GAPSpec
# SlotPlacement and the host remap contract moved to the generic runtime
# package (repro.runtime) when the slot-pool plane was extracted; they are
# re-exported here because the streaming API grew up around this module
# (tests, benches, and examples import them from repro.stream.state).
from repro.runtime.placement import SlotPlacement  # noqa: F401
from repro.runtime.remap import remap_rows  # noqa: F401


# ---------------------------------------------------------------------------
# Ring buffer
# ---------------------------------------------------------------------------

class FrameRing:
    """Fixed-capacity FIFO of (channels,) frames with wrapping pointers.

    ``wr``/``rd`` are monotonic frame counters; the physical slot is the
    counter mod capacity, so the region is reused forever without copies —
    the software twin of the ping-pong SRAM's per-layer pointer latching
    (PTR instructions move pointers, never data).
    """

    def __init__(self, capacity: int, channels: int, dtype=np.int32) -> None:
        assert capacity > 0 and channels > 0
        self.capacity = capacity
        self.channels = channels
        self.data = np.zeros((capacity, channels), dtype=dtype)
        self.rd = 0  # next frame to read (monotonic)
        self.wr = 0  # next frame to write (monotonic)

    def __len__(self) -> int:
        return self.wr - self.rd

    @property
    def free(self) -> int:
        return self.capacity - len(self)

    def push(self, frames: np.ndarray) -> None:
        frames = np.atleast_2d(frames)
        n = frames.shape[0]
        if n == 0:
            return
        assert frames.shape[1] == self.channels, (frames.shape, self.channels)
        if n > self.free:
            raise MemoryError(
                f"ring overflow: push {n} into {self.free} free of "
                f"{self.capacity} frames"
            )
        idx = (self.wr + np.arange(n)) % self.capacity
        self.data[idx] = frames
        self.wr += n

    def pop(self, n: int) -> np.ndarray:
        out = self.peek(n)
        self.rd += n
        return out

    def peek(self, n: int | None = None) -> np.ndarray:
        """Oldest ``n`` frames (default: all) in time order, without consuming."""
        n = len(self) if n is None else n
        if n > len(self):
            raise MemoryError(f"ring underflow: peek {n} of {len(self)}")
        idx = (self.rd + np.arange(n)) % self.capacity
        return self.data[idx].copy()

    def drop(self, n: int) -> None:
        if n > len(self):
            raise MemoryError(f"ring underflow: drop {n} of {len(self)}")
        self.rd += n

    def clone(self) -> "FrameRing":
        r = FrameRing(self.capacity, self.channels, self.data.dtype)
        r.data = self.data.copy()
        r.rd, r.wr = self.rd, self.wr
        return r

    def load(self, frames: np.ndarray) -> None:
        """Reset contents to exactly ``frames`` (keeps pointer positions
        rolling forward — the region is reused, not reallocated)."""
        frames = np.atleast_2d(frames)
        self.rd = self.wr
        self.push(frames)


# ---------------------------------------------------------------------------
# Ring arena: one shared sample inbox for every stream slot
# ---------------------------------------------------------------------------

IN_OFFSET = 128  # offset-binary zero code (models/kws.py)


def quantize_pcm(x: np.ndarray, gain=1.0) -> np.ndarray:
    """float PCM in [-1, 1] -> u8 offset-binary codes.

    ``gain`` may be a scalar or a per-sample vector (the arena repeats each
    stream's fixed gain across its samples so many streams quantize in one
    call); streaming cannot use the offline corpus's per-clip peak
    normalization because the clip never ends.
    """
    q = np.round(np.clip(x * gain, -1.0, 1.0) * 127.0) + IN_OFFSET
    return np.clip(q, 0, 255).astype(np.uint8)


class RingArena:
    """Struct-of-arrays sample inbox shared by EVERY stream slot.

    The pre-arena runtime gave each stream its own ``AudioFrontend`` ring
    object, so packing a hop at B streams cost B python ring pops — the
    serial floor of the whole runtime at B=1024.  The arena instead holds
    ONE ``(capacity_slots, capacity_samples)`` uint8 buffer plus per-slot
    monotonic read/write counters, the array-of-objects ->
    struct-of-arrays turn of the paper's §II-D ping-pong feature SRAM
    argument: one shared, layout-flexible buffer beats per-tenant buffers.
    Every hot-path operation is one vectorized call:

      * ``push_batch``   quantize + scatter chunks for many streams at once
      * ``ready_mask``   which slots hold >= n samples (one compare)
      * ``pack_hops``    gather every ready slot's hop window into the
                         batched ``(capacity_slots, hop)`` int32 step input
                         and consume it — pure fancy indexing

    Samples are stored as uint8 codes (4x smaller than the old per-stream
    ``(n, 1)`` int32 rings) and widened to int32 only at pack time.  Rows
    follow ``SlotPlacement`` through elastic resizes via ``apply_remap``,
    so a slot's inbox never crosses shard blocks.  Like ``FrameRing``,
    over/under-runs raise ``MemoryError``; unlike it, a malformed push is
    rejected at the boundary (wrong dtype, out-of-range codes) instead of
    being silently widened.
    """

    def __init__(self, capacity_slots: int, capacity_samples: int) -> None:
        assert capacity_slots > 0 and capacity_samples > 0
        self.capacity_samples = capacity_samples
        self.data = np.zeros((capacity_slots, capacity_samples), np.uint8)
        self.rd = np.zeros(capacity_slots, np.int64)  # monotonic, per slot
        self.wr = np.zeros(capacity_slots, np.int64)  # monotonic, per slot
        self.samples_in = np.zeros(capacity_slots, np.int64)
        self.chunks_in = np.zeros(capacity_slots, np.int64)
        self.gain = np.ones(capacity_slots, np.float64)
        # fleet totals: monotone even across slot clears, so the metrics
        # fold at hop boundaries is two scalar reads, never a per-slot walk
        self.total_samples_in = 0
        self.total_chunks_in = 0
        # seqlock word for the async ingest pump: odd while a mutation is
        # in progress, bumped to the next even value when it completes.
        # Mutators run under the scheduler's ingest lock; the generation
        # lets lock-FREE observers (`read_consistent`) detect and retry a
        # read that raced a writer instead of returning torn state.
        self.generation = 0
        self.read_retries = 0  # consistency retries observed (stats only)

    @contextlib.contextmanager
    def _write(self):
        """Mark a mutation window: generation is odd for its duration.
        Validation must happen BEFORE entering, so a rejected operation
        leaves the generation untouched (still even)."""
        self.generation += 1
        try:
            yield
        finally:
            self.generation += 1

    def read_consistent(self, fn, max_retries: int = 100_000):
        """Seqlock read: evaluate ``fn()`` at a moment no writer is
        mid-mutation and re-check afterwards, retrying on a torn window.
        ``fn`` must be a pure read of arena state (it may run more than
        once).  Returns ``fn()``'s value from the first clean window."""
        for _ in range(max_retries):
            g0 = self.generation
            if g0 & 1:  # writer mid-flight: spin
                self.read_retries += 1
                continue
            out = fn()
            if self.generation == g0:
                return out
            self.read_retries += 1
        raise RuntimeError(
            "read_consistent starved: a writer never left the arena"
        )

    @property
    def capacity_slots(self) -> int:
        return self.data.shape[0]

    def fill(self) -> np.ndarray:
        """Live sample count per slot, (capacity_slots,) int64."""
        return self.wr - self.rd

    def fill_of(self, slot: int) -> int:
        return int(self.wr[slot] - self.rd[slot])

    def ready_mask(self, n: int) -> np.ndarray:
        """Which slots hold at least ``n`` samples — the scheduler's
        readiness test, one vectorized compare over the whole pool."""
        return (self.wr - self.rd) >= n

    def set_gain(self, slot: int, gain: float) -> None:
        self.gain[slot] = gain

    # -- ingest (quantize + scatter) -----------------------------------------

    def push(self, slot: int, audio: np.ndarray) -> None:
        """Append one stream's chunk (float PCM or u8 codes)."""
        self.push_batch(np.array([slot], np.int64), [audio])

    def push_batch(self, slots: np.ndarray, chunks: list[np.ndarray]) -> None:
        """Append one chunk per slot for many streams in one call.

        Float chunks are quantized in a single vectorized pass (each
        stream's fixed gain repeated across its samples), integer chunks
        are range-checked in a single pass, and everything lands in the
        arena with ONE flat scatter — no python loop over samples.  Slots
        must be unique within a call (chunk order per slot would otherwise
        be ambiguous).
        """
        slots = np.asarray(slots, np.int64)
        assert slots.size == len(chunks), (slots.size, len(chunks))
        if slots.size == 0:
            return
        if np.unique(slots).size != slots.size:
            raise ValueError("push_batch slots must be unique per call")
        chunks = [np.asarray(c).reshape(-1) for c in chunks]
        lens = np.array([c.size for c in chunks], np.int64)
        free = self.capacity_samples - (self.wr[slots] - self.rd[slots])
        if (lens > free).any():
            worst = int(np.argmax(lens - free))
            raise MemoryError(
                f"arena overflow: push {lens[worst]} into {free[worst]} "
                f"free of {self.capacity_samples} samples (slot "
                f"{slots[worst]})"
            )
        is_f = np.array([c.dtype.kind == "f" for c in chunks], bool)
        total = int(lens.sum())
        flat = np.empty(total, np.uint8)
        sample_is_f = np.repeat(is_f, lens)
        if is_f.any():
            pcm = np.concatenate([c for c, f in zip(chunks, is_f) if f])
            g = np.repeat(self.gain[slots[is_f]], lens[is_f])
            flat[sample_is_f] = quantize_pcm(pcm, g)
        if not is_f.all():
            ints = [c for c, f in zip(chunks, is_f) if not f]
            for c in ints:
                if c.dtype.kind not in "iu":
                    raise TypeError(
                        f"audio must be float PCM or integer u8 codes, "
                        f"got dtype {c.dtype}"
                    )
            codes = np.concatenate(ints)
            if codes.dtype != np.uint8 and codes.size and (
                codes.min() < 0 or codes.max() > 255
            ):
                raise ValueError(
                    f"integer sample codes out of u8 range [0, 255]: "
                    f"min {codes.min()}, max {codes.max()}"
                )
            flat[~sample_is_f] = codes.astype(np.uint8, copy=False)
        # flat scatter: (slot row, wrapped column) per sample
        starts = np.cumsum(lens) - lens
        rows = np.repeat(slots, lens)
        offs = np.arange(total) - np.repeat(starts, lens)
        cols = (np.repeat(self.wr[slots], lens) + offs) % self.capacity_samples
        with self._write():
            self.data[rows, cols] = flat
            self.wr[slots] += lens
            self.samples_in[slots] += lens
            self.chunks_in[slots] += 1
            self.total_samples_in += total
            self.total_chunks_in += slots.size

    # -- drain ---------------------------------------------------------------

    def pack_hops(self, ready_slots: np.ndarray, hop: int) -> np.ndarray:
        """Consume one ``hop``-sample window from every ready slot into the
        batched ``(capacity_slots, hop)`` int32 step input.

        Pure fancy indexing — one flat gather, one pointer bump —
        regardless of how many streams are ready; rows not in
        ``ready_slots`` are zero (they ride through the jitted step
        masked).  ``ready_slots`` must be sorted unique slot indices (what
        ``np.nonzero(ready_mask(...))`` yields).  The per-sample index
        math runs un-wrapped and only rows whose window crosses the region
        end pay the wrap fix, so the steady-state gather is one
        broadcast-add plus one take over the flat arena.
        """
        out = np.zeros((self.capacity_slots, hop), np.int32)
        ready_slots = np.asarray(ready_slots, np.int64)
        if ready_slots.size == 0:
            return out
        if ((self.wr[ready_slots] - self.rd[ready_slots]) < hop).any():
            raise MemoryError(
                f"arena underflow: pack_hops({hop}) on a slot holding less"
            )
        cap = self.capacity_samples
        with self._write():
            # the gather itself sits inside the write window: pack is a
            # CONSUMER (it bumps rd), so lock-free observers must treat
            # the whole read-and-consume as one mutation
            start = self.rd[ready_slots] % cap
            if cap % hop == 0 and not (start % hop).any():
                # aligned fast path: every window is one whole block of a
                # (slots, blocks, hop) view of the arena, so the gather is
                # a contiguous block-row take — no per-sample index array.
                # The scheduler keeps slots on this path by rebasing each
                # inbox once at priming (rebase) and sizing the arena in
                # whole hops.
                view = self.data.reshape(self.capacity_slots, cap // hop,
                                         hop)
                gathered = view[ready_slots, start // hop]
            else:
                idx = (ready_slots * cap + start)[:, None] + np.arange(hop)
                over = start + hop > cap  # windows wrapping past region end
                if over.any():
                    row_end = ((ready_slots[over] + 1) * cap)[:, None]
                    sub = idx[over]
                    idx[over] = np.where(sub >= row_end, sub - cap, sub)
                gathered = self.data.reshape(-1)[idx]
            if ready_slots.size == self.capacity_slots:
                out = gathered.astype(np.int32)  # all ready: skip scatter
            else:
                out[ready_slots] = gathered
            self.rd[ready_slots] += hop
        return out

    def rebase(self, slot: int) -> None:
        """Move one slot's live samples to offset 0 (pointers reset, data
        compacted).  The scheduler calls this once per stream right after
        priming: from then on the hot path only consumes whole hops, so
        the slot's windows stay block-aligned and ``pack_hops`` takes the
        contiguous fast path forever."""
        self.rebase_batch(np.array([slot], np.int64))

    def rebase_batch(self, slots: np.ndarray) -> None:
        """``rebase`` for many slots in one vectorized gather/scatter —
        the mass-join twin: a B-stream join realigns all B inboxes without
        a python loop over slots."""
        slots = np.asarray(slots, np.int64)
        if slots.size == 0:
            return
        with self._write():
            n = self.wr[slots] - self.rd[slots]
            m = int(n.max())
            if m:
                idx = (self.rd[slots][:, None]
                       + np.arange(m)) % self.capacity_samples
                vals = self.data[slots[:, None], idx]
                keep = np.arange(m)[None, :] < n[:, None]
                cur = self.data[slots, :m]
                self.data[slots, :m] = np.where(keep, vals, cur)
            self.rd[slots] = 0
            self.wr[slots] = n

    def peek(self, slot: int, n: int | None = None) -> np.ndarray:
        """Oldest ``n`` samples (default: all) of one slot as (n,) int32
        u8-codes, without consuming — the host-path (priming/flush) view."""
        have = self.fill_of(slot)
        n = have if n is None else int(n)
        if n > have:
            raise MemoryError(f"arena underflow: peek {n} of {have} "
                              f"(slot {slot})")
        idx = (self.rd[slot] + np.arange(n)) % self.capacity_samples
        return self.data[slot, idx].astype(np.int32)

    def pop(self, slot: int, n: int) -> np.ndarray:
        out = self.peek(slot, n)
        with self._write():
            self.rd[slot] += n
        return out

    def pop_batch(self, slots: np.ndarray, n: int) -> np.ndarray:
        """Consume the oldest ``n`` samples of many slots in one gather;
        returns (len(slots), n) int32 u8-codes — the batched primer's
        warm-up read (every joining stream pops ``prime_samples`` at
        once)."""
        slots = np.asarray(slots, np.int64)
        if slots.size == 0:
            return np.zeros((0, n), np.int32)
        if ((self.wr[slots] - self.rd[slots]) < n).any():
            raise MemoryError(
                f"arena underflow: pop_batch({n}) on a slot holding less"
            )
        with self._write():
            idx = (self.rd[slots][:, None]
                   + np.arange(n)) % self.capacity_samples
            out = self.data[slots[:, None], idx].astype(np.int32)
            self.rd[slots] += n
        return out

    # -- slot lifecycle ------------------------------------------------------

    def clear_slot(self, slot: int) -> None:
        """Scrub one row so the next tenant starts clean (the fleet-level
        ``total_*`` counters keep counting across tenants)."""
        with self._write():
            self.data[slot] = 0
            self.rd[slot] = self.wr[slot] = 0
            self.samples_in[slot] = 0
            self.chunks_in[slot] = 0
            self.gain[slot] = 1.0

    def apply_remap(self, remap: dict[int, int], new_capacity_slots: int
                    ) -> None:
        """Follow a ``SlotPlacement`` grow/shrink/rebalance: surviving
        rows move to their new slots with one vectorized gather per
        array; vacated rows reset.  Resizes keep rows inside their shard
        block; a ``rebalance`` remap is the one path that moves rows
        across blocks (mirroring the device-side
        ``ops.remap_slot_rows`` gather).
        """
        with self._write():
            self.data = remap_rows(self.data, remap, new_capacity_slots)
            self.rd = remap_rows(self.rd, remap, new_capacity_slots)
            self.wr = remap_rows(self.wr, remap, new_capacity_slots)
            self.samples_in = remap_rows(self.samples_in, remap,
                                         new_capacity_slots)
            self.chunks_in = remap_rows(self.chunks_in, remap,
                                        new_capacity_slots)
            self.gain = remap_rows(self.gain, remap, new_capacity_slots,
                                   fill=1.0)


# ---------------------------------------------------------------------------
# Stream plan: static per-hop geometry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvStage:
    """One conv layer's static streaming geometry.

    The ``flush_*`` fields describe the *finalization tail*: the extra work
    an end-of-stream flush performs from the steady state (append the right
    pad, convolve what fits, pool with drop-remainder).  Because the steady
    tail/phase lengths are constants of the plan, so are these counts —
    which is what lets the scheduler compute "logits as if the stream ended
    now" *inside* the jitted batched step instead of on the host.
    """

    layer_idx: int
    name: str
    k: int
    stride: int
    pad: int
    pool: int
    cin: int
    cout: int
    in_bits: int
    in_offset: int
    tail: int      # steady-state receptive-field tail length (frames)
    phase: int     # steady-state pool phase (frames pending in the window)
    n_in: int      # frames consumed per hop
    n_conv: int    # conv positions emitted per hop
    n_out: int     # pooled frames emitted per hop
    flush_in: int    # extra frames received from the layer above at flush
    flush_conv: int  # extra conv positions a flush emits (tail + right pad)
    flush_out: int   # extra pooled frames a flush emits (remainder dropped)


@dataclasses.dataclass(frozen=True)
class FCStage:
    layer_idx: int
    name: str
    cin: int
    cout: int
    in_bits: int
    out_raw: bool


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Static schedule for one model: hop/prime sizes + per-layer geometry."""

    spec: CNN1DSpec
    hop_samples: int
    prime_samples: int
    convs: tuple[ConvStage, ...]
    fcs: tuple[FCStage, ...]
    gap_channels: int

    @property
    def frames_per_hop(self) -> int:
        return self.convs[-1].n_out

    @property
    def samples_per_frame(self) -> int:
        return self.hop_samples // self.frames_per_hop

    def macs_per_hop(self) -> int:
        """Logical MACs of one steady-state hop (conv cascade only)."""
        return sum(c.n_conv * c.k * c.cin * c.cout for c in self.convs)

    def fc_macs(self) -> int:
        return sum(f.cin * f.cout for f in self.fcs)


def _conv_layers(spec: CNN1DSpec) -> tuple[list[tuple[int, Conv1DSpec]],
                                           int, list[tuple[int, FCSpec]]]:
    """Split the spec into conv prefix / GAP / fc suffix (the streamable
    topology); anything else is rejected."""
    convs: list[tuple[int, Conv1DSpec]] = []
    fcs: list[tuple[int, FCSpec]] = []
    gap_at = None
    for li, lspec in enumerate(spec.layers):
        if isinstance(lspec, Conv1DSpec):
            if gap_at is not None:
                raise ValueError("conv after GAP is not streamable")
            if lspec.out_raw:
                raise ValueError(f"{lspec.name}: raw-output conv mid-stream")
            convs.append((li, lspec))
        elif isinstance(lspec, GAPSpec):
            if gap_at is not None:
                raise ValueError("multiple GAP layers")
            gap_at = li
        elif isinstance(lspec, FCSpec):
            if gap_at is None:
                raise ValueError("FC before GAP is not streamable")
            fcs.append((li, lspec))
        else:
            raise ValueError(f"layer {li} ({type(lspec).__name__}) not streamable")
    if not convs or gap_at is None or not fcs:
        raise ValueError("streamable spec needs convs -> GAP -> FCs")
    return convs, gap_at, fcs


def _simulate_counts(convs: list[tuple[int, Conv1DSpec]], pushes: list[int]
                     ) -> tuple[list[int], list[int], list[list[int]]]:
    """Feed ``pushes`` chunks through the count-level model.

    Returns (tail lengths, pool phases, per-push emissions per layer) after
    all pushes; tails include the layer's left pad on the first push.
    """
    fed = [0] * len(convs)       # frames of the *padded* stream received
    emitted = [0] * len(convs)   # conv positions emitted so far
    pooled = [0] * len(convs)    # pooled frames emitted so far
    per_push: list[list[int]] = []
    for push in pushes:
        cur = push
        outs = []
        for i, (_, L) in enumerate(convs):
            if fed[i] == 0 and cur > 0:
                fed[i] += L.pad  # left pad arrives with the first real frame
            fed[i] += cur
            total = max(0, (fed[i] - L.k) // L.stride + 1) if fed[i] >= L.k else 0
            new_conv = total - emitted[i]
            emitted[i] = total
            new_pool = (emitted[i] // L.pool) - pooled[i]
            pooled[i] += new_pool
            cur = new_pool
            outs.append(new_conv)
        per_push.append(outs)
    tails = [
        fed[i] - emitted[i] * L.stride for i, (_, L) in enumerate(convs)
    ]
    phases = [emitted[i] % L.pool for i, (_, L) in enumerate(convs)]
    return tails, phases, per_push


def plan_stream(
    spec: CNN1DSpec,
    hop_frames: int = 1,
    prime_samples: int | None = None,
) -> StreamPlan:
    """Derive the static streaming schedule for ``spec``.

    ``hop_frames``: final-layer frames per scheduler step; the hop size in
    samples is ``hop_frames * prod(stride*pool)``.  ``prime_samples`` is the
    warm-up prefix a stream must deliver before it enters the steady-state
    batched step; the default is the smallest stride-aligned prefix that
    fills every layer's tail.
    """
    convs, _, fcs = _conv_layers(spec)
    unit = 1
    for _, L in convs:
        unit *= L.stride * L.pool
    hop = hop_frames * unit

    s0 = convs[0][1].stride
    if prime_samples is None:
        # smallest stride-aligned prefix after which every layer has seen a
        # full receptive field (fed >= k), i.e. every tail is at steady size
        prime_samples = 0
        for p in range(s0, 64 * unit + 1, s0):
            f, ok = p, True
            for _, L in convs:
                f_padded = L.pad + f
                if f_padded < L.k:
                    ok = False
                    break
                f = ((f_padded - L.k) // L.stride + 1) // L.pool
            if ok:
                prime_samples = p
                break
        if prime_samples == 0:
            raise ValueError("could not find a priming prefix")

    # verify steady state: two extra hops give identical emissions + tails
    tails, phases, per = _simulate_counts(convs, [prime_samples, hop, hop])
    tails2, phases2, per2 = _simulate_counts(
        convs, [prime_samples, hop, hop, hop]
    )
    if per[1] != per[2] or per2[2] != per2[3] or tails != tails2 or phases != phases2:
        raise ValueError(
            f"hop {hop} / prime {prime_samples} does not reach steady state"
        )

    # finalization-tail geometry: what an end-of-stream flush emits from the
    # steady state (mirrors StreamState._advance_once with flush=True)
    flush_geom = []
    f_in = 0
    for i, (_, L) in enumerate(convs):
        avail = tails[i] + f_in + L.pad  # tail ++ upstream flush ++ right pad
        f_conv = (avail - L.k) // L.stride + 1 if avail >= L.k else 0
        f_out = (phases[i] + f_conv) // L.pool
        flush_geom.append((f_in, f_conv, f_out))
        f_in = f_out

    stages = []
    n_in = hop
    for i, (li, L) in enumerate(convs):
        n_conv = per[1][i]
        if n_conv % L.pool:
            raise ValueError(
                f"{L.name}: {n_conv} conv frames/hop not divisible by pool "
                f"{L.pool}; raise hop_frames"
            )
        stages.append(
            ConvStage(
                layer_idx=li, name=L.name, k=L.k, stride=L.stride, pad=L.pad,
                pool=L.pool, cin=L.cin, cout=L.cout, in_bits=L.in_bits,
                in_offset=L.in_offset, tail=tails[i], phase=phases[i],
                n_in=n_in, n_conv=n_conv, n_out=n_conv // L.pool,
                flush_in=flush_geom[i][0], flush_conv=flush_geom[i][1],
                flush_out=flush_geom[i][2],
            )
        )
        assert n_conv * L.stride == n_in, (L.name, n_conv, n_in)
        n_in = n_conv // L.pool

    fc_stages = tuple(
        FCStage(li, F.name, F.cin, F.cout, F.in_bits, F.out_raw)
        for li, F in fcs
    )
    return StreamPlan(
        spec=spec,
        hop_samples=hop,
        prime_samples=prime_samples,
        convs=tuple(stages),
        fcs=fc_stages,
        gap_channels=convs[-1][1].cout,
    )


# ---------------------------------------------------------------------------
# Reference per-stream state (numpy; priming / flush / peek path)
# ---------------------------------------------------------------------------

def _threshold(raw: np.ndarray, thr: np.ndarray, flip: np.ndarray) -> np.ndarray:
    """Executor-exact SA binarization (float64 compare, flip channels)."""
    ge = raw >= thr[None, :]
    return np.where(flip[None, :], ~ge, ge).astype(np.uint8)


def _conv_raw(window: np.ndarray, w: np.ndarray, stage: ConvStage,
              n_conv: int) -> np.ndarray:
    """n_conv positions of the layer over ``window`` (tail ++ new frames)."""
    x = window.astype(np.int64)
    if stage.in_bits > 1:
        x = x - stage.in_offset  # offset-binary input (pads carry the code)
    taps = np.stack(
        [
            x[t : t + (n_conv - 1) * stage.stride + 1 : stage.stride]
            for t in range(stage.k)
        ],
        axis=0,
    )  # (K, n_conv, Cin)
    return np.einsum("knc,kco->no", taps, w.astype(np.int64))


class StreamState:
    """One stream's incremental inference state (bit-exact numpy path).

    Handles arbitrary chunk sizes: warm-up, steady hops, end-of-stream flush
    with right padding, and non-destructive mid-stream peeks.  The jitted
    batched scheduler path is the steady-state specialization of exactly
    this code.
    """

    def __init__(
        self,
        plan: StreamPlan,
        weights: dict[int, np.ndarray],
        thresholds: dict[int, tuple[np.ndarray, np.ndarray]],
        ring_slack: int | None = None,
    ) -> None:
        self.plan = plan
        self.weights = weights
        self.thresholds = thresholds
        slack = ring_slack if ring_slack is not None else max(
            plan.prime_samples, 2 * plan.hop_samples
        )
        self._max_chunk = slack  # advance() splits larger inputs
        self.hists: list[FrameRing] = []
        self.pendings: list[FrameRing] = []
        for st in plan.convs:
            cap = st.tail + 2 * st.pad + st.k + max(slack, st.n_in) + 1
            self.hists.append(FrameRing(cap, st.cin, np.int32))
            self.pendings.append(
                FrameRing(st.pool + st.k + st.pad + max(slack, st.n_conv) + 1,
                          st.cout, np.int32)
            )
            slack = max(1, -(-slack // max(1, st.stride)))
        self.started = [False] * len(plan.convs)
        self.gap = np.zeros(plan.gap_channels, np.int64)
        self.frames = 0          # final-conv pooled frames accumulated in GAP
        self.samples_seen = 0
        self.flushed = False

    # -- core advance --------------------------------------------------------

    def advance(self, samples: np.ndarray, flush: bool = False) -> np.ndarray:
        """Feed u8 samples (n,) or (n, Cin0); returns newly emitted
        final-conv frames (m, C).  ``flush`` appends each layer's right pad
        and drops incomplete pool windows (end-of-stream semantics)."""
        samples = np.asarray(samples)
        cur = samples.reshape(-1, self.plan.convs[0].cin)
        if cur.shape[0] > self._max_chunk:
            # split oversized inputs so the fixed-capacity rings never
            # overflow (the pointers just wrap more often)
            outs = []
            for i in range(0, cur.shape[0], self._max_chunk):
                seg = cur[i : i + self._max_chunk]
                last = i + self._max_chunk >= cur.shape[0]
                outs.append(self._advance_once(seg, flush=flush and last))
            return np.concatenate(outs, axis=0)
        return self._advance_once(cur, flush=flush)

    def _advance_once(self, samples: np.ndarray, flush: bool) -> np.ndarray:
        assert not self.flushed, "stream already flushed"
        cur = samples.reshape(-1, self.plan.convs[0].cin).astype(np.int32)
        self.samples_seen += cur.shape[0]
        for i, st in enumerate(self.plan.convs):
            hist = self.hists[i]
            w = self.weights[st.layer_idx]
            wk = w.reshape(st.k, st.cin, st.cout)
            if not self.started[i] and (cur.shape[0] > 0 or flush):
                # left pad arrives with the first real frame (offset code
                # for the multi-bit first layer, zeros for binary layers)
                pad_val = st.in_offset if st.in_bits > 1 else 0
                hist.push(np.full((st.pad, st.cin), pad_val, np.int32))
                self.started[i] = True
            hist.push(cur)
            if flush:
                pad_val = st.in_offset if st.in_bits > 1 else 0
                hist.push(np.full((st.pad, st.cin), pad_val, np.int32))
            avail = len(hist)
            n_conv = (avail - st.k) // st.stride + 1 if avail >= st.k else 0
            if n_conv > 0:
                window = hist.peek(avail)
                raw = _conv_raw(window, wk, st, n_conv)
                thr, flip = self.thresholds[st.layer_idx]
                y = _threshold(raw, thr, flip)
                hist.drop(n_conv * st.stride)
            else:
                y = np.zeros((0, st.cout), np.uint8)
            # pool: OR over non-overlapping windows, absolute alignment
            pend = self.pendings[i]
            pend.push(y.astype(np.int32))
            n_pool = len(pend) // st.pool
            if n_pool > 0:
                frames = pend.pop(n_pool * st.pool)
                cur = frames.reshape(n_pool, st.pool, st.cout).max(axis=1)
            else:
                cur = np.zeros((0, st.cout), np.int32)
            if flush:
                pend.drop(len(pend))  # drop-remainder (ref_maxpool1d)
        self.gap += cur.astype(np.int64).sum(axis=0)
        self.frames += cur.shape[0]
        if flush:
            self.flushed = True
        return cur

    # -- logits --------------------------------------------------------------

    def logits(self) -> np.ndarray:
        """fc cascade over the (saturated) GAP counts — executor-exact."""
        h = np.minimum(self.gap, 255).astype(np.int64)[None, :]  # 8-bit PWB
        for st in self.plan.fcs:
            w = self.weights[st.layer_idx].astype(np.int64)
            raw = h @ w
            if st.out_raw:
                h = raw
            else:
                thr, flip = self.thresholds[st.layer_idx]
                h = _threshold(raw, thr, flip).astype(np.int64)
        return h[0]

    def peek_logits(self, extra_samples: np.ndarray | None = None) -> np.ndarray:
        """Logits as if the stream ended now (plus ``extra_samples``),
        without disturbing the live state — the per-frame logits contract:
        peek after feeding audio[:L] == offline executor on audio[:L].

        This is the *exact fallback* path: the scheduler computes per-hop
        finalized logits inside the jitted batched step (the fused
        finalization tail) and only drops to this clone-and-flush numpy
        path for mid-hop peeks that must include leftover sub-hop samples,
        or for streams that are not yet primed."""
        ghost = self.clone()
        if extra_samples is None:
            extra_samples = np.zeros((0,), np.int32)
        ghost.advance(extra_samples, flush=True)
        return ghost.logits()

    def clone(self) -> "StreamState":
        c = StreamState.__new__(StreamState)
        c.plan, c.weights, c.thresholds = self.plan, self.weights, self.thresholds
        c._max_chunk = self._max_chunk
        c.hists = [h.clone() for h in self.hists]
        c.pendings = [p.clone() for p in self.pendings]
        c.started = list(self.started)
        c.gap = self.gap.copy()
        c.frames = self.frames
        c.samples_seen = self.samples_seen
        c.flushed = self.flushed
        return c

    # -- steady-state interchange with the batched scheduler -----------------

    def export_steady(self) -> dict[str, list[np.ndarray] | np.ndarray]:
        """Tail/pending/gap arrays at the plan's steady-state shapes."""
        tails, pends = [], []
        for i, st in enumerate(self.plan.convs):
            h = self.hists[i]
            if len(h) != st.tail:
                raise ValueError(
                    f"{st.name}: tail {len(h)} != steady {st.tail} "
                    "(stream not primed?)"
                )
            tails.append(h.peek(st.tail))
            p = self.pendings[i]
            if len(p) != st.phase:
                raise ValueError(
                    f"{st.name}: pool phase {len(p)} != steady {st.phase}"
                )
            pends.append(p.peek(st.phase))  # exactly (phase, cout)
        return {"tails": tails, "pendings": pends, "gap": self.gap.copy()}

    def import_steady(self, tails, pendings, gap, frames: int) -> None:
        for i, st in enumerate(self.plan.convs):
            self.hists[i].load(np.asarray(tails[i], np.int32))
            self.pendings[i].load(
                np.asarray(pendings[i][: st.phase], np.int32)
            )
            self.started[i] = True
        self.gap = np.asarray(gap, np.int64).copy()
        self.frames = frames


# ---------------------------------------------------------------------------
# Batched primer: warm up a mass join as ONE vectorized advance
# ---------------------------------------------------------------------------

def prime_batch(
    plan: StreamPlan,
    weights: dict[int, np.ndarray],
    thresholds: dict[int, tuple[np.ndarray, np.ndarray]],
    samples: np.ndarray,
) -> dict[str, list[np.ndarray] | np.ndarray | int]:
    """Warm up B fresh streams with one batched numpy advance.

    ``samples`` is (B, prime_samples) u8 codes.  Returns the batched
    steady-state interchange: ``tails[i]`` (B, tail_i, cin_i),
    ``pendings[i]`` (B, phase_i, cout_i), ``gap`` (B, C) int64 and the
    scalar ``frames`` every primed stream has emitted — row ``j`` equals
    ``StreamState().advance(samples[j]); export_steady()`` exactly.  The
    warm-up is integer arithmetic end to end (int64 conv accumulation,
    integer SA thresholds, OR-pooling), so adding the batch axis cannot
    change any value; bit-exactness is pinned by tests/test_rebalance.py.

    This is what lets a B-stream mass join cost one vectorized cascade
    instead of B per-stream ``StreamState`` warm-ups (the last
    per-stream-python ingest edge the PR 4 arena left behind).
    """
    samples = np.asarray(samples)
    if samples.ndim != 2 or samples.shape[1] != plan.prime_samples:
        raise ValueError(
            f"prime_batch wants (B, {plan.prime_samples}) samples, "
            f"got {samples.shape}"
        )
    B = samples.shape[0]
    cur = samples.reshape(B, -1, plan.convs[0].cin).astype(np.int32)
    tails: list[np.ndarray] = []
    pendings: list[np.ndarray] = []
    for st in plan.convs:
        # left pad arrives with the first real frame, exactly like
        # StreamState._advance_once on a fresh stream
        pad_val = st.in_offset if st.in_bits > 1 else 0
        window = np.concatenate(
            [np.full((B, st.pad, st.cin), pad_val, np.int32), cur], axis=1
        )
        avail = window.shape[1]
        n_conv = (avail - st.k) // st.stride + 1 if avail >= st.k else 0
        if n_conv <= 0 or avail - n_conv * st.stride != st.tail:
            raise ValueError(
                f"{st.name}: priming prefix does not reach the steady "
                f"tail (plan prime_samples mismatch?)"
            )
        w = weights[st.layer_idx].reshape(st.k, st.cin, st.cout)
        x = window.astype(np.int64)
        if st.in_bits > 1:
            x = x - st.in_offset  # offset-binary input (pads carry the code)
        taps = np.stack(
            [
                x[:, t : t + (n_conv - 1) * st.stride + 1 : st.stride]
                for t in range(st.k)
            ],
            axis=1,
        )  # (B, K, n_conv, Cin)
        raw = np.einsum("bknc,kco->bno", taps, w.astype(np.int64))
        thr, flip = thresholds[st.layer_idx]
        ge = raw >= thr[None, None, :]
        y = np.where(flip[None, None, :], ~ge, ge).astype(np.int32)
        tails.append(window[:, n_conv * st.stride :])
        used = (n_conv // st.pool) * st.pool
        if n_conv - used != st.phase:
            raise ValueError(
                f"{st.name}: pool phase {n_conv - used} != steady "
                f"{st.phase} after priming"
            )
        pendings.append(y[:, used:])
        cur = y[:, :used].reshape(
            B, n_conv // st.pool, st.pool, st.cout
        ).max(axis=2)
    gap = cur.astype(np.int64).sum(axis=1)
    return {"tails": tails, "pendings": pendings, "gap": gap,
            "frames": cur.shape[1]}
