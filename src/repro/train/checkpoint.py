"""Fault-tolerant checkpointing: atomic, versioned, mesh-elastic.

Format: one zstd-compressed msgpack file per step holding
  { step, meta {arch, mesh_shape, tree_def}, leaves {name: raw bytes} }

Guarantees:
  * atomic: write to <dir>/tmp-<step>, fsync, rename to <dir>/step-<n>
    — a crash mid-save never corrupts the latest checkpoint.
  * versioned: keep_last N checkpoints, GC older ones.
  * elastic restore: arrays are saved unsharded (gathered); ``restore``
    re-places them with whatever NamedSharding the *new* mesh dictates, so a
    job can restart on a different topology (node failure, elastic scale).
  * integrity: per-leaf crc32 verified on load.

On a real multi-host cluster the gather becomes a per-host shard dump +
manifest (same interface); this single-process implementation is the
functional model of that protocol.
"""
from __future__ import annotations

import os
import pathlib
import struct
import zlib

import jax
import jax.numpy as jnp
import ml_dtypes
import msgpack
import numpy as np

try:  # zstd preferred; stdlib zlib keeps checkpointing alive without it
    import zstandard
except ImportError:  # pragma: no cover - depends on environment
    zstandard = None

from repro.utils.logging import get_logger

log = get_logger("checkpoint")

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"  # first 4 bytes of every zstd frame


def _compress(payload: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(payload)
    # zlib blobs never start with the zstd magic (first byte 0x78 for the
    # default window), so _decompress can tell the two formats apart.
    return zlib.compress(payload, level=6)


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise ImportError(
                "checkpoint was written with zstd but the 'zstandard' package "
                "is not installed; install it or re-save with zlib"
            )
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _leaf_to_bytes(x) -> dict:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype.name == "bfloat16":
        raw = arr.view(np.uint16).tobytes()
        dtype = "bfloat16"
    else:
        raw = arr.tobytes()
        dtype = arr.dtype.str
    return {
        "shape": list(arr.shape),
        "dtype": dtype,
        "crc": zlib.crc32(raw),
        "data": raw,
    }


def _leaf_from_bytes(d: dict) -> np.ndarray:
    raw = d["data"]
    if zlib.crc32(raw) != d["crc"]:
        raise IOError("checkpoint leaf CRC mismatch (corrupt file)")
    if d["dtype"] == "bfloat16":
        return np.frombuffer(raw, ml_dtypes.bfloat16).reshape(d["shape"])
    return np.frombuffer(raw, np.dtype(d["dtype"])).reshape(d["shape"])


def save(ckpt_dir: str | os.PathLike, step: int, tree, meta: dict | None = None,
         keep_last: int = 3) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "step": step,
        "meta": meta or {},
        "treedef": str(treedef),
        "leaves": [_leaf_to_bytes(l) for l in leaves],
    }
    blob = _compress(msgpack.packb(payload, use_bin_type=True))
    tmp = ckpt_dir / f"tmp-{step}"
    final = ckpt_dir / f"step-{step:010d}"
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)
    _gc(ckpt_dir, keep_last)
    log.info("saved checkpoint %s (%.1f MB)", final.name, len(blob) / 1e6)
    return final


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(
        int(p.name.split("-")[1]) for p in ckpt_dir.glob("step-*")
    )
    return steps[-1] if steps else None


def restore(ckpt_dir: str | os.PathLike, tree_template, step: int | None = None,
            shardings=None) -> tuple[int, object, dict]:
    """Load a checkpoint into the structure of ``tree_template``.

    ``shardings``: optional pytree of NamedSharding matching the template —
    arrays are device_put with them (elastic re-shard onto the current mesh).
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = ckpt_dir / f"step-{step:010d}"
    blob = _decompress(path.read_bytes())
    payload = msgpack.unpackb(blob, raw=False)
    leaves_raw = [_leaf_from_bytes(d) for d in payload["leaves"]]
    flat_t, treedef = jax.tree_util.tree_flatten(tree_template)
    if len(flat_t) != len(leaves_raw):
        raise ValueError(
            f"checkpoint has {len(leaves_raw)} leaves, template expects "
            f"{len(flat_t)} — architecture mismatch"
        )
    if shardings is not None:
        flat_s = jax.tree_util.tree_flatten(shardings)[0]
        leaves = [
            jax.device_put(np.asarray(l), s) for l, s in zip(leaves_raw, flat_s)
        ]
    else:
        leaves = [jnp.asarray(l) for l in leaves_raw]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    log.info("restored checkpoint step %d from %s", step, path.name)
    return step, tree, payload["meta"]


def _gc(ckpt_dir: pathlib.Path, keep_last: int) -> None:
    ckpts = sorted(ckpt_dir.glob("step-*"))
    for old in ckpts[:-keep_last]:
        old.unlink()
