"""Gradient compression for bandwidth-limited (inter-pod) links.

Two schemes, both with error feedback (the residual of the compression is
carried into the next step, which is what keeps convergence):

  * sign1bit — 1-bit sign + per-tensor L1 scale (signSGD-EF / 1-bit Adam
    style): 32x smaller payload on the pod axis all-reduce.
  * topk     — keep the largest k-fraction entries (magnitude), zero rest.

These run as optimizer ``grad_transform`` hooks *after* the intra-pod
reduce-scatter and *before* the optimizer update; the error-feedback
residual lives in the optimizer state dict under 'ef'.  In the pjit
formulation the compressed tensor is what crosses the "pod" axis; the
benchmark quantifies the collective-bytes reduction on the dry-run HLO
(EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _sign_compress(g):
    scale = jnp.mean(jnp.abs(g))
    return jnp.sign(g) * scale


def _topk_compress(g, frac: float):
    flat = g.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(g) >= thresh
    return g * mask


def make_transform(scheme: str = "sign1bit", topk_frac: float = 0.01):
    """Returns grad_transform(grads, opt_state) -> (grads', opt_state')."""

    if scheme == "none":
        return None

    if scheme == "sign1bit":
        comp = _sign_compress
    elif scheme == "topk":
        comp = functools.partial(_topk_compress, frac=topk_frac)
    else:
        raise ValueError(scheme)

    def transform(grads, state):
        ef = state.get("ef")
        if ef is None:
            ef = jax.tree_util.tree_map(jnp.zeros_like, grads)
        corrected = jax.tree_util.tree_map(lambda g, e: g + e, grads, ef)
        compressed = jax.tree_util.tree_map(comp, corrected)
        new_ef = jax.tree_util.tree_map(
            lambda c, q: c - q, corrected, compressed
        )
        state = dict(state)
        state["ef"] = new_ef
        return compressed, state

    return transform


def compressed_bytes(tree, scheme: str = "sign1bit", topk_frac: float = 0.01
                     ) -> int:
    """Payload size of one cross-pod sync under the scheme (for §Perf)."""
    import numpy as np

    leaves = jax.tree_util.tree_leaves(tree)
    n = int(sum(np.prod(l.shape) for l in leaves))
    if scheme == "sign1bit":
        return n // 8 + 4 * len(leaves)
    if scheme == "topk":
        k = int(n * topk_frac)
        return k * 8  # value + index
    return n * 4
