"""Training loop: grad accumulation, pjit sharding, checkpoints, restart.

The step function is pure (TrainState in, TrainState out) so fault recovery
is exactly "restore + continue".  Microbatch accumulation runs as a
lax.scan so each microbatch's backward completes (and its gradient bucket
becomes eligible for the GSPMD reduce-scatter) before the next microbatch's
forward — compute/communication overlap falls out of XLA's latency-hiding
scheduler over the scanned graph.

Straggler mitigation: per-step wall-time EWMA; hosts slower than
``straggler_factor`` x median are reported for exclusion at the next elastic
boundary (on this single-process container the monitor is exercised with
synthetic timings in tests).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train import schedule as sched_lib
from repro.train.compression import make_transform
from repro.utils.logging import get_logger

log = get_logger("train")


@dataclasses.dataclass
class TrainConfig:
    opt: opt_lib.OptConfig = dataclasses.field(default_factory=opt_lib.OptConfig)
    microbatches: int = 1
    warmup_steps: int = 100
    total_steps: int = 1000
    remat: str = "block"
    compression: str = "none"
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    keep_last: int = 3
    straggler_factor: float = 2.0


def make_train_step(cfg_arch, tcfg: TrainConfig, loss_fn: Callable):
    """Returns step(train_state, batch) -> (train_state, metrics).

    train_state = {"params": bf16 pytree, "opt": optimizer state}.
    batch leaves have a leading microbatch axis when microbatches > 1.
    """
    transform = make_transform(tcfg.compression)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(state, batch):
        params = state["params"]
        if tcfg.microbatches > 1:
            def accum(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, mb)
                return (
                    loss_acc + loss,
                    jax.tree_util.tree_map(jnp.add, g_acc, g),
                ), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss_sum, grads), _ = jax.lax.scan(
                accum, (jnp.zeros(()), zero), batch
            )
            inv = 1.0 / tcfg.microbatches
            loss = loss_sum * inv
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        else:
            loss, grads = grads_of(params, batch)

        lr_scale = sched_lib.warmup_cosine(
            state["opt"]["step"],
            warmup_steps=tcfg.warmup_steps,
            total_steps=tcfg.total_steps,
        )
        new_opt, om = opt_lib.update(
            tcfg.opt, state["opt"], grads, lr_scale, grad_transform=transform
        )
        new_params = opt_lib.cast_params_like(new_opt["master"], params)
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return step


def init_train_state(tcfg: TrainConfig, params) -> dict:
    return {"params": params, "opt": opt_lib.init_opt_state(tcfg.opt, params)}


class StragglerMonitor:
    """EWMA per-host step times; flags hosts above factor x median."""

    def __init__(self, n_hosts: int, factor: float = 2.0, alpha: float = 0.2):
        self.ewma = np.zeros(n_hosts)
        self.factor = factor
        self.alpha = alpha
        self.seen = 0

    def record(self, host_times: np.ndarray) -> list[int]:
        if self.seen == 0:
            self.ewma = host_times.astype(float)
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * host_times
        self.seen += 1
        med = float(np.median(self.ewma))
        return [i for i, t in enumerate(self.ewma) if t > self.factor * med]


class Trainer:
    """Drives the jitted step: data, checkpoints, restart, monitoring."""

    def __init__(self, cfg_arch, tcfg: TrainConfig, loss_fn, params,
                 data_iter, jit_kwargs: dict | None = None):
        self.cfg_arch = cfg_arch
        self.tcfg = tcfg
        self.data_iter = data_iter
        self.state = init_train_state(tcfg, params)
        self.step_idx = 0
        step = make_train_step(cfg_arch, tcfg, loss_fn)
        self.step_fn = jax.jit(step, donate_argnums=(0,), **(jit_kwargs or {}))
        self.monitor = StragglerMonitor(jax.process_count(),
                                        tcfg.straggler_factor)
        if tcfg.ckpt_dir:
            self._maybe_restore()

    def _maybe_restore(self):
        last = ckpt_lib.latest_step(self.tcfg.ckpt_dir)
        if last is not None:
            self.step_idx, self.state, _ = ckpt_lib.restore(
                self.tcfg.ckpt_dir, self.state, step=last
            )
            log.info("resumed at step %d", self.step_idx)

    def run(self, n_steps: int) -> list[dict]:
        history = []
        for _ in range(n_steps):
            batch = next(self.data_iter)
            t0 = time.monotonic()
            self.state, metrics = self.step_fn(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            self.step_idx += 1
            metrics["step"] = self.step_idx
            metrics["step_time_s"] = dt
            stragglers = self.monitor.record(np.array([dt]))
            if stragglers and jax.process_count() > 1:  # pragma: no cover
                log.warning("straggler hosts: %s", stragglers)
            history.append(metrics)
            if (
                self.tcfg.ckpt_dir
                and self.step_idx % self.tcfg.ckpt_every == 0
            ):
                ckpt_lib.save(
                    self.tcfg.ckpt_dir, self.step_idx, self.state,
                    meta={"arch": getattr(self.cfg_arch, "name", "?")},
                    keep_last=self.tcfg.keep_last,
                )
        return history
