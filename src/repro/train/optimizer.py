"""Pure-JAX optimizers (no optax in this environment).

Mixed-precision discipline: model params may be bf16; the optimizer keeps an
fp32 master copy plus moments, all sharded like the params (ZeRO-1 falls out
of pjit sharding everything).  ``update`` returns the new bf16 params and
optimizer state.

Optimizers: AdamW, SGD(+momentum), Lion.  All support global-norm clipping
and a pluggable gradient transform hook (used by train/compression.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.utils.tree import global_norm


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9
    clip_norm: float = 1.0


def init_opt_state(cfg: OptConfig, params) -> dict:
    # copy=True: fp32 leaves must not alias the model params (donation safety)
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.array(x, jnp.float32, copy=True), t
    )
    zeros = lambda: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params
    )
    state = {"step": jnp.zeros((), jnp.int32), "master": f32(params)}
    if cfg.name == "adamw":
        state["m"] = zeros()
        state["v"] = zeros()
    elif cfg.name in ("sgd", "lion"):
        state["m"] = zeros()
    elif cfg.name == "adafactor":
        # factored second moment: ~4 bytes/param total optimizer state —
        # the only optimizer that fits 100B+ models on a 16 GB/chip pod.
        def vrow(x):
            return (jnp.zeros(x.shape[:-1], jnp.float32) if x.ndim >= 2
                    else jnp.zeros(x.shape, jnp.float32))

        def vcol(x):
            return (jnp.zeros(x.shape[:-2] + x.shape[-1:], jnp.float32)
                    if x.ndim >= 2 else jnp.zeros((1,), jnp.float32))

        state["v_row"] = jax.tree_util.tree_map(vrow, params)
        state["v_col"] = jax.tree_util.tree_map(vcol, params)
    else:
        raise ValueError(cfg.name)
    return state


def _clip(grads, clip_norm: float):
    if clip_norm <= 0:
        return grads, jnp.asarray(0.0)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def update(
    cfg: OptConfig,
    state: dict,
    grads,
    lr_scale: jax.Array | float = 1.0,
    grad_transform: Callable | None = None,
):
    """-> (new_params_bf16-likeness-of-master-cast, new_state, metrics)."""
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    if grad_transform is not None:
        grads, state = grad_transform(grads, state)
    grads, gnorm = _clip(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = cfg.lr * lr_scale
    master = state["master"]

    if cfg.name == "adamw":
        b1, b2 = cfg.beta1, cfg.beta2
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
        )
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state["v"], grads
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            mh = m_ / bc1
            vh = v_ / bc2
            return p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)

        master = jax.tree_util.tree_map(upd, master, m, v)
        new_state = {"step": step, "master": master, "m": m, "v": v}
    elif cfg.name == "sgd":
        m = jax.tree_util.tree_map(
            lambda m_, g: cfg.momentum * m_ + g, state["m"], grads
        )
        master = jax.tree_util.tree_map(lambda p, m_: p - lr * m_, master, m)
        new_state = {"step": step, "master": master, "m": m}
    elif cfg.name == "lion":
        b1, b2 = cfg.beta1, cfg.beta2

        def upd(p, m_, g):
            u = jnp.sign(b1 * m_ + (1 - b1) * g)
            return p - lr * (u + cfg.weight_decay * p)

        master = jax.tree_util.tree_map(upd, master, state["m"], grads)
        m = jax.tree_util.tree_map(
            lambda m_, g: b2 * m_ + (1 - b2) * g, state["m"], grads
        )
        new_state = {"step": step, "master": master, "m": m}
    elif cfg.name == "adafactor":
        b2 = cfg.beta2

        def upd_factored(p, g, vr, vc):
            if g.ndim >= 2:
                vr_n = b2 * vr + (1 - b2) * jnp.mean(jnp.square(g), axis=-1)
                vc_n = b2 * vc + (1 - b2) * jnp.mean(jnp.square(g), axis=-2)
                r = vr_n / jnp.maximum(
                    jnp.mean(vr_n, axis=-1, keepdims=True), 1e-30
                )
                v_hat = r[..., None] * vc_n[..., None, :]
            else:
                vr_n = b2 * vr + (1 - b2) * jnp.square(g)
                vc_n = vc
                v_hat = vr_n
            u = g * jax.lax.rsqrt(v_hat + cfg.eps)
            # update clipping (Adafactor's RMS-1 rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            p_n = p - lr * (u + cfg.weight_decay * p)
            return p_n, vr_n, vc_n

        master = jax.tree_util.tree_map(
            lambda p, g, vr, vc: upd_factored(p, g, vr, vc)[0],
            state["master"], grads, state["v_row"], state["v_col"],
        )
        v_row = jax.tree_util.tree_map(
            lambda p, g, vr, vc: upd_factored(p, g, vr, vc)[1],
            state["master"], grads, state["v_row"], state["v_col"],
        )
        v_col = jax.tree_util.tree_map(
            lambda p, g, vr, vc: upd_factored(p, g, vr, vc)[2],
            state["master"], grads, state["v_row"], state["v_col"],
        )
        new_state = {"step": step, "master": master, "v_row": v_row,
                     "v_col": v_col}
    else:
        raise ValueError(cfg.name)

    return new_state, {"grad_norm": gnorm, "lr": jnp.asarray(lr)}


def cast_params_like(master, params_template):
    """fp32 master -> model dtype (bf16) for the forward pass."""
    return jax.tree_util.tree_map(
        lambda m, p: m.astype(p.dtype), master, params_template
    )
