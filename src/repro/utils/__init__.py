from repro.utils.tree import (
    tree_size_bytes,
    tree_count_params,
    tree_zeros_like,
    tree_map_with_path_names,
)
from repro.utils.logging import get_logger

__all__ = [
    "tree_size_bytes",
    "tree_count_params",
    "tree_zeros_like",
    "tree_map_with_path_names",
    "get_logger",
]
