"""Minimal structured logger (stdlib only, consistent format) plus the
per-key rate limiter the observability event log mirrors through."""
from __future__ import annotations

import logging
import sys
import time

_FMT = "%(asctime)s %(levelname).1s %(name)s: %(message)s"
_configured = False


def get_logger(name: str) -> logging.Logger:
    global _configured
    if not _configured:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(_FMT, datefmt="%H:%M:%S"))
        root = logging.getLogger("repro")
        root.addHandler(handler)
        root.setLevel(logging.INFO)
        root.propagate = False
        _configured = True
    return logging.getLogger(f"repro.{name}")


class RateLimiter:
    """Per-key minimum-interval limiter with suppressed-count accounting.

    ``allow(key)`` returns ``(ok, suppressed)``: ``ok`` is True at most
    once per ``min_interval_s`` per key, and ``suppressed`` reports how
    many calls were dropped since the last allowed one — so a
    human-readable mirror of a high-rate event stream (a mass join, a
    resize storm) stays honest about what it elided.  State is one
    ``(last_ts, dropped)`` pair per distinct key: bounded by the event
    vocabulary, not the event rate.
    """

    def __init__(self, min_interval_s: float = 1.0) -> None:
        self.min_interval_s = min_interval_s
        self._state: dict[str, list] = {}  # key -> [last_allowed, dropped]

    def allow(self, key: str, now: float | None = None) -> tuple[bool, int]:
        now = time.monotonic() if now is None else now
        st = self._state.get(key)
        if st is None:
            self._state[key] = [now, 0]
            return True, 0
        if now - st[0] >= self.min_interval_s:
            suppressed, st[0], st[1] = st[1], now, 0
            return True, suppressed
        st[1] += 1
        return False, 0
