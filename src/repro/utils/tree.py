"""Pytree helpers used across the framework (no flax/optax available)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size_bytes(tree) -> int:
    """Total bytes of every array leaf in ``tree``."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize for l in leaves))


def tree_count_params(tree) -> int:
    """Total element count of every array leaf in ``tree``."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) for l in leaves))


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_map_with_path_names(fn, tree):
    """tree_map where ``fn(name, leaf)`` receives a '/'-joined key path."""

    def _name(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:  # pragma: no cover - defensive
                parts.append(str(p))
        return "/".join(parts)

    return jax.tree_util.tree_map_with_path(lambda p, l: fn(_name(p), l), tree)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
