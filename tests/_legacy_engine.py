"""Frozen pre-runtime-port serving engine (parity oracle).

Verbatim copy of src/repro/serve/engine.py as of PR 9, BEFORE the engine
was ported onto the shared continuous-batching runtime (repro.runtime).
tests/test_runtime_pool.py decodes the same request schedules through
this oracle and the ported engine and asserts token identity — the port
must change WHERE the slot machinery lives, never WHAT it computes.
Do not edit except to keep imports resolving.

Original module docstring:

Batched serving engine: prefill + decode with continuous batching.

The engine owns one jitted prefill function and one jitted decode step per
(arch, batch-slot geometry).  Requests enter a queue; free batch slots are
filled per decode tick (continuous batching), finished sequences vacate
their slot.  On this container it runs the smoke configs end-to-end; the
same code lowers the production decode_32k / long_500k shapes in the
dry-run (launch/dryrun.py lowers exactly ``self.decode_step``).

Slot state is the stacked cache pytree from models.api.init_decode_state;
per-slot fill is a dynamic-update into the batch axis.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api
from repro.obs import Observability
from repro.serve import sampler
from repro.utils.logging import get_logger

log = get_logger("serve")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ArchConfig, params, batch_slots: int = 4,
                 max_seq: int = 128, seed: int = 0,
                 obs: Observability | None = None):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.key = jax.random.PRNGKey(seed)

        # same observability plane as the streaming runtime: prefill and
        # decode-tick latencies land in bounded histograms, spans cover
        # both jitted paths (fenced — decode is async-dispatched), and
        # request lifecycle goes to the structured event log
        self.obs = obs if obs is not None else Observability.create()
        self._prefill_hist = self.obs.registry.histogram("serve.prefill_s")
        self._decode_hist = self.obs.registry.histogram("serve.decode_tick_s")
        self._decode = jax.jit(api.decode_fn(cfg))
        self._prefill_one = jax.jit(self._make_prefill())
        self.state = api.init_decode_state(cfg, batch_slots, max_seq)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.slot_remaining = np.zeros(batch_slots, np.int32)
        self.queue: list[Request] = []
        # async decode plane (step_async): the last sampled token per
        # slot stays ON DEVICE so tick T+1 dispatches on tick T's
        # unforced future, and each tick's host copy retires one tick
        # late — the double-buffered dispatch idiom of the streaming
        # scheduler's AsyncStreamScheduler applied to LM decode
        self._last_tok = None           # (slots, 1) int32 device array
        self._pending: list[tuple] = []  # (toks future, snapshot, t0)

    # -- prefill -------------------------------------------------------------

    def _make_prefill(self):
        """Sequential prefill via the decode step (token-by-token through a
        scan) — shape-stable for any prompt padded to max_seq.  Production
        prefill uses the parallel path (api.prefill_fn), which the dry-run
        lowers; this engine variant keeps per-slot cache surgery trivial."""
        cfg = self.cfg
        decode = api.decode_fn(cfg)

        def prefill(params, state, prompt, length):
            def step(carry, tok):
                st, last = carry
                logits, st = decode(params, st, tok[None, None])
                return (st, logits[0, -1]), None

            (state, last_logits), _ = jax.lax.scan(
                step, (state, jnp.zeros((self.cfg.padded_vocab,))), prompt
            )
            del length
            return state, last_logits

        return prefill

    # -- queue management ------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)
        self.obs.events.emit("lm_submit", rid=req.rid,
                             prompt_tokens=len(req.prompt),
                             max_new=req.max_new_tokens)

    def _fill_slots(self) -> None:
        for slot in range(self.slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                with self.obs.trace.span("prefill", rid=req.rid,
                                         tokens=len(req.prompt)):
                    t0 = time.perf_counter()
                    st1 = api.init_decode_state(self.cfg, 1, self.max_seq)
                    st1, last_logits = self._prefill_one(
                        self.params, st1, jnp.asarray(req.prompt),
                        len(req.prompt)
                    )
                    tok = int(
                        sampler.greedy(last_logits[None], self.cfg.vocab)[0]
                    )
                    self._prefill_hist.record(time.perf_counter() - t0)
                req.out_tokens.append(tok)
                self._install(slot, st1)
                if self._last_tok is not None:
                    # keep the device-resident feedback token in sync so
                    # the next async dispatch feeds the prefill's token
                    self._last_tok = self._last_tok.at[slot, 0].set(tok)
                self.slot_req[slot] = req
                self.slot_remaining[slot] = req.max_new_tokens - 1
                self.obs.events.emit("lm_slot_fill", slot=slot, rid=req.rid,
                                     prompt_tokens=len(req.prompt))
                log.info("slot %d <- request %d (prompt %d toks)",
                         slot, req.rid, len(req.prompt))

    def _install(self, slot: int, st1) -> None:
        """Copy a 1-batch cache pytree into batch row ``slot``."""
        def put(full, one):
            if full.ndim == 0:
                return jnp.maximum(full, one)  # cache_len: shared scalar clock
            # find the batch axis: st1 has size-1 where full has slots
            for ax in range(full.ndim):
                if full.shape[ax] == self.slots and one.shape[ax] == 1:
                    idx = [slice(None)] * full.ndim
                    idx[ax] = slice(slot, slot + 1)
                    return full.at[tuple(idx)].set(one)
            return full

        self.state = jax.tree_util.tree_map(put, self.state, st1)

    # -- decode tick -----------------------------------------------------------

    def step(self) -> list[Request]:
        """One continuous-batching tick: fill slots, decode, retire."""
        self._fill_slots()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return []
        last = jnp.asarray(
            [
                (r.out_tokens[-1] if r is not None and r.out_tokens else 0)
                for r in self.slot_req
            ],
            jnp.int32,
        )[:, None]
        with self.obs.trace.span("decode", active=len(active)):
            t0 = time.perf_counter()
            logits, self.state = self._decode(self.params, self.state, last)
            # fence: decode is async-dispatched — without it the recorded
            # tick would measure enqueue latency, not the decode step
            toks = np.asarray(sampler.greedy(logits[:, -1], self.cfg.vocab))
            self._decode_hist.record(time.perf_counter() - t0)
        self.key, sk = jax.random.split(self.key)
        finished = []
        for slot in active:
            req = self.slot_req[slot]
            req.out_tokens.append(int(toks[slot]))
            self.slot_remaining[slot] -= 1
            if self.slot_remaining[slot] <= 0:
                req.done = True
                finished.append(req)
                self.slot_req[slot] = None
                self.obs.events.emit("lm_finish", rid=req.rid, slot=slot,
                                     tokens=len(req.out_tokens))
        return finished

    def run_until_drained(self, max_ticks: int = 1000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not self.queue and all(r is None for r in self.slot_req):
                break
        return done

    # -- async decode (double-buffered ticks) ---------------------------------

    def step_async(self) -> list[Request]:
        """One *pipelined* continuous-batching tick: dispatch tick T on
        tick T-1's device-resident sampled tokens (``sampler.greedy`` is
        pure jnp, so the token feedback loop never leaves the device),
        and retire tick T-1's host copy while T executes.  Requests
        finish one call later than with ``step`` but with bit-identical
        tokens — slot retirement timing is static (``slot_remaining``
        counts down at dispatch), so continuous batching still refills
        slots at the same ticks.
        """
        self._fill_slots()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if active:
            if self._last_tok is None:
                self._last_tok = jnp.asarray(
                    [
                        (r.out_tokens[-1]
                         if r is not None and r.out_tokens else 0)
                        for r in self.slot_req
                    ],
                    jnp.int32,
                )[:, None]
            with self.obs.trace.span("decode_dispatch", active=len(active)):
                t0 = time.perf_counter()
                logits, self.state = self._decode(
                    self.params, self.state, self._last_tok
                )
                toks = sampler.greedy(logits[:, -1], self.cfg.vocab)
                self._last_tok = toks[:, None].astype(jnp.int32)
            # bookkeeping happens at dispatch — retirement counts are
            # static — but the token lands at retire, one tick later
            snapshot = []
            for slot in active:
                req = self.slot_req[slot]
                self.slot_remaining[slot] -= 1
                finishing = self.slot_remaining[slot] <= 0
                snapshot.append((slot, req, finishing))
                if finishing:
                    self.slot_req[slot] = None  # refill next tick
            self._pending.append((toks, snapshot, t0))
        finished: list[Request] = []
        # depth-1 pipeline: retire once a newer tick is executing (or
        # when idle, to drain)
        while self._pending and (len(self._pending) > 1 or not active):
            finished.extend(self._retire_tick())
        return finished

    def _retire_tick(self) -> list[Request]:
        """Fence on the oldest in-flight tick and append its host-side
        tokens; emits ``lm_finish`` for requests that completed there."""
        toks, snapshot, t0 = self._pending.pop(0)
        with self.obs.trace.span("decode_retire", n=len(snapshot)):
            toks_h = np.asarray(toks)  # fence + one bulk transfer
        self._decode_hist.record(time.perf_counter() - t0)
        finished = []
        for slot, req, finishing in snapshot:
            req.out_tokens.append(int(toks_h[slot]))
            if finishing:
                req.done = True
                finished.append(req)
                self.obs.events.emit("lm_finish", rid=req.rid, slot=slot,
                                     tokens=len(req.out_tokens))
        return finished

    def shutdown(self) -> list[Request]:
        """Retire every in-flight decode tick (the engine half of the
        async drain contract: nothing stays unfolded at teardown)."""
        finished: list[Request] = []
        while self._pending:
            finished.extend(self._retire_tick())
        return finished

    def run_until_drained_async(self, max_ticks: int = 1000
                                ) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step_async())
            if (not self.queue and not self._pending
                    and all(r is None for r in self.slot_req)):
                break
        done.extend(self.shutdown())
        return done
