"""Test config: CPU-only, 1 visible device (the dry-run sets its own
XLA_FLAGS in a separate process; tests must NOT see 512 fake devices)."""
import os

# deterministic, quiet
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "")


def pytest_configure(config):
    # hard-watchdog marker for the concurrency suite: enforced by
    # pytest-timeout where installed (CI installs requirements-dev.txt);
    # registered here so environments without the plugin don't warn
    config.addinivalue_line(
        "markers", "timeout(seconds): abort the test after N seconds "
        "(pytest-timeout; inert when the plugin is absent)")
