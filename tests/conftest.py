"""Test config: CPU-only, 1 visible device (the dry-run sets its own
XLA_FLAGS in a separate process; tests must NOT see 512 fake devices)."""
import os

# deterministic, quiet
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "")
