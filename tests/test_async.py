"""Concurrency suite for the async execution plane (stream.async_plane).

The async scheduler's whole claim is that it changes WHEN host work runs
— never WHAT it computes.  This suite proves it:

  * interleaving property test: random schedules of ragged pushes / hop
    steps / joins / closes / peeks / drains (through grows, shrinks and —
    sharded — rebalances) executed on the synchronous and asynchronous
    schedulers with a controllable fake clock must produce bit-identical
    results: close logits/frames/samples, detection events, detector
    hysteresis state, peeks, and the event-log lifecycle;
  * race stress test: N producer threads feed the ingest pump while hops
    are in flight — no sample lost, duplicated, or torn (the arena's
    monotone ``samples_in`` reconciles exactly against pushes, closes
    reconcile against the offline executor on the full byte stream, and
    the seqlock generation guard never admits a torn read);
  * drain/close with a hop in flight retires the future and runs the
    ghost end-of-stream flush (regression vs the offline executor);
  * trace invariants: under overlap the old "spans tile the hop" sum
    double counts wall time, so ``coverage(mode="overlap")`` uses
    interval unions; the device ∩ pack(N+1) overlap is *reported*
    (``overlap_stats``), not flagged.

Event-log note: with the ingest pump enabled, push *timing* (and hence
``mass_join`` batching granularity) is inherently racy, so the
deterministic property tests run with ``use_pump=False`` (pushes land
synchronously, schedules are exactly reproducible); the pump gets its
own stress + error-surfacing coverage.
"""
import dataclasses
import faulthandler
import threading

import jax
import numpy as np
import pytest

from repro.core import compiler, executor
from repro.models import kws
from repro.obs import Observability, coverage, overlap_stats
from repro.stream import AsyncStreamScheduler, StreamScheduler

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without dev extras: seeded sweep still runs
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def smoke():
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(spec, weights, thresholds)
    return spec, weights, thresholds, prog


def _offline(prog, x):
    return executor.Executor(prog).run(x[:, None]).output.ravel()


_prog_cache: dict[int, object] = {}


def _offline_n(smoke, codes: np.ndarray) -> np.ndarray:
    """Offline-executor logits for an utterance of ANY length: the
    compiled program's input geometry is static, so recompile the same
    spec/weights at ``len(codes)`` (cached per length) and run it —
    the oracle a stream closed after ``len(codes)`` samples must match."""
    spec, weights, thresholds, _prog = smoke
    n = len(codes)
    prog = _prog_cache.get(n)
    if prog is None:
        prog = compiler.compile_model(
            dataclasses.replace(spec, in_len=n), weights, thresholds)
        _prog_cache[n] = prog
    return _offline(prog, codes)


def _audio(sid: int, pos: int, n: int) -> np.ndarray:
    """Deterministic per-(sid, position) sample codes: any schedule that
    feeds stream ``sid`` its samples in order feeds identical bytes, so
    sync/async runs and the offline oracle all see the same stream."""
    idx = np.arange(pos, pos + n, dtype=np.uint64)
    return ((idx * 2654435761 + sid * 97003) % 251).astype(np.uint8)


class FakeClock:
    """Controllable monotone clock for deterministic hop stamps: every
    read ticks by ``tick`` (so span ordering mirrors call ordering
    exactly), and tests can ``advance`` it arbitrarily."""

    def __init__(self, tick: float = 1e-4) -> None:
        self.t = 0.0
        self.tick = tick

    def __call__(self) -> float:
        self.t += self.tick
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# ---------------------------------------------------------------------------
# Interleaving property test: sync == async for arbitrary schedules
# ---------------------------------------------------------------------------

_MAX_STREAMS = 8


def _run_schedule(cls, smoke, ops, **kw):
    """Interpret one schedule on a fresh scheduler; returns the full
    observable fingerprint (close results, peeks, detector digests,
    lifecycle events)."""
    spec, weights, thresholds, _prog = smoke
    obs = Observability.create(mirror_events=False)
    hop_cap = 64  # per-sid feed ceiling, in hops (bounds the inbox)
    kwargs = dict(capacity=_MAX_STREAMS, initial_capacity=2, min_capacity=2,
                  obs=obs, clock=FakeClock())
    if cls is AsyncStreamScheduler:
        kwargs["use_pump"] = False  # deterministic landing (see module doc)
    kwargs.update(kw)
    sched = cls(spec, weights, thresholds, **kwargs)
    hop = sched.plan.hop_samples
    limit = hop * hop_cap
    if sched._inbox_samples < limit:  # pragma: no cover - config guard
        limit = sched._inbox_samples
    fed: dict[int, int] = {}
    live: list[int] = []
    fingerprints: dict[int, tuple] = {}
    peeks: list[tuple] = []
    for op in ops:
        kind = op[0]
        if kind == "join":
            if len(live) < _MAX_STREAMS:
                sid = sched.add_stream()
                live.append(sid)
                fed[sid] = 0
        elif kind == "push" and live:
            sid = live[op[1] % len(live)]
            n = op[2] * (hop // 2 + 1)  # ragged: never a hop multiple
            if fed[sid] + n <= limit:
                sched.push_audio_batch([sid], [_audio(sid, fed[sid], n)])
                fed[sid] += n
        elif kind == "step":
            sched.step_batch()
        elif kind == "drain":
            sched.drain()
        elif kind == "peek" and live:
            sid = live[op[1] % len(live)]
            peeks.append((sid, sched.peek(sid).tobytes()))
        elif kind == "close" and live:
            sid = live.pop(op[1] % len(live))
            fingerprints[sid] = _close_fp(sched.close_stream(sid))
    sched.drain()
    digests = {
        sid: sched._detector.state_digest(sched._streams[sid].slot)
        for sid in live
    }
    for sid in list(live):
        fingerprints[sid] = _close_fp(sched.close_stream(sid))
    if isinstance(sched, AsyncStreamScheduler):
        assert sched.in_flight == 0
        sched.shutdown()
    return {
        "fp": fingerprints,
        "peeks": peeks,
        "fed": fed,
        "digests": digests,
        "events": obs.events.tail(),
        "resizes": sched.metrics.resize_count,
        "rebalances": sched.metrics.rebalances,
    }


def _close_fp(r) -> tuple:
    return (
        r.logits.tobytes(), r.frames, r.samples,
        tuple((d.cls, d.frame, d.score) for d in r.events),
    )


def _lifecycle(events, kinds=("join", "detection", "close")):
    """Per-sid ordered lifecycle + the global resize/rebalance/mass_join
    sequences — the event-log facts that must survive the async plane
    (global detection-vs-join interleaving is schedule-timing, per-sid
    ordering and barrier-pinned sequences are semantics)."""
    per_sid: dict[int, list] = {}
    for rec in events:
        if rec["event"] in kinds and "sid" in rec:
            per_sid.setdefault(rec["sid"], []).append(
                (rec["event"],
                 tuple(sorted((k, v) for k, v in rec.items()
                              if k in ("cls", "frame", "score", "frames",
                                       "samples", "events"))))
            )
    resizes = [(r["old"], r["new"]) for r in events if r["event"] == "resize"]
    mass = [r["n"] for r in events if r["event"] == "mass_join"]
    counts: dict[str, int] = {}
    for rec in events:
        counts[rec["event"]] = counts.get(rec["event"], 0) + 1
    return per_sid, resizes, mass, counts


def _assert_equiv(smoke, ops, **kw):
    sync = _run_schedule(StreamScheduler, smoke, ops, **kw)
    asyn = _run_schedule(AsyncStreamScheduler, smoke, ops, **kw)
    assert sync["fed"] == asyn["fed"]  # the interpreter fed both alike
    assert sync["fp"] == asyn["fp"], "close results diverged"
    assert sync["peeks"] == asyn["peeks"], "peeks diverged"
    assert sync["digests"] == asyn["digests"], "detector state diverged"
    assert sync["resizes"] == asyn["resizes"]
    assert sync["rebalances"] == asyn["rebalances"]
    assert _lifecycle(sync["events"]) == _lifecycle(asyn["events"])
    return sync


def _seeded_schedule(seed: int, n_ops: int = 60) -> list[tuple]:
    rng = np.random.default_rng(seed)
    ops: list[tuple] = [("join",), ("join",)]
    kinds = ["push", "push", "push", "step", "join", "close", "peek",
             "drain"]
    for _ in range(n_ops):
        k = kinds[int(rng.integers(0, len(kinds)))]
        ops.append((k, int(rng.integers(0, 64)), int(rng.integers(1, 4))))
    ops += [("drain",)]
    return ops


def test_interleaving_property_seeded(smoke):
    """Seeded schedule sweep (always runs, even without hypothesis):
    sync == async == offline through joins, ragged pushes, closes, peeks
    and at least one grow + one shrink."""
    spec, _w, _t, _prog = smoke
    grew = shrank = False
    checked_offline = 0
    for seed in range(4):
        sync = _assert_equiv(smoke, _seeded_schedule(seed, n_ops=50))
        # every closed stream that saw audio also matches the offline
        # executor on the exact bytes it was fed (the whole-utterance
        # program compiles at any length — bit-exactness end-to-end)
        for sid, n in sync["fed"].items():
            if n == 0:
                continue
            ref = _offline_n(smoke, _audio(sid, 0, n))
            got = np.frombuffer(sync["fp"][sid][0], np.int64)
            np.testing.assert_array_equal(got, ref)
            checked_offline += 1
        resizes = [(r["old"], r["new"]) for r in sync["events"]
                   if r["event"] == "resize"]
        grew = grew or any(new > old for old, new in resizes)
        shrank = shrank or any(new < old for old, new in resizes)
    assert grew and shrank, "sweep never exercised grow+shrink barriers"
    assert checked_offline > 0, "no stream was long enough for the oracle"


if HAVE_HYPOTHESIS:
    _op = st.one_of(
        st.tuples(st.just("push"), st.integers(0, 63), st.integers(1, 3)),
        st.tuples(st.just("step"), st.just(0), st.just(0)),
        st.tuples(st.just("join"), st.just(0), st.just(0)),
        st.tuples(st.just("close"), st.integers(0, 63), st.just(0)),
        st.tuples(st.just("peek"), st.integers(0, 63), st.just(0)),
        st.tuples(st.just("drain"), st.just(0), st.just(0)),
    )

    @settings(max_examples=10, deadline=None)
    @given(ops=st.lists(_op, min_size=6, max_size=40))
    def test_interleaving_property_hypothesis(smoke, ops):
        """Hypothesis-driven schedules (shrinks the failing schedule to a
        minimal op list on mismatch).  Skipped where hypothesis isn't
        installed; the seeded sweep above always runs."""
        _assert_equiv(smoke, [("join",), ("join",)] + list(ops) +
                      [("drain",)])


# ---------------------------------------------------------------------------
# Race stress: producer threads vs in-flight hops
# ---------------------------------------------------------------------------

@pytest.mark.timeout(300)
def test_ingest_pump_race_stress(smoke):
    """4 producer threads push ragged chunks through the pump while the
    main thread keeps hops in flight.  Every sample must land exactly
    once and untorn: the arena's monotone per-slot ``samples_in``
    reconciles against what each producer pushed, the close-time logits
    reconcile against the offline executor on the full byte stream, and
    lock-free seqlock readers never observe an inconsistent window."""
    faulthandler.dump_traceback_later(240, exit=True)
    try:
        spec, weights, thresholds, prog = smoke
        n_threads, sids_per, chunks_per = 4, 2, 30
        n = n_threads * sids_per
        sched = AsyncStreamScheduler(
            spec, weights, thresholds, capacity=n, initial_capacity=n,
            min_capacity=n, inbox_samples=8192,
            obs=Observability.create(mirror_events=False),
        )
        sids = [sched.add_stream() for _ in range(n)]
        pushed = {sid: 0 for sid in sids}

        def producer(t: int) -> None:
            rng = np.random.default_rng(1000 + t)
            mine = sids[t * sids_per:(t + 1) * sids_per]
            for _ in range(chunks_per):
                for sid in mine:
                    k = int(rng.integers(20, 180))
                    sched.push_audio_batch(
                        [sid], [_audio(sid, pushed[sid], k)]
                    )
                    pushed[sid] += k  # thread-local sid: no write race

        stop = threading.Event()
        violations: list = []

        def checker() -> None:
            arena = sched._arena
            while not stop.is_set():
                wr, rd = arena.read_consistent(
                    lambda: (arena.wr.copy(), arena.rd.copy())
                )
                fill = wr - rd
                if (fill < 0).any() or (fill > arena.capacity_samples).any():
                    violations.append((wr, rd))  # torn read admitted
                    return

        threads = [threading.Thread(target=producer, args=(t,))
                   for t in range(n_threads)]
        chk = threading.Thread(target=checker, daemon=True)
        for th in threads:
            th.start()
        chk.start()
        while any(th.is_alive() for th in threads):
            sched.step_batch()  # keep hops in flight under the pushes
        for th in threads:
            th.join()
        stop.set()
        chk.join(timeout=30)
        sched.drain()  # flushes the pump, retires in-flight hops
        assert not violations, "seqlock admitted a torn read"
        assert sched._arena.generation % 2 == 0  # no writer left open
        # exact reconcile: monotone per-slot counters vs producer truth
        for sid in sids:
            slot = sched._streams[sid].slot
            assert int(sched._arena.samples_in[slot]) == pushed[sid], sid
        # content reconcile: the flushed stream == offline on the exact
        # byte sequence — samples landed once, in order, untorn
        for sid in sids:
            r = sched.close_stream(sid)
            assert r.samples == pushed[sid]
            np.testing.assert_array_equal(
                r.logits, _offline_n(smoke, _audio(sid, 0, pushed[sid])))
        sched.shutdown()
    finally:
        faulthandler.cancel_dump_traceback_later()


def test_pump_surfaces_push_errors(smoke):
    """A pumped push to an unknown sid fails on the worker thread; the
    error surfaces at the next flush (and the pump keeps working)."""
    spec, weights, thresholds, _prog = smoke
    sched = AsyncStreamScheduler(
        spec, weights, thresholds, capacity=2, initial_capacity=2,
        min_capacity=2, obs=Observability.create(mirror_events=False),
    )
    sid = sched.add_stream()
    sched.push_audio(9999, np.zeros(8, np.uint8))  # unknown sid
    with pytest.raises(KeyError, match="9999"):
        sched.flush_ingest()
    sched.push_audio(sid, _audio(sid, 0, 64))
    sched.flush_ingest()  # error was consumed; valid pushes still land
    assert int(sched._arena.samples_in[sched._streams[sid].slot]) == 64
    sched.shutdown()


def test_arena_seqlock_parity(smoke):
    """Failed (validated-out) arena ops leave the generation untouched;
    successful mutations bump it by exactly 2 (odd only mid-write)."""
    from repro.stream import RingArena
    arena = RingArena(2, 16)
    g0 = arena.generation
    assert g0 % 2 == 0
    with pytest.raises(MemoryError):
        arena.push(0, np.zeros(32, np.uint8))  # overflow: rejected clean
    assert arena.generation == g0
    arena.push(0, np.zeros(8, np.uint8))
    assert arena.generation == g0 + 2
    out = arena.read_consistent(lambda: arena.fill_of(0))
    assert out == 8


# ---------------------------------------------------------------------------
# drain()/close with a hop in flight
# ---------------------------------------------------------------------------

def test_drain_retires_inflight_hops(smoke):
    """``drain()`` must flush the pump and retire in-flight futures:
    after it, nothing is unfolded and peeks match the offline prefix."""
    spec, weights, thresholds, prog = smoke
    sched = AsyncStreamScheduler(
        spec, weights, thresholds, capacity=2, initial_capacity=2,
        min_capacity=2, obs=Observability.create(mirror_events=False),
    )
    sid = sched.add_stream()
    plan = sched.plan
    total = plan.prime_samples + 5 * plan.hop_samples
    sched.push_audio(sid, _audio(sid, 0, total))
    sched.flush_ingest()
    sched.step_batch()  # primes + dispatches hop 1 — stays in flight
    assert sched.in_flight == 1
    hops = sched.drain()
    assert sched.in_flight == 0
    assert hops >= 4  # the remaining buffered hops all executed
    np.testing.assert_array_equal(
        sched.peek(sid), _offline_n(smoke, _audio(sid, 0, total)))
    sched.shutdown()


def test_close_with_hop_in_flight_matches_offline(smoke):
    """Regression for the drain/teardown contract: closing a stream
    while its hop is still executing must retire the future, fold it,
    then run the ghost end-of-stream flush — byte-identical to the
    offline executor over everything pushed (including a sub-hop
    tail)."""
    spec, weights, thresholds, prog = smoke
    sched = AsyncStreamScheduler(
        spec, weights, thresholds, capacity=2, initial_capacity=2,
        min_capacity=2, obs=Observability.create(mirror_events=False),
    )
    sid = sched.add_stream()
    plan = sched.plan
    total = plan.prime_samples + 3 * plan.hop_samples + 7  # ragged tail
    sched.push_audio(sid, _audio(sid, 0, total))
    sched.flush_ingest()
    sched.step_batch()
    sched.step_batch()
    assert sched.in_flight >= 1  # a hop really is mid-air
    r = sched.close_stream(sid)
    assert sched.in_flight == 0
    assert r.samples == total
    np.testing.assert_array_equal(
        r.logits, _offline_n(smoke, _audio(sid, 0, total)))
    sched.shutdown()


# ---------------------------------------------------------------------------
# Trace invariants under overlap
# ---------------------------------------------------------------------------

def test_coverage_overlap_mode_synthetic():
    """Pinned interval math: overlapping phases double count under the
    tile invariant but union-coverage stays exact, and ``overlap_stats``
    reports the host∩device overlap."""
    spans = [
        # hop 1: pack 0-1, device 1-9 (retired late), fold 9-10
        {"name": "hop", "t0": 0.0, "dur_s": 10.0},
        {"name": "pack", "t0": 0.0, "dur_s": 1.0},
        {"name": "device", "t0": 1.0, "dur_s": 8.0},
        {"name": "detector", "t0": 9.0, "dur_s": 1.0},
        # hop 2's pack+dispatch run INSIDE hop 1's device span
        {"name": "hop", "t0": 2.0, "dur_s": 12.0},
        {"name": "pack", "t0": 2.0, "dur_s": 1.0},
        {"name": "device", "t0": 3.0, "dur_s": 10.0},
        {"name": "detector", "t0": 13.0, "dur_s": 1.0},
    ]
    tile = coverage(spans, phases=("pack", "device", "detector"))
    assert tile == pytest.approx(22.0 / 22.0)
    ov = coverage(spans, phases=("pack", "device", "detector"),
                  mode="overlap")
    assert ov == pytest.approx(1.0)  # unions: no double count, no gap
    stats = overlap_stats(spans)
    # hop2 pack [2,3] ⊂ device union [1,13]; hop1 detector [9,10] too
    assert stats["hidden"] == pytest.approx(2.0)
    assert stats["host_total"] == pytest.approx(4.0)
    assert stats["hidden_frac"] == pytest.approx(0.5)
    assert stats["utilization"] == pytest.approx(12.0 / 14.0)
    # a missing phase still sinks union coverage below the floor
    gappy = [s for s in spans if s["name"] != "device"]
    assert coverage(gappy, phases=("pack", "device", "detector"),
                    mode="overlap") < 0.5


def test_async_trace_overlap_invariants(smoke):
    """Deterministic (fake-clock) async run: each hop's phases still
    tile its own span, union coverage holds the 95% floor, and the
    device ∩ pack(N+1) overlap is reported as hidden wall — the PR 6
    tile assert's overlap-aware replacement."""
    spec, weights, thresholds, _prog = smoke
    obs = Observability.create(mirror_events=False)
    sched = AsyncStreamScheduler(
        spec, weights, thresholds, capacity=4, initial_capacity=4,
        min_capacity=4, obs=obs, clock=FakeClock(), use_pump=False,
        inbox_samples=1 << 13,
    )
    plan = sched.plan
    sids = [sched.add_stream() for _ in range(4)]
    total = plan.prime_samples + 16 * plan.hop_samples
    sched.push_audio_batch(sids, [_audio(s, 0, total) for s in sids])
    sched.drain()
    spans = obs.trace.spans()
    assert coverage(spans) >= 0.95  # per-hop tiling still holds
    ov = coverage(spans, mode="overlap")
    assert 0.95 <= ov <= 1.0 + 1e-9, ov
    stats = overlap_stats(spans)
    # pipelined: every pack but the first ran under an in-flight device
    # span, every fold but the last did too — reported, not flagged
    assert stats["hidden"] > 0.0
    assert stats["hidden_frac"] >= 0.8, stats
    assert sched.metrics.overlap_summary()["hidden_frac"] >= 0.8
    # the synchronous scheduler's trace reports no hidden wall
    obs2 = Observability.create(mirror_events=False)
    sync = StreamScheduler(
        spec, weights, thresholds, capacity=4, initial_capacity=4,
        min_capacity=4, obs=obs2, clock=FakeClock(),
        inbox_samples=1 << 13,
    )
    sids = [sync.add_stream() for _ in range(4)]
    sync.push_audio_batch(sids, [_audio(s, 0, total) for s in sids])
    sync.drain()
    assert sync.metrics.overlap_summary()["hidden_ms"] == 0.0
    assert coverage(obs2.trace.spans(), mode="overlap") >= 0.95


# ---------------------------------------------------------------------------
# Sharded epoch barriers (runs on the CI multi-device leg)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >=2 devices (multi-device CI leg)")
def test_async_sharded_rebalance_barrier(smoke):
    """Cross-shard rebalance as an epoch barrier: skewed closes under a
    mesh trigger a migration on both schedulers at the same boundary,
    and every surviving stream stays bit-exact through it."""
    from repro.launch.mesh import make_stream_mesh
    spec, weights, thresholds, prog = smoke
    mesh = make_stream_mesh()
    S = jax.device_count()
    n = 2 * S

    def run(cls, **kw):
        sched = cls(spec, weights, thresholds, capacity=2 * n,
                    initial_capacity=n, min_capacity=S, mesh=mesh,
                    obs=Observability.create(mirror_events=False), **kw)
        plan = sched.plan
        sids = [sched.add_stream() for _ in range(n)]
        half = plan.prime_samples + 3 * plan.hop_samples
        for sid in sids:
            sched.push_audio(sid, _audio(sid, 0, half))
        sched.drain()
        # close the low half: shards 0..S/2 empty out -> skew -> migrate
        out = {sid: _close_fp(sched.close_stream(sid))
               for sid in sids[:n // 2]}
        for sid in sids[n // 2:]:
            sched.push_audio(sid, _audio(sid, half, 2 * plan.hop_samples))
        sched.drain()
        out.update({sid: _close_fp(sched.close_stream(sid))
                    for sid in sids[n // 2:]})
        if isinstance(sched, AsyncStreamScheduler):
            sched.shutdown()
        return out, sched.metrics.rebalances

    sync_out, sync_reb = run(StreamScheduler)
    asyn_out, asyn_reb = run(AsyncStreamScheduler, use_pump=False)
    assert sync_out == asyn_out
    assert sync_reb == asyn_reb >= 1, "rebalance barrier never exercised"
    # offline oracle over the full fed stream for one migrated survivor
    sid = max(asyn_out)
    n_fed = asyn_out[sid][2]
    np.testing.assert_array_equal(
        np.frombuffer(asyn_out[sid][0], np.int64),
        _offline_n(smoke, _audio(sid, 0, n_fed)))


# ---------------------------------------------------------------------------
# LM engine: double-buffered decode
# ---------------------------------------------------------------------------

def test_engine_async_decode_bit_exact():
    """``Engine.step_async`` (device-resident token feedback, one-tick
    deferred host copy) produces token-identical outputs to the
    synchronous tick loop, through slot refills and shutdown drain."""
    from repro.configs.base import get_arch
    from repro.models import api
    from repro.serve.engine import Engine, Request

    cfg = get_arch("qwen3-0.6b", smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    def run(async_mode):
        eng = Engine(cfg, params, batch_slots=2, max_seq=32,
                     obs=Observability.create(mirror_events=False))
        for i in range(5):
            eng.submit(Request(rid=i,
                               prompt=np.arange(6, dtype=np.int32) + i,
                               max_new_tokens=3))
        done = (eng.run_until_drained_async() if async_mode
                else eng.run_until_drained())
        assert not eng._pending
        return {r.rid: list(r.out_tokens) for r in done}

    sync, asyn = run(False), run(True)
    assert sync == asyn and set(sync) == set(range(5))
