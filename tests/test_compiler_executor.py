"""Compiler + executor: placement invariants, WREP rotation, QAT equivalence,
PWB fusion, ping-pong discipline — on the reduced (smoke) KWS model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler, executor, isa, macro, pingpong
from repro.models import kws


@pytest.fixture(scope="module")
def smoke_prog():
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(spec, weights, thresholds)
    return spec, params, prog


def test_chunking_covers_all_channels(smoke_prog):
    spec, _, prog = smoke_prog
    for b in prog.bindings:
        if not b.chunks:
            continue
        cout = b.spec.cout
        covered = sorted((c.ch0, c.ch1) for c in b.chunks if c.row0_w == 0)
        assert covered[0][0] == 0 and covered[-1][1] == cout
        for (a0, a1), (b0, b1) in zip(covered, covered[1:]):
            assert a1 == b0, "chunks must tile the channel range"
        assert all(c.pairs <= macro.N_SA for c in b.chunks)


def test_placement_no_overlap(smoke_prog):
    _, _, prog = smoke_prog
    owner = np.full((macro.N_ROWS, macro.N_PAIRS), -1)
    for page in prog.cim.pages.values():
        region = owner[page.row0:page.row0 + page.rows,
                       page.pair0:page.pair0 + page.pairs]
        assert (region == -1).all(), f"page {page.page_id} overlaps"
        region[...] = page.page_id


def test_program_structure(smoke_prog):
    _, _, prog = smoke_prog
    ops = [isa.opcode(w) for w in prog.words]
    assert ops[-1] == isa.OP_HALT
    assert ops[0] == isa.OP_PTR
    # every MAC is preceded (possibly through WREPs/MACs) by a PTR
    assert isa.OP_MAC in ops


def test_executor_matches_qat(smoke_prog):
    spec, params, prog = smoke_prog
    rng = np.random.default_rng(0)
    for i in range(3):
        x = rng.integers(0, 256, (spec.in_len, 1)).astype(np.uint8)
        rep = executor.Executor(prog).run(x)
        qat = np.asarray(kws.kws_forward(params, jnp.array(x[:, 0]), spec))
        np.testing.assert_array_equal(
            rep.output.ravel().astype(np.float64), qat.astype(np.float64)
        )


def test_pwb_fusion_saves_cycles_same_result(smoke_prog):
    spec, _, prog = smoke_prog
    x = np.random.default_rng(1).integers(0, 256, (spec.in_len, 1)).astype(np.uint8)
    fused = executor.Executor(prog, fuse_pool=True).run(x)
    unfused = executor.Executor(prog, fuse_pool=False).run(x)
    np.testing.assert_array_equal(fused.output, unfused.output)
    assert fused.ledger.cycles < unfused.ledger.cycles


def test_energy_ledger_sane(smoke_prog):
    spec, _, prog = smoke_prog
    x = np.zeros((spec.in_len, 1), np.uint8)
    rep = executor.Executor(prog).run(x)
    led = rep.ledger
    assert led.macs == spec.total_macs
    assert led.energy_j > 0 and led.latency_s > 0
    assert led.tops_per_w > 0


def test_rotation_correctness():
    """Force rotation on the smoke model and check results are unchanged
    (mis-scheduled WREPs would corrupt activations)."""
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(1), spec)
    weights, thresholds = kws.export_kws(params, spec)
    base = compiler.compile_model(spec, weights, thresholds)
    # rotate the widest layer's chunks explicitly
    biggest = max(
        (c for b in base.bindings for c in b.chunks),
        key=lambda c: c.weights,
    )
    rot = compiler.compile_model(spec, weights, thresholds,
                                 rotate_hints=(biggest.name,))
    assert any(c.rotating for b in rot.bindings for c in b.chunks)
    assert any(isa.opcode(w) == isa.OP_WREP for w in rot.words)
    x = np.random.default_rng(2).integers(0, 256, (spec.in_len, 1)).astype(np.uint8)
    out_base = executor.Executor(base).run(x).output
    out_rot = executor.Executor(rot).run(x).output
    np.testing.assert_array_equal(out_base, out_rot)


def test_pingpong_bank_discipline():
    a = pingpong.FmapRef(0, 100, 32, "bits")          # bank 0
    b = pingpong.FmapRef(4096, 100, 32, "bits")       # bank 2
    pingpong.PingPongSRAM.check_layer(a, b)
    c = pingpong.FmapRef(50, 100, 32, "bits")         # overlaps a's bank
    with pytest.raises(MemoryError):
        pingpong.PingPongSRAM.check_layer(a, c)


def test_pingpong_roundtrip():
    s = pingpong.PingPongSRAM()
    rng = np.random.default_rng(3)
    ref_bits = pingpong.FmapRef(100, 33, 17, "bits")
    bits = rng.integers(0, 2, (33, 17)).astype(np.uint8)
    s.write_bits(ref_bits, bits)
    np.testing.assert_array_equal(s.read_bits(ref_bits), bits)
    ref_u8 = pingpong.FmapRef(3000, 10, 7, "u8")
    vals = rng.integers(0, 256, (10, 7)).astype(np.uint8)
    s.write_u8(ref_u8, vals)
    np.testing.assert_array_equal(s.read_u8(ref_u8), vals)


def test_flexible_beats_fixed_pingpong():
    """Fig. 5(c): a >128Kb feature map hosted by flexible allocation but not
    by the conventional fixed-half scheme."""
    big_ifm = pingpong.FmapRef(0, 5000, 32, "bits")       # 5000 w, banks 0-2
    small_ofm = pingpong.FmapRef(6144, 2000, 32, "bits")  # 2000 w, bank 3
    fixed = pingpong.FixedPingPong()
    assert not fixed.fits(big_ifm, small_ofm)
    pingpong.PingPongSRAM.check_layer(big_ifm, small_ofm)  # flexible: fine


def test_weight_sram_capacity_enforced():
    ws = macro.WeightSRAM()
    with pytest.raises(MemoryError):
        ws.store(0, np.ones((1024, 512), np.int8))  # 1Mb > 512Kb
