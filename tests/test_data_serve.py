"""Data pipeline determinism + serving engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.data import gscd, lm_data
from repro.models import api
from repro.serve import sampler
from repro.serve.engine import Engine, Request


def test_lm_data_deterministic_and_host_sharded():
    cfg = lm_data.DataConfig(vocab=1000, seq_len=16, global_batch=8)
    b1 = lm_data.batch_at(cfg, 3)
    b2 = lm_data.batch_at(cfg, 3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = lm_data.batch_at(cfg, 4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # host sharding partitions the batch deterministically and disjointly
    h0 = lm_data.batch_at(
        lm_data.DataConfig(vocab=1000, seq_len=16, global_batch=8,
                           n_hosts=2, host_id=0), 3)
    h1 = lm_data.batch_at(
        lm_data.DataConfig(vocab=1000, seq_len=16, global_batch=8,
                           n_hosts=2, host_id=1), 3)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_lm_data_labels_are_shifted_tokens():
    cfg = lm_data.DataConfig(vocab=1000, seq_len=16, global_batch=4)
    b = lm_data.batch_at(cfg, 0)
    assert b["tokens"].shape == b["labels"].shape
    assert (b["tokens"] >= 0).all() and (b["tokens"] < 1000).all()


def test_gscd_shapes_and_determinism():
    x, y = gscd.batch(seed=0, step=1, batch_size=6)
    assert x.shape == (6, 16000) and x.dtype == np.uint8
    assert y.shape == (6,) and set(np.unique(y)) <= set(range(12))
    x2, y2 = gscd.batch(seed=0, step=1, batch_size=6)
    np.testing.assert_array_equal(x, x2)
    np.testing.assert_array_equal(y, y2)
    # silence class is quiet
    xs = gscd.sample(np.random.default_rng(0), 11)
    assert np.abs(xs.astype(int) - 128).mean() < 12


def test_sampler_masks_padded_vocab():
    logits = jnp.zeros((2, 100))
    logits = logits.at[:, 99].set(10.0)  # padding column
    tok = sampler.greedy(logits, vocab=90)
    assert (np.asarray(tok) < 90).all()
    key = jax.random.PRNGKey(0)
    tok2 = sampler.sample(key, logits, vocab=90, temperature=1.0, top_k=5)
    assert (np.asarray(tok2) < 90).all()


def test_engine_continuous_batching():
    cfg = get_arch("qwen3-0.6b", smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, batch_slots=2, max_seq=32)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=np.arange(6, dtype=np.int32) + i,
                           max_new_tokens=3))
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 3 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out_tokens)


def test_engine_greedy_deterministic():
    cfg = get_arch("qwen3-0.6b", smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    outs = []
    for _ in range(2):
        eng = Engine(cfg, params, batch_slots=1, max_seq=32)
        eng.submit(Request(rid=0, prompt=np.arange(6, dtype=np.int32),
                           max_new_tokens=4))
        outs.append(eng.run_until_drained()[0].out_tokens)
    assert outs[0] == outs[1]
