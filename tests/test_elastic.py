"""Elastic checkpoint restore: save sharded on mesh A, restore onto mesh B.

Runs in a subprocess so it can set XLA_FLAGS for 4 host devices without
polluting the main test process (which must keep seeing 1 device).
"""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import tempfile
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    try:
        from jax.sharding import AxisType
        kw = {"axis_types": (AxisType.Auto,) * 2}
    except ImportError:
        kw = {}
    from repro.train import checkpoint as ckpt

    mesh_a = jax.make_mesh((4, 1), ("data", "model"), **kw)
    mesh_b = jax.make_mesh((2, 2), ("data", "model"), **kw)

    tree = {
        "w": jax.device_put(
            jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            NamedSharding(mesh_a, P("data", None))),
        "m": jax.device_put(jnp.ones((4, 8), jnp.bfloat16),
                            NamedSharding(mesh_a, P(None, None))),
    }
    d = tempfile.mkdtemp()
    ckpt.save(d, 3, tree)

    shardings_b = {
        "w": NamedSharding(mesh_b, P("data", "model")),
        "m": NamedSharding(mesh_b, P(None, "model")),
    }
    step, restored, _ = ckpt.restore(d, tree, shardings=shardings_b)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(64).reshape(8, 8))
    assert restored["w"].sharding.mesh.shape["model"] == 2
    assert restored["w"].sharding.is_equivalent_to(shardings_b["w"], 2)
    print("ELASTIC_OK")
""")


def test_elastic_reshard_across_meshes():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
