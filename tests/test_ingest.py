"""Vectorized ingest plane (stream.state.RingArena + scheduler hot path):
arena push/pop/pack semantics incl. wraparound and boundary validation,
batched pushes == sequential pushes, the slot-vectorized detector ==
the per-stream state machine, scheduler sid errors, and the property-style
bit-exactness sweep (random ragged float/u8 chunks, B in {1, 8, 64},
across one grow + one shrink) against the offline executor."""
import jax
import numpy as np
import pytest

from repro.core import compiler, executor
from repro.models import kws
from repro.stream import (
    AudioFrontend,
    BatchedDetector,
    DetectorConfig,
    PosteriorDetector,
    RingArena,
    StreamScheduler,
    quantize_pcm,
)
from repro.stream.detector import _softmax


@pytest.fixture(scope="module")
def smoke():
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(spec, weights, thresholds)
    return spec, weights, thresholds, prog


def _offline(prog, x):
    return executor.Executor(prog).run(x[:, None]).output.ravel()


# ---------------------------------------------------------------------------
# RingArena semantics
# ---------------------------------------------------------------------------

def test_arena_push_pop_wraparound():
    arena = RingArena(3, 7)  # tiny so pointers lap the region many times
    rng = np.random.default_rng(0)
    fed = {s: [] for s in range(3)}
    drained = {s: [] for s in range(3)}
    for i in range(40):
        slot = i % 3
        free = 7 - arena.fill_of(slot)
        chunk = rng.integers(
            0, 256, min(free, int(rng.integers(1, 5)))
        ).astype(np.uint8)
        arena.push(slot, chunk)
        fed[slot].append(chunk)
        n = min(arena.fill_of(slot), int(rng.integers(1, 6)))
        drained[slot].append(arena.pop(slot, n))
    for s in range(3):
        drained[s].append(arena.pop(s, arena.fill_of(s)))
        np.testing.assert_array_equal(
            np.concatenate(fed[s]), np.concatenate(drained[s])
        )
    assert arena.fill().tolist() == [0, 0, 0]
    # monotonic counters, wrapped storage
    assert (arena.rd == arena.wr).all() and (arena.wr > 7).all()


def test_arena_over_underflow():
    arena = RingArena(2, 4)
    arena.push(0, np.ones(3, np.uint8))
    with pytest.raises(MemoryError):
        arena.push(0, np.ones(2, np.uint8))
    with pytest.raises(MemoryError):
        arena.pop(0, 4)
    with pytest.raises(MemoryError):
        arena.pack_hops(np.array([0, 1]), 2)  # slot 1 holds nothing
    assert arena.fill_of(0) == 3  # failed ops leave the arena intact


def test_arena_push_boundary_validation():
    """Satellite: malformed audio is rejected AT the push boundary with a
    clear error, not silently widened like the old (n, 1) int32 rings."""
    arena = RingArena(2, 16)
    with pytest.raises(ValueError, match=r"out of u8 range"):
        arena.push(0, np.array([0, 300], np.int32))
    with pytest.raises(ValueError, match=r"out of u8 range"):
        arena.push(0, np.array([-1, 5], np.int64))
    with pytest.raises(TypeError, match=r"float PCM or integer u8"):
        arena.push(0, np.array([True, False]))
    with pytest.raises(ValueError, match=r"unique"):
        arena.push_batch(np.array([1, 1]), [np.ones(1, np.uint8)] * 2)
    assert arena.fill().tolist() == [0, 0]  # nothing landed
    # in-range non-uint8 integers are fine (offline clips arrive as such)
    arena.push(0, np.array([0, 128, 255], np.int64))
    assert arena.pop(0, 3).tolist() == [0, 128, 255]
    # the arena stores u8, 4x smaller than the old int32 rings
    assert arena.data.dtype == np.uint8
    assert arena.pack_hops(np.array([], np.int64), 4).dtype == np.int32


def test_arena_push_batch_matches_sequential():
    """One vectorized quantize+scatter == per-stream pushes, with float
    PCM and u8 codes mixed in the same call and per-slot gains honored."""
    rng = np.random.default_rng(1)
    a = RingArena(5, 64)
    b = RingArena(5, 64)
    for arena in (a, b):
        arena.set_gain(2, 0.5)
        arena.set_gain(4, 2.0)
    chunks = [
        rng.integers(0, 256, 7).astype(np.uint8),
        rng.uniform(-1.2, 1.2, 9),                      # float64, clips
        rng.uniform(-1, 1, 5).astype(np.float32),       # gain 0.5
        np.zeros(0, np.uint8),                          # empty is legal
        rng.uniform(-1, 1, 11),                         # gain 2.0
    ]
    a.push_batch(np.arange(5), chunks)
    for slot, c in enumerate(chunks):
        b.push(slot, c)
    np.testing.assert_array_equal(a.data, b.data)
    assert a.fill().tolist() == b.fill().tolist() == [7, 9, 5, 0, 11]
    np.testing.assert_array_equal(
        a.peek(2), quantize_pcm(chunks[2], 0.5).astype(np.int32)
    )


def test_arena_pack_hops_gathers_and_consumes():
    arena = RingArena(4, 8)
    arena.push_batch(
        np.array([0, 2, 3]),
        [np.full(6, 9, np.uint8), np.arange(5, dtype=np.uint8),
         np.full(3, 7, np.uint8)],
    )
    ready = np.nonzero(arena.ready_mask(4))[0]
    assert ready.tolist() == [0, 2]
    out = arena.pack_hops(ready, 4)
    assert out.shape == (4, 4) and out.dtype == np.int32
    assert out[0].tolist() == [9, 9, 9, 9]
    assert out[2].tolist() == [0, 1, 2, 3]
    assert out[1].tolist() == out[3].tolist() == [0, 0, 0, 0]  # masked rows
    assert arena.fill().tolist() == [2, 0, 1, 3]  # hop consumed
    # wrapped second hop continues seamlessly
    arena.push(2, np.array([5, 6, 7], np.uint8))
    np.testing.assert_array_equal(arena.pack_hops(np.array([2]), 4)[2],
                                  [4, 5, 6, 7])


def test_frontend_facade_over_shared_arena():
    """The per-stream AudioFrontend API is a view of one shared arena."""
    arena = RingArena(3, 32)
    f1 = AudioFrontend(arena=arena, slot=1)
    f1.push(np.array([1, 2, 3], np.uint8))
    assert len(f1) == 3 and f1.samples_in == 3
    assert arena.fill().tolist() == [0, 3, 0]
    np.testing.assert_array_equal(f1.peek_all(), [1, 2, 3])
    np.testing.assert_array_equal(f1.pop(2), [1, 2])
    assert f1.pop_all().tolist() == [3] and len(f1) == 0
    # standalone construction still works (private 1-row arena)
    f2 = AudioFrontend()
    f2.push(np.zeros(4, np.uint8))
    assert len(f2) == 4


# ---------------------------------------------------------------------------
# BatchedDetector == PosteriorDetector
# ---------------------------------------------------------------------------

def test_batched_detector_matches_per_stream():
    """The slot-vectorized state machine is bit-identical to one
    PosteriorDetector per stream: same events (frame/cls/score), same
    hysteresis/refractory behavior, window longer than numpy's pairwise
    threshold to pin the summation-order contract."""
    cfg = DetectorConfig(smooth_frames=5, on_threshold=0.3,
                         off_threshold=0.15, refractory_frames=4)
    n_cls, n_streams = 12, 3
    batched = BatchedDetector(8, n_cls, cfg)
    slots = np.array([1, 4, 6])
    refs = [PosteriorDetector(i, cfg) for i in range(n_streams)]
    rng = np.random.default_rng(5)
    got: dict[int, list] = {i: [] for i in range(n_streams)}
    for frame in range(60):
        posts = np.stack([_softmax(rng.normal(0, 6, n_cls))
                          for _ in range(n_streams)])
        frames = np.full(n_streams, frame)
        rows, cls, score = batched.update_batch(slots, frames, posts)
        for r, c, sc in zip(rows, cls, score):
            got[int(r)].append((frame, int(c), float(sc)))
        for i, ref in enumerate(refs):
            ref.update_posterior(frame, posts[i])
    fired_any = False
    for i, ref in enumerate(refs):
        want = [(e.frame, e.cls, e.score) for e in ref.events]
        assert got[i] == want  # bitwise: scores compare exactly
        fired_any |= bool(want)
    assert fired_any  # the random walk actually exercised the machine


def test_batched_detector_remap_carries_state():
    """apply_remap moves a slot's window/hold/refractory state with it —
    continuing on the new slot equals an uninterrupted reference."""
    cfg = DetectorConfig(smooth_frames=3, on_threshold=0.3,
                         off_threshold=0.15, refractory_frames=4)
    n_cls = 12
    batched = BatchedDetector(4, n_cls, cfg)
    ref = PosteriorDetector(0, cfg)
    rng = np.random.default_rng(9)
    events = []
    for frame in range(30):
        if frame == 11:  # mid-run shrink: slot 3 -> 1
            batched.apply_remap({3: 1, 0: 0}, 2)
        slot = 3 if frame < 11 else 1
        post = _softmax(rng.normal(0, 6, n_cls))
        rows, cls, score = batched.update_batch(
            np.array([slot]), np.array([frame]), post[None, :]
        )
        if rows.size:
            events.append((frame, int(cls[0]), float(score[0])))
        ref.update_posterior(frame, post)
    assert events == [(e.frame, e.cls, e.score) for e in ref.events]
    assert events  # state machine fired across the remap


# ---------------------------------------------------------------------------
# Scheduler: sid errors + batched API
# ---------------------------------------------------------------------------

def test_push_audio_unknown_sid_raises_keyerror(smoke):
    """Satellite: pushing to an unknown/ended sid raises KeyError naming
    the live sid set, on both the scalar and the batched entry point."""
    spec, weights, thresholds, _ = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=4)
    a, b = sched.add_stream(), sched.add_stream()
    with pytest.raises(KeyError, match=r"unknown.*sid 99.*2 live.*0.*1"):
        sched.push_audio(99, np.zeros(8, np.uint8))
    sched.push_audio(b, np.zeros(8, np.uint8))
    sched.close_stream(b)  # ended: its sid must now be rejected too
    with pytest.raises(KeyError, match=r"sid 1"):
        sched.push_audio(b, np.zeros(8, np.uint8))
    with pytest.raises(KeyError, match=r"sid 1"):
        sched.push_audio_batch([a, b], [np.zeros(4, np.uint8)] * 2)
    with pytest.raises(KeyError):
        sched.close_stream(b)
    assert len(sched._streams[a].frontend) == 0  # batch push was atomic


def test_step_batch_columnar_matches_step_tuples(smoke):
    """HopBatch (the zero-collation hot-path result) carries exactly what
    the tuple-per-stream step() API reports."""
    spec, weights, thresholds, _ = smoke
    a = StreamScheduler(spec, weights, thresholds, capacity=4)
    b = StreamScheduler(spec, weights, thresholds, capacity=4)
    rng = np.random.default_rng(21)
    clips = rng.integers(0, 256, (3, 600)).astype(np.uint8)
    for sched in (a, b):
        sids = [sched.add_stream() for _ in range(3)]
        sched.push_audio_batch(sids, list(clips))
    outs = a.run_until_starved()
    hops = []
    while True:
        hb = b.step_batch()
        if hb is None:
            break
        hops.append(hb)
    flat = [
        (int(sid), int(fr), hb.logits[r])
        for hb in hops
        for r, (sid, fr) in enumerate(zip(hb.sids, hb.frames))
    ]
    assert len(outs) == len(flat)
    for (sid_a, fr_a, lg_a, _), (sid_b, fr_b, lg_b) in zip(outs, flat):
        assert (sid_a, fr_a) == (sid_b, fr_b)
        np.testing.assert_array_equal(lg_a, lg_b)
    m = b.metrics.summary()
    assert m["host_pack_ms_p50"] >= 0.0
    assert m["step_ms_p50"] >= m["host_pack_ms_p50"]
    assert m["device_ms_p50"] > 0.0


def test_push_audio_batch_coalesces_duplicate_sids(smoke):
    """Satellite: a sid appearing multiple times in one batch coalesces
    (arrival order, float/u8 dtypes preserved per chunk) instead of
    tripping RingArena.push_batch's unique-slots ValueError — and the
    result is bit-identical to sequential pushes."""
    spec, weights, thresholds, _ = smoke
    a = StreamScheduler(spec, weights, thresholds, capacity=4)
    b = StreamScheduler(spec, weights, thresholds, capacity=4)
    rng = np.random.default_rng(33)
    f0 = rng.uniform(-1.0, 1.0, 37)                    # float PCM
    u1 = rng.integers(0, 256, 21).astype(np.uint8)     # u8 codes
    u0 = rng.integers(0, 256, 13).astype(np.uint8)
    f0b = rng.uniform(-1.0, 1.0, 9).astype(np.float32)
    for sched in (a, b):
        s0, s1 = sched.add_stream(), sched.add_stream()
    a.push_audio_batch([s0, s1, s0, s0], [f0, u1, u0, f0b])
    for sid, chunk in ((s0, f0), (s1, u1), (s0, u0), (s0, f0b)):
        b.push_audio(sid, chunk)
    np.testing.assert_array_equal(a._arena.data, b._arena.data)
    assert a._arena.fill().tolist() == b._arena.fill().tolist()
    # chunk accounting stays arrival-accurate through the coalesce
    assert a._arena.chunks_in.tolist() == b._arena.chunks_in.tolist()
    assert a._arena.total_chunks_in == b._arena.total_chunks_in == 4
    # and the streams compute identically from here
    outs_a, outs_b = a.run_until_starved(), b.run_until_starved()
    assert len(outs_a) == len(outs_b)
    for (sa, fa, la, _), (sb, fb, lb, _) in zip(outs_a, outs_b):
        assert (sa, fa) == (sb, fb)
        np.testing.assert_array_equal(la, lb)
    # malformed dtypes are still rejected on the coalesce path
    with pytest.raises(TypeError, match=r"float PCM or integer u8"):
        a.push_audio_batch([s0, s0], [np.array([True]), np.array([False])])


def test_push_counters_fold_without_per_sid_python(smoke):
    """Satellite: push-side counters accumulate in slot-indexed arena
    arrays and fold into the metrics at hop boundaries (fleet totals) and
    at close (per-stream) — the push path never walks per-sid counter
    objects."""
    spec, weights, thresholds, _ = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=4)
    plan = sched.plan
    sids = [sched.add_stream() for _ in range(3)]
    n = plan.prime_samples + 2 * plan.hop_samples
    rng = np.random.default_rng(8)
    clips = rng.integers(0, 256, (3, n)).astype(np.uint8)
    sched.push_audio_batch(sids, list(clips))          # 1 chunk each
    sched.push_audio(sids[0], clips[0][:5])            # +1 chunk, +5 samples
    assert sched.metrics.summary()["samples_pushed"] == 0.0  # no hop yet
    sched.drain()
    m = sched.metrics.summary()
    assert m["samples_pushed"] == float(3 * n + 5)
    assert m["chunks_pushed"] == 4.0
    res = sched.close_stream(sids[0])
    c = sched.metrics.streams[sids[0]]
    assert c.samples_in == n + 5 and c.chunks_in == 2
    assert res.samples == n + 5


def test_step_batch_profile_has_no_per_sid_python(smoke):
    """Satellite: the steady-state hop's python call count must not scale
    with the number of streams — profile one hop at B=4 and B=32 and
    demand identical call counts (any per-sid loop would add ~B calls)."""
    import cProfile
    import pstats

    spec, weights, thresholds, _ = smoke

    def profile_one_hop(B):
        cfg = DetectorConfig(on_threshold=2.0)  # nothing ever fires
        sched = StreamScheduler(spec, weights, thresholds, capacity=B,
                                initial_capacity=B, min_capacity=B,
                                detector_cfg=cfg)
        plan = sched.plan
        rng = np.random.default_rng(B)
        sids = [sched.add_stream() for _ in range(B)]
        warm = plan.prime_samples + plan.hop_samples
        audio = rng.integers(0, 256, (B, warm + plan.hop_samples)
                             ).astype(np.uint8)
        sched.push_audio_batch(sids, list(audio[:, :warm]))
        sched.drain()  # primes + traces the jitted step at this capacity
        sched.push_audio_batch(sids, list(audio[:, warm:]))
        prof = cProfile.Profile()
        prof.enable()
        batch = sched.step_batch()
        prof.disable()
        assert batch is not None and batch.sids.size == B
        stats = pstats.Stats(prof)
        for (_, _, name), (_, nc, *_rest) in stats.stats.items():
            # nothing that smells per-sid may appear at all
            assert "_require" not in name and "fill_of" not in name, name
        return sum(nc for (_, nc, *_r) in stats.stats.values())

    assert profile_one_hop(4) == profile_one_hop(32)


# ---------------------------------------------------------------------------
# Property-style bit-exactness sweep: ragged mixed-dtype chunks, elastic pool
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_streams,emit", [(1, True), (8, True), (64, False)])
def test_random_chunks_bitexact_across_grow_and_shrink(smoke, n_streams, emit):
    """Feed random-sized chunks (1..hop*3 samples, float PCM and u8 codes
    mixed, batched and scalar pushes mixed) through the arena path while
    the elastic pool grows once and shrinks once; every finalized logit
    must equal one whole-clip offline run."""
    spec, weights, thresholds, prog = smoke
    rng = np.random.default_rng(100 + n_streams)
    # float PCM is the source of truth; the offline run eats the codes the
    # arena's quantizer produces, so both paths see identical u8 streams
    pcm = rng.uniform(-1.1, 1.1, (n_streams, spec.in_len))
    codes = quantize_pcm(pcm)
    want = {j: _offline(prog, codes[j]) for j in range(n_streams)}

    cap0 = max(1, n_streams // 4)
    sched = StreamScheduler(
        spec, weights, thresholds, capacity=n_streams,
        initial_capacity=cap0, min_capacity=1, emit_logits=emit,
        inbox_samples=1024,  # small inbox: arena pointers wrap in-run
    )
    hop = sched.plan.hop_samples
    # first quarter joins early; the rest join mid-run to force a grow
    joined = [sched.add_stream() for _ in range(cap0)]
    pos = {j: 0 for j in joined}
    round_i = 0
    while any(p < spec.in_len for p in pos.values()):
        if round_i == 2 and len(joined) < n_streams:
            for j in range(len(joined), n_streams):
                assert sched.add_stream() == j
                joined.append(j)
                pos[j] = 0
        live = [j for j in joined if pos[j] < spec.in_len]
        sids, chunks = [], []
        for j in live:
            n = int(rng.integers(1, hop * 3 + 1))
            lo, hi = pos[j], min(pos[j] + n, spec.in_len)
            # mix dtypes: float PCM chunks and u8 code chunks interleave
            chunk = pcm[j, lo:hi] if rng.random() < 0.5 else codes[j, lo:hi]
            pos[j] = hi
            if rng.random() < 0.3:
                sched.push_audio(j, chunk)  # scalar path
            else:
                sids.append(j)
                chunks.append(chunk)
        if sids:
            sched.push_audio_batch(sids, chunks)
        sched.run_until_starved()
        round_i += 1
    grew_to = sched.capacity
    assert grew_to == n_streams or n_streams == 1
    # close three quarters -> the pool shrinks; survivors then flush too
    survivors = joined[-max(1, n_streams // 4):]
    for j in joined:
        if j in survivors:
            continue
        np.testing.assert_array_equal(sched.close_stream(j).logits, want[j])
    assert sched.capacity <= grew_to
    if n_streams > 1:
        assert sched.capacity < grew_to  # actually shrank
    for j in survivors:
        np.testing.assert_array_equal(sched.close_stream(j).logits, want[j])
    caps = [c for _, c in sched.metrics.capacity_events]
    if n_streams > 1:
        assert max(caps) == n_streams and caps[-1] < n_streams
