"""32-bit ISA: encode/decode roundtrips (property-based) + structure."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import isa

pow2 = st.sampled_from([1, 2, 4, 8])


@settings(max_examples=100, deadline=None)
@given(
    fuse=st.booleans(),
    ltype=st.integers(0, 1),
    k=st.integers(0, 31),
    stride=pow2,
    cin=st.integers(1, 64).map(lambda g: g * 16),
    cout=st.integers(1, 32).map(lambda g: g * 16),
    bitser=pow2,
    wpage=st.integers(0, 15),
    pool=pow2,
    outmode=st.integers(0, 1),
)
def test_mac_roundtrip(fuse, ltype, k, stride, cin, cout, bitser, wpage, pool,
                       outmode):
    mi = isa.MacInstr(fuse=fuse, ltype=ltype, k=k, stride=stride, cin=cin,
                      cout=cout, bitser=bitser, wpage=wpage, pool=pool,
                      outmode=outmode)
    word = mi.encode()
    assert 0 <= word < 2**32
    assert isa.opcode(word) == isa.OP_MAC
    assert isa.MacInstr.decode(word) == mi


@settings(max_examples=100, deadline=None)
@given(
    row_start=st.integers(0, 1023),
    n_rows=st.integers(0, 1023),
    wsram_page=st.integers(0, 511),
)
def test_wrep_roundtrip(row_start, n_rows, wsram_page):
    wi = isa.WrepInstr(row_start=row_start, n_rows=n_rows,
                       wsram_page=wsram_page)
    assert isa.WrepInstr.decode(wi.encode()) == wi
    assert isa.opcode(wi.encode()) == isa.OP_WREP


@settings(max_examples=100, deadline=None)
@given(
    ifm=st.integers(0, isa.MAX_ADDR - 1),
    ofm=st.integers(0, isa.MAX_ADDR - 1),
)
def test_ptr_roundtrip(ifm, ofm):
    pi = isa.PtrInstr(ifm_addr=ifm, ofm_addr=ofm)
    assert isa.PtrInstr.decode(pi.encode()) == pi


def test_halt_and_dispatch():
    assert isinstance(isa.decode(isa.HaltInstr().encode()), isa.HaltInstr)
    with pytest.raises(ValueError):
        isa.decode(0b111 << 29)


def test_field_overflow_rejected():
    with pytest.raises(ValueError):
        isa.MacInstr(k=32).encode()
    with pytest.raises(ValueError):
        isa.MacInstr(stride=3).encode()  # not a power of two
    with pytest.raises(ValueError):
        isa.WrepInstr(row_start=1024, n_rows=1, wsram_page=0).encode()


def test_program_decode_stops_at_halt():
    words = [
        isa.PtrInstr(0, 4096).encode(),
        isa.MacInstr().encode(),
        isa.HaltInstr().encode(),
        isa.MacInstr().encode(),  # junk past halt
    ]
    prog = isa.decode_program(words)
    assert len(prog) == 3
    assert isinstance(prog[-1], isa.HaltInstr)
    text = isa.disassemble(words)
    assert "HALT" in text and "PTR" in text and "MAC" in text
