"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _rand_dense(m, k, n):
    x = RNG.integers(0, 2, (m, k)).astype(np.uint32)
    w = RNG.integers(-1, 2, (k, n)).astype(np.int32)
    thr = RNG.normal(0, 3, (n,)).astype(np.float32)
    flip = RNG.integers(0, 2, (n,)).astype(bool)
    return jnp.array(x), jnp.array(w), jnp.array(thr), jnp.array(flip)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 32, 16),     # minimal
        (7, 100, 12),    # unaligned everything
        (64, 1024, 128), # macro-shaped: full wordline contraction
        (33, 513, 65),   # prime-ish
    ],
)
def test_twm_matmul_raw_and_sa(m, k, n):
    x, w, thr, flip = _rand_dense(m, k, n)
    raw = ops.twm_linear(x, w, mode="raw")
    np.testing.assert_array_equal(np.asarray(raw),
                                  np.asarray(ref.ref_twm_matmul(x, w)))
    sa = ops.twm_linear(x, w, thr, flip, mode="sa")
    np.testing.assert_array_equal(
        np.asarray(sa), np.asarray(ref.ref_twm_matmul_sa(x, w, thr, flip))
    )


@pytest.mark.parametrize("m,k,n", [(5, 64, 20), (16, 256, 64)])
def test_twm_matmul_mxu_path(m, k, n):
    x, w, thr, flip = _rand_dense(m, k, n)
    got = ops.twm_linear_mxu(x, w, thr, flip)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.ref_twm_matmul_sa(x, w, thr, flip))
    )


@pytest.mark.parametrize(
    "l,cin,cout,k,stride,pad,pool",
    [
        (40, 8, 16, 3, 1, 1, 1),
        (100, 24, 40, 3, 1, 1, 2),
        (64, 16, 20, 5, 1, 2, 4),
        (128, 32, 48, 7, 2, 3, 1),
        (200, 64, 128, 3, 1, 1, 2),   # KWS-block-like
        (33, 8, 12, 2, 2, 0, 1),      # even kernel, no pad
    ],
)
def test_bnn_conv1d_sweep(l, cin, cout, k, stride, pad, pool):
    x = jnp.array(RNG.integers(0, 2, (l, cin)), jnp.uint32)
    w = jnp.array(RNG.integers(-1, 2, (k, cin, cout)), jnp.int32)
    thr = jnp.array(RNG.normal(0, 2, (cout,)), jnp.float32)
    flip = jnp.array(RNG.integers(0, 2, (cout,)), bool)
    got = ops.bnn_conv1d(x, w, thr, flip, stride=stride, pad=pad, pool=pool)
    want = ref.ref_bnn_conv1d_sa(x, w, thr, flip, stride=stride, pad=pad,
                                 pool=pool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bnn_conv1d_raw():
    x = jnp.array(RNG.integers(0, 2, (50, 16)), jnp.uint32)
    w = jnp.array(RNG.integers(-1, 2, (3, 16, 24)), jnp.int32)
    got = ops.bnn_conv1d(x, w, stride=1, pad=1, mode="raw")
    want = ref.ref_bnn_conv1d(x, w, stride=1, pad=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits,offset,stride", [(8, 128, 8), (4, 8, 2)])
def test_bitserial_conv(bits, offset, stride):
    x = jnp.array(RNG.integers(0, 2**bits, (160, 1)), jnp.uint32)
    w = jnp.array(RNG.integers(-1, 2, (19, 1, 16)), jnp.int32)
    got = ops.bitserial_conv1d(x, w, bits=bits, offset=offset, stride=stride,
                               pad=9)
    want = ref.ref_bitserial_conv1d(x, w, bits=bits, offset=offset,
                                    stride=stride, pad=9)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitserial_matmul():
    x = jnp.array(RNG.integers(0, 256, (3, 96)), jnp.uint32)
    w = jnp.array(RNG.integers(-1, 2, (96, 12)), jnp.int32)
    want = ref.ref_bitserial_matmul(x, w, bits=8, offset=0)
    got = sum(
        (1 << b) * np.asarray(ops.twm_linear(((x >> b) & 1).astype(jnp.uint32),
                                             w, mode="raw"))
        for b in range(8)
    )
    np.testing.assert_array_equal(got, np.asarray(want))


def test_pick_path_heuristic():
    # tiny-batch (memory-bound) prefers popcount; big GEMM prefers MXU
    assert ops.pick_path(1, 1024, 512) == "popcount"
    assert ops.pick_path(65536, 1024, 4096) == "mxu"
