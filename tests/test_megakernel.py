"""Hop megakernel (kernels/hop_megakernel.py) oracle suite.

The fused-cascade backend must be bit-exact with the per-stage paths
(``jnp``, ``pallas``) and the offline executor across randomized plan
geometries — strides, pools, pool phases, bit-serial first layers, flush
geometry — including across elastic resize boundaries and on 1/2/8-shard
meshes; and its per-hop device-dispatch count must match the static
accounting (``_BatchedModel.dispatches_per_hop``) exactly.

Multi-shard cases need a forced multi-device host (see
tests/test_stream_sharded.py); they skip on a 1-device host.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler, executor
from repro.core.cnn_spec import CNN1DSpec, Conv1DSpec, FCSpec, GAPSpec
from repro.kernels import dispatch, ops, ref
from repro.launch.mesh import make_stream_mesh
from repro.models import kws
from repro.stream import StreamScheduler

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is optional
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def smoke():
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(spec, weights, thresholds)
    return spec, weights, thresholds, prog


def _offline(prog, x):
    return executor.Executor(prog).run(x[:, None]).output.ravel()


def _clip(spec, seed):
    return np.random.default_rng(seed).integers(
        0, 256, (spec.in_len,)
    ).astype(np.uint8)


def _mesh(n):
    if jax.device_count() < n:
        pytest.skip(
            f"needs {n} devices (XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n})"
        )
    return make_stream_mesh(n)


# ---------------------------------------------------------------------------
# Randomized plan geometries
# ---------------------------------------------------------------------------

def _random_spec(seed: int) -> CNN1DSpec | None:
    """A small random streamable spec: bit-serial first layer with random
    k/stride/pad, 1-2 tail conv blocks with random k/pad/pool (so pool
    phases, tails, and flush geometry all vary), GAP, binary fc, raw fc.
    Returns None when no hop_frames reaches a steady state (rare)."""
    rng = np.random.default_rng(seed)
    k0 = int(rng.integers(3, 13))
    s0 = int(rng.choice([2, 4, 8]))
    c0 = int(rng.choice([4, 8]))
    bits0 = int(rng.choice([4, 8]))
    layers = [
        Conv1DSpec(1, c0, k=k0, stride=s0, pad=int(rng.integers(0, k0)),
                   in_bits=bits0, in_offset=1 << (bits0 - 1),
                   name="l0"),
    ]
    cin = c0
    for j in range(int(rng.integers(1, 3))):
        k = int(rng.choice([3, 5]))
        cout = int(rng.choice([4, 8]))
        layers.append(
            Conv1DSpec(cin, cout, k=k, stride=1,
                       pad=int(rng.integers(0, k // 2 + 1)),
                       pool=int(rng.choice([1, 2, 2, 4])),  # isa: pow2 only
                       name=f"b{j + 1}")
        )
        cin = cout
    layers += [
        GAPSpec(cin, name="gap"),
        FCSpec(cin, 8, in_bits=8, name="fc1"),
        FCSpec(8, kws.N_CLASSES, out_raw=True, name="fc2"),
    ]
    spec = CNN1DSpec(in_len=int(rng.integers(500, 900)), in_channels=1,
                     in_bits=layers[0].in_bits, layers=tuple(layers),
                     name=f"rand{seed}")
    from repro.stream.state import plan_stream
    for hf in (1, 2, 3, 4, 6, 8, 12):
        try:
            plan = plan_stream(spec, hop_frames=hf)
        except ValueError:
            continue
        if spec.in_len >= plan.prime_samples + 3 * plan.hop_samples:
            return spec, hf
    return None


def _check_random_geometry(seed: int) -> None:
    """One randomized geometry: megakernel hop logits + peeks == jnp ==
    offline executor on the consumed prefix."""
    built = _random_spec(seed)
    if built is None:
        pytest.skip(f"seed {seed}: no steady-state hop geometry")
    spec, hf = built
    params = kws.init_kws_params(jax.random.PRNGKey(seed), spec)
    weights, thresholds = kws.export_kws(params, spec)
    # codes must fit the first layer's bit-serial precision: paths that
    # decompose into planes mask to in_bits, the dense path subtracts the
    # offset from the raw value — they agree iff codes < 2**in_bits
    x = np.random.default_rng(1000 + seed).integers(
        0, 1 << spec.in_bits, (spec.in_len,)
    ).astype(np.uint8)
    outs = {}
    for backend in ("jnp", "megakernel"):
        s = StreamScheduler(spec, weights, thresholds, capacity=2,
                            hop_frames=hf, backend=backend)
        a, b = s.add_stream(), s.add_stream()
        s.push_audio(a, x)
        s.push_audio(b, x[: int(0.7 * spec.in_len)])
        hops = s.run_until_starved()
        outs[backend] = (hops, np.asarray(s.peek(a)), np.asarray(s.peek(b)),
                         s.plan)
    hj, pja, pjb, plan = outs["jnp"]
    hm, pma, pmb, _ = outs["megakernel"]
    assert len(hj) == len(hm) >= 2
    for u, v in zip(hj, hm):
        assert u[:2] == v[:2]
        np.testing.assert_array_equal(u[2], v[2])
    np.testing.assert_array_equal(pja, pma)
    np.testing.assert_array_equal(pjb, pmb)
    # the fused finalize tail against the offline executor on the exact
    # prefix stream a has consumed (hop-boundary peek path)
    n_hops = sum(1 for u in hj if u[0] == 0)
    consumed = plan.prime_samples + n_hops * plan.hop_samples
    spec_l = dataclasses.replace(spec, in_len=consumed)
    prog_l = compiler.compile_model(spec_l, weights, thresholds)
    np.testing.assert_array_equal(pma, _offline(prog_l, x[:consumed]))


@pytest.mark.parametrize("seed", range(5))
def test_megakernel_random_geometry_oracle(seed):
    _check_random_geometry(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(seed=hyp_st.integers(min_value=5, max_value=10_000))
    def test_megakernel_hypothesis_geometry_oracle(seed):
        """Property form of the randomized-geometry oracle: any drawn seed
        (→ any streamable random geometry) must be fused-vs-reference
        bit-exact.  Runs only where hypothesis is installed; the seeded
        parametrization above always runs."""
        _check_random_geometry(seed)


# ---------------------------------------------------------------------------
# Smoke-spec equivalence: all three backends, per-stage pallas included
# ---------------------------------------------------------------------------

def test_megakernel_matches_all_backends(smoke):
    """Fused hop + fused emit tail + standalone finalize peek, against both
    per-stage backends on the KWS smoke spec."""
    spec, weights, thresholds, _ = smoke
    x = _clip(spec, 42)
    outs = {}
    for backend in ("jnp", "pallas", "megakernel"):
        s = StreamScheduler(spec, weights, thresholds, capacity=2,
                            hop_frames=4, backend=backend)
        a, b = s.add_stream(), s.add_stream()
        s.push_audio(a, x)
        s.push_audio(b, x[:600])
        hops = s.run_until_starved()
        outs[backend] = (hops, np.asarray(s.peek(a)), np.asarray(s.peek(b)))
    for backend in ("pallas", "megakernel"):
        hj, pja, pjb = outs["jnp"]
        hk, pka, pkb = outs[backend]
        assert len(hj) == len(hk) >= 1, backend
        for u, v in zip(hj, hk):
            assert u[:2] == v[:2], backend
            np.testing.assert_array_equal(u[2], v[2])
        np.testing.assert_array_equal(pja, pka)
        np.testing.assert_array_equal(pjb, pkb)


def test_megakernel_full_clip_matches_offline(smoke):
    """Close-out logits through the megakernel backend equal the offline
    executor on the whole clip."""
    spec, weights, thresholds, prog = smoke
    s = StreamScheduler(spec, weights, thresholds, capacity=2,
                        backend="megakernel")
    x = _clip(spec, 7)
    sid = s.add_stream()
    s.push_audio(sid, x)
    s.run_until_starved()
    res = s.close_stream(sid)
    np.testing.assert_array_equal(res.logits, _offline(prog, x))


def test_megakernel_grow_shrink_bitexact(smoke):
    """Streams fed across 4->8 grow and 8->4 shrink boundaries through the
    megakernel backend emit hop logits bit-identical to a pinned-capacity
    jnp scheduler (resize = pure pad/slice of fused-kernel state)."""
    spec, weights, thresholds, _ = smoke
    clips = {j: _clip(spec, 80 + j) for j in range(8)}
    el = StreamScheduler(spec, weights, thresholds, capacity=8,
                         initial_capacity=4, backend="megakernel")
    fx = StreamScheduler(spec, weights, thresholds, capacity=8,
                         initial_capacity=8, min_capacity=8, backend="jnp")

    def lockstep(stage):
        a, b = el.run_until_starved(), fx.run_until_starved()
        assert len(a) == len(b), stage
        for ea, eb in zip(a, b):
            assert ea[:2] == eb[:2], stage
            np.testing.assert_array_equal(ea[2], eb[2])

    sids_e = [el.add_stream() for _ in range(3)]
    sids_f = [fx.add_stream() for _ in range(3)]
    for j in range(3):
        el.push_audio(sids_e[j], clips[j][:400])
        fx.push_audio(sids_f[j], clips[j][:400])
    lockstep("pre-grow")
    sids_e += [el.add_stream() for _ in range(3)]  # forces 4 -> 8 grow
    sids_f += [fx.add_stream() for _ in range(3)]
    for j in range(6):
        el.push_audio(sids_e[j], clips[j][400:])
        fx.push_audio(sids_f[j], clips[j][400:])
    lockstep("post-grow")
    for j in range(5):  # occupancy 6 -> 1 triggers the 8 -> 4 shrink
        el.close_stream(sids_e[j])
        fx.close_stream(sids_f[j])
    assert el.capacity < 8  # shrank (elastic), fx stays pinned at 8
    el.push_audio(sids_e[5], clips[6])
    fx.push_audio(sids_f[5], clips[6])
    lockstep("post-shrink")


# ---------------------------------------------------------------------------
# Sharded meshes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", (1, 2, 8))
def test_megakernel_sharded_matches_unsharded(smoke, n_shards):
    """One fused launch per shard: the mesh megakernel scheduler is
    bit-exact with the single-device jnp scheduler."""
    spec, weights, thresholds, _ = smoke
    mesh = _mesh(n_shards)
    x = {j: _clip(spec, 90 + j) for j in range(8)}
    outs = {}
    for backend, m in (("jnp", None), ("megakernel", mesh)):
        s = StreamScheduler(spec, weights, thresholds, capacity=8,
                            initial_capacity=8, min_capacity=8,
                            hop_frames=2, backend=backend, mesh=m)
        sids = [s.add_stream() for _ in range(8)]
        for j, sid in enumerate(sids):
            s.push_audio(sid, x[j][: 600 + 64 * (j % 3)])
        hops = s.run_until_starved()
        outs[backend] = (hops, [np.asarray(s.peek(sid)) for sid in sids])
    def by_sid(hops):
        d = {}
        for sid, frame, logits, _post in hops:
            d.setdefault(sid, []).append((frame, logits))
        return d

    hj, pj = outs["jnp"]
    hm, pm = outs["megakernel"]
    assert len(hj) == len(hm) >= 1
    dj, dm = by_sid(hj), by_sid(hm)
    assert dj.keys() == dm.keys()
    for sid in dj:  # per-stream hop sequences match; cross-shard emit
        assert len(dj[sid]) == len(dm[sid])  # order may differ
        for (fa, la), (fb, lb) in zip(dj[sid], dm[sid]):
            assert fa == fb
            np.testing.assert_array_equal(la, lb)
    for a, b in zip(pj, pm):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Dispatch accounting: static per-hop figure == traced pallas_call count
# ---------------------------------------------------------------------------

def _traced_dispatches(sched, emit: bool) -> int:
    """pallas_calls captured by one fresh trace of the hop step."""
    m = sched._model
    plan = sched.plan
    B = sched.capacity
    args = (
        jnp.zeros((B, plan.hop_samples), jnp.int32),
        jnp.zeros((B,), bool),
        tuple(jnp.zeros((B, st.tail, st.cin), jnp.int32)
              for st in plan.convs),
        tuple(jnp.zeros((B, st.phase, st.cout), jnp.int32)
              for st in plan.convs),
        jnp.zeros((B, plan.gap_channels), jnp.int32),
    )
    jax.clear_caches()  # a jit cache hit would trace (and count) nothing
    with dispatch.counting() as traced:
        jax.eval_shape(lambda *a: m._step(*a, emit=emit), *args)
    return traced()


@pytest.mark.parametrize("backend", ("jnp", "pallas", "megakernel"))
def test_dispatches_per_hop_matches_trace(smoke, backend):
    """The static accounting surfaced in metrics/BENCH must equal the
    launches actually traced through kernels.dispatch — and the megakernel
    hits the fused target: ONE launch per hop, emit included."""
    spec, weights, thresholds, _ = smoke
    s = StreamScheduler(spec, weights, thresholds, capacity=2,
                        hop_frames=2, backend=backend)
    for emit in (False, True):
        static = s._model.dispatches_per_hop(emit)
        assert _traced_dispatches(s, emit) == static
    assert s._model.dispatches_per_hop(True) <= 2 or backend != "megakernel"
    if backend == "megakernel":
        assert s._model.dispatches_per_hop(True) == 1
    if backend == "jnp":
        assert s._model.dispatches_per_hop(True) == 0


def test_metrics_surface_dispatch_counts(smoke):
    """StreamMetrics carries the per-hop figure + running total into
    summary(), and the device trace span is annotated with it."""
    spec, weights, thresholds, _ = smoke
    s = StreamScheduler(spec, weights, thresholds, capacity=2,
                        backend="megakernel")
    sid = s.add_stream()
    s.push_audio(sid, _clip(spec, 3))
    hops = s.run_until_starved()
    assert len(hops) >= 2
    summ = s.metrics.summary()
    assert summ["device_dispatches_per_hop"] == 1.0
    assert summ["device_dispatches_total"] == float(s.metrics.steps)
    dev_spans = s.obs.trace.spans("device")
    assert dev_spans and all(
        sp["args"].get("dispatches") == 1 for sp in dev_spans
    )


# ---------------------------------------------------------------------------
# Satellite: single-launch bit-serial first layer (per-stage fallback path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,stride,pad", [(8, 8, 9), (4, 2, 0), (2, 4, 3)])
def test_bitserial_batched_single_dispatch(bits, stride, pad):
    """ops.bitserial_conv1d_batched accumulates every bit plane inside ONE
    pallas launch and matches the plane-looped reference exactly."""
    rng = np.random.default_rng(5)
    b, l, cin, cout, k = 3, 75, 2, 5, 7
    x = jnp.asarray(rng.integers(0, 1 << bits, (b, l, cin)), jnp.uint32)
    w = jnp.asarray(rng.integers(-1, 2, (k, cin, cout)), jnp.int32)
    offset = 1 << (bits - 1)
    jax.clear_caches()
    with dispatch.counting() as traced:
        got = ops.bitserial_conv1d_batched(
            x, w, bits=bits, offset=offset, stride=stride, pad=pad,
            interpret=True,
        )
    assert traced() == 1  # not `bits` separate launches
    for r in range(b):
        want = ref.ref_bitserial_conv1d(x[r], w, bits, offset=offset,
                                        stride=stride, pad=pad)
        np.testing.assert_array_equal(np.asarray(got[r]), np.asarray(want))
