"""Per-arch smoke tests (reduced configs): one train step + decode on CPU,
shape and finiteness assertions; prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, arch_names, get_arch
from repro.models import api, stack

B, S = 2, 16


def _batch(cfg):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.array(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.n_frontend_tokens:
        batch["frontend"] = jnp.zeros(
            (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "encdec":
        batch["frontend"] = jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", arch_names())
def test_arch_smoke_train_step(name):
    cfg = get_arch(name, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(api.loss_fn(cfg, remat="none"))(
        params, batch
    )
    assert jnp.isfinite(loss), name
    gnorm = sum(
        float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
        for g in jax.tree_util.tree_leaves(grads)
    )
    assert np.isfinite(gnorm) and gnorm > 0, name


@pytest.mark.parametrize("name", arch_names())
def test_arch_smoke_decode_step(name):
    cfg = get_arch(name, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    state = api.init_decode_state(cfg, B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, state2 = api.decode_fn(cfg)(params, state, tok)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), name
    assert int(state2["cache_len"]) == 1


@pytest.mark.parametrize("name", ["qwen3-0.6b", "jamba-1.5-large-398b",
                                  "xlstm-350m", "deepseek-moe-16b"])
def test_prefill_decode_consistency(name, monkeypatch):
    """decode after a step-by-step 'prefill' must match the parallel forward
    logits at the last position (cache semantics are coherent).

    MoE uses the exact dense dispatch here: capacity dropping depends on
    batch composition by design, so the dropping paths are not expected to
    be bitwise consistent between full-sequence and token-by-token runs."""
    from repro.models import moe
    monkeypatch.setattr(moe, "FORCE_IMPL", "dense")
    cfg = get_arch(name, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.array(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    full_logits, _ = stack.forward(cfg, params, toks, mode="train",
                                   remat="none")
    state = api.init_decode_state(cfg, 1, 16)
    dec = api.decode_fn(cfg)
    for t in range(8):
        logits, state = dec(params, state, toks[:, t:t + 1])
    np.testing.assert_allclose(
        np.asarray(logits[0, -1], np.float32),
        np.asarray(full_logits[0, -1], np.float32),
        rtol=0.06, atol=0.15,
    )


def test_input_specs_cover_all_cells():
    for name in arch_names():
        cfg = get_arch(name)
        for shape in SHAPES.values():
            if not cfg.supports(shape):
                assert shape.name == "long_500k"
                continue
            specs = cfg.input_specs(shape)
            assert "tokens" in specs
            b = shape.global_batch
            assert specs["tokens"].shape[0] == b


def test_long_context_flags():
    ok = {n for n in arch_names()
          if get_arch(n).supports(SHAPES["long_500k"])}
    assert ok == {"xlstm-350m", "jamba-1.5-large-398b"}


def test_ternary_quant_mode_runs():
    import dataclasses
    cfg = dataclasses.replace(get_arch("qwen3-0.6b", smoke=True),
                              quant_mode="ternary")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    loss = api.loss_fn(cfg, remat="none")(params, _batch(cfg))
    assert jnp.isfinite(loss)
    g = jax.grad(api.loss_fn(cfg, remat="none"))(params, _batch(cfg))
    assert all(
        bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
        for l in jax.tree_util.tree_leaves(g)
    )
