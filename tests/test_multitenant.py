"""Multi-tenant weight pool (stream/scheduler.WeightPool) oracle suite.

K complete model variants share one plan geometry and ONE batched hop
dispatch: each slot carries an int32 model index and the kernels gather
that slot-block's weight planes from a stacked ``(K, ...)`` pool.  The
bar is strict: a mixed-tenant batch must be bit-exact with K independent
single-tenant schedulers slot-for-slot — through ragged joins, closes,
elastic resizes, the async plane, and sharded meshes — while the traced
device-launch count stays K-independent (1 steady / <=2 emit hop on the
megakernel, exactly as the single-model scheduler).

Also covers the satellite surfaces: LRU admission/eviction with
refcounts, packed-plane memoization (``param_cache_stats``), idle jit
prewarm (post-grow hop has no compile event in the trace), and the
per-tenant metrics split (``tenant_summary``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler, executor
from repro.kernels import dispatch
from repro.launch.mesh import make_stream_mesh
from repro.models import kws
from repro.stream import (
    DEFAULT_MODEL,
    AsyncStreamScheduler,
    StreamScheduler,
    WeightPool,
    param_cache_stats,
)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is optional
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def spec():
    return kws.build_kws_smoke_spec()


@pytest.fixture(scope="module")
def variants(spec):
    """Four complete tenant variants of the same smoke geometry: distinct
    init seeds -> distinct ternary weights + SA thresholds."""
    out = {}
    for name, seed in [(DEFAULT_MODEL, 0), ("b", 7), ("c", 11), ("d", 13)]:
        params = kws.init_kws_params(jax.random.PRNGKey(seed), spec)
        out[name] = kws.export_kws(params, spec)
    return out


def _clip(spec, seed, n=None):
    return np.random.default_rng(seed).integers(
        0, 256, (n or spec.in_len,)
    ).astype(np.uint8)


def _mesh(n):
    if jax.device_count() < n:
        pytest.skip(
            f"needs {n} devices (XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n})"
        )
    return make_stream_mesh(n)


def _pooled(spec, variants, *, max_models=4, backend="megakernel", cls=None,
            **kw):
    w0, t0 = variants[DEFAULT_MODEL]
    s = (cls or StreamScheduler)(
        spec, w0, t0, max_models=max_models, tenant_block=2,
        backend=backend, **kw)
    for name in list(variants)[1:max_models]:
        s.register_model(name, *variants[name])
    return s


def _feed(s, sid, audio, chunk=320):
    for j in range(0, len(audio), chunk):
        s.push_audio(sid, audio[j:j + chunk])


def _drain(s):
    out = s.run_until_starved()
    if hasattr(s, "drain"):
        s.drain()
    return out


# ---------------------------------------------------------------------------
# Mixed-tenant bit-exactness: fused pool == K single-tenant schedulers
#                              == offline executor, per slot
# ---------------------------------------------------------------------------

def _check_mixed(spec, variants, seed, backend, cls=StreamScheduler,
                 mesh=None):
    """One randomized mixed-tenant scenario: K in {1,2,4} tenants, random
    per-stream binding, ragged clip lengths, a mid-scenario close wave
    (shrink pressure) and a second join wave (grow pressure).  Every
    surviving stream's peek/close logits must equal a single-tenant
    scheduler fed identically, and the close-out logits must equal the
    offline executor on the full clip."""
    rng = np.random.default_rng(seed)
    K = int(rng.choice([1, 2, 4]))
    names = list(variants)[:K]
    s = _pooled(spec, variants, max_models=max(K, 2), backend=backend,
                cls=cls, capacity=16, hop_frames=2, mesh=mesh)
    binding = [str(rng.choice(names)) for _ in range(6)]
    clips = [_clip(spec, 100 * seed + i, 480 + 160 * int(rng.integers(0, 4)))
             for i in range(6)]
    sids = [s.add_stream(model=m) for m in binding]
    for sid, a in zip(sids, clips):
        _feed(s, sid, a[: len(a) // 2])
    _drain(s)
    closed = {i: s.close_stream(sids[i]).logits
              for i in range(0, 6, 3)}  # ragged closes -> shrink pressure
    for i in range(6, 10):  # second wave -> grow pressure
        binding.append(str(rng.choice(names)))
        clips.append(_clip(spec, 100 * seed + i, 640))
        sids.append(s.add_stream(model=binding[i]))
        _feed(s, sids[i], clips[i])
    for i in range(6):
        if i not in closed:
            _feed(s, sids[i], clips[i][len(clips[i]) // 2:])
    _drain(s)
    results = dict(closed)
    for i in range(10):
        if i not in results:
            results[i] = s.close_stream(sids[i]).logits
    if hasattr(s, "shutdown"):
        s.shutdown()
    # oracle 1: one single-tenant scheduler per stream, fed identically
    for i in range(10):
        w, t = variants[binding[i]]
        consumed = len(clips[i]) if i not in closed else len(clips[i]) // 2
        ref = StreamScheduler(spec, w, t, capacity=4, hop_frames=2,
                              backend="jnp")
        sid = ref.add_stream()
        _feed(ref, sid, clips[i][:consumed])
        ref.run_until_starved()
        np.testing.assert_array_equal(
            results[i], ref.close_stream(sid).logits,
            err_msg=f"stream {i} tenant {binding[i]}")
        # oracle 2: the offline executor on the exact consumed clip
        spec_i = dataclasses.replace(spec, in_len=consumed)
        prog = compiler.compile_model(spec_i, w, t)
        off = executor.Executor(prog).run(
            clips[i][:consumed][:, None].astype(np.uint8)).output.ravel()
        np.testing.assert_array_equal(results[i], off)


@pytest.mark.parametrize("backend", ("jnp", "pallas", "megakernel"))
def test_mixed_tenant_bitexact(spec, variants, backend):
    _check_mixed(spec, variants, seed=1, backend=backend)


@pytest.mark.parametrize("seed", range(2, 5))
def test_mixed_tenant_bitexact_seeds(spec, variants, seed):
    _check_mixed(spec, variants, seed=seed, backend="megakernel")


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(seed=hyp_st.integers(min_value=10, max_value=10_000))
    def test_mixed_tenant_hypothesis(seed):
        """Property form: any drawn seed (-> any K, binding, raggedness)
        must be fused-pool-vs-reference bit-exact."""
        spec = kws.build_kws_smoke_spec()
        variants = {}
        for name, s_ in [(DEFAULT_MODEL, 0), ("b", 7), ("c", 11), ("d", 13)]:
            p = kws.init_kws_params(jax.random.PRNGKey(s_), spec)
            variants[name] = kws.export_kws(p, spec)
        _check_mixed(spec, variants, seed=seed, backend="megakernel")


def test_mixed_tenant_async_matches_sync(spec, variants):
    """The async plane (epoch barriers on register_model/resize) is
    bit-identical to the synchronous pooled scheduler."""
    _check_mixed(spec, variants, seed=6, backend="megakernel",
                 cls=AsyncStreamScheduler)


@pytest.mark.parametrize("n_shards", (2,))
def test_mixed_tenant_sharded(spec, variants, n_shards):
    """Tenant-blocked placement keeps every kernel block single-model on
    a sharded mesh too (per-shard pow-2 capacities, replicated pool)."""
    _check_mixed(spec, variants, seed=7, backend="megakernel",
                 mesh=_mesh(n_shards))


# ---------------------------------------------------------------------------
# Dispatch accounting: launches/hop is K-independent
# ---------------------------------------------------------------------------

def _traced_dispatches(sched, emit: bool) -> int:
    """pallas_calls captured by one fresh trace of the pooled hop step."""
    m = sched._model
    plan = sched.plan
    B = sched.capacity
    args = (
        jnp.zeros((B, plan.hop_samples), jnp.int32),
        jnp.zeros((B,), bool),
        tuple(jnp.zeros((B, st.tail, st.cin), jnp.int32)
              for st in plan.convs),
        tuple(jnp.zeros((B, st.phase, st.cout), jnp.int32)
              for st in plan.convs),
        jnp.zeros((B, plan.gap_channels), jnp.int32),
        jnp.zeros((B,), jnp.int32),  # model_idx
    )
    jax.clear_caches()
    with dispatch.counting() as traced:
        jax.eval_shape(lambda *a: m._step(*a, emit=emit), *args)
    return traced()


@pytest.mark.parametrize("backend", ("jnp", "pallas", "megakernel"))
@pytest.mark.parametrize("K", (1, 4, 8))
def test_dispatches_per_hop_k_independent(spec, backend, K):
    """The traced launch count of a K-tenant hop equals the single-model
    scheduler's static accounting for every backend — the pool rides the
    same batched dispatch, it never fans out per tenant.  At K=8 the
    megakernel still fuses to ONE launch per hop, emit included."""
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    w0, t0 = kws.export_kws(params, spec)
    base = StreamScheduler(spec, w0, t0, capacity=16, initial_capacity=16,
                           min_capacity=16, hop_frames=2, backend=backend)
    s = StreamScheduler(spec, w0, t0, capacity=16, initial_capacity=16,
                        min_capacity=16, hop_frames=2, backend=backend,
                        max_models=K if K > 1 else 2, tenant_block=2)
    for emit in (False, True):
        static = base._model.dispatches_per_hop(emit)
        assert s._model.dispatches_per_hop(emit) == static
        assert _traced_dispatches(s, emit) == static
    if backend == "megakernel":
        assert s._model.dispatches_per_hop(False) == 1
        assert s._model.dispatches_per_hop(True) <= 2


# ---------------------------------------------------------------------------
# WeightPool admission / LRU eviction / refcounts
# ---------------------------------------------------------------------------

def test_pool_lru_eviction_and_refcounts(spec, variants):
    w0, t0 = variants[DEFAULT_MODEL]
    s = StreamScheduler(spec, w0, t0, capacity=16, max_models=2,
                        tenant_block=2)
    sid0 = s.add_stream()  # pins DEFAULT_MODEL (refcount 1)
    s.register_model("b", *variants["b"])
    s.register_model("c", *variants["c"])  # evicts b: only refcount-0 row
    assert [m for m, _ in s.models] == [DEFAULT_MODEL, "c"]
    sidc = s.add_stream(model="c")
    with pytest.raises(MemoryError, match="weight pool full"):
        s.register_model("d", *variants["d"])  # every row pinned
    s.close_stream(sidc)  # c's refcount -> 0
    row = s.register_model("d", *variants["d"])
    assert [m for m, _ in s.models] == [DEFAULT_MODEL, "d"]
    assert row == 1  # reuses c's freed row, never grows the stack
    with pytest.raises(KeyError, match="unknown model"):
        s.add_stream(model="nope")
    s.close_stream(sid0)
    ts = s.metrics.tenant_summary()
    assert ts["models_admitted"] == 3 and ts["models_evicted"] == 2


def test_pool_readmit_is_touch_not_swap(spec, variants):
    """Re-registering a resident tenant must not re-pack or move rows —
    it only refreshes LRU recency."""
    w0, t0 = variants[DEFAULT_MODEL]
    s = StreamScheduler(spec, w0, t0, capacity=16, max_models=3,
                        tenant_block=2)
    r1 = s.register_model("b", *variants["b"])
    s.register_model("c", *variants["c"])
    assert s.register_model("b", *variants["b"]) == r1  # touch
    # now default is LRU -> next admission evicts it, not b
    s.register_model("d", *variants["d"])
    assert DEFAULT_MODEL not in dict(s.models)
    assert dict(s.models).keys() == {"b", "c", "d"}
    with pytest.raises(KeyError):  # default evicted: unbound joins fail
        s.add_stream()


def test_single_model_scheduler_rejects_tenancy(spec, variants):
    w0, t0 = variants[DEFAULT_MODEL]
    s = StreamScheduler(spec, w0, t0, capacity=4)
    with pytest.raises(ValueError, match="max_models"):
        s.register_model("b", *variants["b"])
    with pytest.raises(ValueError, match="tenant pool"):
        s.add_stream(model="b")
    assert s.models == [(DEFAULT_MODEL, 0)]


def test_weight_pool_unit(spec, variants):
    """WeightPool standalone: rows are stable while referenced, eviction
    is LRU among refcount-0 variants only."""
    pool = WeightPool(2)
    r0, ev = pool.admit("a", *variants[DEFAULT_MODEL])
    assert (r0, ev) == (0, None)
    r1, ev = pool.admit("b", *variants["b"])
    assert (r1, ev) == (1, None)
    pool.acquire("b")
    r2, ev = pool.admit("c", *variants["c"])
    assert (r2, ev) == (0, "a")  # a was LRU and unreferenced
    pool.release("b")
    assert pool.refcount("b") == 0
    assert len(pool) == 2 and "a" not in pool


# ---------------------------------------------------------------------------
# Satellite: packed-plane memoization
# ---------------------------------------------------------------------------

def test_param_cache_memoizes_packing(spec, variants):
    """Grow/shrink and pool admission re-use packed planes: the second
    scheduler built from the same (weights, thresholds, plan) objects is
    a pure cache hit, and resizes never re-pack at all."""
    w0, t0 = variants[DEFAULT_MODEL]
    before = param_cache_stats()
    s1 = StreamScheduler(spec, w0, t0, capacity=8, max_models=2,
                         tenant_block=2)
    s1.register_model("b", *variants["b"])
    mid = param_cache_stats()
    assert mid["misses"] >= before["misses"]
    s2 = StreamScheduler(spec, w0, t0, capacity=8, max_models=2,
                         tenant_block=2)
    s2.register_model("b", *variants["b"])
    after = param_cache_stats()
    assert after["misses"] == mid["misses"]  # same arrays: all hits
    assert after["hits"] >= mid["hits"] + 2
    # elastic resize packs nothing: force a grow and compare miss count
    sids = [s2.add_stream() for _ in range(8)]
    assert param_cache_stats()["misses"] == after["misses"]
    for sid in sids:
        s2.close_stream(sid)
    assert param_cache_stats()["misses"] == after["misses"]


# ---------------------------------------------------------------------------
# Satellite: idle jit prewarm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", (StreamScheduler, AsyncStreamScheduler))
def test_prewarm_post_grow_hop_has_no_compile_event(spec, variants, cls):
    """With prewarm=True, a starved turn warms the next pow-2 capacity,
    so the first hop after a grow must NOT log a compile trace event."""
    s = _pooled(spec, variants, max_models=2, backend="megakernel",
                cls=cls, capacity=32, hop_frames=2, prewarm=True)
    sids = [s.add_stream(model=m) for m in (None, "b")]
    for j, sid in enumerate(sids):
        _feed(s, sid, _clip(spec, 20 + j, 960))
    _drain(s)
    s.step_batch()  # starved turn -> _maybe_prewarm fires
    assert s.obs.trace.spans("prewarm"), "starved turn did not prewarm"
    warmed_caps = {c for c, _ in s._warmed}
    sids += [s.add_stream(model="b") for _ in range(3)]  # forces a grow
    assert s.capacity in warmed_caps
    before = len(s.obs.trace.spans("compile"))
    for j, sid in enumerate(sids[2:]):
        _feed(s, sid, _clip(spec, 30 + j, 640))
    _drain(s)
    grown = [c for c in s.obs.trace.spans("compile")[before:]
             if c["args"]["capacity"] == s.capacity]
    assert not grown, f"post-grow hop recompiled: {grown}"
    if hasattr(s, "shutdown"):
        s.shutdown()


# ---------------------------------------------------------------------------
# Per-tenant metrics
# ---------------------------------------------------------------------------

def test_tenant_metrics_split(spec, variants):
    s = _pooled(spec, variants, max_models=4, backend="jnp", capacity=8,
                hop_frames=2)
    sids = {m: s.add_stream(model=m) for m in (None, "b", "c")}
    for j, sid in enumerate(sids.values()):
        _feed(s, sid, _clip(spec, 40 + j, 960))
    _drain(s)
    ts = s.metrics.tenant_summary()
    per = ts["per_model"]
    assert per[DEFAULT_MODEL] > 0 and per["b"] > 0 and per["c"] > 0
    assert per[DEFAULT_MODEL] == per["b"] == per["c"]  # same clip length
    assert sum(per.values()) == s.metrics.stream_hops_total
    assert ts["models_admitted"] == 3.0  # b, c, d (d idle: no hops row)
    # the summary() contract is untouched by tenancy
    assert {"streams", "steps", "device_dispatches_per_hop"} <= set(
        s.metrics.summary())
