"""Duplication guard: slot-pool logic lives ONLY in repro.runtime.

The PR that extracted the generic continuous-batching plane moved
``SlotPlacement``, the row-remap contract, the elastic resize /
rebalance machinery, and the async in-flight queue + ingest pump into
``src/repro/runtime/``.  The workloads — the KWS streaming scheduler and
the LM serving engine — are *clients* of that plane.  This guard keeps
it that way: a new private slot pool, resize loop, or placement class
growing back inside a workload module fails here, statically, before it
can drift from the shared one.
"""
import ast
import pathlib

import pytest

SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"

WORKLOAD_MODULES = [
    SRC / "stream" / "scheduler.py",
    SRC / "stream" / "state.py",
    SRC / "stream" / "async_plane.py",
    SRC / "serve" / "engine.py",
]

# names whose *definition* belongs to repro.runtime alone
RUNTIME_CLASSES = {
    "SlotPlacement", "SlotPool", "InFlightQueue", "IngestPump",
}
RUNTIME_FUNCTIONS = {
    # placement / remap plane
    "remap_rows", "remap_device_rows", "perm_keep",
    # pool machinery (old private scheduler spellings included so the
    # exact pre-extraction implementations cannot quietly return)
    "next_pow2", "_next_pow2",
    "alloc", "rebalance",
    "_resize_inner", "_execute_rebalance",
    "_maybe_shrink", "_maybe_rebalance",
    "maybe_shrink", "maybe_rebalance",
}


def _defs(path):
    tree = ast.parse(path.read_text(), filename=str(path))
    classes, funcs = set(), set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            classes.add(node.name)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            funcs.add(node.name)
    return tree, classes, funcs


@pytest.mark.parametrize("path", WORKLOAD_MODULES,
                         ids=lambda p: f"{p.parent.name}/{p.name}")
def test_workload_defines_no_slot_pool_logic(path):
    _, classes, funcs = _defs(path)
    leaked = (classes & RUNTIME_CLASSES) | (funcs & RUNTIME_FUNCTIONS)
    assert not leaked, (
        f"{path.name} re-defines runtime-plane names {sorted(leaked)}; "
        f"extend repro.runtime instead of forking it"
    )


@pytest.mark.parametrize("path", [
    SRC / "stream" / "scheduler.py",
    SRC / "stream" / "async_plane.py",
    SRC / "serve" / "engine.py",
], ids=lambda p: f"{p.parent.name}/{p.name}")
def test_workload_imports_shared_runtime(path):
    tree, _, _ = _defs(path)
    imported = {
        node.module
        for node in ast.walk(tree)
        if isinstance(node, ast.ImportFrom) and node.module
    }
    assert any(m == "repro.runtime" or m.startswith("repro.runtime.")
               for m in imported), (
        f"{path.name} no longer imports from repro.runtime — the workload "
        f"must ride the shared slot plane"
    )


def test_runtime_package_owns_the_plane():
    """The shared plane actually defines what the guard protects (guards
    against renames silently voiding the checks above)."""
    owned = set()
    for mod in ("pool.py", "placement.py", "remap.py", "async_plane.py"):
        _, classes, funcs = _defs(SRC / "runtime" / mod)
        owned |= classes | funcs
    assert RUNTIME_CLASSES <= owned
    for name in ("remap_rows", "remap_device_rows", "perm_keep",
                 "next_pow2", "alloc", "rebalance", "maybe_shrink",
                 "maybe_rebalance"):
        assert name in owned, name
