"""Observability plane: bounded instruments, traces, events, and their
integration with the streaming runtime.

Pins the contracts the always-on deployment depends on:

* histogram quantile estimates stay within the log-linear error bound
  against exact percentiles, for every shape of latency distribution;
* metrics memory is constant over 10k hops of join/close/resize churn
  (the unbounded-list leak this plane replaced cannot come back);
* device-phase timing is fenced — the jitted step's execution cost lands
  in the ``device`` span, not wherever results happen to be forced;
* empty summaries report NaN, never a fabricated 0.0, and the report
  renders them as "—";
* sid reuse retires the first tenant's counters instead of clobbering;
* a dead shard inflates ``shard_summary``'s imbalance;
* ``_charge_scaled`` scales every *runtime* ledger field, so a grown
  EnergyLedger can't silently drop a counter from streaming accounting;
* the JSONL event log records every lifecycle event even when the human
  log mirror is rate-limited down to a handful of lines.
"""
from __future__ import annotations

import dataclasses
import json
import math

import jax
import numpy as np
import pytest

from repro.core.energy import EnergyLedger
from repro.launch.report import _num
from repro.models import kws
from repro.obs import (
    EventLog,
    Histogram,
    MetricsRegistry,
    Observability,
    Reservoir,
    Tracer,
    coverage,
)
from repro.stream import StreamScheduler, plan_stream
from repro.stream.metrics import StreamMetrics, _charge_scaled
from repro.utils.logging import RateLimiter


@pytest.fixture(scope="module")
def smoke():
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    return spec, weights, thresholds


@pytest.fixture(scope="module")
def plan(smoke):
    return plan_stream(smoke[0], hop_frames=2)


# -- histogram ----------------------------------------------------------------


def _distributions():
    rng = np.random.default_rng(0)
    return {
        "lognormal": rng.lognormal(-6.0, 1.5, 5000),
        "uniform": rng.uniform(1e-4, 5e-1, 5000),
        "exponential": rng.exponential(2e-3, 5000) + 1e-6,
        "bimodal": np.concatenate(
            [rng.normal(1e-3, 1e-4, 2500), rng.normal(3e-2, 3e-3, 2500)]
        ).clip(1e-6),
    }


def test_histogram_quantile_error_bound():
    """Estimates stay within the log-linear bucket bound of the exact
    order statistics: each power-of-two range splits into ``lin`` linear
    sub-buckets, so the estimate must land within relative error 2/lin
    of the samples bracketing the target rank (a quantile that falls in
    a gap between modes is bracketed, not interpolated — interpolating
    across empty mass is a choice no bounded sketch can reproduce)."""
    for name, dist in _distributions().items():
        h = Histogram(name)
        for v in dist:
            h.record(v)
        srt = np.sort(dist)
        for q in (0.5, 0.95, 0.99, 0.999):
            rank = q * (len(srt) - 1)
            lo = float(srt[math.floor(rank)])
            hi = float(srt[math.ceil(rank)])
            est = h.quantile(q)
            bound = 2.0 / h.lin
            assert lo * (1 - bound) <= est <= hi * (1 + bound), (
                name, q, est, lo, hi
            )


def test_histogram_record_many_matches_record():
    rng = np.random.default_rng(1)
    vals = rng.lognormal(-5, 2, 2000)
    a, b = Histogram("a"), Histogram("b")
    for v in vals:
        a.record(v)
    b.record_many(vals)
    assert a.count == b.count and a.min == b.min and a.max == b.max
    assert a.sum == pytest.approx(b.sum)
    for q in (0.01, 0.5, 0.9, 0.99, 0.999):
        assert a.quantile(q) == b.quantile(q)


def test_histogram_empty_and_clamping():
    h = Histogram("h", lo=1e-3, hi=1.0)
    assert math.isnan(h.quantile(0.5))
    assert "p50" not in h.snapshot()  # strict JSON: no NaN in snapshots
    h.record(1e-9)   # underflow
    h.record(100.0)  # overflow
    # extremes are exact even though the samples clamped into edge buckets
    assert h.quantile(0.0) == 1e-9
    assert h.quantile(1.0) == 100.0
    assert h.min == 1e-9 and h.max == 100.0


def test_histogram_memory_is_fixed():
    h = Histogram("h")
    before = h.nbytes
    for v in np.random.default_rng(2).uniform(1e-6, 1e3, 20000):
        h.record(v)
    assert h.nbytes == before


# -- reservoir ----------------------------------------------------------------


def test_reservoir_exact_until_wrap():
    r = Reservoir(8)
    for i in range(8):
        r.record(float(i))
    assert not r.saturated  # exactly full still holds every sample
    assert sorted(r.values().tolist()) == [float(i) for i in range(8)]
    r.record(8.0)
    assert r.saturated
    assert len(r.values()) == 8  # last-N window, O(1) memory
    r.reset()
    assert r.count == 0 and not r.saturated


# -- registry -----------------------------------------------------------------


def test_registry_get_or_create_and_type_guard():
    reg = MetricsRegistry()
    c = reg.counter("hops")
    c.inc()
    assert reg.counter("hops") is c and c.value == 1
    reg.gauge("occ").set(3.5)
    reg.histogram("lat").record(0.5)
    with pytest.raises(TypeError):
        reg.histogram("hops")
    snap = reg.snapshot()
    assert snap["hops"] == 1 and snap["occ"] == 3.5
    json.loads(reg.to_json())  # strict JSON round-trips


# -- rate limiter + event log -------------------------------------------------


def test_rate_limiter_suppression_accounting():
    rl = RateLimiter(min_interval_s=10.0)
    ok, suppressed = rl.allow("join", now=0.0)
    assert ok and suppressed == 0
    for t in (1.0, 2.0, 3.0):
        ok, _ = rl.allow("join", now=t)
        assert not ok
    ok, _ = rl.allow("close", now=3.0)  # independent per key
    assert ok
    ok, suppressed = rl.allow("join", now=11.0)
    assert ok and suppressed == 3  # the dropped count surfaces


def test_event_log_writes_every_event_mirror_limited(tmp_path):
    """All 100 events reach the JSONL sink; the human log mirror is
    rate-limited to the first line per kind inside the interval."""
    import io
    import logging

    path = tmp_path / "events.jsonl"
    ev = EventLog(path=str(path), mirror_interval_s=3600.0)
    buf = io.StringIO()
    handler = logging.StreamHandler(buf)
    logger = logging.getLogger("repro.obs.events")
    logger.addHandler(handler)
    try:
        for i in range(100):
            ev.emit("join", sid=i)
    finally:
        logger.removeHandler(handler)
    ev.close()
    recs = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(recs) == 100
    assert [r["seq"] for r in recs] == list(range(100))
    assert all(r["event"] == "join" for r in recs)
    assert buf.getvalue().count("join sid=") == 1


def test_event_log_ring_and_counts(tmp_path):
    ev = EventLog(capacity=4, mirror=False)
    for i in range(10):
        ev.emit("resize", new=i)
    ev.emit("close", sid=0)
    assert len(ev) == 4 and ev.seq == 11  # ring bounded, count exact
    assert ev.counts() == {"resize": 3, "close": 1}
    assert ev.tail(1)[0]["event"] == "close"


# -- tracer -------------------------------------------------------------------


def test_tracer_spans_and_chrome_export(tmp_path):
    tr = Tracer()
    t0 = 0.0
    tr.add_batch((
        ("pack", t0, 0.2, {"n": 4}),
        ("device", 0.2, 0.7, {}),
        ("hop", t0, 0.9, {"n": 4}),
    ))
    with tr.span("resize", old=2, new=4):
        pass
    assert len(tr) == 4
    events = tr.export_chrome()
    names = [e["name"] for e in events]
    assert names[0] == "process_name"  # metadata record
    assert {"pack", "device", "hop", "resize"} <= set(names)
    path = tmp_path / "trace.json"
    n = tr.export_chrome(path=str(path))
    doc = json.loads(path.read_text())
    assert len(doc["traceEvents"]) == n + 1
    hop = next(e for e in doc["traceEvents"] if e["name"] == "hop")
    assert hop["ph"] == "X" and hop["dur"] == pytest.approx(0.9e6)
    assert coverage(events, phases=("pack", "device")) == pytest.approx(1.0)


def test_tracer_bounded_and_disabled():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.add("hop", float(i), 0.1)
    assert len(tr) == 4 and tr.dropped == 6
    off = Tracer(enabled=False)
    off.add("hop", 0.0, 0.1)
    with off.span("hop"):
        pass
    assert len(off) == 0


# -- metrics: bounded memory, NaN, sid reuse, shards, energy ------------------


def test_metrics_constant_memory_over_10k_steps(plan):
    """The leak fix: 10k hops of step + resize + join/close churn retain
    exactly as much memory as 2k hops."""
    m = StreamMetrics(plan, max_retained=64, reservoir=256)
    tr = Tracer(capacity=512)

    def hop(i):
        m.on_step(8, plan.frames_per_hop, 1e-3, host_pack_s=1e-4,
                  dispatch_s=2e-4, device_s=6e-4, detector_s=1e-4)
        if i % 7 == 0:
            m.on_resize(8 << (i % 3))
        sid = i % 1000
        m.on_join(sid)
        m.on_close(sid)
        tr.add("hop", float(i), 1e-3)

    for i in range(2000):
        hop(i)
    footprint_2k = m.footprint_bytes()
    trace_2k = len(tr)
    for i in range(2000, 10000):
        hop(i)
    assert m.footprint_bytes() == footprint_2k
    assert len(tr) == trace_2k == tr.capacity
    assert len(m.capacity_events) <= 64 and m.resize_count == 1429
    assert len(m.streams) <= 64 + 1
    # exact totals survive the bounded retention
    assert m.steps == 10000 and m.streams_total == 10000
    assert m.latency_estimated  # reservoirs wrapped long ago...
    s = m.summary()
    assert s["latency_estimated"] == 1.0
    assert s["step_ms_p50"] == pytest.approx(1.0, rel=2.0 / 32)
    # ...and the histograms still cover every sample ever recorded
    assert m._wall_hist.count == 10000


def test_metrics_empty_summary_nan_not_zero(plan):
    m = StreamMetrics(plan)
    s = m.summary()
    for key in ("step_ms_p50", "step_ms_p95", "step_ms_p99", "step_ms_p999",
                "host_pack_ms_p50", "device_ms_p50", "device_ms_p99"):
        assert math.isnan(s[key]), key
    # non-latency aggregates legitimately start at zero
    assert s["samples_pushed"] == 0.0 and s["steps"] == 0.0
    for p, d in m.phase_summary().items():
        assert math.isnan(d["ms_p50"]) and d["share_of_wall"] == 0.0, p


def test_report_renders_nan_and_missing_as_dash():
    assert _num({"x": float("nan")}, "x", ".3f") == "—"
    assert _num({}, "x", ".3f") == "—"
    assert _num({"x": 0.0}, "x", ".3f") == "0.000"  # measured zero is real


def test_sid_reuse_retires_first_tenant(plan):
    m = StreamMetrics(plan)
    m.on_join(5)
    m.on_detection(5)
    m.on_close(5, frames_out=7)
    first = m.streams[5]
    m.on_join(5)  # sid reused by a new tenant
    assert m.streams[5] is not first
    assert m.streams[5].detections == 0
    assert list(m.retired) == [first] and m.retired_total == 1
    assert first.detections == 1 and first.frames_out == 7
    assert m.streams_total == 2 and m.detections_total == 1


def test_closed_streams_evict_oldest_but_stay_inspectable(plan):
    m = StreamMetrics(plan, max_retained=4)
    for sid in range(10):
        m.on_join(sid)
        m.on_close(sid, frames_out=sid)
    assert set(m.streams) == {6, 7, 8, 9}  # most recent stay inspectable
    assert m.streams[9].frames_out == 9
    assert m.closed_total == 10


def test_shard_summary_dead_shard_inflates_imbalance(plan):
    m = StreamMetrics(plan, n_shards=4)
    for _ in range(5):
        m.on_step(12, plan.frames_per_hop, 1e-3, shard_counts=[4, 4, 4, 0])
    s = m.shard_summary()
    assert s["per_shard"][3]["stream_hops"] == 0
    assert s["per_shard"][0]["mean_occupancy"] == pytest.approx(4.0)
    # mean counts the dead shard: 4 / (12/4) = 4/3
    assert s["imbalance"] == pytest.approx(4.0 / 3.0)
    assert s["fleet_stream_hops"] == 60


def test_charge_scaled_covers_grown_ledger_fields():
    @dataclasses.dataclass
    class GrownLedger(EnergyLedger):
        dram_bits: int = 0  # a field EnergyLedger doesn't have today

    src = GrownLedger(dram_bits=7)
    src.charge_mac_op(10, 20, 30, 40)
    dst = GrownLedger()
    _charge_scaled(dst, src, 3)
    assert dst.dram_bits == 21  # runtime-generic: the new field scales too
    assert dst.macs == 30 and dst.phys_macs == 60
    assert dst.sa_decisions == 90 and dst.cycles == 120


def test_begin_window_resets_latency_not_lifecycle(plan):
    m = StreamMetrics(plan)
    m.on_join(0)
    for _ in range(3):
        m.on_step(4, plan.frames_per_hop, 1e-3)
    macs_before = m.ledger.macs
    m.begin_window()
    s = m.summary()
    assert s["steps"] == 0.0 and math.isnan(s["step_ms_p50"])
    assert s["streams"] == 1.0  # lifecycle survives
    assert m.ledger.macs == macs_before  # energy stays cumulative


def test_latency_estimated_flips_after_reservoir_wrap(plan):
    m = StreamMetrics(plan, reservoir=16)
    for _ in range(16):
        m.on_step(1, plan.frames_per_hop, 2e-3)
    assert not m.latency_estimated
    assert m.summary()["step_ms_p50"] == pytest.approx(2.0)  # exact
    m.on_step(1, plan.frames_per_hop, 2e-3)
    assert m.latency_estimated
    # the lazily-backfilled histogram covers all 17 samples
    assert m._wall_hist.count == 17
    assert m.summary()["step_ms_p50"] == pytest.approx(2.0, rel=2.0 / 32)


# -- scheduler integration: fencing, coverage, lifecycle ----------------------


def _stream_rounds(sched, n_streams, rounds, rng, warm: int = 4):
    """Prime + ``warm`` hops (compile lands here), then open a fresh
    metrics window and run ``rounds`` steady-state hops."""
    plan = sched.plan
    need = plan.prime_samples + (warm + rounds + 1) * plan.hop_samples
    audio = rng.integers(0, 256, (n_streams, need)).astype(np.uint8)
    sids = [sched.add_stream() for _ in range(n_streams)]
    pos = plan.prime_samples + (warm + 1) * plan.hop_samples
    sched.push_audio_batch(sids, list(audio[:, :pos]))
    sched.drain()
    sched.metrics.begin_window()
    sched.push_audio_batch(sids, list(audio[:, pos:]))
    sched.drain()
    return sids


def test_device_phase_dominates_at_large_batch(smoke):
    """The fencing regression: ``block_until_ready`` sits at the device
    span boundary, so the jitted step's execution cost lands between the
    dispatch stamp and the device stamp.  If the fence is removed, the
    wait silently moves to wherever results are first forced (the
    detector's host copy) and the device-side share collapses to enqueue
    time.  The CPU backend splits execution between "inside the dispatch
    call" and "behind the fence" at the whim of the scheduler, so the
    assertion pools dispatch+device — that sum is fence-bounded and
    load-stable where the individual split is not."""
    spec, weights, thresholds = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=128,
                            initial_capacity=128, min_capacity=128,
                            emit_logits=False)
    _stream_rounds(sched, 128, 8, np.random.default_rng(0))
    ps = sched.metrics.phase_summary()
    m = sched.metrics.summary()
    assert m["steps"] >= 4
    devside = ps["device"]["share_of_wall"] + ps["dispatch"]["share_of_wall"]
    assert devside > ps["pack"]["share_of_wall"]
    assert devside > ps["detector"]["share_of_wall"]
    assert devside > 0.5, ps  # execution, not host work, owns the hop
    # the legacy host/device split agrees: device strictly dominates
    assert m["device_ms_p50"] > m["host_pack_ms_p50"]


def test_trace_spans_cover_hop_wall(smoke):
    spec, weights, thresholds = smoke
    obs = Observability.create(mirror_events=False)
    sched = StreamScheduler(spec, weights, thresholds, capacity=8,
                            initial_capacity=8, min_capacity=8, obs=obs)
    _stream_rounds(sched, 8, 6, np.random.default_rng(1))
    events = obs.trace.export_chrome()
    names = {e["name"] for e in events}
    assert {"hop", "pack", "dispatch", "device", "detector",
            "push_fold", "prime_batch"} <= names
    assert coverage(events) >= 0.95
    # phase stamps are consecutive: each hop is tiled exactly
    hops = [e for e in events if e["name"] == "hop"]
    assert all(e["ph"] == "X" and e["dur"] > 0 for e in hops)


def test_scheduler_event_log_lifecycle(smoke, tmp_path):
    spec, weights, thresholds = smoke
    path = tmp_path / "events.jsonl"
    obs = Observability.create(event_path=str(path), mirror_events=False)
    sched = StreamScheduler(spec, weights, thresholds, capacity=8,
                            initial_capacity=2, min_capacity=2, obs=obs)
    sids = _stream_rounds(sched, 6, 4, np.random.default_rng(2))
    for sid in sids:
        sched.close_stream(sid)
    obs.events.close()
    recs = [json.loads(line) for line in path.read_text().splitlines()]
    kinds = {r["event"] for r in recs}
    assert {"join", "mass_join", "resize", "close"} <= kinds
    assert sum(r["event"] == "join" for r in recs) == 6
    assert sum(r["event"] == "close" for r in recs) == 6
    seqs = [r["seq"] for r in recs]
    assert seqs == sorted(seqs)
    resize = next(r for r in recs if r["event"] == "resize")
    assert resize["old"] < resize["new"]  # the pool grew under the joins


def test_metrics_summary_bit_compatible_with_reservoir(plan):
    """While the reservoir holds every sample, summary quantiles are
    np.percentile over the full sample list — bit-identical to the old
    unbounded implementation."""
    rng = np.random.default_rng(3)
    walls = rng.uniform(5e-4, 5e-3, 200)
    packs = rng.uniform(1e-5, 1e-4, 200)
    m = StreamMetrics(plan)
    for w, p in zip(walls, packs):
        m.on_step(4, plan.frames_per_hop, float(w), host_pack_s=float(p))
    s = m.summary()
    assert s["step_ms_p50"] == float(np.percentile(walls, 50) * 1e3)
    assert s["step_ms_p95"] == float(np.percentile(walls, 95) * 1e3)
    assert s["step_ms_p999"] == float(np.percentile(walls, 99.9) * 1e3)
    assert s["host_pack_ms_p50"] == float(np.percentile(packs, 50) * 1e3)
    assert s["device_ms_p50"] == float(
        np.percentile(walls - packs, 50) * 1e3
    )
    assert s["latency_estimated"] == 0.0
