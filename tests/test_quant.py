"""Quantization primitives: packing, STE, threshold folding (+ property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import quant


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 7),
    words=st.integers(1, 4),
    seed=st.integers(0, 2**16),
)
def test_pack_unpack_roundtrip(rows, words, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (rows, words * 32)).astype(np.uint32)
    packed = quant.pack_bits(jnp.array(bits))
    assert packed.shape == (rows, words)
    out = quant.unpack_bits(packed, n=words * 32)
    np.testing.assert_array_equal(np.asarray(out), bits)


def test_pack_rejects_unaligned():
    with pytest.raises(ValueError):
        quant.pack_bits(jnp.zeros((2, 33), jnp.uint32))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_popcount_equals_int_matmul(seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2, (5, 64)).astype(np.uint32)
    wp = rng.integers(0, 2, (64, 9)).astype(np.int64)
    wn = (rng.integers(0, 2, (64, 9)) * (1 - wp)).astype(np.int64)
    from repro.kernels import ref

    xp = quant.pack_bits(jnp.array(x))
    got = ref.ref_popcount_gemm_packed(
        xp,
        quant.pack_bits(jnp.array(wp, jnp.uint32), axis=0),
        quant.pack_bits(jnp.array(wn, jnp.uint32), axis=0),
    )
    want = x.astype(np.int64) @ (wp - wn)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_binarize_act_values_and_grad():
    x = jnp.array([-2.0, -0.5, 0.0, 0.5, 2.0])
    y = quant.binarize_act(x)
    np.testing.assert_array_equal(np.asarray(y), [0, 0, 1, 1, 1])
    g = jax.grad(lambda x: jnp.sum(quant.binarize_act(x)))(x)
    # clipped STE: gradient passes only where |x| <= 1
    np.testing.assert_array_equal(np.asarray(g), [0, 1, 1, 1, 0])


def test_ternarize_weight_values_and_grad():
    w = jnp.array([-1.0, -0.01, 0.0, 0.01, 1.0])
    t = quant.ternarize_weight(w)
    assert set(np.asarray(t).tolist()) <= {-1.0, 0.0, 1.0}
    assert np.asarray(t)[0] == -1 and np.asarray(t)[-1] == 1
    g = jax.grad(lambda w: jnp.sum(quant.ternarize_weight(w)))(w)
    np.testing.assert_array_equal(np.asarray(g), np.ones(5))  # identity STE


def test_fold_bn_threshold_matches_bn_sign():
    rng = np.random.default_rng(0)
    s = jnp.array(rng.integers(-50, 50, (13, 7)), jnp.float32)
    gamma = jnp.array(rng.normal(1, 0.5, 7), jnp.float32)
    beta = jnp.array(rng.normal(0, 1, 7), jnp.float32)
    mean = jnp.array(rng.normal(0, 5, 7), jnp.float32)
    var = jnp.array(rng.uniform(0.5, 2, 7), jnp.float32)
    bn = gamma * (s - mean) / jnp.sqrt(var + 1e-5) + beta
    want = (bn >= 0).astype(np.uint32)
    thr, flip = quant.fold_bn_to_threshold(gamma, beta, mean, var)
    got = quant.apply_threshold(s, thr, flip)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pad_to_multiple():
    x = jnp.ones((3, 5))
    assert quant.pad_to_multiple(x, 4, 1).shape == (3, 8)
    assert quant.pad_to_multiple(x, 5, 1).shape == (3, 5)
