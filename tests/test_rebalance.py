"""Cross-shard rebalance plane + batched mass-join primer.

The migrate-on-idle rebalance (SlotPlacement.rebalance executed by
StreamScheduler at hop boundaries through ops.remap_slot_rows) must lift
the elastic pool's shrink floor from the fullest shard's tenant count to
ceil(active / n_shards) — and stay bit-exact with the single-device
scheduler and the offline executor through every migration.  The batched
primer (state.prime_batch) must warm up a B-stream mass join in one
vectorized advance, bit-identical to B per-stream StreamState warm-ups.

Multi-shard cases need a forced multi-device host (the CI multi-device
leg):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_rebalance.py
"""
import jax
import numpy as np
import pytest

from repro.core import compiler, executor
from repro.kernels import ops
from repro.launch.mesh import make_stream_mesh
from repro.models import kws
from repro.stream import (
    SlotPlacement,
    StreamScheduler,
    StreamState,
    plan_stream,
    prime_batch,
)
from repro.stream.scheduler import _next_pow2


@pytest.fixture(scope="module")
def smoke():
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(spec, weights, thresholds)
    return spec, weights, thresholds, prog


def _mesh(n):
    if jax.device_count() < n:
        pytest.skip(
            f"needs {n} devices (XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n})"
        )
    return make_stream_mesh(n)


def _offline(prog, x):
    return executor.Executor(prog).run(x[:, None]).output.ravel()


def _clip(spec, seed):
    return np.random.default_rng(seed).integers(
        0, 256, (spec.in_len,)
    ).astype(np.uint8)


def _by_sid(outs):
    d = {}
    for sid, frame, logits, _ in outs:
        d.setdefault(sid, []).append((frame, logits))
    return d


def _assert_outs_equal(a, b, stage=""):
    da, db = _by_sid(a), _by_sid(b)
    assert da.keys() == db.keys(), stage
    for sid in da:
        assert len(da[sid]) == len(db[sid]), (stage, sid)
        for (fa, la), (fb, lb) in zip(da[sid], db[sid]):
            assert fa == fb, (stage, sid)
            np.testing.assert_array_equal(la, lb)


# ---------------------------------------------------------------------------
# Planner unit behavior
# ---------------------------------------------------------------------------

def test_placement_rebalance_levels_skewed_occupancy():
    p = SlotPlacement(4, 4)
    for sid in range(8):
        p.alloc(sid)  # least-loaded: 2 per shard
    # churn: free everything off shard 0 -> occupancy [2, 0, 0, 0] ... plus
    # pile 2 more onto shard 0 via direct placement
    for slot, sid in enumerate(list(p.slots)):
        if sid is not None and p.shard_of(slot) != 0:
            p.free(slot)
    p.slots[2], p.slots[3] = 90, 91  # shard 0 now holds 4 of 4 active... 6
    occ = p.occupancy()
    assert occ == [4, 0, 0, 0]
    moves, remap = p.rebalance()  # target = ceil(4/4) = 1
    assert p.occupancy() == [1, 1, 1, 1]
    assert len(moves) == 3
    for dst, src in moves:
        assert p.shard_of(dst) != p.shard_of(src)  # genuinely cross-shard
    # remap covers EVERY tenant: identity for unmoved, src->dst for moved
    assert len(remap) == 4
    for old, new in remap.items():
        assert p.slots[new] is not None
    moved = {src: dst for dst, src in moves}
    for old, new in remap.items():
        assert new == moved.get(old, old)


def test_placement_rebalance_noop_when_level():
    p = SlotPlacement(2, 4)
    for sid in range(5):
        p.alloc(sid)  # 3 / 2: max == ceil(5/2), already level
    before = list(p.slots)
    moves, remap = p.rebalance()
    assert moves == [] and p.slots == before
    assert remap == {s: s for s, sid in enumerate(before) if sid is not None}


def test_placement_rebalance_deterministic_slots():
    # donors give up their HIGHEST occupied local slot, receivers fill
    # their LOWEST free local slot, ties break to the lowest shard
    p = SlotPlacement(2, 4)
    p.slots = [10, 11, 12, None, None, None, None, None]
    moves, remap = p.rebalance()  # target ceil(3/2) = 2
    assert moves == [(4, 2)]
    assert remap == {0: 0, 1: 1, 2: 4}


def test_remap_slot_rows_gathers_and_clears():
    x = np.arange(24, dtype=np.int32).reshape(4, 3, 2)
    # tenant at 0 stays, tenant at 3 migrates to 1, rows 2 and 3 vacate
    perm = np.array([0, 3, 2, 3])
    keep = np.array([True, True, False, False])
    out = np.asarray(ops.remap_slot_rows(x, perm, keep))
    np.testing.assert_array_equal(out[0], x[0])
    np.testing.assert_array_equal(out[1], x[3])
    assert (out[2] == 0).all() and (out[3] == 0).all()


# ---------------------------------------------------------------------------
# Batched primer
# ---------------------------------------------------------------------------

def test_prime_batch_matches_streamstate(smoke):
    """One vectorized warm-up == B per-stream StreamState warm-ups, bit
    for bit (the export_steady interchange contract)."""
    spec, weights, thresholds, _ = smoke
    plan = plan_stream(spec, hop_frames=2)
    rng = np.random.default_rng(42)
    B = 5
    codes = rng.integers(0, 256, (B, plan.prime_samples))
    batched = prime_batch(plan, weights, thresholds, codes)
    for j in range(B):
        st = StreamState(plan, weights, thresholds)
        st.advance(codes[j])
        steady = st.export_steady()
        for i in range(len(plan.convs)):
            np.testing.assert_array_equal(
                batched["tails"][i][j], steady["tails"][i]
            )
            np.testing.assert_array_equal(
                batched["pendings"][i][j], steady["pendings"][i]
            )
        np.testing.assert_array_equal(batched["gap"][j], steady["gap"])
        assert batched["frames"] == st.frames


def test_prime_batch_rejects_wrong_prefix(smoke):
    spec, weights, thresholds, _ = smoke
    plan = plan_stream(spec)
    with pytest.raises(ValueError, match="prime_batch wants"):
        prime_batch(plan, weights, thresholds,
                    np.zeros((2, plan.prime_samples - 1), np.uint8))


def test_mass_join_bitexact_vs_sequential_joins(smoke):
    """B streams joining in one hop (one batched primer cascade) emit the
    same per-hop and final logits as B sequential join/prime/drain
    rounds, and both equal the offline executor."""
    spec, weights, thresholds, prog = smoke
    B = 16
    clips = {j: _clip(spec, 700 + j) for j in range(B)}

    mass = StreamScheduler(spec, weights, thresholds, capacity=B,
                           initial_capacity=B, min_capacity=B)
    sids = [mass.add_stream() for _ in range(B)]
    mass.push_audio_batch(sids, [clips[j] for j in range(B)])
    outs_mass = mass.run_until_starved()  # all B prime in ONE call

    seq = StreamScheduler(spec, weights, thresholds, capacity=B,
                          initial_capacity=B, min_capacity=B)
    outs_seq = []
    for j in range(B):
        assert seq.add_stream() == j
        seq.push_audio(j, clips[j])
        outs_seq.extend(seq.run_until_starved())

    _assert_outs_equal(outs_mass, outs_seq, "mass vs sequential")
    for j in range(B):
        ra, rb = mass.close_stream(j), seq.close_stream(j)
        np.testing.assert_array_equal(ra.logits, rb.logits)
        np.testing.assert_array_equal(ra.logits, _offline(prog, clips[j]))


# ---------------------------------------------------------------------------
# Empty-pool shrink floor (satellite)
# ---------------------------------------------------------------------------

def test_empty_pool_shrinks_to_min_capacity(smoke):
    """With occupancy all zeros mid-churn the _next_pow2(max(occ)) floor
    must collapse to one empty slot, i.e. min_capacity wins."""
    spec, weights, thresholds, prog = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=32,
                            initial_capacity=32, min_capacity=2)
    sids = [sched.add_stream() for _ in range(32)]
    for sid in sids:  # close everything, never having fed audio
        sched.close_stream(sid)
    assert sched.capacity == 2
    # and the pool regrows cleanly from the floor
    clip = _clip(spec, 800)
    sid = sched.add_stream()
    sched.push_audio(sid, clip)
    sched.run_until_starved()
    np.testing.assert_array_equal(
        sched.close_stream(sid).logits, _offline(prog, clip)
    )
    assert sched.capacity == 2


def test_empty_pool_shrinks_to_min_capacity_sharded(smoke):
    spec, weights, thresholds, _ = smoke
    mesh = _mesh(2)
    sched = StreamScheduler(spec, weights, thresholds, capacity=16,
                            initial_capacity=16, min_capacity=2, mesh=mesh)
    sids = [sched.add_stream() for _ in range(16)]
    for sid in sids:
        sched.close_stream(sid)
    assert sched.capacity == 2


# ---------------------------------------------------------------------------
# Skewed churn: the rebalanced pool shrinks where the pinned pool cannot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [2, 8])
def test_skewed_churn_rebalance_lifts_shrink_floor(smoke, n_shards):
    """Leaves skewed onto one shard: the rebalanced pool's steady
    capacity reaches <= 2 * _next_pow2(ceil(active/S)) * S, the
    no-rebalance pool stays pinned at the fullest shard's floor (at
    S >= 4, where skew can exceed the elastic quarter-occupancy floor),
    and logits stay bit-exact vs a single-device scheduler and the
    offline executor through every migration."""
    spec, weights, thresholds, prog = smoke
    mesh = _mesh(n_shards)
    total = 16 if n_shards == 2 else 4 * n_shards
    n_keep = 2 if n_shards == 2 else 4
    clips = {j: _clip(spec, 500 + j) for j in range(total)}

    reb = StreamScheduler(spec, weights, thresholds, capacity=total,
                          initial_capacity=total, min_capacity=n_shards,
                          mesh=mesh)  # rebalance_threshold=1 (default)
    pin = StreamScheduler(spec, weights, thresholds, capacity=total,
                          initial_capacity=total, min_capacity=n_shards,
                          mesh=mesh, rebalance_threshold=None)  # PR 3 mode
    ref = StreamScheduler(spec, weights, thresholds, capacity=total,
                          initial_capacity=total, min_capacity=total)
    scheds = (reb, pin, ref)

    plan = reb.plan
    cut = plan.prime_samples + 2 * plan.hop_samples
    prog_cut = compiler.compile_model(
        kws.build_kws_spec(in_len=cut, width=16), weights, thresholds
    )
    for sched in scheds:
        for j in range(total):
            assert sched.add_stream() == j
            sched.push_audio(j, clips[j][:cut])
    outs = [s.run_until_starved() for s in scheds]
    _assert_outs_equal(outs[0], outs[2], "warm reb-vs-ref")
    _assert_outs_equal(outs[1], outs[2], "warm pin-vs-ref")

    # leave skewed: keep only n_keep tenants, all on shard 0 (placements
    # are identical across schedulers at this point — no migration yet)
    shard0 = [j for j in range(total)
              if reb._streams[j].slot < reb.shard_capacity]
    assert [pin._streams[j].slot for j in shard0] == \
        [reb._streams[j].slot for j in shard0]
    survivors = shard0[:n_keep]
    for sched in scheds:
        for j in range(total):
            if j in survivors:
                continue
            res = sched.close_stream(j)
            np.testing.assert_array_equal(
                res.logits, _offline(prog_cut, clips[j][:cut])
            )

    # survivors keep streaming: the next hop boundary migrates + shrinks
    for sched in scheds:
        for j in survivors:
            sched.push_audio(j, clips[j][cut:])
    outs = [s.run_until_starved() for s in scheds]
    _assert_outs_equal(outs[0], outs[2], "post-migration reb-vs-ref")
    _assert_outs_equal(outs[1], outs[2], "post-migration pin-vs-ref")

    active = len(survivors)
    balanced_floor = n_shards * _next_pow2(-(-active // n_shards))
    assert reb.capacity <= 2 * balanced_floor  # the acceptance bound
    assert reb.metrics.rebalances >= 1
    assert reb.metrics.rows_migrated >= 1
    occ = reb._placement.occupancy()
    assert max(occ) - min(occ) <= 1  # leveled
    assert pin.metrics.rebalances == 0
    assert pin.capacity >= reb.capacity
    if n_shards >= 4:
        # skew beyond the quarter-occupancy elastic floor: only the
        # rebalanced pool escapes the fullest shard's pin
        assert pin.capacity == total
        assert reb.capacity < pin.capacity

    for j in survivors:
        ra, rb, rc = (s.close_stream(j) for s in scheds)
        np.testing.assert_array_equal(ra.logits, rc.logits)
        np.testing.assert_array_equal(rb.logits, rc.logits)
        np.testing.assert_array_equal(ra.logits, _offline(prog, clips[j]))


@pytest.mark.parametrize("n_shards", [2, 8])
def test_rebalance_mid_stream_peek_and_detector_state(smoke, n_shards):
    """A migration carries inbox, detector and stamp state with the
    stream: peeks right after a migration equal the offline prefix."""
    spec, weights, thresholds, _ = smoke
    mesh = _mesh(n_shards)
    total = 4 * n_shards
    clips = {j: _clip(spec, 600 + j) for j in range(total)}
    sched = StreamScheduler(spec, weights, thresholds, capacity=total,
                            initial_capacity=total, min_capacity=n_shards,
                            mesh=mesh)
    for j in range(total):
        sched.add_stream()
        sched.push_audio(j, clips[j])
    sched.run_until_starved()
    keep = [j for j in range(total)
            if sched._streams[j].slot < sched.shard_capacity][:2]
    for j in range(total):
        if j not in keep:
            sched.close_stream(j)
    assert len({sched._streams[j].slot // sched.shard_capacity
                for j in keep}) == 1  # both tenants crowd one shard
    sched.run_until_starved()  # hop boundary: migration runs (no audio)
    assert sched.metrics.rebalances >= 1
    assert len({sched._streams[j].slot // sched.shard_capacity
                for j in keep}) == 2  # the migration spread them apart
    prog = smoke[3]
    for j in keep:
        # peek right after the migration covers ALL audio pushed so far
        # (inbox leftovers via the exact fallback, drained state via the
        # in-jit tail) — both must equal the offline full-clip run, so a
        # migrated row with stale/shifted state cannot hide
        np.testing.assert_array_equal(sched.peek(j), _offline(prog, clips[j]))
        np.testing.assert_array_equal(
            sched.close_stream(j).logits, _offline(prog, clips[j])
        )
