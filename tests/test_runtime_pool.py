"""LM engine on the shared continuous-batching runtime (repro.runtime).

The engine port's whole claim mirrors the async plane's: it changes WHERE
the slot machinery lives — one generic ``SlotPool`` shared with the KWS
streaming scheduler — never WHAT it computes.  This suite pins that:

  * token parity: the ported engine (sync and ``step_async``) is
    token-identical to the frozen pre-port engine vendored in
    ``tests/_legacy_engine.py``, through slot refills and shutdown drain;
  * elastic capacity: with ``max_slots``/``min_slots`` the pool doubles
    on demand and halves at quarter occupancy *mid-decode*, emitting
    ``lm_resize`` from the pool, with zero perturbation of any request's
    tokens (rows travel unchanged through every pad/slice);
  * sharded decode: under a 2-shard host mesh the slot axis shards over
    the mesh's data axis and tokens match the unsharded engine;
  * sharded rebalance: skewed finishes (one shard's requests all short)
    trigger a cross-shard migration at a tick boundary — ``lm_rebalance``
    emitted by the pool, with the event-payload completeness the report
    pipeline relies on — again token-identically, sync and async.

Runs on the CI multi-device leg (the sharded cases skip on 1-device
hosts).
"""
import importlib.util
import pathlib
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import api
from repro.obs import Observability
from repro.serve.engine import Engine, Request

_SPEC = importlib.util.spec_from_file_location(
    "legacy_engine", pathlib.Path(__file__).with_name("_legacy_engine.py"))
legacy = importlib.util.module_from_spec(_SPEC)
sys.modules["legacy_engine"] = legacy  # dataclass field resolution
_SPEC.loader.exec_module(legacy)


@pytest.fixture(scope="module")
def lm():
    cfg = get_arch("qwen3-0.6b", smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _submit(eng, lengths):
    for i, n in enumerate(lengths):
        eng.submit(Request(rid=i, prompt=np.arange(6, dtype=np.int32) + i,
                           max_new_tokens=n))


def _tokens(done):
    return {r.rid: list(r.out_tokens) for r in done}


def _run(eng, lengths, async_mode):
    _submit(eng, lengths)
    done = (eng.run_until_drained_async() if async_mode
            else eng.run_until_drained())
    assert not eng._pending
    assert all(r.done for r in done)
    return _tokens(done)


# ---------------------------------------------------------------------------
# Parity against the frozen pre-port engine
# ---------------------------------------------------------------------------

def test_engine_token_parity_with_frozen_oracle(lm):
    """Sync and async decode through the pool-backed engine produce the
    exact token streams of the pre-refactor engine, through refills,
    uneven request lengths, and the shutdown drain."""
    cfg, params = lm
    lengths = [3, 5, 2, 4, 3]

    def make(cls):
        return cls(cfg, params, batch_slots=2, max_seq=32,
                   obs=Observability.create(mirror_events=False))

    oracle_sync = _run(make(legacy.Engine), lengths, async_mode=False)
    oracle_asyn = _run(make(legacy.Engine), lengths, async_mode=True)
    ported_sync = _run(make(Engine), lengths, async_mode=False)
    ported_asyn = _run(make(Engine), lengths, async_mode=True)
    assert set(ported_sync) == set(range(len(lengths)))
    assert ported_sync == oracle_sync
    assert ported_asyn == oracle_asyn
    assert ported_sync == ported_asyn


def test_engine_lifecycle_events_preserved(lm):
    """The port keeps the engine's request-lifecycle event stream: every
    request still gets lm_submit / lm_slot_fill / lm_finish."""
    cfg, params = lm
    obs = Observability.create(mirror_events=False)
    eng = Engine(cfg, params, batch_slots=2, max_seq=32, obs=obs)
    done = _run(eng, [3, 2, 3], async_mode=False)
    assert set(done) == {0, 1, 2}
    counts = obs.events.counts()
    assert counts.get("lm_submit") == 3
    assert counts.get("lm_slot_fill") == 3
    assert counts.get("lm_finish") == 3


# ---------------------------------------------------------------------------
# Elastic capacity (grow/shrink mid-decode)
# ---------------------------------------------------------------------------

def test_engine_elastic_grow_shrink_mid_decode(lm):
    """``max_slots`` turns the fixed pool elastic: admitting 6 requests
    through a 2-slot pool doubles it to 8 on demand, and the short
    requests finishing shrinks it back — all mid-decode, with the
    surviving requests' tokens untouched (vs a fixed 8-slot oracle) and
    ``lm_resize`` emitted by the pool with the full payload."""
    cfg, params = lm
    lengths = [8, 2, 7, 2, 6, 2]  # staggered: finishes straddle resizes

    oracle = _run(
        legacy.Engine(cfg, params, batch_slots=8, max_seq=32,
                      obs=Observability.create(mirror_events=False)),
        lengths, async_mode=False)

    for async_mode in (False, True):
        obs = Observability.create(mirror_events=False)
        eng = Engine(cfg, params, batch_slots=2, max_seq=32, obs=obs,
                     max_slots=8, min_slots=2)
        assert eng.slots == 2
        out = _run(eng, lengths, async_mode=async_mode)
        assert out == oracle, f"async_mode={async_mode}"
        resizes = [e for e in obs.events.tail() if e["event"] == "lm_resize"]
        grew = [e for e in resizes if e["new"] > e["old"]]
        shrank = [e for e in resizes if e["new"] < e["old"]]
        assert grew and shrank, resizes
        assert eng.slots < 8  # churn shrank the pool back down
        for e in resizes:  # pool-emitted payload completeness
            assert {"old", "new", "active", "shards"} <= set(e)


def test_engine_ceiling_queues_instead_of_failing(lm):
    """At the capacity ceiling the queue holds (continuous batching), and
    every request still completes as slots vacate."""
    cfg, params = lm
    eng = Engine(cfg, params, batch_slots=1, max_seq=32, max_slots=2,
                 obs=Observability.create(mirror_events=False))
    out = _run(eng, [3, 3, 3, 3], async_mode=False)
    assert set(out) == {0, 1, 2, 3}
    assert all(len(t) == 3 for t in out.values())


# ---------------------------------------------------------------------------
# Sharded decode (CI multi-device leg)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >=2 devices (multi-device CI leg)")
def test_engine_sharded_decode_smoke(lm):
    """2-shard mesh: the cache's slot axis shards over the mesh's data
    axis and decode is token-identical to the unsharded engine."""
    from repro.launch.mesh import make_stream_mesh
    cfg, params = lm
    mesh = make_stream_mesh(2)
    lengths = [3, 4, 2, 3, 4, 2]

    def make(mesh_arg):
        return Engine(cfg, params, batch_slots=4, max_seq=32, mesh=mesh_arg,
                      obs=Observability.create(mirror_events=False))

    base = _run(make(None), lengths, async_mode=False)
    shard_sync = _run(make(mesh), lengths, async_mode=False)
    shard_asyn = _run(make(mesh), lengths, async_mode=True)
    assert shard_sync == base
    assert shard_asyn == base


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >=2 devices (multi-device CI leg)")
def test_engine_sharded_rebalance_event_complete(lm):
    """Skewed finishes under a mesh: one shard's requests are all short,
    so it empties while the other stays full — the pool migrates rows at
    the tick boundary (``lm_rebalance`` with the complete payload the
    report pipeline consumes) and every surviving request's tokens are
    identical to the unsharded run.  This is the event-log completeness
    gate for the multi-device CI leg."""
    from repro.launch.mesh import make_stream_mesh
    cfg, params = lm
    mesh = make_stream_mesh(2)
    # least-loaded placement alternates shards: even rids land on shard 0,
    # odd on shard 1.  Short even requests empty shard 0 mid-decode.
    lengths = [2, 8, 2, 8, 2, 8, 2, 8]

    base = _run(
        Engine(cfg, params, batch_slots=8, max_seq=32,
               obs=Observability.create(mirror_events=False)),
        lengths, async_mode=False)

    for async_mode in (False, True):
        obs = Observability.create(mirror_events=False)
        eng = Engine(cfg, params, batch_slots=8, max_seq=32, mesh=mesh,
                     obs=obs)
        out = _run(eng, lengths, async_mode=async_mode)
        assert out == base, f"async_mode={async_mode}"
        rebs = [e for e in obs.events.tail()
                if e["event"] == "lm_rebalance"]
        assert rebs, "skewed finishes never triggered a migration"
        for e in rebs:  # pool-emitted payload completeness
            assert {"moves", "shards", "occupancy_before",
                    "occupancy_after"} <= set(e)
            assert e["shards"] == 2
            assert max(e["occupancy_after"]) - min(e["occupancy_after"]) \
                <= max(e["occupancy_before"]) - min(e["occupancy_before"])
