"""Sharding rules + roofline HLO parsing (no multi-device requirement:
divisibility logic is pure; the parser works on HLO text)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import arch_names, get_arch
from repro.launch import roofline as rl
from repro.models import api
from repro.sharding import specs as sh


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


class FakeMeshMP:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


def _leaf(shape, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_param_rules_megatron_pairing():
    m = FakeMesh()
    assert sh.param_pspec("blocks/0/attn/wq", _leaf((28, 1024, 2048)), m) == \
        P(None, None, "model")
    assert sh.param_pspec("blocks/0/attn/wo", _leaf((28, 2048, 1024)), m) == \
        P(None, "model", None)
    # vocab-parallel embed; 19MB/shard is under the 32MB FSDP threshold
    assert sh.param_pspec("embed", _leaf((151936, 1024)), m) == \
        P("model", None)
    # a 4x bigger embed crosses the threshold and gains FSDP on d_model
    assert sh.param_pspec("embed", _leaf((151936, 4096)), m) == \
        P("model", "data")
    # shared experts are plain MLPs
    assert sh.param_pspec("blocks/0/moe/shared/wi_up",
                          _leaf((27, 2048, 2816)), m) == \
        P(None, None, "model")
    # norms replicate (P(None) == fully replicated 1-D)
    assert sh.param_pspec("blocks/0/norm1", _leaf((1024,)), m) == P(None)


def test_param_rules_moe_expert_parallel():
    m = FakeMesh()
    # fine-grained bank (deepseek-moe: 69MB/shard after TP) stays unsharded
    # over E — grouped local-capacity dispatch, zero token movement; FSDP
    # adds 'data' storage sharding on the biggest free dim (>32MB/shard)
    spec = sh.param_pspec("blocks/0/moe/wi_gate",
                          _leaf((27, 64, 2048, 1408)), m)
    assert spec == P(None, None, "data", "model")
    spec = sh.param_pspec("blocks/0/moe/wo", _leaf((27, 64, 1408, 2048)), m)
    assert spec == P(None, None, "model", "data")
    # a bank too big to keep resident (>4GB/shard after TP) goes
    # expert-parallel over data
    spec = sh.param_pspec("blocks/0/moe/wi_gate",
                          _leaf((36, 64, 8192, 24576)), m)
    assert spec == P(None, "data", None, "model")
    spec = sh.param_pspec("blocks/0/moe/wo", _leaf((36, 64, 24576, 8192)), m)
    assert spec == P(None, "data", "model", None)


def test_fsdp_added_for_large_params():
    m = FakeMesh()
    # deepseek-33b mlp wi: (62, 7168, 19200) bf16: per model-shard 148MB
    spec = sh.param_pspec("blocks/0/mlp/wi_up", _leaf((62, 7168, 19200)), m)
    assert spec == P(None, "data", "model")
    # small layer stays TP-only
    spec = sh.param_pspec("blocks/0/mlp/wi_up", _leaf((2, 64, 128)), m)
    assert spec == P(None, None, "model")


def test_degradation_on_indivisible():
    m = FakeMesh()
    rep = sh.ShardingReport()
    spec = sh.param_pspec("blocks/0/attn/wq", _leaf((2, 30, 30)), m,
                          report=rep)
    assert spec == P(None, None, None)
    assert rep.degraded


@pytest.mark.parametrize("name", arch_names())
def test_no_degradations_for_full_archs(name):
    """Every parameter of every assigned arch shards cleanly on 16x16."""
    cfg = get_arch(name)
    params = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    rep = sh.ShardingReport()
    m = FakeMesh()
    from repro.utils.tree import tree_map_with_path_names
    tree_map_with_path_names(
        lambda n, l: sh.param_pspec(n, l, m, cfg, rep), params
    )
    assert rep.degraded == [], (name, rep.degraded[:5])


def test_batch_specs():
    m = FakeMesh()
    assert sh.batch_pspec("b", _leaf((256, 4096), jnp.int32), m) == \
        P("data", None)
    assert sh.batch_pspec("b", _leaf((1, 1), jnp.int32), m) == P()
    assert sh.batch_pspec("b", _leaf((16, 16, 4096), jnp.int32), m,
                          micro=True) == P(None, "data", None)
    mp = FakeMeshMP()
    assert sh.batch_pspec("b", _leaf((256, 4096), jnp.int32), mp) == \
        P(("pod", "data"), None)


def test_decode_state_specs():
    m = FakeMesh()
    # KV cache (reps, B, S, Hk_eff, Dh): with kv replication Hk_eff=16
    # shards over model (zero-comm attention)
    assert sh.decode_state_pspec("layers/0/0",
                                 _leaf((28, 128, 32768, 16, 128)), m) == \
        P(None, "data", None, "model", None)
    # unreplicated kv=8: falls to sequence sharding
    assert sh.decode_state_pspec("layers/0/0",
                                 _leaf((28, 128, 32768, 8, 128)), m) == \
        P(None, "data", "model", None, None)
    # long-context B=1: sequence sharding
    assert sh.decode_state_pspec("layers/0/0",
                                 _leaf((9, 1, 524288, 8, 128)), m) == \
        P(None, None, "data", None, None)
    # recurrent state B=1: feature sharding over model
    assert sh.decode_state_pspec("layers/0/1", _leaf((9, 1, 16384, 16)), m) \
        == P(None, None, "data", None)


# ---------------------------------------------------------------------------
# roofline HLO parsing
# ---------------------------------------------------------------------------

HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[16,512]{1,0} parameter(0)
  %ag = bf16[16,8192]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[1024]{0} all-reduce(%conv), to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%ar), dimensions={0}
  %cp = bf16[16,512]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %a2a = bf16[16,512]{1,0} all-to-all(%p0), dimensions={0}
}
"""


def test_collective_parser():
    colls = rl.parse_collectives(HLO)
    kinds = sorted(c.kind for c in colls)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]
    ag = next(c for c in colls if c.kind == "all-gather")
    assert ag.operand_bytes == 16 * 512 * 2
    assert ag.result_bytes == 16 * 8192 * 2
    assert ag.moved_bytes == ag.result_bytes - ag.operand_bytes
    ar = next(c for c in colls if c.kind == "all-reduce")
    assert ar.moved_bytes == 2 * ar.operand_bytes


def test_roofline_terms():
    r = rl.Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=0,
                    collectives={})
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert r.dominant in ("compute", "memory")
    r2 = rl.Roofline(flops=1, hbm_bytes=1, coll_bytes=50e9, collectives={})
    assert r2.dominant == "collective"


def test_model_flops():
    assert rl.model_flops_train(1e9, 1000) == 6e12
    assert rl.model_flops_infer(1e9, 1) == 2e9


def test_active_param_count_moe():
    cfg = get_arch("deepseek-moe-16b")
    total = api.param_count(cfg)
    active = api.active_param_count(cfg)
    assert active < total
    # 27 MoE layers x 58 inactive experts x 3*2048*1408
    assert total - active == 27 * 58 * 3 * 2048 * 1408


# ---------------------------------------------------------------------------
# launch/report.py rendering
# ---------------------------------------------------------------------------

def _ok_cell(uf):
    c = {
        "arch": "a", "shape": "s", "mesh": "16x16", "status": "ok",
        "roofline": {"compute_s": 0.5, "memory_s": 0.2, "collective_s": 0.1,
                     "dominant": "compute"},
        "mem": {"peak_gb": 1.0},
    }
    if uf is not None:
        c["useful_flops_frac"] = uf
    return c


def test_report_zero_useful_flops_renders_as_value():
    """useful_flops_frac == 0.0 is a measurement, not a missing field: it
    must render as 0.00, while an absent field renders as em-dash."""
    from repro.launch import report

    line_zero = report.roofline_lines([_ok_cell(0.0)])[2]
    assert "| 0.00 |" in line_zero and "| — |" not in line_zero
    line_missing = report.roofline_lines([_ok_cell(None)])[2]
    assert "| — |" in line_missing
    line_half = report.roofline_lines([_ok_cell(0.5)])[2]
    assert "| 0.50 |" in line_half


def test_report_stream_table_renders_sweep_and_sharded():
    from repro.launch import report

    bench = {
        "sweep": {"8": {"hop_ms_p50": 1.5, "hop_ms_p99": 3.8,
                        "host_pack_ms_p50": 0.2,
                        "device_ms_p50": 1.3,
                        "stream_hops_per_sec": 4000.0,
                        "uj_per_inference": 0.0005}},
        "sharded": {
            "total_streams": 1024,
            "configs": {
                "1": {"hop_ms_p50": 180.0, "host_pack_ms_p50": 4.0,
                      "device_ms_p50": 176.0,
                      "stream_hops_per_sec": 5000.0,
                      "uj_per_inference": 0.0005},
                "8": {"hop_ms_p50": 150.0, "host_pack_ms_p50": 4.0,
                      "device_ms_p50": 146.0,
                      "stream_hops_per_sec": 6000.0,
                      "uj_per_inference": 0.0005},
            },
            "multi_vs_single": 1.2,
        },
        "host_pack": {"streams": 1024.0, "host_pack_ms_before": 20.0,
                      "host_pack_ms_after": 2.0, "reduction": 10.0},
    }
    lines = report.stream_lines(bench)
    text = "\n".join(lines)
    assert ("| steady | 8 | 1 | 1.500 | 3.800 | 0.200 | 1.300 "
            "| 4000 | 0.0005 |") in text
    assert ("| mesh-sharded | 1024 | 8 | 150.000 | — | 4.000 | 146.000 "
            "| 6000 | 0.0005 |") in text
    assert "1.20x aggregate stream-hops/s" in text
    assert "10.0x" in text  # host-pack before/after footer
    # rows missing the newer fields (older artifacts) degrade to em-dash;
    # a measured 0.0 in any column must still render as a number, and a
    # NaN (empty latency window) must render as em-dash, never 0.0
    legacy = report.stream_lines(
        {"sweep": {"8": {"hop_ms_p50": 1.5, "hop_ms_p99": float("nan"),
                         "host_pack_ms_p50": 0.0}}}
    )
    assert ("| steady | 8 | 1 | 1.500 | — | 0.000 | — | — | — |"
            in "\n".join(legacy))
