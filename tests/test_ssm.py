"""Recurrent blocks: chunked-parallel vs sequential equivalence; step vs
prefill state consistency (the long-context serving contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm

KEY = jax.random.PRNGKey(0)


def test_mlstm_chunked_matches_sequential():
    B, S, D, H = 2, 64, 32, 4
    p = ssm.init_mlstm(KEY, D, H)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)) * 0.5
    out_c, st_c = ssm.mlstm_prefill(p, x, n_heads=H, chunk=16)
    out_s, st_s = ssm.mlstm_prefill_sequential(p, x, n_heads=H)
    np.testing.assert_allclose(np.asarray(out_c, np.float32),
                               np.asarray(out_s, np.float32),
                               rtol=1e-2, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_c[0]), np.asarray(st_s[0]),
                               rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("chunk", [8, 32, 64])
def test_mlstm_chunk_size_invariance(chunk):
    B, S, D, H = 1, 64, 16, 2
    p = ssm.init_mlstm(KEY, D, H)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, D))
    ref, _ = ssm.mlstm_prefill(p, x, n_heads=H, chunk=S)
    got, _ = ssm.mlstm_prefill(p, x, n_heads=H, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=1e-2, atol=1e-3)


def test_mlstm_prefill_then_step_continues():
    B, S, D, H = 1, 32, 16, 2
    p = ssm.init_mlstm(KEY, D, H)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S + 1, D))
    full, _ = ssm.mlstm_prefill(p, x, n_heads=H, chunk=8)
    _, st = ssm.mlstm_prefill(p, x[:, :S], n_heads=H, chunk=8)
    step_out, _ = ssm.mlstm_step(p, x[:, S:], st, n_heads=H)
    np.testing.assert_allclose(np.asarray(step_out[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=1e-2, atol=1e-3)


def test_mamba_prefill_then_step_continues():
    B, S, D = 1, 40, 16
    p = ssm.init_mamba(KEY, D, d_state=8)
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S + 1, D))
    full, _ = ssm.mamba_prefill(p, x, d_state=8)
    _, st = ssm.mamba_prefill(p, x[:, :S], d_state=8)
    step_out, _ = ssm.mamba_step(p, x[:, S:], st, d_state=8)
    np.testing.assert_allclose(np.asarray(step_out[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=1e-2, atol=1e-3)


def test_slstm_prefill_then_step_continues():
    B, S, D = 2, 24, 16
    p = ssm.init_slstm(KEY, D, 1)
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S + 1, D))
    full, _ = ssm.slstm_prefill(p, x)
    _, st = ssm.slstm_prefill(p, x[:, :S])
    step_out, _ = ssm.slstm_step(p, x[:, S:], st)
    np.testing.assert_allclose(np.asarray(step_out[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=1e-3, atol=1e-4)


def test_mamba_state_shapes():
    st = ssm.mamba_init_state(3, 8, d_state=4, d_conv=4, expand=2)
    assert st[0].shape == (3, 16, 4) and st[1].shape == (3, 3, 16)
