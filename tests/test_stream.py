"""Streaming runtime (repro.stream): golden equivalence with the offline
executor, ring-buffer wraparound, mid-batch join/leave, the in-jit
finalization tail (per-hop logits == offline prefix), elastic slot-pool
resize boundaries, detector hysteresis, and the batched Pallas kernels."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler, executor
from repro.kernels import ops, ref
from repro.models import kws
from repro.stream import (
    DetectorConfig,
    FrameRing,
    PosteriorDetector,
    StreamScheduler,
    StreamState,
    plan_stream,
)
from repro.stream.detector import _softmax

RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def smoke():
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(spec, weights, thresholds)
    return spec, weights, thresholds, prog


def _offline(prog, x):
    return executor.Executor(prog).run(x[:, None]).output.ravel()


def _clip(spec, seed):
    return np.random.default_rng(seed).integers(
        0, 256, (spec.in_len,)
    ).astype(np.uint8)


# ---------------------------------------------------------------------------
# Plan geometry
# ---------------------------------------------------------------------------

def test_plan_steady_state_geometry(smoke):
    spec, *_ = smoke
    plan = plan_stream(spec)
    # hop = prod(stride*pool) per final frame; KWS: 8*1 * 1*2 * 1*2 * 1*2
    assert plan.hop_samples == 64 and plan.frames_per_hop == 1
    n_in = plan.hop_samples
    for st in plan.convs:
        assert st.n_in == n_in
        assert st.n_conv * st.stride == st.n_in
        assert st.n_conv % st.pool == 0
        assert 0 <= st.phase < st.pool
        n_in = st.n_out
    # larger hops scale every stage linearly
    plan4 = plan_stream(spec, hop_frames=4)
    assert plan4.hop_samples == 256 and plan4.frames_per_hop == 4


def test_plan_flush_geometry(smoke):
    """The static finalization-tail counts must match both the count model
    and what a real numpy flush emits from the steady state."""
    spec, weights, thresholds, _ = smoke
    for hf in (1, 4):
        plan = plan_stream(spec, hop_frames=hf)
        f_in = 0
        for st in plan.convs:
            assert st.flush_in == f_in
            avail = st.tail + f_in + st.pad
            want = (avail - st.k) // st.stride + 1 if avail >= st.k else 0
            assert st.flush_conv == want
            assert st.flush_out == (st.phase + st.flush_conv) // st.pool
            f_in = st.flush_out
        # a primed stream's ghost flush emits exactly flush_out final frames
        st0 = StreamState(plan, weights, thresholds)
        st0.advance(_clip(spec, 9)[: plan.prime_samples + plan.hop_samples])
        ghost = st0.clone()
        emitted = ghost.advance(np.zeros((0,), np.int32), flush=True)
        assert emitted.shape[0] == plan.convs[-1].flush_out


# ---------------------------------------------------------------------------
# Ring buffer
# ---------------------------------------------------------------------------

def test_frame_ring_wraparound():
    ring = FrameRing(7, 3)
    total_in, total_out = [], []
    for i in range(25):  # pointers lap the 7-slot region multiple times
        f = np.full((2, 3), i)
        ring.push(f)
        total_in.append(f)
        got = ring.pop(2 if i % 2 else 1)
        total_out.append(got)
        if i % 2 == 0:
            total_out.append(ring.pop(1))
    np.testing.assert_array_equal(
        np.concatenate(total_in), np.concatenate(total_out)
    )
    assert len(ring) == 0
    assert ring.wr == ring.rd == 50  # monotonic counters, wrapped storage


def test_frame_ring_over_underflow():
    ring = FrameRing(4, 1)
    ring.push(np.ones((3, 1)))
    with pytest.raises(MemoryError):
        ring.push(np.ones((2, 1)))
    with pytest.raises(MemoryError):
        ring.pop(4)
    assert len(ring) == 3  # failed ops leave the ring intact


def test_stream_state_rings_wrap(smoke):
    """Tiny ring slack forces every hist ring to wrap many times; the
    results must not change."""
    spec, weights, thresholds, prog = smoke
    plan = plan_stream(spec)
    x = _clip(spec, 1)
    big = StreamState(plan, weights, thresholds)
    small = StreamState(plan, weights, thresholds, ring_slack=plan.hop_samples)
    for st in (big, small):
        for i in range(0, spec.in_len, 160):
            st.advance(x[i : i + 160])
        st.advance(np.zeros((0,), np.int32), flush=True)
    np.testing.assert_array_equal(big.logits(), small.logits())
    np.testing.assert_array_equal(big.logits(), _offline(prog, x))


# ---------------------------------------------------------------------------
# Golden equivalence: streaming == offline executor
# ---------------------------------------------------------------------------

def test_stream_matches_offline_full_clip(smoke):
    spec, weights, thresholds, prog = smoke
    plan = plan_stream(spec)
    x = _clip(spec, 2)
    st = StreamState(plan, weights, thresholds)
    i = 0
    for sz in itertools.cycle([37, 200, 111, 64, 5]):  # ragged chunks
        st.advance(x[i : i + sz])
        i += sz
        if i >= spec.in_len:
            break
    st.advance(x[i:] if i < spec.in_len else np.zeros((0,), np.int32),
               flush=True)
    np.testing.assert_array_equal(st.logits(), _offline(prog, x))


@pytest.mark.parametrize("prefix", [320, 520, 648])
def test_stream_peek_matches_offline_prefix(smoke, prefix):
    """Per-frame logits contract: peek after audio[:L] == offline run on
    audio[:L] (same weights, shorter program)."""
    spec, weights, thresholds, _ = smoke
    x = _clip(spec, 3)
    spec_l = kws.build_kws_spec(in_len=prefix, width=16)
    prog_l = compiler.compile_model(spec_l, weights, thresholds)
    st = StreamState(plan_stream(spec), weights, thresholds)
    st.advance(x[: prefix - 100])
    st.advance(x[prefix - 100 : prefix])
    np.testing.assert_array_equal(
        st.peek_logits(), _offline(prog_l, x[:prefix])
    )
    assert not st.flushed  # peek is non-destructive
    st.advance(x[prefix:], flush=True)  # stream continues normally


# ---------------------------------------------------------------------------
# Scheduler: continuous batching, join/leave mid-batch
# ---------------------------------------------------------------------------

def test_scheduler_join_leave_mid_batch(smoke):
    spec, weights, thresholds, prog = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=3,
                            hop_frames=1, emit_logits=False)
    clips = {j: _clip(spec, 10 + j) for j in range(4)}
    want = {j: _offline(prog, clips[j]) for j in range(4)}

    a = sched.add_stream()
    b = sched.add_stream()
    sched.push_audio(a, clips[0][:500])
    sched.push_audio(b, clips[1][:200])  # b is a straggler
    sched.run_until_starved()

    # c joins while a/b are mid-flight
    c = sched.add_stream()
    sched.push_audio(c, clips[2])
    sched.push_audio(a, clips[0][500:])
    sched.run_until_starved()

    # a leaves; its slot is recycled by d mid-run
    res_a = sched.close_stream(a)
    np.testing.assert_array_equal(res_a.logits, want[0])
    d = sched.add_stream()
    sched.push_audio(d, clips[3])
    sched.push_audio(b, clips[1][200:])
    sched.run_until_starved()

    for sid, j in ((b, 1), (c, 2), (d, 3)):
        res = sched.close_stream(sid)
        np.testing.assert_array_equal(res.logits, want[j])
    assert sched.active == []


def test_scheduler_peek_matches_offline_prefix(smoke):
    spec, weights, thresholds, _ = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=2,
                            emit_logits=False)
    x = _clip(spec, 20)
    prefix = 520
    spec_l = kws.build_kws_spec(in_len=prefix, width=16)
    off = _offline(compiler.compile_model(spec_l, weights, thresholds),
                   x[:prefix])
    sid = sched.add_stream()
    sched.push_audio(sid, x[:prefix])
    sched.run_until_starved()  # leaves a sub-hop remainder in the inbox
    np.testing.assert_array_equal(sched.peek(sid), off)


def test_scheduler_capacity_enforced(smoke):
    spec, weights, thresholds, _ = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=1)
    sched.add_stream()
    with pytest.raises(MemoryError):
        sched.add_stream()


# ---------------------------------------------------------------------------
# In-jit finalization tail: per-hop logits == offline executor on the prefix
# ---------------------------------------------------------------------------

def test_scheduler_hop_logits_match_offline_prefix(smoke):
    """Each hop's emitted logits (computed on-device by the fused
    finalization tail) equal an offline executor run over exactly the
    samples consumed so far."""
    spec, weights, thresholds, _ = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=2)
    plan = sched.plan
    x = _clip(spec, 40)
    sid = sched.add_stream()
    sched.push_audio(sid, x[: spec.in_len // 2])
    outs = sched.run_until_starved()
    assert len(outs) >= 2
    for hop_i in (0, len(outs) - 1):  # first and latest hop boundaries
        consumed = plan.prime_samples + (hop_i + 1) * plan.hop_samples
        spec_l = kws.build_kws_spec(in_len=consumed, width=16)
        prog_l = compiler.compile_model(spec_l, weights, thresholds)
        np.testing.assert_array_equal(
            outs[hop_i][2], _offline(prog_l, x[:consumed])
        )


def test_scheduler_peek_on_hop_boundary_uses_device_tail(smoke):
    """peek() with an empty inbox reads the in-jit tail and must agree with
    the logits emitted at the last hop."""
    spec, weights, thresholds, _ = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=2)
    plan = sched.plan
    x = _clip(spec, 41)
    sid = sched.add_stream()
    sched.push_audio(sid, x[: plan.prime_samples + 2 * plan.hop_samples])
    outs = sched.run_until_starved()
    assert len(outs) == 2 and len(sched._streams[sid].frontend) == 0
    np.testing.assert_array_equal(sched.peek(sid), outs[-1][2])


def test_scheduler_peek_cached_across_masked_steps(smoke):
    """A stream idle at a hop boundary keeps peeking its own last logits
    (served from the emit cache) while OTHER streams advance — masked
    rows ride through later finalizations unchanged."""
    spec, weights, thresholds, _ = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=2)
    plan = sched.plan
    a, b = sched.add_stream(), sched.add_stream()
    xa, xb = _clip(spec, 43), _clip(spec, 44)
    sched.push_audio(a, xa[: plan.prime_samples + 2 * plan.hop_samples])
    sched.push_audio(b, xb[: plan.prime_samples + 2 * plan.hop_samples])
    outs = sched.run_until_starved()
    want_a = [o[2] for o in outs if o[0] == a][-1]
    # only b advances now; a sits masked at its hop boundary
    sched.push_audio(b, xb[plan.prime_samples + 2 * plan.hop_samples :
                           plan.prime_samples + 4 * plan.hop_samples])
    sched.run_until_starved()
    np.testing.assert_array_equal(sched.peek(a), want_a)
    # and a freshly primed stream (no emit step yet) still peeks exactly
    c_sched = StreamScheduler(spec, weights, thresholds, capacity=2)
    c = c_sched.add_stream()
    c_sched.push_audio(c, xa[: c_sched.plan.prime_samples])
    c_sched.step()  # primes c; nothing ready -> no emit
    spec_l = kws.build_kws_spec(in_len=c_sched.plan.prime_samples, width=16)
    prog_l = compiler.compile_model(spec_l, weights, thresholds)
    np.testing.assert_array_equal(
        c_sched.peek(c), _offline(prog_l, xa[: c_sched.plan.prime_samples])
    )


def test_scheduler_pallas_hop_logits_match_jnp(smoke):
    """The pallas step + fused classifier-tail kernel emit the same per-hop
    logits as the jnp reference path."""
    spec, weights, thresholds, _ = smoke
    x = _clip(spec, 42)
    outs = {}
    for backend in ("jnp", "pallas"):
        sched = StreamScheduler(spec, weights, thresholds, capacity=2,
                                hop_frames=4, backend=backend)
        sid = sched.add_stream()
        sched.push_audio(sid, x)
        outs[backend] = sched.run_until_starved()
    assert len(outs["jnp"]) == len(outs["pallas"]) >= 1
    for a, b in zip(outs["jnp"], outs["pallas"]):
        assert a[:2] == b[:2]
        np.testing.assert_array_equal(a[2], b[2])


# ---------------------------------------------------------------------------
# Elastic slot pool: grow/shrink resize boundaries are bit-exact
# ---------------------------------------------------------------------------

def test_scheduler_elastic_capacity_lifecycle(smoke):
    spec, weights, thresholds, _ = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=4)
    assert sched.capacity == 2 and sched.max_capacity == 4
    sids = [sched.add_stream() for _ in range(4)]  # forces a 2 -> 4 grow
    assert sched.capacity == 4
    with pytest.raises(MemoryError):
        sched.add_stream()  # ceiling still enforced
    for sid in sids:
        sched.close_stream(sid)
    assert sched.capacity == 2  # pool shrank back
    assert sched.metrics.summary()["resizes"] >= 2.0


def test_scheduler_grow_shrink_bitexact(smoke):
    """A stream fed across a 4->8 grow and an 8->4 shrink produces per-hop
    and flushed logits bit-identical to a fixed-capacity run."""
    spec, weights, thresholds, prog = smoke
    clips = {j: _clip(spec, 60 + j) for j in range(8)}
    want = {j: _offline(prog, clips[j]) for j in range(8)}
    el = StreamScheduler(spec, weights, thresholds, capacity=8,
                         initial_capacity=4)
    fx = StreamScheduler(spec, weights, thresholds, capacity=8,
                         initial_capacity=8, min_capacity=8)  # pinned pool

    def lockstep(stage):
        a = el.run_until_starved()
        b = fx.run_until_starved()
        assert len(a) == len(b), stage
        for ea, eb in zip(a, b):
            assert ea[:2] == eb[:2], stage
            np.testing.assert_array_equal(ea[2], eb[2])
        return a

    # 4 streams fit the elastic pool's initial capacity exactly
    for sched in (el, fx):
        sids = [sched.add_stream() for _ in range(4)]
        assert sids == list(range(4))
        for j in range(4):
            sched.push_audio(j, clips[j][:300])
    lockstep("warm")
    assert el.capacity == 4

    # 4 more join -> elastic pool grows 4 -> 8 with streams 0..3 mid-flight
    for sched in (el, fx):
        for j in range(4, 8):
            assert sched.add_stream() == j
            sched.push_audio(j, clips[j][:600] if j >= 6 else clips[j])
        for j in range(4):
            sched.push_audio(j, clips[j][300:])
    lockstep("grow")
    assert el.capacity == 8

    # streams 0..5 leave -> pool shrinks 8 -> 4, relocating the survivors
    # (sids 6/7) out of the doomed upper slots
    for sched in (el, fx):
        for j in range(6):
            res = sched.close_stream(j)
            np.testing.assert_array_equal(res.logits, want[j])
    assert el.capacity == 4 and fx.capacity == 8
    assert {el._streams[j].slot for j in (6, 7)} <= {0, 1, 2, 3}

    # survivors keep streaming across the shrink boundary, then flush
    for sched in (el, fx):
        for j in (6, 7):
            sched.push_audio(j, clips[j][600:])
    lockstep("shrink")
    for sched in (el, fx):
        for j in (6, 7):
            res = sched.close_stream(j)
            np.testing.assert_array_equal(res.logits, want[j])
    grows = [c for _, c in el.metrics.capacity_events]
    assert 8 in grows and 4 in grows  # both directions actually happened


# ---------------------------------------------------------------------------
# Batched Pallas kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "b,l,cin,cout,k,stride,pad,pool",
    [
        (3, 40, 8, 16, 3, 1, 1, 1),
        (8, 32, 24, 40, 3, 1, 1, 2),
        (5, 66, 16, 20, 5, 2, 2, 1),
    ],
)
def test_bnn_conv1d_batched_kernel(b, l, cin, cout, k, stride, pad, pool):
    x = jnp.array(RNG.integers(0, 2, (b, l, cin)), jnp.uint32)
    w = jnp.array(RNG.integers(-1, 2, (k, cin, cout)), jnp.int32)
    thr = jnp.array(RNG.normal(0, 2, (cout,)), jnp.float32)
    flip = jnp.array(RNG.integers(0, 2, (cout,)), bool)
    raw = ops.bnn_conv1d_batched(x, w, stride=stride, pad=pad, mode="raw")
    np.testing.assert_array_equal(
        np.asarray(raw),
        np.asarray(ref.ref_bnn_conv1d_batched(x, w, stride, pad)),
    )
    sa = ops.bnn_conv1d_batched(x, w, thr, flip, stride=stride, pad=pad,
                                pool=pool)
    want = jnp.stack([
        ref.ref_bnn_conv1d_sa(x[i], w, thr, flip, stride, pad, pool)
        for i in range(b)
    ])
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(want))


def test_classifier_tail_kernel_matches_oracle():
    """Fused GAP-saturate + fc cascade kernel vs StreamState.logits math."""
    rng = np.random.default_rng(11)
    b, c, h_dim, ncls = 5, 24, 40, 12
    gap = rng.integers(0, 400, (b, c)).astype(np.int32)  # exceeds 255 ceiling
    w1 = rng.integers(-1, 2, (c, h_dim)).astype(np.int32)
    w2 = rng.integers(-1, 2, (h_dim, ncls)).astype(np.int32)
    thr1 = rng.integers(-5, 6, (h_dim,)).astype(np.float64)
    flip1 = rng.integers(0, 2, (h_dim,)).astype(bool)
    # numpy oracle: int64 math, float64 compare (StreamState.logits)
    h = np.minimum(gap.astype(np.int64), 255)
    raw = h @ w1.astype(np.int64)
    ge = raw >= thr1[None, :]
    h = np.where(flip1[None, :], ~ge, ge).astype(np.int64)
    want = h @ w2.astype(np.int64)
    got = ops.classifier_tail(
        jnp.asarray(gap),
        (jnp.asarray(w1), jnp.asarray(w2)),
        (jnp.asarray(thr1, jnp.float32), jnp.zeros((ncls,), jnp.float32)),
        (jnp.asarray(flip1, jnp.int32), jnp.zeros((ncls,), jnp.int32)),
        out_raw=(False, True),
    )
    np.testing.assert_array_equal(np.asarray(got), want.astype(np.int32))


def test_scheduler_pallas_backend_matches_offline(smoke):
    spec, weights, thresholds, prog = smoke
    x = _clip(spec, 30)
    sched = StreamScheduler(spec, weights, thresholds, capacity=2,
                            hop_frames=4, backend="pallas",
                            emit_logits=False)
    sid = sched.add_stream()
    sched.push_audio(sid, x)
    sched.run_until_starved()
    res = sched.close_stream(sid)
    np.testing.assert_array_equal(res.logits, _offline(prog, x))


# ---------------------------------------------------------------------------
# Streaming energy: measured ledger charges, all Table-I components
# ---------------------------------------------------------------------------

def test_stream_energy_ledger_covers_all_components(smoke):
    """Each hop charges the executor's EnergyLedger from the static plan:
    the summary must carry real (non-zero) SA/SRAM/controller components,
    not just e_mac, and scale linearly with hops executed."""
    spec, weights, thresholds, _ = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=2)
    plan = sched.plan
    sid = sched.add_stream()
    x = _clip(spec, 70)
    sched.push_audio(sid, x[: plan.prime_samples + 4 * plan.hop_samples])
    outs = sched.run_until_starved()
    assert len(outs) == 4
    e = sched.metrics.energy_summary()
    for k in ("e_mac_uj", "e_sa_uj", "e_sram_uj", "e_ctrl_uj"):
        assert e[k] > 0.0, k
    assert e["energy_uj"] == pytest.approx(
        e["e_mac_uj"] + e["e_sa_uj"] + e["e_sram_uj"] + e["e_ctrl_uj"]
    )
    assert e["tops_per_w_equiv"] > 0
    # 4 hops, 4 finalizations: per-inference energy is the per-hop charge
    assert e["uj_per_inference"] == pytest.approx(e["energy_uj"] / 4)
    # the conv-cascade MAC count must match the plan's static budget
    from repro.stream import plan_hop_ledger
    hop = plan_hop_ledger(plan)
    assert hop.macs == plan.macs_per_hop()
    # another 2 hops scale every component linearly
    sched.push_audio(
        sid, x[plan.prime_samples + 4 * plan.hop_samples :
               plan.prime_samples + 6 * plan.hop_samples]
    )
    sched.run_until_starved()
    e2 = sched.metrics.energy_summary()
    assert e2["energy_uj"] == pytest.approx(e["energy_uj"] * 6 / 4)


def test_stream_energy_tail_only_when_finalizing(smoke):
    """With emit_logits off the classifier tail is never executed, so its
    fc MACs must not be charged."""
    spec, weights, thresholds, _ = smoke
    runs = {}
    for emit in (True, False):
        sched = StreamScheduler(spec, weights, thresholds, capacity=2,
                                emit_logits=emit)
        sid = sched.add_stream()
        x = _clip(spec, 71)
        sched.push_audio(
            sid, x[: sched.plan.prime_samples + 2 * sched.plan.hop_samples]
        )
        sched.run_until_starved()
        runs[emit] = sched.metrics
    on, off = runs[True], runs[False]
    fc_macs_per_hop = on.plan.fc_macs()
    assert on.ledger.macs - off.ledger.macs == 2 * fc_macs_per_hop
    assert off.finalizations == 0 and on.finalizations == 2
    assert off.energy_summary()["uj_per_inference"] == 0.0


# ---------------------------------------------------------------------------
# Detector hysteresis
# ---------------------------------------------------------------------------

def _logit(cls: int, strength: float = 30.0, n: int = 12) -> np.ndarray:
    z = np.zeros(n)
    z[cls] = strength
    return z


def test_detector_fires_once_per_utterance():
    cfg = DetectorConfig(smooth_frames=2, on_threshold=0.6,
                         off_threshold=0.4, refractory_frames=5)
    det = PosteriorDetector(0, cfg)
    events = []
    for f in range(10):  # sustained keyword: must fire exactly once
        e = det.update(f, _logit(3))
        if e:
            events.append(e)
    assert [e.cls for e in events] == [3]
    assert events[0].score >= cfg.on_threshold


def test_detector_refractory_blocks_refire():
    cfg = DetectorConfig(smooth_frames=1, on_threshold=0.6,
                         off_threshold=0.4, refractory_frames=8)
    det = PosteriorDetector(0, cfg)
    assert det.update(0, _logit(2)) is not None
    # dips below off-threshold immediately, but refractory still holds
    assert det.update(1, _logit(11)) is None
    assert det.update(2, _logit(2)) is None  # inside refractory: no refire
    # silence until refractory expires, then a new utterance fires again
    for f in range(3, 9):
        assert det.update(f, _logit(11)) is None
    e = det.update(9, _logit(5))
    assert e is not None and e.cls == 5


def test_detector_no_fire_before_window_full():
    # a confident-wrong first frame (right after priming) must not bypass
    # the smoother just because the window is still partial
    cfg = DetectorConfig(smooth_frames=4, on_threshold=0.6,
                         off_threshold=0.4, refractory_frames=4)
    det = PosteriorDetector(0, cfg)
    assert det.update(0, _logit(3)) is None
    assert det.update(1, _logit(11)) is None
    assert det.events == []


def test_detector_smoothing_suppresses_single_frame_glitch():
    cfg = DetectorConfig(smooth_frames=4, on_threshold=0.6,
                         off_threshold=0.4, refractory_frames=4)
    det = PosteriorDetector(0, cfg)
    for f in range(4):
        assert det.update(f, _logit(11)) is None
    # one glitch frame inside a 4-frame window: smoothed posterior ~0.25
    assert det.update(4, _logit(6)) is None
    assert det.update(5, _logit(11)) is None
    assert det.events == []


def test_detector_update_posterior_matches_update():
    """Feeding device-computed posteriors must drive the state machine
    exactly like feeding raw logits (the scheduler's per-hop path)."""
    cfg = DetectorConfig(smooth_frames=2, on_threshold=0.4,
                         off_threshold=0.2, refractory_frames=3)
    via_logits = PosteriorDetector(0, cfg)
    via_post = PosteriorDetector(0, cfg)
    rng = np.random.default_rng(13)
    for f in range(40):
        z = rng.normal(0, 8, 12)
        ea = via_logits.update(f, z)
        eb = via_post.update_posterior(f, _softmax(z))
        assert (ea is None) == (eb is None)
    assert [(e.cls, e.frame) for e in via_logits.events] == [
        (e.cls, e.frame) for e in via_post.events
    ]
    assert via_logits.events  # the random walk actually fired


def test_detector_hysteresis_rearm_requires_decay():
    cfg = DetectorConfig(smooth_frames=1, on_threshold=0.6,
                         off_threshold=0.4, refractory_frames=2)
    det = PosteriorDetector(0, cfg)
    assert det.update(0, _logit(1)) is not None
    # posterior stays above off_threshold long past refractory: still held
    for f in range(1, 10):
        assert det.update(f, _logit(1)) is None
    # decays -> re-arms -> new event
    assert det.update(10, _logit(11)) is None
    e = det.update(11, _logit(1))
    assert e is not None and e.frame == 11
