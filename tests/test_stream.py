"""Streaming runtime (repro.stream): golden equivalence with the offline
executor, ring-buffer wraparound, mid-batch join/leave, detector hysteresis,
and the batched Pallas kernel."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler, executor
from repro.kernels import ops, ref
from repro.models import kws
from repro.stream import (
    DetectorConfig,
    FrameRing,
    PosteriorDetector,
    StreamScheduler,
    StreamState,
    plan_stream,
)

RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def smoke():
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(spec, weights, thresholds)
    return spec, weights, thresholds, prog


def _offline(prog, x):
    return executor.Executor(prog).run(x[:, None]).output.ravel()


def _clip(spec, seed):
    return np.random.default_rng(seed).integers(
        0, 256, (spec.in_len,)
    ).astype(np.uint8)


# ---------------------------------------------------------------------------
# Plan geometry
# ---------------------------------------------------------------------------

def test_plan_steady_state_geometry(smoke):
    spec, *_ = smoke
    plan = plan_stream(spec)
    # hop = prod(stride*pool) per final frame; KWS: 8*1 * 1*2 * 1*2 * 1*2
    assert plan.hop_samples == 64 and plan.frames_per_hop == 1
    n_in = plan.hop_samples
    for st in plan.convs:
        assert st.n_in == n_in
        assert st.n_conv * st.stride == st.n_in
        assert st.n_conv % st.pool == 0
        assert 0 <= st.phase < st.pool
        n_in = st.n_out
    # larger hops scale every stage linearly
    plan4 = plan_stream(spec, hop_frames=4)
    assert plan4.hop_samples == 256 and plan4.frames_per_hop == 4


# ---------------------------------------------------------------------------
# Ring buffer
# ---------------------------------------------------------------------------

def test_frame_ring_wraparound():
    ring = FrameRing(7, 3)
    total_in, total_out = [], []
    for i in range(25):  # pointers lap the 7-slot region multiple times
        f = np.full((2, 3), i)
        ring.push(f)
        total_in.append(f)
        got = ring.pop(2 if i % 2 else 1)
        total_out.append(got)
        if i % 2 == 0:
            total_out.append(ring.pop(1))
    np.testing.assert_array_equal(
        np.concatenate(total_in), np.concatenate(total_out)
    )
    assert len(ring) == 0
    assert ring.wr == ring.rd == 50  # monotonic counters, wrapped storage


def test_frame_ring_over_underflow():
    ring = FrameRing(4, 1)
    ring.push(np.ones((3, 1)))
    with pytest.raises(MemoryError):
        ring.push(np.ones((2, 1)))
    with pytest.raises(MemoryError):
        ring.pop(4)
    assert len(ring) == 3  # failed ops leave the ring intact


def test_stream_state_rings_wrap(smoke):
    """Tiny ring slack forces every hist ring to wrap many times; the
    results must not change."""
    spec, weights, thresholds, prog = smoke
    plan = plan_stream(spec)
    x = _clip(spec, 1)
    big = StreamState(plan, weights, thresholds)
    small = StreamState(plan, weights, thresholds, ring_slack=plan.hop_samples)
    for st in (big, small):
        for i in range(0, spec.in_len, 160):
            st.advance(x[i : i + 160])
        st.advance(np.zeros((0,), np.int32), flush=True)
    np.testing.assert_array_equal(big.logits(), small.logits())
    np.testing.assert_array_equal(big.logits(), _offline(prog, x))


# ---------------------------------------------------------------------------
# Golden equivalence: streaming == offline executor
# ---------------------------------------------------------------------------

def test_stream_matches_offline_full_clip(smoke):
    spec, weights, thresholds, prog = smoke
    plan = plan_stream(spec)
    x = _clip(spec, 2)
    st = StreamState(plan, weights, thresholds)
    i = 0
    for sz in itertools.cycle([37, 200, 111, 64, 5]):  # ragged chunks
        st.advance(x[i : i + sz])
        i += sz
        if i >= spec.in_len:
            break
    st.advance(x[i:] if i < spec.in_len else np.zeros((0,), np.int32),
               flush=True)
    np.testing.assert_array_equal(st.logits(), _offline(prog, x))


@pytest.mark.parametrize("prefix", [320, 520, 648])
def test_stream_peek_matches_offline_prefix(smoke, prefix):
    """Per-frame logits contract: peek after audio[:L] == offline run on
    audio[:L] (same weights, shorter program)."""
    spec, weights, thresholds, _ = smoke
    x = _clip(spec, 3)
    spec_l = kws.build_kws_spec(in_len=prefix, width=16)
    prog_l = compiler.compile_model(spec_l, weights, thresholds)
    st = StreamState(plan_stream(spec), weights, thresholds)
    st.advance(x[: prefix - 100])
    st.advance(x[prefix - 100 : prefix])
    np.testing.assert_array_equal(
        st.peek_logits(), _offline(prog_l, x[:prefix])
    )
    assert not st.flushed  # peek is non-destructive
    st.advance(x[prefix:], flush=True)  # stream continues normally


# ---------------------------------------------------------------------------
# Scheduler: continuous batching, join/leave mid-batch
# ---------------------------------------------------------------------------

def test_scheduler_join_leave_mid_batch(smoke):
    spec, weights, thresholds, prog = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=3,
                            hop_frames=1, emit_logits=False)
    clips = {j: _clip(spec, 10 + j) for j in range(4)}
    want = {j: _offline(prog, clips[j]) for j in range(4)}

    a = sched.add_stream()
    b = sched.add_stream()
    sched.push_audio(a, clips[0][:500])
    sched.push_audio(b, clips[1][:200])  # b is a straggler
    sched.run_until_starved()

    # c joins while a/b are mid-flight
    c = sched.add_stream()
    sched.push_audio(c, clips[2])
    sched.push_audio(a, clips[0][500:])
    sched.run_until_starved()

    # a leaves; its slot is recycled by d mid-run
    res_a = sched.close_stream(a)
    np.testing.assert_array_equal(res_a.logits, want[0])
    d = sched.add_stream()
    sched.push_audio(d, clips[3])
    sched.push_audio(b, clips[1][200:])
    sched.run_until_starved()

    for sid, j in ((b, 1), (c, 2), (d, 3)):
        res = sched.close_stream(sid)
        np.testing.assert_array_equal(res.logits, want[j])
    assert sched.active == []


def test_scheduler_peek_matches_offline_prefix(smoke):
    spec, weights, thresholds, _ = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=2,
                            emit_logits=False)
    x = _clip(spec, 20)
    prefix = 520
    spec_l = kws.build_kws_spec(in_len=prefix, width=16)
    off = _offline(compiler.compile_model(spec_l, weights, thresholds),
                   x[:prefix])
    sid = sched.add_stream()
    sched.push_audio(sid, x[:prefix])
    sched.run_until_starved()  # leaves a sub-hop remainder in the inbox
    np.testing.assert_array_equal(sched.peek(sid), off)


def test_scheduler_capacity_enforced(smoke):
    spec, weights, thresholds, _ = smoke
    sched = StreamScheduler(spec, weights, thresholds, capacity=1)
    sched.add_stream()
    with pytest.raises(MemoryError):
        sched.add_stream()


# ---------------------------------------------------------------------------
# Batched Pallas kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "b,l,cin,cout,k,stride,pad,pool",
    [
        (3, 40, 8, 16, 3, 1, 1, 1),
        (8, 32, 24, 40, 3, 1, 1, 2),
        (5, 66, 16, 20, 5, 2, 2, 1),
    ],
)
def test_bnn_conv1d_batched_kernel(b, l, cin, cout, k, stride, pad, pool):
    x = jnp.array(RNG.integers(0, 2, (b, l, cin)), jnp.uint32)
    w = jnp.array(RNG.integers(-1, 2, (k, cin, cout)), jnp.int32)
    thr = jnp.array(RNG.normal(0, 2, (cout,)), jnp.float32)
    flip = jnp.array(RNG.integers(0, 2, (cout,)), bool)
    raw = ops.bnn_conv1d_batched(x, w, stride=stride, pad=pad, mode="raw")
    np.testing.assert_array_equal(
        np.asarray(raw),
        np.asarray(ref.ref_bnn_conv1d_batched(x, w, stride, pad)),
    )
    sa = ops.bnn_conv1d_batched(x, w, thr, flip, stride=stride, pad=pad,
                                pool=pool)
    want = jnp.stack([
        ref.ref_bnn_conv1d_sa(x[i], w, thr, flip, stride, pad, pool)
        for i in range(b)
    ])
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(want))


def test_scheduler_pallas_backend_matches_offline(smoke):
    spec, weights, thresholds, prog = smoke
    x = _clip(spec, 30)
    sched = StreamScheduler(spec, weights, thresholds, capacity=2,
                            hop_frames=4, backend="pallas",
                            emit_logits=False)
    sid = sched.add_stream()
    sched.push_audio(sid, x)
    sched.run_until_starved()
    res = sched.close_stream(sid)
    np.testing.assert_array_equal(res.logits, _offline(prog, x))


# ---------------------------------------------------------------------------
# Detector hysteresis
# ---------------------------------------------------------------------------

def _logit(cls: int, strength: float = 30.0, n: int = 12) -> np.ndarray:
    z = np.zeros(n)
    z[cls] = strength
    return z


def test_detector_fires_once_per_utterance():
    cfg = DetectorConfig(smooth_frames=2, on_threshold=0.6,
                         off_threshold=0.4, refractory_frames=5)
    det = PosteriorDetector(0, cfg)
    events = []
    for f in range(10):  # sustained keyword: must fire exactly once
        e = det.update(f, _logit(3))
        if e:
            events.append(e)
    assert [e.cls for e in events] == [3]
    assert events[0].score >= cfg.on_threshold


def test_detector_refractory_blocks_refire():
    cfg = DetectorConfig(smooth_frames=1, on_threshold=0.6,
                         off_threshold=0.4, refractory_frames=8)
    det = PosteriorDetector(0, cfg)
    assert det.update(0, _logit(2)) is not None
    # dips below off-threshold immediately, but refractory still holds
    assert det.update(1, _logit(11)) is None
    assert det.update(2, _logit(2)) is None  # inside refractory: no refire
    # silence until refractory expires, then a new utterance fires again
    for f in range(3, 9):
        assert det.update(f, _logit(11)) is None
    e = det.update(9, _logit(5))
    assert e is not None and e.cls == 5


def test_detector_no_fire_before_window_full():
    # a confident-wrong first frame (right after priming) must not bypass
    # the smoother just because the window is still partial
    cfg = DetectorConfig(smooth_frames=4, on_threshold=0.6,
                         off_threshold=0.4, refractory_frames=4)
    det = PosteriorDetector(0, cfg)
    assert det.update(0, _logit(3)) is None
    assert det.update(1, _logit(11)) is None
    assert det.events == []


def test_detector_smoothing_suppresses_single_frame_glitch():
    cfg = DetectorConfig(smooth_frames=4, on_threshold=0.6,
                         off_threshold=0.4, refractory_frames=4)
    det = PosteriorDetector(0, cfg)
    for f in range(4):
        assert det.update(f, _logit(11)) is None
    # one glitch frame inside a 4-frame window: smoothed posterior ~0.25
    assert det.update(4, _logit(6)) is None
    assert det.update(5, _logit(11)) is None
    assert det.events == []


def test_detector_hysteresis_rearm_requires_decay():
    cfg = DetectorConfig(smooth_frames=1, on_threshold=0.6,
                         off_threshold=0.4, refractory_frames=2)
    det = PosteriorDetector(0, cfg)
    assert det.update(0, _logit(1)) is not None
    # posterior stays above off_threshold long past refractory: still held
    for f in range(1, 10):
        assert det.update(f, _logit(1)) is None
    # decays -> re-arms -> new event
    assert det.update(10, _logit(11)) is None
    e = det.update(11, _logit(1))
    assert e is not None and e.frame == 11
