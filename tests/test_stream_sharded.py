"""Mesh-sharded streaming runtime: the sharded scheduler must be bit-exact
with the single-device scheduler for identical stream traces — full-clip
logits, per-hop logits, mid-hop peeks, join/leave churn, and elastic
resize boundaries — across 1-, 2- and 8-shard meshes.

Multi-shard cases need a forced multi-device host:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_stream_sharded.py

(the CI multi-device leg); on a 1-device host they skip and the 1-shard
mesh case still proves the mesh path collapses to today's behavior.
"""
import jax
import numpy as np
import pytest

from repro.core import compiler, executor
from repro.launch.mesh import make_stream_mesh
from repro.models import kws
from repro.stream import SlotPlacement, StreamScheduler

SHARD_SWEEP = (1, 2, 8)


@pytest.fixture(scope="module")
def smoke():
    spec = kws.build_kws_smoke_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(spec, weights, thresholds)
    return spec, weights, thresholds, prog


def _mesh(n):
    if jax.device_count() < n:
        pytest.skip(
            f"needs {n} devices (XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n})"
        )
    return make_stream_mesh(n)


def _offline(prog, x):
    return executor.Executor(prog).run(x[:, None]).output.ravel()


def _clip(spec, seed):
    return np.random.default_rng(seed).integers(
        0, 256, (spec.in_len,)
    ).astype(np.uint8)


def _by_sid(outs):
    d = {}
    for sid, frame, logits, _ in outs:
        d.setdefault(sid, []).append((frame, logits))
    return d


def _assert_outs_equal(a, b, stage=""):
    da, db = _by_sid(a), _by_sid(b)
    assert da.keys() == db.keys(), stage
    for sid in da:
        assert len(da[sid]) == len(db[sid]), (stage, sid)
        for (fa, la), (fb, lb) in zip(da[sid], db[sid]):
            assert fa == fb, (stage, sid)
            if la is None or lb is None:
                assert la is None and lb is None, (stage, sid)
            else:
                np.testing.assert_array_equal(la, lb)


# ---------------------------------------------------------------------------
# Placement unit behavior
# ---------------------------------------------------------------------------

def test_placement_least_loaded_alloc_and_balance():
    p = SlotPlacement(4, 2)
    slots = [p.alloc(sid) for sid in range(8)]
    assert sorted(slots) == list(range(8))
    # first 4 streams spread one per shard before any shard takes a second
    assert sorted(p.shard_of(s) for s in slots[:4]) == [0, 1, 2, 3]
    assert p.alloc(99) is None  # full
    p.free(slots[3])
    assert p.alloc(99) == slots[3]  # freed slot's shard is least loaded


def test_placement_single_shard_is_lowest_free_slot():
    # one shard must reproduce the pre-mesh scheduler's slot choice
    p = SlotPlacement(1, 4)
    assert [p.alloc(s) for s in range(3)] == [0, 1, 2]
    p.free(1)
    assert p.alloc(7) == 1


def test_placement_grow_shrink_never_cross_shards():
    p = SlotPlacement(2, 2)
    for sid in range(4):
        p.alloc(sid)
    shard_before = {sid: p.shard_of(p.slots.index(sid)) for sid in range(4)}
    remap = p.grow(4)
    assert p.capacity == 8 and set(remap) == {0, 1, 2, 3}
    for old, new in remap.items():
        assert old // 2 == new // 4  # same shard block
    # occupy the new upper local slots, then vacate the low ones so the
    # shrink has to compact within each shard
    for sid in (4, 5):
        p.alloc(sid)
    for sid in (0, 1):
        p.free(p.slots.index(sid))
    shard_up = {sid: p.shard_of(p.slots.index(sid)) for sid in (2, 3, 4, 5)}
    moves, remap2 = p.shrink(2)
    assert p.capacity == 4 and moves  # compaction actually happened
    for dst, src in moves:
        assert dst // 4 == src // 4  # moves stay inside one old shard block
    for sid in (2, 3, 4, 5):
        slot = p.slots.index(sid)
        assert p.shard_of(slot) == shard_up[sid]
    # every survivor's pre-shrink slot is remapped into the new indexing
    assert set(remap2.values()) == {p.slots.index(sid) for sid in (2, 3, 4, 5)}
    assert shard_before[2] == p.shard_of(p.slots.index(2))


def test_placement_shrink_refuses_overfull_shard():
    p = SlotPlacement(2, 4)
    for sid in range(3):  # least-loaded spreads 2/1
        p.alloc(sid)
    p.alloc(3)
    p.alloc(4)  # shard 0 now holds 3 tenants
    with pytest.raises(ValueError):
        p.shrink(2)


# ---------------------------------------------------------------------------
# Sharded == single-device, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", SHARD_SWEEP)
def test_sharded_full_clip_and_hop_logits_bitexact(smoke, n_shards):
    """Identical traces through a sharded and a single-device scheduler:
    every per-hop logit row and every flushed close() must agree, and both
    must equal the offline executor."""
    spec, weights, thresholds, prog = smoke
    mesh = _mesh(n_shards)
    n = 2 * n_shards
    clips = {j: _clip(spec, 300 + j) for j in range(n)}
    ref = StreamScheduler(spec, weights, thresholds, capacity=n)
    sh = StreamScheduler(spec, weights, thresholds, capacity=n, mesh=mesh)
    for sched in (ref, sh):
        for j in range(n):
            assert sched.add_stream() == j
            sched.push_audio(j, clips[j])
    _assert_outs_equal(ref.run_until_starved(), sh.run_until_starved())
    for j in range(n):
        ra, rb = ref.close_stream(j), sh.close_stream(j)
        np.testing.assert_array_equal(ra.logits, rb.logits)
        np.testing.assert_array_equal(rb.logits, _offline(prog, clips[j]))


@pytest.mark.parametrize("n_shards", SHARD_SWEEP)
def test_sharded_mid_hop_peek_bitexact(smoke, n_shards):
    """peek() with leftover sub-hop samples (exact numpy fallback) and on a
    hop boundary (device finalization tail) both match the single-device
    scheduler and the offline prefix."""
    spec, weights, thresholds, _ = smoke
    mesh = _mesh(n_shards)
    x = _clip(spec, 310)
    prefix = 520  # not hop-aligned: leaves leftover samples in the inbox
    spec_l = kws.build_kws_spec(in_len=prefix, width=16)
    off = _offline(compiler.compile_model(spec_l, weights, thresholds),
                   x[:prefix])
    peeks = {}
    for label, mesh_ in (("ref", None), ("sharded", mesh)):
        sched = StreamScheduler(spec, weights, thresholds,
                                capacity=n_shards, mesh=mesh_)
        sid = sched.add_stream()
        sched.push_audio(sid, x[:prefix])
        sched.run_until_starved()
        assert len(sched._streams[sid].frontend) > 0  # mid-hop leftover
        peeks[label] = sched.peek(sid)
        # drain to a hop boundary: peek now reads the in-jit tail
        sched.push_audio(sid, x[prefix:])
        outs = sched.run_until_starved()
        assert len(sched._streams[sid].frontend) < sched.plan.hop_samples
        peeks[label + "_hop"] = (outs[-1][2], sched.peek(sid))
    np.testing.assert_array_equal(peeks["ref"], off)
    np.testing.assert_array_equal(peeks["sharded"], off)
    np.testing.assert_array_equal(peeks["ref_hop"][0], peeks["sharded_hop"][0])


@pytest.mark.parametrize("n_shards", SHARD_SWEEP)
def test_sharded_churn_and_resize_bitexact(smoke, n_shards):
    """Join/leave churn across elastic grow AND shrink boundaries: the
    sharded elastic pool must emit the same logits as a pinned
    single-device pool, and resizes must stay per-shard."""
    spec, weights, thresholds, prog = smoke
    mesh = _mesh(n_shards)
    n = 4 * n_shards  # ceiling; elastic pool starts at 2 * n_shards
    clips = {j: _clip(spec, 330 + j) for j in range(n)}
    el = StreamScheduler(spec, weights, thresholds, capacity=n, mesh=mesh)
    fx = StreamScheduler(spec, weights, thresholds, capacity=n,
                         initial_capacity=n, min_capacity=n)  # pinned, 1 dev
    assert el.capacity == 2 * n_shards and el.shard_capacity == 2

    def lockstep(stage):
        _assert_outs_equal(el.run_until_starved(), fx.run_until_starved(),
                           stage)

    half = n // 2
    for sched in (el, fx):
        for j in range(half):
            assert sched.add_stream() == j
            sched.push_audio(j, clips[j][:400])
    lockstep("warm")
    assert el.capacity == 2 * n_shards  # no grow yet

    # the second half joins -> elastic pool grows per-shard (2 -> 4 local)
    for sched in (el, fx):
        for j in range(half, n):
            assert sched.add_stream() == j
            sched.push_audio(j, clips[j])
        for j in range(half):
            sched.push_audio(j, clips[j][400:])
    lockstep("grow")
    assert el.capacity == n and el.shard_capacity == 4

    # most streams leave -> pool shrinks; survivors keep streaming
    survivors = list(range(n - max(1, n_shards // 2), n))
    for sched in (el, fx):
        for j in range(n):
            if j in survivors:
                continue
            res = sched.close_stream(j)
            np.testing.assert_array_equal(
                res.logits, _offline(prog, clips[j])
            )
    assert el.capacity < n  # actually shrank
    for sched in (el, fx):
        for j in survivors:
            sched.push_audio(j, clips[j][:0])  # no-op keeps traces aligned
    lockstep("shrink")
    for sched in (el, fx):
        for j in survivors:
            res = sched.close_stream(j)
            np.testing.assert_array_equal(
                res.logits, _offline(prog, clips[j])
            )
    caps = [c for _, c in el.metrics.capacity_events]
    assert any(c == n for c in caps) and caps[-1] < n  # grew and shrank


@pytest.mark.parametrize("n_shards", [2, 8])
def test_sharded_pallas_backend_matches_jnp(smoke, n_shards):
    """The shard_map kernel entry points emit the same per-hop logits as
    the GSPMD-partitioned jnp path."""
    spec, weights, thresholds, _ = smoke
    mesh = _mesh(n_shards)
    x = _clip(spec, 350)
    outs = {}
    for backend in ("jnp", "pallas"):
        sched = StreamScheduler(spec, weights, thresholds,
                                capacity=n_shards, hop_frames=4,
                                backend=backend, mesh=mesh)
        sid = sched.add_stream()
        sched.push_audio(sid, x)
        outs[backend] = sched.run_until_starved()
    assert len(outs["jnp"]) == len(outs["pallas"]) >= 1
    _assert_outs_equal(outs["jnp"], outs["pallas"])


def test_sharded_capacity_must_divide(smoke):
    spec, weights, thresholds, _ = smoke
    mesh = _mesh(2)
    with pytest.raises(AssertionError):
        StreamScheduler(spec, weights, thresholds, capacity=3, mesh=mesh)


@pytest.mark.parametrize("n_shards", [2, 8])
def test_shard_metrics_cover_all_shards(smoke, n_shards):
    spec, weights, thresholds, _ = smoke
    mesh = _mesh(n_shards)
    sched = StreamScheduler(spec, weights, thresholds,
                            capacity=2 * n_shards, mesh=mesh)
    for j in range(n_shards):
        sched.add_stream()
        sched.push_audio(
            j, _clip(spec, 360 + j)[: sched.plan.prime_samples
                                    + 2 * sched.plan.hop_samples]
        )
    sched.run_until_starved()
    ss = sched.metrics.shard_summary()
    assert ss["n_shards"] == n_shards
    # least-loaded placement spreads one stream per shard
    assert all(p["stream_hops"] == 2 for p in ss["per_shard"])
    assert ss["imbalance"] == pytest.approx(1.0)
    assert ss["fleet_stream_hops"] == 2 * n_shards
